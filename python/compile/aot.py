"""AOT entry point: lower every model variant to an HLO-text artifact.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(behind the ``xla`` crate) rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Outputs, per variant ``name``:
  artifacts/<name>.hlo.txt   — HLO text, lowered with return_tuple=True
  artifacts/<name>.meta.json — interface description (inputs/outputs,
                               kinds, shapes, dtypes, hyperparams)
and a global artifacts/manifest.json.

Python runs ONLY here (build time); the Rust coordinator is self-contained
once artifacts exist.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from .models import MODELS

DEFAULT_VARIANTS = [
    "qp4",
    "qp32",
    "mlr_mnist",
    "mlr_covtype",
    "mf_movielens",
    "mf_jester",
    "cnn_mnist",
    "tfm_tiny",
    "tfm_small",
]
LARGE_VARIANTS = ["tfm_100m"]


def variant_index():
    idx = {}
    for model_name, mod in MODELS.items():
        for variant, cfg in mod.configs().items():
            idx[variant] = (model_name, mod, cfg)
    return idx


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(variant: str, outdir: str) -> dict:
    model_name, mod, cfg = variant_index()[variant]
    step, example, meta = mod.build(cfg)
    specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in example]
    lowered = jax.jit(step).lower(*specs)
    text = to_hlo_text(lowered)

    hlo_path = os.path.join(outdir, f"{variant}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(text)

    # Default dtype is f32; models mark exceptions explicitly.
    for entry in meta["inputs"] + meta["outputs"]:
        entry.setdefault("dtype", "f32")
    meta.update(
        {
            "name": variant,
            "model": model_name,
            "config": cfg,
            "hlo": f"{variant}.hlo.txt",
            "hlo_sha256": hashlib.sha256(text.encode()).hexdigest(),
        }
    )
    with open(os.path.join(outdir, f"{variant}.meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return {"variant": variant, "model": model_name, "hlo_bytes": len(text)}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--variants", nargs="*", default=None)
    ap.add_argument("--large", action="store_true", help="also lower tfm_100m")
    # Back-compat with the original scaffold Makefile invocation.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    outdir = args.outdir if args.out is None else os.path.dirname(args.out) or "."
    os.makedirs(outdir, exist_ok=True)

    variants = args.variants or list(DEFAULT_VARIANTS)
    if args.large:
        variants += LARGE_VARIANTS

    entries = []
    for v in variants:
        entry = lower_variant(v, outdir)
        entries.append(entry)
        print(f"lowered {v}: {entry['hlo_bytes']} bytes", file=sys.stderr)

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump({"artifacts": entries}, f, indent=1)
    print(f"wrote {len(entries)} artifacts to {outdir}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
