"""Fused multinomial-logistic-regression gradient Pallas kernel (L1).

Computes, in one pass over the batch,

    logits = X @ W                  (tile-local matmul)
    p      = softmax(logits)        (on-chip, row-wise, numerically safe)
    grad   = X^T @ (p - Y) / B      (accumulated across batch tiles)
    loss   = -sum(Y * log p) / B    (accumulated across batch tiles)

i.e. the entire SGD inner loop of the paper's MLR workload (§5.1) fused
into a single kernel: one read of X per tile, no logits/probability
round-trip through HBM.

The grid walks batch tiles; ``W`` (d x k) stays resident in VMEM across
the whole grid (for the paper's MLR shapes d*k is 784x10 / 54x7 — a few
tens of KB, far under the ~16 MiB VMEM budget; see EXPERIMENTS.md §Perf
for the footprint table). interpret=True for CPU-PJRT execution.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mlr_grad_kernel(x_ref, w_ref, y_ref, g_ref, loss_ref, *, batch: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    x = x_ref[...]  # (bb, d)
    w = w_ref[...]  # (d, k)
    y = y_ref[...]  # (bb, k)

    logits = jnp.dot(x, w, preferred_element_type=jnp.float32)
    zmax = jnp.max(logits, axis=1, keepdims=True)
    z = logits - zmax
    ez = jnp.exp(z)
    denom = jnp.sum(ez, axis=1, keepdims=True)
    p = ez / denom
    # Cross-entropy via logsumexp for stability: -sum(y * (z - log denom)).
    logp = z - jnp.log(denom)

    inv_b = 1.0 / batch
    g_ref[...] += jnp.dot(x.T, (p - y), preferred_element_type=jnp.float32) * inv_b
    loss_ref[...] += -jnp.sum(y * logp, keepdims=False)[None] * inv_b


@functools.partial(jax.jit, static_argnames=("bb",))
def mlr_grad_pallas(x, w, y, bb: int = 128):
    """Fused MLR gradient + mean cross-entropy loss.

    Args:
      x: (B, d) batch inputs.
      w: (d, k) weights.
      y: (B, k) one-hot labels.
      bb: batch tile size (must divide B after clamping).

    Returns:
      (grad (d, k), loss (1,)) — both fp32.
    """
    b, d = x.shape
    _, k = w.shape
    if y.shape != (b, k):
        raise ValueError(f"mlr_grad: y shape {y.shape} != {(b, k)}")
    bb = min(bb, b)
    while b % bb:
        bb -= 1
    grid = (b // bb,)
    return pl.pallas_call(
        functools.partial(_mlr_grad_kernel, batch=b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, d), lambda i: (i, 0)),
            pl.BlockSpec((d, k), lambda i: (0, 0)),
            pl.BlockSpec((bb, k), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((d, k), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, k), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=True,
    )(x, w, y)
