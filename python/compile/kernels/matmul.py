"""Blocked Pallas matmul kernel (L1) with a custom VJP.

This is the dense hot-spot kernel shared by the transformer and CNN
fully-connected layers. It is written TPU-idiomatically — tiles sized for
the MXU (multiples of 128 where the problem allows), fp32 accumulation,
a (M/bm, N/bn, K/bk) grid expressing the HBM->VMEM schedule via BlockSpec
— but is lowered with ``interpret=True`` because the CPU PJRT plugin
cannot execute Mosaic custom-calls (see DESIGN.md §Hardware adaptation).

``matmul`` carries a custom VJP whose backward pass re-uses the same
kernel (dA = g @ B^T, dB = A^T @ g), so the kernel stays on the hot path
under ``jax.grad``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target.

    Prefers MXU-friendly power-of-two tiles. Falls back to ``dim`` itself
    for small or prime dimensions (the whole axis fits in one block).
    """
    if dim <= target:
        return dim
    for cand in (target, target // 2, target // 4, target // 8):
        if cand >= 1 and dim % cand == 0:
            return cand
    # No friendly divisor: single block over the axis.
    return dim


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (bm, bn) output tile; grid axis 2 walks the K blocks."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def _matmul_unpadded(a, b, bm, bn, bk):
    m, k = a.shape
    _, n = b.shape
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)


def matmul_pallas(a: jax.Array, b: jax.Array, *, bm=128, bn=128, bk=128):
    """Blocked matmul. Pads ragged shapes up to block multiples."""
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"matmul_pallas: bad shapes {a.shape} @ {b.shape}")
    m, k = a.shape
    _, n = b.shape
    bm, bn, bk = _pick_block(m, bm), _pick_block(n, bn), _pick_block(k, bk)
    # _pick_block guarantees divisibility unless it fell back to the full
    # axis, which also divides. So no padding is needed here; keep the pad
    # path anyway for callers that request explicit non-dividing blocks.
    if m % bm or n % bn or k % bk:
        pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
        a = jnp.pad(a, ((0, pm), (0, pk)))
        b = jnp.pad(b, ((0, pk), (0, pn)))
        out = _matmul_unpadded(a, b, bm, bn, bk)
        return out[:m, :n]
    return _matmul_unpadded(a, b, bm, bn, bk)


@jax.custom_vjp
def matmul(a, b):
    """Differentiable blocked-Pallas matmul (fp32)."""
    return matmul_pallas(a, b)


def _matmul_fwd(a, b):
    return matmul_pallas(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    return matmul_pallas(g, b.T), matmul_pallas(a.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)
