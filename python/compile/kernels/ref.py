"""Pure-jnp oracles for the Pallas kernels (build-time correctness only).

These are the ground truth the pytest/hypothesis suites compare against.
They are deliberately written in the most obvious way possible — no
tiling, no fusion — so that a mismatch unambiguously implicates the
kernel, not the reference.
"""

import jax.numpy as jnp


def matmul_ref(a, b):
    """Plain fp32 matmul."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def softmax_ref(z, axis=-1):
    z = z - jnp.max(z, axis=axis, keepdims=True)
    ez = jnp.exp(z)
    return ez / jnp.sum(ez, axis=axis, keepdims=True)


def mlr_loss_ref(x, w, y):
    """Mean softmax cross-entropy of one-hot labels ``y``."""
    logits = x @ w
    z = logits - jnp.max(logits, axis=1, keepdims=True)
    logp = z - jnp.log(jnp.sum(jnp.exp(z), axis=1, keepdims=True))
    return -jnp.mean(jnp.sum(y * logp, axis=1))


def mlr_grad_ref(x, w, y):
    """(grad, loss) of mean softmax cross-entropy w.r.t. ``w``."""
    b = x.shape[0]
    p = softmax_ref(x @ w, axis=1)
    grad = x.T @ (p - y) / b
    return grad, mlr_loss_ref(x, w, y)
