"""QP model (Fig 3): gradient descent on a d-dimensional quadratic.

loss(x) = 0.5 (x - b)^T A (x - b), A SPD, supplied by the coordinator so
that Rust controls the problem instance (conditioning determines the
contraction rate c in Theorem 3.2).
"""

import jax.numpy as jnp

from .common import io


def configs():
    return {
        "qp4": {"dim": 4, "lr": 0.05},
        "qp32": {"dim": 32, "lr": 0.02},
    }


def build(cfg):
    d = cfg["dim"]
    lr = cfg["lr"]

    def step(x, a, b):
        r = x - b
        grad = a @ r
        loss = 0.5 * jnp.dot(r, a @ r)
        return (x - lr * grad, loss[None])

    example = (
        jnp.zeros((d,), jnp.float32),
        jnp.eye(d, dtype=jnp.float32),
        jnp.zeros((d,), jnp.float32),
    )
    meta = {
        "inputs": [
            io("x", "param", (d,)),
            io("a", "data", (d, d)),
            io("b", "data", (d,)),
        ],
        "outputs": [io("x", "param", (d,)), io("loss", "metric", (1,))],
        "hyper": {"lr": lr},
    }
    return step, example, meta
