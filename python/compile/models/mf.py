"""Matrix factorization trained with alternating least squares (§5.1).

One artifact step performs a full ALS sweep: solve L rows given R, then R
columns given the new L. Inner solves use batched fixed-iteration CG on
the regularized normal equations (see common.cg_solve_batched for why
this — and not jnp.linalg.solve — is the AOT-safe formulation).

Variants mirror the paper's datasets:
  - movielens-like: 671 x 1200 ratings at ~1.7% density, rank 20
    (movielens-small is 671 users x 9125 items; we shrink the item axis
    to keep the dense-mask Gram einsum CPU-tractable — see DESIGN.md §3).
  - jester-like: 7200 x 140 at ~56% density, rank 5.
"""

import jax.numpy as jnp

from .common import cg_solve_batched, io


def configs():
    # Damped ALS: each sweep moves the factors a fraction `relax` toward
    # the regularized least-squares solution. Undamped exact ALS collapses
    # our synthetic problems to the noise floor in <10 sweeps, leaving no
    # iteration-cost signal; damping (standard practice for distributed MF
    # stability) restores the paper's ~60-iteration convergence horizon
    # (App. C) with a smooth geometric rate.
    return {
        "mf_movielens": {"m": 671, "n": 1200, "rank": 20, "reg": 0.1, "cg_iters": 8, "relax": 0.18},
        "mf_jester": {"m": 1200, "n": 140, "rank": 5, "reg": 0.1, "cg_iters": 8, "relax": 0.15},
    }


def build(cfg):
    m, n, p = cfg["m"], cfg["n"], cfg["rank"]
    reg, iters = cfg["reg"], cfg["cg_iters"]
    relax = cfg["relax"]

    def step(l, r, ratings, mask):
        # --- solve for L given R ---------------------------------------
        # grams[i] = sum_j mask[i,j] * r_j r_j^T   (r_j is column j of R)
        grams_l = jnp.einsum("ij,pj,qj->ipq", mask, r, r)
        rhs_l = (mask * ratings) @ r.T  # (m, p)
        l_star = cg_solve_batched(grams_l, rhs_l, l, iters, reg)
        l_new = l + relax * (l_star - l)
        # --- solve for R given L ----------------------------------------
        grams_r = jnp.einsum("ij,ip,iq->jpq", mask, l_new, l_new)
        rhs_r = (mask * ratings).T @ l_new  # (n, p)
        r_star = cg_solve_batched(grams_r, rhs_r, r.T, iters, reg).T  # (p, n)
        r_new = r + relax * (r_star - r)
        # --- masked MSE --------------------------------------------------
        err = mask * (l_new @ r_new - ratings)
        nnz = jnp.maximum(jnp.sum(mask), 1.0)
        loss = jnp.sum(err * err) / nnz
        return (l_new, r_new, loss[None])

    example = (
        jnp.zeros((m, p), jnp.float32),
        jnp.zeros((p, n), jnp.float32),
        jnp.zeros((m, n), jnp.float32),
        jnp.zeros((m, n), jnp.float32),
    )
    meta = {
        "inputs": [
            io("l", "param", (m, p)),
            io("r", "param", (p, n)),
            io("ratings", "data", (m, n)),
            io("mask", "data", (m, n)),
        ],
        "outputs": [
            io("l", "param", (m, p)),
            io("r", "param", (p, n)),
            io("loss", "metric", (1,)),
        ],
        "hyper": {"reg": reg},
        "atoms": {"l": "rows", "r": "cols"},
    }
    return step, example, meta
