"""Shared L2 building blocks: Adam, batched conjugate gradients, meta spec.

The CG solver exists so that the ALS artifact contains only matmul-class
HLO ops: ``jnp.linalg.solve`` lowers to LAPACK custom-calls on CPU, which
xla_extension 0.5.1 (the version behind the ``xla`` crate) does not
register. A fixed-iteration matrix-free CG on the SPD normal equations is
numerically equivalent for our well-conditioned, regularized systems and
round-trips through HLO text cleanly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def adam_update(p, g, m, v, t, lr, b1=0.9, b2=0.999, eps=1e-8):
    """One Adam step for a single tensor; ``t`` is the 1-based step."""
    m = b1 * m + (1.0 - b1) * g
    v = b2 * v + (1.0 - b2) * g * g
    mhat = m / (1.0 - b1**t)
    vhat = v / (1.0 - b2**t)
    return p - lr * mhat / (jnp.sqrt(vhat) + eps), m, v


def adam_update_tree(params, grads, ms, vs, t, lr):
    """Adam over pytrees; returns (params, ms, vs)."""
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(ms)
    flat_v = treedef.flatten_up_to(vs)
    out = [adam_update(p, g, m, v, t, lr) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, new_m, new_v


def cg_solve_batched(grams, rhs, x0, iters: int, reg: float):
    """Solve (grams[i] + reg*I) x[i] = rhs[i] for a batch of SPD systems.

    grams: (B, p, p), rhs/x0: (B, p). Fixed ``iters`` CG iterations (no
    early exit — shapes must be static for AOT lowering). Warm-starting
    from ``x0`` (the current ALS factors) both speeds convergence and
    keeps the factors live inputs of the lowered artifact (jax prunes
    unused parameters, which would break the L3 state contract).
    """

    def matvec(x):
        return jnp.einsum("bpq,bq->bp", grams, x) + reg * x

    x = x0
    r = rhs - matvec(x)
    p = r
    rs = jnp.sum(r * r, axis=1)

    def body(_, state):
        x, r, p, rs = state
        ap = matvec(p)
        denom = jnp.sum(p * ap, axis=1)
        alpha = rs / jnp.maximum(denom, 1e-30)
        x = x + alpha[:, None] * p
        r = r - alpha[:, None] * ap
        rs_new = jnp.sum(r * r, axis=1)
        beta = rs_new / jnp.maximum(rs, 1e-30)
        p = r + beta[:, None] * p
        return x, r, p, rs_new

    x, _, _, _ = jax.lax.fori_loop(0, iters, body, (x, r, p, rs))
    return x


def io(name: str, kind: str, shape) -> dict:
    """One entry of the artifact interface description."""
    return {"name": name, "kind": kind, "shape": [int(s) for s in shape]}
