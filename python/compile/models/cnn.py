"""CNN from the paper (§5.1): 2x [conv5x5 + ReLU + maxpool2] + 3 FC, Adam.

The three fully-connected layers run through the blocked Pallas matmul
(L1, custom-VJP) so that both the forward and backward FC matmuls stay on
the kernel path under ``jax.grad``. Convolutions use
``lax.conv_general_dilated`` (native stablehlo convolutions; their
transposed-gradient forms are also plain convolutions, which XLA-CPU
0.5.1 executes natively).

Adam first/second moments are separate ``opt``-kind tensors mirroring the
params; the coordinator co-partitions them with their parameter atoms
(paper §5.1 "by-layer"/"by-shard" partitioning includes optimizer state).
"""

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels.matmul import matmul
from .common import adam_update, io


def configs():
    return {
        "cnn_mnist": {
            "batch": 64,
            "image": 28,
            "c1": 8,
            "c2": 16,
            "f1": 128,
            "f2": 64,
            "classes": 10,
            "lr": 1e-3,
        }
    }


def param_shapes(cfg):
    im, c1, c2, f1, f2, k = (
        cfg["image"],
        cfg["c1"],
        cfg["c2"],
        cfg["f1"],
        cfg["f2"],
        cfg["classes"],
    )
    flat = (im // 4) * (im // 4) * c2
    return [
        ("c1w", (5, 5, 1, c1)),
        ("c1b", (c1,)),
        ("c2w", (5, 5, c1, c2)),
        ("c2b", (c2,)),
        ("f1w", (flat, f1)),
        ("f1b", (f1,)),
        ("f2w", (f1, f2)),
        ("f2b", (f2,)),
        ("f3w", (f2, k)),
        ("f3b", (k,)),
    ]


def _conv(x, w, b):
    out = lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return jax.nn.relu(out + b)


def _maxpool2(x):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def forward(params, x):
    h = _maxpool2(_conv(x, params["c1w"], params["c1b"]))
    h = _maxpool2(_conv(h, params["c2w"], params["c2b"]))
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(matmul(h, params["f1w"]) + params["f1b"])
    h = jax.nn.relu(matmul(h, params["f2w"]) + params["f2b"])
    return matmul(h, params["f3w"]) + params["f3b"]


def loss_fn(params, x, y):
    logits = forward(params, x)
    z = logits - jnp.max(logits, axis=1, keepdims=True)
    logp = z - jnp.log(jnp.sum(jnp.exp(z), axis=1, keepdims=True))
    return -jnp.mean(jnp.sum(y * logp, axis=1))


def build(cfg):
    shapes = param_shapes(cfg)
    b, im, k = cfg["batch"], cfg["image"], cfg["classes"]
    lr = cfg["lr"]
    n = len(shapes)

    def step(*args):
        params = {name: a for (name, _), a in zip(shapes, args[:n])}
        ms = {name: a for (name, _), a in zip(shapes, args[n : 2 * n])}
        vs = {name: a for (name, _), a in zip(shapes, args[2 * n : 3 * n])}
        t, x, y = args[3 * n], args[3 * n + 1], args[3 * n + 2]
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        outs = []
        new_p, new_m, new_v = {}, {}, {}
        for name, _ in shapes:
            p2, m2, v2 = adam_update(params[name], grads[name], ms[name], vs[name], t[0], lr)
            new_p[name], new_m[name], new_v[name] = p2, m2, v2
        for d in (new_p, new_m, new_v):
            outs.extend(d[name] for name, _ in shapes)
        outs.append(loss[None])
        return tuple(outs)

    example = (
        [jnp.zeros(s, jnp.float32) for _, s in shapes] * 3
        + [
            jnp.ones((1,), jnp.float32),
            jnp.zeros((b, im, im, 1), jnp.float32),
            jnp.zeros((b, k), jnp.float32),
        ]
    )
    inputs = (
        [io(nm, "param", s) for nm, s in shapes]
        + [io(f"m_{nm}", "opt", s) for nm, s in shapes]
        + [io(f"v_{nm}", "opt", s) for nm, s in shapes]
        + [
            io("t", "data", (1,)),
            io("x", "data", (b, im, im, 1)),
            io("y", "data", (b, k)),
        ]
    )
    outputs = (
        [io(nm, "param", s) for nm, s in shapes]
        + [io(f"m_{nm}", "opt", s) for nm, s in shapes]
        + [io(f"v_{nm}", "opt", s) for nm, s in shapes]
        + [io("loss", "metric", (1,))]
    )
    meta = {
        "inputs": inputs,
        "outputs": outputs,
        "hyper": {"lr": lr},
        # by-layer atoms: each (w, b) pair is one atom; by-shard handled in
        # rust by subdividing tensors along the first dim.
        "atoms": {"scheme": "cnn"},
    }
    return step, tuple(example), meta
