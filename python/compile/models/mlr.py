"""Multinomial logistic regression trained with SGD (paper §5.1).

The gradient + loss are computed by the fused Pallas kernel
``kernels.mlr_grad`` (L1), so this artifact's hot loop *is* the kernel.
Variants mirror the paper's two datasets:

  - mnist-like:     d=784, k=10, batch=10000 is the paper's setting; we
                    default to 2048 to keep 100-trial sweeps tractable on
                    the CPU PJRT backend (documented in DESIGN.md §3).
  - covertype-like: d=54,  k=7,  batch=1000.
"""

import jax.numpy as jnp

from ..kernels.mlr_grad import mlr_grad_pallas
from .common import io


def configs():
    return {
        "mlr_mnist": {"dim": 784, "classes": 10, "batch": 2048, "lr": 1e-1, "bb": 256},
        "mlr_covtype": {"dim": 54, "classes": 7, "batch": 1000, "lr": 1e-2, "bb": 200},
    }


def build(cfg):
    d, k, b, bb = cfg["dim"], cfg["classes"], cfg["batch"], cfg["bb"]
    lr = cfg["lr"]

    def step(w, x, y):
        grad, loss = mlr_grad_pallas(x, w, y, bb=bb)
        return (w - lr * grad, loss)

    example = (
        jnp.zeros((d, k), jnp.float32),
        jnp.zeros((b, d), jnp.float32),
        jnp.zeros((b, k), jnp.float32),
    )
    meta = {
        "inputs": [
            io("w", "param", (d, k)),
            io("x", "data", (b, d)),
            io("y", "data", (b, k)),
        ],
        "outputs": [io("w", "param", (d, k)), io("loss", "metric", (1,))],
        "hyper": {"lr": lr},
        "atoms": {"w": "rows"},
    }
    return step, example, meta
