"""L2 model definitions (build-time JAX; lowered once to HLO artifacts).

Each submodule exposes:

  configs() -> {variant_name: cfg_dict}
  build(cfg) -> (step_fn, example_args, meta)

where ``step_fn(*args)`` returns a flat tuple whose leading entries are
the updated ``param``/``opt`` tensors (same order as the inputs of those
kinds) followed by a ``(1,)`` loss. ``meta`` is the JSON-serializable
interface description consumed by the Rust runtime (see
rust/src/runtime/artifact.rs).
"""

from . import cnn, mf, mlr, qp, transformer  # noqa: F401

MODELS = {
    "qp": qp,
    "mlr": mlr,
    "mf": mf,
    "cnn": cnn,
    "transformer": transformer,
}
