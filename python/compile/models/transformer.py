"""Decoder-only transformer LM trained with Adam (end-to-end driver).

This is the repo's training-systems validation workload (system-prompt
requirement): a real multi-layer transformer whose training loop runs
entirely from the Rust coordinator against this AOT artifact, under SCAR
checkpointing with injected PS failures.

Layers are stacked along a leading axis and iterated with ``lax.scan`` so
the artifact stays compact (14 parameter tensors regardless of depth).
Dense projections can optionally route through the Pallas blocked matmul;
the default keeps them as einsums because interpret-mode Pallas inside a
scanned layer multiplies CPU wallclock without changing the lowered
structure on a real TPU (DESIGN.md §Hardware adaptation).

Variants:
  tfm_tiny  (~0.9M params)  — CI / tests
  tfm_small (~6.4M params)  — default e2e driver
  tfm_100m  (~102M params)  — paper-scale config (compile-only on CPU CI)
"""

import jax
import jax.numpy as jnp
from jax import lax

from .common import adam_update, io


def configs():
    return {
        "tfm_tiny": {
            "vocab": 256, "d": 64, "layers": 2, "heads": 2, "ff": 128,
            "seq": 32, "batch": 8, "lr": 1e-3,
        },
        "tfm_small": {
            "vocab": 1024, "d": 256, "layers": 4, "heads": 4, "ff": 1024,
            "seq": 128, "batch": 8, "lr": 3e-4,
        },
        "tfm_100m": {
            "vocab": 8192, "d": 768, "layers": 12, "heads": 12, "ff": 3072,
            "seq": 256, "batch": 4, "lr": 3e-4,
        },
    }


def param_shapes(cfg):
    v, d, nl, f, s = cfg["vocab"], cfg["d"], cfg["layers"], cfg["ff"], cfg["seq"]
    return [
        ("emb", (v, d)),
        ("pos", (s, d)),
        ("ln1g", (nl, d)),
        ("ln1b", (nl, d)),
        ("wqkv", (nl, d, 3 * d)),
        ("wo", (nl, d, d)),
        ("ln2g", (nl, d)),
        ("ln2b", (nl, d)),
        ("w1", (nl, d, f)),
        ("b1", (nl, f)),
        ("w2", (nl, f, d)),
        ("b2", (nl, d)),
        ("lnfg", (d,)),
        ("lnfb", (d,)),
    ]


def _layernorm(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * lax.rsqrt(var + 1e-5) * g + b


def forward(params, tokens, cfg):
    d, nh, s = cfg["d"], cfg["heads"], cfg["seq"]
    hd = d // nh
    x = params["emb"][tokens] + params["pos"][None, :, :]  # (B, S, d)
    mask = jnp.tril(jnp.ones((s, s), jnp.float32))
    neg = jnp.float32(-1e9)

    def layer(h, lp):
        ln1g, ln1b, wqkv, wo, ln2g, ln2b, w1, b1, w2, b2 = lp
        a_in = _layernorm(h, ln1g, ln1b)
        qkv = jnp.einsum("bsd,de->bse", a_in, wqkv)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        bsz = q.shape[0]

        def heads(t):
            return t.reshape(bsz, s, nh, hd).transpose(0, 2, 1, 3)

        q, k, v = heads(q), heads(k), heads(v)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(hd))
        att = jnp.where(mask[None, None] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        o = o.transpose(0, 2, 1, 3).reshape(bsz, s, d)
        h = h + jnp.einsum("bsd,de->bse", o, wo)
        f_in = _layernorm(h, ln2g, ln2b)
        f = jax.nn.relu(jnp.einsum("bsd,df->bsf", f_in, w1) + b1)
        h = h + jnp.einsum("bsf,fd->bsd", f, w2) + b2
        return h, None

    layer_params = (
        params["ln1g"], params["ln1b"], params["wqkv"], params["wo"],
        params["ln2g"], params["ln2b"], params["w1"], params["b1"],
        params["w2"], params["b2"],
    )
    x, _ = lax.scan(layer, x, layer_params)
    x = _layernorm(x, params["lnfg"], params["lnfb"])
    return jnp.einsum("bsd,vd->bsv", x, params["emb"])  # tied unembedding


def loss_fn(params, tokens, targets, cfg):
    logits = forward(params, tokens, cfg)
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    logp = z - jnp.log(jnp.sum(jnp.exp(z), axis=-1, keepdims=True))
    onehot = jax.nn.one_hot(targets, cfg["vocab"], dtype=jnp.float32)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


def build(cfg):
    shapes = param_shapes(cfg)
    n = len(shapes)
    b, s = cfg["batch"], cfg["seq"]
    lr = cfg["lr"]

    def step(*args):
        params = {name: a for (name, _), a in zip(shapes, args[:n])}
        ms = {name: a for (name, _), a in zip(shapes, args[n : 2 * n])}
        vs = {name: a for (name, _), a in zip(shapes, args[2 * n : 3 * n])}
        t, tokens, targets = args[3 * n], args[3 * n + 1], args[3 * n + 2]
        loss, grads = jax.value_and_grad(loss_fn)(params, tokens, targets, cfg)
        outs = []
        new = {}
        for name, _ in shapes:
            new[name] = adam_update(params[name], grads[name], ms[name], vs[name], t[0], lr)
        outs.extend(new[name][0] for name, _ in shapes)
        outs.extend(new[name][1] for name, _ in shapes)
        outs.extend(new[name][2] for name, _ in shapes)
        outs.append(loss[None])
        return tuple(outs)

    example = tuple(
        [jnp.zeros(sh, jnp.float32) for _, sh in shapes] * 3
        + [
            jnp.ones((1,), jnp.float32),
            jnp.zeros((b, s), jnp.int32),
            jnp.zeros((b, s), jnp.int32),
        ]
    )
    inputs = (
        [io(nm, "param", sh) for nm, sh in shapes]
        + [io(f"m_{nm}", "opt", sh) for nm, sh in shapes]
        + [io(f"v_{nm}", "opt", sh) for nm, sh in shapes]
        + [
            io("t", "data", (1,)),
            {"name": "tokens", "kind": "data", "shape": [b, s], "dtype": "i32"},
            {"name": "targets", "kind": "data", "shape": [b, s], "dtype": "i32"},
        ]
    )
    outputs = (
        [io(nm, "param", sh) for nm, sh in shapes]
        + [io(f"m_{nm}", "opt", sh) for nm, sh in shapes]
        + [io(f"v_{nm}", "opt", sh) for nm, sh in shapes]
        + [io("loss", "metric", (1,))]
    )
    meta = {
        "inputs": inputs,
        "outputs": outputs,
        "hyper": {"lr": lr, "vocab": cfg["vocab"], "seq": s, "batch": b},
        "atoms": {"scheme": "stacked"},
    }
    return step, example, meta
