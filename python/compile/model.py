"""L2 facade: re-exports the model zoo for tests and the AOT driver.

The actual definitions live in ``compile.models.*`` (one module per
paper workload — QP, MLR, MF-ALS, CNN, Transformer); this module exists
so ``from compile import model; model.MODELS`` is the single entry point.
"""

from .models import MODELS, cnn, mf, mlr, qp, transformer  # noqa: F401
