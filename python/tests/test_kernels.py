"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (and dtypes for the matmul) — the CORE
correctness signal for the compute hot path. Kernels run in interpret
mode (CPU PJRT cannot execute Mosaic custom-calls).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matmul import matmul, matmul_pallas, _pick_block
from compile.kernels.mlr_grad import mlr_grad_pallas

SETTINGS = dict(max_examples=25, deadline=None)


def rand(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = rand(rng, m, k), rand(rng, k, n)
    got = matmul_pallas(a, b)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    m=st.sampled_from([8, 32, 128, 130, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_blocked_shapes(m, seed):
    """Shapes that exercise multi-block grids and the padding path."""
    rng = np.random.default_rng(seed)
    a, b = rand(rng, m, 64), rand(rng, 64, m)
    np.testing.assert_allclose(
        matmul_pallas(a, b), ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4
    )


def test_matmul_bf16_accumulates_in_f32():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(64, 64))).astype(jnp.bfloat16)
    b = jnp.asarray(rng.normal(size=(64, 64))).astype(jnp.bfloat16)
    got = matmul_pallas(a, b).astype(jnp.float32)
    want = jnp.dot(a, b, preferred_element_type=jnp.float32).astype(jnp.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_matmul_custom_vjp_matches_autodiff(seed):
    rng = np.random.default_rng(seed)
    a, b = rand(rng, 24, 16), rand(rng, 16, 8)

    def loss_kernel(a, b):
        return jnp.sum(jnp.tanh(matmul(a, b)))

    def loss_ref(a, b):
        return jnp.sum(jnp.tanh(ref.matmul_ref(a, b)))

    ga = jax.grad(loss_kernel, argnums=(0, 1))(a, b)
    gr = jax.grad(loss_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(ga[0], gr[0], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(ga[1], gr[1], rtol=1e-4, atol=1e-4)


def test_matmul_rejects_bad_shapes():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        matmul_pallas(rand(rng, 4, 5), rand(rng, 6, 7))
    with pytest.raises(ValueError):
        matmul_pallas(rand(rng, 4), rand(rng, 4, 2))


def test_pick_block_divides():
    for dim in [1, 7, 54, 128, 130, 784, 1000]:
        b = _pick_block(dim, 128)
        assert 1 <= b <= max(dim, 128)
        assert dim % b == 0 or b == dim


# ---------------------------------------------------------------------------
# fused MLR gradient
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    b=st.sampled_from([8, 32, 100, 128, 256]),
    d=st.integers(2, 100),
    k=st.integers(2, 12),
    bb=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mlr_grad_matches_ref(b, d, k, bb, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, b, d)
    w = rand(rng, d, k)
    labels = rng.integers(0, k, size=b)
    y = jnp.asarray(np.eye(k, dtype=np.float32)[labels])
    grad, loss = mlr_grad_pallas(x, w, y, bb=bb)
    gref, lref = ref.mlr_grad_ref(x, w, y)
    np.testing.assert_allclose(grad, gref, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(loss[0], lref, rtol=1e-4, atol=1e-5)


def test_mlr_grad_extreme_logits_stable():
    """Softmax must not overflow for large logits (stability guard)."""
    rng = np.random.default_rng(1)
    x = rand(rng, 32, 10) * 100.0
    w = rand(rng, 10, 5) * 10.0
    labels = rng.integers(0, 5, size=32)
    y = jnp.asarray(np.eye(5, dtype=np.float32)[labels])
    grad, loss = mlr_grad_pallas(x, w, y, bb=16)
    assert np.isfinite(np.asarray(grad)).all()
    assert np.isfinite(np.asarray(loss)).all()


def test_mlr_grad_zero_when_perfect():
    """One-hot probabilities at the labels => near-zero gradient & loss."""
    k = 4
    x = jnp.eye(k, dtype=jnp.float32) * 50.0
    w = jnp.eye(k, dtype=jnp.float32) * 10.0  # logits hugely favor label i
    y = jnp.eye(k, dtype=jnp.float32)
    grad, loss = mlr_grad_pallas(x, w, y, bb=2)
    assert float(loss[0]) < 1e-3
    assert float(jnp.max(jnp.abs(grad))) < 1e-3


def test_mlr_grad_shape_mismatch_raises():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        mlr_grad_pallas(rand(rng, 8, 4), rand(rng, 4, 3), rand(rng, 8, 2))
