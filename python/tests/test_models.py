"""L2 model step functions: interface contracts and training numerics.

Each model's `build(cfg)` must produce a step whose outputs are the
updated state tensors (input order) followed by a (1,) loss, and a few
steps of each must actually reduce its loss — the property the entire
iteration-cost framework rests on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.models import MODELS, cnn, mf, mlr, qp, transformer


def _state_kinds(meta):
    return [io for io in meta["inputs"] if io["kind"] in ("param", "opt")]


@pytest.mark.parametrize("model_name", sorted(MODELS))
def test_interface_contract(model_name):
    mod = MODELS[model_name]
    for variant, cfg in mod.configs().items():
        if variant == "tfm_100m":
            continue  # too big to trace in tests
        step, example, meta = mod.build(cfg)
        state_in = _state_kinds(meta)
        state_out = [io for io in meta["outputs"] if io["kind"] in ("param", "opt")]
        assert [s["name"] for s in state_in] == [s["name"] for s in state_out], variant
        assert meta["outputs"][-1]["kind"] == "metric", variant
        assert len(example) == len(meta["inputs"]), variant
        for arr, io in zip(example, meta["inputs"]):
            assert list(arr.shape) == list(io["shape"]), f"{variant}:{io['name']}"


def _run_steps(mod, variant, n_steps, init_fn, data_fn):
    cfg = mod.configs()[variant]
    step, example, meta = mod.build(cfg)
    jstep = jax.jit(step)
    rng = np.random.default_rng(0)
    args = list(example)
    init_fn(args, meta, rng, cfg)
    n_state = len(_state_kinds(meta))
    losses = []
    for it in range(n_steps):
        data_fn(args, meta, rng, cfg, it)
        outs = jstep(*args)
        assert len(outs) == n_state + 1
        args[:n_state] = list(outs[:n_state])
        losses.append(float(outs[-1][0]))
    return losses


def test_qp_descends():
    def init(args, meta, rng, cfg):
        d = cfg["dim"]
        args[0] = jnp.zeros((d,), jnp.float32)
        a = np.eye(d, dtype=np.float32) * np.linspace(0.5, 1.0, d, dtype=np.float32)
        args[1] = jnp.asarray(a)
        args[2] = jnp.asarray(rng.normal(size=d).astype(np.float32))

    losses = _run_steps(qp, "qp4", 30, init, lambda *a: None)
    assert losses[-1] < losses[0] * 0.5
    assert all(np.isfinite(losses))


def test_mlr_descends():
    cfg = dict(mlr.configs()["mlr_covtype"])

    def init(args, meta, rng, c):
        pass  # w = 0 default

    def data(args, meta, rng, c, it):
        b, d, k = c["batch"], c["dim"], c["classes"]
        labels = rng.integers(0, k, size=b)
        x = rng.normal(size=(b, d)).astype(np.float32) + 3.0 * np.eye(k, d, dtype=np.float32)[labels]
        args[1] = jnp.asarray(x)
        args[2] = jnp.asarray(np.eye(k, dtype=np.float32)[labels])

    class _Mod:
        @staticmethod
        def configs():
            return {"v": cfg}

        @staticmethod
        def build(c):
            return mlr.build(c)

    losses = _run_steps(_Mod, "v", 15, init, data)
    assert losses[-1] < losses[0]


def test_mf_descends_and_is_damped():
    cfg = dict(mf.configs()["mf_jester"])
    cfg.update(m=60, n=40, rank=4)

    def init(args, meta, rng, c):
        m, n, p = c["m"], c["n"], c["rank"]
        args[0] = jnp.asarray(rng.uniform(size=(m, p)).astype(np.float32))
        args[1] = jnp.asarray(rng.uniform(size=(p, n)).astype(np.float32))
        u = rng.normal(size=(m, p)).astype(np.float32)
        v = rng.normal(size=(p, n)).astype(np.float32)
        ratings = u @ v + 0.1 * rng.normal(size=(m, n)).astype(np.float32)
        mask = (rng.uniform(size=(m, n)) < 0.5).astype(np.float32)
        args[2] = jnp.asarray(ratings)
        args[3] = jnp.asarray(mask)

    class _Mod:
        @staticmethod
        def configs():
            return {"v": cfg}

        @staticmethod
        def build(c):
            return mf.build(c)

    losses = _run_steps(_Mod, "v", 25, init, lambda *a: None)
    assert losses[-1] < losses[0] * 0.5
    # Damping: single step must NOT jump to the plateau.
    assert losses[1] > losses[-1] * 1.5


def test_cnn_descends():
    cfg = dict(cnn.configs()["cnn_mnist"])
    cfg.update(batch=16, image=12, c1=4, c2=8, f1=32, f2=16)

    def init(args, meta, rng, c):
        shapes = cnn.param_shapes(c)
        for i, (_, s) in enumerate(shapes):
            if len(s) >= 2:
                fan_in = int(np.prod(s[:-1]))
                args[i] = jnp.asarray(
                    (rng.normal(size=s) * np.sqrt(2.0 / fan_in)).astype(np.float32)
                )

    def data(args, meta, rng, c, it):
        b, im, k = c["batch"], c["image"], c["classes"]
        labels = rng.integers(0, k, size=b)
        x = rng.normal(size=(b, im, im, 1)).astype(np.float32) * 0.2
        for i, lab in enumerate(labels):
            x[i, lab % im, :, 0] += 2.0  # class-dependent stripe
        args[-3] = jnp.asarray([float(it + 1)], dtype=jnp.float32)
        args[-2] = jnp.asarray(x)
        args[-1] = jnp.asarray(np.eye(k, dtype=np.float32)[labels])

    class _Mod:
        @staticmethod
        def configs():
            return {"v": cfg}

        @staticmethod
        def build(c):
            return cnn.build(c)

    losses = _run_steps(_Mod, "v", 10, init, data)
    assert losses[-1] < losses[0]


def test_transformer_descends_on_repeated_batch():
    cfg = dict(transformer.configs()["tfm_tiny"])
    cfg.update(vocab=64, d=32, layers=2, heads=2, ff=64, seq=16, batch=4)

    fixed = {}

    def init(args, meta, rng, c):
        shapes = transformer.param_shapes(c)
        for i, (name, s) in enumerate(shapes):
            if name.startswith("ln") and name.endswith("g"):
                args[i] = jnp.ones(s, jnp.float32)
            elif not name.startswith(("ln", "b")):
                args[i] = jnp.asarray((rng.normal(size=s) * 0.05).astype(np.float32))
        toks = rng.integers(0, c["vocab"], size=(c["batch"], c["seq"]))
        fixed["tokens"] = jnp.asarray(toks, dtype=jnp.int32)
        fixed["targets"] = jnp.asarray(np.roll(toks, -1, axis=1), dtype=jnp.int32)

    def data(args, meta, rng, c, it):
        args[-3] = jnp.asarray([float(it + 1)], dtype=jnp.float32)
        args[-2] = fixed["tokens"]
        args[-1] = fixed["targets"]

    class _Mod:
        @staticmethod
        def configs():
            return {"v": cfg}

        @staticmethod
        def build(c):
            return transformer.build(c)

    losses = _run_steps(_Mod, "v", 12, init, data)
    # Memorizing one batch must drive loss down hard.
    assert losses[-1] < losses[0] * 0.9
    assert all(np.isfinite(losses))


def test_transformer_causality():
    """Changing future tokens must not change past logits."""
    cfg = dict(transformer.configs()["tfm_tiny"])
    cfg.update(vocab=32, d=16, layers=1, heads=2, ff=32, seq=8, batch=1)
    rng = np.random.default_rng(0)
    shapes = transformer.param_shapes(cfg)
    params = {}
    for name, s in shapes:
        if name.startswith("ln") and name.endswith("g"):
            params[name] = jnp.ones(s, jnp.float32)
        elif name.startswith("ln") or name.startswith("b"):
            params[name] = jnp.zeros(s, jnp.float32)
        else:
            params[name] = jnp.asarray((rng.normal(size=s) * 0.1).astype(np.float32))
    toks = rng.integers(0, 32, size=(1, 8)).astype(np.int32)
    toks2 = toks.copy()
    toks2[0, -1] = (toks2[0, -1] + 1) % 32
    la = transformer.forward(params, jnp.asarray(toks), cfg)
    lb = transformer.forward(params, jnp.asarray(toks2), cfg)
    np.testing.assert_allclose(la[0, :-1], lb[0, :-1], rtol=1e-5, atol=1e-5)
    assert not np.allclose(la[0, -1], lb[0, -1])
