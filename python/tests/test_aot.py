"""AOT lowering: artifact files, metadata integrity, HLO text sanity."""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def outdir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    return str(d)


def test_variant_index_covers_defaults():
    idx = aot.variant_index()
    for v in aot.DEFAULT_VARIANTS + aot.LARGE_VARIANTS:
        assert v in idx, v


@pytest.mark.parametrize("variant", ["qp4", "mlr_covtype"])
def test_lower_writes_hlo_and_meta(variant, outdir):
    entry = aot.lower_variant(variant, outdir)
    assert entry["hlo_bytes"] > 100
    hlo = open(os.path.join(outdir, f"{variant}.hlo.txt")).read()
    assert "HloModule" in hlo
    # Lowered with return_tuple=True: the root computation returns a tuple.
    assert "ROOT" in hlo

    meta = json.load(open(os.path.join(outdir, f"{variant}.meta.json")))
    assert meta["name"] == variant
    assert meta["outputs"][-1]["kind"] == "metric"
    state_in = [i["name"] for i in meta["inputs"] if i["kind"] in ("param", "opt")]
    state_out = [o["name"] for o in meta["outputs"] if o["kind"] in ("param", "opt")]
    assert state_in == state_out
    # Parameter count of the ENTRY computation must match the meta inputs:
    # jax prunes unused arguments, which would silently break the Rust
    # runtime ("Execution supplied N buffers but compiled program expected
    # M"). Nested computations (pallas interpret loops) have their own
    # parameters, so scope the count to the ENTRY block.
    entry = hlo[hlo.index("ENTRY"):]
    depth = 0
    for i, ch in enumerate(entry):
        if ch == "{":
            depth += 1
        elif ch == "}":
            depth -= 1
            if depth == 0:
                entry = entry[: i + 1]
                break
    n_params = entry.count("parameter(")
    assert n_params == len(meta["inputs"]), (
        f"{variant}: ENTRY has {n_params} parameters, meta lists {len(meta['inputs'])} "
        "(an unused step-function argument was pruned?)"
    )


def test_meta_dtypes_default_f32(outdir):
    aot.lower_variant("qp4", outdir)
    meta = json.load(open(os.path.join(outdir, "qp4.meta.json")))
    assert all(e["dtype"] == "f32" for e in meta["inputs"] + meta["outputs"])


def test_transformer_meta_marks_int_inputs(outdir):
    aot.lower_variant("tfm_tiny", outdir)
    meta = json.load(open(os.path.join(outdir, "tfm_tiny.meta.json")))
    dtypes = {e["name"]: e["dtype"] for e in meta["inputs"]}
    assert dtypes["tokens"] == "i32"
    assert dtypes["targets"] == "i32"
    assert dtypes["emb"] == "f32"
