# SCAR build/verify entry points. The Rust crate is fully offline
# (vendored path deps); `artifacts` needs a Python env with JAX.

CARGO_DIR := rust

.PHONY: build test check fmt clippy doc smoke bench artifacts figures figures-pjrt clean

build:
	cd $(CARGO_DIR) && cargo build --release

test:
	cd $(CARGO_DIR) && cargo test -q

fmt:
	cd $(CARGO_DIR) && cargo fmt --check

clippy:
	cd $(CARGO_DIR) && cargo clippy --all-targets -- -D warnings

doc:
	cd $(CARGO_DIR) && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# The full gate: formatting, lints, tests, docs.
check: fmt clippy test doc

# Local mirror of CI's backend-matrix smoke job: the chaos scenario
# family at 2 trials per cell over both storage backends (disk_chaos runs
# disk-backed as written, then again forced onto memory shards into a
# separate CSV, and the two reports are diffed — byte-identity is the
# contract).
smoke: build
	$(CARGO_DIR)/target/release/scar run-scenario scenarios/shard_failures.toml --trials 2
	$(CARGO_DIR)/target/release/scar run-scenario scenarios/shard_failures_cluster.toml --trials 2
	$(CARGO_DIR)/target/release/scar run-scenario scenarios/selective_recovery.toml --trials 2
	$(CARGO_DIR)/target/release/scar run-scenario scenarios/erasure_recovery.toml --trials 2
	$(CARGO_DIR)/target/release/scar run-scenario scenarios/disk_chaos.toml --trials 2
	$(CARGO_DIR)/target/release/scar run-scenario scenarios/disk_chaos.toml --trials 2 --backend mem --output results/disk_chaos-mem.csv
	diff results/disk_chaos.csv results/disk_chaos-mem.csv

# Hot-path micro-bench: pinned fence/checkpoint/rebuild workload over
# {mem,disk} x {sync,async} x parity {0,1}; writes BENCH_7.json. CI runs
# the --quick variant on every push and the full one nightly.
bench: build
	$(CARGO_DIR)/target/release/scar bench --out BENCH_7.json

# AOT-lower every model variant to HLO text + metadata (L2 -> artifacts/).
artifacts:
	python3 python/compile/aot.py --outdir artifacts

# Scenario sweeps runnable on a fresh offline clone (pure-Rust LDA
# substrate, no PJRT artifacts needed).
figures: build
	$(CARGO_DIR)/target/release/scar run-scenario scenarios/failure_models.toml

# Paper-figure sweeps: additionally require `make artifacts` plus the
# real PJRT bindings in place of rust/vendor/xla (the vendored stub
# refuses to compile HLO by design).
figures-pjrt: build
	$(CARGO_DIR)/target/release/scar run-scenario scenarios/fig5.toml
	$(CARGO_DIR)/target/release/scar run-scenario scenarios/fig6.toml
	$(CARGO_DIR)/target/release/scar run-scenario scenarios/fig7.toml

clean:
	cd $(CARGO_DIR) && cargo clean
	rm -rf results
