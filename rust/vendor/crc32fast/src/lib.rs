//! Offline subset of the `crc32fast` crate: the standard IEEE CRC-32
//! (reflected, polynomial 0xEDB88320) behind the same `hash` entry point.
//! Table-driven single-byte implementation — plenty for checkpoint record
//! integrity checks; swap back to the SIMD crate when the registry is
//! available.

const fn make_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = make_table();

/// CRC-32 (IEEE) of a byte slice — same value as `crc32fast::hash`.
pub fn hash(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

/// Incremental hasher with the upstream crate's shape.
#[derive(Debug, Clone, Default)]
pub struct Hasher {
    state: u32,
    started: bool,
}

impl Hasher {
    pub fn new() -> Hasher {
        Hasher { state: 0xFFFF_FFFF, started: true }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        if !self.started {
            self.state = 0xFFFF_FFFF;
            self.started = true;
        }
        for &b in bytes {
            self.state = (self.state >> 8) ^ TABLE[((self.state ^ b as u32) & 0xFF) as usize];
        }
    }

    pub fn finalize(self) -> u32 {
        !self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard test vector for CRC-32/IEEE.
        assert_eq!(hash(b"123456789"), 0xCBF4_3926);
        assert_eq!(hash(b""), 0);
    }

    #[test]
    fn hasher_matches_oneshot() {
        let mut h = Hasher::new();
        h.update(b"1234");
        h.update(b"56789");
        assert_eq!(h.finalize(), hash(b"123456789"));
    }
}
