//! Offline stand-in for the `xla` (PJRT bindings) crate.
//!
//! The build image carries no PJRT shared library, so this vendored crate
//! implements the *host-side* surface the `scar` runtime uses for real —
//! [`Literal`] construction/readback and [`PjRtBuffer`] round-trips are
//! fully functional pure-Rust code — while the device-side entry points
//! ([`PjRtClient::compile`], [`PjRtLoadedExecutable::execute_b`]) return a
//! descriptive [`Error`]. Everything that does not execute compiled HLO
//! (the LDA substrate, the synthetic trainer, the whole checkpoint/
//! recovery/scenario stack, every literal helper) works unchanged.
//!
//! When the real PJRT toolchain is linked in, point the `xla` path
//! dependency in `rust/Cargo.toml` back at the full bindings; the API
//! below is signature-compatible with the subset `scar` calls.

use std::borrow::Borrow;
use std::fmt;

/// Error type matching the bindings' shape: a message string.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtypes used by the scar artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    pub fn byte_width(self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
        }
    }
}

mod private {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// Host element types uploadable to device buffers.
pub trait NativeType: Copy + private::Sealed {
    const ELEMENT_TYPE: ElementType;
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;
}

/// A host-side literal: dense typed bytes with a shape, or a tuple.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    shape: Vec<usize>,
    bytes: Vec<u8>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Dense literal from untyped host bytes (native byte order).
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        bytes: &[u8],
    ) -> Result<Literal> {
        let want = shape.iter().product::<usize>().max(1) * ty.byte_width();
        if want != bytes.len() {
            return Err(Error::new(format!(
                "literal shape {shape:?} wants {want} bytes, got {}",
                bytes.len()
            )));
        }
        Ok(Literal { ty, shape: shape.to_vec(), bytes: bytes.to_vec(), tuple: None })
    }

    /// Tuple literal from element literals.
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal { ty: ElementType::F32, shape: Vec::new(), bytes: Vec::new(), tuple: Some(elements) }
    }

    pub fn element_count(&self) -> usize {
        match &self.tuple {
            Some(t) => t.iter().map(Literal::element_count).sum(),
            None => self.bytes.len() / self.ty.byte_width(),
        }
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.tuple {
            Some(t) => Ok(t),
            None => Err(Error::new("to_tuple on a non-tuple literal")),
        }
    }

    /// Read back as a host vector of `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.tuple.is_some() {
            return Err(Error::new("to_vec on a tuple literal"));
        }
        if self.ty != T::ELEMENT_TYPE {
            return Err(Error::new(format!(
                "to_vec element type mismatch: literal is {:?}",
                self.ty
            )));
        }
        let size = std::mem::size_of::<T>();
        let n = self.bytes.len() / size;
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            // Native byte order, possibly unaligned source.
            let v = unsafe {
                std::ptr::read_unaligned(self.bytes.as_ptr().add(i * size) as *const T)
            };
            out.push(v);
        }
        Ok(out)
    }

    /// Copy into an existing host slice without allocating.
    pub fn copy_raw_to<T: NativeType>(&self, dst: &mut [T]) -> Result<()> {
        if self.tuple.is_some() {
            return Err(Error::new("copy_raw_to on a tuple literal"));
        }
        if self.ty != T::ELEMENT_TYPE {
            return Err(Error::new(format!(
                "copy_raw_to element type mismatch: literal is {:?}",
                self.ty
            )));
        }
        let size = std::mem::size_of::<T>();
        if dst.len() * size != self.bytes.len() {
            return Err(Error::new(format!(
                "copy_raw_to length mismatch: literal {} bytes, dst {} bytes",
                self.bytes.len(),
                dst.len() * size
            )));
        }
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.bytes.as_ptr(),
                dst.as_mut_ptr() as *mut u8,
                self.bytes.len(),
            );
        }
        Ok(())
    }
}

/// Parsed HLO module text. The stub only records the source path; parsing
/// happens inside the real PJRT compiler.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    path: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        if !std::path::Path::new(path).exists() {
            return Err(Error::new(format!("HLO text file not found: {path}")));
        }
        Ok(HloModuleProto { path: path.to_string() })
    }
}

/// A computation handle wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    path: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { path: proto.path.clone() }
    }
}

/// A device buffer. In the stub a buffer is its host literal.
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// Compiled executable handle. Only obtainable from the real bindings;
/// the stub's [`PjRtClient::compile`] never produces one.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<B: Borrow<PjRtBuffer>>(&self, _inputs: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(
            "PJRT execution unavailable in the offline xla stub (link the real bindings)",
        ))
    }
}

/// The PJRT client. Host-side operations work; compilation is gated.
pub struct PjRtClient {
    platform: &'static str,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { platform: "cpu-stub" })
    }

    pub fn platform_name(&self) -> String {
        self.platform.to_string()
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(format!(
            "cannot compile '{}': PJRT unavailable in the offline xla stub (link the real bindings and run `make artifacts`)",
            comp.path
        )))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
        };
        let literal = Literal::create_from_shape_and_untyped_data(T::ELEMENT_TYPE, dims, bytes)?;
        Ok(PjRtBuffer { literal })
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer { literal: literal.clone() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_ne_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.element_count(), 3);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data.to_vec());
        let mut out = [0.0f32; 3];
        lit.copy_raw_to(&mut out).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn buffer_roundtrip_through_client() {
        let client = PjRtClient::cpu().unwrap();
        let buf = client.buffer_from_host_buffer(&[7i32, 8, 9], &[3], None).unwrap();
        let lit = buf.to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7, 8, 9]);
    }

    #[test]
    fn type_and_shape_mismatches_rejected() {
        let lit = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 8])
            .unwrap();
        assert!(lit.to_vec::<i32>().is_err());
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &[0u8; 8])
            .is_err());
        let mut small = [0.0f32; 1];
        assert!(lit.copy_raw_to(&mut small).is_err());
    }

    #[test]
    fn tuple_decomposes() {
        let a = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0u8; 4])
            .unwrap();
        let t = Literal::tuple(vec![a.clone(), a]);
        assert_eq!(t.element_count(), 2);
        assert_eq!(t.to_tuple().unwrap().len(), 2);
    }

    #[test]
    fn compile_is_gated_with_clear_error() {
        let client = PjRtClient::cpu().unwrap();
        std::fs::write("/tmp/xla-stub-test.hlo.txt", "HloModule m").unwrap();
        let proto = HloModuleProto::from_text_file("/tmp/xla-stub-test.hlo.txt").unwrap();
        let comp = XlaComputation::from_proto(&proto);
        let err = client.compile(&comp).unwrap_err();
        assert!(format!("{err}").contains("offline xla stub"));
    }
}
