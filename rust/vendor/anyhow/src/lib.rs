//! Offline subset of the `anyhow` error-handling crate.
//!
//! The build image has no crates.io access, so this vendored crate
//! reimplements the slice of anyhow's API that the `scar` crate uses:
//!
//! * [`Error`] — an opaque error value carrying a context chain;
//! * [`Result<T>`] — alias with `Error` as the default error type;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! * [`anyhow!`] / [`bail!`] — ad-hoc error construction macros;
//! * a blanket `From<E: std::error::Error>` so `?` converts any standard
//!   error.
//!
//! Unlike upstream anyhow it does not capture backtraces or support
//! downcasting: the error is flattened to a chain of display strings at
//! construction time, which is all the surrounding code relies on.

use std::fmt::{self, Display};

/// Alias matching `anyhow::Result`: one type parameter, `Error` default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error with a chain of human-readable context frames. `chain[0]` is
/// the outermost (most recently attached) context; the last entry is the
/// root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (`anyhow::Error::msg`).
    pub fn msg<M: Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach a new outermost context frame.
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first (root cause last).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or("unknown error"))?;
        if f.alternate() {
            for cause in &self.chain[1..] {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or("unknown error"))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// `?` conversion from any standard error. `Error` itself deliberately
// does NOT implement `std::error::Error`, exactly like upstream anyhow —
// that is what keeps this blanket impl coherent alongside
// `impl From<Error> for Error` (the std identity impl).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

mod ext {
    use super::*;

    /// Private glue so `Context` works both for `Result<T, E>` with a
    /// standard error E and for `Result<T, anyhow::Error>` — mirrors
    /// upstream anyhow's `ext::StdError`.
    pub trait StdError {
        fn ext_context<C: Display>(self, context: C) -> Error;
    }

    impl<E> StdError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn ext_context<C: Display>(self, context: C) -> Error {
            Error::from(self).context(context)
        }
    }

    impl StdError for Error {
        fn ext_context<C: Display>(self, context: C) -> Error {
            self.context(context)
        }
    }
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::StdError + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.ext_context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.ext_context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening file").unwrap_err();
        assert_eq!(format!("{e}"), "opening file");
        assert!(format!("{e:?}").contains("missing thing"));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("want {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "want 7");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_chains_on_anyhow_result() {
        fn inner() -> Result<()> {
            bail!("root {}", "cause");
        }
        let e = inner().context("outer").unwrap_err();
        let frames: Vec<&str> = e.chain().collect();
        assert_eq!(frames, vec!["outer", "root cause"]);
        assert_eq!(e.root_cause(), "root cause");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("x = {}", 3);
        assert_eq!(format!("{e}"), "x = 3");
    }
}
