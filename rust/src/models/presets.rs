//! Named experiment presets: the eight panels of Fig 7/8 plus the QP and
//! transformer workloads, with the paper's convergence-horizon settings.

use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::data::Corpus;
use crate::models::{build_trainer, lda::LdaTrainer, BuildOpts, Partitioning};
use crate::runtime::Engine;
use crate::trainer::Trainer;

/// One experiment workload: how to build it and its horizon settings.
#[derive(Debug, Clone)]
pub struct Preset {
    pub name: &'static str,
    /// Artifact variant, or "lda" for the Rust substrate.
    pub kind: PresetKind,
    /// Iterations the unperturbed run should take to reach ε (paper
    /// App. C: "roughly 60 iterations"; Fig 3 uses ~1000, Fig 5 ~100).
    pub target_iters: usize,
    /// Extra iterations past the target recorded in the trajectory (the
    /// tail refines the x* estimate).
    pub max_iters: usize,
}

#[derive(Debug, Clone)]
pub enum PresetKind {
    Hlo { variant: &'static str, partitioning: Partitioning },
    Lda { docs: usize, vocab: usize, topics: usize, mean_len: usize },
}

/// The eight Fig 7/8 panels in paper order.
pub fn standard_panels() -> Vec<Preset> {
    vec![
        preset("mlr_mnist"),
        preset("mlr_covtype"),
        preset("mf_movielens"),
        preset("mf_jester"),
        preset("lda_20news"),
        preset("lda_reuters"),
        preset("cnn_bylayer"),
        preset("cnn_byshard"),
    ]
}

/// Look up a preset by name (panics on unknown names — preset names are
/// compile-time constants in the examples). Fallible callers (the
/// scenario engine, CLI paths fed by user data) use [`try_preset`].
pub fn preset(name: &str) -> Preset {
    try_preset(name).unwrap_or_else(|| panic!("unknown preset '{name}'"))
}

/// Look up a preset by name, returning `None` for unknown names.
pub fn try_preset(name: &str) -> Option<Preset> {
    let hlo = |variant, partitioning, target, max| Preset {
        name: Box::leak(name.to_string().into_boxed_str()),
        kind: PresetKind::Hlo { variant, partitioning },
        target_iters: target,
        max_iters: max,
    };
    Some(match name {
        "qp4" => hlo("qp4", Partitioning::ByShard, 1000, 6000),
        "qp32" => hlo("qp32", Partitioning::ByShard, 1000, 6000),
        "mlr_mnist" => hlo("mlr_mnist", Partitioning::ByShard, 60, 100),
        "mlr_mnist_fig5" => hlo("mlr_mnist", Partitioning::ByShard, 100, 320),
        "mlr_covtype" => hlo("mlr_covtype", Partitioning::ByShard, 60, 100),
        "mf_movielens" => hlo("mf_movielens", Partitioning::ByShard, 60, 100),
        "mf_jester" => hlo("mf_jester", Partitioning::ByShard, 60, 100),
        "cnn_bylayer" => hlo("cnn_mnist", Partitioning::ByLayer, 60, 100),
        "cnn_byshard" => hlo("cnn_mnist", Partitioning::ByShard, 60, 100),
        "tfm_tiny" => hlo("tfm_tiny", Partitioning::ByShard, 200, 260),
        "tfm_small" => hlo("tfm_small", Partitioning::ByShard, 200, 260),
        "lda_20news" => Preset {
            name: "lda_20news",
            kind: PresetKind::Lda { docs: 1200, vocab: 1500, topics: 20, mean_len: 110 },
            target_iters: 60,
            max_iters: 100,
        },
        "lda_reuters" => Preset {
            name: "lda_reuters",
            kind: PresetKind::Lda { docs: 1600, vocab: 1000, topics: 20, mean_len: 70 },
            target_iters: 60,
            max_iters: 100,
        },
        "lda_clueweb" => Preset {
            name: "lda_clueweb",
            kind: PresetKind::Lda { docs: 4000, vocab: 4000, topics: 50, mean_len: 160 },
            target_iters: 30,
            max_iters: 40,
        },
        _ => return None,
    })
}

/// Build the preset's trainer. `engine` is only used by HLO presets.
/// The trainer is `Send` so scenario sweeps can run trials on worker
/// threads (each worker builds and owns its own instance).
pub fn build_preset(
    engine: Option<Arc<Mutex<Engine>>>,
    p: &Preset,
    data_seed: u64,
) -> Result<Box<dyn Trainer + Send>> {
    match &p.kind {
        PresetKind::Hlo { variant, partitioning } => {
            let Some(engine) = engine else {
                bail!("preset {} needs a PJRT engine", p.name)
            };
            let opts = BuildOpts { data_seed, partitioning: *partitioning, ..BuildOpts::default() };
            Ok(Box::new(build_trainer(engine, variant, &opts)?))
        }
        PresetKind::Lda { docs, vocab, topics, mean_len } => {
            // alpha=beta=1 per App. C.
            let corpus =
                Corpus::lda_generative(*docs, *vocab, *topics, *mean_len, 0.5, 0.1, data_seed);
            Ok(Box::new(LdaTrainer::new(p.name, corpus, *topics, 1.0, 1.0)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_panels_are_eight() {
        let panels = standard_panels();
        assert_eq!(panels.len(), 8);
        let names: Vec<&str> = panels.iter().map(|p| p.name).collect();
        assert!(names.contains(&"cnn_bylayer") && names.contains(&"lda_reuters"));
    }

    #[test]
    fn lda_preset_builds_without_engine() {
        let p = preset("lda_20news");
        let t = build_preset(None, &p, 7).unwrap();
        assert_eq!(t.name(), "lda_20news");
        assert!(t.layout().n_atoms() == 1200);
    }

    #[test]
    #[should_panic(expected = "unknown preset")]
    fn unknown_preset_panics() {
        preset("nope");
    }
}
