//! Synthetic linear-contraction trainer: the analytic workload of §3.
//!
//! Iterates `x ← x* + c (x − x*)` with loss ‖x − x*‖, so assumption (3)
//! holds *exactly* with contraction rate `c`. It needs no PJRT engine and
//! no artifacts, which makes it the reference workload for scenario-engine
//! tests (parallel-vs-serial equivalence, failure-plan semantics) and a
//! fast way to sanity-check a scenario file before pointing it at a real
//! model.
//!
//! Scenario files reference it as a model spec string:
//! `"synthetic"` or `"synthetic:dim=64,c=0.85,xseed=7"`.

use anyhow::{bail, Context, Result};

use crate::params::{AtomLayout, ParamStore, Tensor};
use crate::trainer::Trainer;
use crate::util::rng::Rng;

/// Analytic contraction toward a fixed random `x*`; one atom per
/// coordinate row.
pub struct SyntheticTrainer {
    name: String,
    c: f32,
    xstar: Vec<f32>,
    state: ParamStore,
    layout: AtomLayout,
}

impl SyntheticTrainer {
    /// `dim` coordinates contracting at rate `c`; `xseed` fixes x*.
    pub fn new(dim: usize, c: f64, xseed: u64) -> SyntheticTrainer {
        assert!(dim >= 1, "synthetic: dim must be >= 1");
        assert!(c > 0.0 && c < 1.0, "synthetic: need 0 < c < 1, got {c}");
        let mut rng = Rng::new(xseed);
        let xstar: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        let state = ParamStore::new(vec![Tensor::zeros("x", &[dim, 1])]);
        let layout = AtomLayout::new(AtomLayout::rows_of(&state, "x"));
        SyntheticTrainer {
            name: format!("synthetic(dim={dim},c={c})"),
            c: c as f32,
            xstar,
            state,
            layout,
        }
    }

    /// Parse a `"synthetic[:k=v,...]"` model spec. Keys: `dim` (default
    /// 64), `c` (default 0.9), `xseed` (default 7).
    pub fn from_spec(spec: &str) -> Result<SyntheticTrainer> {
        let mut dim = 64usize;
        let mut c = 0.9f64;
        let mut xseed = 7u64;
        if let Some(params) = spec.strip_prefix("synthetic").and_then(|r| r.strip_prefix(':')) {
            for kv in params.split(',').filter(|s| !s.is_empty()) {
                let (k, v) = kv
                    .split_once('=')
                    .with_context(|| format!("synthetic spec: expected key=value, got '{kv}'"))?;
                match k.trim() {
                    "dim" => dim = v.trim().parse().context("synthetic spec: dim")?,
                    "c" => c = v.trim().parse().context("synthetic spec: c")?,
                    "xseed" => xseed = v.trim().parse().context("synthetic spec: xseed")?,
                    other => bail!("synthetic spec: unknown key '{other}' (dim|c|xseed)"),
                }
            }
        } else if spec != "synthetic" {
            bail!("not a synthetic model spec: '{spec}'");
        }
        if dim == 0 {
            bail!("synthetic spec: dim must be >= 1");
        }
        if !(c > 0.0 && c < 1.0) {
            bail!("synthetic spec: c must be in (0, 1), got {c}");
        }
        Ok(SyntheticTrainer::new(dim, c, xseed))
    }
}

impl Trainer for SyntheticTrainer {
    fn name(&self) -> &str {
        &self.name
    }

    fn init(&mut self, _seed: u64) -> Result<()> {
        // x(0) = 0 regardless of seed: the trajectory is deterministic,
        // which is exactly what equivalence tests want.
        self.state.get_mut("x").data.iter_mut().for_each(|v| *v = 0.0);
        Ok(())
    }

    fn step(&mut self, _iter: usize) -> Result<f64> {
        let mut err = 0.0f64;
        let data = &mut self.state.get_mut("x").data;
        for (x, s) in data.iter_mut().zip(&self.xstar) {
            *x = s + self.c * (*x - s);
            let d = (*x - s) as f64;
            err += d * d;
        }
        Ok(err.sqrt())
    }

    fn state(&self) -> &ParamStore {
        &self.state
    }

    fn state_mut(&mut self) -> &mut ParamStore {
        &mut self.state
    }

    fn layout(&self) -> &AtomLayout {
        &self.layout
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contracts_at_exactly_c() {
        let mut t = SyntheticTrainer::new(16, 0.8, 3);
        t.init(0).unwrap();
        let l1 = t.step(0).unwrap();
        let l2 = t.step(1).unwrap();
        assert!((l2 / l1 - 0.8).abs() < 1e-5, "ratio {}", l2 / l1);
    }

    #[test]
    fn spec_parsing() {
        assert!(SyntheticTrainer::from_spec("synthetic").is_ok());
        let t = SyntheticTrainer::from_spec("synthetic:dim=8,c=0.5,xseed=1").unwrap();
        assert_eq!(t.layout().n_atoms(), 8);
        assert!(SyntheticTrainer::from_spec("synthetic:dim=0").is_err());
        assert!(SyntheticTrainer::from_spec("synthetic:c=1.5").is_err());
        assert!(SyntheticTrainer::from_spec("synthetic:bogus=1").is_err());
        assert!(SyntheticTrainer::from_spec("mlr_covtype").is_err());
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = SyntheticTrainer::from_spec("synthetic:dim=8,c=0.7").unwrap();
        let mut b = SyntheticTrainer::from_spec("synthetic:dim=8,c=0.7").unwrap();
        a.init(1).unwrap();
        b.init(2).unwrap(); // seed-independent by design
        for iter in 0..5 {
            assert_eq!(a.step(iter).unwrap(), b.step(iter).unwrap());
        }
    }
}
