//! Model glue: bind AOT artifacts to trainers with paper-faithful atom
//! decompositions, initializers, and synthetic data streams.
//!
//! Atomization follows §5.1:
//! * MLR — rows of the weight matrix;
//! * MF — rows of L and columns of R;
//! * LDA — per-document topic distributions (see [`lda`]);
//! * CNN — *by-layer* (one atom per parameter tensor, bias separate) or
//!   *by-shard* (one atom per first-dimension slice);
//! * Transformer — by-shard.
//! Optimizer moments (`m_*`, `v_*`) are co-located with their parameter
//! atoms, so losing a PS node loses them together.

pub mod lda;
pub mod presets;
pub mod synthetic;

use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::data::{Classification, Ratings, TokenStream};
use crate::params::{AtomLayout, ParamStore, Segment, Tensor};
use crate::runtime::{literal_to_f32, Engine, HostTensor};
use crate::trainer::Trainer;
use crate::util::rng::Rng;

/// How to atomize CNN-style per-tensor parameters (§5.1 CNN experiments).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partitioning {
    /// One atom per parameter tensor (weights and biases separate).
    ByLayer,
    /// One atom per first-dimension slice of each parameter tensor.
    ByShard,
}

type InitFn = Box<dyn FnMut(&mut ParamStore, &mut Rng) + Send>;
type DataFn = Box<dyn FnMut(usize, &mut Rng) -> Result<Vec<HostTensor>> + Send>;

/// Artifact-backed trainer: state lives host-side in a [`ParamStore`]
/// (the checkpoint/recovery machinery operates there); each step uploads
/// state + data literals, executes the compiled HLO, and downloads the
/// updated state.
pub struct HloTrainer {
    variant: String,
    engine: Arc<Mutex<Engine>>,
    state: ParamStore,
    layout: AtomLayout,
    n_state: usize,
    state_shapes: Vec<Vec<usize>>,
    seed_rng: Rng,
    init_fn: InitFn,
    data_fn: DataFn,
    /// Data inputs are iteration-independent (QP problem matrices, MF
    /// ratings): upload them to device buffers once and re-use them every
    /// step instead of re-uploading megabytes per iteration (§Perf L3).
    const_data: bool,
    data_cache: Option<Vec<xla::PjRtBuffer>>,
}

impl HloTrainer {
    #[allow(clippy::too_many_arguments)]
    fn new(
        engine: Arc<Mutex<Engine>>,
        variant: &str,
        layout_fn: impl FnOnce(&ParamStore) -> AtomLayout,
        init_fn: InitFn,
        data_fn: DataFn,
        const_data: bool,
    ) -> Result<HloTrainer> {
        let meta = {
            let mut eng = engine.lock().unwrap();
            eng.load(variant)?.meta.clone()
        };
        let state_specs = meta.state_specs();
        let tensors: Vec<Tensor> = state_specs
            .iter()
            .map(|s| Tensor::zeros(&s.name, &s.shape))
            .collect();
        let state_shapes = state_specs.iter().map(|s| s.shape.clone()).collect();
        let n_state = tensors.len();
        let state = ParamStore::new(tensors);
        let layout = layout_fn(&state);
        assert!(layout.n_atoms() > 0, "{variant}: empty atom layout");
        Ok(HloTrainer {
            variant: variant.to_string(),
            engine,
            state,
            layout,
            n_state,
            state_shapes,
            seed_rng: Rng::new(0),
            init_fn,
            data_fn,
            const_data,
            data_cache: None,
        })
    }

    pub fn variant(&self) -> &str {
        &self.variant
    }
}

impl Trainer for HloTrainer {
    fn name(&self) -> &str {
        &self.variant
    }

    fn init(&mut self, seed: u64) -> Result<()> {
        self.seed_rng = Rng::new(seed);
        let mut init_rng = self.seed_rng.derive(u64::MAX);
        for t in self.state.tensors.iter_mut() {
            t.data.iter_mut().for_each(|v| *v = 0.0);
        }
        (self.init_fn)(&mut self.state, &mut init_rng);
        Ok(())
    }

    fn step(&mut self, iter: usize) -> Result<f64> {
        let engine = self.engine.lock().unwrap();
        // Data stream must be a pure function of (seed, iter): snapshots
        // resumed mid-run replay the identical batches. Constant data is
        // uploaded once and stays device-resident.
        if !self.const_data || self.data_cache.is_none() {
            let mut data_rng = self.seed_rng.derive(iter as u64);
            let host = (self.data_fn)(iter, &mut data_rng)?;
            self.data_cache =
                Some(host.iter().map(|t| engine.to_buffer(t)).collect::<Result<_>>()?);
        }
        let data_bufs = self.data_cache.as_ref().unwrap();

        // State upload: host tensor -> device buffer, one copy, no
        // intermediate Literal (§Perf L3).
        let state_bufs: Vec<xla::PjRtBuffer> = self
            .state
            .tensors
            .iter()
            .zip(&self.state_shapes)
            .map(|(t, shape)| engine.buffer_f32(shape, &t.data))
            .collect::<Result<_>>()?;
        let mut inputs: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(self.n_state + data_bufs.len());
        inputs.extend(state_bufs.iter());
        inputs.extend(data_bufs.iter());

        let outputs = engine.execute_buffers(&self.variant, &inputs)?;
        drop(inputs);
        drop(state_bufs);
        drop(engine);

        if outputs.len() != self.n_state + 1 {
            bail!(
                "{}: expected {} outputs, got {}",
                self.variant,
                self.n_state + 1,
                outputs.len()
            );
        }
        for (t, out) in self.state.tensors.iter_mut().zip(&outputs[..self.n_state]) {
            crate::runtime::literal_into_f32(out, &mut t.data)?;
        }
        let loss = literal_to_f32(&outputs[self.n_state])?[0] as f64;
        Ok(loss)
    }

    fn state(&self) -> &ParamStore {
        &self.state
    }

    fn state_mut(&mut self) -> &mut ParamStore {
        &mut self.state
    }

    fn layout(&self) -> &AtomLayout {
        &self.layout
    }
}

// ---------------------------------------------------------------------------
// Atom layout helpers
// ---------------------------------------------------------------------------

/// Atoms = first-dim slices of `param`, each co-located with the matching
/// slices of its `m_*`/`v_*` optimizer tensors when present.
fn sharded_atoms(store: &ParamStore, param_names: &[&str]) -> Vec<Vec<Segment>> {
    let mut atoms = Vec::new();
    for name in param_names {
        let ti = store.index(name);
        let t = &store.tensors[ti];
        let rl = t.row_len();
        let opt_ids: Vec<usize> = ["m_", "v_"]
            .iter()
            .filter_map(|p| {
                let oname = format!("{p}{name}");
                store.tensors.iter().position(|t| t.name == oname)
            })
            .collect();
        for r in 0..t.rows() {
            let mut segs = vec![Segment { tensor: ti, start: r * rl, len: rl }];
            for &oi in &opt_ids {
                segs.push(Segment { tensor: oi, start: r * rl, len: rl });
            }
            atoms.push(segs);
        }
    }
    atoms
}

/// Atoms = whole tensors (by-layer), optimizer moments co-located.
fn per_tensor_atoms(store: &ParamStore, param_names: &[&str]) -> Vec<Vec<Segment>> {
    let mut atoms = Vec::new();
    for name in param_names {
        let ti = store.index(name);
        let len = store.tensors[ti].len();
        let mut segs = vec![Segment { tensor: ti, start: 0, len }];
        for p in ["m_", "v_"] {
            let oname = format!("{p}{name}");
            if let Some(oi) = store.tensors.iter().position(|t| t.name == oname) {
                segs.push(Segment { tensor: oi, start: 0, len });
            }
        }
        atoms.push(segs);
    }
    atoms
}

fn param_tensor_names(store: &ParamStore) -> Vec<String> {
    store
        .tensors
        .iter()
        .map(|t| t.name.clone())
        .filter(|n| !n.starts_with("m_") && !n.starts_with("v_"))
        .collect()
}

// ---------------------------------------------------------------------------
// Builders
// ---------------------------------------------------------------------------

/// Options for [`build_trainer`]. Defaults reproduce the paper settings.
#[derive(Debug, Clone)]
pub struct BuildOpts {
    /// Dataset seed (independent of the trainer's init/data seed).
    pub data_seed: u64,
    /// CNN/Transformer atomization.
    pub partitioning: Partitioning,
    /// QP condition number (controls the contraction rate c).
    pub qp_cond: f64,
}

impl Default for BuildOpts {
    fn default() -> Self {
        BuildOpts { data_seed: 1234, partitioning: Partitioning::ByShard, qp_cond: 40.0 }
    }
}

/// Build a trainer for any artifact variant (`qp4`, `mlr_mnist`,
/// `mf_jester`, `cnn_mnist`, `tfm_small`, ...). LDA is built separately
/// via [`lda::LdaTrainer`] (pure-Rust substrate).
pub fn build_trainer(
    engine: Arc<Mutex<Engine>>,
    variant: &str,
    opts: &BuildOpts,
) -> Result<HloTrainer> {
    let meta = {
        let mut eng = engine.lock().unwrap();
        eng.load(variant)?.meta.clone()
    };
    match meta.model.as_str() {
        "qp" => build_qp(engine, variant, &meta, opts),
        "mlr" => build_mlr(engine, variant, &meta, opts),
        "mf" => build_mf(engine, variant, &meta, opts),
        "cnn" => build_cnn(engine, variant, &meta, opts),
        "transformer" => build_transformer(engine, variant, &meta, opts),
        other => bail!("unknown model family '{other}' for variant {variant}"),
    }
}

fn build_qp(
    engine: Arc<Mutex<Engine>>,
    variant: &str,
    meta: &crate::runtime::ArtifactMeta,
    opts: &BuildOpts,
) -> Result<HloTrainer> {
    let dim = meta.inputs[0].shape[0];
    let mut rng = Rng::new(opts.data_seed);
    let a = crate::data::spd_matrix(dim, opts.qp_cond, &mut rng);
    let b: Vec<f32> = (0..dim).map(|_| (rng.normal() * 3.0) as f32).collect();
    let a2 = a.clone();
    let b2 = b.clone();
    HloTrainer::new(
        engine,
        variant,
        |store| AtomLayout::new(AtomLayout::rows_of(store, "x")),
        Box::new(move |_store, _rng| {
            // x(0) = 0; the optimum is b, so ‖x(0) − x*‖ = ‖b‖.
        }),
        Box::new(move |_iter, _rng| {
            Ok(vec![
                HostTensor::f32(&[dim, dim], a2.clone()),
                HostTensor::f32(&[dim], b2.clone()),
            ])
        }),
        true, // constant problem data: uploaded to device once
    )
}

fn build_mlr(
    engine: Arc<Mutex<Engine>>,
    variant: &str,
    meta: &crate::runtime::ArtifactMeta,
    opts: &BuildOpts,
) -> Result<HloTrainer> {
    let (dim, classes) = (meta.inputs[0].shape[0], meta.inputs[0].shape[1]);
    let batch = meta.inputs[1].shape[0];
    let n_examples = (batch * 8).max(4096);
    let ds = Classification::gaussian_mixture(dim, classes, n_examples, 3.0, opts.data_seed);
    HloTrainer::new(
        engine,
        variant,
        |store| AtomLayout::new(AtomLayout::rows_of(store, "w")),
        Box::new(|_store, _rng| { /* w(0) = 0 */ }),
        Box::new(move |_iter, rng| {
            let (x, y) = ds.batch(batch, rng);
            Ok(vec![
                HostTensor::f32(&[batch, dim], x),
                HostTensor::f32(&[batch, classes], y),
            ])
        }),
        false,
    )
}

fn build_mf(
    engine: Arc<Mutex<Engine>>,
    variant: &str,
    meta: &crate::runtime::ArtifactMeta,
    opts: &BuildOpts,
) -> Result<HloTrainer> {
    let (m, rank) = (meta.inputs[0].shape[0], meta.inputs[0].shape[1]);
    let n = meta.inputs[1].shape[1];
    // Density mirrors the dataset being stood in for: movielens-small is
    // sparse (~1.7%), jester dense (~56%); pick by aspect.
    let density = if n > m { 0.05 } else { 0.5 };
    let ratings = Ratings::lowrank(m, n, rank, density, 0.3, opts.data_seed);
    let vals = ratings.values.clone();
    let mask = ratings.mask.clone();
    HloTrainer::new(
        engine,
        variant,
        |store| {
            let mut atoms = AtomLayout::rows_of(store, "l");
            atoms.extend(AtomLayout::cols_of(store, "r"));
            AtomLayout::new(atoms)
        },
        Box::new(move |store, rng| {
            // Paper App C: entries uniform in [0, 1).
            for name in ["l", "r"] {
                let t = store.get_mut(name);
                t.data.iter_mut().for_each(|v| *v = rng.f32());
            }
        }),
        Box::new(move |_iter, _rng| {
            Ok(vec![
                HostTensor::f32(&[m, n], vals.clone()),
                HostTensor::f32(&[m, n], mask.clone()),
            ])
        }),
        true, // ratings + mask never change: device-resident (6.4 MB/step saved)
    )
}

fn build_cnn(
    engine: Arc<Mutex<Engine>>,
    variant: &str,
    meta: &crate::runtime::ArtifactMeta,
    opts: &BuildOpts,
) -> Result<HloTrainer> {
    let data_spec = meta
        .inputs
        .iter()
        .find(|s| s.name == "x")
        .context("cnn artifact missing x input")?;
    let (batch, im) = (data_spec.shape[0], data_spec.shape[1]);
    let classes = meta
        .inputs
        .iter()
        .find(|s| s.name == "y")
        .context("cnn artifact missing y input")?
        .shape[1];
    let dim = im * im;
    let ds = Classification::gaussian_mixture(dim, classes, 4096, 6.0, opts.data_seed);
    let partitioning = opts.partitioning;
    HloTrainer::new(
        engine,
        variant,
        move |store| {
            let names = param_tensor_names(store);
            let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
            let atoms = match partitioning {
                Partitioning::ByLayer => per_tensor_atoms(store, &refs),
                Partitioning::ByShard => sharded_atoms(store, &refs),
            };
            AtomLayout::new(atoms)
        },
        Box::new(|store, rng| {
            // He init for weights; zeros for biases and moments.
            let names = param_tensor_names(store);
            for name in names {
                let t = store.get_mut(&name);
                if t.shape.len() >= 2 {
                    let fan_in: usize = t.shape[..t.shape.len() - 1].iter().product();
                    let scale = (2.0 / fan_in as f64).sqrt();
                    t.data.iter_mut().for_each(|v| *v = (rng.normal() * scale) as f32);
                }
            }
        }),
        Box::new(move |iter, rng| {
            let (x, y) = ds.batch(batch, rng);
            Ok(vec![
                HostTensor::f32(&[1], vec![(iter + 1) as f32]),
                HostTensor::f32(&[batch, im, im, 1], x),
                HostTensor::f32(&[batch, classes], y),
            ])
        }),
        false,
    )
}

fn build_transformer(
    engine: Arc<Mutex<Engine>>,
    variant: &str,
    meta: &crate::runtime::ArtifactMeta,
    opts: &BuildOpts,
) -> Result<HloTrainer> {
    let tok_spec = meta
        .inputs
        .iter()
        .find(|s| s.name == "tokens")
        .context("transformer artifact missing tokens input")?;
    let (batch, seq) = (tok_spec.shape[0], tok_spec.shape[1]);
    let vocab = meta.hyper_f64("vocab").context("missing vocab hyper")? as usize;
    let stream = TokenStream::markov(vocab, 4, opts.data_seed);
    let partitioning = opts.partitioning;
    HloTrainer::new(
        engine,
        variant,
        move |store| {
            let names = param_tensor_names(store);
            let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
            let atoms = match partitioning {
                Partitioning::ByLayer => per_tensor_atoms(store, &refs),
                Partitioning::ByShard => sharded_atoms(store, &refs),
            };
            AtomLayout::new(atoms)
        },
        Box::new(|store, rng| {
            let names = param_tensor_names(store);
            for name in names {
                let t = store.get_mut(&name);
                if name.starts_with("ln") && name.ends_with('g') {
                    t.data.iter_mut().for_each(|v| *v = 1.0);
                } else if name.starts_with("ln") || name.starts_with('b') {
                    // layernorm biases and ff biases stay zero
                } else {
                    t.data.iter_mut().for_each(|v| *v = (rng.normal() * 0.02) as f32);
                }
            }
        }),
        Box::new(move |iter, rng| {
            let (tokens, targets) = stream.batch(batch, seq, rng);
            Ok(vec![
                HostTensor::f32(&[1], vec![(iter + 1) as f32]),
                HostTensor::i32(&[batch, seq], tokens),
                HostTensor::i32(&[batch, seq], targets),
            ])
        }),
        false,
    )
}

/// Shared engine constructor for examples/benches.
pub fn default_engine() -> Result<Arc<Mutex<Engine>>> {
    Ok(Arc::new(Mutex::new(Engine::cpu(&crate::artifact_dir())?)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::{ParamStore, Tensor};

    fn store_with_opt() -> ParamStore {
        ParamStore::new(vec![
            Tensor::zeros("w", &[4, 3]),
            Tensor::zeros("b", &[3]),
            Tensor::zeros("m_w", &[4, 3]),
            Tensor::zeros("v_w", &[4, 3]),
            Tensor::zeros("m_b", &[3]),
            Tensor::zeros("v_b", &[3]),
        ])
    }

    #[test]
    fn sharded_atoms_colocate_moments() {
        let s = store_with_opt();
        let atoms = sharded_atoms(&s, &["w", "b"]);
        // 4 shards of w + 1 shard of b (rows() of [3] is 3... b has shape [3])
        // b.rows() == 3, row_len == 1 -> 3 atoms.
        assert_eq!(atoms.len(), 4 + 3);
        // Each w atom: w slice + m_w + v_w slices.
        assert_eq!(atoms[0].len(), 3);
        let layout = AtomLayout::new(atoms);
        assert!(layout.is_disjoint(&s));
        assert_eq!(layout.total_len(), s.total_elems());
    }

    #[test]
    fn per_tensor_atoms_cover_everything() {
        let s = store_with_opt();
        let atoms = per_tensor_atoms(&s, &["w", "b"]);
        assert_eq!(atoms.len(), 2);
        let layout = AtomLayout::new(atoms);
        assert!(layout.is_disjoint(&s));
        assert_eq!(layout.total_len(), s.total_elems());
    }

    #[test]
    fn param_names_exclude_moments() {
        let s = store_with_opt();
        assert_eq!(param_tensor_names(&s), vec!["w".to_string(), "b".to_string()]);
    }
}
