//! Latent Dirichlet Allocation by collapsed Gibbs sampling (§5.1, App. C).
//!
//! The one paper workload that is not an AOT artifact: collapsed Gibbs is
//! inherently sequential per-token state mutation (the exact algorithm
//! the paper's C++ system ran), so it lives as a Rust substrate.
//!
//! State/atom semantics follow App. C:
//! * checkpointed parameters are the **document-topic counts** (one atom
//!   per document, distance = total variation scaled by document length);
//! * word-topic counts are *not* checkpointed — they are regenerated from
//!   token-topic assignments;
//! * losing a document's topic distribution also loses its token-topic
//!   assignments, so recovery re-samples the document's assignments from
//!   the restored distribution, then rebuilds the word-topic tables.

use anyhow::Result;

use crate::data::Corpus;
use crate::params::{AtomLayout, AtomNorm, ParamStore, Segment, Tensor};
use crate::trainer::Trainer;
use crate::util::rng::Rng;

pub struct LdaTrainer {
    name: String,
    corpus: Corpus,
    topics: usize,
    alpha: f64,
    beta: f64,
    /// token-topic assignments, per document
    z: Vec<Vec<u16>>,
    /// word-topic counts (vocab x topics)
    nwk: Vec<u32>,
    /// per-topic totals
    nk: Vec<u32>,
    /// The coordinator-visible state: doc-topic counts as f32 (docs x K).
    state: ParamStore,
    layout: AtomLayout,
    seed_rng: Rng,
    /// set when the coordinator rewrote `state` (recovery/perturbation);
    /// the next step first re-syncs assignments from the restored counts.
    dirty: bool,
}

impl LdaTrainer {
    pub fn new(name: &str, corpus: Corpus, topics: usize, alpha: f64, beta: f64) -> LdaTrainer {
        let n_docs = corpus.docs.len();
        let state = ParamStore::new(vec![Tensor::zeros("doc_topic", &[n_docs, topics])]);
        let atoms: Vec<Vec<Segment>> = (0..n_docs)
            .map(|d| vec![Segment { tensor: 0, start: d * topics, len: topics }])
            .collect();
        let mut layout = AtomLayout::new(atoms);
        layout.norm = AtomNorm::ScaledTv;
        // Distance scaled by document length (App. C) so prioritization is
        // not biased toward short documents.
        layout.weights = corpus.docs.iter().map(|d| d.len() as f64).collect();
        LdaTrainer {
            name: name.to_string(),
            z: corpus.docs.iter().map(|d| vec![0u16; d.len()]).collect(),
            nwk: vec![0; corpus.vocab * topics],
            nk: vec![0; topics],
            corpus,
            topics,
            alpha,
            beta,
            state,
            layout,
            seed_rng: Rng::new(0),
            dirty: false,
        }
    }

    pub fn n_docs(&self) -> usize {
        self.corpus.docs.len()
    }

    fn ndk(&self, d: usize, k: usize) -> f32 {
        self.state.tensors[0].data[d * self.topics + k]
    }

    fn ndk_add(&mut self, d: usize, k: usize, delta: f32) {
        self.state.tensors[0].data[d * self.topics + k] += delta;
    }

    /// Rebuild word-topic tables and doc counts from assignments.
    fn rebuild_counts(&mut self) {
        self.nwk.iter_mut().for_each(|c| *c = 0);
        self.nk.iter_mut().for_each(|c| *c = 0);
        self.state.tensors[0].data.iter_mut().for_each(|c| *c = 0.0);
        for d in 0..self.corpus.docs.len() {
            for (i, &w) in self.corpus.docs[d].iter().enumerate() {
                let k = self.z[d][i] as usize;
                self.nwk[w as usize * self.topics + k] += 1;
                self.nk[k] += 1;
                self.state.tensors[0].data[d * self.topics + k] += 1.0;
            }
        }
    }

    /// Re-sample a document's assignments to match a (possibly stale)
    /// doc-topic count row restored from a checkpoint. The restored row is
    /// treated as an (unnormalized) distribution over topics.
    fn resync_doc(&mut self, d: usize, rng: &mut Rng) {
        let row: Vec<f64> = (0..self.topics)
            .map(|k| (self.ndk(d, k) as f64).max(0.0) + self.alpha)
            .collect();
        let len = self.corpus.docs[d].len();
        for i in 0..len {
            self.z[d][i] = rng.categorical(&row) as u16;
        }
    }

    /// After the coordinator rewrote `state`: adopt it by re-sampling each
    /// document whose counts no longer match its assignments, then rebuild
    /// global tables from assignments.
    fn sync_from_state(&mut self, rng: &mut Rng) {
        for d in 0..self.corpus.docs.len() {
            let mut counts = vec![0f32; self.topics];
            for &zi in &self.z[d] {
                counts[zi as usize] += 1.0;
            }
            let matches = (0..self.topics)
                .all(|k| (counts[k] - self.ndk(d, k)).abs() < 0.5);
            if !matches {
                self.resync_doc(d, rng);
            }
        }
        self.rebuild_counts();
    }

    /// Negative log-likelihood of the corpus under the current smoothed
    /// topic estimates (lower = better; the paper's convergence metric).
    pub fn neg_log_likelihood(&self) -> f64 {
        let v = self.corpus.vocab as f64;
        let k_f = self.topics as f64;
        let mut nll = 0.0f64;
        for d in 0..self.corpus.docs.len() {
            let doc_len: f64 = (0..self.topics).map(|k| self.ndk(d, k) as f64).sum();
            let theta_den = doc_len + k_f * self.alpha;
            for &w in &self.corpus.docs[d] {
                let mut p = 0.0f64;
                for k in 0..self.topics {
                    let theta = (self.ndk(d, k) as f64 + self.alpha) / theta_den;
                    let phi = (self.nwk[w as usize * self.topics + k] as f64 + self.beta)
                        / (self.nk[k] as f64 + v * self.beta);
                    p += theta * phi;
                }
                nll -= p.max(1e-300).ln();
            }
        }
        nll
    }
}

impl Trainer for LdaTrainer {
    fn name(&self) -> &str {
        &self.name
    }

    fn init(&mut self, seed: u64) -> Result<()> {
        self.seed_rng = Rng::new(seed);
        let mut rng = self.seed_rng.derive(u64::MAX);
        for d in 0..self.corpus.docs.len() {
            for i in 0..self.corpus.docs[d].len() {
                self.z[d][i] = rng.below(self.topics) as u16;
            }
        }
        self.rebuild_counts();
        self.dirty = false;
        Ok(())
    }

    fn step(&mut self, iter: usize) -> Result<f64> {
        let mut rng = self.seed_rng.derive(iter as u64);
        if self.dirty {
            self.sync_from_state(&mut rng);
            self.dirty = false;
        }
        let v_beta = self.corpus.vocab as f64 * self.beta;
        let mut probs = vec![0f64; self.topics];
        for d in 0..self.corpus.docs.len() {
            for i in 0..self.corpus.docs[d].len() {
                let w = self.corpus.docs[d][i] as usize;
                let old = self.z[d][i] as usize;
                // Remove the token from all counts.
                self.ndk_add(d, old, -1.0);
                self.nwk[w * self.topics + old] -= 1;
                self.nk[old] -= 1;
                // Collapsed Gibbs conditional.
                for k in 0..self.topics {
                    probs[k] = (self.ndk(d, k) as f64 + self.alpha)
                        * (self.nwk[w * self.topics + k] as f64 + self.beta)
                        / (self.nk[k] as f64 + v_beta);
                }
                let new = rng.categorical(&probs);
                self.z[d][i] = new as u16;
                self.ndk_add(d, new, 1.0);
                self.nwk[w * self.topics + new] += 1;
                self.nk[new] += 1;
            }
        }
        Ok(self.neg_log_likelihood())
    }

    fn state(&self) -> &ParamStore {
        &self.state
    }

    fn state_mut(&mut self) -> &mut ParamStore {
        self.dirty = true;
        &mut self.state
    }

    fn layout(&self) -> &AtomLayout {
        &self.layout
    }

    fn set_state(&mut self, state: ParamStore) {
        self.state = state;
        self.dirty = true;
    }

    fn loss_name(&self) -> &str {
        "neg_log_likelihood"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> LdaTrainer {
        let corpus = Corpus::lda_generative(40, 60, 4, 24, 0.5, 0.1, 11);
        LdaTrainer::new("lda_test", corpus, 4, 1.0, 1.0)
    }

    #[test]
    fn nll_decreases_with_training() {
        let mut t = small();
        t.init(5).unwrap();
        let first = t.step(0).unwrap();
        let mut last = first;
        for it in 1..15 {
            last = t.step(it).unwrap();
        }
        assert!(last < first, "nll should drop: {first} -> {last}");
    }

    #[test]
    fn counts_stay_consistent() {
        let mut t = small();
        t.init(6).unwrap();
        for it in 0..3 {
            t.step(it).unwrap();
        }
        // doc-topic rows sum to doc lengths; topic totals match.
        for d in 0..t.n_docs() {
            let sum: f32 = (0..t.topics).map(|k| t.ndk(d, k)).sum();
            assert_eq!(sum as usize, t.corpus.docs[d].len());
        }
        let total_nk: u32 = t.nk.iter().sum();
        assert_eq!(total_nk as usize, t.corpus.n_tokens());
    }

    #[test]
    fn recovery_resync_restores_consistency() {
        let mut t = small();
        t.init(7).unwrap();
        for it in 0..4 {
            t.step(it).unwrap();
        }
        // Simulate a partial recovery: clobber one doc's row with an old
        // distribution (e.g. all mass on topic 0).
        let topics = t.topics;
        let row0: Vec<f32> = {
            let mut v = vec![0.0; topics];
            v[0] = t.corpus.docs[3].len() as f32;
            v
        };
        t.state_mut().tensors[0].data[3 * topics..4 * topics].copy_from_slice(&row0);
        let loss = t.step(4).unwrap();
        assert!(loss.is_finite());
        for d in 0..t.n_docs() {
            let sum: f32 = (0..topics).map(|k| t.ndk(d, k)).sum();
            assert_eq!(sum as usize, t.corpus.docs[d].len(), "doc {d}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut t = small();
            t.init(9).unwrap();
            let mut losses = Vec::new();
            for it in 0..5 {
                losses.push(t.step(it).unwrap());
            }
            losses
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn layout_uses_scaled_tv_with_doc_length_weights() {
        let t = small();
        assert_eq!(t.layout().norm, AtomNorm::ScaledTv);
        assert_eq!(t.layout().n_atoms(), t.n_docs());
        for (d, &w) in t.layout().weights.iter().enumerate() {
            assert_eq!(w as usize, t.corpus.docs[d].len());
        }
    }
}
