//! Checkpoint coordinator (paper §4.2–4.3, Fig 4).
//!
//! Implements both the traditional baseline (full checkpoints every `C`
//! iterations) and SCAR's prioritized partial checkpoints: a fraction `r`
//! of atoms every `rC` iterations, selected by one of
//!
//! * **priority** — atoms whose current values have drifted farthest from
//!   their last-saved values (distance under the layout's norm);
//! * **round** — round-robin over atom ids;
//! * **random** — uniform without replacement;
//!
//! writing into a *running checkpoint*: persistent storage initialized
//! with x⁽⁰⁾ and updated per partial checkpoint, so at any time it holds a
//! mix of atoms saved at different iterations.
//!
//! Each PS node keeps an in-memory cache of the running checkpoint for
//! distance computation (§4.3); in this coordinator the cache is one
//! `ParamStore` and the distance pass is the hot path measured in
//! `benches/priority_selection.rs`.
//!
//! Two write paths share the selection/cache logic:
//!
//! * [`CheckpointCoordinator`] — synchronous: the barrier persists atoms
//!   inline into any [`CheckpointStore`].
//! * [`AsyncCheckpointer`] (in [`pipeline`]) — pipelined: the barrier
//!   snapshots the selected atoms copy-on-write and hands them to a
//!   background writer pool over a sharded store; training resumes
//!   immediately and a `flush` fence makes the state durable before any
//!   recovery read.

pub mod pipeline;
pub mod select;

use anyhow::Result;

use crate::params::{AtomLayout, ParamStore};
use crate::storage::CheckpointStore;
use crate::util::rng::Rng;

pub use pipeline::AsyncCheckpointer;
pub use select::Selector;

/// Whether checkpoint barriers block on persistent storage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CheckpointMode {
    /// The barrier writes to storage inline (traditional).
    #[default]
    Sync,
    /// The barrier snapshots atoms and returns; a writer pool persists
    /// them in the background (§4.3 step 4, made explicit).
    Async,
}

impl std::str::FromStr for CheckpointMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "sync" => Ok(CheckpointMode::Sync),
            "async" => Ok(CheckpointMode::Async),
            other => Err(format!("unknown checkpoint mode '{other}' (sync|async)")),
        }
    }
}

impl std::fmt::Display for CheckpointMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            CheckpointMode::Sync => "sync",
            CheckpointMode::Async => "async",
        })
    }
}

/// Checkpoint policy: the paper's (r, rC) scheme. `fraction = 1.0` with
/// `interval = C` is the traditional full-checkpoint baseline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CheckpointPolicy {
    /// Fraction r of atoms saved per checkpoint (0 < r <= 1).
    pub fraction: f64,
    /// Iterations between checkpoints (the paper's rC).
    pub interval: usize,
    pub selector: Selector,
}

impl CheckpointPolicy {
    pub fn full(interval: usize) -> Self {
        CheckpointPolicy { fraction: 1.0, interval, selector: Selector::Priority }
    }

    /// SCAR policy with data-volume parity against `full(base_interval)`.
    ///
    /// When `k` divides `base_interval` this is exactly the paper's
    /// parametrization: fraction `1/k` every `base_interval/k` iterations.
    /// When it does not, the interval is rounded to the nearest integer
    /// and the fraction recomputed as `interval / base_interval`, so
    /// bytes-per-`base_interval` parity holds *by construction* (the old
    /// behavior silently over- or under-wrote by up to ~2× for, e.g.,
    /// `base_interval = 10, k = 3`).
    pub fn partial(base_interval: usize, k: usize, selector: Selector) -> Self {
        assert!(k >= 1, "k must be >= 1");
        assert!(base_interval >= 1, "base_interval must be >= 1");
        let interval = ((base_interval as f64 / k as f64).round() as usize)
            .clamp(1, base_interval);
        let fraction = interval as f64 / base_interval as f64;
        CheckpointPolicy { fraction, interval, selector }
    }

    pub fn atoms_per_checkpoint(&self, n_atoms: usize) -> usize {
        ((self.fraction * n_atoms as f64).round() as usize).clamp(1, n_atoms)
    }
}

/// Outcome of one checkpoint barrier, for §5.5 accounting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CheckpointStats {
    pub iter: usize,
    pub atoms_saved: usize,
    pub bytes: u64,
    /// Seconds spent selecting atoms + updating the in-memory cache — the
    /// only part the training loop blocks on (storage output is async in
    /// SCAR; §4.3 step 4).
    pub blocking_secs: f64,
}

pub struct CheckpointCoordinator {
    pub policy: CheckpointPolicy,
    /// In-memory cache of the running checkpoint (what the PS nodes use
    /// for distance computation, and what recovery reads through).
    cache: ParamStore,
    /// Iteration at which each atom was last saved.
    saved_iter: Vec<usize>,
    rr_cursor: usize,
    scratch: Vec<f32>,
}

impl CheckpointCoordinator {
    /// Initialize the running checkpoint with the initial parameters x⁽⁰⁾
    /// (paper §4.2) and persist them.
    pub fn new(
        policy: CheckpointPolicy,
        init: &ParamStore,
        layout: &AtomLayout,
        store: &mut dyn CheckpointStore,
    ) -> Result<CheckpointCoordinator> {
        let mut coord = CheckpointCoordinator::new_unpersisted(policy, init, layout);
        // Persist x(0) as the initial running checkpoint.
        coord.persist_atoms(0, &(0..layout.n_atoms()).collect::<Vec<_>>(), init, layout, store)?;
        store.mark_committed(0);
        Ok(coord)
    }

    /// Build the coordinator state without touching storage (the async
    /// pipeline persists x⁽⁰⁾ through its own path).
    pub(crate) fn new_unpersisted(
        policy: CheckpointPolicy,
        init: &ParamStore,
        layout: &AtomLayout,
    ) -> CheckpointCoordinator {
        CheckpointCoordinator {
            policy,
            cache: init.clone(),
            saved_iter: vec![0; layout.n_atoms()],
            rr_cursor: 0,
            scratch: Vec::new(),
        }
    }

    pub fn cache(&self) -> &ParamStore {
        &self.cache
    }

    pub fn saved_iter(&self, atom: usize) -> usize {
        self.saved_iter[atom]
    }

    /// Select the barrier's atoms and fold them into the in-memory cache
    /// — the blocking part of every barrier, shared by the sync and async
    /// write paths. Returns the chosen atom ids.
    pub(crate) fn select_and_update_cache(
        &mut self,
        iter: usize,
        current: &ParamStore,
        layout: &AtomLayout,
        rng: &mut Rng,
    ) -> Vec<usize> {
        let k = self.policy.atoms_per_checkpoint(layout.n_atoms());
        let chosen = select::select_atoms(
            self.policy.selector,
            k,
            current,
            &self.cache,
            layout,
            &mut self.rr_cursor,
            rng,
        );
        for &a in &chosen {
            current.read_atom(layout, a, &mut self.scratch);
            self.cache.write_atom(layout, a, &self.scratch);
            self.saved_iter[a] = iter;
        }
        chosen
    }

    /// Run a checkpoint barrier if the policy schedules one at `iter`.
    pub fn maybe_checkpoint(
        &mut self,
        iter: usize,
        current: &ParamStore,
        layout: &AtomLayout,
        store: &mut dyn CheckpointStore,
        rng: &mut Rng,
    ) -> Result<Option<CheckpointStats>> {
        if iter == 0 || iter % self.policy.interval != 0 {
            return Ok(None);
        }
        Ok(Some(self.checkpoint_now(iter, current, layout, store, rng)?))
    }

    /// Force a checkpoint barrier at `iter` regardless of schedule.
    pub fn checkpoint_now(
        &mut self,
        iter: usize,
        current: &ParamStore,
        layout: &AtomLayout,
        store: &mut dyn CheckpointStore,
        rng: &mut Rng,
    ) -> Result<CheckpointStats> {
        let t0 = std::time::Instant::now();
        let chosen = self.select_and_update_cache(iter, current, layout, rng);
        // After the cache update the training loop could resume; the
        // persistent write is accounted separately.
        let blocking_secs = t0.elapsed().as_secs_f64();
        let bytes_before = store.bytes_written();
        self.persist_atoms(iter, &chosen, current, layout, store)?;
        store.mark_committed(iter);
        Ok(CheckpointStats {
            iter,
            atoms_saved: chosen.len(),
            bytes: store.bytes_written() - bytes_before,
            blocking_secs,
        })
    }

    fn persist_atoms(
        &mut self,
        iter: usize,
        atoms: &[usize],
        from: &ParamStore,
        layout: &AtomLayout,
        store: &mut dyn CheckpointStore,
    ) -> Result<()> {
        let payloads = collect_payloads(atoms, from, layout);
        let refs: Vec<(usize, &[f32])> =
            payloads.iter().map(|(a, v)| (*a, v.as_slice())).collect();
        store.put_atoms(iter, &refs)
    }
}

/// Copy the given atoms' values out of `from` into owned buffers — the
/// copy-on-write snapshot a barrier hands to the writer pool (atoms may
/// have multi-segment values, so each payload is flattened).
pub(crate) fn collect_payloads(
    atoms: &[usize],
    from: &ParamStore,
    layout: &AtomLayout,
) -> Vec<(usize, Vec<f32>)> {
    let mut payloads: Vec<(usize, Vec<f32>)> = Vec::with_capacity(atoms.len());
    for &a in atoms {
        let mut buf = Vec::new();
        from.read_atom(layout, a, &mut buf);
        payloads.push((a, buf));
    }
    payloads
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::{AtomLayout, ParamStore, Tensor};
    use crate::storage::MemStore;

    fn setup(n: usize) -> (ParamStore, AtomLayout) {
        let store = ParamStore::new(vec![Tensor::zeros("w", &[n, 2])]);
        let layout = AtomLayout::new(AtomLayout::rows_of(&store, "w"));
        (store, layout)
    }

    #[test]
    fn initial_checkpoint_holds_x0() {
        let (mut ps, layout) = setup(4);
        ps.get_mut("w").data[0] = 5.0;
        let mut store = MemStore::new();
        let coord = CheckpointCoordinator::new(
            CheckpointPolicy::full(4),
            &ps,
            &layout,
            &mut store,
        )
        .unwrap();
        assert_eq!(store.records_written(), 4);
        assert_eq!(coord.cache().get("w").data[0], 5.0);
        assert_eq!(store.get_atom(0).unwrap().unwrap().values, vec![5.0, 0.0]);
    }

    #[test]
    fn schedule_respected() {
        let (ps, layout) = setup(4);
        let mut store = MemStore::new();
        let mut coord =
            CheckpointCoordinator::new(CheckpointPolicy::full(3), &ps, &layout, &mut store)
                .unwrap();
        let mut rng = Rng::new(0);
        assert!(coord.maybe_checkpoint(1, &ps, &layout, &mut store, &mut rng).unwrap().is_none());
        assert!(coord.maybe_checkpoint(2, &ps, &layout, &mut store, &mut rng).unwrap().is_none());
        let stats = coord.maybe_checkpoint(3, &ps, &layout, &mut store, &mut rng).unwrap().unwrap();
        assert_eq!(stats.atoms_saved, 4);
    }

    #[test]
    fn priority_saves_most_changed() {
        let (mut ps, layout) = setup(4);
        let mut store = MemStore::new();
        let policy = CheckpointPolicy { fraction: 0.25, interval: 1, selector: Selector::Priority };
        let mut coord = CheckpointCoordinator::new(policy, &ps, &layout, &mut store).unwrap();
        let mut rng = Rng::new(0);
        // Atom 2 drifts the most.
        ps.get_mut("w").data[4] = 100.0;
        ps.get_mut("w").data[0] = 1.0;
        let stats = coord.checkpoint_now(1, &ps, &layout, &mut store, &mut rng).unwrap();
        assert_eq!(stats.atoms_saved, 1);
        assert_eq!(store.get_atom(2).unwrap().unwrap().values, vec![100.0, 0.0]);
        assert_eq!(coord.saved_iter(2), 1);
        assert_eq!(coord.saved_iter(0), 0);
    }

    #[test]
    fn parity_of_bytes_written() {
        // fraction 1/2 at interval 2 writes the same bytes per 4 iters as
        // full at interval 4 (§4.2 parity).
        let (ps, layout) = setup(8);
        let mut rng = Rng::new(0);

        let mut bytes_for = |policy: CheckpointPolicy| -> u64 {
            let mut store = MemStore::new();
            let mut coord =
                CheckpointCoordinator::new(policy, &ps, &layout, &mut store).unwrap();
            let base = store.bytes_written();
            for iter in 1..=8 {
                coord.maybe_checkpoint(iter, &ps, &layout, &mut store, &mut rng).unwrap();
            }
            store.bytes_written() - base
        };

        let full = bytes_for(CheckpointPolicy::full(4));
        let half = bytes_for(CheckpointPolicy::partial(4, 2, Selector::RoundRobin));
        assert_eq!(full, half);
    }

    #[test]
    fn parity_holds_when_k_does_not_divide_interval() {
        // base_interval = 10, k = 3: the old `(10 / 3).max(1) = 3` with
        // fraction 1/3 wrote 10/9 of the full-policy volume. The fixed
        // policy saves fraction 3/10 every 3 iterations — exact parity
        // over any common multiple of the intervals.
        let policy = CheckpointPolicy::partial(10, 3, Selector::RoundRobin);
        assert_eq!(policy.interval, 3);
        assert!((policy.fraction - 0.3).abs() < 1e-12);

        let (ps, layout) = setup(30);
        let mut rng = Rng::new(0);
        let mut bytes_for = |policy: CheckpointPolicy| -> u64 {
            let mut store = MemStore::new();
            let mut coord =
                CheckpointCoordinator::new(policy, &ps, &layout, &mut store).unwrap();
            let base = store.bytes_written();
            for iter in 1..=30 {
                coord.maybe_checkpoint(iter, &ps, &layout, &mut store, &mut rng).unwrap();
            }
            store.bytes_written() - base
        };
        let full = bytes_for(CheckpointPolicy::full(10));
        let partial = bytes_for(policy);
        assert_eq!(full, partial, "bytes-written parity must hold exactly");
    }

    #[test]
    fn partial_keeps_exact_form_when_k_divides() {
        let p = CheckpointPolicy::partial(8, 4, Selector::Priority);
        assert_eq!(p.interval, 2);
        assert!((p.fraction - 0.25).abs() < 1e-12);
        let p1 = CheckpointPolicy::partial(8, 1, Selector::Priority);
        assert_eq!(p1.interval, 8);
        assert!((p1.fraction - 1.0).abs() < 1e-12);
    }

    #[test]
    fn round_robin_cycles_all_atoms() {
        let (ps, layout) = setup(6);
        let mut store = MemStore::new();
        let policy = CheckpointPolicy { fraction: 1.0 / 3.0, interval: 1, selector: Selector::RoundRobin };
        let mut coord = CheckpointCoordinator::new(policy, &ps, &layout, &mut store).unwrap();
        let mut rng = Rng::new(0);
        for iter in 1..=3 {
            coord.checkpoint_now(iter, &ps, &layout, &mut store, &mut rng).unwrap();
        }
        // After 3 checkpoints of 2 atoms each, every atom saved at >= 1.
        for a in 0..6 {
            assert!(coord.saved_iter(a) >= 1, "atom {a} never saved");
        }
    }

    #[test]
    fn checkpoint_mode_parses() {
        assert_eq!("sync".parse::<CheckpointMode>().unwrap(), CheckpointMode::Sync);
        assert_eq!("async".parse::<CheckpointMode>().unwrap(), CheckpointMode::Async);
        assert!("background".parse::<CheckpointMode>().is_err());
        assert_eq!(CheckpointMode::Async.to_string(), "async");
    }
}
