//! Pipelined checkpoint writes: non-blocking barriers over a sharded
//! store (the ROADMAP's "async" leg of the storage refactor).
//!
//! A traditional barrier stalls the training loop for the full storage
//! dump. SCAR's observation (§4.3 step 4) is that only atom *selection*
//! and the in-memory cache update must happen at the barrier; the
//! persistent write can proceed concurrently with training. The
//! [`AsyncCheckpointer`] makes that explicit:
//!
//! 1. At the barrier it runs the shared selection/cache logic of
//!    [`CheckpointCoordinator`], then snapshots the chosen atoms
//!    copy-on-write into owned buffers.
//! 2. In [`CheckpointMode::Async`] the snapshot is handed to a writer
//!    pool (one thread per shard group) and the barrier returns
//!    immediately; in [`CheckpointMode::Sync`] it is written inline —
//!    both modes share one code path so experiments can price them
//!    against each other.
//! 3. [`flush`](AsyncCheckpointer::flush) is the epoch fence: it drains
//!    the pool, syncs every shard (disk manifests), and advances the
//!    store's commit watermark. Recovery must fence first — the watermark
//!    makes a forgotten fence a loud error instead of a silent
//!    nondeterminism (see [`crate::recovery::recover`]).
//!
//! Determinism: the payload handed to the pool is snapshotted *at the
//! barrier*, each shard's jobs flow through exactly one writer's FIFO, and
//! records supersede by iteration — so after a fence, async and sync runs
//! of the same seed hold byte-identical running checkpoints
//! (`rust/tests/async_checkpoint.rs` pins this).
//!
//! Two failure-domain extensions ride on the same front-end:
//!
//! * **Back-pressure** ([`with_max_pending`](AsyncCheckpointer::with_max_pending)):
//!   a bounded job queue makes a barrier block once the pool falls more
//!   than `max_pending` jobs behind, so a slow shard throttles barrier
//!   frequency instead of growing snapshot memory without bound.
//! * **Storage chaos**: every `maybe_checkpoint` call advances the
//!   store's injected-fault clock ([`crate::chaos`]); when a shard dies,
//!   the [`RebuildPlan`](crate::recovery::RebuildPlan) planner
//!   re-persists *only that shard's slice* (per the store's placement
//!   map) from the in-memory cache, so recovery can always read every
//!   atom through the survivors at ~`1/n_shards` of the old full
//!   re-persist's write amplification; healed (flaky) shards re-adopt
//!   their slices the same way.
//! * **Segment compaction**
//!   ([`with_compaction`](AsyncCheckpointer::with_compaction)): disk
//!   shards accumulate superseded records; at every `flush` fence — the
//!   one point where the writer pool is drained and the store state is
//!   settled, so the garbage ratios are a deterministic function of the
//!   run — shards past the configured garbage-ratio threshold are folded
//!   into fresh segments. Scheduling compaction off the drained fence
//!   (rather than inside the writer threads) is what keeps the
//!   `compaction_*` counters identical run to run and across sync/async
//!   modes; the pass changes the on-disk footprint, never a read result.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::{bail, Result};

use crate::obs::{EventKind, Recorder};
use crate::params::{AtomLayout, ParamStore};
use crate::recovery::RebuildPlan;
use crate::storage::ShardedStore;
use crate::util::rng::Rng;

use super::{
    collect_payloads, CheckpointCoordinator, CheckpointMode, CheckpointPolicy, CheckpointStats,
};

/// One barrier's snapshot for one writer: atoms routed to that writer's
/// shards, copied at barrier time.
struct WriteJob {
    iter: usize,
    atoms: Vec<(usize, Vec<f32>)>,
}

struct PendingState {
    in_flight: usize,
    error: Option<String>,
}

struct PoolShared {
    pending: Mutex<PendingState>,
    drained: Condvar,
}

struct Writer {
    tx: Option<Sender<WriteJob>>,
    join: Option<JoinHandle<()>>,
}

/// Checkpoint front-end over a [`ShardedStore`] with sync and pipelined
/// (async) write modes. See the module docs for the protocol.
pub struct AsyncCheckpointer {
    coord: CheckpointCoordinator,
    store: Arc<ShardedStore>,
    mode: CheckpointMode,
    writers: Vec<Writer>,
    shared: Arc<PoolShared>,
    last_barrier_iter: usize,
    /// Async back-pressure bound: a barrier blocks once more than this
    /// many write jobs are pending (0 = unbounded, the default).
    max_pending: usize,
    /// Barriers that hit the back-pressure bound and had to wait.
    stalled_barriers: u64,
    /// Last iteration the fault clock advanced to (dedupes the
    /// maybe_checkpoint → checkpoint_now double tick).
    last_tick_iter: usize,
    /// Garbage-ratio threshold that triggers shard compaction at flush
    /// fences (0 = never compact, the default).
    compact_threshold: f64,
    /// Minimum on-disk shard size before compaction is worthwhile.
    compact_min_bytes: u64,
    /// Per-pass segment-byte budget for generational compaction
    /// (0 = monolithic full-shard passes, the default).
    compact_max_pass_bytes: u64,
    /// Flush fences run so far (denominator for per-fence gauges).
    fences: u64,
    /// Wall-clock of the most recent flush fence, in milliseconds.
    last_fence_wall_ms: f64,
    /// Total wall-clock across all flush fences, in milliseconds.
    total_fence_wall_ms: f64,
    /// Atoms selectively rebuilt onto survivors after shard deaths.
    rebuilt_atoms: u64,
    /// Payload bytes those rebuilds re-persisted (the selective-recovery
    /// headline number: ~`1/n_shards` of the checkpoint per death,
    /// where the old full re-persist paid the whole checkpoint).
    rebuilt_bytes: u64,
    /// Atoms re-adopted by healed shards (flaky up phases).
    readopted_atoms: u64,
    /// Payload bytes those re-adoptions re-persisted.
    readopted_bytes: u64,
    /// Per-atom CRC of the last payload handed to the store: the
    /// delta-skip filter drops a selected atom whose bytes are unchanged
    /// since its last persisted record (recovery is untouched — the
    /// freshest-record scan simply finds the identical older record).
    last_crc: Vec<u32>,
    /// Atoms elided by the delta-skip filter.
    skipped_atoms: u64,
    /// Payload bytes those elided writes would have cost.
    skipped_bytes: u64,
    /// Flight recorder (disabled unless attached via
    /// [`with_recorder`](AsyncCheckpointer::with_recorder)): narrates
    /// barriers, flush fences, parity-fence phases, rebuild-plan
    /// executions, and back-pressure stalls as iteration-clocked events.
    rec: Recorder,
}

/// Spawn `n_writers` background writer threads over the store (each
/// shard's jobs flow through exactly one writer, so per-shard order is
/// barrier order). Shared by construction-time and lazy
/// ([`AsyncCheckpointer::with_writer_pool`]) pool creation.
fn spawn_pool(
    store: &Arc<ShardedStore>,
    shared: &Arc<PoolShared>,
    n_writers: usize,
) -> Vec<Writer> {
    let mut pool = Vec::with_capacity(n_writers);
    for w in 0..n_writers {
        let (tx, rx): (Sender<WriteJob>, Receiver<WriteJob>) = channel();
        let store = store.clone();
        let shared = shared.clone();
        let join = std::thread::Builder::new()
            .name(format!("ckpt-writer-{w}"))
            .spawn(move || {
                while let Ok(job) = rx.recv() {
                    let refs: Vec<(usize, &[f32])> =
                        job.atoms.iter().map(|(a, v)| (*a, v.as_slice())).collect();
                    let res = store.put_atoms_at(job.iter, &refs);
                    let mut p = shared.pending.lock().unwrap();
                    if let Err(e) = res {
                        if p.error.is_none() {
                            p.error = Some(format!("{e:?}"));
                        }
                    }
                    p.in_flight -= 1;
                    shared.drained.notify_all();
                }
            })
            .expect("spawning checkpoint writer thread");
        pool.push(Writer { tx: Some(tx), join: Some(join) });
    }
    pool
}

/// Content fingerprint of one atom's payload (the delta-skip key).
fn payload_crc(vals: &[f32]) -> u32 {
    let mut hasher = crc32fast::Hasher::new();
    for v in vals {
        hasher.update(&v.to_le_bytes());
    }
    hasher.finalize()
}

impl AsyncCheckpointer {
    /// Initialize the running checkpoint with x⁽⁰⁾ (persisted inline and
    /// committed — startup is not the hot path) and, in async mode, spawn
    /// `writers` background threads (clamped to `[1, n_shards]`; each
    /// shard's writes always flow through exactly one writer so per-shard
    /// order is barrier order).
    pub fn new(
        policy: CheckpointPolicy,
        init: &ParamStore,
        layout: &AtomLayout,
        store: Arc<ShardedStore>,
        mode: CheckpointMode,
        writers: usize,
    ) -> Result<AsyncCheckpointer> {
        let coord = CheckpointCoordinator::new_unpersisted(policy, init, layout);
        let all: Vec<usize> = (0..layout.n_atoms()).collect();
        let payloads = collect_payloads(&all, init, layout);
        // Seed the delta-skip cache from the x⁽⁰⁾ dump: every atom's CRC
        // is known from here on, so the filter never misses a change.
        let mut last_crc = vec![0u32; layout.n_atoms()];
        for (atom, vals) in &payloads {
            last_crc[*atom] = payload_crc(vals);
        }
        let refs: Vec<(usize, &[f32])> =
            payloads.iter().map(|(a, v)| (*a, v.as_slice())).collect();
        store.put_atoms_at(0, &refs)?;
        store.sync_all()?;
        store.mark_committed_at(0);

        let shared = Arc::new(PoolShared {
            pending: Mutex::new(PendingState { in_flight: 0, error: None }),
            drained: Condvar::new(),
        });
        let n_writers = match mode {
            CheckpointMode::Sync => 0,
            CheckpointMode::Async => writers.clamp(1, store.n_shards()),
        };
        // Parity fences and rebuild slices fan out over the same width
        // as the writer pool (1 = serial for sync single-writer runs);
        // the fan-out is byte-identical to a serial pass by design.
        store.set_fence_workers(n_writers.max(1));
        let pool = spawn_pool(&store, &shared, n_writers);
        Ok(AsyncCheckpointer {
            coord,
            store,
            mode,
            writers: pool,
            shared,
            last_barrier_iter: 0,
            max_pending: 0,
            stalled_barriers: 0,
            last_tick_iter: usize::MAX,
            compact_threshold: 0.0,
            compact_min_bytes: 0,
            compact_max_pass_bytes: 0,
            fences: 0,
            last_fence_wall_ms: 0.0,
            total_fence_wall_ms: 0.0,
            rebuilt_atoms: 0,
            rebuilt_bytes: 0,
            readopted_atoms: 0,
            readopted_bytes: 0,
            last_crc,
            skipped_atoms: 0,
            skipped_bytes: 0,
            rec: Recorder::disabled(),
        })
    }

    /// Attach a flight recorder: the checkpointer narrates its barriers,
    /// fences, rebuilds, and stalls through it, and forwards the handle
    /// to every store backend so chaos injections are narrated too. The
    /// default (disabled) recorder costs one branch per would-be event.
    pub fn with_recorder(mut self, rec: Recorder) -> AsyncCheckpointer {
        self.store.set_recorder(rec.clone());
        self.rec = rec;
        self
    }

    /// Bound the async writer queue: barriers block once more than
    /// `max_pending` write jobs are pending (one job per writer per
    /// barrier), so a slow shard throttles barrier frequency instead of
    /// growing memory without bound. `0` = unbounded (the default).
    pub fn with_max_pending(mut self, max_pending: usize) -> AsyncCheckpointer {
        self.max_pending = max_pending;
        self
    }

    /// Barriers that hit the back-pressure bound and waited for the pool
    /// to drain (price them with
    /// [`LatencyModel::backpressure_stall_seconds`](crate::storage::LatencyModel::backpressure_stall_seconds)).
    pub fn backpressure_stalls(&self) -> u64 {
        self.stalled_barriers
    }

    /// Enable background segment compaction: at every `flush` fence, any
    /// live shard whose garbage ratio has reached `threshold` (and whose
    /// on-disk size is at least `min_bytes`) is folded into fresh
    /// segments. `threshold = 0` disables (the default); memory shards
    /// never report garbage, so this is a no-op for them either way.
    pub fn with_compaction(mut self, threshold: f64, min_bytes: u64) -> AsyncCheckpointer {
        self.compact_threshold = threshold;
        self.compact_min_bytes = min_bytes;
        self
    }

    /// Bound each triggered compaction pass to a generational fold of at
    /// most `max_pass_bytes` segment bytes (worst-garbage segments
    /// first), so pass latency stays flat regardless of shard size.
    /// `0` (the default) keeps monolithic full-shard passes.
    pub fn with_compaction_budget(mut self, max_pass_bytes: u64) -> AsyncCheckpointer {
        self.compact_max_pass_bytes = max_pass_bytes;
        self
    }

    /// Flush fences run so far.
    pub fn fences(&self) -> u64 {
        self.fences
    }

    /// Measured wall-clock of the most recent flush fence, in
    /// milliseconds. Observability only — wall-clock never feeds a
    /// decision, so byte-determinism is untouched; the policy controller
    /// can consume it as a measured dump-cost signal.
    pub fn last_fence_wall_ms(&self) -> f64 {
        self.last_fence_wall_ms
    }

    /// Mean measured flush-fence wall-clock so far, in milliseconds.
    pub fn avg_fence_wall_ms(&self) -> f64 {
        if self.fences == 0 {
            0.0
        } else {
            self.total_fence_wall_ms / self.fences as f64
        }
    }

    pub fn mode(&self) -> CheckpointMode {
        self.mode
    }

    /// Atoms selectively rebuilt onto survivors after storage-shard
    /// deaths so far (the planner's slices, not full re-persists).
    pub fn rebuilt_atoms(&self) -> u64 {
        self.rebuilt_atoms
    }

    /// Payload bytes those rebuilds re-persisted. With a placement-aware
    /// plan this is ~`1/n_shards` of the running checkpoint per death.
    ///
    /// Like `degraded_records`, the exact count is observability, not
    /// part of the determinism contract: with async writers, whether an
    /// in-flight pre-kill job lands before or after the tick can move an
    /// atom's placement between a dead and a live shard — the rebuilt
    /// *content* any read returns is identical either way.
    pub fn rebuilt_bytes(&self) -> u64 {
        self.rebuilt_bytes
    }

    /// Atoms re-adopted by healed shards (flaky up phases) so far.
    pub fn readopted_atoms(&self) -> u64 {
        self.readopted_atoms
    }

    /// Payload bytes those re-adoptions re-persisted.
    pub fn readopted_bytes(&self) -> u64 {
        self.readopted_bytes
    }

    /// Selected atoms elided by the delta-skip filter so far (bytes
    /// unchanged since their last persisted record).
    pub fn skipped_atoms(&self) -> u64 {
        self.skipped_atoms
    }

    /// Payload bytes those elided writes would have cost — checkpoint
    /// bandwidth the filter saved (big for sparse-update workloads,
    /// where `partial-k` keeps re-selecting barely-moving atoms).
    pub fn skipped_bytes(&self) -> u64 {
        self.skipped_bytes
    }

    pub fn policy(&self) -> CheckpointPolicy {
        self.coord.policy
    }

    /// Live-retune the checkpoint policy — the adaptive controller's
    /// write path. Safe at any iteration boundary: the schedule gate in
    /// [`maybe_checkpoint`](AsyncCheckpointer::maybe_checkpoint) reads
    /// the policy fresh on every call, so a change between barriers only
    /// reschedules *future* barriers; it never rewrites history. Byte-
    /// determinism holds as long as the decision itself is a pure
    /// function of iteration-clocked inputs (see [`crate::policy`]).
    pub fn set_policy(&mut self, policy: CheckpointPolicy) {
        self.coord.policy = policy;
    }

    /// Flip sync ↔ async at a safe switch point. Async → sync drains the
    /// writer pool first (a mini-fence), so an inline put can never race
    /// an in-flight async write to the same shard; sync → async requires
    /// a writer pool (construct in async mode or call
    /// [`with_writer_pool`](AsyncCheckpointer::with_writer_pool)). The
    /// stored bytes after any fence are identical either way — the
    /// sync/async byte-identity contract is exactly what makes this flip
    /// free to take mid-run.
    pub fn set_mode(&mut self, mode: CheckpointMode) -> Result<()> {
        if mode == self.mode {
            return Ok(());
        }
        if self.mode == CheckpointMode::Async {
            self.wait_pending_at_most(0)?;
        }
        if mode == CheckpointMode::Async && self.writers.is_empty() {
            bail!(
                "cannot switch to async checkpoints: no writer pool \
                 (construct in async mode or call with_writer_pool first)"
            );
        }
        self.mode = mode;
        Ok(())
    }

    /// Ensure a writer pool exists even when the initial mode is sync, so
    /// an adaptive policy controller can flip to async mid-run. No-op if
    /// the pool is already running. Also widens the parity-fence/rebuild
    /// fan-out to the pool width (byte-identical to serial by design).
    pub fn with_writer_pool(mut self, writers: usize) -> AsyncCheckpointer {
        if self.writers.is_empty() {
            let n = writers.clamp(1, self.store.n_shards());
            self.store.set_fence_workers(n);
            self.writers = spawn_pool(&self.store, &self.shared, n);
        }
        self
    }

    pub fn store(&self) -> &Arc<ShardedStore> {
        &self.store
    }

    /// In-memory running-checkpoint cache (see [`CheckpointCoordinator::cache`]).
    pub fn cache(&self) -> &ParamStore {
        self.coord.cache()
    }

    pub fn saved_iter(&self, atom: usize) -> usize {
        self.coord.saved_iter(atom)
    }

    /// Run a checkpoint barrier if the policy schedules one at `iter`.
    ///
    /// Also the storage fault clock: every call (barrier or not) advances
    /// the injected-fault epoch, so chaos kills/slow windows take effect
    /// at deterministic iterations — call it once per training iteration.
    pub fn maybe_checkpoint(
        &mut self,
        iter: usize,
        current: &ParamStore,
        layout: &AtomLayout,
        rng: &mut Rng,
    ) -> Result<Option<CheckpointStats>> {
        self.tick(iter, layout)?;
        if iter == 0 || iter % self.coord.policy.interval != 0 {
            return Ok(None);
        }
        Ok(Some(self.checkpoint_now(iter, current, layout, rng)?))
    }

    /// Advance the store's injected-fault clock to `iter` and react to
    /// health transitions through the rebuild planner
    /// ([`RebuildPlan`](crate::recovery::RebuildPlan)):
    ///
    /// * a shard that just **died** gets exactly its slice — the atoms
    ///   whose freshest routed record the placement map puts on it —
    ///   re-persisted from the in-memory cache (the §4.3 cache exists
    ///   precisely so the persistent copy is re-derivable), landing on
    ///   survivors through the degraded router. This used to re-persist
    ///   the *entire* running checkpoint; the planner cuts the write
    ///   amplification to the dead shard's ~`1/n_shards` share
    ///   (`rebuilt_atoms`/`rebuilt_bytes` report it).
    /// * a shard that just **healed** (a flaky shard's up phase, or a
    ///   kill window ending) re-adopts its slice: the atoms *routed* to
    ///   it are re-persisted from the cache so the healed shard holds
    ///   their freshest records again and a later death of a survivor
    ///   has nothing of theirs to rebuild.
    ///
    /// Either way every record keeps its original saved iteration and
    /// carries the exact cache value the freshest committed record
    /// already holds, so the commit-watermark rule — and byte-identity
    /// with the old full re-persist — is unchanged
    /// (`rust/tests/chaos.rs` pins both).
    fn tick(&mut self, iter: usize, layout: &AtomLayout) -> Result<()> {
        if iter == self.last_tick_iter {
            return Ok(());
        }
        self.last_tick_iter = iter;
        let epoch = self.store.advance_epoch(iter);
        if !epoch.newly_down.is_empty() {
            let placement = self.store.placement_shards();
            let plan = RebuildPlan::for_dead_shards(
                &epoch.newly_down,
                &placement,
                |a| self.coord.saved_iter(a),
                layout.n_atoms(),
            );
            let bytes = plan.execute_from_cache_with(
                self.coord.cache(),
                layout,
                &self.store,
                self.store.fence_workers(),
            )?;
            self.rebuilt_atoms += plan.rebuilt_atoms() as u64;
            self.rebuilt_bytes += bytes;
            plan.record_into(&self.rec, iter, "cache", bytes, self.store.fence_workers());
        }
        if !epoch.newly_healed.is_empty() {
            // Batch route resolution: one lock for the whole layout, not
            // one shard_of() lock round-trip per atom.
            let all: Vec<usize> = (0..layout.n_atoms()).collect();
            let homes = self.store.shard_map(&all);
            let atoms: Vec<usize> = all
                .into_iter()
                .zip(homes)
                .filter(|(_, home)| epoch.newly_healed.contains(home))
                .map(|(a, _)| a)
                .collect();
            let plan = RebuildPlan::for_atoms(&atoms, |a| self.coord.saved_iter(a));
            let bytes = plan.execute_from_cache_with(
                self.coord.cache(),
                layout,
                &self.store,
                self.store.fence_workers(),
            )?;
            self.readopted_atoms += plan.rebuilt_atoms() as u64;
            self.readopted_bytes += bytes;
            plan.record_into(&self.rec, iter, "readopt", bytes, self.store.fence_workers());
        }
        Ok(())
    }

    /// Force a checkpoint barrier at `iter`: select, update the cache,
    /// snapshot copy-on-write, then write inline (sync) or enqueue
    /// (async). `blocking_secs` covers exactly the part the training loop
    /// waits on in async mode.
    pub fn checkpoint_now(
        &mut self,
        iter: usize,
        current: &ParamStore,
        layout: &AtomLayout,
        rng: &mut Rng,
    ) -> Result<CheckpointStats> {
        // Fault clock first: any job enqueued with this iteration must be
        // preceded by the epoch advance, so the degraded router (not the
        // backend's own kill check) is what sees a dead shard.
        self.tick(iter, layout)?;
        let t0 = std::time::Instant::now();
        let chosen = self.coord.select_and_update_cache(iter, current, layout, rng);
        let mut payloads = collect_payloads(&chosen, current, layout);
        // Delta-skip: drop selected atoms whose bytes are unchanged
        // since their last persisted record — the store already holds an
        // identical copy at an older iteration, and the freshest-record
        // recovery scan reads the same values from it. The filter runs
        // on the barrier snapshot, before the mode branch, so sync and
        // async pipelines skip identically.
        let (skipped_atoms_before, skipped_bytes_before) =
            (self.skipped_atoms, self.skipped_bytes);
        let last_crc = &mut self.last_crc;
        let (skipped_atoms, skipped_bytes) = (&mut self.skipped_atoms, &mut self.skipped_bytes);
        payloads.retain(|(atom, vals)| {
            let crc = payload_crc(vals);
            if last_crc.get(*atom) == Some(&crc) {
                *skipped_atoms += 1;
                *skipped_bytes += (vals.len() * 4) as u64;
                return false;
            }
            if last_crc.len() <= *atom {
                last_crc.resize(*atom + 1, 0);
            }
            last_crc[*atom] = crc;
            true
        });
        let bytes: u64 = payloads.iter().map(|(_, v)| (v.len() * 4) as u64).sum();
        let blocking_secs = t0.elapsed().as_secs_f64();
        let atoms_saved = payloads.len();
        if self.rec.is_enabled() {
            self.rec.record(
                iter,
                EventKind::Barrier {
                    atoms: atoms_saved,
                    bytes,
                    skipped_atoms: self.skipped_atoms - skipped_atoms_before,
                    skipped_bytes: self.skipped_bytes - skipped_bytes_before,
                },
            );
        }

        match self.mode {
            CheckpointMode::Sync => {
                let refs: Vec<(usize, &[f32])> =
                    payloads.iter().map(|(a, v)| (*a, v.as_slice())).collect();
                self.store.put_atoms_at(iter, &refs)?;
                self.store.mark_committed_at(iter);
            }
            CheckpointMode::Async => {
                // Route each atom to the writer that owns its shard so a
                // shard's records always arrive in barrier order. The
                // route is resolved for the whole batch under one lock.
                let n_writers = self.writers.len();
                let ids: Vec<usize> = payloads.iter().map(|(a, _)| *a).collect();
                let shards = self.store.shard_map(&ids);
                let mut per_writer: Vec<Vec<(usize, Vec<f32>)>> =
                    (0..n_writers).map(|_| Vec::new()).collect();
                for ((atom, vals), shard) in payloads.into_iter().zip(shards) {
                    per_writer[shard % n_writers].push((atom, vals));
                }
                for (w, atoms) in per_writer.into_iter().enumerate() {
                    if atoms.is_empty() {
                        continue;
                    }
                    {
                        let mut p = self.shared.pending.lock().unwrap();
                        p.in_flight += 1;
                    }
                    let tx = self.writers[w].tx.as_ref().expect("writer pool running");
                    if tx.send(WriteJob { iter, atoms }).is_err() {
                        // Undo the reservation so a later flush can still
                        // drain instead of waiting forever.
                        self.shared.pending.lock().unwrap().in_flight -= 1;
                        bail!("checkpoint writer {w} died; state lost before flush");
                    }
                }
                // Back-pressure: a bounded queue turns a slow shard into
                // throttled barriers instead of unbounded snapshot memory.
                if self.max_pending > 0 {
                    self.wait_for_queue_room()?;
                }
            }
        }
        self.last_barrier_iter = iter;
        Ok(CheckpointStats { iter, atoms_saved, bytes, blocking_secs })
    }

    /// Block until at most `bound` write jobs are pending; returns
    /// whether any waiting happened. Bounded waits so a writer that died
    /// abnormally (panic in a backend, poisoned shard lock) turns into an
    /// error instead of an unbounded hang: a finished thread can no
    /// longer drain its queue.
    fn wait_pending_at_most(&mut self, bound: usize) -> Result<bool> {
        let mut waited = false;
        let mut p = self.shared.pending.lock().unwrap();
        while p.in_flight > bound {
            waited = true;
            let (guard, _timeout) = self
                .shared
                .drained
                .wait_timeout(p, std::time::Duration::from_millis(200))
                .unwrap();
            p = guard;
            if p.in_flight > bound
                && self
                    .writers
                    .iter()
                    .any(|w| w.join.as_ref().map(|j| j.is_finished()).unwrap_or(true))
            {
                bail!(
                    "checkpoint writer thread exited with {} write(s) still pending",
                    p.in_flight
                );
            }
        }
        Ok(waited)
    }

    /// Back-pressure point of a bounded queue: wait for room, counting
    /// the barrier as stalled if it had to wait. Writer errors surface at
    /// the next `flush` (the fence every recovery goes through).
    ///
    /// Stall events (like the `degraded_records` counter) are
    /// observability, not part of the determinism contract: whether a
    /// barrier stalls at all depends on how far the writer pool happened
    /// to fall behind, which is wall-clock scheduling.
    fn wait_for_queue_room(&mut self) -> Result<()> {
        let pending = self.shared.pending.lock().unwrap().in_flight;
        if self.wait_pending_at_most(self.max_pending)? {
            self.stalled_barriers += 1;
            self.rec.record(self.last_tick_iter, EventKind::Stall { pending });
        }
        Ok(())
    }

    /// Epoch fence: drain all in-flight writes, surface any writer error,
    /// sync every shard, and advance the commit watermark. Recovery MUST
    /// call this before reading the store (the watermark turns a missing
    /// fence into an error instead of silent nondeterminism). With
    /// compaction enabled, the drained fence is also where garbage-heavy
    /// disk shards are folded into fresh segments — the store is settled
    /// here, so the trigger fires at the same points in every run.
    pub fn flush(&mut self) -> Result<()> {
        let fence_start = std::time::Instant::now();
        if self.mode == CheckpointMode::Async {
            self.wait_pending_at_most(0)?;
            if let Some(e) = self.shared.pending.lock().unwrap().error.take() {
                bail!("checkpoint writer failed: {e}");
            }
        }
        // Parity fence before the durability fence, on the drained store:
        // scrub-repair any member a bitflip (or a dead shard the cache
        // path missed) left unreadable, then re-encode the stripes
        // touched since the last fence from the settled state — running
        // it here, after the async drain, is what keeps sync and async
        // parity byte-identical.
        let (scrubbed_before, reencoded_before) =
            (self.store.stripes_scrubbed(), self.store.stripes_reencoded());
        let repaired = self.store.parity_fence()?;
        self.store.sync_all()?;
        self.store.mark_committed_at(self.last_barrier_iter);
        if self.rec.is_enabled() {
            let at = self.last_barrier_iter;
            let scrubbed = self.store.stripes_scrubbed() - scrubbed_before;
            let reencoded = self.store.stripes_reencoded() - reencoded_before;
            if scrubbed > 0 || repaired > 0 {
                self.rec.record(at, EventKind::Scrub { stripes: scrubbed, repaired });
            }
            if reencoded > 0 {
                self.rec.record(at, EventKind::Reencode { stripes: reencoded });
            }
            self.rec.record(at, EventKind::Flush { watermark: at });
        }
        if self.compact_threshold > 0.0 {
            let runs = self.store.compact_if_needed(
                self.compact_threshold,
                self.compact_min_bytes,
                self.compact_max_pass_bytes,
            )?;
            if self.rec.is_enabled() {
                for (shard, stats) in &runs {
                    self.rec.record(
                        self.last_barrier_iter,
                        EventKind::Compaction {
                            shard: *shard,
                            generation: stats.generation,
                            segments: stats.segments_compacted as u64,
                            reclaimed: stats.reclaimed_bytes,
                        },
                    );
                }
            }
        }
        // Measured, not modeled: the gauge the policy controller can
        // later learn dump costs from. Never feeds a decision here.
        self.last_fence_wall_ms = fence_start.elapsed().as_secs_f64() * 1e3;
        self.total_fence_wall_ms += self.last_fence_wall_ms;
        self.fences += 1;
        Ok(())
    }

    /// Final fence, then hand the store back (the checkpointer's writer
    /// threads are joined on drop).
    pub fn finish(mut self) -> Result<Arc<ShardedStore>> {
        self.flush()?;
        Ok(self.store.clone())
    }
}

impl Drop for AsyncCheckpointer {
    fn drop(&mut self) {
        for w in self.writers.iter_mut() {
            w.tx = None; // close the channel so the thread's recv() ends
        }
        for w in self.writers.iter_mut() {
            if let Some(j) = w.join.take() {
                let _ = j.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::Selector;
    use crate::params::{AtomLayout, ParamStore, Tensor};

    fn setup(n: usize) -> (ParamStore, AtomLayout) {
        let store = ParamStore::new(vec![Tensor::zeros("w", &[n, 2])]);
        let layout = AtomLayout::new(AtomLayout::rows_of(&store, "w"));
        (store, layout)
    }

    /// Drive `iters` barriers of drifting state through a checkpointer
    /// and return the flushed store.
    fn drive(mode: CheckpointMode, shards: usize, writers: usize) -> Arc<ShardedStore> {
        let (mut ps, layout) = setup(12);
        let store = Arc::new(ShardedStore::new_mem(shards));
        let policy = CheckpointPolicy::partial(4, 2, Selector::Priority);
        let mut ck =
            AsyncCheckpointer::new(policy, &ps, &layout, store, mode, writers).unwrap();
        let mut rng = Rng::new(42);
        for iter in 1..=12usize {
            for (i, v) in ps.get_mut("w").data.iter_mut().enumerate() {
                *v += (iter * (i + 1)) as f32 * 0.01;
            }
            ck.maybe_checkpoint(iter, &ps, &layout, &mut rng).unwrap();
        }
        ck.finish().unwrap()
    }

    #[test]
    fn async_store_matches_sync_store_after_flush() {
        let sync = drive(CheckpointMode::Sync, 3, 1);
        let single = drive(CheckpointMode::Sync, 1, 1);
        let parallel = drive(CheckpointMode::Async, 3, 2);
        assert_eq!(sync.total_bytes(), parallel.total_bytes());
        assert_eq!(sync.total_records(), parallel.total_records());
        assert_eq!(sync.committed(), parallel.committed());
        for atom in 0..12 {
            let a = sync.get_atom_any(atom).unwrap().unwrap();
            let b = parallel.get_atom_any(atom).unwrap().unwrap();
            let c = single.get_atom_any(atom).unwrap().unwrap();
            assert_eq!(a, b, "atom {atom}: async differs from sync");
            assert_eq!(a, c, "atom {atom}: sharded differs from single-shard");
        }
    }

    #[test]
    fn flush_advances_watermark() {
        let (ps, layout) = setup(6);
        let store = Arc::new(ShardedStore::new_mem(2));
        let mut ck = AsyncCheckpointer::new(
            CheckpointPolicy::full(2),
            &ps,
            &layout,
            store.clone(),
            CheckpointMode::Async,
            2,
        )
        .unwrap();
        assert_eq!(store.committed(), Some(0));
        let mut rng = Rng::new(1);
        ck.checkpoint_now(2, &ps, &layout, &mut rng).unwrap();
        ck.checkpoint_now(4, &ps, &layout, &mut rng).unwrap();
        ck.flush().unwrap();
        assert_eq!(store.committed(), Some(4));
        // Every record is now visible and none is beyond the watermark.
        for atom in 0..6 {
            let saved = store.get_atom_any(atom).unwrap().unwrap();
            assert!(saved.iter <= 4);
        }
    }

    #[test]
    fn stats_are_deterministic_across_modes() {
        let (ps, layout) = setup(8);
        let mut stats = Vec::new();
        for mode in [CheckpointMode::Sync, CheckpointMode::Async] {
            let store = Arc::new(ShardedStore::new_mem(2));
            let mut ck = AsyncCheckpointer::new(
                CheckpointPolicy::partial(4, 4, Selector::RoundRobin),
                &ps,
                &layout,
                store,
                mode,
                2,
            )
            .unwrap();
            let mut rng = Rng::new(5);
            let s = ck.checkpoint_now(1, &ps, &layout, &mut rng).unwrap();
            ck.flush().unwrap();
            stats.push((s.iter, s.atoms_saved, s.bytes));
        }
        assert_eq!(stats[0], stats[1]);
    }

    #[test]
    fn delta_skip_elides_unchanged_atoms() {
        let (mut ps, layout) = setup(4);
        let store = Arc::new(ShardedStore::new_mem(2));
        let mut ck = AsyncCheckpointer::new(
            CheckpointPolicy::full(1),
            &ps,
            &layout,
            store.clone(),
            CheckpointMode::Sync,
            1,
        )
        .unwrap();
        let mut rng = Rng::new(7);
        // Nothing changed since the x⁽⁰⁾ dump: the barrier writes nothing.
        let s = ck.checkpoint_now(1, &ps, &layout, &mut rng).unwrap();
        assert_eq!((s.atoms_saved, s.bytes), (0, 0));
        assert_eq!((ck.skipped_atoms(), ck.skipped_bytes()), (4, 32));
        // Touch one atom: only it is written, the other three skip again.
        ps.get_mut("w").data[0] = 1.5;
        let s = ck.checkpoint_now(2, &ps, &layout, &mut rng).unwrap();
        assert_eq!((s.atoms_saved, s.bytes), (1, 8));
        assert_eq!(ck.skipped_atoms(), 7);
        ck.flush().unwrap();
        // The touched atom reads back fresh; skipped atoms still recover
        // from their byte-identical iter-0 records.
        let got = store.get_atom_any(0).unwrap().unwrap();
        assert_eq!((got.iter, got.values), (2, vec![1.5, 0.0]));
        for atom in 1..4 {
            let got = store.get_atom_any(atom).unwrap().unwrap();
            assert_eq!(got.iter, 0, "atom {atom} must keep its iter-0 record");
            assert_eq!(got.values, vec![0.0, 0.0]);
        }
        // An unchanged barrier after the flush skips everything again.
        let s = ck.checkpoint_now(3, &ps, &layout, &mut rng).unwrap();
        assert_eq!((s.atoms_saved, s.bytes), (0, 0));
    }
}
