//! Atom selection strategies for partial checkpoints (paper §4.2, §5.4).
//!
//! The priority selector implements the paper's heuristic — "save the
//! parameters which have changed the most since they were previously
//! saved" — as a top-k over per-atom distances between the current state
//! and the in-memory running-checkpoint cache. Selection is O(n) via
//! `select_nth_unstable` (no full sort): this is per-iteration overhead
//! on the training path, benchmarked in `benches/priority_selection.rs`.

use crate::params::{AtomLayout, ParamStore};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Selector {
    /// Largest distance from last-saved value first (SCAR's strategy).
    Priority,
    /// Cyclic over atom ids (paper's `round` baseline).
    RoundRobin,
    /// Uniform without replacement (paper's `random` baseline).
    Random,
}

impl std::str::FromStr for Selector {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "priority" => Ok(Selector::Priority),
            "round" | "round-robin" => Ok(Selector::RoundRobin),
            "random" => Ok(Selector::Random),
            other => Err(format!("unknown selector '{other}' (priority|round|random)")),
        }
    }
}

impl std::fmt::Display for Selector {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Selector::Priority => "priority",
            Selector::RoundRobin => "round",
            Selector::Random => "random",
        };
        f.write_str(s)
    }
}

/// Pick `k` atoms to checkpoint. `rr_cursor` is the coordinator's
/// persistent round-robin position (advanced on use).
pub fn select_atoms(
    selector: Selector,
    k: usize,
    current: &ParamStore,
    cache: &ParamStore,
    layout: &AtomLayout,
    rr_cursor: &mut usize,
    rng: &mut Rng,
) -> Vec<usize> {
    let n = layout.n_atoms();
    let k = k.min(n);
    if k == n {
        return (0..n).collect();
    }
    match selector {
        Selector::Priority => top_k_by_distance(k, current, cache, layout),
        Selector::RoundRobin => {
            let mut out = Vec::with_capacity(k);
            for i in 0..k {
                out.push((*rr_cursor + i) % n);
            }
            *rr_cursor = (*rr_cursor + k) % n;
            out
        }
        Selector::Random => rng.sample_indices(n, k),
    }
}

/// Work thresholds below which the distance pass stays serial: thread
/// spawn costs more than it saves for small models, and the models used
/// inside already-parallel scenario sweeps stay under these, so sweeps
/// don't oversubscribe the machine (workers × selection threads).
const PARALLEL_MIN_ATOMS: usize = 1024;
const PARALLEL_MIN_ELEMS: usize = 200_000;

/// Top-k atom ids by distance, O(n) average via quickselect then a sort of
/// only the selected prefix (stable output order for determinism).
///
/// The per-atom distance pass — the documented hot path of
/// `benches/priority_selection.rs` — fans out over scoped worker threads
/// for large models, using the same fixed-slot pattern as the scenario
/// runner's sweep pool (`scenario/runner.rs`): each worker fills a
/// disjoint chunk of the score vector, so the result is byte-identical to
/// the serial pass regardless of scheduling.
fn top_k_by_distance(
    k: usize,
    current: &ParamStore,
    cache: &ParamStore,
    layout: &AtomLayout,
) -> Vec<usize> {
    let n = layout.n_atoms();
    let workers = std::thread::available_parallelism()
        .map(|w| w.get())
        .unwrap_or(1)
        .min(8);
    let mut scored: Vec<(f64, usize)>;
    if n >= PARALLEL_MIN_ATOMS && layout.total_len() >= PARALLEL_MIN_ELEMS && workers > 1 {
        scored = vec![(0.0, 0); n];
        let chunk = (n + workers - 1) / workers;
        std::thread::scope(|s| {
            for (ci, slots) in scored.chunks_mut(chunk).enumerate() {
                let base = ci * chunk;
                s.spawn(move || {
                    for (i, slot) in slots.iter_mut().enumerate() {
                        let a = base + i;
                        *slot = (current.atom_distance(cache, layout, a), a);
                    }
                });
            }
        });
    } else {
        scored = (0..n)
            .map(|a| (current.atom_distance(cache, layout, a), a))
            .collect();
    }
    // Partition so the k largest are in the front (descending by score).
    scored.select_nth_unstable_by(k.saturating_sub(1).min(n - 1), |a, b| {
        b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut out: Vec<usize> = scored[..k].iter().map(|&(_, a)| a).collect();
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::{AtomLayout, ParamStore, Tensor};

    fn fixtures(n: usize) -> (ParamStore, ParamStore, AtomLayout) {
        let cur = ParamStore::new(vec![Tensor::zeros("w", &[n, 1])]);
        let cache = cur.clone();
        let layout = AtomLayout::new(AtomLayout::rows_of(&cur, "w"));
        (cur, cache, layout)
    }

    #[test]
    fn priority_picks_largest_distances() {
        let (mut cur, cache, layout) = fixtures(10);
        for (i, v) in [(3usize, 9.0f32), (7, 5.0), (1, 2.0)] {
            cur.get_mut("w").data[i] = v;
        }
        let mut cursor = 0;
        let mut rng = Rng::new(0);
        let got = select_atoms(Selector::Priority, 2, &cur, &cache, &layout, &mut cursor, &mut rng);
        assert_eq!(got, vec![3, 7]);
    }

    #[test]
    fn priority_full_selection_returns_all() {
        let (cur, cache, layout) = fixtures(5);
        let mut cursor = 0;
        let mut rng = Rng::new(0);
        let got = select_atoms(Selector::Priority, 5, &cur, &cache, &layout, &mut cursor, &mut rng);
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn round_robin_wraps() {
        let (cur, cache, layout) = fixtures(5);
        let mut cursor = 0;
        let mut rng = Rng::new(0);
        let a = select_atoms(Selector::RoundRobin, 3, &cur, &cache, &layout, &mut cursor, &mut rng);
        let b = select_atoms(Selector::RoundRobin, 3, &cur, &cache, &layout, &mut cursor, &mut rng);
        assert_eq!(a, vec![0, 1, 2]);
        assert_eq!(b, vec![3, 4, 0]);
        assert_eq!(cursor, 1);
    }

    #[test]
    fn random_is_distinct_and_in_range() {
        let (cur, cache, layout) = fixtures(20);
        let mut cursor = 0;
        let mut rng = Rng::new(7);
        let got = select_atoms(Selector::Random, 8, &cur, &cache, &layout, &mut cursor, &mut rng);
        assert_eq!(got.len(), 8);
        let mut sorted = got.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 8);
    }

    #[test]
    fn parallel_distance_pass_matches_serial_reference() {
        // Atom and element counts above both parallel thresholds so the
        // scoped-worker path runs.
        let n = 6000usize;
        let len = 40usize;
        let mut cur = ParamStore::new(vec![Tensor::zeros("w", &[n, len])]);
        let cache = cur.clone();
        let layout = AtomLayout::new(AtomLayout::rows_of(&cur, "w"));
        // Distinct, non-monotonic drift per atom (i -> i*c mod n is a
        // bijection for gcd(c, n) = 1), so top-k has no score ties.
        for a in 0..n {
            cur.get_mut("w").data[a * len] = ((a * 2_654_435_761) % n) as f32 + 1.0;
        }
        let k = 37;
        let mut cursor = 0;
        let mut rng = Rng::new(0);
        let got =
            select_atoms(Selector::Priority, k, &cur, &cache, &layout, &mut cursor, &mut rng);
        // Serial reference: full sort by distance, take k, order by id.
        let mut scored: Vec<(f64, usize)> =
            (0..n).map(|a| (cur.atom_distance(&cache, &layout, a), a)).collect();
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let mut expect: Vec<usize> = scored[..k].iter().map(|&(_, a)| a).collect();
        expect.sort_unstable();
        assert_eq!(got, expect);
    }

    #[test]
    fn selector_parses() {
        assert_eq!("priority".parse::<Selector>().unwrap(), Selector::Priority);
        assert_eq!("round".parse::<Selector>().unwrap(), Selector::RoundRobin);
        assert!("bogus".parse::<Selector>().is_err());
    }
}
