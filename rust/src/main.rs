//! `scar` — launcher CLI for the SCAR fault-tolerant training runtime.
//!
//! Subcommands:
//!   info                       list artifacts and their interfaces
//!   train   [--config f] [--set k=v ...]   run one training job (local loop)
//!   cluster [--set k=v ...]    run on the threaded PS cluster with a
//!                              schedule of node kills
//!   run-scenario <file>        execute a declarative scenario sweep
//!   bound   --model V          estimate c / ‖x0−x*‖ and print Theorem 3.2
//!                              bounds for a range of perturbation sizes

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use scar::checkpoint::{AsyncCheckpointer, CheckpointCoordinator, CheckpointMode, CheckpointPolicy};
use scar::config::RunConfig;
use scar::failure::{FailureEvent, FailureInjector};
use scar::harness;
use scar::models::{build_trainer, default_engine, BuildOpts};
use scar::obs::{standard_registry, EventKind, Recorder, Registry};
use scar::params::{AtomLayout, ParamStore, Tensor};
use scar::recovery;
use scar::recovery::RebuildPlan;
use scar::runtime::artifact;
use scar::scenario::{self, Scenario};
use scar::storage::{MemStore, ShardedStore};
use scar::theory;
use scar::trainer::Trainer;
use scar::util::cli::Args;
use scar::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::parse();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "info" => cmd_info(),
        "train" => cmd_train(&args),
        "cluster" => cmd_cluster(&args),
        "run-scenario" => cmd_run_scenario(&args),
        "bound" => cmd_bound(&args),
        "advisor" => cmd_advisor(&args),
        "compact" => cmd_compact(&args),
        "trend" => cmd_trend(&args),
        "policy-gate" => cmd_policy_gate(&args),
        "bench" => cmd_bench(&args),
        "trace" => cmd_trace(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            bail!("unknown subcommand '{other}'");
        }
    }
}

fn print_help() {
    eprintln!(
        "scar — self-correcting checkpoint-based fault tolerance for ML training

USAGE: scar <info|train|cluster|run-scenario|bound|advisor|compact|trend|policy-gate|bench|trace> [flags]

  info                          list AOT artifacts
  train   --set k=v ...         local training loop with SCAR checkpointing
          [--config run.json]     and an optional injected failure plan
          [--trace f] [--json]    (--trace dumps a flight-recorder trace:
                                  .jsonl, or Chrome trace_event otherwise;
                                  --json prints end-of-run metrics)
  cluster --set k=v ...         threaded PS cluster with heartbeats and a
          [--kills i:n,i:n]       schedule of node kills
          [--trace f] [--json]
  run-scenario <file.toml|json> declarative scenario sweep on a worker pool
          [--workers n] [--trials n] [--seed s] [--output f.csv] [--dry-run]
          [--backend mem|disk] [--checkpoint-dir d] [--metrics-out f.json]
          [--trace-dir d]         (per-trial flight-recorder JSONL traces)
  bound   --model <variant>     Theorem 3.2 iteration-cost bounds
  advisor --model <variant>     run a probe, estimate c on-the-fly, and
          [--fail-rate p]         recommend a checkpoint policy (§7)
  compact --dir <checkpoint_dir> fold superseded records of every disk
          [--shards n]            shard into fresh segments ([--threshold r]
                                  only compacts shards at/above that
                                  garbage ratio; default compacts any)
  trend   --file trend.csv      append nightly metrics to an append-only
          --commit <sha>          commit-keyed CSV and fail on >max-regress
          --from-metrics a.json[,b.json...]   vs the previous row
          [--max-regress 0.25] [--gate wall_secs,rebuilt_bytes]
          [--render out.svg|out.html]  plot the accumulated CSV instead
  policy-gate --report f.csv    assert every adaptive cell's total
                                  iteration cost <= every static cell's
                                  (per panel; labels containing
                                  \"adaptive\" are the adaptive cells)
  bench   [--quick] [--out BENCH_10.json]  hot-path benchmark sweep over
          [--dir d]               {mem,disk} x {sync,async} x parity
                                  {off,on}: fence wall-clock + stripes
                                  re-encoded, checkpoint bytes written vs
                                  delta-skipped, per-record vs group-commit
                                  fsyncs, budgeted compaction passes,
                                  serial vs parallel rebuild
  trace   <trace.jsonl>         inspect a flight-recorder trace: per-shard
          [--render out.svg]      SVG timeline, fault -> recovery latency
          [--chrome out.json]     table, Chrome trace_event conversion

Config keys (for --set): model seed iters target_iters ps_nodes workers
  checkpoint_interval checkpoint_k checkpoint_mode(sync|async) selector
  recovery storage_shards storage_writers storage_max_pending
  storage_compact_threshold storage_compact_min_bytes
  storage_compact_max_bytes_per_pass storage_group_commit storage_parity
  fail_fraction fail_geom_p fail_plan fail_nodes fail_cascade_extra
  fail_cascade_gap fail_flaky_period fail_flaky_prob fail_flaky_max
  checkpoint_dir chaos (e.g. \"kill:1@6..9,part:0@4..12,flaky:2@5p8d3c2,
  bitflip:1@6a9,replay:1@7\" — bitflip:SHARD@EPOCH[aATOM] corrupts one
  record; replay:SHARD@EPOCH re-delivers a stale put batch at a fence)

Scenario files additionally take [chaos] (per-shard
kill/slow/torn/partition/flaky/fsync/bitflip/replay schedules),
checkpoint_dir (disk-backed trials), [storage]
compact_threshold/compact_min_bytes/compact_max_bytes_per_pass/
group_commit/parity, deploy =
\"harness\"|\"cluster\", ps_nodes, [obs] trace_dir (per-trial
flight-recorder JSONL traces), policy = \"static\"|\"adaptive\" (per
scenario or per cell: the runtime policy controller retunes the
checkpoint interval and sync/async mode mid-run), and [advisor]
window/dump_cost_iters/hysteresis/lost_fraction (controller tuning;
dump_cost_iters also prices checkpoint bandwidth into every cell's
iteration cost).

Bundled scenarios: scenarios/fig5.toml, fig6.toml, fig7.toml (paper
figure sweeps), scenarios/failure_models.toml (correlated/cascade/flaky),
scenarios/shard_failures.toml + shard_failures_cluster.toml (storage
chaos), scenarios/disk_chaos.toml (the same chaos family over real
on-disk shards, with compaction), scenarios/selective_recovery.toml
(partition + flaky-shard families over the selective rebuild planner),
scenarios/erasure_recovery.toml (parity-coded shards under bitflip and
kill faults), scenarios/adaptive_policy.toml (fixed-interval cells vs
the adaptive policy controller across bursty/quiet/flaky failure
regimes — `scar policy-gate` asserts adaptive wins)."
    );
}

fn cmd_run_scenario(args: &Args) -> Result<()> {
    let file = args
        .positional
        .get(1)
        .context("usage: scar run-scenario <file.toml|file.json> [--workers n] [--trials n]")?;
    let path = scenario::find_bundled(file);
    let mut scn = Scenario::from_file(&path)?;
    scenario::apply_cli_overrides(&mut scn, args)?;
    if args.bool("dry-run") {
        print!("{}", scn.describe());
        return Ok(());
    }
    let t0 = std::time::Instant::now();
    let report = scenario::run_with_default_engine(&scn)?;
    let wall_secs = t0.elapsed().as_secs_f64();
    print!("{}", report.render());
    if let Some(out) = scenario::write_output(&report, &scn)? {
        println!("-> {out}");
    }
    // Trend surface: sweep wall-clock plus the selective-rebuild and
    // compaction totals, as one JSON object `scar trend` can aggregate.
    if let Some(path) = args.str_opt("metrics-out") {
        use scar::util::json::Json;
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("scenario".to_string(), Json::from(report.scenario.as_str()));
        obj.insert("wall_secs".to_string(), Json::Num(wall_secs));
        for (k, v) in report.metrics() {
            obj.insert(k, Json::Num(v));
        }
        let path = std::path::Path::new(path);
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, Json::Obj(obj).to_string())
            .with_context(|| format!("writing {}", path.display()))?;
        println!("-> {}", path.display());
    }
    Ok(())
}

/// `scar policy-gate`: CI assertion over a scenario report CSV — in every
/// panel, each adaptive cell's total iteration cost must be no worse than
/// every static cell's. Cells are classified by label: a label containing
/// "adaptive" is an adaptive cell, the rest are the static baselines.
/// Exits nonzero (with a per-panel breakdown) when the gate fails.
fn cmd_policy_gate(args: &Args) -> Result<()> {
    let file = args
        .str_opt("report")
        .context("usage: scar policy-gate --report results/report.csv")?;
    let text = std::fs::read_to_string(file)
        .with_context(|| format!("reading report csv {file}"))?;
    // (panel, cell) -> (total cost, trials, censored trials). The CSV is
    // scar's own `scenario,panel,cell,trial,cost,delta,bound,censored`;
    // labels never contain commas in bundled scenarios, so a plain split
    // suffices (quoted fields are rejected loudly rather than misparsed).
    let mut cells: std::collections::BTreeMap<(String, String), (f64, usize, usize)> =
        std::collections::BTreeMap::new();
    for (lineno, line) in text.lines().enumerate().skip(1) {
        if line.trim().is_empty() {
            continue;
        }
        let f: Vec<&str> = line.split(',').collect();
        if f.len() != 8 || line.contains('"') {
            bail!("{file}:{} is not a scar report row: {line}", lineno + 1);
        }
        let cost: f64 = f[4]
            .parse()
            .with_context(|| format!("{file}:{}: bad cost '{}'", lineno + 1, f[4]))?;
        let censored = f[7].trim() == "1";
        let e = cells.entry((f[1].to_string(), f[2].to_string())).or_insert((0.0, 0, 0));
        e.0 += cost;
        e.1 += 1;
        e.2 += censored as usize;
    }
    if cells.is_empty() {
        bail!("no data rows in {file}");
    }
    let mut panels: Vec<String> = cells.keys().map(|(p, _)| p.clone()).collect();
    panels.dedup();
    let mut failures = 0usize;
    for panel in &panels {
        let (adaptive, fixed): (Vec<_>, Vec<_>) = cells
            .iter()
            .filter(|((p, _), _)| p == panel)
            .partition(|((_, c), _)| c.contains("adaptive"));
        if adaptive.is_empty() {
            bail!("panel '{panel}' has no adaptive cell (label containing 'adaptive')");
        }
        if fixed.is_empty() {
            bail!("panel '{panel}' has no static baseline cells");
        }
        for ((_, alabel), (acost, atrials, acens)) in &adaptive {
            println!(
                "panel {panel}: {alabel} total cost {acost:.2} over {atrials} trial(s), \
                 {acens} censored"
            );
            for ((_, slabel), (scost, _, _)) in &fixed {
                if acost > scost {
                    eprintln!(
                        "POLICY GATE: panel {panel}: adaptive '{alabel}' ({acost:.2}) \
                         costs more than static '{slabel}' ({scost:.2})"
                    );
                    failures += 1;
                } else {
                    println!("  <= static {slabel} ({scost:.2})");
                }
            }
        }
    }
    if failures > 0 {
        bail!("policy gate failed: {failures} adaptive-vs-static comparison(s) regressed");
    }
    println!("policy gate passed: adaptive cost <= every static cell in every panel");
    Ok(())
}

/// `scar trend`: fold one or more `--metrics-out` JSON files into a new
/// commit-keyed row of an append-only trend CSV, and fail loudly when a
/// gated metric regressed more than `--max-regress` vs the previous row
/// (the nightly CI's regression gate).
fn cmd_trend(args: &Args) -> Result<()> {
    let file = args
        .str_opt("file")
        .context("usage: scar trend --file trend.csv --commit sha --from-metrics a.json[,b.json]")?;
    // `--render out.svg|out.html`: plot the accumulated CSV instead of
    // appending to it (the nightly's drift dashboard artifact).
    if let Some(out) = args.str_opt("render") {
        let csv = std::fs::read_to_string(file)
            .with_context(|| format!("reading trend file {file}"))?;
        let svg = scar::util::trend::render_svg(&csv)?;
        let text = if out.ends_with(".html") {
            format!(
                "<!doctype html>\n<html><head><title>scar trend</title></head>\n\
                 <body>{svg}</body></html>\n"
            )
        } else {
            svg
        };
        let path = std::path::Path::new(out);
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, text).with_context(|| format!("writing {out}"))?;
        println!(
            "trend: rendered {} data row(s) from {file} -> {out}",
            csv.lines().filter(|l| !l.trim().is_empty()).count().saturating_sub(1)
        );
        return Ok(());
    }
    let commit = args.str_opt("commit").context("scar trend needs --commit <sha>")?;
    let sources = args
        .str_opt("from-metrics")
        .context("scar trend needs --from-metrics a.json[,b.json...]")?;
    let max_regress = args.f64_or("max-regress", 0.25);
    // Cost-like metrics (lower is better) gate the run; the rest are
    // recorded for plots only. `wall_secs` and `rebuilt_bytes` regressing
    // means sweeps got slower / selective recovery got less selective.
    let gate_csv = args.str_or("gate", "wall_secs,rebuilt_bytes");
    let gates: Vec<&str> = gate_csv.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();

    // Sum same-named numeric metrics across the source files (several
    // scenarios feed one nightly row).
    let mut metrics: std::collections::BTreeMap<String, f64> = std::collections::BTreeMap::new();
    for src in sources.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let text = std::fs::read_to_string(src)
            .with_context(|| format!("reading metrics file {src}"))?;
        let v = scar::util::json::Json::parse(&text)
            .with_context(|| format!("parsing metrics file {src}"))?;
        let obj = v
            .as_obj()
            .with_context(|| format!("metrics file {src} must be a JSON object"))?;
        for (k, val) in obj {
            if let Some(n) = val.as_f64() {
                *metrics.entry(k.clone()).or_insert(0.0) += n;
            }
        }
    }
    if metrics.is_empty() {
        bail!("no numeric metrics found in {sources}");
    }
    let regressions = scar::util::trend::append_and_check(
        std::path::Path::new(file),
        commit,
        &metrics,
        &gates,
        max_regress,
    )?;
    println!("trend: appended {} metric(s) for {commit} to {file}", metrics.len());
    for (k, v) in &metrics {
        println!("  {k} = {v}");
    }
    if !regressions.is_empty() {
        for r in &regressions {
            eprintln!("REGRESSION {r}");
        }
        bail!(
            "{} metric(s) regressed more than {:.0}% vs the previous nightly",
            regressions.len(),
            max_regress * 100.0
        );
    }
    Ok(())
}

/// `scar bench`: the hot-path benchmark sweep behind `BENCH_10.json`.
///
/// Four pinned workloads:
/// * **fence**: a single-atom-update checkpoint loop over every
///   {mem, disk} × {sync, async} × parity {0, 1} cell — per-fence stripes
///   re-encoded (the dirty-only fence's work unit), checkpoint bytes
///   written vs delta-skipped, durability barriers paid, and the fence
///   loop's wall-clock. Disk cells run with group-commit on.
/// * **group-commit**: the same multi-atom fence schedule driven through
///   the per-record and batched disk write paths, counting durability
///   barriers each pays.
/// * **compaction**: a churned single-shard log folded by repeated
///   budgeted generational passes — bytes processed per pass (bounded by
///   the budget), segments folded, generations stepped, pass latency.
/// * **rebuild**: a wiped shard slice reconstructed from parity, serial
///   vs fanned out over 4 workers, with the pooled-buffer allocation
///   savings counted.
///
/// Work counters (stripes, bytes, fsyncs, allocations) are deterministic
/// — they are what the nightly trend gates on; wall-clocks ride along
/// for humans and plots. `--quick` shrinks the workload for the CI smoke
/// job; `--out` defaults to `BENCH_10.json`.
fn cmd_bench(args: &Args) -> Result<()> {
    use scar::util::json::Json;
    let quick = args.bool("quick");
    let out = args.str_or("out", "BENCH_10.json");
    let base_dir = std::path::PathBuf::from(args.str_or("dir", "results/bench-ckpt"));
    let (n_rows, n_fences, rebuild_reps) = if quick { (64, 8, 3) } else { (256, 32, 10) };
    let shards = 4usize;
    let row_elems = 8usize;
    let n_stripes = (n_rows + shards - 1) / shards;

    println!(
        "scar bench{}: {n_rows} atoms x {row_elems} f32, {shards} shards, {n_fences} fences/cell",
        if quick { " --quick" } else { "" }
    );

    let mut cells = std::collections::BTreeMap::new();
    let mut top = std::collections::BTreeMap::new();
    for backend in ["mem", "disk"] {
        for mode in [CheckpointMode::Sync, CheckpointMode::Async] {
            for parity in [0usize, 1] {
                let label = format!("{backend}-{mode}-parity{parity}");
                let dir = base_dir.join(&label);
                let store = match backend {
                    "mem" => ShardedStore::new_mem(shards).with_mem_parity(parity),
                    _ => {
                        if dir.exists() {
                            std::fs::remove_dir_all(&dir)
                                .with_context(|| format!("clearing {}", dir.display()))?;
                        }
                        std::fs::create_dir_all(&dir)?;
                        ShardedStore::open_disk(&dir, shards)?
                            .with_disk_parity(&dir, parity)?
                            .with_group_commit(true)
                    }
                };
                let store = Arc::new(store);
                let mut ps = ParamStore::new(vec![Tensor::zeros("w", &[n_rows, row_elems])]);
                let layout = AtomLayout::new(AtomLayout::rows_of(&ps, "w"));
                let mut rng = Rng::new(7);
                let mut ck = AsyncCheckpointer::new(
                    CheckpointPolicy::full(1),
                    &ps,
                    &layout,
                    store.clone(),
                    mode,
                    shards,
                )?;
                // Warm fence: the iter-0 dump dirtied every stripe, so
                // the first fence re-encodes the full state. Steady-state
                // counters start after it.
                ps.get_mut("w").data[0] += 1.0;
                ck.maybe_checkpoint(1, &ps, &layout, &mut rng)?;
                ck.flush()?;
                let (s_reenc, s_scrub) = (store.stripes_reencoded(), store.stripes_scrubbed());
                let (s_skip_a, s_skip_b) = (ck.skipped_atoms(), ck.skipped_bytes());
                let t0 = std::time::Instant::now();
                for fence in 0..n_fences {
                    // One atom changes per fence — the workload dirty-only
                    // fences exist for.
                    let atom = (3 + fence * 7) % n_rows;
                    ps.get_mut("w").data[atom * row_elems] += 1.0;
                    ck.maybe_checkpoint(2 + fence, &ps, &layout, &mut rng)?;
                    ck.flush()?;
                }
                let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
                let reencoded = store.stripes_reencoded() - s_reenc;
                let scrubbed = store.stripes_scrubbed() - s_scrub;
                let skipped_atoms = ck.skipped_atoms() - s_skip_a;
                let skipped_bytes = ck.skipped_bytes() - s_skip_b;
                let bytes_written = store.total_bytes();
                ck.finish()?;
                let cell_fsyncs = store.total_fsyncs();
                println!(
                    "  {label:<22} fence {wall_ms:>8.2} ms  stripes re-encoded {reencoded:>4} \
                     (full would be {})  skipped {}",
                    n_stripes * n_fences,
                    scar::util::fmt_bytes(skipped_bytes)
                );
                let mut m = std::collections::BTreeMap::new();
                m.insert("fence_wall_ms".to_string(), Json::Num(wall_ms));
                m.insert("stripes_reencoded".to_string(), Json::Num(reencoded as f64));
                m.insert("stripes_scrubbed".to_string(), Json::Num(scrubbed as f64));
                m.insert("skipped_atoms".to_string(), Json::Num(skipped_atoms as f64));
                m.insert("skipped_bytes".to_string(), Json::Num(skipped_bytes as f64));
                m.insert("bytes_written".to_string(), Json::Num(bytes_written as f64));
                m.insert("fence_fsyncs".to_string(), Json::Num(cell_fsyncs as f64));
                cells.insert(label.clone(), Json::Obj(m));
                if backend == "mem" && mode == CheckpointMode::Async && parity == 1 {
                    // The canonical cell feeds the flat, trend-gateable
                    // top-level keys.
                    top.insert("bench_fence_wall_ms".to_string(), Json::Num(wall_ms));
                    top.insert(
                        "bench_fence_stripes_reencoded".to_string(),
                        Json::Num(reencoded as f64),
                    );
                    top.insert(
                        "bench_fence_full_stripes".to_string(),
                        Json::from(n_stripes * n_fences),
                    );
                    top.insert("bench_skipped_bytes".to_string(), Json::Num(skipped_bytes as f64));
                    top.insert(
                        "bench_ckpt_bytes_written".to_string(),
                        Json::Num(bytes_written as f64),
                    );
                }
                if backend == "disk" {
                    let _ = std::fs::remove_dir_all(&dir);
                }
            }
        }
    }

    // Rebuild workload: shard 2's slice reconstructed from parity, fresh
    // store per repetition, best-of-N wall-clock.
    let victims: Vec<usize> = (2..n_rows).step_by(shards).collect();
    let plan = RebuildPlan::for_atoms(&victims, |_| 0);
    let prepare = || -> Result<ShardedStore> {
        let store = ShardedStore::new_mem(shards).with_mem_parity(1);
        let payloads: Vec<(usize, Vec<f32>)> = (0..n_rows)
            .map(|a| (a, vec![a as f32 + 0.5; row_elems]))
            .collect();
        let refs: Vec<(usize, &[f32])> =
            payloads.iter().map(|(a, v)| (*a, v.as_slice())).collect();
        store.put_atoms_at(5, &refs)?;
        store.parity_fence()?;
        for &atom in &victims {
            store.corrupt_record_on(2, atom)?;
        }
        Ok(store)
    };
    let mut serial_ms = f64::INFINITY;
    let mut parallel_ms = f64::INFINITY;
    let mut rebuilt_bytes = 0u64;
    for _ in 0..rebuild_reps {
        let store = prepare()?;
        let t0 = std::time::Instant::now();
        rebuilt_bytes = plan.execute_from_parity_with(&store, 1)?;
        serial_ms = serial_ms.min(t0.elapsed().as_secs_f64() * 1e3);
        let store = prepare()?;
        let t0 = std::time::Instant::now();
        let b = plan.execute_from_parity_with(&store, 4)?;
        parallel_ms = parallel_ms.min(t0.elapsed().as_secs_f64() * 1e3);
        anyhow::ensure!(b == rebuilt_bytes, "parallel rebuild bytes diverged");
    }
    // The pooled reconstruction buffer replaces one owned Vec<f32> per
    // rebuilt atom (reconstruct_atom's SavedAtom payload).
    let allocs_avoided = victims.len() as u64;
    println!(
        "  rebuild {} atoms ({}): serial {serial_ms:.2} ms, 4 workers {parallel_ms:.2} ms, \
         {allocs_avoided} allocation(s) avoided",
        victims.len(),
        scar::util::fmt_bytes(rebuilt_bytes)
    );
    top.insert("bench_rebuild_serial_ms".to_string(), Json::Num(serial_ms));
    top.insert("bench_rebuild_parallel_ms".to_string(), Json::Num(parallel_ms));
    top.insert("bench_rebuild_bytes".to_string(), Json::Num(rebuilt_bytes as f64));
    top.insert("bench_rebuild_allocs_avoided".to_string(), Json::Num(allocs_avoided as f64));

    // Group-commit comparison: one fence schedule, two disk write paths.
    // Every fence updates 3 atoms on each of the 4 shards; the per-record
    // path pays a durability barrier per acknowledged record plus a
    // manifest rewrite per dirty shard, the batched path exactly one
    // barrier per shard per fence.
    let mut gc_fsyncs = [0u64; 2];
    for (slot, group) in [false, true].into_iter().enumerate() {
        let dir = base_dir.join(if group { "group-commit" } else { "per-record" });
        if dir.exists() {
            std::fs::remove_dir_all(&dir)
                .with_context(|| format!("clearing {}", dir.display()))?;
        }
        std::fs::create_dir_all(&dir)?;
        let store = ShardedStore::open_disk(&dir, shards)?.with_group_commit(group);
        for fence in 0..n_fences {
            // (fence*3 + slot)*shards + residue keeps atom % shards == residue
            // because n_rows is a multiple of the shard count.
            let payloads: Vec<(usize, Vec<f32>)> = (0..3 * shards)
                .map(|i| {
                    let atom = ((fence * 3 + i / shards) * shards + i % shards) % n_rows;
                    (atom, vec![(fence * 12 + i) as f32; row_elems])
                })
                .collect();
            let refs: Vec<(usize, &[f32])> =
                payloads.iter().map(|(a, v)| (*a, v.as_slice())).collect();
            store.put_atoms_at(fence + 1, &refs)?;
            store.sync_all()?;
        }
        gc_fsyncs[slot] = store.total_fsyncs();
        let _ = std::fs::remove_dir_all(&dir);
    }
    println!(
        "  group-commit: {} per-record fsyncs vs {} batched ({} fences x {} shards, {:.1}x)",
        gc_fsyncs[0],
        gc_fsyncs[1],
        n_fences,
        shards,
        gc_fsyncs[0] as f64 / gc_fsyncs[1].max(1) as f64
    );
    top.insert("bench_shards".to_string(), Json::from(shards));
    top.insert("bench_group_fences".to_string(), Json::from(n_fences));
    top.insert("bench_fence_fsyncs_per_record".to_string(), Json::Num(gc_fsyncs[0] as f64));
    top.insert("bench_fence_fsyncs_group".to_string(), Json::Num(gc_fsyncs[1] as f64));

    // Compaction latency: one disk shard carved into many small sealed
    // segments by overwrite churn, folded by repeated budgeted passes.
    // Every pass processes at most the byte budget and steps the
    // generation clock; the byte/segment counters are deterministic, the
    // pass wall-clock rides along.
    let compact_dir = base_dir.join("compact-bench");
    if compact_dir.exists() {
        std::fs::remove_dir_all(&compact_dir)
            .with_context(|| format!("clearing {}", compact_dir.display()))?;
    }
    let mut disk = scar::storage::DiskStore::open(&compact_dir)?;
    disk.set_segment_limit(256);
    let compact_budget = 2048u64;
    let compact_rounds = 6usize;
    let compact_atoms = 32usize;
    let mut pass_ms = f64::INFINITY;
    let mut pass_bytes_max = 0u64;
    let mut segments_total = 0u64;
    let mut generation = 0u64;
    for round in 0..compact_rounds {
        // Two overwrites of every atom per round: the first rep's records
        // are garbage as soon as the second lands.
        for rep in 0..2usize {
            let iter = round * 2 + rep + 1;
            let payloads: Vec<(usize, Vec<f32>)> = (0..compact_atoms)
                .map(|a| (a, vec![(iter + a) as f32; row_elems]))
                .collect();
            let refs: Vec<(usize, &[f32])> =
                payloads.iter().map(|(a, v)| (*a, v.as_slice())).collect();
            scar::storage::ShardBackend::put_atoms(&mut disk, iter, &refs)?;
        }
        disk.write_manifest()?;
        let t0 = std::time::Instant::now();
        let stats = disk.compact(compact_budget)?;
        pass_ms = pass_ms.min(t0.elapsed().as_secs_f64() * 1e3);
        pass_bytes_max = pass_bytes_max.max(stats.pass_bytes);
        segments_total += stats.segments_compacted as u64;
        generation = stats.generation;
    }
    let _ = std::fs::remove_dir_all(&compact_dir);
    println!(
        "  compaction: {compact_rounds} budgeted passes -> generation {generation}, \
         {segments_total} segment(s) folded, max pass {} of budget {}, best {pass_ms:.2} ms",
        scar::util::fmt_bytes(pass_bytes_max),
        scar::util::fmt_bytes(compact_budget)
    );
    top.insert("bench_compact_pass_ms".to_string(), Json::Num(pass_ms));
    top.insert("bench_compact_pass_bytes".to_string(), Json::Num(pass_bytes_max as f64));
    top.insert("bench_compact_budget_bytes".to_string(), Json::Num(compact_budget as f64));
    top.insert("bench_compact_segments".to_string(), Json::Num(segments_total as f64));
    top.insert("bench_compact_generations".to_string(), Json::Num(generation as f64));
    top.insert("cells".to_string(), Json::Obj(cells));

    let path = std::path::Path::new(&out);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, Json::Obj(top).to_string())
        .with_context(|| format!("writing {out}"))?;
    println!("-> {out}");
    Ok(())
}

fn parse_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.str_opt("config") {
        Some(path) => RunConfig::from_file(std::path::Path::new(path))?,
        None => RunConfig::default(),
    };
    // --set k=v may appear multiple times; our tiny parser keeps only the
    // last one per key, so also accept direct --key value for every key.
    for key in [
        "model", "seed", "iters", "target_iters", "ps_nodes", "workers",
        "checkpoint_interval", "checkpoint_k", "checkpoint_mode", "selector",
        "recovery", "storage_shards", "storage_writers", "storage_max_pending",
        "storage_compact_threshold", "storage_compact_min_bytes",
        "storage_compact_max_bytes_per_pass", "storage_group_commit", "storage_parity",
        "fail_fraction", "fail_geom_p", "fail_plan", "fail_nodes",
        "fail_cascade_extra", "fail_cascade_gap", "fail_flaky_period",
        "fail_flaky_prob", "fail_flaky_max", "checkpoint_dir", "chaos",
    ] {
        if let Some(v) = args.str_opt(key) {
            cfg.apply(key, v)?;
        }
    }
    if let Some(kv) = args.str_opt("set") {
        let (k, v) = kv.split_once('=').context("--set expects key=value")?;
        cfg.apply(k, v)?;
    }
    Ok(cfg)
}

fn cmd_info() -> Result<()> {
    let dir = scar::artifact_dir();
    let metas = artifact::discover(&dir)?;
    println!("{} artifacts in {}", metas.len(), dir.display());
    for m in metas {
        let params: usize = m
            .state_specs()
            .iter()
            .map(|s| s.elem_count())
            .sum();
        println!(
            "  {:<14} model={:<12} state elems={:<10} inputs={} outputs={}",
            m.name,
            m.model,
            params,
            m.inputs.len(),
            m.outputs.len()
        );
    }
    Ok(())
}

fn make_store(cfg: &RunConfig) -> Result<Arc<ShardedStore>> {
    // The `chaos` config key wraps every shard in the fault-injecting
    // backend (the same plans scenario files take), so `scar
    // train`/`cluster` can drive storage faults straight from the CLI.
    let plan = cfg.chaos_plan()?;
    let store = match (cfg.checkpoint_dir.is_empty(), plan.is_empty()) {
        (true, true) => ShardedStore::new_mem(cfg.storage_shards)
            .with_mem_parity(cfg.storage_parity),
        (true, false) => plan
            .mem_store(cfg.storage_shards)
            .with_mem_parity(cfg.storage_parity),
        (false, _) => {
            let dir = std::path::Path::new(&cfg.checkpoint_dir);
            plan.disk_store(dir, cfg.storage_shards)?
                .with_disk_parity(dir, cfg.storage_parity)?
        }
    };
    Ok(Arc::new(store.with_group_commit(cfg.storage_group_commit)))
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = parse_config(args)?;
    let engine = default_engine()?;
    let mut trainer = build_trainer(engine, &cfg.model, &BuildOpts::default())?;
    let store = make_store(&cfg)?;
    let mut rng = Rng::new(cfg.seed ^ 0xF00D);

    trainer.init(cfg.seed)?;
    // Flight recorder: enabled only when --trace asks for a dump, so the
    // untraced hot path never pays for event bookkeeping.
    let rec = match args.str_opt("trace") {
        Some(_) => Recorder::enabled(),
        None => Recorder::disabled(),
    };
    let layout = trainer.layout().clone();
    let mut ck = AsyncCheckpointer::new(
        cfg.policy(),
        trainer.state(),
        &layout,
        store.clone(),
        cfg.checkpoint_mode,
        cfg.effective_writers(),
    )?
    .with_max_pending(cfg.storage_max_pending)
    .with_compaction(cfg.storage_compact_threshold, cfg.storage_compact_min_bytes as u64)
    .with_compaction_budget(cfg.storage_compact_max_bytes_per_pass as u64)
    .with_recorder(rec.clone());

    // Optional failure schedule: the configured plan expands to one or
    // more events (cascades and flaky nodes produce several).
    let events: Vec<FailureEvent> = match cfg.failure_plan() {
        Some(plan) => {
            let inj = FailureInjector::new(cfg.fail_geom_p, cfg.iters.max(2) - 1);
            let evs = plan.sample_events(&inj, layout.n_atoms(), &mut rng);
            println!("failure plan: {plan:?}");
            evs
        }
        None => Vec::new(),
    };
    // Cascade/flaky follow-ups can land past the fixed run length; they
    // are dropped (and said so) rather than announced and never applied.
    let (events, skipped): (Vec<FailureEvent>, Vec<FailureEvent>) =
        events.into_iter().partition(|f| f.iter < cfg.iters);
    for f in &events {
        println!(
            "scheduled failure: iter={} lost_atoms={}/{}",
            f.iter,
            f.lost_atoms.len(),
            layout.n_atoms()
        );
    }
    if !skipped.is_empty() {
        println!(
            "note: {} follow-up failure(s) fell past --iters {} and were dropped",
            skipped.len(),
            cfg.iters
        );
    }

    println!(
        "training {} for {} iters (policy: r={:.3} every {} iters, {} selector, {} writes, \
         {} shard(s); recovery: {:?})",
        cfg.model, cfg.iters, cfg.policy().fraction, cfg.policy().interval,
        cfg.selector, cfg.checkpoint_mode, cfg.storage_shards, cfg.recovery,
    );
    let t0 = std::time::Instant::now();
    for iter in 0..cfg.iters {
        for f in events.iter().filter(|f| f.iter == iter) {
            // Epoch fence: recovery only reads fully-committed state.
            ck.flush()?;
            let report = recovery::recover(
                cfg.recovery,
                trainer.state_mut(),
                &layout,
                &f.lost_atoms,
                store.as_ref(),
            )?;
            println!(
                "iter {iter}: FAILURE lost {} atoms -> {:?} recovery, ‖δ‖={:.4}",
                f.lost_atoms.len(),
                report.mode,
                report.delta_norm
            );
        }
        // The update norm costs a full state clone per iteration; only
        // traced runs pay for it.
        let prev = if rec.is_enabled() { Some(trainer.state().clone()) } else { None };
        let loss = trainer.step(iter)?;
        if let Some(prev) = prev {
            rec.record(
                iter + 1,
                EventKind::Progress { loss, update_norm: trainer.state().l2_distance(&prev) },
            );
        }
        let stats = ck.maybe_checkpoint(iter + 1, trainer.state(), &layout, &mut rng)?;
        if iter % 10 == 0 || iter + 1 == cfg.iters {
            println!(
                "iter {:>4}  loss {:>12.5}  {}",
                iter,
                loss,
                stats.map(|c| format!("[ckpt {} atoms]", c.atoms_saved)).unwrap_or_default()
            );
        }
    }
    let (rebuilt_atoms, rebuilt_bytes) = (ck.rebuilt_atoms(), ck.rebuilt_bytes());
    let (readopted_atoms, readopted_bytes) = (ck.readopted_atoms(), ck.readopted_bytes());
    let (skipped_atoms, skipped_bytes) = (ck.skipped_atoms(), ck.skipped_bytes());
    let stalls = ck.backpressure_stalls();
    ck.finish()?;
    println!(
        "done in {:.1}s; checkpoint bytes written: {}",
        t0.elapsed().as_secs_f64(),
        scar::util::fmt_bytes(store.total_bytes())
    );
    if rebuilt_atoms > 0 {
        println!(
            "selective rebuild after shard death(s): {} atom(s), {} (placement-planned \
             slices, not full re-persists)",
            rebuilt_atoms,
            scar::util::fmt_bytes(rebuilt_bytes)
        );
    }
    if readopted_atoms > 0 {
        println!(
            "healed shards re-adopted {} atom(s), {}",
            readopted_atoms,
            scar::util::fmt_bytes(readopted_bytes)
        );
    }
    if store.repaired_records() > 0 {
        println!(
            "parity scrub repaired {} corrupt record(s) in place, {}",
            store.repaired_records(),
            scar::util::fmt_bytes(store.repaired_bytes())
        );
    }
    if store.compaction_runs() > 0 {
        println!(
            "compaction: {} pass(es), {} reclaimed; on disk now: {}",
            store.compaction_runs(),
            scar::util::fmt_bytes(store.compaction_reclaimed_bytes()),
            scar::util::fmt_bytes(store.total_on_disk_bytes())
        );
    }
    if let Some(path) = args.str_opt("trace") {
        write_trace(path, &rec)?;
    }
    if args.bool("json") {
        let reg = standard_registry();
        reg.counter("rebuilt_atoms").set(rebuilt_atoms + readopted_atoms);
        reg.counter("rebuilt_bytes").set(rebuilt_bytes + readopted_bytes);
        reg.counter("skipped_atoms").set(skipped_atoms);
        reg.counter("skipped_bytes").set(skipped_bytes);
        reg.counter("backpressure_stalls").set(stalls);
        reg.counter("repaired_records").set(store.repaired_records());
        reg.counter("repaired_bytes").set(store.repaired_bytes());
        reg.counter("compaction_runs").set(store.compaction_runs());
        reg.counter("compaction_reclaimed_bytes").set(store.compaction_reclaimed_bytes());
        reg.counter("degraded_records").set(store.degraded_records());
        print_json_metrics(&reg);
    }
    Ok(())
}

fn cmd_cluster(args: &Args) -> Result<()> {
    let cfg = parse_config(args)?;
    let engine = default_engine()?;
    let mut trainer = build_trainer(engine, &cfg.model, &BuildOpts::default())?;
    let store = make_store(&cfg)?;
    // Kill schedule: --kills "iter:node,iter:node" (correlated kills share
    // an iteration); falls back to the single --kill-iter/--kill-node.
    let kills: Vec<(usize, usize)> = match args.str_opt("kills") {
        Some(spec) => spec
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|pair| -> Result<(usize, usize)> {
                let (i, n) = pair
                    .trim()
                    .split_once(':')
                    .with_context(|| format!("--kills expects iter:node, got '{pair}'"))?;
                Ok((
                    i.parse().with_context(|| format!("bad kill iter '{i}'"))?,
                    n.parse().with_context(|| format!("bad kill node '{n}'"))?,
                ))
            })
            .collect::<Result<_>>()?,
        None => vec![(
            args.usize_or("kill-iter", cfg.iters / 3),
            args.usize_or("kill-node", 0),
        )],
    };
    println!(
        "cluster run: {} nodes, {} storage shard(s), {} checkpoints, kill schedule {:?}",
        cfg.ps_nodes, cfg.storage_shards, cfg.checkpoint_mode, kills
    );
    let rec = match args.str_opt("trace") {
        Some(_) => Recorder::enabled(),
        None => Recorder::disabled(),
    };
    let job = scar::cluster::ClusterJob {
        ckpt_mode: cfg.checkpoint_mode,
        ckpt_writers: cfg.effective_writers(),
        max_pending: cfg.storage_max_pending,
        compact_threshold: cfg.storage_compact_threshold,
        compact_min_bytes: cfg.storage_compact_min_bytes as u64,
        compact_max_pass_bytes: cfg.storage_compact_max_bytes_per_pass as u64,
        kills,
        detect: scar::cluster::Detect::Heartbeat(Duration::from_millis(20)),
        recorder: rec.clone(),
        ..scar::cluster::ClusterJob::new(cfg.ps_nodes, cfg.iters, cfg.policy(), cfg.seed)
    };
    let report = scar::cluster::run_cluster_training(&mut trainer, store.clone(), &job)?;
    for e in &report.events {
        println!("event: {e:?}");
    }
    if report.degraded_records > 0 {
        println!(
            "degraded storage writes (re-homed off a dead shard): {}",
            report.degraded_records
        );
    }
    if report.rebuilt_atoms > 0 {
        println!(
            "selective rebuilds (dead node/shard slices only): {} atom(s), {}",
            report.rebuilt_atoms,
            scar::util::fmt_bytes(report.rebuilt_bytes)
        );
    }
    if report.compaction_runs > 0 {
        println!(
            "compaction: {} pass(es), {} reclaimed",
            report.compaction_runs,
            scar::util::fmt_bytes(report.compaction_reclaimed_bytes)
        );
    }
    println!(
        "final loss: {:.5}; recovery ‖δ‖: {:.4}; checkpoint bytes: {}",
        report.losses.last().copied().unwrap_or(f64::NAN),
        report.recovery_delta_norm,
        scar::util::fmt_bytes(report.checkpoint_bytes)
    );
    if let Some(path) = args.str_opt("trace") {
        write_trace(path, &rec)?;
    }
    if args.bool("json") {
        let reg = standard_registry();
        reg.counter("rebuilt_atoms").set(report.rebuilt_atoms);
        reg.counter("rebuilt_bytes").set(report.rebuilt_bytes);
        reg.counter("compaction_runs").set(report.compaction_runs);
        reg.counter("compaction_reclaimed_bytes").set(report.compaction_reclaimed_bytes);
        reg.counter("repaired_records").set(store.repaired_records());
        reg.counter("repaired_bytes").set(store.repaired_bytes());
        reg.counter("degraded_records").set(report.degraded_records);
        print_json_metrics(&reg);
    }
    Ok(())
}

/// Dump a flight-recorder trace: `.jsonl` gets the line-per-event JSONL
/// format (`scar trace` input), anything else the Chrome `trace_event`
/// JSON loadable in `chrome://tracing` / Perfetto.
fn write_trace(path: &str, rec: &Recorder) -> Result<()> {
    let events = rec.drain();
    let body = if path.ends_with(".jsonl") {
        scar::obs::to_jsonl(&events)
    } else {
        scar::obs::to_chrome_trace(&events)
    };
    if let Some(parent) = std::path::Path::new(path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating trace dir {}", parent.display()))?;
        }
    }
    std::fs::write(path, body).with_context(|| format!("writing trace {path}"))?;
    println!("trace -> {path} ({} events)", events.len());
    Ok(())
}

/// `--json`: machine-readable end-of-run metrics on stdout, one flat
/// object keyed by standard counter names.
fn print_json_metrics(reg: &Registry) {
    use scar::util::json::Json;
    let mut obj = std::collections::BTreeMap::new();
    for (k, v) in reg.snapshot() {
        obj.insert(k, Json::Num(v));
    }
    println!("{}", Json::Obj(obj).to_string());
}

/// `scar trace`: load a JSONL flight-recorder trace and report on it —
/// event counts, fault -> recovery latency, optionally an SVG timeline
/// (`--render`) or a Chrome trace_event conversion (`--chrome`).
fn cmd_trace(args: &Args) -> Result<()> {
    let path = args
        .positional
        .get(1)
        .context("usage: scar trace <trace.jsonl> [--render out.svg] [--chrome out.json]")?;
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading trace {path}"))?;
    let events = scar::obs::parse_jsonl(&text)?;
    println!("{path}: {} event(s)", events.len());
    for (tag, n) in scar::obs::timeline::summary_counts(&events) {
        println!("  {tag:<14} {n}");
    }
    // Aggregate the compaction narration: how much the generational
    // passes folded and reclaimed across the run.
    let (mut passes, mut segments, mut reclaimed, mut max_gen) = (0u64, 0u64, 0u64, 0u64);
    for e in &events {
        if let scar::obs::EventKind::Compaction { generation, segments: s, reclaimed: r, .. } =
            &e.kind
        {
            passes += 1;
            segments += s;
            reclaimed += r;
            max_gen = max_gen.max(*generation);
        }
    }
    if passes > 0 {
        println!(
            "compaction: {passes} pass(es), {segments} segment(s) folded, \
             {reclaimed} byte(s) reclaimed, max generation {max_gen}"
        );
    }
    let table = scar::obs::timeline::fault_latency_table(&events);
    if !table.is_empty() {
        print!("{table}");
    }
    if let Some(out) = args.str_opt("chrome") {
        std::fs::write(out, scar::obs::to_chrome_trace(&events))
            .with_context(|| format!("writing chrome trace {out}"))?;
        println!("chrome trace -> {out}");
    }
    if let Some(out) = args.str_opt("render") {
        std::fs::write(out, scar::obs::timeline::render_timeline(&events))
            .with_context(|| format!("writing timeline {out}"))?;
        println!("timeline -> {out}");
    }
    Ok(())
}

/// `scar compact`: fold superseded records of an on-disk sharded
/// checkpoint store into fresh segments, in place.
fn cmd_compact(args: &Args) -> Result<()> {
    let dir = args
        .str_opt("dir")
        .context(
            "usage: scar compact --dir <checkpoint_dir> [--shards n] [--threshold r] \
             [--budget bytes]",
        )?;
    let dir = std::path::Path::new(dir);
    let shards = match args.str_opt("shards") {
        Some(s) => s.parse().context("--shards expects an integer")?,
        None => detect_shards(dir)?,
    };
    let threshold = args.f64_or("threshold", 0.0);
    let min_bytes = args.u64_or("min-bytes", 0);
    // --budget bounds each shard's pass to a generational fold of the
    // worst-garbage segments; 0 keeps the monolithic full-shard pass.
    let budget = args.u64_or("budget", 0);
    let store = ShardedStore::open_disk(dir, shards)?;
    let before = store.total_on_disk_bytes();
    let ratios = store.garbage_ratios();
    let runs = store.compact_if_needed(threshold, min_bytes, budget)?;
    for (s, stats) in &runs {
        let pass = if stats.generation > 0 {
            format!(
                " (generation {}: {} segment(s), {} read)",
                stats.generation,
                stats.segments_compacted,
                scar::util::fmt_bytes(stats.pass_bytes)
            )
        } else {
            String::new()
        };
        println!(
            "shard {s}: garbage {:.1}% -> {} live record(s), {} dead dropped, {} reclaimed, \
             {} segment file(s) removed{pass}",
            ratios[*s] * 100.0,
            stats.live_records,
            stats.dead_records,
            scar::util::fmt_bytes(stats.reclaimed_bytes),
            stats.segments_removed
        );
    }
    println!(
        "{} of {} shard(s) compacted; on disk {} -> {}",
        runs.len(),
        shards,
        scar::util::fmt_bytes(before),
        scar::util::fmt_bytes(store.total_on_disk_bytes())
    );
    Ok(())
}

/// Count the `shard-NNN` subdirectories of a checkpoint dir (the layout
/// `ShardedStore::open_disk` writes). Only real directories with an
/// all-digit suffix count — a stray `shard-000.bak` file must not
/// inflate the shard count and make `open_disk` invent an empty shard.
fn detect_shards(dir: &std::path::Path) -> Result<usize> {
    let mut n = 0;
    for entry in std::fs::read_dir(dir)
        .with_context(|| format!("reading checkpoint dir {}", dir.display()))?
    {
        let entry = entry?;
        if !entry.file_type()?.is_dir() {
            continue;
        }
        let name = entry.file_name();
        let is_shard = name
            .to_string_lossy()
            .strip_prefix("shard-")
            .map(|s| !s.is_empty() && s.bytes().all(|b| b.is_ascii_digit()))
            .unwrap_or(false);
        if is_shard {
            n += 1;
        }
    }
    if n == 0 {
        bail!("no shard-NNN directories under {}", dir.display());
    }
    Ok(n)
}

fn cmd_bound(args: &Args) -> Result<()> {
    let model = args.str_or("model", "qp4");
    let iters = args.usize_or("iters", 200);
    let target = args.usize_or("target_iters", 60.min(iters));
    let seed = args.u64_or("seed", 42);
    let engine = default_engine()?;
    let mut trainer = build_trainer(engine, &model, &BuildOpts::default())?;
    let traj = harness::run_trajectory(&mut trainer, seed, iters, target)?;
    // Errors against x* (final snapshot).
    let xstar = traj.x_star().clone();
    let errors: Vec<f64> = traj
        .snapshots
        .iter()
        .take(traj.converged_iters + 1)
        .map(|s| s.l2_distance(&xstar))
        .collect();
    let c = theory::estimate_rate(&errors, errors.last().copied().unwrap_or(0.0) * 2.0);
    let x0 = errors[0];
    println!("model={model} empirical c={c:.5} ‖x0−x*‖={x0:.4} ε-iters={}", traj.converged_iters);
    println!("{:>12} {:>14}", "‖δ‖", "bound (iters)");
    for mult in [0.01, 0.05, 0.1, 0.25, 0.5, 1.0] {
        let norm = x0 * mult;
        let b = theory::iteration_cost_bound(
            c,
            x0,
            &[theory::Perturbation { iter: traj.converged_iters / 2, norm }],
        );
        println!("{:>12.4} {:>14.2}", norm, b);
    }
    Ok(())
}

fn cmd_advisor(args: &Args) -> Result<()> {
    let model = args.str_or("model", "mlr_covtype");
    let probe_iters = args.usize_or("probe-iters", 40);
    let fail_rate = args.f64_or("fail-rate", 0.02);
    let lost_fraction = args.f64_or("lost-fraction", 0.25);
    let base_interval = args.usize_or("checkpoint_interval", 8);
    let seed = args.u64_or("seed", 42);

    let engine = default_engine()?;
    let mut trainer = build_trainer(engine, &model, &BuildOpts::default())?;
    trainer.init(seed)?;

    // Probe phase: run a few iterations, estimating c online and
    // measuring T_iter and a full checkpoint barrier's blocking time.
    let mut est = scar::advisor::OnlineRateEstimator::default();
    let layout = trainer.layout().clone();
    let mut store = MemStore::new();
    let mut coord = CheckpointCoordinator::new(
        scar::checkpoint::CheckpointPolicy::full(probe_iters + 1),
        trainer.state(),
        &layout,
        &mut store,
    )?;
    let t0 = std::time::Instant::now();
    for iter in 0..probe_iters {
        let loss = trainer.step(iter)?;
        est.observe(loss);
    }
    let t_iter = t0.elapsed().as_secs_f64() / probe_iters as f64;
    let mut rng = Rng::new(seed);
    let stats = coord.checkpoint_now(probe_iters, trainer.state(), &layout, &mut store, &mut rng)?;

    let Some(c) = est.rate() else {
        bail!("probe too short to estimate c; raise --probe-iters");
    };
    println!(
        "probe: {model}, {probe_iters} iters; c≈{c:.4}, T_iter={:.3}s, full T_dump(blocking)={:.4}s",
        t_iter, stats.blocking_secs
    );

    let inputs = scar::advisor::AdvisorInputs {
        c,
        lost_fraction,
        failure_rate: fail_rate,
        t_iter,
        t_dump_full: stats.blocking_secs,
        base_interval,
    };
    let scores = scar::advisor::recommend_policy(&inputs);
    println!(
        "\n{:>4} {:>10} {:>18} {:>22}",
        "k", "fraction", "E[rework iters]", "overhead s/iter"
    );
    for s in &scores {
        println!(
            "{:>4} {:>10.3} {:>18.2} {:>22.6}",
            s.k, s.policy.fraction, s.rework_iters, s.overhead_per_iter
        );
    }
    let best = &scores[0];
    println!(
        "\nrecommendation: 1/{} priority checkpoints every {} iterations (+partial recovery)",
        best.k, best.policy.interval
    );
    Ok(())
}
