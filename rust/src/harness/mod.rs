//! Experiment harness: iteration-cost measurement (§3, §5).
//!
//! The iteration cost ι(δ, ε) = κ(y, ε) − κ(x, ε) is measured exactly as
//! in the paper: run the unperturbed trainer once to fix the convergence
//! threshold ε ("the value of ε is set so that an unperturbed trial
//! converges in roughly N iterations") and the baseline iteration count;
//! then, per trial, perturb/fail at iteration T and count how many extra
//! iterations the perturbed run needs to reach ε.
//!
//! Trajectory caching: the unperturbed run snapshots the full state at
//! every iteration, so each trial replays only the post-failure suffix —
//! this is what makes 100-trial sweeps tractable on the CPU PJRT backend.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::chaos::FaultPlan;
use crate::checkpoint::{
    AsyncCheckpointer, CheckpointCoordinator, CheckpointMode, CheckpointPolicy,
};
use crate::failure::FailureEvent;
use crate::obs::{standard_registry, EventKind, Recorder};
use crate::params::ParamStore;
use crate::policy::{PolicyConfig, PolicyController};
use crate::recovery::{recover, RecoveryMode, RecoveryReport};
use crate::storage::{MemStore, ShardedStore};
use crate::trainer::Trainer;
use crate::util::rng::Rng;
use crate::util::stats::{summarize, Summary};

/// Cached unperturbed run.
pub struct Trajectory {
    pub seed: u64,
    /// losses[i] = loss after iteration i (0-based).
    pub losses: Vec<f64>,
    /// snapshots[i] = full state after i iterations (so snapshots[0] is
    /// the initial state and snapshots.len() == losses.len() + 1).
    pub snapshots: Vec<ParamStore>,
    /// Convergence threshold ε (loss space).
    pub threshold: f64,
    /// Iterations the unperturbed run needed to first reach ε.
    pub converged_iters: usize,
}

impl Trajectory {
    pub fn max_iters(&self) -> usize {
        self.losses.len()
    }

    /// State after `iter` iterations.
    pub fn state_at(&self, iter: usize) -> &ParamStore {
        &self.snapshots[iter]
    }

    /// Best available approximation of x*: the final snapshot.
    pub fn x_star(&self) -> &ParamStore {
        self.snapshots.last().unwrap()
    }
}

/// Run the unperturbed trajectory. ε is set to the loss reached after
/// `target_iters` iterations, and the run continues to `max_iters` so the
/// final snapshot can serve as the x* estimate.
pub fn run_trajectory(
    trainer: &mut dyn Trainer,
    seed: u64,
    max_iters: usize,
    target_iters: usize,
) -> Result<Trajectory> {
    assert!(target_iters >= 1 && target_iters <= max_iters);
    trainer.init(seed)?;
    let mut losses = Vec::with_capacity(max_iters);
    let mut snapshots = Vec::with_capacity(max_iters + 1);
    snapshots.push(trainer.state().clone());
    for iter in 0..max_iters {
        losses.push(trainer.step(iter)?);
        snapshots.push(trainer.state().clone());
    }
    let threshold = losses[target_iters - 1];
    let converged_iters = losses
        .iter()
        .position(|&l| l <= threshold)
        .map(|i| i + 1)
        .unwrap_or(target_iters);
    Ok(Trajectory { seed, losses, snapshots, threshold, converged_iters })
}

/// Resume from `state` at iteration `start_iter` and train until the loss
/// reaches `threshold` or `cap` total iterations elapse. Returns total
/// iteration count at convergence (`None` if censored at the cap).
pub fn continue_from(
    trainer: &mut dyn Trainer,
    state: ParamStore,
    start_iter: usize,
    threshold: f64,
    cap: usize,
) -> Result<Option<usize>> {
    trainer.set_state(state);
    for iter in start_iter..cap {
        let loss = trainer.step(iter)?;
        if loss <= threshold {
            return Ok(Some(iter + 1));
        }
    }
    Ok(None)
}

/// Replay the checkpoint coordinator along the cached trajectory up to
/// (and including) iteration `upto`, under `policy`. Returns the
/// coordinator (whose cache is the running checkpoint at failure time)
/// and the backing store.
pub fn replay_checkpoints(
    traj: &Trajectory,
    trainer: &dyn Trainer,
    policy: CheckpointPolicy,
    upto: usize,
    ckpt_seed: u64,
) -> Result<(CheckpointCoordinator, MemStore)> {
    let layout = trainer.layout();
    let mut store = MemStore::new();
    let mut coord = CheckpointCoordinator::new(policy, traj.state_at(0), layout, &mut store)?;
    let mut rng = Rng::new(ckpt_seed);
    for iter in 1..=upto {
        coord.maybe_checkpoint(iter, traj.state_at(iter), layout, &mut store, &mut rng)?;
    }
    Ok((coord, store))
}

/// Full checkpoint-subsystem configuration for a trial: the (r, rC)
/// policy plus the write mode, storage topology, back-pressure bound and
/// storage-fault schedule the scenario engine wires through
/// (`checkpoint.mode`, `storage.shards`, `storage.writers`,
/// `storage.max_pending`, `[chaos]`). Async and sync setups on the same
/// seed produce byte-identical results — the flush fence before every
/// recovery guarantees it (pinned by `rust/tests/async_checkpoint.rs`
/// and, with storage faults, `rust/tests/chaos.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointSetup {
    pub policy: CheckpointPolicy,
    pub mode: CheckpointMode,
    pub shards: usize,
    pub writers: usize,
    /// Async back-pressure bound (0 = unbounded queue).
    pub max_pending: usize,
    /// Injected storage faults (empty = no chaos).
    pub chaos: FaultPlan,
    /// Erasure-coded parity shards (`storage.parity`; 0 = none, 1 = one
    /// XOR parity shard per store — the only coding implemented). With
    /// parity on, every flush fence scrubs/re-encodes stripes, CRC-failed
    /// records are repaired in place, and a cold-restarted store can
    /// rebuild a dead shard's slice from survivors alone.
    pub parity: usize,
    /// Deep-scrub cadence for dirty-only parity fences
    /// (`storage.scrub_interval`): 0 = fences touch only stripes written
    /// since the last fence; N > 0 = every Nth fence scans and re-encodes
    /// the entire state.
    pub scrub_interval: usize,
    /// Disk-backed trial: root directory for this trial's shards
    /// (`None` = in-memory shards, the default). The directory is wiped
    /// at store build time — stale records from an earlier run would
    /// otherwise win the freshest-record read scan and change results.
    pub checkpoint_dir: Option<PathBuf>,
    /// Garbage-ratio threshold triggering segment compaction at flush
    /// fences (0 = never compact; meaningless on memory shards).
    pub compact_threshold: f64,
    /// Minimum on-disk shard size before compaction runs.
    pub compact_min_bytes: u64,
    /// Per-pass segment-byte budget for generational compaction
    /// (`storage.compact_max_bytes_per_pass`; 0 = monolithic full-shard
    /// passes).
    pub compact_max_pass_bytes: u64,
    /// Group-commit write batching (`storage.group_commit`): one
    /// coalesced write + one durability barrier per shard per fence
    /// instead of a barrier per record plus a manifest rewrite. Byte-
    /// identical to the per-record path; no-op on memory shards.
    pub group_commit: bool,
    /// Write the trial's flight-recorder trace to this JSONL file
    /// (`None` = recorder disabled, the default — a single untaken
    /// branch per would-be event). Tracing never changes results: the
    /// traced run's recovered parameters and report are byte-identical
    /// to the untraced run (pinned by `rust/tests/obs.rs`).
    pub trace_path: Option<PathBuf>,
    /// Blocking cost of one *full-size* checkpoint dump in iteration
    /// units (`[advisor] dump_cost_iters`), priced into
    /// `iteration_cost` pro rata per atom actually written. Charged to
    /// every trial — static and adaptive alike — so policy comparisons
    /// pay for checkpoint bandwidth, not just rework. `0` (the default)
    /// keeps checkpoints free and all existing reports byte-identical.
    pub dump_cost_iters: f64,
    /// Adaptive-policy controller config (`policy = "adaptive"` cells):
    /// when set, a [`PolicyController`] watches the live loss curve and
    /// failure arrivals and retunes the checkpoint policy/mode at
    /// iteration boundaries mid-trial. `None` = static policy (the
    /// default).
    pub adaptive: Option<PolicyConfig>,
}

impl CheckpointSetup {
    /// Synchronous single-shard setup — the classic configuration the
    /// legacy entry points default to.
    pub fn sync(policy: CheckpointPolicy) -> CheckpointSetup {
        CheckpointSetup::new(policy, CheckpointMode::Sync, 1, 1)
    }

    /// A fault-free in-memory setup with the given topology.
    pub fn new(
        policy: CheckpointPolicy,
        mode: CheckpointMode,
        shards: usize,
        writers: usize,
    ) -> CheckpointSetup {
        CheckpointSetup {
            policy,
            mode,
            shards,
            writers,
            max_pending: 0,
            chaos: FaultPlan::default(),
            parity: 0,
            scrub_interval: 0,
            checkpoint_dir: None,
            compact_threshold: 0.0,
            compact_min_bytes: 0,
            compact_max_pass_bytes: 0,
            group_commit: false,
            trace_path: None,
            dump_cost_iters: 0.0,
            adaptive: None,
        }
    }

    /// The trial's sharded store — in-memory by default, on-disk segment
    /// logs under `checkpoint_dir` when set — chaos-wrapped when the
    /// setup carries a fault schedule. Both backends behind the same
    /// plan produce byte-identical trial results
    /// (`rust/tests/chaos.rs`).
    pub fn build_store(&self) -> Result<ShardedStore> {
        if self.parity > 1 {
            bail!(
                "storage.parity = {} is not supported: only single-parity XOR coding \
                 (parity <= 1) is implemented (Reed–Solomon m > 1 is not)",
                self.parity
            );
        }
        let store = match &self.checkpoint_dir {
            None => {
                let store = if self.chaos.is_empty() {
                    ShardedStore::new_mem(self.shards)
                } else {
                    self.chaos.validate(self.shards)?;
                    self.chaos.mem_store(self.shards)
                };
                store.with_mem_parity(self.parity)
            }
            Some(dir) => {
                if dir.exists() {
                    std::fs::remove_dir_all(dir).with_context(|| {
                        format!("clearing trial checkpoint dir {}", dir.display())
                    })?;
                }
                self.chaos.validate(self.shards)?;
                self.chaos.disk_store(dir, self.shards)?.with_disk_parity(dir, self.parity)?
            }
        };
        Ok(store.with_scrub_interval(self.scrub_interval).with_group_commit(self.group_commit))
    }
}

/// One failure-recovery trial (Fig 7/8 semantics).
#[derive(Debug, Clone)]
pub struct TrialSpec {
    pub policy: CheckpointPolicy,
    pub mode: RecoveryMode,
    pub fail_iter: usize,
    pub lost_atoms: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct TrialResult {
    /// Rework iterations: total iterations to ε minus the unperturbed
    /// count. Censored trials are reported at the cap.
    pub iteration_cost: f64,
    pub censored: bool,
    pub recovery: RecoveryReport,
    /// Atoms the checkpointer selectively rebuilt after storage-shard
    /// deaths (plus healed-shard re-adoptions) during the trial — the
    /// planner's slices, never the full checkpoint. 0 without chaos.
    pub rebuilt_atoms: u64,
    /// Payload bytes those rebuilds re-persisted.
    pub rebuilt_bytes: u64,
    /// Segment-compaction passes the trial's store ran.
    pub compaction_runs: u64,
    /// Segment bytes those passes reclaimed.
    pub compaction_reclaimed_bytes: u64,
    /// Records the parity scrub repaired in place (bitflipped/CRC-failed
    /// members). 0 without `storage.parity`.
    pub repaired_records: u64,
    /// Payload bytes of those repaired records.
    pub repaired_bytes: u64,
    /// Atoms the delta-skip filter elided from checkpoint barriers
    /// because their payload CRC was unchanged since the last write.
    pub skipped_atoms: u64,
    /// Payload bytes those elided atoms would have written.
    pub skipped_bytes: u64,
    /// Registry snapshot of the trial's counters, keyed by the
    /// [`STANDARD_COUNTERS`](crate::obs::STANDARD_COUNTERS) names — one
    /// shared key set in every trial (zeros where a subsystem never
    /// ran), so cell-level sums and trend CSV columns stay stable.
    pub metrics: BTreeMap<String, f64>,
}

/// Cap for perturbed runs: generous multiple of the baseline so heavy
/// perturbations still resolve, while keeping worst-case trial time
/// bounded.
pub fn default_cap(traj: &Trajectory) -> usize {
    traj.converged_iters * 4 + 60
}

pub fn run_trial(
    trainer: &mut dyn Trainer,
    traj: &Trajectory,
    spec: &TrialSpec,
    trial_seed: u64,
) -> Result<TrialResult> {
    let (_, store) = replay_checkpoints(traj, trainer, spec.policy, spec.fail_iter, trial_seed)?;
    let mut state = traj.state_at(spec.fail_iter).clone();
    let report = recover(spec.mode, &mut state, trainer.layout(), &spec.lost_atoms, &store)
        .context("recovery failed")?;
    let cap = default_cap(traj);
    // The trainer replays the *same* data stream (same seed) from the
    // failure iteration onward.
    trainer.init(traj.seed)?;
    let total = continue_from(trainer, state, spec.fail_iter, traj.threshold, cap)?;
    let (total, censored) = match total {
        Some(t) => (t, false),
        None => (cap, true),
    };
    Ok(TrialResult {
        iteration_cost: total as f64 - traj.converged_iters as f64,
        censored,
        recovery: report,
        rebuilt_atoms: 0,
        rebuilt_bytes: 0,
        compaction_runs: 0,
        compaction_reclaimed_bytes: 0,
        repaired_records: 0,
        repaired_bytes: 0,
        skipped_atoms: 0,
        skipped_bytes: 0,
        metrics: standard_registry().snapshot(),
    })
}

/// Run one trial under a multi-event failure plan (the generalization of
/// [`run_trial`] that cascades and flaky nodes need).
///
/// The first event behaves exactly like [`run_trial`]: checkpoints are
/// replayed along the cached trajectory up to the failure, the lost atoms
/// are recovered, and the run resumes on the same data stream. Unlike the
/// single-event path, the checkpoint coordinator then *keeps running* on
/// the live (diverged) suffix, so later events recover from a checkpoint
/// that reflects post-failure progress — the semantics a real deployment
/// would see. The trial ends at the first ε-crossing (the κ(y, ε) of §3)
/// even if later scheduled events never get to strike.
///
/// The returned [`RecoveryReport`] aggregates all events: counts are
/// summed, and `delta_norm` combines the per-event perturbations as
/// sqrt(Σ‖δᵢ‖²) — exact for the first event, an accounting convention for
/// the rest (later δs are measured against the live run, not the cached
/// trajectory).
pub fn run_plan_trial(
    trainer: &mut dyn Trainer,
    traj: &Trajectory,
    policy: CheckpointPolicy,
    mode: RecoveryMode,
    events: &[FailureEvent],
    trial_seed: u64,
) -> Result<TrialResult> {
    run_plan_trial_with(trainer, traj, &CheckpointSetup::sync(policy), mode, events, trial_seed)
}

/// Apply the controller's decision (if any) for iteration `iter` at its
/// fence point: retune the policy/mode on the live checkpointer and
/// narrate the switch through the flight recorder. Switches land only
/// here — between `step` and the iteration's barrier — never inside a
/// barrier or a recovery.
fn apply_policy_decision(
    ctl: &mut PolicyController,
    iter: usize,
    ck: &mut AsyncCheckpointer,
    rec: &Recorder,
) -> Result<()> {
    if let Some(sw) = ctl.decide(iter) {
        ck.set_policy(sw.policy);
        ck.set_mode(sw.mode)?;
        if rec.is_enabled() {
            rec.record(
                iter,
                EventKind::PolicySwitch {
                    k: sw.k,
                    interval: sw.policy.interval,
                    mode: sw.mode.to_string(),
                },
            );
        }
    }
    Ok(())
}

/// [`run_plan_trial`] with an explicit [`CheckpointSetup`]: the trial's
/// running checkpoint lives in a sharded store driven sync or async by an
/// [`AsyncCheckpointer`], and every recovery is preceded by the `flush`
/// epoch fence — so the result is a pure function of (scenario inputs,
/// seed) whatever the mode, shard count, writer count, or injected
/// storage-fault schedule.
///
/// With `setup.adaptive` set, a [`PolicyController`] rides along: it is
/// fed every loss and failure arrival (iteration-clocked, so decisions
/// stay deterministic), and its switches are applied at iteration
/// boundaries via [`apply_policy_decision`]. With `dump_cost_iters > 0`,
/// every barrier's written atoms are priced into `iteration_cost` at
/// `dump_cost_iters / n_atoms` each — for static and adaptive cells
/// alike, so the comparison charges both for checkpoint bandwidth.
pub fn run_plan_trial_with(
    trainer: &mut dyn Trainer,
    traj: &Trajectory,
    setup: &CheckpointSetup,
    mode: RecoveryMode,
    events: &[FailureEvent],
    trial_seed: u64,
) -> Result<TrialResult> {
    assert!(!events.is_empty(), "run_plan_trial needs at least one event");
    let mut events = events.to_vec();
    events.sort_by_key(|e| e.iter);
    let first_iter = events[0].iter.max(1).min(traj.max_iters());

    let layout = trainer.layout().clone();
    let store = Arc::new(setup.build_store()?);
    let rec = match setup.trace_path {
        Some(_) => Recorder::enabled(),
        None => Recorder::disabled(),
    };
    let mut ck = AsyncCheckpointer::new(
        setup.policy,
        traj.state_at(0),
        &layout,
        store.clone(),
        setup.mode,
        setup.writers,
    )?
    .with_max_pending(setup.max_pending)
    .with_compaction(setup.compact_threshold, setup.compact_min_bytes)
    .with_compaction_budget(setup.compact_max_pass_bytes)
    .with_recorder(rec.clone());
    if setup.adaptive.is_some() {
        // The controller may flip sync → async mid-run; make sure the
        // writer pool exists even when the trial starts sync.
        ck = ck.with_writer_pool(setup.writers.max(1));
    }
    let mut ctl = setup.adaptive.map(|cfg| {
        // Map the configured policy onto the controller's candidate
        // grid: k ≈ base_interval / interval (k = 1 ⇔ full dumps every
        // base_interval iterations).
        let base = cfg.base_interval.max(1) as f64;
        let initial_k = (base / setup.policy.interval.max(1) as f64).round().max(1.0) as usize;
        PolicyController::new(cfg, initial_k, setup.mode)
    });
    let dump_price = setup.dump_cost_iters / layout.n_atoms().max(1) as f64;
    let mut dump_cost = 0.0f64;
    // Replay barriers along the cached trajectory up to the failure
    // (same RNG stream as replay_checkpoints).
    let mut replay_rng = Rng::new(trial_seed);
    for iter in 1..=first_iter {
        if let Some(ctl) = ctl.as_mut() {
            ctl.observe_loss(traj.losses[iter - 1]);
            apply_policy_decision(ctl, iter, &mut ck, &rec)?;
        }
        if let Some(stats) =
            ck.maybe_checkpoint(iter, traj.state_at(iter), &layout, &mut replay_rng)?
        {
            dump_cost += dump_price * stats.atoms_saved as f64;
        }
        if rec.is_enabled() {
            // The replayed prefix comes straight off the cached
            // trajectory: per-iteration loss and update norm are
            // re-derivable from its snapshots.
            rec.record(
                iter,
                EventKind::Progress {
                    loss: traj.losses[iter - 1],
                    update_norm: traj.state_at(iter).l2_distance(traj.state_at(iter - 1)),
                },
            );
        }
    }

    let mut state = traj.state_at(first_iter).clone();
    ck.flush()?;
    let mut report = recover(mode, &mut state, &layout, &events[0].lost_atoms, store.as_ref())
        .context("recovery failed")?;
    let mut delta_sq = report.delta_norm * report.delta_norm;
    if let Some(ctl) = ctl.as_mut() {
        let frac = events[0].lost_atoms.len() as f64 / layout.n_atoms().max(1) as f64;
        ctl.observe_failure(first_iter, frac);
    }

    let cap = default_cap(traj);
    trainer.init(traj.seed)?;
    trainer.set_state(state);
    let mut ckpt_rng = Rng::new(trial_seed ^ 0x5EED_CA5C);
    let mut next_event = 1usize;
    let mut total = None;
    for iter in first_iter..cap {
        while next_event < events.len() && events[next_event].iter <= iter {
            ck.flush()?;
            let r = recover(
                mode,
                trainer.state_mut(),
                &layout,
                &events[next_event].lost_atoms,
                store.as_ref(),
            )
            .context("recovery failed")?;
            report.atoms_restored += r.atoms_restored;
            report.elems_restored += r.elems_restored;
            report.secs += r.secs;
            delta_sq += r.delta_norm * r.delta_norm;
            if let Some(ctl) = ctl.as_mut() {
                let frac = events[next_event].lost_atoms.len() as f64
                    / layout.n_atoms().max(1) as f64;
                ctl.observe_failure(events[next_event].iter, frac);
            }
            next_event += 1;
        }
        // The update norm is only computed when tracing: it costs a full
        // state clone per iteration, which the untraced hot path never
        // pays.
        let prev = if rec.is_enabled() { Some(trainer.state().clone()) } else { None };
        let loss = trainer.step(iter)?;
        if let Some(prev) = prev {
            rec.record(
                iter + 1,
                EventKind::Progress { loss, update_norm: trainer.state().l2_distance(&prev) },
            );
        }
        if let Some(ctl) = ctl.as_mut() {
            ctl.observe_loss(loss);
            apply_policy_decision(ctl, iter + 1, &mut ck, &rec)?;
        }
        if let Some(stats) =
            ck.maybe_checkpoint(iter + 1, trainer.state(), &layout, &mut ckpt_rng)?
        {
            dump_cost += dump_price * stats.atoms_saved as f64;
        }
        if loss <= traj.threshold {
            total = Some(iter + 1);
            break;
        }
    }
    let rebuilt_atoms = ck.rebuilt_atoms() + ck.readopted_atoms();
    let rebuilt_bytes = ck.rebuilt_bytes() + ck.readopted_bytes();
    let skipped_atoms = ck.skipped_atoms();
    let skipped_bytes = ck.skipped_bytes();
    let backpressure_stalls = ck.backpressure_stalls();
    let final_interval = ck.policy().interval;
    let fences = ck.fences();
    let fence_wall_ms = ck.avg_fence_wall_ms();
    if let Some(ctl) = ctl.as_mut() {
        // Stalls are wall-clock observability, outside the determinism
        // surface: the controller records them for reporting but never
        // reads them in `decide`.
        ctl.note_stalls(backpressure_stalls);
        // Measured fence wall-clock feeds the controller's future
        // learned dump-cost model — same reporting-only rule as stalls.
        ctl.observe_fence_wall_ms(ck.last_fence_wall_ms());
    }
    ck.finish()?;
    if let Some(path) = &setup.trace_path {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating trace dir {}", dir.display()))?;
        }
        std::fs::write(path, crate::obs::to_jsonl(&rec.drain()))
            .with_context(|| format!("writing trace {}", path.display()))?;
    }
    report.delta_norm = delta_sq.sqrt();
    let (total, censored) = match total {
        Some(t) => (t, false),
        None => (cap, true),
    };
    // Fill the metrics registry from the trial's counters — every trial
    // shares the standard key set, so cell sums and trend columns are
    // stable whatever subsystems actually ran.
    let reg = standard_registry();
    reg.counter("rebuilt_atoms").set(rebuilt_atoms);
    reg.counter("rebuilt_bytes").set(rebuilt_bytes);
    reg.counter("compaction_runs").set(store.compaction_runs());
    reg.counter("compaction_reclaimed_bytes").set(store.compaction_reclaimed_bytes());
    reg.counter("repaired_records").set(store.repaired_records());
    reg.counter("repaired_bytes").set(store.repaired_bytes());
    reg.counter("skipped_atoms").set(skipped_atoms);
    reg.counter("skipped_bytes").set(skipped_bytes);
    reg.counter("backpressure_stalls").set(backpressure_stalls);
    reg.counter("degraded_records").set(store.degraded_records());
    reg.counter("fence_fsyncs").set(store.total_fsyncs());
    reg.counter("segments_compacted").set(store.segments_compacted());
    reg.counter("compact_pass_bytes").set(store.compact_pass_bytes());
    if fences > 0 {
        reg.gauge("fsyncs_per_fence").set(store.total_fsyncs() as f64 / fences as f64);
        reg.gauge("fence_wall_ms").set(fence_wall_ms);
    }
    if let Some(ctl) = &ctl {
        reg.counter("policy_switches").set(ctl.switches());
        reg.counter("interval_chosen").set(final_interval as u64);
        reg.gauge("policy_regret").set(ctl.regret_per_iter(total));
    }
    Ok(TrialResult {
        iteration_cost: total as f64 - traj.converged_iters as f64 + dump_cost,
        censored,
        recovery: report,
        rebuilt_atoms,
        rebuilt_bytes,
        compaction_runs: store.compaction_runs(),
        compaction_reclaimed_bytes: store.compaction_reclaimed_bytes(),
        repaired_records: store.repaired_records(),
        repaired_bytes: store.repaired_bytes(),
        skipped_atoms,
        skipped_bytes,
        metrics: reg.snapshot(),
    })
}

// ---------------------------------------------------------------------------
// Direct perturbation trials (Fig 3, 5, 6)
// ---------------------------------------------------------------------------

/// Perturbation generators from §5.2.
#[derive(Debug, Clone, Copy)]
pub enum Perturb {
    /// Gaussian direction scaled to exactly `norm`.
    Random { norm: f64 },
    /// Directly away from x* (opposite the direction of convergence),
    /// scaled to `norm`.
    Adversarial { norm: f64 },
    /// Reset a uniformly-random `fraction` of atoms to their initial
    /// values (the partial-recovery-shaped perturbation of Fig 6).
    ResetFraction { fraction: f64 },
}

/// Apply a perturbation to `state` (at trajectory iteration `iter`).
/// Returns ‖δ‖.
pub fn apply_perturbation(
    state: &mut ParamStore,
    traj: &Trajectory,
    layout: &crate::params::AtomLayout,
    kind: Perturb,
    rng: &mut Rng,
) -> f64 {
    match kind {
        Perturb::Random { norm } => {
            let mut dirs: Vec<Vec<f32>> = state
                .tensors
                .iter()
                .map(|t| t.data.iter().map(|_| rng.normal() as f32).collect())
                .collect();
            let total: f64 = dirs
                .iter()
                .flat_map(|v| v.iter())
                .map(|&x| (x as f64) * (x as f64))
                .sum::<f64>()
                .sqrt()
                .max(1e-12);
            let scale = (norm / total) as f32;
            for (t, d) in state.tensors.iter_mut().zip(dirs.iter_mut()) {
                for (x, dx) in t.data.iter_mut().zip(d.iter()) {
                    *x += dx * scale;
                }
            }
            norm
        }
        Perturb::Adversarial { norm } => {
            let xstar = traj.x_star();
            let mut total = 0.0f64;
            for (t, s) in state.tensors.iter().zip(&xstar.tensors) {
                for (x, opt) in t.data.iter().zip(&s.data) {
                    let d = (*x - *opt) as f64;
                    total += d * d;
                }
            }
            let total = total.sqrt().max(1e-12);
            let scale = (norm / total) as f32;
            for (t, s) in state.tensors.iter_mut().zip(&xstar.tensors) {
                for (x, opt) in t.data.iter_mut().zip(&s.data) {
                    *x += (*x - *opt) * scale;
                }
            }
            norm
        }
        Perturb::ResetFraction { fraction } => {
            let n = layout.n_atoms();
            let k = ((n as f64 * fraction).round() as usize).clamp(1, n);
            let lost = rng.sample_indices(n, k);
            let before = state.clone();
            let init = traj.state_at(0);
            let mut buf = Vec::new();
            for &a in &lost {
                init.read_atom(layout, a, &mut buf);
                state.write_atom(layout, a, &buf);
            }
            state.l2_distance(&before)
        }
    }
}

/// Run one direct-perturbation trial at iteration `iter`; returns
/// (‖δ‖, iteration cost, censored).
pub fn run_perturbation_trial(
    trainer: &mut dyn Trainer,
    traj: &Trajectory,
    iter: usize,
    kind: Perturb,
    trial_seed: u64,
) -> Result<(f64, f64, bool)> {
    let mut rng = Rng::new(trial_seed);
    let mut state = traj.state_at(iter).clone();
    let layout = trainer.layout().clone();
    let delta = apply_perturbation(&mut state, traj, &layout, kind, &mut rng);
    let cap = default_cap(traj);
    trainer.init(traj.seed)?;
    let total = continue_from(trainer, state, iter, traj.threshold, cap)?;
    let (total, censored) = match total {
        Some(t) => (t, false),
        None => (cap, true),
    };
    Ok((delta, total as f64 - traj.converged_iters as f64, censored))
}

// ---------------------------------------------------------------------------
// Reporting
// ---------------------------------------------------------------------------

/// Aggregate of one sweep cell (e.g. "partial recovery, 1/2 lost").
#[derive(Debug, Clone)]
pub struct Cell {
    pub label: String,
    pub costs: Vec<f64>,
    pub summary: Summary,
    pub censored: usize,
}

impl Cell {
    pub fn new(label: impl Into<String>, costs: Vec<f64>, censored: usize) -> Cell {
        let summary = summarize(&costs);
        Cell { label: label.into(), costs, summary, censored }
    }
}

/// Render cells as an aligned table (paper-style rows).
pub fn render_table(title: &str, cells: &[Cell]) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    out.push_str(&format!(
        "{:<38} {:>8} {:>10} {:>10} {:>9}\n",
        "cell", "n", "mean", "ci95", "censored"
    ));
    for c in cells {
        out.push_str(&format!(
            "{:<38} {:>8} {:>10.2} {:>10.2} {:>9}\n",
            c.label, c.summary.n, c.summary.mean, c.summary.ci95, c.censored
        ));
    }
    out
}

/// Write a CSV of per-trial costs for external plotting; one column per
/// cell, rows are trials.
pub fn write_csv(path: &std::path::Path, cells: &[Cell]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut rows = String::new();
    let header: Vec<&str> = cells.iter().map(|c| c.label.as_str()).collect();
    rows.push_str(&header.join(","));
    rows.push('\n');
    let max_len = cells.iter().map(|c| c.costs.len()).max().unwrap_or(0);
    for i in 0..max_len {
        let row: Vec<String> = cells
            .iter()
            .map(|c| c.costs.get(i).map(|v| format!("{v}")).unwrap_or_default())
            .collect();
        rows.push_str(&row.join(","));
        rows.push('\n');
    }
    std::fs::write(path, rows)?;
    Ok(())
}

/// Per-series key/value results (for EXPERIMENTS.md extraction).
pub fn render_kv(title: &str, kv: &BTreeMap<String, f64>) -> String {
    let mut out = format!("-- {title} --\n");
    for (k, v) in kv {
        out.push_str(&format!("{k} = {v:.4}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::Selector;
    use crate::params::{AtomLayout, Tensor};
    use crate::trainer::Trainer;

    /// Scalar-per-atom geometric decay toward zero; loss = L2 norm.
    struct Decay {
        state: ParamStore,
        layout: crate::params::AtomLayout,
        c: f32,
    }

    impl Decay {
        fn new(n: usize, c: f32) -> Decay {
            let mut t = Tensor::zeros("x", &[n, 1]);
            t.data.iter_mut().enumerate().for_each(|(i, v)| *v = 1.0 + i as f32);
            let state = ParamStore::new(vec![t]);
            let layout = AtomLayout::new(AtomLayout::rows_of(&state, "x"));
            Decay { state, layout, c }
        }
    }

    impl Trainer for Decay {
        fn name(&self) -> &str {
            "decay"
        }

        fn init(&mut self, _seed: u64) -> anyhow::Result<()> {
            let n = self.state.get("x").len();
            self.state
                .get_mut("x")
                .data
                .iter_mut()
                .enumerate()
                .for_each(|(i, v)| *v = 1.0 + (i % n) as f32);
            Ok(())
        }

        fn step(&mut self, _iter: usize) -> anyhow::Result<f64> {
            let mut norm = 0.0f64;
            for v in self.state.get_mut("x").data.iter_mut() {
                *v *= self.c;
                norm += (*v as f64) * (*v as f64);
            }
            Ok(norm.sqrt())
        }

        fn state(&self) -> &ParamStore {
            &self.state
        }

        fn state_mut(&mut self) -> &mut ParamStore {
            &mut self.state
        }

        fn layout(&self) -> &crate::params::AtomLayout {
            &self.layout
        }
    }

    #[test]
    fn trajectory_threshold_is_target_loss() {
        let mut t = Decay::new(8, 0.9);
        let traj = run_trajectory(&mut t, 0, 50, 20).unwrap();
        assert_eq!(traj.converged_iters, 20);
        assert!((traj.threshold - traj.losses[19]).abs() < 1e-12);
    }

    #[test]
    fn continue_from_converges_and_caps() {
        let mut t = Decay::new(8, 0.9);
        let traj = run_trajectory(&mut t, 0, 50, 20).unwrap();
        // Resuming from the state at iter 10 must take ~10 more iters.
        let total = continue_from(&mut t, traj.state_at(10).clone(), 10, traj.threshold, 100)
            .unwrap()
            .unwrap();
        assert_eq!(total, 20);
        // Impossible threshold: censored.
        let capped =
            continue_from(&mut t, traj.state_at(0).clone(), 0, -1.0, 15).unwrap();
        assert!(capped.is_none());
    }

    #[test]
    fn replay_checkpoints_tracks_policy() {
        let mut t = Decay::new(6, 0.8);
        let traj = run_trajectory(&mut t, 0, 30, 15).unwrap();
        let policy = CheckpointPolicy::partial(4, 2, Selector::RoundRobin);
        let (coord, store) = replay_checkpoints(&traj, &t, policy, 9, 1).unwrap();
        // Barriers at 2,4,6,8 -> every atom refreshed at least once.
        for a in 0..6 {
            assert!(coord.saved_iter(a) > 0, "atom {a}");
        }
        use crate::storage::CheckpointStore;
        assert!(store.bytes_written() > 0);
    }

    #[test]
    fn run_trial_zero_cost_when_checkpoint_fresh() {
        let mut t = Decay::new(6, 0.8);
        let traj = run_trajectory(&mut t, 0, 40, 15).unwrap();
        // Failure lands exactly on a checkpoint iteration: δ = 0, cost 0.
        let spec = TrialSpec {
            policy: CheckpointPolicy::full(5),
            mode: RecoveryMode::Partial,
            fail_iter: 5,
            lost_atoms: vec![0, 1, 2],
            };
        let r = run_trial(&mut t, &traj, &spec, 3).unwrap();
        assert_eq!(r.recovery.delta_norm, 0.0);
        assert_eq!(r.iteration_cost, 0.0);
    }

    #[test]
    fn plan_trial_with_single_event_matches_run_trial() {
        let mut t = Decay::new(8, 0.85);
        let traj = run_trajectory(&mut t, 0, 60, 25).unwrap();
        let spec = TrialSpec {
            policy: CheckpointPolicy::full(7),
            mode: RecoveryMode::Partial,
            fail_iter: 12,
            lost_atoms: vec![1, 4, 6],
        };
        let single = run_trial(&mut t, &traj, &spec, 9).unwrap();
        let ev = crate::failure::FailureEvent {
            iter: 12,
            lost_atoms: vec![1, 4, 6],
            failed_nodes: vec![],
        };
        let plan =
            run_plan_trial(&mut t, &traj, spec.policy, spec.mode, &[ev], 9).unwrap();
        assert_eq!(plan.iteration_cost, single.iteration_cost);
        assert_eq!(plan.censored, single.censored);
        assert!((plan.recovery.delta_norm - single.recovery.delta_norm).abs() < 1e-12);
        assert_eq!(plan.recovery.atoms_restored, single.recovery.atoms_restored);
    }

    #[test]
    fn plan_trial_applies_cascading_events() {
        let mut t = Decay::new(8, 0.85);
        let traj = run_trajectory(&mut t, 0, 60, 25).unwrap();
        let mk = |iter: usize| crate::failure::FailureEvent {
            iter,
            lost_atoms: vec![0, 2, 5],
            failed_nodes: vec![],
        };
        let one = run_plan_trial(
            &mut t,
            &traj,
            CheckpointPolicy::full(7),
            RecoveryMode::Partial,
            &[mk(10)],
            3,
        )
        .unwrap();
        let three = run_plan_trial(
            &mut t,
            &traj,
            CheckpointPolicy::full(7),
            RecoveryMode::Partial,
            &[mk(10), mk(15), mk(20)],
            3,
        )
        .unwrap();
        assert_eq!(three.recovery.atoms_restored, 9);
        // A cascade can only slow convergence down relative to one event.
        assert!(three.iteration_cost >= one.iteration_cost);
        assert!(three.recovery.delta_norm >= one.recovery.delta_norm);
    }

    #[test]
    fn plan_trial_async_matches_sync_byte_for_byte() {
        let mut t = Decay::new(8, 0.85);
        let traj = run_trajectory(&mut t, 0, 60, 25).unwrap();
        let mk = |iter: usize| crate::failure::FailureEvent {
            iter,
            lost_atoms: vec![0, 3, 5],
            failed_nodes: vec![],
        };
        let events = [mk(9), mk(14)];
        let policy = CheckpointPolicy::partial(6, 3, Selector::Priority);
        let sync = run_plan_trial_with(
            &mut t,
            &traj,
            &CheckpointSetup::sync(policy),
            RecoveryMode::Partial,
            &events,
            5,
        )
        .unwrap();
        let pipelined = CheckpointSetup::new(policy, CheckpointMode::Async, 3, 2);
        let asynced = run_plan_trial_with(
            &mut t,
            &traj,
            &pipelined,
            RecoveryMode::Partial,
            &events,
            5,
        )
        .unwrap();
        assert_eq!(sync.iteration_cost, asynced.iteration_cost);
        assert_eq!(sync.censored, asynced.censored);
        assert_eq!(sync.recovery.atoms_restored, asynced.recovery.atoms_restored);
        assert_eq!(sync.recovery.delta_norm, asynced.recovery.delta_norm);
    }

    #[test]
    fn adaptive_with_zero_window_matches_static() {
        let mut t = Decay::new(8, 0.85);
        let traj = run_trajectory(&mut t, 0, 60, 25).unwrap();
        let events = [crate::failure::FailureEvent {
            iter: 9,
            lost_atoms: vec![0, 3, 5],
            failed_nodes: vec![],
        }];
        let policy = CheckpointPolicy::partial(6, 3, Selector::Priority);
        let fixed = run_plan_trial_with(
            &mut t,
            &traj,
            &CheckpointSetup::sync(policy),
            RecoveryMode::Partial,
            &events,
            5,
        )
        .unwrap();
        // window = 0 disables the controller: the adaptive plumbing must
        // be a pure pass-through.
        let mut setup = CheckpointSetup::sync(policy);
        setup.adaptive =
            Some(crate::policy::PolicyConfig { window: 0, ..Default::default() });
        let adaptive =
            run_plan_trial_with(&mut t, &traj, &setup, RecoveryMode::Partial, &events, 5)
                .unwrap();
        assert_eq!(fixed.iteration_cost, adaptive.iteration_cost);
        assert_eq!(fixed.censored, adaptive.censored);
        assert_eq!(fixed.recovery.delta_norm, adaptive.recovery.delta_norm);
        assert_eq!(adaptive.metrics["policy_switches"], 0.0);
    }

    #[test]
    fn dump_cost_prices_checkpoint_bandwidth_into_cost() {
        let mut t = Decay::new(8, 0.85);
        let traj = run_trajectory(&mut t, 0, 60, 25).unwrap();
        let events = [crate::failure::FailureEvent {
            iter: 9,
            lost_atoms: vec![0, 3, 5],
            failed_nodes: vec![],
        }];
        let policy = CheckpointPolicy::full(4);
        let free = run_plan_trial_with(
            &mut t,
            &traj,
            &CheckpointSetup::sync(policy),
            RecoveryMode::Partial,
            &events,
            5,
        )
        .unwrap();
        let mut priced = CheckpointSetup::sync(policy);
        priced.dump_cost_iters = 3.0;
        let charged =
            run_plan_trial_with(&mut t, &traj, &priced, RecoveryMode::Partial, &events, 5)
                .unwrap();
        // Decay moves every atom every iteration, so barriers write real
        // bytes and the priced run must cost strictly more.
        assert!(charged.iteration_cost > free.iteration_cost);
    }

    #[test]
    fn csv_writer_emits_ragged_columns() {
        let cells = vec![
            Cell::new("a", vec![1.0, 2.0], 0),
            Cell::new("b", vec![3.0], 1),
        ];
        let dir = std::env::temp_dir().join(format!("scar-csv-{}", std::process::id()));
        let path = dir.join("t.csv");
        write_csv(&path, &cells).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("a,b\n1,3\n2,"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn render_table_contains_cells() {
        let cells = vec![Cell::new("hello", vec![1.0, 3.0], 2)];
        let s = render_table("T", &cells);
        assert!(s.contains("hello"));
        assert!(s.contains("T"));
    }
}
