//! Scenario execution: trajectory once, trials fanned out over a worker
//! pool.
//!
//! Determinism contract: a sweep's results are a pure function of the
//! [`Scenario`] — every trial's randomness (failure events, perturbation
//! norms, checkpoint selection) is derived from `(scenario seed, cell
//! index, trial index)` *before* the pool starts, and results land in
//! per-trial slots, so the report is byte-identical whatever the worker
//! count or scheduling order. `parallel_sweep_matches_serial_byte_for_byte`
//! in `rust/tests/scenario.rs` pins this.
//!
//! Data flow (see `docs/ARCHITECTURE.md` for the long-form version):
//!
//! ```text
//! Scenario ──▶ run_panel (per model panel)
//!               ├─ build trainer, run unperturbed Trajectory (serial)
//!               ├─ estimate (c, ‖x0−x*‖) for Theorem 3.2 bounds
//!               ├─ expand cells × trials into Jobs (all rng here)
//!               ├─ worker pool: each worker owns a trainer, pulls jobs,
//!               │   replays the Trajectory suffix per trial
//!               └─ aggregate per-cell CellReports (trial order)
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::advisor::OnlineRateEstimator;
use crate::cluster::{run_cluster_training, ClusterJob, Detect};
use crate::failure::{FailureEvent, FailureInjector, FailurePlan};
use crate::harness::{self, CheckpointSetup, Perturb, Trajectory};
use crate::models::presets::{build_preset, try_preset, PresetKind};
use crate::models::synthetic::SyntheticTrainer;
use crate::obs::{merge_metrics, standard_registry, Recorder};
use crate::recovery::RecoveryMode;
use crate::runtime::Engine;
use crate::theory::{self, Perturbation};
use crate::trainer::Trainer;
use crate::util::cli::Args;
use crate::util::rng::Rng;
use crate::util::stats::{summarize, Summary};

use super::spec::{CellAction, DeployMode, NormSpec, PerturbSpec, PolicyMode, Scenario};

/// Dataset seed shared with the `examples/fig*.rs` drivers.
const DATA_SEED: u64 = 1234;

/// Aggregated results of one (panel, cell): per-trial vectors in trial
/// order plus the summary statistics.
#[derive(Debug, Clone)]
pub struct CellReport {
    pub label: String,
    /// Iteration cost per trial (censored trials at the cap).
    pub costs: Vec<f64>,
    /// Perturbation size ‖δ‖ per trial.
    pub deltas: Vec<f64>,
    /// Theorem 3.2 bound per trial (NaN for failure cells and when `c`
    /// could not be estimated).
    pub bounds: Vec<f64>,
    /// Per-trial censoring flags (cost reported at the cap).
    pub censored_trials: Vec<bool>,
    pub censored: usize,
    pub summary: Summary,
    /// Standard metric counters ([`crate::obs::STANDARD_COUNTERS`] —
    /// selective rebuilds, compaction, parity repairs, delta-skip
    /// savings, back-pressure stalls, degraded routing), summed over
    /// trials from each trial's registry snapshot. Not part of the
    /// rendered report — the trend/metrics surface.
    pub metrics: BTreeMap<String, f64>,
}

impl CellReport {
    /// Trials whose cost lands within the (ceiled) Thm 3.2 bound, if
    /// bounds were computed.
    pub fn within_bound(&self) -> Option<usize> {
        if self.bounds.iter().all(|b| b.is_nan()) {
            return None;
        }
        Some(
            self.costs
                .iter()
                .zip(&self.bounds)
                .filter(|(c, b)| b.is_finite() && **c <= b.ceil())
                .count(),
        )
    }
}

/// One model panel's sweep results.
#[derive(Debug, Clone)]
pub struct PanelReport {
    pub panel: String,
    pub converged_iters: usize,
    pub threshold: f64,
    /// Empirical contraction rate (NaN when not estimable).
    pub c: f64,
    /// Effective ‖x⁽⁰⁾ − x*‖ used for norm scaling and bounds.
    pub x0: f64,
    pub cells: Vec<CellReport>,
}

/// Full scenario results; [`render`](ScenarioReport::render) and
/// [`to_csv`](ScenarioReport::to_csv) are deterministic byte-for-byte.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    pub scenario: String,
    pub panels: Vec<PanelReport>,
}

impl ScenarioReport {
    /// Paper-style summary tables, one per panel.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for p in &self.panels {
            out.push_str(&format!("== scenario '{}' · panel {} ==\n", self.scenario, p.panel));
            out.push_str(&format!(
                "unperturbed: {} iters to ε={:.6}; c={:.5}, ‖x0−x*‖={:.4}\n",
                p.converged_iters, p.threshold, p.c, p.x0
            ));
            out.push_str(&format!(
                "{:<34} {:>4} {:>10} {:>8} {:>9} {:>10} {:>9}\n",
                "cell", "n", "mean", "ci95", "censored", "mean ‖δ‖", "in-bound"
            ));
            for c in &p.cells {
                let mean_delta = if c.deltas.is_empty() {
                    f64::NAN
                } else {
                    c.deltas.iter().sum::<f64>() / c.deltas.len() as f64
                };
                let within = match c.within_bound() {
                    Some(w) => format!("{w}/{}", c.costs.len()),
                    None => "-".to_string(),
                };
                out.push_str(&format!(
                    "{:<34} {:>4} {:>10.2} {:>8.2} {:>9} {:>10.4} {:>9}\n",
                    c.label, c.summary.n, c.summary.mean, c.summary.ci95, c.censored,
                    mean_delta, within
                ));
            }
            out.push('\n');
        }
        out
    }

    /// Aggregate counters for the nightly trend artifact (`scar trend`):
    /// selective-rebuild and compaction totals summed over every (panel,
    /// cell, trial). Deliberately *not* part of [`render`] /
    /// [`to_csv`] — those are pinned byte-identical across storage
    /// configurations, while these counters legitimately vary with the
    /// fault plan (that variation is the thing the trend tracks).
    ///
    /// [`render`]: ScenarioReport::render
    /// [`to_csv`]: ScenarioReport::to_csv
    pub fn metrics(&self) -> BTreeMap<String, f64> {
        // Start from the standard registry's zeroed snapshot so every
        // standard counter is present (key-set stability is what the
        // trend CSV's append-only columns rely on), then fold in each
        // cell's summed trial snapshots.
        let mut m = standard_registry().snapshot();
        for p in &self.panels {
            for c in &p.cells {
                merge_metrics(&mut m, &c.metrics);
            }
        }
        m
    }

    /// Per-trial CSV (`scenario,panel,cell,trial,cost,delta,bound,censored`).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("scenario,panel,cell,trial,cost,delta,bound,censored\n");
        for p in &self.panels {
            for c in &p.cells {
                for i in 0..c.costs.len() {
                    out.push_str(&format!(
                        "{},{},{},{},{},{},{},{}\n",
                        csv_field(&self.scenario),
                        csv_field(&p.panel),
                        csv_field(&c.label),
                        i,
                        c.costs[i],
                        c.deltas[i],
                        c.bounds[i],
                        c.censored_trials[i] as u8
                    ));
                }
            }
        }
        out
    }
}

/// Quote a free-form CSV field when it would break the row structure.
fn csv_field(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Apply the standard scenario CLI overrides (`--trials`, `--seed`,
/// `--workers`, `--output`, `--panels`, `--checkpoint-dir`, `--backend`,
/// `--trace-dir`) and re-validate — shared by `scar run-scenario` and
/// the fig example wrappers.
pub fn apply_cli_overrides(scn: &mut Scenario, args: &Args) -> Result<()> {
    if let Some(t) = args.str_opt("trials") {
        scn.trials = t.parse().context("--trials expects an integer")?;
    }
    if let Some(s) = args.str_opt("seed") {
        scn.seed = s.parse().context("--seed expects an integer")?;
    }
    if let Some(w) = args.str_opt("workers") {
        scn.workers = w.parse().context("--workers expects an integer")?;
    }
    if let Some(o) = args.str_opt("output") {
        scn.output = Some(o.to_string());
    }
    if let Some(csv) = args.str_opt("panels") {
        scn.panels = csv.split(',').map(|s| s.trim().to_string()).collect();
    }
    if let Some(dir) = args.str_opt("checkpoint-dir") {
        scn.checkpoint_dir = Some(dir.to_string());
    }
    // `--trace-dir` switches the flight recorder on for every trial
    // without editing the scenario file.
    if let Some(dir) = args.str_opt("trace-dir") {
        scn.trace_dir = Some(dir.to_string());
    }
    // `--backend mem|disk` flips the storage tier of any scenario — the
    // CI backend matrix runs one scenario file both ways and diffs the
    // (byte-identical) reports.
    if let Some(backend) = args.str_opt("backend") {
        match backend {
            "mem" => scn.checkpoint_dir = None,
            "disk" => {
                if scn.checkpoint_dir.is_none() {
                    scn.checkpoint_dir = Some(format!("results/{}-ckpt", scn.name));
                }
            }
            other => bail!("--backend expects mem|disk, got '{other}'"),
        }
    }
    scn.validate()
}

/// Write the report CSV to the scenario's `output` path, creating parent
/// directories; returns the path written (None when no output is set).
pub fn write_output(report: &ScenarioReport, scn: &Scenario) -> Result<Option<String>> {
    let Some(out) = &scn.output else {
        return Ok(None);
    };
    let path = Path::new(out);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating output dir {}", dir.display()))?;
        }
    }
    std::fs::write(path, report.to_csv())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(Some(out.clone()))
}

/// Locate a bundled scenario file whether the process runs from the repo
/// root (examples via `cargo run` configured there) or from `rust/`
/// (cargo's default test/working directory).
pub fn find_bundled(rel: &str) -> PathBuf {
    let direct = PathBuf::from(rel);
    if direct.exists() {
        return direct;
    }
    let up = Path::new("..").join(rel);
    if up.exists() {
        return up;
    }
    direct
}

/// Run a scenario, creating the default PJRT engine only if some panel is
/// artifact-backed (LDA and synthetic panels never touch PJRT).
pub fn run_with_default_engine(scn: &Scenario) -> Result<ScenarioReport> {
    let needs_engine = scn
        .panels
        .iter()
        .any(|p| panel_needs_engine(p).unwrap_or(true));
    let engine = if needs_engine {
        Some(crate::models::default_engine()?)
    } else {
        None
    };
    run_scenario(scn, engine)
}

/// Run a scenario against an explicit (optional) engine.
pub fn run_scenario(
    scn: &Scenario,
    engine: Option<Arc<Mutex<Engine>>>,
) -> Result<ScenarioReport> {
    scn.validate()?;
    let mut panels = Vec::with_capacity(scn.panels.len());
    for (pi, panel) in scn.panels.iter().enumerate() {
        panels.push(
            run_panel(scn, pi, panel, engine.as_ref())
                .with_context(|| format!("scenario '{}', panel '{panel}'", scn.name))?,
        );
    }
    Ok(ScenarioReport { scenario: scn.name.clone(), panels })
}

/// Does this panel require the PJRT engine?
fn panel_needs_engine(panel: &str) -> Result<bool> {
    if panel.starts_with("synthetic") {
        return Ok(false);
    }
    match try_preset(panel) {
        Some(p) => Ok(matches!(p.kind, PresetKind::Hlo { .. })),
        None => bail!(
            "unknown model '{panel}' (expected a preset name or 'synthetic[:dim=..,c=..]')"
        ),
    }
}

fn build_panel_trainer(
    panel: &str,
    engine: Option<&Arc<Mutex<Engine>>>,
    data_seed: u64,
) -> Result<Box<dyn Trainer + Send>> {
    if panel.starts_with("synthetic") {
        return Ok(Box::new(SyntheticTrainer::from_spec(panel)?));
    }
    let p = try_preset(panel).with_context(|| {
        format!("unknown model '{panel}' (expected a preset name or 'synthetic[:dim=..,c=..]')")
    })?;
    match p.kind {
        PresetKind::Hlo { .. } => {
            let engine = engine
                .with_context(|| format!("panel '{panel}' needs a PJRT engine"))?;
            build_preset(Some(engine.clone()), &p, data_seed)
        }
        PresetKind::Lda { .. } => build_preset(None, &p, data_seed),
    }
}

/// (target_iters, max_iters) for a panel, honoring scenario overrides.
fn horizons(scn: &Scenario, panel: &str) -> Result<(usize, usize)> {
    let (dt, dm) = if panel.starts_with("synthetic") {
        (60, 100)
    } else {
        match try_preset(panel) {
            Some(p) => (p.target_iters, p.max_iters),
            None => (60, 100),
        }
    };
    let target = scn.target_iters.unwrap_or(dt);
    let max = scn.max_iters.unwrap_or(dm.max(target));
    if target == 0 || target > max {
        bail!("need 1 <= target_iters={target} <= max_iters={max}");
    }
    Ok((target, max))
}

/// Empirical (c, ‖x0−x*‖) for Theorem 3.2, with the fig6 likelihood-curve
/// fallback for workloads (LDA) whose state has no L2 contraction.
fn panel_theory(traj: &Trajectory) -> (f64, f64) {
    let xstar = traj.x_star();
    let errors: Vec<f64> = traj
        .snapshots
        .iter()
        .take(traj.converged_iters)
        .map(|s| s.l2_distance(xstar))
        .collect();
    if errors.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let floor = errors[traj.converged_iters - 1] * 1.05;
    let mut c = theory::estimate_rate_conservative(&errors, floor);
    if !c.is_finite() {
        let mut est = OnlineRateEstimator::default();
        for &l in &traj.losses[..traj.converged_iters] {
            est.observe(l);
        }
        c = est.rate().unwrap_or(f64::NAN);
    }
    let (amp, _) = theory::estimate_slow_mode(&errors, floor);
    let x0 = if amp.is_finite() { amp.min(errors[0]) } else { errors[0] };
    (c, x0)
}

/// One unit of work: everything random already resolved.
#[derive(Debug, Clone)]
enum JobKind {
    Perturb { kind: Perturb, at_iter: usize },
    Plan { setup: CheckpointSetup, mode: RecoveryMode, events: Vec<FailureEvent> },
    /// `deploy = "cluster"`: a live threaded-PS run with a node-kill
    /// schedule (and the setup's storage faults, if any).
    Cluster { setup: CheckpointSetup, n_nodes: usize, kills: Vec<(usize, usize)> },
}

#[derive(Debug, Clone)]
struct Job {
    kind: JobKind,
    seed: u64,
}

#[derive(Debug, Clone)]
struct Outcome {
    cost: f64,
    delta: f64,
    censored: bool,
    /// Standard-counter registry snapshot for this trial.
    metrics: BTreeMap<String, f64>,
}

fn job_rng(scn_seed: u64, cell: usize, trial: usize) -> Rng {
    Rng::new(scn_seed ^ 0x5CE7_A110).derive(((cell as u64) << 32) | trial as u64)
}

fn job_seed(scn_seed: u64, cell: usize, trial: usize) -> u64 {
    scn_seed
        ^ (cell as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (trial as u64 + 1).wrapping_mul(0xD1B5_4A32_D192_ED03)
}

/// Expand cells × trials into jobs, drawing all per-trial randomness in
/// the caller's (deterministic, serial) context. `panel_idx` keys each
/// disk-backed trial's private shard directory under the scenario's
/// `checkpoint_dir`.
fn build_jobs(
    scn: &Scenario,
    panel_idx: usize,
    traj: &Trajectory,
    n_atoms: usize,
    x0: f64,
) -> Vec<Job> {
    let default_pert_iter = scn
        .perturb_iter
        .unwrap_or_else(|| 50.min(traj.converged_iters.saturating_sub(5)).max(1));
    let pert_iter = default_pert_iter.min(traj.max_iters().saturating_sub(1)).max(1);
    let inj = FailureInjector::new(
        scn.fail_geom_p,
        traj.converged_iters.saturating_sub(2).max(2),
    );
    let mut jobs = Vec::with_capacity(scn.cells.len() * scn.trials);
    for (ci, cell) in scn.cells.iter().enumerate() {
        for trial in 0..scn.trials {
            let mut rng = job_rng(scn.seed, ci, trial);
            let kind = match &cell.action {
                CellAction::Perturb(p) => {
                    let resolve = |norm: &NormSpec, rng: &mut Rng| match norm {
                        NormSpec::Rel(r) => r * x0,
                        NormSpec::LogUniform { lo, hi } => {
                            10f64.powf(rng.range_f64(*lo, *hi)) * x0
                        }
                    };
                    let kind = match p {
                        PerturbSpec::Random { norm } => {
                            Perturb::Random { norm: resolve(norm, &mut rng) }
                        }
                        PerturbSpec::Adversarial { norm } => {
                            Perturb::Adversarial { norm: resolve(norm, &mut rng) }
                        }
                        PerturbSpec::Reset { fraction } => {
                            Perturb::ResetFraction { fraction: *fraction }
                        }
                    };
                    JobKind::Perturb { kind, at_iter: pert_iter }
                }
                CellAction::Fail(plan) => {
                    let ckpt = cell.checkpoint.unwrap_or(scn.checkpoint);
                    let setup = CheckpointSetup {
                        policy: ckpt.policy(),
                        mode: ckpt.mode,
                        shards: scn.storage.shards,
                        writers: scn.storage.writers,
                        max_pending: scn.storage.max_pending,
                        chaos: scn.chaos.clone(),
                        // Disk-backed sweeps: trials run in parallel, so
                        // each gets its own shard directory.
                        checkpoint_dir: scn.checkpoint_dir.as_ref().map(|d| {
                            Path::new(d).join(format!("p{panel_idx}-c{ci}-t{trial}"))
                        }),
                        // `[obs] trace_dir`: one JSONL trace per trial,
                        // keyed like the shard directories.
                        trace_path: scn.trace_dir.as_ref().map(|d| {
                            Path::new(d).join(format!("p{panel_idx}-c{ci}-t{trial}.jsonl"))
                        }),
                        parity: scn.storage.parity,
                        scrub_interval: scn.storage.scrub_interval,
                        compact_threshold: scn.storage.compact_threshold,
                        compact_min_bytes: scn.storage.compact_min_bytes as u64,
                        compact_max_pass_bytes: scn.storage.compact_max_bytes_per_pass as u64,
                        group_commit: scn.storage.group_commit,
                        // Checkpoint bandwidth is priced into every
                        // cell's cost so adaptive-vs-static comparisons
                        // charge both sides the same way.
                        dump_cost_iters: scn.advisor.dump_cost_iters,
                        adaptive: (cell.policy.unwrap_or(scn.policy) == PolicyMode::Adaptive)
                            .then(|| scn.advisor.config(ckpt.interval)),
                    };
                    match scn.deploy {
                        DeployMode::Harness => {
                            let events = plan.sample_events(&inj, n_atoms, &mut rng);
                            JobKind::Plan {
                                setup,
                                mode: cell.mode.unwrap_or(scn.recovery),
                                events,
                            }
                        }
                        DeployMode::Cluster => {
                            let cap = harness::default_cap(traj);
                            let kills =
                                sample_cluster_kills(plan, scn.ps_nodes, &inj, &mut rng, cap);
                            JobKind::Cluster { setup, n_nodes: scn.ps_nodes, kills }
                        }
                    }
                }
            };
            jobs.push(Job { kind, seed: job_seed(scn.seed, ci, trial) });
        }
    }
    jobs
}

/// Map a failure plan onto a deterministic node-kill schedule for the
/// threaded-PS path. The lost *fraction* becomes a node count (clamped to
/// keep a survivor); cascades kill one further not-yet-dead node per
/// step, with follow-ups past the trial cap dropped. All randomness comes
/// from the caller's per-trial stream, so the schedule is a pure function
/// of (seed, cell, trial).
fn sample_cluster_kills(
    plan: &FailurePlan,
    n_nodes: usize,
    inj: &FailureInjector,
    rng: &mut Rng,
    cap: usize,
) -> Vec<(usize, usize)> {
    let node_count = |fraction: f64| -> usize {
        ((n_nodes as f64 * fraction).round() as usize).clamp(1, n_nodes.saturating_sub(1))
    };
    match plan {
        FailurePlan::Single { fraction } => {
            let iter = inj.sample_iter(rng);
            let mut nodes = rng.sample_indices(n_nodes, node_count(*fraction));
            nodes.sort_unstable();
            nodes.into_iter().map(|nd| (iter, nd)).collect()
        }
        FailurePlan::Correlated { nodes, .. } => {
            // `of_nodes` is a harness-path concept (it sizes a synthetic
            // partition); on the cluster the real `ps_nodes` governs.
            let iter = inj.sample_iter(rng);
            let k = (*nodes).clamp(1, n_nodes.saturating_sub(1));
            let mut picked = rng.sample_indices(n_nodes, k);
            picked.sort_unstable();
            picked.into_iter().map(|nd| (iter, nd)).collect()
        }
        FailurePlan::Cascade { fraction, extra, gap } => {
            let first_iter = inj.sample_iter(rng);
            let mut nodes = rng.sample_indices(n_nodes, node_count(*fraction));
            nodes.sort_unstable();
            let mut killed = vec![false; n_nodes];
            for &nd in &nodes {
                killed[nd] = true;
            }
            let mut kills: Vec<(usize, usize)> =
                nodes.into_iter().map(|nd| (first_iter, nd)).collect();
            for i in 1..=*extra {
                let alive: Vec<usize> = (0..n_nodes).filter(|&nd| !killed[nd]).collect();
                if alive.len() <= 1 {
                    break; // always leave a survivor
                }
                let pick = alive[rng.sample_indices(alive.len(), 1)[0]];
                killed[pick] = true;
                let iter = first_iter + i * gap;
                if iter < cap {
                    kills.push((iter, pick));
                }
            }
            kills
        }
        // Rejected by Scenario::validate — PS nodes are never revived.
        FailurePlan::Flaky { .. } => {
            unreachable!("flaky plans are rejected for deploy = \"cluster\"")
        }
    }
}

/// Run one `deploy = "cluster"` trial: a live threaded-PS training run
/// from the trajectory's seed, with deterministic (immediate) failure
/// detection and the trial's chaos-wrapped store. The iteration cost is
/// measured against the same ε as the harness path.
fn run_cluster_job(
    trainer: &mut dyn Trainer,
    traj: &Trajectory,
    setup: &CheckpointSetup,
    n_nodes: usize,
    kills: &[(usize, usize)],
) -> Result<Outcome> {
    let store = Arc::new(setup.build_store()?);
    let cap = harness::default_cap(traj);
    let rec = match &setup.trace_path {
        Some(_) => Recorder::enabled(),
        None => Recorder::disabled(),
    };
    let job = ClusterJob {
        n_nodes,
        iters: cap,
        policy: setup.policy,
        ckpt_mode: setup.mode,
        ckpt_writers: setup.writers,
        max_pending: setup.max_pending,
        compact_threshold: setup.compact_threshold,
        compact_min_bytes: setup.compact_min_bytes,
        compact_max_pass_bytes: setup.compact_max_pass_bytes,
        kills: kills.to_vec(),
        seed: traj.seed,
        detect: Detect::Immediate,
        stop_at_loss: Some(traj.threshold),
        recorder: rec.clone(),
        adaptive: setup.adaptive,
    };
    let report = run_cluster_training(trainer, store.clone(), &job)?;
    if let Some(path) = &setup.trace_path {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating trace dir {}", dir.display()))?;
        }
        std::fs::write(path, crate::obs::to_jsonl(&rec.drain()))
            .with_context(|| format!("writing trace {}", path.display()))?;
    }
    let total = report
        .losses
        .iter()
        .position(|&l| l <= traj.threshold)
        .map(|i| i + 1);
    let (total, censored) = match total {
        Some(t) => (t, false),
        None => (cap, true),
    };
    // Delta-skip accounting (`skipped_*`) is a harness-path surface for
    // now; the registry's zeroed defaults cover it. Parity repairs are
    // read straight off the shared store handle.
    let reg = standard_registry();
    reg.counter("rebuilt_atoms").set(report.rebuilt_atoms);
    reg.counter("rebuilt_bytes").set(report.rebuilt_bytes);
    reg.counter("compaction_runs").set(report.compaction_runs);
    reg.counter("compaction_reclaimed_bytes").set(report.compaction_reclaimed_bytes);
    reg.counter("repaired_records").set(store.repaired_records());
    reg.counter("repaired_bytes").set(store.repaired_bytes());
    reg.counter("degraded_records").set(report.degraded_records);
    reg.counter("fence_fsyncs").set(store.total_fsyncs());
    reg.counter("segments_compacted").set(store.segments_compacted());
    reg.counter("compact_pass_bytes").set(store.compact_pass_bytes());
    if setup.adaptive.is_some() {
        reg.counter("policy_switches").set(report.policy_switches);
        reg.counter("interval_chosen").set(report.final_interval as u64);
    }
    Ok(Outcome {
        cost: total as f64 - traj.converged_iters as f64,
        // ‖δ‖ is measured inside the cluster's recovery coordinator:
        // checkpoint values vs the controller's pre-recovery view of the
        // lost atoms — the cluster analogue of the harness's pre/post
        // recovery distance, feeding the same report column.
        delta: report.recovery_delta_norm,
        censored,
        metrics: reg.snapshot(),
    })
}

fn run_job(trainer: &mut dyn Trainer, traj: &Trajectory, job: &Job) -> Result<Outcome> {
    match &job.kind {
        JobKind::Perturb { kind, at_iter } => {
            let (delta, cost, censored) =
                harness::run_perturbation_trial(trainer, traj, *at_iter, *kind, job.seed)?;
            // Perturbation trials never touch storage; the zeroed
            // standard snapshot keeps every cell's key set identical.
            Ok(Outcome { cost, delta, censored, metrics: standard_registry().snapshot() })
        }
        JobKind::Plan { setup, mode, events } => {
            let r = harness::run_plan_trial_with(trainer, traj, setup, *mode, events, job.seed)?;
            Ok(Outcome {
                cost: r.iteration_cost,
                delta: r.recovery.delta_norm,
                censored: r.censored,
                metrics: r.metrics,
            })
        }
        JobKind::Cluster { setup, n_nodes, kills } => {
            run_cluster_job(trainer, traj, setup, *n_nodes, kills)
        }
    }
}

fn run_panel(
    scn: &Scenario,
    panel_idx: usize,
    panel: &str,
    engine: Option<&Arc<Mutex<Engine>>>,
) -> Result<PanelReport> {
    let mut trainer = build_panel_trainer(panel, engine, DATA_SEED)?;
    let (target, max) = horizons(scn, panel)?;
    let traj = harness::run_trajectory(trainer.as_mut(), scn.seed, max, target)?;
    let (c, x0) = panel_theory(&traj);
    let n_atoms = trainer.layout().n_atoms();
    let jobs = build_jobs(scn, panel_idx, &traj, n_atoms, x0);

    let workers = if scn.workers == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        scn.workers
    }
    .min(jobs.len())
    .max(1);

    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<Result<Outcome, String>>>> =
        Mutex::new(vec![None; jobs.len()]);
    let build_error: Mutex<Option<String>> = Mutex::new(None);
    // Worker 0 inherits the trajectory trainer; the rest build their own
    // instance inside their thread.
    let mut main_trainer = Some(trainer);

    std::thread::scope(|s| {
        for _worker in 0..workers {
            let mine = main_trainer.take();
            let (jobs, traj, next, results, build_error) =
                (&jobs, &traj, &next, &results, &build_error);
            s.spawn(move || {
                let mut owned: Box<dyn Trainer + Send> = match mine {
                    Some(t) => t,
                    None => match build_panel_trainer(panel, engine, DATA_SEED) {
                        Ok(t) => t,
                        Err(e) => {
                            let mut slot = build_error.lock().unwrap();
                            if slot.is_none() {
                                *slot = Some(format!("{e:?}"));
                            }
                            return;
                        }
                    },
                };
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= jobs.len() {
                        break;
                    }
                    let out =
                        run_job(owned.as_mut(), traj, &jobs[i]).map_err(|e| format!("{e:?}"));
                    results.lock().unwrap()[i] = Some(out);
                }
            });
        }
    });

    if let Some(e) = build_error.into_inner().unwrap() {
        // Only fatal if some job never ran (a single surviving worker
        // still completes the sweep).
        let results = results.lock().unwrap();
        if results.iter().any(|r| r.is_none()) {
            bail!("worker failed to build trainer for '{panel}': {e}");
        }
    }

    let results = results.into_inner().unwrap();
    let mut cells = Vec::with_capacity(scn.cells.len());
    for (ci, cell) in scn.cells.iter().enumerate() {
        let mut costs = Vec::with_capacity(scn.trials);
        let mut deltas = Vec::with_capacity(scn.trials);
        let mut bounds = Vec::with_capacity(scn.trials);
        let mut censored_trials = Vec::with_capacity(scn.trials);
        let mut censored = 0usize;
        let mut metrics = standard_registry().snapshot();
        for trial in 0..scn.trials {
            let idx = ci * scn.trials + trial;
            let out = results[idx]
                .as_ref()
                .with_context(|| format!("cell '{}' trial {trial} never ran", cell.label))?
                .as_ref()
                .map_err(|e| {
                    anyhow::anyhow!("cell '{}' trial {trial} failed: {e}", cell.label)
                })?;
            costs.push(out.cost);
            deltas.push(out.delta);
            censored_trials.push(out.censored);
            censored += out.censored as usize;
            merge_metrics(&mut metrics, &out.metrics);
            let bound = match &jobs[idx].kind {
                JobKind::Perturb { at_iter, .. }
                    if c.is_finite() && c > 0.0 && c < 1.0 && x0 > 0.0 =>
                {
                    theory::iteration_cost_bound(
                        c,
                        x0,
                        &[Perturbation { iter: *at_iter, norm: out.delta }],
                    )
                }
                _ => f64::NAN,
            };
            bounds.push(bound);
        }
        let summary = summarize(&costs);
        cells.push(CellReport {
            label: cell.label.clone(),
            costs,
            deltas,
            bounds,
            censored_trials,
            censored,
            summary,
            metrics,
        });
    }

    Ok(PanelReport {
        panel: panel.to_string(),
        converged_iters: traj.converged_iters,
        threshold: traj.threshold,
        c,
        x0,
        cells,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_panel_is_a_clear_error() {
        let scn = Scenario::from_toml_str(
            "name=\"t\"\nmodel=\"no_such_model\"\n[[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap();
        let e = run_scenario(&scn, None).unwrap_err();
        assert!(format!("{e:?}").contains("no_such_model"), "{e:?}");
    }

    #[test]
    fn bundled_lookup_prefers_existing() {
        // Nonexistent stays as given (callers get the original path in
        // their error message).
        assert_eq!(find_bundled("scenarios/definitely-missing.toml"),
                   PathBuf::from("scenarios/definitely-missing.toml"));
    }
}
