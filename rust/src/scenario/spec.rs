//! Declarative scenario specs: what to sweep, constructible from TOML or
//! JSON.
//!
//! A [`Scenario`] is the data-file form of one experiment: the model
//! panels, the convergence horizon, the checkpoint/recovery policy, and a
//! grid of [`CellSpec`]s, each describing either a direct perturbation
//! (Fig 3/5/6 style) or a failure plan (Fig 7/8 style, plus the richer
//! [`FailurePlan`] models). Both file formats parse into the repo's
//! [`Json`] value model first ([`super::toml`] handles TOML), so the two
//! are interchangeable and round-trip through [`Scenario::to_json`].
//!
//! Every parse error names the offending key and scenario/cell, so a typo
//! in a scenario file fails loudly instead of silently changing the
//! sweep.

use std::collections::BTreeMap;
use std::path::Path;
use std::str::FromStr;

use anyhow::{bail, Context, Result};

use crate::chaos::{FaultKind, FaultPlan, ShardFault};
use crate::checkpoint::{CheckpointMode, CheckpointPolicy, Selector};
use crate::failure::FailurePlan;
use crate::recovery::RecoveryMode;
use crate::util::json::Json;

/// Checkpoint policy in (base interval, divisor k, selector) form — the
/// paper's parametrization (fraction 1/k every interval/k iterations;
/// when k does not divide the interval, [`CheckpointPolicy::partial`]
/// adjusts the fraction so bytes-written parity holds) — plus the write
/// `mode` (`"sync"` barriers block on storage; `"async"` hands snapshots
/// to the writer pool).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CheckpointSpec {
    pub interval: usize,
    pub k: usize,
    pub selector: Selector,
    pub mode: CheckpointMode,
}

impl Default for CheckpointSpec {
    fn default() -> Self {
        CheckpointSpec {
            interval: 10,
            k: 1,
            selector: Selector::Priority,
            mode: CheckpointMode::Sync,
        }
    }
}

impl CheckpointSpec {
    pub fn policy(&self) -> CheckpointPolicy {
        CheckpointPolicy::partial(self.interval, self.k, self.selector)
    }

    fn validate(&self, ctx: &str) -> Result<()> {
        if self.interval == 0 {
            bail!("{ctx}: checkpoint interval must be >= 1");
        }
        if self.k == 0 || self.k > self.interval {
            bail!("{ctx}: checkpoint k must be in [1, interval={}]", self.interval);
        }
        Ok(())
    }
}

/// Storage topology for the running checkpoint: how many shards the
/// sharded store stripes atoms over, how many background writer threads
/// serve them in async mode (clamped to `[1, shards]` at runtime), the
/// async back-pressure bound (`max_pending` pending write jobs; 0 =
/// unbounded), and the disk-tier compaction trigger (`compact_threshold`
/// garbage ratio at flush fences, 0 = never; `compact_min_bytes` floors
/// the shard size worth compacting). Compaction keys only matter when the
/// scenario sets `checkpoint_dir` — memory shards never report garbage.
/// `parity` adds that many erasure-coded parity shards (0 = off, 1 = the
/// single-parity XOR coding implemented): every flush fence encodes each
/// stripe of atom records into a parity record, so a dead shard's slice
/// is reconstructable from survivors alone and a CRC-failed record is
/// repaired in place.
///
/// `scrub_interval` controls the deep-scrub cadence under dirty-only
/// parity fences: 0 (default) means every fence touches only the stripes
/// written since the previous fence; N > 0 additionally scans and
/// re-encodes the *entire* state every Nth fence, catching silent media
/// decay on cold stripes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StorageSpec {
    pub shards: usize,
    pub writers: usize,
    pub max_pending: usize,
    pub compact_threshold: f64,
    pub compact_min_bytes: usize,
    /// Per-pass segment-byte budget for generational compaction
    /// (0 = monolithic full-shard passes).
    pub compact_max_bytes_per_pass: usize,
    /// Batch each fence's disk appends into one coalesced write + one
    /// durability barrier per shard (no-op on memory backends).
    pub group_commit: bool,
    pub parity: usize,
    pub scrub_interval: usize,
}

impl Default for StorageSpec {
    fn default() -> Self {
        StorageSpec {
            shards: 1,
            writers: 1,
            max_pending: 0,
            compact_threshold: 0.0,
            compact_min_bytes: 0,
            compact_max_bytes_per_pass: 0,
            group_commit: false,
            parity: 0,
            scrub_interval: 0,
        }
    }
}

impl StorageSpec {
    fn validate(&self, ctx: &str) -> Result<()> {
        if self.shards == 0 {
            bail!("{ctx}: storage shards must be >= 1");
        }
        if self.writers == 0 {
            bail!("{ctx}: storage writers must be >= 1");
        }
        if !(0.0..1.0).contains(&self.compact_threshold) {
            bail!(
                "{ctx}: storage compact_threshold must be in [0, 1), got {}",
                self.compact_threshold
            );
        }
        if self.parity > 1 {
            bail!(
                "{ctx}: storage parity must be 0 or 1 (only single-parity XOR \
                 coding is implemented), got {}",
                self.parity
            );
        }
        Ok(())
    }
}

/// Fault-tolerance policy axis: does a trial hold the configured
/// checkpoint policy, or let the runtime controller retune it?
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PolicyMode {
    /// Every checkpoint knob stays fixed for the whole trial (the
    /// default — every pre-existing scenario means this).
    #[default]
    Static,
    /// A [`crate::policy::PolicyController`] watches the live loss and
    /// failure arrivals and retunes interval/k and sync↔async at
    /// iteration boundaries mid-trial.
    Adaptive,
}

impl FromStr for PolicyMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "static" => Ok(PolicyMode::Static),
            "adaptive" => Ok(PolicyMode::Adaptive),
            other => Err(format!("unknown policy mode '{other}' (static|adaptive)")),
        }
    }
}

impl std::fmt::Display for PolicyMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            PolicyMode::Static => "static",
            PolicyMode::Adaptive => "adaptive",
        })
    }
}

/// Tuning of the adaptive controller (`[advisor]` table), shared by every
/// adaptive cell. `dump_cost_iters` does double duty: it is the
/// controller's dump-vs-rework price *and* it is charged into every
/// trial's iteration cost (static cells too), so adaptive-vs-static
/// comparisons pay for checkpoint bandwidth on both sides.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdvisorSpec {
    /// Iterations between controller decision points.
    pub window: usize,
    /// Cost of one full-size checkpoint dump, in iteration units.
    pub dump_cost_iters: f64,
    /// Relative overhead improvement required before a switch.
    pub hysteresis: f64,
    /// Prior lost-parameter fraction until the first observed failure.
    pub lost_fraction: f64,
}

impl Default for AdvisorSpec {
    fn default() -> Self {
        let d = crate::policy::PolicyConfig::default();
        AdvisorSpec {
            window: d.window,
            dump_cost_iters: d.dump_cost_iters,
            hysteresis: d.hysteresis,
            lost_fraction: d.lost_fraction,
        }
    }
}

impl AdvisorSpec {
    /// The controller config for a cell whose base checkpoint interval is
    /// `base_interval` (the candidate grid is derived from it).
    pub fn config(&self, base_interval: usize) -> crate::policy::PolicyConfig {
        crate::policy::PolicyConfig {
            window: self.window,
            dump_cost_iters: self.dump_cost_iters,
            hysteresis: self.hysteresis,
            base_interval,
            lost_fraction: self.lost_fraction,
        }
    }

    fn validate(&self, ctx: &str) -> Result<()> {
        if !(0.0..1.0).contains(&self.hysteresis) {
            bail!("{ctx}: advisor hysteresis must be in [0, 1), got {}", self.hysteresis);
        }
        if !(0.0..=1.0).contains(&self.lost_fraction) {
            bail!(
                "{ctx}: advisor lost_fraction must be in [0, 1], got {}",
                self.lost_fraction
            );
        }
        if self.dump_cost_iters < 0.0 {
            bail!(
                "{ctx}: advisor dump_cost_iters must be >= 0, got {}",
                self.dump_cost_iters
            );
        }
        Ok(())
    }
}

/// Which execution substrate a scenario's failure cells run on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DeployMode {
    /// The experiment harness: cached-trajectory replay per trial (fast,
    /// the default).
    #[default]
    Harness,
    /// The threaded parameter-server cluster: every trial is a live
    /// gather/step/scatter run with `ps_nodes` node threads, scheduled
    /// kills declared deterministically at their kill iteration.
    Cluster,
}

impl FromStr for DeployMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "harness" => Ok(DeployMode::Harness),
            "cluster" => Ok(DeployMode::Cluster),
            other => Err(format!("unknown deploy mode '{other}' (harness|cluster)")),
        }
    }
}

impl std::fmt::Display for DeployMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            DeployMode::Harness => "harness",
            DeployMode::Cluster => "cluster",
        })
    }
}

/// How a perturbation's L2 norm is chosen, in units of ‖x⁽⁰⁾ − x*‖.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum NormSpec {
    /// Fixed: ‖δ‖ = rel · ‖x⁽⁰⁾ − x*‖.
    Rel(f64),
    /// Per-trial log-uniform: ‖δ‖ = 10^U(lo, hi) · ‖x⁽⁰⁾ − x*‖ (the Fig
    /// 3/5 sampling scheme).
    LogUniform { lo: f64, hi: f64 },
}

/// A direct perturbation cell (§5.2 generators).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PerturbSpec {
    Random { norm: NormSpec },
    Adversarial { norm: NormSpec },
    Reset { fraction: f64 },
}

/// What one sweep cell does to each trial.
#[derive(Debug, Clone, PartialEq)]
pub enum CellAction {
    Perturb(PerturbSpec),
    Fail(FailurePlan),
}

/// One cell of the sweep grid: an action plus optional per-cell overrides
/// of the scenario-level recovery mode and checkpoint policy.
#[derive(Debug, Clone, PartialEq)]
pub struct CellSpec {
    pub label: String,
    pub action: CellAction,
    pub mode: Option<RecoveryMode>,
    pub checkpoint: Option<CheckpointSpec>,
    /// Per-cell override of the scenario-level policy axis, so one sweep
    /// can pit `policy = "adaptive"` against fixed-interval static cells.
    pub policy: Option<PolicyMode>,
}

/// A full declarative experiment.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub name: String,
    /// Model panels: preset names ([`crate::models::presets`]), or
    /// `"synthetic[:dim=..,c=..,xseed=..]"` for the analytic workload.
    pub panels: Vec<String>,
    pub seed: u64,
    /// Trials per (panel, cell).
    pub trials: usize,
    /// Sweep worker threads; 0 = one per available core.
    pub workers: usize,
    /// Override the preset's ε-target iteration count.
    pub target_iters: Option<usize>,
    /// Override the preset's trajectory length.
    pub max_iters: Option<usize>,
    /// Iteration perturbation cells strike at (default: the Fig 5 rule,
    /// min(50, converged − 5)).
    pub perturb_iter: Option<usize>,
    /// Geometric parameter for failure iterations (§5.3).
    pub fail_geom_p: f64,
    pub checkpoint: CheckpointSpec,
    /// Scenario-level policy axis (`policy = "static" | "adaptive"`),
    /// overridable per cell.
    pub policy: PolicyMode,
    /// Adaptive-controller tuning (`[advisor]`); its `dump_cost_iters`
    /// also prices checkpoint dumps into every cell's iteration cost.
    pub advisor: AdvisorSpec,
    pub storage: StorageSpec,
    /// Root directory for disk-backed trials: every trial gets its own
    /// on-disk sharded store under it (`None` = in-memory shards). A
    /// disk-backed sweep produces reports byte-identical to the same
    /// sweep on memory shards.
    pub checkpoint_dir: Option<String>,
    /// Injected storage faults, applied to every trial's store
    /// (`[chaos]` — per-shard kill/slow/torn-write schedules).
    pub chaos: FaultPlan,
    /// Execution substrate for failure cells.
    pub deploy: DeployMode,
    /// PS node threads per trial when `deploy = "cluster"`.
    pub ps_nodes: usize,
    pub recovery: RecoveryMode,
    /// CSV output path (written by `scar run-scenario` and the fig
    /// wrappers; in-process callers read the report instead).
    pub output: Option<String>,
    /// Flight-recorder trace directory (`[obs] trace_dir`): when set,
    /// every trial writes a JSONL event trace
    /// (`p{panel}-c{cell}-t{trial}.jsonl`) under it. `None` (the
    /// default) keeps the recorder a zero-cost no-op — tracing never
    /// changes results.
    pub trace_dir: Option<String>,
    pub cells: Vec<CellSpec>,
}

fn mode_str(m: RecoveryMode) -> &'static str {
    match m {
        RecoveryMode::Full => "full",
        RecoveryMode::Partial => "partial",
    }
}

impl Scenario {
    /// Load from a file; `.toml` parses as TOML, anything else as JSON.
    pub fn from_file(path: &Path) -> Result<Scenario> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading scenario {}", path.display()))?;
        let is_toml = path
            .extension()
            .map(|e| e.eq_ignore_ascii_case("toml"))
            .unwrap_or(false);
        let parsed = if is_toml {
            Scenario::from_toml_str(&text)
        } else {
            Scenario::from_json_str(&text)
        };
        parsed.with_context(|| format!("in scenario file {}", path.display()))
    }

    pub fn from_toml_str(text: &str) -> Result<Scenario> {
        let v = super::toml::parse(text).map_err(anyhow::Error::msg)?;
        Scenario::from_json(&v)
    }

    pub fn from_json_str(text: &str) -> Result<Scenario> {
        let v = Json::parse(text).context("parsing scenario JSON")?;
        Scenario::from_json(&v)
    }

    /// Build from a parsed value (the shared back-end of both formats).
    pub fn from_json(v: &Json) -> Result<Scenario> {
        let obj = v.as_obj().context("scenario: top level must be a table/object")?;
        const TOP_KEYS: &[&str] = &[
            "name", "model", "panels", "seed", "trials", "workers", "target_iters",
            "max_iters", "perturb_iter", "fail_geom_p", "checkpoint", "policy",
            "advisor", "storage", "checkpoint_dir", "chaos", "deploy", "ps_nodes",
            "recovery", "output", "obs", "cell", "cells",
        ];
        for key in obj.keys() {
            if !TOP_KEYS.contains(&key.as_str()) {
                bail!("scenario: unknown key '{key}' (expected one of {TOP_KEYS:?})");
            }
        }

        let name = req_str(obj, "name", "scenario")?;
        let ctx = format!("scenario '{name}'");

        let mut panels: Vec<String> = Vec::new();
        if let Some(m) = opt_str(obj, "model", &ctx)? {
            panels.push(m);
        }
        if let Some(arr) = obj.get("panels") {
            let arr = arr
                .as_arr()
                .with_context(|| format!("{ctx}: 'panels' must be an array of strings"))?;
            for (i, p) in arr.iter().enumerate() {
                panels.push(
                    p.as_str()
                        .with_context(|| format!("{ctx}: panels[{i}] must be a string"))?
                        .to_string(),
                );
            }
        }
        if panels.is_empty() {
            bail!("{ctx}: needs 'model = \"...\"' or 'panels = [...]'");
        }

        let checkpoint = match obj.get("checkpoint") {
            None => CheckpointSpec::default(),
            Some(c) => parse_checkpoint(c, &CheckpointSpec::default(), &ctx)?,
        };

        let policy = match opt_str(obj, "policy", &ctx)? {
            None => PolicyMode::Static,
            Some(s) => PolicyMode::from_str(&s)
                .map_err(|e| anyhow::anyhow!("{ctx}: policy: {e}"))?,
        };

        let advisor = match obj.get("advisor") {
            None => AdvisorSpec::default(),
            Some(a) => parse_advisor(a, &ctx)?,
        };

        let storage = match obj.get("storage") {
            None => StorageSpec::default(),
            Some(s) => parse_storage(s, &ctx)?,
        };

        let chaos = match obj.get("chaos") {
            None => FaultPlan::default(),
            Some(c) => parse_chaos(c, &ctx)?,
        };

        let trace_dir = match obj.get("obs") {
            None => None,
            Some(o) => parse_obs(o, &ctx)?,
        };

        let deploy = match opt_str(obj, "deploy", &ctx)? {
            None => DeployMode::Harness,
            Some(s) => DeployMode::from_str(&s)
                .map_err(|e| anyhow::anyhow!("{ctx}: deploy: {e}"))?,
        };

        let recovery = match opt_str(obj, "recovery", &ctx)? {
            None => RecoveryMode::Partial,
            Some(s) => RecoveryMode::from_str(&s)
                .map_err(|e| anyhow::anyhow!("{ctx}: recovery: {e}"))?,
        };

        let cells_val = match (obj.get("cell"), obj.get("cells")) {
            (Some(_), Some(_)) => bail!("{ctx}: use either 'cell' or 'cells', not both"),
            (Some(c), None) | (None, Some(c)) => c,
            (None, None) => bail!("{ctx}: needs at least one [[cell]]"),
        };
        let cells_arr = cells_val
            .as_arr()
            .with_context(|| format!("{ctx}: cells must be an array of tables"))?;
        let mut cells = Vec::with_capacity(cells_arr.len());
        for (i, c) in cells_arr.iter().enumerate() {
            cells.push(parse_cell(c, i, &checkpoint, &ctx)?);
        }

        let scenario = Scenario {
            name,
            panels,
            seed: opt_u64(obj, "seed", &ctx)?.unwrap_or(42),
            trials: opt_usize(obj, "trials", &ctx)?.unwrap_or(20),
            workers: opt_usize(obj, "workers", &ctx)?.unwrap_or(0),
            target_iters: opt_usize(obj, "target_iters", &ctx)?,
            max_iters: opt_usize(obj, "max_iters", &ctx)?,
            perturb_iter: opt_usize(obj, "perturb_iter", &ctx)?,
            fail_geom_p: opt_f64(obj, "fail_geom_p", &ctx)?.unwrap_or(0.05),
            checkpoint,
            policy,
            advisor,
            storage,
            checkpoint_dir: opt_str(obj, "checkpoint_dir", &ctx)?,
            chaos,
            deploy,
            ps_nodes: opt_usize(obj, "ps_nodes", &ctx)?.unwrap_or(4),
            recovery,
            output: opt_str(obj, "output", &ctx)?,
            trace_dir,
            cells,
        };
        scenario.validate()?;
        Ok(scenario)
    }

    pub fn validate(&self) -> Result<()> {
        let ctx = format!("scenario '{}'", self.name);
        if self.trials == 0 {
            bail!("{ctx}: trials must be >= 1");
        }
        if !(self.fail_geom_p > 0.0 && self.fail_geom_p <= 1.0) {
            bail!("{ctx}: fail_geom_p must be in (0, 1], got {}", self.fail_geom_p);
        }
        self.checkpoint.validate(&ctx)?;
        self.advisor.validate(&ctx)?;
        self.storage.validate(&ctx)?;
        self.chaos
            .validate(self.storage.shards)
            .map_err(|e| anyhow::anyhow!("{ctx}: {e}"))?;
        if self.deploy == DeployMode::Cluster && self.ps_nodes < 2 {
            bail!(
                "{ctx}: deploy = \"cluster\" needs ps_nodes >= 2 (a kill must leave a \
                 survivor), got {}",
                self.ps_nodes
            );
        }
        if self.deploy == DeployMode::Cluster && self.recovery == RecoveryMode::Full {
            bail!(
                "{ctx}: deploy = \"cluster\" implements partial recovery only (lost atoms \
                 are re-homed and reloaded); use recovery = \"partial\""
            );
        }
        if let (Some(t), Some(m)) = (self.target_iters, self.max_iters) {
            if t == 0 || t > m {
                bail!("{ctx}: need 1 <= target_iters <= max_iters, got {t} > {m}");
            }
        }
        if self.cells.is_empty() {
            bail!("{ctx}: needs at least one cell");
        }
        for cell in &self.cells {
            let cctx = format!("{ctx}, cell '{}'", cell.label);
            if let Some(ck) = &cell.checkpoint {
                ck.validate(&cctx)?;
            }
            match &cell.action {
                CellAction::Fail(plan) => {
                    plan.validate().map_err(|e| anyhow::anyhow!("{cctx}: {e}"))?;
                    if self.deploy == DeployMode::Cluster
                        && matches!(plan, FailurePlan::Flaky { .. })
                    {
                        bail!(
                            "{cctx}: fail = \"flaky\" is not supported with deploy = \
                             \"cluster\" (PS nodes are not revived)"
                        );
                    }
                    if self.deploy == DeployMode::Cluster
                        && cell.mode == Some(RecoveryMode::Full)
                    {
                        bail!(
                            "{cctx}: deploy = \"cluster\" implements partial recovery only; \
                             remove mode = \"full\""
                        );
                    }
                }
                CellAction::Perturb(p) => {
                    validate_perturb(p, &cctx)?;
                    if self.deploy == DeployMode::Cluster {
                        bail!(
                            "{cctx}: perturb cells are not supported with deploy = \
                             \"cluster\" (only failure plans map to node kills)"
                        );
                    }
                }
            }
        }
        Ok(())
    }

    /// Serialize back to the shared value model (JSON-compatible, and
    /// re-parseable by [`Scenario::from_json`] — the round-trip contract).
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("name".into(), Json::from(self.name.as_str()));
        obj.insert("panels".into(), Json::from(self.panels.clone()));
        obj.insert("seed".into(), Json::Num(self.seed as f64));
        obj.insert("trials".into(), Json::from(self.trials));
        obj.insert("workers".into(), Json::from(self.workers));
        if let Some(t) = self.target_iters {
            obj.insert("target_iters".into(), Json::from(t));
        }
        if let Some(m) = self.max_iters {
            obj.insert("max_iters".into(), Json::from(m));
        }
        if let Some(p) = self.perturb_iter {
            obj.insert("perturb_iter".into(), Json::from(p));
        }
        obj.insert("fail_geom_p".into(), Json::Num(self.fail_geom_p));
        obj.insert("checkpoint".into(), checkpoint_json(&self.checkpoint));
        obj.insert("policy".into(), Json::from(self.policy.to_string()));
        obj.insert("advisor".into(), advisor_json(&self.advisor));
        obj.insert("storage".into(), storage_json(&self.storage));
        if let Some(d) = &self.checkpoint_dir {
            obj.insert("checkpoint_dir".into(), Json::from(d.as_str()));
        }
        if !self.chaos.is_empty() {
            obj.insert("chaos".into(), self.chaos.to_json());
        }
        obj.insert("deploy".into(), Json::from(self.deploy.to_string()));
        obj.insert("ps_nodes".into(), Json::from(self.ps_nodes));
        obj.insert("recovery".into(), Json::from(mode_str(self.recovery)));
        if let Some(o) = &self.output {
            obj.insert("output".into(), Json::from(o.as_str()));
        }
        if let Some(d) = &self.trace_dir {
            let mut m = BTreeMap::new();
            m.insert("trace_dir".into(), Json::from(d.as_str()));
            obj.insert("obs".into(), Json::Obj(m));
        }
        obj.insert(
            "cells".into(),
            Json::Arr(self.cells.iter().map(cell_json).collect()),
        );
        Json::Obj(obj)
    }

    /// Human-readable summary (used by `scar run-scenario --dry-run`).
    pub fn describe(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "scenario '{}': {} panel(s) x {} cell(s) x {} trial(s), seed {}\n",
            self.name,
            self.panels.len(),
            self.cells.len(),
            self.trials,
            self.seed
        ));
        out.push_str(&format!(
            "  checkpoint: 1/{} every {} iters ({}, {} writes); recovery: {}; geom p = {}\n",
            self.checkpoint.k,
            self.checkpoint.policy().interval,
            self.checkpoint.selector,
            self.checkpoint.mode,
            mode_str(self.recovery),
            self.fail_geom_p
        ));
        out.push_str(&format!(
            "  storage: {} shard(s), {} writer(s), max_pending {}, backend {}; deploy: {}\n",
            self.storage.shards,
            self.storage.writers,
            self.storage.max_pending,
            match &self.checkpoint_dir {
                None => "mem".to_string(),
                Some(d) => format!("disk ({d})"),
            },
            match self.deploy {
                DeployMode::Harness => "harness".to_string(),
                DeployMode::Cluster => format!("cluster ({} PS nodes)", self.ps_nodes),
            }
        ));
        let any_adaptive = self.policy == PolicyMode::Adaptive
            || self.cells.iter().any(|c| c.policy == Some(PolicyMode::Adaptive));
        if any_adaptive {
            out.push_str(&format!(
                "  policy: adaptive cells retune live (window {}, dump cost {} iters, \
                 hysteresis {})\n",
                self.advisor.window, self.advisor.dump_cost_iters, self.advisor.hysteresis
            ));
        }
        if self.storage.group_commit {
            out.push_str("  group commit: one coalesced write + barrier per shard per fence\n");
        }
        if self.storage.compact_threshold > 0.0 {
            out.push_str(&format!(
                "  compaction: garbage ratio >= {:.2} at flush fences (min {} bytes)\n",
                self.storage.compact_threshold, self.storage.compact_min_bytes
            ));
            if self.storage.compact_max_bytes_per_pass > 0 {
                out.push_str(&format!(
                    "  generational passes: <= {} segment byte(s) folded per pass\n",
                    self.storage.compact_max_bytes_per_pass
                ));
            }
        }
        if self.storage.parity > 0 {
            out.push_str(&format!(
                "  erasure coding: {} XOR parity shard(s), encoded at flush fences\n",
                self.storage.parity
            ));
            if self.storage.scrub_interval > 0 {
                out.push_str(&format!(
                    "  deep scrub: full-state parity scan every {} fence(s)\n",
                    self.storage.scrub_interval
                ));
            }
        }
        if !self.chaos.is_empty() {
            out.push_str(&format!("  chaos: {} storage fault(s)\n", self.chaos.faults.len()));
            for f in &self.chaos.faults {
                out.push_str(&format!(
                    "    shard {} at iter {}: {:?}\n",
                    f.shard, f.at, f.kind
                ));
            }
        }
        if let Some(d) = &self.trace_dir {
            out.push_str(&format!("  tracing: per-trial JSONL traces under {d}\n"));
        }
        for p in &self.panels {
            out.push_str(&format!("  panel: {p}\n"));
        }
        for c in &self.cells {
            let action = match &c.action {
                CellAction::Perturb(p) => format!("perturb {p:?}"),
                CellAction::Fail(plan) => format!("fail {plan:?}"),
            };
            let mode = c.mode.map(|m| format!(" mode={}", mode_str(m))).unwrap_or_default();
            let policy = c.policy.map(|p| format!(" policy={p}")).unwrap_or_default();
            out.push_str(&format!("  cell '{}': {action}{mode}{policy}\n", c.label));
        }
        out
    }
}

fn checkpoint_json(c: &CheckpointSpec) -> Json {
    let mut m = BTreeMap::new();
    m.insert("interval".into(), Json::from(c.interval));
    m.insert("k".into(), Json::from(c.k));
    m.insert("selector".into(), Json::from(c.selector.to_string()));
    m.insert("mode".into(), Json::from(c.mode.to_string()));
    Json::Obj(m)
}

fn advisor_json(a: &AdvisorSpec) -> Json {
    let mut m = BTreeMap::new();
    m.insert("window".into(), Json::from(a.window));
    m.insert("dump_cost_iters".into(), Json::Num(a.dump_cost_iters));
    m.insert("hysteresis".into(), Json::Num(a.hysteresis));
    m.insert("lost_fraction".into(), Json::Num(a.lost_fraction));
    Json::Obj(m)
}

fn storage_json(s: &StorageSpec) -> Json {
    let mut m = BTreeMap::new();
    m.insert("shards".into(), Json::from(s.shards));
    m.insert("writers".into(), Json::from(s.writers));
    m.insert("max_pending".into(), Json::from(s.max_pending));
    m.insert("compact_threshold".into(), Json::Num(s.compact_threshold));
    m.insert("compact_min_bytes".into(), Json::from(s.compact_min_bytes));
    m.insert("compact_max_bytes_per_pass".into(), Json::from(s.compact_max_bytes_per_pass));
    m.insert("group_commit".into(), Json::Bool(s.group_commit));
    m.insert("parity".into(), Json::from(s.parity));
    m.insert("scrub_interval".into(), Json::from(s.scrub_interval));
    Json::Obj(m)
}

fn cell_json(c: &CellSpec) -> Json {
    let mut m = BTreeMap::new();
    m.insert("label".into(), Json::from(c.label.as_str()));
    if let Some(mode) = c.mode {
        m.insert("mode".into(), Json::from(mode_str(mode)));
    }
    if let Some(ck) = &c.checkpoint {
        m.insert("interval".into(), Json::from(ck.interval));
        m.insert("k".into(), Json::from(ck.k));
        m.insert("selector".into(), Json::from(ck.selector.to_string()));
        m.insert("checkpoint_mode".into(), Json::from(ck.mode.to_string()));
    }
    if let Some(p) = c.policy {
        m.insert("policy".into(), Json::from(p.to_string()));
    }
    match &c.action {
        CellAction::Perturb(PerturbSpec::Random { norm }) => {
            m.insert("perturb".into(), Json::from("random"));
            norm_json(&mut m, norm);
        }
        CellAction::Perturb(PerturbSpec::Adversarial { norm }) => {
            m.insert("perturb".into(), Json::from("adversarial"));
            norm_json(&mut m, norm);
        }
        CellAction::Perturb(PerturbSpec::Reset { fraction }) => {
            m.insert("perturb".into(), Json::from("reset"));
            m.insert("fraction".into(), Json::Num(*fraction));
        }
        CellAction::Fail(plan) => {
            m.insert("fail".into(), Json::from(plan.kind()));
            match plan {
                FailurePlan::Single { fraction } => {
                    m.insert("fraction".into(), Json::Num(*fraction));
                }
                FailurePlan::Correlated { nodes, of_nodes } => {
                    m.insert("nodes".into(), Json::from(*nodes));
                    m.insert("of_nodes".into(), Json::from(*of_nodes));
                }
                FailurePlan::Cascade { fraction, extra, gap } => {
                    m.insert("fraction".into(), Json::Num(*fraction));
                    m.insert("extra".into(), Json::from(*extra));
                    m.insert("gap".into(), Json::from(*gap));
                }
                FailurePlan::Flaky { fraction, period, prob, max_events } => {
                    m.insert("fraction".into(), Json::Num(*fraction));
                    m.insert("period".into(), Json::from(*period));
                    m.insert("prob".into(), Json::Num(*prob));
                    m.insert("max_events".into(), Json::from(*max_events));
                }
            }
        }
    }
    Json::Obj(m)
}

fn norm_json(m: &mut BTreeMap<String, Json>, norm: &NormSpec) {
    match norm {
        NormSpec::Rel(r) => {
            m.insert("norm_rel".into(), Json::Num(*r));
        }
        NormSpec::LogUniform { lo, hi } => {
            m.insert("norm_log10".into(), Json::Arr(vec![Json::Num(*lo), Json::Num(*hi)]));
        }
    }
}

// ---------------------------------------------------------------------------
// Field helpers
// ---------------------------------------------------------------------------

fn req_str(obj: &BTreeMap<String, Json>, key: &str, ctx: &str) -> Result<String> {
    match obj.get(key) {
        Some(Json::Str(s)) => Ok(s.clone()),
        Some(_) => bail!("{ctx}: '{key}' must be a string"),
        None => bail!("{ctx}: missing required key '{key}'"),
    }
}

fn opt_str(obj: &BTreeMap<String, Json>, key: &str, ctx: &str) -> Result<Option<String>> {
    match obj.get(key) {
        None => Ok(None),
        Some(Json::Str(s)) => Ok(Some(s.clone())),
        Some(_) => bail!("{ctx}: '{key}' must be a string"),
    }
}

fn opt_f64(obj: &BTreeMap<String, Json>, key: &str, ctx: &str) -> Result<Option<f64>> {
    match obj.get(key) {
        None => Ok(None),
        Some(Json::Num(n)) => Ok(Some(*n)),
        Some(_) => bail!("{ctx}: '{key}' must be a number"),
    }
}

fn opt_bool(obj: &BTreeMap<String, Json>, key: &str, ctx: &str) -> Result<Option<bool>> {
    match obj.get(key) {
        None => Ok(None),
        Some(Json::Bool(b)) => Ok(Some(*b)),
        Some(_) => bail!("{ctx}: '{key}' must be a boolean"),
    }
}

fn opt_usize(obj: &BTreeMap<String, Json>, key: &str, ctx: &str) -> Result<Option<usize>> {
    match obj.get(key) {
        None => Ok(None),
        Some(v) => Ok(Some(v.as_usize().with_context(|| {
            format!("{ctx}: '{key}' must be a non-negative integer")
        })?)),
    }
}

fn opt_u64(obj: &BTreeMap<String, Json>, key: &str, ctx: &str) -> Result<Option<u64>> {
    // Numbers travel through f64 (the shared Json model), which is exact
    // only up to 2^53 — reject larger values instead of silently rounding
    // a seed to a different sweep.
    let v = opt_usize(obj, key, ctx)?;
    if let Some(v) = v {
        if v as u64 > (1u64 << 53) {
            bail!("{ctx}: '{key}' must be <= 2^53 (JSON/TOML numbers are f64), got {v}");
        }
    }
    Ok(v.map(|v| v as u64))
}

fn parse_checkpoint(v: &Json, base: &CheckpointSpec, ctx: &str) -> Result<CheckpointSpec> {
    let obj = v
        .as_obj()
        .with_context(|| format!("{ctx}: 'checkpoint' must be a table"))?;
    for key in obj.keys() {
        if !["interval", "k", "selector", "mode"].contains(&key.as_str()) {
            bail!("{ctx}: checkpoint: unknown key '{key}' (interval|k|selector|mode)");
        }
    }
    let selector = match opt_str(obj, "selector", ctx)? {
        None => base.selector,
        Some(s) => {
            Selector::from_str(&s).map_err(|e| anyhow::anyhow!("{ctx}: selector: {e}"))?
        }
    };
    let mode = match opt_str(obj, "mode", ctx)? {
        None => base.mode,
        Some(s) => CheckpointMode::from_str(&s)
            .map_err(|e| anyhow::anyhow!("{ctx}: checkpoint mode: {e}"))?,
    };
    Ok(CheckpointSpec {
        interval: opt_usize(obj, "interval", ctx)?.unwrap_or(base.interval),
        k: opt_usize(obj, "k", ctx)?.unwrap_or(base.k),
        selector,
        mode,
    })
}

/// Parse the `[advisor]` table: adaptive-controller tuning.
fn parse_advisor(v: &Json, ctx: &str) -> Result<AdvisorSpec> {
    let obj = v
        .as_obj()
        .with_context(|| format!("{ctx}: 'advisor' must be a table"))?;
    const ADVISOR_KEYS: &[&str] = &["window", "dump_cost_iters", "hysteresis", "lost_fraction"];
    for key in obj.keys() {
        if !ADVISOR_KEYS.contains(&key.as_str()) {
            bail!("{ctx}: advisor: unknown key '{key}' (expected one of {ADVISOR_KEYS:?})");
        }
    }
    let base = AdvisorSpec::default();
    Ok(AdvisorSpec {
        window: opt_usize(obj, "window", ctx)?.unwrap_or(base.window),
        dump_cost_iters: opt_f64(obj, "dump_cost_iters", ctx)?.unwrap_or(base.dump_cost_iters),
        hysteresis: opt_f64(obj, "hysteresis", ctx)?.unwrap_or(base.hysteresis),
        lost_fraction: opt_f64(obj, "lost_fraction", ctx)?.unwrap_or(base.lost_fraction),
    })
}

fn parse_storage(v: &Json, ctx: &str) -> Result<StorageSpec> {
    let obj = v
        .as_obj()
        .with_context(|| format!("{ctx}: 'storage' must be a table"))?;
    const STORAGE_KEYS: &[&str] = &[
        "shards",
        "writers",
        "max_pending",
        "compact_threshold",
        "compact_min_bytes",
        "compact_max_bytes_per_pass",
        "group_commit",
        "parity",
        "scrub_interval",
    ];
    for key in obj.keys() {
        if !STORAGE_KEYS.contains(&key.as_str()) {
            bail!("{ctx}: storage: unknown key '{key}' (expected one of {STORAGE_KEYS:?})");
        }
    }
    let base = StorageSpec::default();
    let shards = opt_usize(obj, "shards", ctx)?.unwrap_or(base.shards);
    Ok(StorageSpec {
        shards,
        // Default the pool to one writer per shard.
        writers: opt_usize(obj, "writers", ctx)?.unwrap_or(shards),
        max_pending: opt_usize(obj, "max_pending", ctx)?.unwrap_or(base.max_pending),
        compact_threshold: opt_f64(obj, "compact_threshold", ctx)?
            .unwrap_or(base.compact_threshold),
        compact_min_bytes: opt_usize(obj, "compact_min_bytes", ctx)?
            .unwrap_or(base.compact_min_bytes),
        compact_max_bytes_per_pass: opt_usize(obj, "compact_max_bytes_per_pass", ctx)?
            .unwrap_or(base.compact_max_bytes_per_pass),
        group_commit: opt_bool(obj, "group_commit", ctx)?.unwrap_or(base.group_commit),
        parity: opt_usize(obj, "parity", ctx)?.unwrap_or(base.parity),
        scrub_interval: opt_usize(obj, "scrub_interval", ctx)?.unwrap_or(base.scrub_interval),
    })
}

/// Parse the `[obs]` table: flight-recorder settings. The only key is
/// `trace_dir` — where per-trial JSONL traces land.
fn parse_obs(v: &Json, ctx: &str) -> Result<Option<String>> {
    let obj = v
        .as_obj()
        .with_context(|| format!("{ctx}: 'obs' must be a table"))?;
    for key in obj.keys() {
        if key.as_str() != "trace_dir" {
            bail!("{ctx}: obs: unknown key '{key}' (trace_dir)");
        }
    }
    opt_str(obj, "trace_dir", ctx)
}

/// Parse the `[chaos]` table: per-shard fault schedules under the keys
/// `kill`, `slow`, `torn`, `partition`, `flaky`, `fsync`, `bitflip`, and
/// `replay`, each an array of tables.
fn parse_chaos(v: &Json, ctx: &str) -> Result<FaultPlan> {
    let obj = v
        .as_obj()
        .with_context(|| format!("{ctx}: 'chaos' must be a table"))?;
    const CHAOS_KEYS: &[&str] =
        &["kill", "slow", "torn", "partition", "flaky", "fsync", "bitflip", "replay"];
    for key in obj.keys() {
        if !CHAOS_KEYS.contains(&key.as_str()) {
            bail!("{ctx}: chaos: unknown key '{key}' (expected one of {CHAOS_KEYS:?})");
        }
    }
    /// The `chaos.<key>` array as a list of tables (empty when absent).
    fn entries<'a>(
        obj: &'a BTreeMap<String, Json>,
        key: &str,
        ctx: &str,
    ) -> Result<Vec<&'a BTreeMap<String, Json>>> {
        match obj.get(key) {
            None => Ok(Vec::new()),
            Some(arr) => {
                let arr = arr.as_arr().with_context(|| {
                    format!("{ctx}: chaos.{key} must be an array of tables ([[chaos.{key}]])")
                })?;
                arr.iter()
                    .enumerate()
                    .map(|(i, e)| {
                        e.as_obj().with_context(|| {
                            format!("{ctx}: chaos.{key}[{i}] must be a table")
                        })
                    })
                    .collect()
            }
        }
    }

    fn shard_at(e: &BTreeMap<String, Json>, key: &str, ctx: &str) -> Result<(usize, usize)> {
        let ectx = format!("{ctx}: chaos.{key}");
        let shard = opt_usize(e, "shard", &ectx)?
            .with_context(|| format!("{ectx}: needs 'shard'"))?;
        let at = opt_usize(e, "at", &ectx)?
            .with_context(|| format!("{ectx}: needs 'at'"))?;
        Ok((shard, at))
    }

    let mut faults = Vec::new();
    for e in entries(obj, "kill", ctx)? {
        for key in e.keys() {
            if !["shard", "at", "heal_at"].contains(&key.as_str()) {
                bail!("{ctx}: chaos.kill: unknown key '{key}' (shard|at|heal_at)");
            }
        }
        let (shard, at) = shard_at(e, "kill", ctx)?;
        let heal_at = opt_usize(e, "heal_at", ctx)?;
        faults.push(ShardFault { shard, at, kind: FaultKind::Kill { heal_at } });
    }
    for e in entries(obj, "slow", ctx)? {
        for key in e.keys() {
            if !["shard", "at", "until", "delay_us"].contains(&key.as_str()) {
                bail!("{ctx}: chaos.slow: unknown key '{key}' (shard|at|until|delay_us)");
            }
        }
        let (shard, at) = shard_at(e, "slow", ctx)?;
        let until = opt_usize(e, "until", ctx)?;
        let delay_us = opt_usize(e, "delay_us", ctx)?.unwrap_or(0) as u64;
        faults.push(ShardFault { shard, at, kind: FaultKind::Slow { until, delay_us } });
    }
    for e in entries(obj, "torn", ctx)? {
        for key in e.keys() {
            if !["shard", "at"].contains(&key.as_str()) {
                bail!("{ctx}: chaos.torn: unknown key '{key}' (shard|at)");
            }
        }
        let (shard, at) = shard_at(e, "torn", ctx)?;
        faults.push(ShardFault { shard, at, kind: FaultKind::TornWrite });
    }
    for e in entries(obj, "partition", ctx)? {
        for key in e.keys() {
            if !["shard", "at", "until"].contains(&key.as_str()) {
                bail!("{ctx}: chaos.partition: unknown key '{key}' (shard|at|until)");
            }
        }
        let (shard, at) = shard_at(e, "partition", ctx)?;
        let until = opt_usize(e, "until", ctx)?;
        faults.push(ShardFault { shard, at, kind: FaultKind::Partition { until } });
    }
    for e in entries(obj, "flaky", ctx)? {
        for key in e.keys() {
            if !["shard", "at", "period", "down_for", "cycles"].contains(&key.as_str()) {
                bail!(
                    "{ctx}: chaos.flaky: unknown key '{key}' \
                     (shard|at|period|down_for|cycles)"
                );
            }
        }
        let (shard, at) = shard_at(e, "flaky", ctx)?;
        faults.push(ShardFault {
            shard,
            at,
            kind: FaultKind::Flaky {
                period: opt_usize(e, "period", ctx)?.unwrap_or(5),
                down_for: opt_usize(e, "down_for", ctx)?.unwrap_or(2),
                cycles: opt_usize(e, "cycles", ctx)?.unwrap_or(2),
            },
        });
    }
    for e in entries(obj, "fsync", ctx)? {
        for key in e.keys() {
            if !["shard", "at"].contains(&key.as_str()) {
                bail!("{ctx}: chaos.fsync: unknown key '{key}' (shard|at)");
            }
        }
        let (shard, at) = shard_at(e, "fsync", ctx)?;
        faults.push(ShardFault { shard, at, kind: FaultKind::FsyncFail });
    }
    for e in entries(obj, "bitflip", ctx)? {
        for key in e.keys() {
            if !["shard", "at", "atom"].contains(&key.as_str()) {
                bail!("{ctx}: chaos.bitflip: unknown key '{key}' (shard|at|atom)");
            }
        }
        let (shard, at) = shard_at(e, "bitflip", ctx)?;
        // The corrupted atom defaults to the shard index, mirroring the
        // CLI grammar's `bitflip:SHARD@AT` shorthand.
        let atom = opt_usize(e, "atom", ctx)?.unwrap_or(shard);
        faults.push(ShardFault { shard, at, kind: FaultKind::Bitflip { atom } });
    }
    for e in entries(obj, "replay", ctx)? {
        for key in e.keys() {
            if !["shard", "at"].contains(&key.as_str()) {
                bail!("{ctx}: chaos.replay: unknown key '{key}' (shard|at)");
            }
        }
        let (shard, at) = shard_at(e, "replay", ctx)?;
        faults.push(ShardFault { shard, at, kind: FaultKind::Replay });
    }
    Ok(FaultPlan { faults })
}

fn parse_norm(obj: &BTreeMap<String, Json>, ctx: &str) -> Result<NormSpec> {
    let rel = opt_f64(obj, "norm_rel", ctx)?;
    let log10 = obj.get("norm_log10");
    match (rel, log10) {
        (Some(_), Some(_)) => {
            bail!("{ctx}: use either 'norm_rel' or 'norm_log10', not both")
        }
        (Some(r), None) => Ok(NormSpec::Rel(r)),
        (None, Some(v)) => {
            let arr = v
                .as_arr()
                .with_context(|| format!("{ctx}: 'norm_log10' must be [lo, hi]"))?;
            if arr.len() != 2 {
                bail!("{ctx}: 'norm_log10' must be [lo, hi]");
            }
            let lo = arr[0]
                .as_f64()
                .with_context(|| format!("{ctx}: norm_log10[0] must be a number"))?;
            let hi = arr[1]
                .as_f64()
                .with_context(|| format!("{ctx}: norm_log10[1] must be a number"))?;
            Ok(NormSpec::LogUniform { lo, hi })
        }
        (None, None) => bail!("{ctx}: perturbation needs 'norm_rel' or 'norm_log10'"),
    }
}

fn validate_perturb(p: &PerturbSpec, ctx: &str) -> Result<()> {
    match p {
        PerturbSpec::Reset { fraction } => {
            if !(*fraction > 0.0 && *fraction <= 1.0) {
                bail!("{ctx}: reset fraction must be in (0, 1], got {fraction}");
            }
        }
        PerturbSpec::Random { norm } | PerturbSpec::Adversarial { norm } => match norm {
            NormSpec::Rel(r) => {
                if *r <= 0.0 {
                    bail!("{ctx}: norm_rel must be > 0, got {r}");
                }
            }
            NormSpec::LogUniform { lo, hi } => {
                if lo > hi {
                    bail!("{ctx}: norm_log10 needs lo <= hi, got [{lo}, {hi}]");
                }
            }
        },
    }
    Ok(())
}

fn parse_cell(
    v: &Json,
    index: usize,
    base_ck: &CheckpointSpec,
    scn_ctx: &str,
) -> Result<CellSpec> {
    let obj = v
        .as_obj()
        .with_context(|| format!("{scn_ctx}: cell {index} must be a table"))?;
    let label = req_str(obj, "label", &format!("{scn_ctx}: cell {index}"))?;
    let ctx = format!("{scn_ctx}, cell '{label}'");

    // Exactly the keys each action kind consumes — an irrelevant key
    // (e.g. 'gap' on a single-loss cell, or 'mode' on a perturbation
    // cell, which no recovery ever runs for) is a hard error, never
    // silently ignored, because it usually means the kind itself is a
    // typo or the user expects an effect the sweep won't have.
    const PERTURB_COMMON: &[&str] = &["label", "perturb", "fail"];
    const FAIL_COMMON: &[&str] = &[
        "label", "perturb", "fail", "mode", "interval", "k", "selector", "checkpoint_mode",
        "policy",
    ];
    let check_keys = |common: &[&str], allowed: &[&str], kind: &str| -> Result<()> {
        for key in obj.keys() {
            if !common.contains(&key.as_str()) && !allowed.contains(&key.as_str()) {
                bail!(
                    "{ctx}: key '{key}' is not valid for '{kind}' (allowed: {allowed:?})"
                );
            }
        }
        Ok(())
    };

    let perturb = opt_str(obj, "perturb", &ctx)?;
    let fail = opt_str(obj, "fail", &ctx)?;
    let action = match (perturb, fail) {
        (Some(_), Some(_)) => bail!("{ctx}: a cell is either 'perturb' or 'fail', not both"),
        (None, None) => bail!("{ctx}: needs 'perturb = \"...\"' or 'fail = \"...\"'"),
        (Some(kind), None) => {
            let spec = match kind.as_str() {
                "random" => {
                    check_keys(PERTURB_COMMON, &["norm_rel", "norm_log10"], "perturb = random")?;
                    PerturbSpec::Random { norm: parse_norm(obj, &ctx)? }
                }
                "adversarial" => {
                    check_keys(
                        PERTURB_COMMON,
                        &["norm_rel", "norm_log10"],
                        "perturb = adversarial",
                    )?;
                    PerturbSpec::Adversarial { norm: parse_norm(obj, &ctx)? }
                }
                "reset" => {
                    check_keys(PERTURB_COMMON, &["fraction"], "perturb = reset")?;
                    PerturbSpec::Reset {
                        fraction: opt_f64(obj, "fraction", &ctx)?
                            .with_context(|| format!("{ctx}: reset needs 'fraction'"))?,
                    }
                }
                other => bail!("{ctx}: unknown perturbation '{other}' (random|adversarial|reset)"),
            };
            CellAction::Perturb(spec)
        }
        (None, Some(kind)) => {
            let fraction = || -> Result<f64> {
                opt_f64(obj, "fraction", &ctx)?
                    .with_context(|| format!("{ctx}: fail '{kind}' needs 'fraction'"))
            };
            let plan = match kind.as_str() {
                "single" => {
                    check_keys(FAIL_COMMON, &["fraction"], "fail = single")?;
                    FailurePlan::Single { fraction: fraction()? }
                }
                "correlated" => {
                    check_keys(FAIL_COMMON, &["nodes", "of_nodes"], "fail = correlated")?;
                    FailurePlan::Correlated {
                        nodes: opt_usize(obj, "nodes", &ctx)?.unwrap_or(1),
                        of_nodes: opt_usize(obj, "of_nodes", &ctx)?.unwrap_or(4),
                    }
                }
                "cascade" => {
                    check_keys(FAIL_COMMON, &["fraction", "extra", "gap"], "fail = cascade")?;
                    FailurePlan::Cascade {
                        fraction: fraction()?,
                        extra: opt_usize(obj, "extra", &ctx)?.unwrap_or(1),
                        gap: opt_usize(obj, "gap", &ctx)?.unwrap_or(5),
                    }
                }
                "flaky" => {
                    check_keys(FAIL_COMMON, &["fraction", "period", "prob", "max_events"], "fail = flaky")?;
                    FailurePlan::Flaky {
                        fraction: fraction()?,
                        period: opt_usize(obj, "period", &ctx)?.unwrap_or(5),
                        prob: opt_f64(obj, "prob", &ctx)?.unwrap_or(0.5),
                        max_events: opt_usize(obj, "max_events", &ctx)?.unwrap_or(5),
                    }
                }
                other => {
                    bail!("{ctx}: unknown failure plan '{other}' (single|correlated|cascade|flaky)")
                }
            };
            CellAction::Fail(plan)
        }
    };

    let mode = match opt_str(obj, "mode", &ctx)? {
        None => None,
        Some(s) => {
            Some(RecoveryMode::from_str(&s).map_err(|e| anyhow::anyhow!("{ctx}: mode: {e}"))?)
        }
    };

    let policy = match opt_str(obj, "policy", &ctx)? {
        None => None,
        Some(s) => {
            Some(PolicyMode::from_str(&s).map_err(|e| anyhow::anyhow!("{ctx}: policy: {e}"))?)
        }
    };

    // Per-cell checkpoint override: missing components inherit the
    // scenario-level spec. `checkpoint_mode` is the cell-level spelling
    // of `[checkpoint] mode` ('mode' on a cell is the recovery mode), so
    // one sweep can compare sync and async barriers side by side.
    let has_ck_override = obj.contains_key("interval")
        || obj.contains_key("k")
        || obj.contains_key("selector")
        || obj.contains_key("checkpoint_mode");
    let checkpoint = if has_ck_override {
        let mut sub: BTreeMap<String, Json> = obj
            .iter()
            .filter(|(k, _)| ["interval", "k", "selector"].contains(&k.as_str()))
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        if let Some(m) = obj.get("checkpoint_mode") {
            sub.insert("mode".to_string(), m.clone());
        }
        Some(parse_checkpoint(&Json::Obj(sub), base_ck, &ctx)?)
    } else {
        None
    };

    Ok(CellSpec { label, action, mode, checkpoint, policy })
}

#[cfg(test)]
mod tests {
    use super::*;

    const FIG7ISH: &str = r#"
name = "mini"
model = "synthetic:dim=16,c=0.8"
trials = 4
seed = 7

[checkpoint]
interval = 8
k = 2
selector = "round"

[[cell]]
label = "single full"
fail = "single"
fraction = 0.5
mode = "full"

[[cell]]
label = "cascade"
fail = "cascade"
fraction = 0.25
extra = 2
gap = 3

[[cell]]
label = "rand"
perturb = "random"
norm_log10 = [-2.0, 0.0]
"#;

    #[test]
    fn parses_toml_scenario() {
        let s = Scenario::from_toml_str(FIG7ISH).unwrap();
        assert_eq!(s.name, "mini");
        assert_eq!(s.panels, vec!["synthetic:dim=16,c=0.8".to_string()]);
        assert_eq!(s.trials, 4);
        assert_eq!(s.checkpoint.k, 2);
        assert_eq!(s.checkpoint.selector, Selector::RoundRobin);
        assert_eq!(s.cells.len(), 3);
        assert_eq!(s.cells[0].mode, Some(RecoveryMode::Full));
        assert_eq!(
            s.cells[1].action,
            CellAction::Fail(FailurePlan::Cascade { fraction: 0.25, extra: 2, gap: 3 })
        );
        assert_eq!(
            s.cells[2].action,
            CellAction::Perturb(PerturbSpec::Random {
                norm: NormSpec::LogUniform { lo: -2.0, hi: 0.0 }
            })
        );
    }

    #[test]
    fn toml_json_roundtrip() {
        let a = Scenario::from_toml_str(FIG7ISH).unwrap();
        let json_text = a.to_json().to_string();
        let b = Scenario::from_json_str(&json_text).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn error_messages_name_the_problem() {
        let e = Scenario::from_toml_str("model = \"synthetic\"\n[[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n")
            .unwrap_err();
        assert!(format!("{e:?}").contains("name"), "{e:?}");

        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\nbogus=1\n[[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("bogus"), "{e:?}");

        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[[cell]]\nlabel=\"x\"\nfail=\"meteor\"\nfraction=0.5\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("meteor"), "{e:?}");

        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[[cell]]\nlabel=\"x\"\nfail=\"cascade\"\nfraction=0.5\ngap=0\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("gap"), "{e:?}");

        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[[cell]]\nlabel=\"x\"\nperturb=\"random\"\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("norm"), "{e:?}");

        // Keys from a *different* plan kind are rejected, not ignored.
        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\nperiod=2\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("period"), "{e:?}");
    }

    #[test]
    fn checkpoint_mode_and_storage_parse_and_roundtrip() {
        let s = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[checkpoint]\nmode=\"async\"\n[storage]\nshards=4\n[[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap();
        assert_eq!(s.checkpoint.mode, CheckpointMode::Async);
        assert_eq!(s.storage.shards, 4);
        assert_eq!(s.storage.writers, 4, "writers default to one per shard");
        let again = Scenario::from_json_str(&s.to_json().to_string()).unwrap();
        assert_eq!(s, again);

        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[storage]\nshards=0\n[[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("shards"), "{e:?}");

        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[checkpoint]\nmode=\"background\"\n[[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("background"), "{e:?}");
    }

    #[test]
    fn checkpoint_dir_and_compaction_keys_parse_and_roundtrip() {
        let s = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\ncheckpoint_dir=\"results/s-ckpt\"\n\
             [storage]\nshards=2\ncompact_threshold=0.4\ncompact_min_bytes=4096\n\
             compact_max_bytes_per_pass=65536\ngroup_commit=true\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap();
        assert_eq!(s.checkpoint_dir.as_deref(), Some("results/s-ckpt"));
        assert!((s.storage.compact_threshold - 0.4).abs() < 1e-12);
        assert_eq!(s.storage.compact_min_bytes, 4096);
        assert_eq!(s.storage.compact_max_bytes_per_pass, 65536);
        assert!(s.storage.group_commit);
        let again = Scenario::from_json_str(&s.to_json().to_string()).unwrap();
        assert_eq!(s, again);
        // The dry-run description names the backend and the trigger.
        let desc = s.describe();
        assert!(desc.contains("disk (results/s-ckpt)"), "{desc}");
        assert!(desc.contains("compaction"), "{desc}");
        assert!(desc.contains("group commit"), "{desc}");
        assert!(desc.contains("generational"), "{desc}");
        // group_commit must be a boolean, not a number.
        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[storage]\ngroup_commit=1\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("group_commit"), "{e:?}");

        // Threshold outside [0, 1) is rejected with a named key.
        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[storage]\ncompact_threshold=1.5\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("compact_threshold"), "{e:?}");
        // Unknown storage keys still fail loudly.
        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[storage]\ncompactify=1\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("compactify"), "{e:?}");
    }

    #[test]
    fn cell_checkpoint_override() {
        let s = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\ninterval=4\nk=4\n",
        )
        .unwrap();
        let ck = s.cells[0].checkpoint.unwrap();
        assert_eq!((ck.interval, ck.k), (4, 4));
        assert_eq!(ck.policy().fraction, 0.25);
        // Un-overridden components inherit the scenario default.
        assert_eq!(ck.mode, CheckpointMode::Sync);
    }

    #[test]
    fn cell_checkpoint_mode_override() {
        // One sweep comparing sync vs async barriers side by side: the
        // cell-level `checkpoint_mode` key overrides `[checkpoint] mode`.
        let s = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[checkpoint]\nmode=\"sync\"\n\
             [[cell]]\nlabel=\"sync\"\nfail=\"single\"\nfraction=0.5\n\
             [[cell]]\nlabel=\"async\"\nfail=\"single\"\nfraction=0.5\ncheckpoint_mode=\"async\"\n",
        )
        .unwrap();
        assert!(s.cells[0].checkpoint.is_none());
        let ck = s.cells[1].checkpoint.unwrap();
        assert_eq!(ck.mode, CheckpointMode::Async);
        // Other components inherit the scenario spec.
        assert_eq!(ck.interval, s.checkpoint.interval);
        // And it round-trips through the value model.
        let again = Scenario::from_json_str(&s.to_json().to_string()).unwrap();
        assert_eq!(s, again);
        // A perturbation cell never checkpoints, so the key is rejected.
        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[[cell]]\nlabel=\"x\"\nperturb=\"reset\"\nfraction=0.5\ncheckpoint_mode=\"async\"\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("checkpoint_mode"), "{e:?}");
    }

    #[test]
    fn chaos_and_deploy_keys_parse_and_roundtrip() {
        use crate::chaos::FaultKind;
        let s = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\ndeploy=\"cluster\"\nps_nodes=3\n\
             [storage]\nshards=4\nmax_pending=2\n\
             [[chaos.kill]]\nshard=1\nat=6\n\
             [[chaos.slow]]\nshard=0\nat=4\nuntil=9\ndelay_us=50\n\
             [[chaos.torn]]\nshard=2\nat=8\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap();
        assert_eq!(s.deploy, DeployMode::Cluster);
        assert_eq!(s.ps_nodes, 3);
        assert_eq!(s.storage.max_pending, 2);
        assert_eq!(s.chaos.faults.len(), 3);
        assert_eq!(s.chaos.faults[0].shard, 1);
        assert_eq!(s.chaos.faults[0].kind, FaultKind::Kill { heal_at: None });
        assert_eq!(
            s.chaos.faults[1].kind,
            FaultKind::Slow { until: Some(9), delay_us: 50 }
        );
        assert_eq!(s.chaos.faults[2].kind, FaultKind::TornWrite);
        let again = Scenario::from_json_str(&s.to_json().to_string()).unwrap();
        assert_eq!(s, again);
    }

    #[test]
    fn partition_flaky_fsync_chaos_keys_parse_and_roundtrip() {
        use crate::chaos::FaultKind;
        let s = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[storage]\nshards=4\n\
             [[chaos.partition]]\nshard=0\nat=4\nuntil=12\n\
             [[chaos.flaky]]\nshard=2\nat=6\nperiod=8\ndown_for=3\ncycles=2\n\
             [[chaos.fsync]]\nshard=1\nat=7\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap();
        assert_eq!(s.chaos.faults.len(), 3);
        assert_eq!(s.chaos.faults[0].kind, FaultKind::Partition { until: Some(12) });
        assert_eq!(
            s.chaos.faults[1].kind,
            FaultKind::Flaky { period: 8, down_for: 3, cycles: 2 }
        );
        assert_eq!(s.chaos.faults[2].kind, FaultKind::FsyncFail);
        let again = Scenario::from_json_str(&s.to_json().to_string()).unwrap();
        assert_eq!(s, again);
        // Defaults fill missing flaky parameters.
        let d = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[storage]\nshards=2\n\
             [[chaos.flaky]]\nshard=1\nat=3\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap();
        assert_eq!(
            d.chaos.faults[0].kind,
            FaultKind::Flaky { period: 5, down_for: 2, cycles: 2 }
        );
        // Validation runs through the shared FaultPlan rules: a flaky
        // window overlapping a forever-kill on the only other shard is
        // rejected with a named epoch.
        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[storage]\nshards=2\n\
             [[chaos.kill]]\nshard=0\nat=2\n\
             [[chaos.flaky]]\nshard=1\nat=3\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("down at iteration"), "{e:?}");
        // Unknown per-entry keys are named.
        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[[chaos.partition]]\nshard=0\nat=3\nheal=9\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("heal"), "{e:?}");
    }

    #[test]
    fn parity_and_bitflip_keys_parse_and_roundtrip() {
        use crate::chaos::FaultKind;
        let s = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[storage]\nshards=4\nparity=1\n\
             [[chaos.bitflip]]\nshard=1\nat=6\natom=9\n\
             [[chaos.bitflip]]\nshard=2\nat=8\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap();
        assert_eq!(s.storage.parity, 1);
        assert_eq!(s.chaos.faults.len(), 2);
        assert_eq!(s.chaos.faults[0].kind, FaultKind::Bitflip { atom: 9 });
        // Atom defaults to the shard index, like the CLI grammar.
        assert_eq!(s.chaos.faults[1].kind, FaultKind::Bitflip { atom: 2 });
        let again = Scenario::from_json_str(&s.to_json().to_string()).unwrap();
        assert_eq!(s, again);
        // The dry-run description names the coding.
        assert!(s.describe().contains("erasure coding"), "{}", s.describe());
        // Only single-parity coding exists; m > 1 is rejected by name.
        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[storage]\nshards=4\nparity=2\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("parity"), "{e:?}");
        // Unknown per-entry keys are named.
        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[[chaos.bitflip]]\nshard=0\nat=3\nbit=4\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("bit"), "{e:?}");
    }

    #[test]
    fn replay_chaos_key_parses_and_roundtrips() {
        use crate::chaos::FaultKind;
        let s = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[storage]\nshards=4\n\
             [[chaos.replay]]\nshard=1\nat=7\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap();
        assert_eq!(s.chaos.faults.len(), 1);
        assert_eq!((s.chaos.faults[0].shard, s.chaos.faults[0].at), (1, 7));
        assert_eq!(s.chaos.faults[0].kind, FaultKind::Replay);
        let again = Scenario::from_json_str(&s.to_json().to_string()).unwrap();
        assert_eq!(s, again);
        // Unknown per-entry keys are named.
        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[[chaos.replay]]\nshard=0\nat=3\ntimes=2\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("times"), "{e:?}");
    }

    #[test]
    fn obs_trace_dir_parses_and_roundtrips() {
        let s = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[obs]\ntrace_dir=\"results/traces\"\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap();
        assert_eq!(s.trace_dir.as_deref(), Some("results/traces"));
        assert!(s.describe().contains("tracing"), "{}", s.describe());
        let again = Scenario::from_json_str(&s.to_json().to_string()).unwrap();
        assert_eq!(s, again);
        // Omitted: tracing off, and the recorder stays a no-op.
        let s = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap();
        assert_eq!(s.trace_dir, None);
        // Unknown obs keys fail loudly.
        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[obs]\ntracedir=\"x\"\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("tracedir"), "{e:?}");
    }

    #[test]
    fn scrub_interval_parses_defaults_and_roundtrips() {
        let s = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[storage]\nshards=4\nparity=1\n\
             scrub_interval=8\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap();
        assert_eq!(s.storage.scrub_interval, 8);
        let again = Scenario::from_json_str(&s.to_json().to_string()).unwrap();
        assert_eq!(s, again);
        assert!(s.describe().contains("deep scrub"), "{}", s.describe());

        // Omitted: dirty-only fences with no periodic deep scrub.
        let s = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[storage]\nshards=4\nparity=1\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap();
        assert_eq!(s.storage.scrub_interval, 0);
        assert!(!s.describe().contains("deep scrub"), "{}", s.describe());
    }

    #[test]
    fn chaos_and_deploy_validation_errors() {
        // Fault targeting a shard the store doesn't have.
        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[storage]\nshards=2\n\
             [[chaos.kill]]\nshard=5\nat=3\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("shard 5"), "{e:?}");
        // Unknown chaos key.
        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[[chaos.explode]]\nshard=0\nat=3\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("explode"), "{e:?}");
        // Flaky plans need node revival; the cluster path has none.
        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\ndeploy=\"cluster\"\n\
             [[cell]]\nlabel=\"x\"\nfail=\"flaky\"\nfraction=0.5\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("flaky"), "{e:?}");
        // Perturb cells never run on the cluster path.
        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\ndeploy=\"cluster\"\n\
             [[cell]]\nlabel=\"x\"\nperturb=\"reset\"\nfraction=0.5\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("perturb"), "{e:?}");
        // Bad deploy value names the options.
        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\ndeploy=\"cloud\"\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("cloud"), "{e:?}");
    }

    #[test]
    fn policy_axis_and_advisor_parse_and_roundtrip() {
        let s = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\npolicy=\"static\"\n\
             [advisor]\nwindow=8\ndump_cost_iters=2.0\nhysteresis=0.05\n\
             [[cell]]\nlabel=\"fixed\"\nfail=\"single\"\nfraction=0.5\n\
             [[cell]]\nlabel=\"adaptive\"\nfail=\"single\"\nfraction=0.5\npolicy=\"adaptive\"\n",
        )
        .unwrap();
        assert_eq!(s.policy, PolicyMode::Static);
        assert_eq!(s.advisor.window, 8);
        assert!((s.advisor.dump_cost_iters - 2.0).abs() < 1e-12);
        assert!((s.advisor.hysteresis - 0.05).abs() < 1e-12);
        // Unset advisor keys inherit the controller defaults.
        assert!((s.advisor.lost_fraction - 0.25).abs() < 1e-12);
        assert_eq!(s.cells[0].policy, None);
        assert_eq!(s.cells[1].policy, Some(PolicyMode::Adaptive));
        let desc = s.describe();
        assert!(desc.contains("policy: adaptive"), "{desc}");
        assert!(desc.contains("policy=adaptive"), "{desc}");
        let again = Scenario::from_json_str(&s.to_json().to_string()).unwrap();
        assert_eq!(s, again);

        // The derived controller config carries the cell's base interval.
        let cfg = s.advisor.config(12);
        assert_eq!((cfg.window, cfg.base_interval), (8, 12));

        // Omitted entirely: static, default advisor.
        let d = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap();
        assert_eq!(d.policy, PolicyMode::Static);
        assert_eq!(d.advisor, AdvisorSpec::default());
        assert!(!d.describe().contains("policy: adaptive"));
    }

    #[test]
    fn policy_axis_rejects_bad_values_by_name() {
        // Bad axis value names the options.
        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\npolicy=\"clever\"\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("clever"), "{e:?}");
        // Unknown advisor keys fail loudly.
        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[advisor]\nwindows=8\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("windows"), "{e:?}");
        // Out-of-range hysteresis is rejected by name.
        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n[advisor]\nhysteresis=1.5\n\
             [[cell]]\nlabel=\"x\"\nfail=\"single\"\nfraction=0.5\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("hysteresis"), "{e:?}");
        // A perturbation cell never checkpoints, so the axis is rejected
        // there (like checkpoint_mode).
        let e = Scenario::from_toml_str(
            "name=\"s\"\nmodel=\"synthetic\"\n\
             [[cell]]\nlabel=\"x\"\nperturb=\"reset\"\nfraction=0.5\npolicy=\"adaptive\"\n",
        )
        .unwrap_err();
        assert!(format!("{e:?}").contains("policy"), "{e:?}");
    }

    #[test]
    fn json_front_end_accepts_same_shape() {
        let s = Scenario::from_json_str(
            r#"{"name":"j","model":"synthetic","cells":[{"label":"c","perturb":"reset","fraction":0.5}]}"#,
        )
        .unwrap();
        assert_eq!(s.cells.len(), 1);
        assert_eq!(
            s.cells[0].action,
            CellAction::Perturb(PerturbSpec::Reset { fraction: 0.5 })
        );
    }
}
