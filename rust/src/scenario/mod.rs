//! Scenario engine: declarative failure scenarios and parallel trial
//! sweeps.
//!
//! Every experiment in this repo used to be a bespoke `examples/fig*.rs`
//! driver running 100-trial sweeps serially. This subsystem folds that
//! pattern into data + one engine:
//!
//! * [`spec`] — the [`Scenario`] data model: model panels, horizons,
//!   checkpoint/recovery policy, and a grid of perturbation or
//!   failure-plan cells; constructible from TOML ([`toml`]) or JSON, with
//!   key-level error messages and a lossless
//!   [`to_json`](Scenario::to_json) round-trip.
//! * [`runner`] — the [`ScenarioRunner`-style executor](run_scenario):
//!   traces the unperturbed [`crate::harness::Trajectory`] once per
//!   panel, pre-draws all per-trial randomness, then replays trial
//!   suffixes across a worker-thread pool. Parallel and serial sweeps are
//!   byte-identical on the same seed.
//!
//! End-to-end flow:
//!
//! ```text
//! fig7.toml ──parse──▶ Scenario ──run_scenario──▶ ScenarioReport
//!                         │                           ├─ render()  (tables)
//!                         │                           └─ to_csv()  (per trial)
//!                         └─ cells expand to FailurePlan events /
//!                            Perturb kinds (crate::failure, crate::harness)
//! ```
//!
//! Entry points: `scar run-scenario <file>` on the CLI, the bundled files
//! under `scenarios/`, and the thin `examples/fig{5,6,7}_*.rs` wrappers.

pub mod runner;
pub mod spec;
pub mod toml;

pub use runner::{
    apply_cli_overrides, find_bundled, run_scenario, run_with_default_engine, write_output,
    CellReport, PanelReport, ScenarioReport,
};
pub use spec::{
    CellAction, CellSpec, CheckpointSpec, DeployMode, NormSpec, PerturbSpec, Scenario,
    StorageSpec,
};
