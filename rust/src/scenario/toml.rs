//! Minimal TOML parser for scenario files.
//!
//! The `toml` crate is not in the offline set, so scenario files are
//! parsed with this self-contained implementation, which covers the
//! subset scenario specs use and produces the repo's own
//! [`Json`](crate::util::json::Json) value model — the spec layer
//! ([`super::spec`]) consumes `Json` and therefore accepts TOML and JSON
//! interchangeably.
//!
//! Supported subset:
//! * `#` comments, blank lines;
//! * `[table]` and `[a.b]` headers, `[[array-of-tables]]` headers;
//! * bare, quoted, and dotted keys;
//! * basic `"..."` strings (with `\n \t \r \\ \" \u....` escapes) and
//!   literal `'...'` strings;
//! * integers (with `_` separators), floats, booleans;
//! * single-line arrays `[1, 2, 3]` and inline tables `{ a = 1 }`.
//!
//! Not supported (errors, never silent misparses): multi-line strings,
//! dates/times, multi-line arrays.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Parse TOML text into a [`Json::Obj`]. Errors carry 1-based line
/// numbers.
pub fn parse(text: &str) -> Result<Json, String> {
    let mut root: BTreeMap<String, Json> = BTreeMap::new();
    let mut current: Vec<String> = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx + 1;
        let at = |msg: String| format!("toml line {lineno}: {msg}");
        let line = strip_comment(raw).map_err(&at)?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix("[[") {
            let inner = inner
                .strip_suffix("]]")
                .ok_or_else(|| at("unterminated '[[' table header".to_string()))?;
            let path = parse_key_path(inner).map_err(&at)?;
            push_array_table(&mut root, &path).map_err(&at)?;
            current = path;
        } else if let Some(inner) = line.strip_prefix('[') {
            let inner = inner
                .strip_suffix(']')
                .ok_or_else(|| at("unterminated '[' table header".to_string()))?;
            let path = parse_key_path(inner).map_err(&at)?;
            walk_mut(&mut root, &path).map_err(&at)?;
            current = path;
        } else {
            let eq = find_unquoted_eq(line)
                .ok_or_else(|| at("expected 'key = value'".to_string()))?;
            let keypath = parse_key_path(&line[..eq]).map_err(&at)?;
            let mut vp = ValueParser::new(line[eq + 1..].trim());
            let value = vp.value().map_err(&at)?;
            vp.finish().map_err(&at)?;
            insert(&mut root, &current, &keypath, value).map_err(&at)?;
        }
    }
    Ok(Json::Obj(root))
}

/// Remove a trailing comment, honoring quotes.
fn strip_comment(line: &str) -> Result<&str, String> {
    let bytes = line.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'#' => return Ok(&line[..i]),
            b'"' => {
                i += 1;
                while i < bytes.len() && bytes[i] != b'"' {
                    if bytes[i] == b'\\' {
                        i += 1;
                    }
                    i += 1;
                }
                if i >= bytes.len() {
                    return Err("unterminated string".to_string());
                }
                i += 1;
            }
            b'\'' => {
                i += 1;
                while i < bytes.len() && bytes[i] != b'\'' {
                    i += 1;
                }
                if i >= bytes.len() {
                    return Err("unterminated literal string".to_string());
                }
                i += 1;
            }
            _ => i += 1,
        }
    }
    Ok(line)
}

/// Position of the first `=` outside quotes.
fn find_unquoted_eq(line: &str) -> Option<usize> {
    let bytes = line.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'=' => return Some(i),
            b'"' | b'\'' => {
                let quote = bytes[i];
                i += 1;
                while i < bytes.len() && bytes[i] != quote {
                    if quote == b'"' && bytes[i] == b'\\' {
                        i += 1;
                    }
                    i += 1;
                }
                i += 1;
            }
            _ => i += 1,
        }
    }
    None
}

fn is_bare_key_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_' || c == '-'
}

/// Parse a (possibly dotted, possibly quoted) key path.
fn parse_key_path(s: &str) -> Result<Vec<String>, String> {
    let mut parts = Vec::new();
    let mut rest = s.trim();
    loop {
        if rest.is_empty() {
            return Err("empty key".to_string());
        }
        let (part, after) = if let Some(r) = rest.strip_prefix('"') {
            let end = r.find('"').ok_or_else(|| "unterminated quoted key".to_string())?;
            (r[..end].to_string(), r[end + 1..].trim_start())
        } else if let Some(r) = rest.strip_prefix('\'') {
            let end = r.find('\'').ok_or_else(|| "unterminated quoted key".to_string())?;
            (r[..end].to_string(), r[end + 1..].trim_start())
        } else {
            let end = rest.find(|c: char| !is_bare_key_char(c)).unwrap_or(rest.len());
            if end == 0 {
                return Err(format!("invalid key '{rest}'"));
            }
            (rest[..end].to_string(), rest[end..].trim_start())
        };
        parts.push(part);
        if after.is_empty() {
            return Ok(parts);
        }
        rest = after
            .strip_prefix('.')
            .ok_or_else(|| format!("unexpected characters in key: '{after}'"))?
            .trim_start();
    }
}

/// Descend to (creating as needed) the table at `path`. Array-of-table
/// entries resolve to their most recent element.
fn walk_mut<'a>(
    root: &'a mut BTreeMap<String, Json>,
    path: &[String],
) -> Result<&'a mut BTreeMap<String, Json>, String> {
    let mut cur = root;
    for key in path {
        let entry = cur
            .entry(key.clone())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        cur = match entry {
            Json::Obj(m) => m,
            Json::Arr(a) => match a.last_mut() {
                Some(Json::Obj(m)) => m,
                _ => return Err(format!("'{key}' is not a table array")),
            },
            _ => return Err(format!("'{key}' is already a non-table value")),
        };
    }
    Ok(cur)
}

fn push_array_table(root: &mut BTreeMap<String, Json>, path: &[String]) -> Result<(), String> {
    let (last, parent) = path.split_last().ok_or_else(|| "empty header".to_string())?;
    let map = walk_mut(root, parent)?;
    let entry = map
        .entry(last.clone())
        .or_insert_with(|| Json::Arr(Vec::new()));
    match entry {
        Json::Arr(a) => {
            a.push(Json::Obj(BTreeMap::new()));
            Ok(())
        }
        _ => Err(format!("'{last}' is already a non-array value")),
    }
}

fn insert(
    root: &mut BTreeMap<String, Json>,
    table: &[String],
    keypath: &[String],
    value: Json,
) -> Result<(), String> {
    let (last, key_parent) = keypath.split_last().ok_or_else(|| "empty key".to_string())?;
    let mut full = table.to_vec();
    full.extend_from_slice(key_parent);
    let map = walk_mut(root, &full)?;
    if map.contains_key(last) {
        return Err(format!("duplicate key '{last}'"));
    }
    map.insert(last.clone(), value);
    Ok(())
}

// ---------------------------------------------------------------------------
// Value parser
// ---------------------------------------------------------------------------

struct ValueParser<'a> {
    s: &'a str,
    i: usize,
}

impl<'a> ValueParser<'a> {
    fn new(s: &'a str) -> ValueParser<'a> {
        ValueParser { s, i: 0 }
    }

    // Returns the tail with the *input's* lifetime (not tied to &self),
    // so callers can hold slices across `self.i` advances.
    fn rest(&self) -> &'a str {
        let s = self.s;
        &s[self.i..]
    }

    fn skip_ws(&mut self) {
        while self.rest().starts_with(' ') || self.rest().starts_with('\t') {
            self.i += 1;
        }
    }

    fn finish(&mut self) -> Result<(), String> {
        self.skip_ws();
        if self.rest().is_empty() {
            Ok(())
        } else {
            Err(format!("trailing characters after value: '{}'", self.rest()))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        let rest = self.rest();
        if rest.starts_with('"') {
            self.basic_string()
        } else if rest.starts_with('\'') {
            self.literal_string()
        } else if rest.starts_with('[') {
            self.array()
        } else if rest.starts_with('{') {
            self.inline_table()
        } else if let Some(r) = rest.strip_prefix("true") {
            if r.starts_with(is_bare_key_char) {
                return Err(format!("bad value '{rest}'"));
            }
            self.i += 4;
            Ok(Json::Bool(true))
        } else if let Some(r) = rest.strip_prefix("false") {
            if r.starts_with(is_bare_key_char) {
                return Err(format!("bad value '{rest}'"));
            }
            self.i += 5;
            Ok(Json::Bool(false))
        } else {
            self.number()
        }
    }

    fn basic_string(&mut self) -> Result<Json, String> {
        debug_assert!(self.rest().starts_with('"'));
        self.i += 1;
        let mut out = String::new();
        let mut chars = self.rest().char_indices();
        while let Some((off, c)) = chars.next() {
            match c {
                '"' => {
                    self.i += off + 1;
                    return Ok(Json::Str(out));
                }
                '\\' => match chars.next() {
                    Some((_, 'n')) => out.push('\n'),
                    Some((_, 't')) => out.push('\t'),
                    Some((_, 'r')) => out.push('\r'),
                    Some((_, '\\')) => out.push('\\'),
                    Some((_, '"')) => out.push('"'),
                    Some((uoff, 'u')) => {
                        let hex = self
                            .rest()
                            .get(uoff + 1..uoff + 5)
                            .ok_or_else(|| "bad \\u escape".to_string())?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| "bad \\u escape".to_string())?;
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        for _ in 0..4 {
                            chars.next();
                        }
                    }
                    other => {
                        return Err(format!(
                            "unsupported escape '\\{}'",
                            other.map(|(_, c)| c).unwrap_or(' ')
                        ))
                    }
                },
                c => out.push(c),
            }
        }
        Err("unterminated string".to_string())
    }

    fn literal_string(&mut self) -> Result<Json, String> {
        debug_assert!(self.rest().starts_with('\''));
        self.i += 1;
        match self.rest().find('\'') {
            Some(end) => {
                let out = self.rest()[..end].to_string();
                self.i += end + 1;
                Ok(Json::Str(out))
            }
            None => Err("unterminated literal string".to_string()),
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.i += 1; // '['
        let mut items = Vec::new();
        loop {
            self.skip_ws();
            if let Some(r) = self.rest().strip_prefix(']') {
                let _ = r;
                self.i += 1;
                return Ok(Json::Arr(items));
            }
            items.push(self.value()?);
            self.skip_ws();
            if self.rest().starts_with(',') {
                self.i += 1;
            } else if !self.rest().starts_with(']') {
                return Err(format!("expected ',' or ']' in array, got '{}'", self.rest()));
            }
        }
    }

    fn inline_table(&mut self) -> Result<Json, String> {
        self.i += 1; // '{'
        let mut map = BTreeMap::new();
        loop {
            self.skip_ws();
            if self.rest().starts_with('}') {
                self.i += 1;
                return Ok(Json::Obj(map));
            }
            let eq = find_unquoted_eq(self.rest())
                .ok_or_else(|| "expected 'key = value' in inline table".to_string())?;
            // Keys in inline tables must precede any ',' or '}'.
            let key_str = &self.rest()[..eq];
            if key_str.contains(',') || key_str.contains('}') {
                return Err("expected 'key = value' in inline table".to_string());
            }
            let keypath = parse_key_path(key_str)?;
            if keypath.len() != 1 {
                return Err("dotted keys unsupported in inline tables".to_string());
            }
            self.i += eq + 1;
            let val = self.value()?;
            if map.insert(keypath[0].clone(), val).is_some() {
                return Err(format!("duplicate key '{}' in inline table", keypath[0]));
            }
            self.skip_ws();
            if self.rest().starts_with(',') {
                self.i += 1;
            } else if !self.rest().starts_with('}') {
                return Err(format!(
                    "expected ',' or '}}' in inline table, got '{}'",
                    self.rest()
                ));
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let end = self
            .rest()
            .find(|c: char| !(c.is_ascii_digit() || "+-._eE".contains(c)))
            .unwrap_or(self.rest().len());
        let raw = &self.rest()[..end];
        if raw.is_empty() {
            return Err(format!("bad value '{}'", self.rest()));
        }
        let cleaned: String = raw.chars().filter(|&c| c != '_').collect();
        let n: f64 = cleaned
            .parse()
            .map_err(|_| format!("bad number '{raw}'"))?;
        self.i += end;
        Ok(Json::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_tables() {
        let v = parse(
            r#"
# a scenario
name = "fig7"            # trailing comment
seed = 42
frac = 0.25
deep = true
title = 'literal # not a comment'

[checkpoint]
interval = 10
selector = "priority"

[nested.inner]
x = 1
"#,
        )
        .unwrap();
        assert_eq!(v.get("name").as_str(), Some("fig7"));
        assert_eq!(v.get("seed").as_usize(), Some(42));
        assert_eq!(v.get("frac").as_f64(), Some(0.25));
        assert_eq!(v.get("deep").as_bool(), Some(true));
        assert_eq!(v.get("title").as_str(), Some("literal # not a comment"));
        assert_eq!(v.get("checkpoint").get("interval").as_usize(), Some(10));
        assert_eq!(v.get("nested").get("inner").get("x").as_usize(), Some(1));
    }

    #[test]
    fn parses_arrays_and_array_of_tables() {
        let v = parse(
            r#"
panels = ["a", "b", "c"]
range = [-2.0, 0.0]

[[cell]]
label = "one"
frac = 0.25

[[cell]]
label = "two"
plan = { kind = "cascade", gap = 5 }
"#,
        )
        .unwrap();
        let panels = v.get("panels").as_arr().unwrap();
        assert_eq!(panels.len(), 3);
        assert_eq!(panels[1].as_str(), Some("b"));
        assert_eq!(v.get("range").idx(0).as_f64(), Some(-2.0));
        let cells = v.get("cell").as_arr().unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].get("label").as_str(), Some("one"));
        assert_eq!(cells[1].get("plan").get("gap").as_usize(), Some(5));
    }

    #[test]
    fn dotted_keys_and_quoted_keys() {
        let v = parse("a.b = 1\n\"odd key\" = 2\n").unwrap();
        assert_eq!(v.get("a").get("b").as_usize(), Some(1));
        assert_eq!(v.get("odd key").as_usize(), Some(2));
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#"s = "line\nnext\t\"q\"""#).unwrap();
        assert_eq!(v.get("s").as_str(), Some("line\nnext\t\"q\""));
    }

    #[test]
    fn underscored_and_signed_numbers() {
        let v = parse("big = 1_000_000\nneg = -3\nexp = 1e3\n").unwrap();
        assert_eq!(v.get("big").as_usize(), Some(1_000_000));
        assert_eq!(v.get("neg").as_f64(), Some(-3.0));
        assert_eq!(v.get("exp").as_f64(), Some(1000.0));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("ok = 1\nbad line\n").unwrap_err();
        assert!(e.contains("line 2"), "{e}");
        let e = parse("x = \"unterminated\n").unwrap_err();
        assert!(e.contains("line 1"), "{e}");
        let e = parse("dup = 1\ndup = 2\n").unwrap_err();
        assert!(e.contains("duplicate"), "{e}");
        let e = parse("[t\nx = 1\n").unwrap_err();
        assert!(e.contains("unterminated"), "{e}");
    }

    #[test]
    fn rejects_trailing_garbage_after_value() {
        let e = parse("x = 1 2\n").unwrap_err();
        assert!(e.contains("trailing"), "{e}");
    }
}
