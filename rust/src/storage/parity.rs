//! Stripe-parity codec for erasure-coded shards.
//!
//! A [`crate::storage::ShardedStore`] built with `parity = 1` groups its
//! atoms into *stripes* of `k` members (one per data shard, because atom
//! routing and striping both use modulo arithmetic) and maintains one XOR
//! parity record per stripe in a dedicated parity backend. The parity
//! record is an ordinary atom record whose id is the stripe index, so it
//! rides the existing record codec, CRC, manifest, and compaction
//! machinery unchanged — only its payload is interpreted differently:
//!
//! ```text
//! [0]          k (shard count at encode time; reopen guard)
//! [1 + 3j]     member j's atom id        (j in 0..k)
//! [2 + 3j]     member j's iteration
//! [3 + 3j]     member j's payload length (0 = no member record)
//! [1 + 3k ..]  XOR of member payloads, zero-padded to the longest
//! ```
//!
//! Every meta word is a `u32` bit-cast into the `f32` slot (`enc`/`dec`
//! below), and the XOR region combines raw bit patterns
//! (`f32::from_bits(a.to_bits() ^ b.to_bits())`) — payload floats are
//! only ever copied, never arithmetically combined, so reconstruction is
//! bit-exact: XOR-ing out every surviving member's payload leaves the
//! missing member's exact bits. `0.0f32` is the all-zeros pattern, which
//! is what makes zero-padding the XOR identity.

use anyhow::{bail, Result};

/// Stripe index that atom `atom` belongs to under `k` data shards.
pub fn stripe_of(atom: usize, k: usize) -> usize {
    atom / k
}

/// Slot (member position) of atom `atom` within its stripe.
pub fn slot_of(atom: usize, k: usize) -> usize {
    atom % k
}

/// Bitwise XOR of two f32 payload words. Pure bit manipulation — the
/// result is not a meaningful float until the final XOR restores a real
/// payload word.
pub fn xor_bits(a: f32, b: f32) -> f32 {
    f32::from_bits(a.to_bits() ^ b.to_bits())
}

fn enc(n: usize) -> f32 {
    f32::from_bits(n as u32)
}

fn dec(v: f32) -> usize {
    v.to_bits() as usize
}

/// One stripe's parity state, decoded from (or encodable into) the
/// parity record's payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Stripe {
    k: usize,
    /// Per-slot member metadata: `(atom, iter, len)`. `len == 0` means
    /// the slot has no member record yet.
    meta: Vec<(usize, usize, usize)>,
    /// XOR of the member payloads, zero-padded to the longest member.
    data: Vec<f32>,
}

impl Stripe {
    /// Fresh, empty stripe `stripe` for a `k`-data-shard store: every
    /// slot pre-labelled with its member atom id, no payload bits yet.
    pub fn new(k: usize, stripe: usize) -> Stripe {
        Stripe {
            k,
            meta: (0..k).map(|j| (stripe * k + j, 0, 0)).collect(),
            data: Vec::new(),
        }
    }

    /// Decode a parity record payload. The embedded shard count must
    /// match `k`: a mismatch means the store was reopened with a
    /// different shard layout, for which the stripe geometry (and so
    /// every XOR) would be wrong.
    pub fn from_payload(payload: &[f32], k: usize) -> Result<Stripe> {
        if payload.is_empty() {
            bail!("parity record is empty");
        }
        let rec_k = dec(payload[0]);
        if rec_k != k {
            bail!("parity record encoded for {rec_k} data shards, store has {k}");
        }
        let head = 1 + 3 * k;
        if payload.len() < head {
            bail!("parity record truncated: {} < {head} meta words", payload.len());
        }
        let meta = (0..k)
            .map(|j| {
                (
                    dec(payload[1 + 3 * j]),
                    dec(payload[2 + 3 * j]),
                    dec(payload[3 + 3 * j]),
                )
            })
            .collect();
        Ok(Stripe { k, meta, data: payload[head..].to_vec() })
    }

    /// Serialize into the parity record payload (the layout in the
    /// module doc).
    pub fn payload(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(1 + 3 * self.k + self.data.len());
        out.push(enc(self.k));
        for &(atom, iter, len) in &self.meta {
            out.push(enc(atom));
            out.push(enc(iter));
            out.push(enc(len));
        }
        out.extend_from_slice(&self.data);
        out
    }

    /// Member metadata `(atom, iter, len)` for `slot`.
    pub fn member(&self, slot: usize) -> (usize, usize, usize) {
        self.meta[slot]
    }

    /// Record that `slot`'s member now holds a `len`-word payload saved
    /// at `iter`. The atom id is fixed by the stripe geometry.
    pub fn set_member(&mut self, slot: usize, iter: usize, len: usize) {
        self.meta[slot].1 = iter;
        self.meta[slot].2 = len;
    }

    /// True when no slot has a member record (nothing to persist).
    pub fn is_empty(&self) -> bool {
        self.meta.iter().all(|&(_, _, len)| len == 0)
    }

    /// XOR `vals` into the parity region, growing it (zero-padded) if
    /// `vals` is the longest member seen so far. XOR is its own inverse,
    /// so the same call both adds a member payload and removes it — the
    /// incremental update on overwrite is `xor(old); xor(new)`.
    pub fn xor(&mut self, vals: &[f32]) {
        if self.data.len() < vals.len() {
            self.data.resize(vals.len(), 0.0);
        }
        for (d, v) in self.data.iter_mut().zip(vals) {
            *d = xor_bits(*d, *v);
        }
    }

    /// The raw XOR region (longest-member length, zero-padded).
    pub fn data(&self) -> &[f32] {
        &self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_roundtrips_through_the_payload() {
        let mut s = Stripe::new(4, 7);
        s.set_member(0, 12, 5);
        s.set_member(3, 9, 3);
        s.xor(&[1.5, -2.25, f32::NAN, 0.0, 1e-38]);
        let back = Stripe::from_payload(&s.payload(), 4).unwrap();
        assert_eq!(back.member(0), (28, 12, 5));
        assert_eq!(back.member(1), (29, 0, 0));
        assert_eq!(back.member(3), (31, 9, 3));
        // Bit-for-bit, including the NaN.
        let (a, b): (Vec<u32>, Vec<u32>) = (
            s.data().iter().map(|v| v.to_bits()).collect(),
            back.data().iter().map(|v| v.to_bits()).collect(),
        );
        assert_eq!(a, b);
    }

    #[test]
    fn shard_count_mismatch_is_an_error() {
        let s = Stripe::new(2, 0);
        let err = Stripe::from_payload(&s.payload(), 4).unwrap_err();
        assert!(err.to_string().contains("2 data shards"), "{err}");
    }

    #[test]
    fn xor_reconstructs_a_missing_member_bit_exactly() {
        let members: Vec<Vec<f32>> = vec![
            vec![0.1, -7.5, 3.25],
            vec![42.0],
            vec![f32::INFINITY, f32::MIN_POSITIVE, -0.0, 9.0],
        ];
        let mut s = Stripe::new(3, 0);
        for (j, m) in members.iter().enumerate() {
            s.xor(m);
            s.set_member(j, 1, m.len());
        }
        // Lose member 2; XOR the survivors back out.
        let mut acc = s.data().to_vec();
        for m in &members[..2] {
            let mut padded = m.clone();
            padded.resize(acc.len(), 0.0);
            for (a, v) in acc.iter_mut().zip(&padded) {
                *a = xor_bits(*a, *v);
            }
        }
        acc.truncate(s.member(2).2);
        let (got, want): (Vec<u32>, Vec<u32>) = (
            acc.iter().map(|v| v.to_bits()).collect(),
            members[2].iter().map(|v| v.to_bits()).collect(),
        );
        assert_eq!(got, want);
    }

    #[test]
    fn incremental_overwrite_matches_fresh_encode() {
        // xor(old); xor(new) on a live stripe == re-encoding from scratch.
        let old = vec![1.0f32, 2.0, 3.0];
        let new = vec![-4.5f32, 0.25, 6.0, 7.5];
        let other = vec![10.0f32, 20.0];

        let mut incremental = Stripe::new(2, 1);
        incremental.xor(&other);
        incremental.set_member(0, 1, other.len());
        incremental.xor(&old);
        incremental.set_member(1, 1, old.len());
        incremental.xor(&old); // remove the superseded payload
        incremental.xor(&new);
        incremental.set_member(1, 2, new.len());

        let mut fresh = Stripe::new(2, 1);
        fresh.xor(&other);
        fresh.set_member(0, 1, other.len());
        fresh.xor(&new);
        fresh.set_member(1, 2, new.len());

        assert_eq!(
            incremental.payload().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            fresh.payload().iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }
}
