//! Sharded checkpoint storage: N independent [`ShardBackend`] instances
//! behind one router, plus the commit watermark the async write pipeline
//! needs.
//!
//! Atom records are routed by atom id — either `atom % n_shards` (the
//! default) or through an explicit per-atom map derived from the PS
//! [`Partition`](crate::partition::Partition), so each PS node's atoms
//! land in that node's shard (the paper's Fig 4 layout, where every node
//! streams its own slice of the running checkpoint to shared storage).
//!
//! Reads scan every shard and return the freshest record. That makes the
//! router correct across re-partitions: after a failure moves atoms to a
//! surviving node (and therefore to a different shard), older records in
//! the original shard are still found and superseded by iteration number,
//! never by routing accidents.
//!
//! **Degraded mode** (chaos subsystem): a shard reporting
//! [`is_down`](crate::storage::ShardBackend::is_down) — an injected fault
//! from [`crate::chaos`] — is routed around: its batches re-route to the
//! first surviving shard, reads skip it, and `sync_all` ignores it. A
//! *partitioned* shard ([`is_writable`](crate::storage::ShardBackend::is_writable)
//! false — reachable but unwritable) is routed around for writes only;
//! reads still serve from it, so nothing needs rebuilding. The
//! freshest-record read scan makes the re-homing invisible to callers.
//!
//! The **placement map** tracks, per atom, which shard holds its
//! freshest routed record (updated on every put, including degraded
//! re-routes; compaction never moves records between shards). When a
//! shard dies, the checkpoint front-end consults it through the
//! [`RebuildPlan`](crate::recovery::RebuildPlan) planner and re-persists
//! *only the dead shard's slice* from its in-memory cache — roughly
//! `1/n_shards` of the checkpoint instead of the whole thing — so no
//! atom is left without a readable record, at minimal write
//! amplification. Healed shards re-adopt their slices the same way.
//!
//! The **commit watermark** is the recovery rule for pipelined writes:
//! `committed()` is the highest iteration whose barrier the writer pool
//! has fully flushed. Recovery refuses to read a record newer than the
//! watermark (see [`crate::recovery::recover`]); the
//! [`AsyncCheckpointer`](crate::checkpoint::AsyncCheckpointer)'s `flush`
//! fence drains the pool and advances it, which is what makes async and
//! sync checkpointing byte-identical at recovery time.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::{CompactionStats, DiskStore, LatencyModel, MemStore, SavedAtom, ShardBackend};
use crate::partition::Partition;

/// What one fault-clock tick changed about shard health (returned by
/// [`ShardedStore::advance_epoch`]): the checkpoint front-end rebuilds
/// the `newly_down` shards' slices from its cache, and re-adopts the
/// `newly_healed` shards' slices back onto them — both through the
/// [`RebuildPlan`](crate::recovery::RebuildPlan) planner.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EpochReport {
    /// Shards that went down since the last tick.
    pub newly_down: Vec<usize>,
    /// Shards that came back up since the last tick (a flaky shard's
    /// heal, or a `heal_at` kill window ending).
    pub newly_healed: Vec<usize>,
}

pub struct ShardedStore {
    shards: Vec<Mutex<Box<dyn ShardBackend>>>,
    /// Explicit per-atom shard map (empty = route by `atom % n_shards`).
    route: Mutex<Vec<usize>>,
    /// Placement map: per atom, `(shard, iter)` of the freshest record
    /// *routed through this handle* — maintained on every put (including
    /// degraded re-routes), it is what lets the recovery planner rebuild
    /// exactly a dead shard's slice instead of the whole checkpoint.
    /// Compaction never moves a record between shards, so placement
    /// survives it; a store reopened from disk starts with an empty map
    /// (unknown placement is treated as possibly-lost by the planner).
    placement: Mutex<Vec<Option<(usize, usize)>>>,
    /// Commit watermark; `None` until the first `mark_committed`.
    committed: Mutex<Option<usize>>,
    /// Last-observed per-shard health, updated by
    /// [`advance_epoch`](ShardedStore::advance_epoch) so a kill is
    /// reported newly-down exactly once.
    down: Mutex<Vec<bool>>,
    /// Records written through degraded routing (home shard down,
    /// re-routed to a survivor).
    degraded: AtomicU64,
    /// Compaction passes run across all shards (via
    /// [`compact_if_needed`](ShardedStore::compact_if_needed)).
    compaction_runs: AtomicU64,
    /// Segment bytes reclaimed by those passes.
    compaction_reclaimed: AtomicU64,
    latency: LatencyModel,
}

impl ShardedStore {
    /// `n_shards` in-memory shards (the harness configuration).
    pub fn new_mem(n_shards: usize) -> ShardedStore {
        assert!(n_shards >= 1, "need at least one shard");
        let shards = (0..n_shards)
            .map(|_| Box::new(MemStore::new()) as Box<dyn ShardBackend>)
            .collect();
        ShardedStore::from_backends(shards)
    }

    /// The `n_shards` on-disk backends a disk-backed store routes over,
    /// one `DiskStore` per `dir/shard-NNN/` subdirectory. Exposed so the
    /// chaos subsystem can wrap them
    /// ([`FaultPlan::disk_store`](crate::chaos::FaultPlan::disk_store)).
    pub fn disk_backends(dir: &Path, n_shards: usize) -> Result<Vec<Box<dyn ShardBackend>>> {
        assert!(n_shards >= 1, "need at least one shard");
        let mut backends: Vec<Box<dyn ShardBackend>> = Vec::with_capacity(n_shards);
        for s in 0..n_shards {
            let sub = dir.join(format!("shard-{s:03}"));
            let store = DiskStore::open(&sub)
                .with_context(|| format!("opening shard {s} at {}", sub.display()))?;
            backends.push(Box::new(store));
        }
        Ok(backends)
    }

    /// `n_shards` on-disk shards under `dir/shard-NNN/`.
    pub fn open_disk(dir: &Path, n_shards: usize) -> Result<ShardedStore> {
        Ok(ShardedStore::from_backends(ShardedStore::disk_backends(dir, n_shards)?))
    }

    /// Build from caller-provided backends (tests, custom backends).
    pub fn from_backends(backends: Vec<Box<dyn ShardBackend>>) -> ShardedStore {
        assert!(!backends.is_empty(), "need at least one shard");
        let n = backends.len();
        ShardedStore {
            shards: backends.into_iter().map(Mutex::new).collect(),
            route: Mutex::new(Vec::new()),
            placement: Mutex::new(Vec::new()),
            committed: Mutex::new(None),
            down: Mutex::new(vec![false; n]),
            degraded: AtomicU64::new(0),
            compaction_runs: AtomicU64::new(0),
            compaction_reclaimed: AtomicU64::new(0),
            latency: LatencyModel::default(),
        }
    }

    pub fn with_latency(mut self, latency: LatencyModel) -> ShardedStore {
        self.latency = latency;
        self
    }

    pub fn latency_model(&self) -> LatencyModel {
        self.latency
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Shard an atom's new records are written to.
    pub fn shard_of(&self, atom: usize) -> usize {
        let route = self.route.lock().unwrap();
        match route.get(atom) {
            Some(&s) => s,
            None => atom % self.shards.len(),
        }
    }

    /// Routed shard for each atom, resolved under a single route lock
    /// (the batch form of [`shard_of`](ShardedStore::shard_of)).
    pub fn shard_map(&self, atoms: &[usize]) -> Vec<usize> {
        let n = self.shards.len();
        let route = self.route.lock().unwrap();
        atoms
            .iter()
            .map(|&a| route.get(a).copied().unwrap_or(a % n))
            .collect()
    }

    /// Route each atom to its owning PS node's shard (node id modulo the
    /// shard count). Called at cluster start and again after every
    /// re-partition so new records follow the atom's new owner.
    pub fn set_route_partition(&self, partition: &Partition) {
        let n = self.shards.len();
        let mut route = self.route.lock().unwrap();
        route.clear();
        route.extend(partition.owner.iter().map(|&node| node % n));
    }

    /// Drop any explicit routing (back to `atom % n_shards`).
    pub fn clear_route(&self) {
        self.route.lock().unwrap().clear();
    }

    /// Write records through the router. Shared-reference version used by
    /// the writer pool; grouped so each shard is locked once per call.
    ///
    /// **Degraded mode:** a batch whose home shard is down (injected
    /// fault) re-routes to the first surviving shard after it — the
    /// freshest-record read scan makes placement irrelevant to
    /// correctness, so a dead shard degrades throughput, never data.
    pub fn put_atoms_at(&self, iter: usize, atoms: &[(usize, &[f32])]) -> Result<()> {
        let n = self.shards.len();
        let mut per_shard: Vec<Vec<(usize, &[f32])>> = vec![Vec::new(); n];
        {
            let route = self.route.lock().unwrap();
            for &(atom, vals) in atoms {
                let s = route.get(atom).copied().unwrap_or(atom % n);
                per_shard[s].push((atom, vals));
            }
        }
        for (s, batch) in per_shard.iter().enumerate() {
            if batch.is_empty() {
                continue;
            }
            let target = self.live_target(s)?;
            if target != s {
                self.degraded.fetch_add(batch.len() as u64, Ordering::Relaxed);
            }
            {
                let mut shard = self.shards[target].lock().unwrap();
                shard
                    .put_atoms(iter, batch)
                    .with_context(|| format!("writing {} atoms to shard {target}", batch.len()))?;
            }
            // Placement follows the freshest routed record (ties go to
            // the latest write, so a rebuild/re-adoption copy at the same
            // iteration moves placement to where the readable copy is).
            let mut placement = self.placement.lock().unwrap();
            for &(atom, _) in batch {
                if placement.len() <= atom {
                    placement.resize(atom + 1, None);
                }
                let newer = match placement[atom] {
                    Some((_, have)) => iter >= have,
                    None => true,
                };
                if newer {
                    placement[atom] = Some((target, iter));
                }
            }
        }
        Ok(())
    }

    /// First *writable* serving shard at or after `s` (wrapping), for
    /// degraded writes: both dead shards and partitioned
    /// (reachable-but-unwritable) shards are routed around. Errors only
    /// when no shard accepts writes.
    fn live_target(&self, s: usize) -> Result<usize> {
        let n = self.shards.len();
        for off in 0..n {
            let t = (s + off) % n;
            let guard = self.shards[t].lock().unwrap();
            if !guard.is_down() && guard.is_writable() {
                return Ok(t);
            }
        }
        bail!("all {n} storage shard(s) are down or unwritable (injected faults)");
    }

    /// Advance every shard's injected-fault clock to training iteration
    /// `iter`; reports health transitions since the last call — the
    /// checkpoint front-end rebuilds newly-down shards' slices from its
    /// in-memory cache and re-adopts newly-healed shards' slices back
    /// onto them (see [`crate::checkpoint::AsyncCheckpointer`] and
    /// [`crate::recovery::RebuildPlan`]).
    pub fn advance_epoch(&self, iter: usize) -> EpochReport {
        let mut report = EpochReport::default();
        let mut down = self.down.lock().unwrap();
        for (s, shard) in self.shards.iter().enumerate() {
            let mut guard = shard.lock().unwrap();
            guard.advance_epoch(iter);
            let d = guard.is_down();
            if d && !down[s] {
                report.newly_down.push(s);
            }
            if !d && down[s] {
                report.newly_healed.push(s);
            }
            down[s] = d;
        }
        report
    }

    /// Shards currently refusing service.
    pub fn down_shards(&self) -> Vec<usize> {
        self.shards
            .iter()
            .enumerate()
            .filter(|(_, s)| s.lock().unwrap().is_down())
            .map(|(s, _)| s)
            .collect()
    }

    /// Shards currently refusing *writes* while still serving reads (an
    /// injected network partition). Down shards are not listed — they
    /// refuse everything.
    pub fn unwritable_shards(&self) -> Vec<usize> {
        self.shards
            .iter()
            .enumerate()
            .filter(|(_, s)| {
                let guard = s.lock().unwrap();
                !guard.is_down() && !guard.is_writable()
            })
            .map(|(s, _)| s)
            .collect()
    }

    /// Shard holding the freshest record routed through this handle for
    /// `atom` (`None` when nothing was written for it through this
    /// handle — e.g. a store reopened from disk).
    pub fn placement_of(&self, atom: usize) -> Option<usize> {
        self.placement.lock().unwrap().get(atom).copied().flatten().map(|(s, _)| s)
    }

    /// Snapshot of the whole placement map (shard of each atom's
    /// freshest routed record), the planner's input. Indices past the
    /// highest atom ever written read as `None`.
    pub fn placement_shards(&self) -> Vec<Option<usize>> {
        self.placement
            .lock()
            .unwrap()
            .iter()
            .map(|p| p.map(|(s, _)| s))
            .collect()
    }

    /// Records written through degraded (re-routed) paths so far.
    ///
    /// Observability only, not part of the determinism contract: with
    /// async writers, whether a pre-kill in-flight job re-routes depends
    /// on when the pool dequeues it relative to the fault clock, so the
    /// exact count can vary run to run (the *content* of the store never
    /// does — identical records land either way).
    pub fn degraded_records(&self) -> u64 {
        self.degraded.load(Ordering::Relaxed)
    }

    /// Freshest record for an atom across all shards (highest iteration;
    /// ties broken by lowest shard index for determinism). Scanning keeps
    /// reads correct after re-partitions move an atom between shards, and
    /// shards that are down (injected faults) are skipped — the degraded
    /// read path recovery depends on.
    pub fn get_atom_any(&self, atom: usize) -> Result<Option<SavedAtom>> {
        let mut best: Option<SavedAtom> = None;
        for shard in &self.shards {
            let guard = shard.lock().unwrap();
            if guard.is_down() {
                continue;
            }
            if let Some(saved) = guard.get_atom(atom)? {
                let newer = match &best {
                    Some(b) => saved.iter > b.iter,
                    None => true,
                };
                if newer {
                    best = Some(saved);
                }
            }
        }
        Ok(best)
    }

    /// Freshest record for an atom decoded straight into `out` (cleared
    /// first), returning its iteration — the single-copy read path: on
    /// mmap-backed disk shards the payload is decoded directly out of the
    /// mapped segment, so the planner's (and recovery's) slice copy into
    /// `out` is the only copy.
    ///
    /// Byte-equal to [`get_atom_any`](ShardedStore::get_atom_any) by
    /// construction: shards are first ranked by a cheap index peek
    /// ([`ShardBackend::atom_iter`]), and if the winning shard's actual
    /// read disagrees with its peek (a physically corrupt record behind a
    /// stale index entry, repaired by the fallback chain), the owned
    /// full scan is served instead.
    pub fn get_atom_any_ref(&self, atom: usize, out: &mut Vec<f32>) -> Result<Option<usize>> {
        // Rank live shards by their peeked freshest iteration (ties to
        // the lowest shard index, matching the owned scan).
        let mut best: Option<(usize, usize)> = None; // (shard, iter)
        for (s, shard) in self.shards.iter().enumerate() {
            let guard = shard.lock().unwrap();
            if guard.is_down() {
                continue;
            }
            if let Some(it) = guard.atom_iter(atom)? {
                let better = match best {
                    Some((_, have)) => it > have,
                    None => true,
                };
                if better {
                    best = Some((s, it));
                }
            }
        }
        let Some((s, expect)) = best else {
            return Ok(None);
        };
        {
            let guard = self.shards[s].lock().unwrap();
            if !guard.is_down() {
                if let Some(it) = guard.read_atom_into(atom, out)? {
                    if it == expect {
                        return Ok(Some(it));
                    }
                }
            }
        }
        // The peek and the actual read disagreed (corrupt-record
        // fallback): serve the owned scan, which applies the full
        // fallback chain across every shard.
        match self.get_atom_any(atom)? {
            Some(saved) => {
                out.clear();
                out.extend_from_slice(&saved.values);
                Ok(Some(saved.iter))
            }
            None => Ok(None),
        }
    }

    /// Per-shard `(bytes, records)` written so far, for the latency model
    /// (the slowest shard gates a parallel barrier).
    pub fn per_shard_io(&self) -> Vec<(u64, u64)> {
        self.shards
            .iter()
            .map(|s| {
                let guard = s.lock().unwrap();
                (guard.bytes_written(), guard.records_written())
            })
            .collect()
    }

    /// Durability fence across every shard (disk manifests etc.). Down
    /// shards are skipped — their records are unreachable until they
    /// heal, and the rebuilt copies on the survivors are what recovery
    /// reads. Partitioned (unwritable) shards are skipped too: their
    /// manifest catches up at the first fence after the partition lifts.
    ///
    /// Caveat: skipping a partitioned shard means records it accepted
    /// *between its last synced fence and the partition start* are not
    /// manifest-durable until it heals — in-process reads are unaffected
    /// (the segment log has the bytes), but a **crash inside the
    /// window** reopens that shard on its stale manifest, the same
    /// exposure `[[chaos.fsync]]` models deliberately. The no-data-loss
    /// partition contract is an in-process/post-heal property, not a
    /// crash-durability one.
    pub fn sync_all(&self) -> Result<()> {
        for (s, shard) in self.shards.iter().enumerate() {
            let mut guard = shard.lock().unwrap();
            if guard.is_down() || !guard.is_writable() {
                continue;
            }
            guard.sync().with_context(|| format!("syncing shard {s}"))?;
        }
        Ok(())
    }

    /// Advance the commit watermark (monotonic).
    pub fn mark_committed_at(&self, iter: usize) {
        let mut committed = self.committed.lock().unwrap();
        *committed = Some(match *committed {
            Some(old) => old.max(iter),
            None => iter,
        });
    }

    pub fn committed(&self) -> Option<usize> {
        *self.committed.lock().unwrap()
    }

    pub fn total_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().bytes_written()).sum()
    }

    pub fn total_records(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().records_written()).sum()
    }

    /// Bytes the shards' on-disk representation currently occupies
    /// (0 for memory shards; shrinks when compaction runs).
    pub fn total_on_disk_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().on_disk_bytes()).sum()
    }

    /// Per-shard garbage ratios (superseded-record fraction a compaction
    /// pass would reclaim; always 0 for memory shards).
    pub fn garbage_ratios(&self) -> Vec<f64> {
        self.shards.iter().map(|s| s.lock().unwrap().garbage_ratio()).collect()
    }

    /// Compact every live shard whose garbage ratio has reached
    /// `threshold` and whose on-disk size is at least `min_bytes`
    /// (`threshold <= 0` compacts any shard with garbage at all). Down
    /// shards are skipped — their log is unreachable until they heal.
    /// Returns `(shard, stats)` for each pass that ran, and feeds the
    /// `compaction_runs`/`compaction_reclaimed_bytes` counters.
    pub fn compact_if_needed(
        &self,
        threshold: f64,
        min_bytes: u64,
    ) -> Result<Vec<(usize, CompactionStats)>> {
        let mut out = Vec::new();
        for (s, shard) in self.shards.iter().enumerate() {
            let mut guard = shard.lock().unwrap();
            if guard.is_down() || !guard.is_writable() {
                continue;
            }
            let ratio = guard.garbage_ratio();
            if ratio <= 0.0 || ratio < threshold || guard.on_disk_bytes() < min_bytes {
                continue;
            }
            if let Some(stats) =
                guard.compact().with_context(|| format!("compacting shard {s}"))?
            {
                self.compaction_runs.fetch_add(1, Ordering::Relaxed);
                self.compaction_reclaimed.fetch_add(stats.reclaimed_bytes, Ordering::Relaxed);
                out.push((s, stats));
            }
        }
        Ok(out)
    }

    /// Compaction passes run through this router so far.
    pub fn compaction_runs(&self) -> u64 {
        self.compaction_runs.load(Ordering::Relaxed)
    }

    /// Segment bytes reclaimed by those passes.
    pub fn compaction_reclaimed_bytes(&self) -> u64 {
        self.compaction_reclaimed.load(Ordering::Relaxed)
    }
}

impl super::CheckpointStore for ShardedStore {
    fn put_atoms(&mut self, iter: usize, atoms: &[(usize, &[f32])]) -> Result<()> {
        self.put_atoms_at(iter, atoms)
    }

    fn get_atom(&self, atom: usize) -> Result<Option<SavedAtom>> {
        self.get_atom_any(atom)
    }

    fn read_atom_into(&self, atom: usize, out: &mut Vec<f32>) -> Result<Option<usize>> {
        self.get_atom_any_ref(atom, out)
    }

    fn bytes_written(&self) -> u64 {
        self.total_bytes()
    }

    fn records_written(&self) -> u64 {
        self.total_records()
    }

    fn committed_iter(&self) -> Option<usize> {
        self.committed()
    }

    fn mark_committed(&mut self, iter: usize) {
        self.mark_committed_at(iter);
    }

    fn sync(&mut self) -> Result<()> {
        self.sync_all()
    }
}

#[cfg(test)]
mod tests {
    use super::ShardedStore;
    use crate::partition::Partition;
    use crate::util::rng::Rng;

    #[test]
    fn routes_by_modulo_and_reads_back() {
        let s = ShardedStore::new_mem(3);
        s.put_atoms_at(2, &[(0, &[1.0][..]), (1, &[2.0][..]), (5, &[3.0][..])]).unwrap();
        assert_eq!(s.shard_of(5), 2);
        assert_eq!(s.get_atom_any(5).unwrap().unwrap().values, vec![3.0]);
        assert!(s.get_atom_any(7).unwrap().is_none());
        assert_eq!(s.total_records(), 3);
        assert_eq!(s.total_bytes(), 12);
        // Exactly one shard holds each atom.
        let io = s.per_shard_io();
        assert_eq!(io.len(), 3);
        assert_eq!(io.iter().map(|&(_, r)| r).sum::<u64>(), 3);
    }

    #[test]
    fn partition_routing_follows_owners() {
        let mut rng = Rng::new(9);
        let partition = Partition::random(12, 4, &mut rng);
        let s = ShardedStore::new_mem(4);
        s.set_route_partition(&partition);
        for atom in 0..12 {
            assert_eq!(s.shard_of(atom), partition.owner[atom] % 4);
        }
    }

    #[test]
    fn reads_survive_rerouting() {
        // Write under one routing, re-route, write a newer record, and
        // confirm the freshest record wins regardless of which shard
        // holds it — including after routing an atom *back* to a shard
        // that still holds one of its stale records.
        let mut rng = Rng::new(10);
        let mut partition = Partition::random(8, 4, &mut rng);
        let s = ShardedStore::new_mem(2);
        s.set_route_partition(&partition);
        let atoms: Vec<(usize, &[f32])> = (0..8).map(|a| (a, &[1.0f32][..])).collect();
        s.put_atoms_at(1, &atoms).unwrap();

        partition.repartition(&[0, 1]);
        s.set_route_partition(&partition);
        let newer: Vec<(usize, &[f32])> = (0..8).map(|a| (a, &[2.0f32][..])).collect();
        s.put_atoms_at(5, &newer).unwrap();

        for a in 0..8 {
            let got = s.get_atom_any(a).unwrap().unwrap();
            assert_eq!(got.iter, 5, "atom {a}");
            assert_eq!(got.values, vec![2.0]);
        }
    }

    #[test]
    fn placement_tracks_freshest_routed_record() {
        let s = ShardedStore::new_mem(2);
        assert_eq!(s.placement_of(0), None, "nothing written yet");
        s.put_atoms_at(1, &[(0, &[1.0][..]), (1, &[1.0][..]), (2, &[1.0][..])]).unwrap();
        assert_eq!(s.placement_of(0), Some(0));
        assert_eq!(s.placement_of(1), Some(1));
        assert_eq!(s.placement_of(2), Some(0));
        // A newer record re-routed elsewhere moves placement; an *older*
        // record does not (the freshest copy still governs).
        let mut route = Partition::random(3, 1, &mut Rng::new(1));
        route.owner = vec![1, 1, 1];
        route.atoms_of = vec![vec![], vec![0, 1, 2]];
        s.set_route_partition(&route);
        s.put_atoms_at(5, &[(0, &[5.0][..])]).unwrap();
        assert_eq!(s.placement_of(0), Some(1));
        s.clear_route();
        s.put_atoms_at(3, &[(0, &[3.0][..])]).unwrap();
        assert_eq!(s.placement_of(0), Some(1), "older record must not move placement");
        // Same-iteration rewrite (a rebuild/re-adoption copy) does move
        // placement to where the latest copy landed.
        s.put_atoms_at(5, &[(0, &[5.0][..])]).unwrap();
        assert_eq!(s.placement_of(0), Some(0));
        let snapshot = s.placement_shards();
        assert_eq!(snapshot[0], Some(0));
        assert_eq!(snapshot[1], Some(1));
    }

    #[test]
    fn get_atom_any_ref_matches_owned_scan() {
        let s = ShardedStore::new_mem(3);
        s.put_atoms_at(1, &[(0, &[1.0, 2.0][..]), (1, &[3.0][..])]).unwrap();
        s.put_atoms_at(4, &[(1, &[4.0][..])]).unwrap();
        let mut buf = Vec::new();
        for atom in 0..2 {
            let owned = s.get_atom_any(atom).unwrap().unwrap();
            let it = s.get_atom_any_ref(atom, &mut buf).unwrap().unwrap();
            assert_eq!((it, buf.clone()), (owned.iter, owned.values.clone()), "atom {atom}");
        }
        assert_eq!(s.get_atom_any_ref(9, &mut buf).unwrap(), None);
    }

    #[test]
    fn watermark_is_monotonic() {
        let s = ShardedStore::new_mem(1);
        assert_eq!(s.committed(), None);
        s.mark_committed_at(4);
        s.mark_committed_at(2);
        assert_eq!(s.committed(), Some(4));
        s.mark_committed_at(9);
        assert_eq!(s.committed(), Some(9));
    }

    #[test]
    fn disk_shards_roundtrip() {
        let dir = std::env::temp_dir()
            .join(format!("scar-sharded-disk-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let s = ShardedStore::open_disk(&dir, 2).unwrap();
            s.put_atoms_at(3, &[(0, &[1.0][..]), (1, &[2.0, 3.0][..])]).unwrap();
            s.sync_all().unwrap();
        }
        let s = ShardedStore::open_disk(&dir, 2).unwrap();
        assert_eq!(s.get_atom_any(1).unwrap().unwrap().values, vec![2.0, 3.0]);
        assert_eq!(s.total_bytes(), 12);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn compact_if_needed_respects_threshold_and_counts() {
        let dir = std::env::temp_dir()
            .join(format!("scar-sharded-compact-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let s = ShardedStore::open_disk(&dir, 2).unwrap();
        for iter in 1..=6usize {
            s.put_atoms_at(iter, &[(0, &[iter as f32][..]), (1, &[iter as f32 * 2.0][..])])
                .unwrap();
        }
        s.sync_all().unwrap();
        let before = s.total_on_disk_bytes();
        assert!(s.garbage_ratios().iter().all(|&r| r > 0.5), "{:?}", s.garbage_ratios());
        // A threshold above the actual ratios runs nothing.
        assert!(s.compact_if_needed(0.99, 0).unwrap().is_empty());
        assert_eq!(s.compaction_runs(), 0);
        // A min_bytes floor above the shard sizes also runs nothing.
        assert!(s.compact_if_needed(0.5, before * 4).unwrap().is_empty());
        let runs = s.compact_if_needed(0.5, 0).unwrap();
        assert_eq!(runs.len(), 2, "both shards were above the threshold");
        assert!(s.total_on_disk_bytes() < before);
        assert_eq!(s.compaction_runs(), 2);
        assert!(s.compaction_reclaimed_bytes() > 0);
        assert_eq!(s.get_atom_any(0).unwrap().unwrap().values, vec![6.0]);
        assert_eq!(s.get_atom_any(1).unwrap().unwrap().values, vec![12.0]);
        // Memory shards never report garbage, so the trigger is inert.
        let mem = ShardedStore::new_mem(2);
        mem.put_atoms_at(1, &[(0, &[1.0][..])]).unwrap();
        mem.put_atoms_at(2, &[(0, &[2.0][..])]).unwrap();
        assert!(mem.compact_if_needed(0.0, 0).unwrap().is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
