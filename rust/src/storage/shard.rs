//! Sharded checkpoint storage: N independent [`ShardBackend`] instances
//! behind one router, plus the commit watermark the async write pipeline
//! needs.
//!
//! Atom records are routed by atom id — either `atom % n_shards` (the
//! default) or through an explicit per-atom map derived from the PS
//! [`Partition`](crate::partition::Partition), so each PS node's atoms
//! land in that node's shard (the paper's Fig 4 layout, where every node
//! streams its own slice of the running checkpoint to shared storage).
//!
//! Reads scan every shard and return the freshest record. That makes the
//! router correct across re-partitions: after a failure moves atoms to a
//! surviving node (and therefore to a different shard), older records in
//! the original shard are still found and superseded by iteration number,
//! never by routing accidents.
//!
//! **Degraded mode** (chaos subsystem): a shard reporting
//! [`is_down`](crate::storage::ShardBackend::is_down) — an injected fault
//! from [`crate::chaos`] — is routed around: its batches re-route to the
//! first surviving shard, reads skip it, and `sync_all` ignores it. A
//! *partitioned* shard ([`is_writable`](crate::storage::ShardBackend::is_writable)
//! false — reachable but unwritable) is routed around for writes only;
//! reads still serve from it, so nothing needs rebuilding. The
//! freshest-record read scan makes the re-homing invisible to callers.
//!
//! The **placement map** tracks, per atom, which shard holds its
//! freshest routed record (updated on every put, including degraded
//! re-routes; compaction never moves records between shards). When a
//! shard dies, the checkpoint front-end consults it through the
//! [`RebuildPlan`](crate::recovery::RebuildPlan) planner and re-persists
//! *only the dead shard's slice* from its in-memory cache — roughly
//! `1/n_shards` of the checkpoint instead of the whole thing — so no
//! atom is left without a readable record, at minimal write
//! amplification. Healed shards re-adopt their slices the same way.
//! Disk-backed stores persist the map as a `placement.json` sidecar at
//! every durability fence and reload it on open (each entry validated
//! against the shard's actual index), so the first post-restart shard
//! death plans a selective rebuild instead of conservatively rebuilding
//! everything.
//!
//! **Erasure coding** (`storage.parity = 1`): atoms are grouped into
//! *stripes* of `n_shards` members — one per data shard, since striping
//! and routing share the modulo arithmetic — and each stripe maintains
//! an XOR parity record in a dedicated parity backend (see
//! [`crate::storage::parity`]). Every put incrementally updates the
//! stripe (XOR the superseded payload out, the new one in), and the
//! [`parity_fence`](ShardedStore::parity_fence) run at each flush
//! barrier scrubs damaged members (CRC-failed records are *repaired in
//! place* from parity, not fallen back from) and re-encodes parity from
//! the settled store state. A cold-restarted store can then rebuild a
//! dead shard's slice from the survivors alone — no warm checkpointer
//! cache — via [`reconstruct_atom`](ShardedStore::reconstruct_atom).
//!
//! The **commit watermark** is the recovery rule for pipelined writes:
//! `committed()` is the highest iteration whose barrier the writer pool
//! has fully flushed. Recovery refuses to read a record newer than the
//! watermark (see [`crate::recovery::recover`]); the
//! [`AsyncCheckpointer`](crate::checkpoint::AsyncCheckpointer)'s `flush`
//! fence drains the pool and advances it, which is what makes async and
//! sync checkpointing byte-identical at recovery time.

use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::parity::{self, Stripe};
use super::{CompactionStats, DiskStore, LatencyModel, MemStore, SavedAtom, ShardBackend};
use crate::partition::Partition;
use crate::util::json::Json;

/// What one fault-clock tick changed about shard health (returned by
/// [`ShardedStore::advance_epoch`]): the checkpoint front-end rebuilds
/// the `newly_down` shards' slices from its cache, and re-adopts the
/// `newly_healed` shards' slices back onto them — both through the
/// [`RebuildPlan`](crate::recovery::RebuildPlan) planner.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EpochReport {
    /// Shards that went down since the last tick.
    pub newly_down: Vec<usize>,
    /// Shards that came back up since the last tick (a flaky shard's
    /// heal, or a `heal_at` kill window ending).
    pub newly_healed: Vec<usize>,
}

pub struct ShardedStore {
    shards: Vec<Mutex<Box<dyn ShardBackend>>>,
    /// Explicit per-atom shard map (empty = route by `atom % n_shards`).
    route: Mutex<Vec<usize>>,
    /// Placement map: per atom, `(shard, iter)` of the freshest record
    /// *routed through this handle* — maintained on every put (including
    /// degraded re-routes), it is what lets the recovery planner rebuild
    /// exactly a dead shard's slice instead of the whole checkpoint.
    /// Compaction never moves a record between shards, so placement
    /// survives it. Disk stores persist the map as a sidecar at each
    /// durability fence and reload it on open; entries that fail
    /// validation (or a missing sidecar) read as `None`, which the
    /// planner treats as possibly-lost.
    placement: Mutex<Vec<Option<(usize, usize)>>>,
    /// Parity backends (`m` of them; currently `m <= 1`, single XOR
    /// parity). Stripe `s` routes to parity backend `s % m`. Excluded
    /// from the byte/record totals — parity is redundancy, not
    /// checkpoint data — but synced and compacted alongside the data
    /// shards.
    parity: Vec<Mutex<Box<dyn ShardBackend>>>,
    /// Disk root this store was opened under (`placement.json` sidecar
    /// and `parity-NNN/` subdirectories live here); `None` for memory
    /// stores.
    dir: Option<PathBuf>,
    /// Records repaired in place from parity (bitflipped/CRC-failed
    /// members and dead-shard members re-persisted by the scrub).
    repaired_records: AtomicU64,
    /// Payload bytes of those repaired records.
    repaired_bytes: AtomicU64,
    /// Payload bytes of parity records written at encode fences.
    parity_bytes: AtomicU64,
    /// Stripes whose incremental parity is known stale: a member was
    /// overwritten while the record carrying its previous contribution
    /// was unreadable (dead shard, bitflip), so the XOR
    /// read-modify-write could not remove it. Reconstructing from a
    /// stale stripe would fabricate bytes, so the scrub refuses it (a
    /// clean error if another member is also unreadable — that really
    /// is more damage than single parity absorbs); the fence re-encode
    /// washes the set clean.
    dirty_stripes: Mutex<HashSet<usize>>,
    /// Stripes touched since the last parity fence: every parity
    /// read-modify-write marks its stripe here, as do injected
    /// corruptions and the media-error notifications drained from the
    /// backends at each epoch advance
    /// ([`ShardBackend::take_corruptions`]). The fence's dirty-only mode
    /// scrubs and re-encodes exactly this set — O(stripes touched), not
    /// O(state) — and the quarantine set above is always a subset (its
    /// only insert site also writes a parity record, which marks the
    /// stripe here).
    fence_dirty: Mutex<HashSet<usize>>,
    /// Every `scrub_interval`-th fence widens to a full-state deep scrub
    /// (`0` = dirty-only always): the periodic safety net against decay
    /// no backend reported.
    scrub_interval: usize,
    /// Parity fences run so far (drives the deep-scrub cadence).
    fences_run: AtomicU64,
    /// Threads a fence pass may fan its per-stripe work over (`1` =
    /// serial; the async checkpointer sets this to its writer-pool
    /// width). Stripes are disjoint work units — distinct parity
    /// records, distinct member atoms — so the fan-out is
    /// byte-identical to the serial pass.
    fence_workers: AtomicUsize,
    /// Stripes visited by scrub passes / parity records written by
    /// encode passes: the deterministic per-fence work counters the
    /// bench harness gates on (wall-clock is too noisy for CI).
    stripes_scrubbed: AtomicU64,
    stripes_reencoded: AtomicU64,
    /// Set when a placement entry actually changes value, cleared when
    /// the sidecar is persisted — a fence without puts does no sidecar
    /// I/O.
    placement_dirty: AtomicBool,
    /// Sidecar files actually written (the pin for the above).
    sidecar_writes: AtomicU64,
    /// Commit watermark; `None` until the first `mark_committed`.
    committed: Mutex<Option<usize>>,
    /// Last-observed per-shard health, updated by
    /// [`advance_epoch`](ShardedStore::advance_epoch) so a kill is
    /// reported newly-down exactly once.
    down: Mutex<Vec<bool>>,
    /// Records written through degraded routing (home shard down,
    /// re-routed to a survivor).
    degraded: AtomicU64,
    /// Compaction passes run across all shards (via
    /// [`compact_if_needed`](ShardedStore::compact_if_needed)).
    compaction_runs: AtomicU64,
    /// Segment bytes reclaimed by those passes.
    compaction_reclaimed: AtomicU64,
    /// Segments folded by generational (budgeted) passes.
    segments_compacted: AtomicU64,
    /// Segment bytes read by those passes (the budgeted quantity).
    compact_pass_bytes: AtomicU64,
    latency: LatencyModel,
}

impl ShardedStore {
    /// `n_shards` in-memory shards (the harness configuration).
    pub fn new_mem(n_shards: usize) -> ShardedStore {
        assert!(n_shards >= 1, "need at least one shard");
        let shards = (0..n_shards)
            .map(|_| Box::new(MemStore::new()) as Box<dyn ShardBackend>)
            .collect();
        ShardedStore::from_backends(shards)
    }

    /// The `n_shards` on-disk backends a disk-backed store routes over,
    /// one `DiskStore` per `dir/shard-NNN/` subdirectory. Exposed so the
    /// chaos subsystem can wrap them
    /// ([`FaultPlan::disk_store`](crate::chaos::FaultPlan::disk_store)).
    pub fn disk_backends(dir: &Path, n_shards: usize) -> Result<Vec<Box<dyn ShardBackend>>> {
        assert!(n_shards >= 1, "need at least one shard");
        let mut backends: Vec<Box<dyn ShardBackend>> = Vec::with_capacity(n_shards);
        for s in 0..n_shards {
            let sub = dir.join(format!("shard-{s:03}"));
            let store = DiskStore::open(&sub)
                .with_context(|| format!("opening shard {s} at {}", sub.display()))?;
            backends.push(Box::new(store));
        }
        Ok(backends)
    }

    /// `n_shards` on-disk shards under `dir/shard-NNN/`. Parity shards a
    /// previous handle created under `dir/parity-NNN/` are reattached
    /// automatically — a cold restart must find its redundancy without
    /// being told — and the placement sidecar is reloaded.
    pub fn open_disk(dir: &Path, n_shards: usize) -> Result<ShardedStore> {
        let mut store = ShardedStore::from_backends(ShardedStore::disk_backends(dir, n_shards)?);
        let mut m = 0;
        while dir.join(format!("parity-{m:03}")).is_dir() {
            m += 1;
        }
        if m > 0 {
            store = store.with_disk_parity(dir, m)?;
        }
        Ok(store.with_placement_dir(dir))
    }

    /// Build from caller-provided backends (tests, custom backends).
    pub fn from_backends(backends: Vec<Box<dyn ShardBackend>>) -> ShardedStore {
        assert!(!backends.is_empty(), "need at least one shard");
        let n = backends.len();
        ShardedStore {
            shards: backends.into_iter().map(Mutex::new).collect(),
            route: Mutex::new(Vec::new()),
            placement: Mutex::new(Vec::new()),
            committed: Mutex::new(None),
            down: Mutex::new(vec![false; n]),
            degraded: AtomicU64::new(0),
            compaction_runs: AtomicU64::new(0),
            compaction_reclaimed: AtomicU64::new(0),
            segments_compacted: AtomicU64::new(0),
            compact_pass_bytes: AtomicU64::new(0),
            parity: Vec::new(),
            dir: None,
            repaired_records: AtomicU64::new(0),
            repaired_bytes: AtomicU64::new(0),
            parity_bytes: AtomicU64::new(0),
            dirty_stripes: Mutex::new(HashSet::new()),
            fence_dirty: Mutex::new(HashSet::new()),
            scrub_interval: 0,
            fences_run: AtomicU64::new(0),
            fence_workers: AtomicUsize::new(1),
            stripes_scrubbed: AtomicU64::new(0),
            stripes_reencoded: AtomicU64::new(0),
            placement_dirty: AtomicBool::new(false),
            sidecar_writes: AtomicU64::new(0),
            latency: LatencyModel::default(),
        }
    }

    /// Run a full-state deep scrub every `every`-th parity fence
    /// (`0`, the default, keeps every fence dirty-only).
    pub fn with_scrub_interval(mut self, every: usize) -> ShardedStore {
        self.scrub_interval = every;
        self
    }

    /// Switch every backend (data and parity) to group-commit write
    /// batching: appends buffer in memory and land as one coalesced
    /// write + one durability barrier per shard at each `sync_all`
    /// fence, instead of a barrier per record plus a manifest rewrite.
    /// No-op for memory backends.
    pub fn with_group_commit(self, on: bool) -> ShardedStore {
        for shard in self.shards.iter().chain(self.parity.iter()) {
            shard.lock().unwrap().set_group_commit(on);
        }
        self
    }

    /// Attach `m` in-memory parity backends (XOR erasure coding over
    /// stripes of `n_shards` atoms; see [`crate::storage::parity`]).
    pub fn with_mem_parity(mut self, m: usize) -> ShardedStore {
        assert!(m <= 1, "only single-parity XOR coding (m <= 1) is implemented");
        self.parity = (0..m)
            .map(|_| Mutex::new(Box::new(MemStore::new()) as Box<dyn ShardBackend>))
            .collect();
        self
    }

    /// Attach `m` on-disk parity backends under `dir/parity-NNN/` and
    /// remember `dir` as the store's disk root (for the placement
    /// sidecar).
    pub fn with_disk_parity(mut self, dir: &Path, m: usize) -> Result<ShardedStore> {
        assert!(m <= 1, "only single-parity XOR coding (m <= 1) is implemented");
        let mut parity = Vec::with_capacity(m);
        for p in 0..m {
            let sub = dir.join(format!("parity-{p:03}"));
            let store = DiskStore::open(&sub)
                .with_context(|| format!("opening parity shard {p} at {}", sub.display()))?;
            parity.push(Mutex::new(Box::new(store) as Box<dyn ShardBackend>));
        }
        self.parity = parity;
        self.dir = Some(dir.to_path_buf());
        Ok(self)
    }

    /// Remember `dir` as the store's disk root and reload the placement
    /// sidecar a previous handle persisted there (see
    /// [`sync_all`](ShardedStore::sync_all)). Each entry is validated
    /// against the named shard's actual index — an entry the shard can
    /// no longer honour (e.g. the sidecar outlived a fence the shard's
    /// manifest lost to an fsync fault) is dropped, leaving the planner
    /// conservative rather than wrong.
    pub fn with_placement_dir(mut self, dir: &Path) -> ShardedStore {
        self.dir = Some(dir.to_path_buf());
        self.load_placement(&dir.join("placement.json"));
        self
    }

    fn load_placement(&self, path: &Path) {
        let Ok(text) = std::fs::read_to_string(path) else { return };
        let Ok(v) = Json::parse(&text) else { return };
        let Some(entries) = v.get("placement").as_arr() else { return };
        let mut placement = self.placement.lock().unwrap();
        for e in entries {
            let (Some(atom), Some(shard), Some(iter)) =
                (e.idx(0).as_usize(), e.idx(1).as_usize(), e.idx(2).as_usize())
            else {
                continue;
            };
            if shard >= self.shards.len() {
                continue;
            }
            let honoured = {
                let guard = self.shards[shard].lock().unwrap();
                !guard.is_down()
                    && matches!(guard.atom_iter(atom), Ok(Some(it)) if it >= iter)
            };
            if !honoured {
                continue;
            }
            if placement.len() <= atom {
                placement.resize(atom + 1, None);
            }
            placement[atom] = Some((shard, iter));
        }
    }

    /// Persist the placement map as a JSON sidecar (tmp + rename, like
    /// the shard manifests): `{"placement": [[atom, shard, iter], ...]}`
    /// with only the known entries listed.
    fn persist_placement(&self, dir: &Path) -> Result<()> {
        let entries: Vec<Json> = {
            let placement = self.placement.lock().unwrap();
            placement
                .iter()
                .enumerate()
                .filter_map(|(atom, p)| {
                    p.map(|(shard, iter)| {
                        Json::Arr(vec![
                            Json::from(atom),
                            Json::from(shard),
                            Json::from(iter),
                        ])
                    })
                })
                .collect()
        };
        let v = crate::util::json::obj([("placement", Json::Arr(entries))]);
        let tmp = dir.join("placement.json.tmp");
        std::fs::write(&tmp, v.to_string())?;
        std::fs::rename(&tmp, dir.join("placement.json"))?;
        Ok(())
    }

    pub fn with_latency(mut self, latency: LatencyModel) -> ShardedStore {
        self.latency = latency;
        self
    }

    pub fn latency_model(&self) -> LatencyModel {
        self.latency
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Shard an atom's new records are written to.
    pub fn shard_of(&self, atom: usize) -> usize {
        let route = self.route.lock().unwrap();
        match route.get(atom) {
            Some(&s) => s,
            None => atom % self.shards.len(),
        }
    }

    /// Routed shard for each atom, resolved under a single route lock
    /// (the batch form of [`shard_of`](ShardedStore::shard_of)).
    pub fn shard_map(&self, atoms: &[usize]) -> Vec<usize> {
        let n = self.shards.len();
        let route = self.route.lock().unwrap();
        atoms
            .iter()
            .map(|&a| route.get(a).copied().unwrap_or(a % n))
            .collect()
    }

    /// Route each atom to its owning PS node's shard (node id modulo the
    /// shard count). Called at cluster start and again after every
    /// re-partition so new records follow the atom's new owner.
    pub fn set_route_partition(&self, partition: &Partition) {
        let n = self.shards.len();
        let mut route = self.route.lock().unwrap();
        route.clear();
        route.extend(partition.owner.iter().map(|&node| node % n));
    }

    /// Drop any explicit routing (back to `atom % n_shards`).
    pub fn clear_route(&self) {
        self.route.lock().unwrap().clear();
    }

    /// Write records through the router. Shared-reference version used by
    /// the writer pool; grouped so each shard is locked once per call.
    ///
    /// **Degraded mode:** a batch whose home shard is down (injected
    /// fault) re-routes to the first surviving shard after it — the
    /// freshest-record read scan makes placement irrelevant to
    /// correctness, so a dead shard degrades throughput, never data.
    pub fn put_atoms_at(&self, iter: usize, atoms: &[(usize, &[f32])]) -> Result<()> {
        let n = self.shards.len();
        let mut per_shard: Vec<Vec<(usize, &[f32])>> = vec![Vec::new(); n];
        {
            let route = self.route.lock().unwrap();
            for &(atom, vals) in atoms {
                let s = route.get(atom).copied().unwrap_or(atom % n);
                per_shard[s].push((atom, vals));
            }
        }
        for (s, batch) in per_shard.iter().enumerate() {
            if batch.is_empty() {
                continue;
            }
            let target = self.live_target(s)?;
            if target != s {
                self.degraded.fetch_add(batch.len() as u64, Ordering::Relaxed);
            }
            // Snapshot the payloads these records supersede *before* the
            // put: the incremental parity update below XORs the old
            // contribution out and the new one in.
            let old: Vec<Option<SavedAtom>> = if self.parity.is_empty() {
                Vec::new()
            } else {
                batch.iter().map(|&(atom, _)| self.best_readable(atom)).collect()
            };
            {
                let mut shard = self.shards[target].lock().unwrap();
                shard
                    .put_atoms(iter, batch)
                    .with_context(|| format!("writing {} atoms to shard {target}", batch.len()))?;
            }
            self.update_parity(iter, batch, &old)?;
            self.update_placement(iter, target, batch);
        }
        Ok(())
    }

    /// Re-persist repaired records (parity scrub, cold-restart parity
    /// rebuild). Identical routing/placement behaviour to
    /// [`put_atoms_at`](ShardedStore::put_atoms_at) but *bypasses the
    /// incremental parity update*: a repaired payload is exactly the
    /// contribution parity already holds for that member, so XOR-ing a
    /// fallback "old" value out (the normal path's rule) would corrupt
    /// the stripe. Degraded-routing counters are also left alone —
    /// repairs re-home records by design.
    pub(crate) fn put_atoms_repair(&self, iter: usize, atoms: &[(usize, &[f32])]) -> Result<()> {
        let n = self.shards.len();
        let mut per_shard: Vec<Vec<(usize, &[f32])>> = vec![Vec::new(); n];
        {
            let route = self.route.lock().unwrap();
            for &(atom, vals) in atoms {
                let s = route.get(atom).copied().unwrap_or(atom % n);
                per_shard[s].push((atom, vals));
            }
        }
        for (s, batch) in per_shard.iter().enumerate() {
            if batch.is_empty() {
                continue;
            }
            let target = self.live_target(s)?;
            {
                let mut shard = self.shards[target].lock().unwrap();
                shard.put_atoms(iter, batch).with_context(|| {
                    format!("repairing {} atoms onto shard {target}", batch.len())
                })?;
            }
            self.update_placement(iter, target, batch);
        }
        Ok(())
    }

    /// Placement follows the freshest routed record (ties go to the
    /// latest write, so a rebuild/re-adoption/repair copy at the same
    /// iteration moves placement to where the readable copy is).
    fn update_placement(&self, iter: usize, target: usize, batch: &[(usize, &[f32])]) {
        let mut placement = self.placement.lock().unwrap();
        for &(atom, _) in batch {
            if placement.len() <= atom {
                placement.resize(atom + 1, None);
            }
            let newer = match placement[atom] {
                Some((_, have)) => iter >= have,
                None => true,
            };
            if newer && placement[atom] != Some((target, iter)) {
                placement[atom] = Some((target, iter));
                self.placement_dirty.store(true, Ordering::Release);
            }
        }
    }

    /// First *writable* serving shard at or after `s` (wrapping), for
    /// degraded writes: both dead shards and partitioned
    /// (reachable-but-unwritable) shards are routed around. Errors only
    /// when no shard accepts writes.
    fn live_target(&self, s: usize) -> Result<usize> {
        let n = self.shards.len();
        for off in 0..n {
            let t = (s + off) % n;
            let guard = self.shards[t].lock().unwrap();
            if !guard.is_down() && guard.is_writable() {
                return Ok(t);
            }
        }
        bail!("all {n} storage shard(s) are down or unwritable (injected faults)");
    }

    /// Advance every shard's injected-fault clock to training iteration
    /// `iter`; reports health transitions since the last call — the
    /// checkpoint front-end rebuilds newly-down shards' slices from its
    /// in-memory cache and re-adopts newly-healed shards' slices back
    /// onto them (see [`crate::checkpoint::AsyncCheckpointer`] and
    /// [`crate::recovery::RebuildPlan`]).
    pub fn advance_epoch(&self, iter: usize) -> EpochReport {
        let mut report = EpochReport::default();
        let mut corrupted: Vec<usize> = Vec::new();
        let mut down = self.down.lock().unwrap();
        for (s, shard) in self.shards.iter().enumerate() {
            let mut guard = shard.lock().unwrap();
            guard.advance_epoch(iter);
            corrupted.append(&mut guard.take_corruptions());
            let d = guard.is_down();
            if d && !down[s] {
                report.newly_down.push(s);
            }
            if !d && down[s] {
                report.newly_healed.push(s);
            }
            down[s] = d;
        }
        drop(down);
        // Media-error notifications: the damaged atoms' stripes go into
        // the fence-dirty set so the next dirty-only fence scrubs (and
        // repairs) them even though no write touched their stripe.
        if !self.parity.is_empty() && !corrupted.is_empty() {
            let k = self.shards.len();
            let mut fence_dirty = self.fence_dirty.lock().unwrap();
            for atom in corrupted {
                fence_dirty.insert(parity::stripe_of(atom, k));
            }
        }
        report
    }

    /// Shards currently refusing service.
    pub fn down_shards(&self) -> Vec<usize> {
        self.shards
            .iter()
            .enumerate()
            .filter(|(_, s)| s.lock().unwrap().is_down())
            .map(|(s, _)| s)
            .collect()
    }

    /// Shards currently refusing *writes* while still serving reads (an
    /// injected network partition). Down shards are not listed — they
    /// refuse everything.
    pub fn unwritable_shards(&self) -> Vec<usize> {
        self.shards
            .iter()
            .enumerate()
            .filter(|(_, s)| {
                let guard = s.lock().unwrap();
                !guard.is_down() && !guard.is_writable()
            })
            .map(|(s, _)| s)
            .collect()
    }

    /// Attach a flight-recorder handle to every shard backend (data and
    /// parity). Chaos-wrapped backends narrate their injections, heals,
    /// and replays through it; plain backends drop it (see
    /// [`ShardBackend::set_recorder`]).
    pub fn set_recorder(&self, rec: crate::obs::Recorder) {
        for shard in self.shards.iter().chain(self.parity.iter()) {
            shard.lock().unwrap().set_recorder(rec.clone());
        }
    }

    /// Shard holding the freshest record routed through this handle for
    /// `atom` (`None` when nothing was written for it through this
    /// handle — e.g. a store reopened from disk).
    pub fn placement_of(&self, atom: usize) -> Option<usize> {
        self.placement.lock().unwrap().get(atom).copied().flatten().map(|(s, _)| s)
    }

    /// Snapshot of the whole placement map (shard of each atom's
    /// freshest routed record), the planner's input. Indices past the
    /// highest atom ever written read as `None`.
    pub fn placement_shards(&self) -> Vec<Option<usize>> {
        self.placement
            .lock()
            .unwrap()
            .iter()
            .map(|p| p.map(|(s, _)| s))
            .collect()
    }

    /// Records written through degraded (re-routed) paths so far.
    ///
    /// Observability only, not part of the determinism contract: with
    /// async writers, whether a pre-kill in-flight job re-routes depends
    /// on when the pool dequeues it relative to the fault clock, so the
    /// exact count can vary run to run (the *content* of the store never
    /// does — identical records land either way).
    pub fn degraded_records(&self) -> u64 {
        self.degraded.load(Ordering::Relaxed)
    }

    /// Freshest record for an atom across all shards (highest iteration;
    /// ties broken by lowest shard index for determinism). Scanning keeps
    /// reads correct after re-partitions move an atom between shards, and
    /// shards that are down (injected faults) are skipped — the degraded
    /// read path recovery depends on.
    pub fn get_atom_any(&self, atom: usize) -> Result<Option<SavedAtom>> {
        let mut best: Option<SavedAtom> = None;
        for shard in &self.shards {
            let guard = shard.lock().unwrap();
            if guard.is_down() {
                continue;
            }
            if let Some(saved) = guard.get_atom(atom)? {
                let newer = match &best {
                    Some(b) => saved.iter > b.iter,
                    None => true,
                };
                if newer {
                    best = Some(saved);
                }
            }
        }
        Ok(best)
    }

    /// Freshest record for an atom decoded straight into `out` (cleared
    /// first), returning its iteration — the single-copy read path: on
    /// mmap-backed disk shards the payload is decoded directly out of the
    /// mapped segment, so the planner's (and recovery's) slice copy into
    /// `out` is the only copy.
    ///
    /// Byte-equal to [`get_atom_any`](ShardedStore::get_atom_any) by
    /// construction: shards are first ranked by a cheap index peek
    /// ([`ShardBackend::atom_iter`]), and if the winning shard's actual
    /// read disagrees with its peek (a physically corrupt record behind a
    /// stale index entry, repaired by the fallback chain), the owned
    /// full scan is served instead.
    pub fn get_atom_any_ref(&self, atom: usize, out: &mut Vec<f32>) -> Result<Option<usize>> {
        // Rank live shards by their peeked freshest iteration (ties to
        // the lowest shard index, matching the owned scan).
        let mut best: Option<(usize, usize)> = None; // (shard, iter)
        for (s, shard) in self.shards.iter().enumerate() {
            let guard = shard.lock().unwrap();
            if guard.is_down() {
                continue;
            }
            if let Some(it) = guard.atom_iter(atom)? {
                let better = match best {
                    Some((_, have)) => it > have,
                    None => true,
                };
                if better {
                    best = Some((s, it));
                }
            }
        }
        let Some((s, expect)) = best else {
            return Ok(None);
        };
        {
            let guard = self.shards[s].lock().unwrap();
            if !guard.is_down() {
                if let Some(it) = guard.read_atom_into(atom, out)? {
                    if it == expect {
                        return Ok(Some(it));
                    }
                }
            }
        }
        // The peek and the actual read disagreed (corrupt-record
        // fallback): serve the owned scan, which applies the full
        // fallback chain across every shard.
        match self.get_atom_any(atom)? {
            Some(saved) => {
                out.clear();
                out.extend_from_slice(&saved.values);
                Ok(Some(saved.iter))
            }
            None => Ok(None),
        }
    }

    // -----------------------------------------------------------------
    // Erasure coding (single XOR parity; see crate::storage::parity)
    // -----------------------------------------------------------------

    /// Number of parity backends attached (`m`; 0 = no erasure coding).
    pub fn n_parity(&self) -> usize {
        self.parity.len()
    }

    fn parity_backend_of(&self, stripe: usize) -> &Mutex<Box<dyn ShardBackend>> {
        &self.parity[stripe % self.parity.len()]
    }

    /// Freshest *readable* record for an atom across live data shards:
    /// like [`get_atom_any`](ShardedStore::get_atom_any), but a shard
    /// whose record is unreadable (bitflipped, torn with no fallback) is
    /// skipped instead of failing the scan — the parity machinery's view
    /// of "what can the survivors actually serve".
    fn best_readable(&self, atom: usize) -> Option<SavedAtom> {
        let mut best: Option<SavedAtom> = None;
        for shard in &self.shards {
            let guard = shard.lock().unwrap();
            if guard.is_down() {
                continue;
            }
            if let Ok(Some(saved)) = guard.get_atom(atom) {
                let newer = best.as_ref().map(|b| saved.iter > b.iter).unwrap_or(true);
                if newer {
                    best = Some(saved);
                }
            }
        }
        best
    }

    /// Decode the parity record for `stripe` (`None` when no parity was
    /// ever encoded for it).
    fn read_stripe(&self, stripe: usize) -> Result<Option<Stripe>> {
        let guard = self.parity_backend_of(stripe).lock().unwrap();
        match guard.get_atom(stripe)? {
            Some(rec) => Ok(Some(Stripe::from_payload(&rec.values, self.shards.len())?)),
            None => Ok(None),
        }
    }

    /// Incremental (RAID-4 style) parity maintenance: for each written
    /// record, XOR the superseded contribution out of its stripe and the
    /// new payload in, under the parity backend's lock. XOR is
    /// commutative, so concurrent writer threads converge on the same
    /// final bits regardless of interleaving.
    ///
    /// The superseded contribution can only be removed if the record
    /// carrying it is still readable *exactly as the stripe metadata
    /// recorded it* (same iteration, same length). When it is not — the
    /// member sat on a dead shard, or its record was bitflipped before
    /// the overwrite — the stripe is marked dirty and its XOR region is
    /// left alone: reconstruction from it is refused until the next
    /// fence re-encode rebuilds it from the now-readable store.
    fn update_parity(
        &self,
        iter: usize,
        batch: &[(usize, &[f32])],
        old: &[Option<SavedAtom>],
    ) -> Result<()> {
        if self.parity.is_empty() {
            return Ok(());
        }
        let k = self.shards.len();
        for (&(atom, vals), old) in batch.iter().zip(old) {
            let stripe_id = parity::stripe_of(atom, k);
            let mut guard = self.parity_backend_of(stripe_id).lock().unwrap();
            let mut stripe = match guard.get_atom(stripe_id)? {
                Some(rec) => Stripe::from_payload(&rec.values, k)?,
                None => Stripe::new(k, stripe_id),
            };
            let slot = parity::slot_of(atom, k);
            let (_, had_iter, had_len) = stripe.member(slot);
            let mut dirty = self.dirty_stripes.lock().unwrap();
            let removable = had_len == 0
                || matches!(old, Some(o) if o.iter == had_iter && o.values.len() == had_len);
            if !removable {
                dirty.insert(stripe_id);
            }
            if !dirty.contains(&stripe_id) {
                if had_len > 0 {
                    if let Some(old) = old {
                        stripe.xor(&old.values); // remove the superseded contribution
                    }
                }
                stripe.xor(vals);
            }
            drop(dirty);
            stripe.set_member(slot, iter, vals.len());
            let payload = stripe.payload();
            guard
                .put_atoms(iter, &[(stripe_id, &payload[..])])
                .with_context(|| format!("updating parity for stripe {stripe_id}"))?;
            drop(guard);
            self.fence_dirty.lock().unwrap().insert(stripe_id);
        }
        Ok(())
    }

    /// Reconstruct `atom`'s record from the parity shard and its stripe
    /// co-members *alone* — the target atom's own records are never
    /// read, which is what makes this a cold-restart recovery path.
    /// `None` when no parity record covers the atom; an error when the
    /// stripe has more damage than single parity can absorb.
    pub fn reconstruct_atom(&self, atom: usize) -> Result<Option<SavedAtom>> {
        let mut values = Vec::new();
        Ok(self
            .reconstruct_atom_into(atom, &mut values)?
            .map(|iter| SavedAtom { iter, values }))
    }

    /// Buffer-reusing form of
    /// [`reconstruct_atom`](ShardedStore::reconstruct_atom): the
    /// reconstructed payload is decoded into `out` (cleared first) and
    /// its iteration returned, so a rebuild loop reconstructing a whole
    /// slice pays one buffer, not one allocation per record.
    pub fn reconstruct_atom_into(&self, atom: usize, out: &mut Vec<f32>) -> Result<Option<usize>> {
        if self.parity.is_empty() {
            return Ok(None);
        }
        let k = self.shards.len();
        let stripe_id = parity::stripe_of(atom, k);
        if self.dirty_stripes.lock().unwrap().contains(&stripe_id) {
            bail!(
                "stripe {stripe_id}: parity record is stale (a member was rewritten \
                 while its previous record was unreadable) — re-encode at the next \
                 flush fence before reconstructing atom {atom}"
            );
        }
        let Some(stripe) = self.read_stripe(stripe_id)? else {
            return Ok(None);
        };
        let slot = parity::slot_of(atom, k);
        let (_, iter, len) = stripe.member(slot);
        if len == 0 {
            return Ok(None);
        }
        self.reconstruct_member_into(&stripe, stripe_id, slot, out)?;
        Ok(Some(iter))
    }

    /// XOR every *other* member's readable payload out of the stripe's
    /// parity region, leaving exactly the missing member's bits.
    fn reconstruct_member(&self, stripe: &Stripe, stripe_id: usize, slot: usize) -> Result<Vec<f32>> {
        let mut acc = Vec::new();
        self.reconstruct_member_into(stripe, stripe_id, slot, &mut acc)?;
        Ok(acc)
    }

    /// [`reconstruct_member`](ShardedStore::reconstruct_member) into a
    /// caller-owned buffer (cleared first).
    fn reconstruct_member_into(
        &self,
        stripe: &Stripe,
        stripe_id: usize,
        slot: usize,
        acc: &mut Vec<f32>,
    ) -> Result<()> {
        let k = self.shards.len();
        let (atom, _, len) = stripe.member(slot);
        acc.clear();
        acc.extend_from_slice(stripe.data());
        for co in 0..k {
            if co == slot {
                continue;
            }
            let (co_atom, co_iter, co_len) = stripe.member(co);
            if co_len == 0 {
                continue;
            }
            let saved = self
                .best_readable(co_atom)
                .filter(|s| s.iter == co_iter)
                .with_context(|| {
                    format!(
                        "stripe {stripe_id}: cannot reconstruct atom {atom} from parity: \
                         member atom {co_atom} has no readable record at iteration \
                         {co_iter} (more corruptions than the parity shard can absorb)"
                    )
                })?;
            for (a, v) in acc.iter_mut().zip(&saved.values) {
                *a = parity::xor_bits(*a, *v);
            }
        }
        acc.truncate(len);
        Ok(())
    }

    /// Detect-and-repair pass over every stripe (phase one of the parity
    /// fence): a member whose freshest readable record is older than the
    /// parity metadata says it should be — a bitflipped record, or a
    /// record stranded on a dead shard — is reconstructed from parity
    /// and re-put *in place at its original iteration*. Returns the
    /// number of records repaired. An unrepairable stripe is a hard
    /// error, never silently-wrong parameters.
    pub fn scrub_parity(&self) -> Result<u64> {
        if self.parity.is_empty() {
            return Ok(0);
        }
        self.scrub_stripes(&self.all_stripes())
    }

    /// Every stripe id the store's state currently spans, in ascending
    /// order (the full-scan work list).
    fn all_stripes(&self) -> Vec<usize> {
        let k = self.shards.len();
        let n_atoms = self.placement.lock().unwrap().len();
        let n_stripes = if n_atoms == 0 { 0 } else { parity::stripe_of(n_atoms - 1, k) + 1 };
        (0..n_stripes).collect()
    }

    /// Fan per-stripe fence work over the worker pool as contiguous
    /// chunks of the ascending stripe list, summing each job's count.
    /// Stripes are disjoint work units (distinct parity records,
    /// distinct member atoms; repairs route by atom id), every lock
    /// below is taken one at a time, and XOR accumulation is
    /// commutative — so the fan-out is byte-identical to the serial
    /// pass. Errors surface deterministically too: a worker stops at
    /// its chunk's first failure and chunks are scanned in order, so
    /// the lowest failing stripe's error wins, exactly as in a serial
    /// scan.
    fn for_stripes<F>(&self, stripes: &[usize], job: F) -> Result<u64>
    where
        F: Fn(usize) -> Result<u64> + Sync,
    {
        let workers = self.fence_workers.load(Ordering::Relaxed).max(1).min(stripes.len());
        if workers <= 1 {
            let mut total = 0u64;
            for &stripe_id in stripes {
                total += job(stripe_id)?;
            }
            return Ok(total);
        }
        let chunk = (stripes.len() + workers - 1) / workers;
        let job = &job;
        let results: Vec<Result<u64>> = std::thread::scope(|scope| {
            let handles: Vec<_> = stripes
                .chunks(chunk)
                .map(|part| {
                    scope.spawn(move || -> Result<u64> {
                        let mut total = 0u64;
                        for &stripe_id in part {
                            total += job(stripe_id)?;
                        }
                        Ok(total)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("fence worker panicked")).collect()
        });
        let mut total = 0u64;
        for r in results {
            total += r?;
        }
        Ok(total)
    }

    /// Scrub exactly `stripes` (repairing damaged members in place from
    /// parity), returning the number of records repaired.
    fn scrub_stripes(&self, stripes: &[usize]) -> Result<u64> {
        let dirty: HashSet<usize> = self.dirty_stripes.lock().unwrap().clone();
        self.for_stripes(stripes, |stripe_id| self.scrub_one(stripe_id, &dirty))
    }

    fn scrub_one(&self, stripe_id: usize, dirty: &HashSet<usize>) -> Result<u64> {
        self.stripes_scrubbed.fetch_add(1, Ordering::Relaxed);
        let Some(stripe) = self.read_stripe(stripe_id)? else { return Ok(0) };
        let k = self.shards.len();
        let mut repaired = 0u64;
        for slot in 0..k {
            let (atom, want_iter, len) = stripe.member(slot);
            if len == 0 {
                continue;
            }
            let healthy = matches!(self.best_readable(atom), Some(s) if s.iter >= want_iter);
            if healthy {
                continue;
            }
            if dirty.contains(&stripe_id) {
                bail!(
                    "stripe {stripe_id}: cannot reconstruct atom {atom}: the \
                     stripe's parity went stale when another member was \
                     rewritten while its old record was unreadable — more \
                     corruptions than the parity shard can absorb"
                );
            }
            let values = self.reconstruct_member(&stripe, stripe_id, slot)?;
            self.put_atoms_repair(want_iter, &[(atom, &values[..])])?;
            self.repaired_records.fetch_add(1, Ordering::Relaxed);
            self.repaired_bytes.fetch_add((values.len() * 4) as u64, Ordering::Relaxed);
            repaired += 1;
        }
        Ok(repaired)
    }

    /// Re-encode every stripe's parity from the store's current readable
    /// state (phase two of the parity fence): heals any drift the
    /// incremental updates could not see and normalizes the records so
    /// sync and async pipelines persist byte-identical parity.
    pub fn encode_parity(&self) -> Result<()> {
        if self.parity.is_empty() {
            return Ok(());
        }
        self.encode_stripes(&self.all_stripes())?;
        // Every stripe now reflects the store's readable state: whatever
        // incremental drift was flagged has been overwritten, and no
        // stripe owes the next fence anything.
        self.dirty_stripes.lock().unwrap().clear();
        self.fence_dirty.lock().unwrap().clear();
        Ok(())
    }

    /// Re-encode exactly `stripes` from the store's readable state.
    /// Leaves the dirty bookkeeping to the caller.
    fn encode_stripes(&self, stripes: &[usize]) -> Result<()> {
        self.for_stripes(stripes, |stripe_id| self.encode_one(stripe_id).map(|_| 0u64))?;
        Ok(())
    }

    fn encode_one(&self, stripe_id: usize) -> Result<()> {
        let k = self.shards.len();
        let mut stripe = Stripe::new(k, stripe_id);
        let mut iter = 0usize;
        for slot in 0..k {
            let atom = stripe_id * k + slot;
            if let Some(saved) = self.best_readable(atom) {
                stripe.xor(&saved.values);
                stripe.set_member(slot, saved.iter, saved.values.len());
                iter = iter.max(saved.iter);
            }
        }
        if stripe.is_empty() {
            return Ok(());
        }
        let payload = stripe.payload();
        self.parity_bytes.fetch_add((payload.len() * 4) as u64, Ordering::Relaxed);
        self.stripes_reencoded.fetch_add(1, Ordering::Relaxed);
        let mut guard = self.parity_backend_of(stripe_id).lock().unwrap();
        guard
            .put_atoms(iter, &[(stripe_id, &payload[..])])
            .with_context(|| format!("encoding parity for stripe {stripe_id}"))
    }

    /// The parity fence run at every flush barrier: scrub (repair
    /// damaged members from the parity that still holds their
    /// contribution) then re-encode (rewrite parity from the
    /// now fully-readable store). Ordering matters: the scrub must run
    /// against the pre-repair parity, and the re-encode must run after
    /// repairs. Returns the number of records repaired.
    ///
    /// The pass is **dirty-only**: it visits exactly the stripes touched
    /// since the last fence (writes, injected corruptions, drained
    /// media-error notifications), so a fence after a single-atom update
    /// costs one stripe, not the whole state. Untouched stripes keep
    /// their previous fence's record — already normalized, so sync and
    /// async pipelines stay byte-identical. When
    /// [`with_scrub_interval`](ShardedStore::with_scrub_interval) is set,
    /// every `N`-th fence widens to the full-state scan.
    pub fn parity_fence(&self) -> Result<u64> {
        if self.parity.is_empty() {
            return Ok(0);
        }
        let fence = self.fences_run.fetch_add(1, Ordering::Relaxed) + 1;
        let deep = self.scrub_interval > 0 && fence % (self.scrub_interval as u64) == 0;
        if deep {
            let repaired = self.scrub_parity()?;
            self.encode_parity()?;
            return Ok(repaired);
        }
        let work: Vec<usize> = {
            let fence_dirty = self.fence_dirty.lock().unwrap();
            let mut v: Vec<usize> = fence_dirty.iter().copied().collect();
            v.sort_unstable();
            v
        };
        if work.is_empty() {
            return Ok(0);
        }
        let repaired = self.scrub_stripes(&work)?;
        self.encode_stripes(&work)?;
        // Only the stripes this fence actually settled are washed clean
        // — anything marked while the pass ran stays owed to the next
        // fence. The quarantine set is a subset of the fence-dirty set
        // (see the field docs), so removing the worked stripes from both
        // cannot leave a stale quarantined stripe behind.
        {
            let mut quarantined = self.dirty_stripes.lock().unwrap();
            for s in &work {
                quarantined.remove(s);
            }
        }
        {
            let mut fence_dirty = self.fence_dirty.lock().unwrap();
            for s in &work {
                fence_dirty.remove(s);
            }
        }
        Ok(repaired)
    }

    /// Width of the fence/rebuild worker fan-out (`1` = serial). Set by
    /// the async checkpointer to its writer-pool width; safe to change
    /// between fences.
    pub fn set_fence_workers(&self, workers: usize) {
        self.fence_workers.store(workers.max(1), Ordering::Relaxed);
    }

    pub fn fence_workers(&self) -> usize {
        self.fence_workers.load(Ordering::Relaxed).max(1)
    }

    /// Parity fences run so far.
    pub fn parity_fences(&self) -> u64 {
        self.fences_run.load(Ordering::Relaxed)
    }

    /// Stripes visited by scrub passes so far (the per-fence work the
    /// dirty-only fence keeps proportional to what changed).
    pub fn stripes_scrubbed(&self) -> u64 {
        self.stripes_scrubbed.load(Ordering::Relaxed)
    }

    /// Parity records written by encode passes so far.
    pub fn stripes_reencoded(&self) -> u64 {
        self.stripes_reencoded.load(Ordering::Relaxed)
    }

    /// Placement sidecar files actually written by
    /// [`sync_all`](ShardedStore::sync_all) (a fence with a clean
    /// placement map writes none).
    pub fn sidecar_writes(&self) -> u64 {
        self.sidecar_writes.load(Ordering::Relaxed)
    }

    /// Corrupt `atom`'s latest record on data shard `shard` in place
    /// (delegates to [`ShardBackend::corrupt_record`]) — the soft-error
    /// injection surface the chaos subsystem and the parity tests drive.
    pub fn corrupt_record_on(&self, shard: usize, atom: usize) -> Result<bool> {
        let hit = self.shards[shard].lock().unwrap().corrupt_record(atom)?;
        if hit && !self.parity.is_empty() {
            let stripe = parity::stripe_of(atom, self.shards.len());
            self.fence_dirty.lock().unwrap().insert(stripe);
        }
        Ok(hit)
    }

    /// Records repaired in place from parity so far.
    pub fn repaired_records(&self) -> u64 {
        self.repaired_records.load(Ordering::Relaxed)
    }

    /// Payload bytes of those repaired records.
    pub fn repaired_bytes(&self) -> u64 {
        self.repaired_bytes.load(Ordering::Relaxed)
    }

    /// Payload bytes written to parity backends at encode fences.
    pub fn parity_bytes(&self) -> u64 {
        self.parity_bytes.load(Ordering::Relaxed)
    }

    /// Per-shard `(bytes, records)` written so far, for the latency model
    /// (the slowest shard gates a parallel barrier).
    pub fn per_shard_io(&self) -> Vec<(u64, u64)> {
        self.shards
            .iter()
            .map(|s| {
                let guard = s.lock().unwrap();
                (guard.bytes_written(), guard.records_written())
            })
            .collect()
    }

    /// Durability fence across every shard (disk manifests etc.). Down
    /// shards are skipped — their records are unreachable until they
    /// heal, and the rebuilt copies on the survivors are what recovery
    /// reads. Partitioned (unwritable) shards are skipped too: their
    /// manifest catches up at the first fence after the partition lifts.
    ///
    /// Caveat: skipping a partitioned shard means records it accepted
    /// *between its last synced fence and the partition start* are not
    /// manifest-durable until it heals — in-process reads are unaffected
    /// (the segment log has the bytes), but a **crash inside the
    /// window** reopens that shard on its stale manifest, the same
    /// exposure `[[chaos.fsync]]` models deliberately. The no-data-loss
    /// partition contract is an in-process/post-heal property, not a
    /// crash-durability one.
    pub fn sync_all(&self) -> Result<()> {
        for (s, shard) in self.shards.iter().enumerate() {
            let mut guard = shard.lock().unwrap();
            if guard.is_down() || !guard.is_writable() {
                continue;
            }
            guard.sync().with_context(|| format!("syncing shard {s}"))?;
        }
        for (p, shard) in self.parity.iter().enumerate() {
            let mut guard = shard.lock().unwrap();
            guard.sync().with_context(|| format!("syncing parity shard {p}"))?;
        }
        if let Some(dir) = self.dir.clone() {
            // Rewrite the sidecar only when the map changed since the
            // last persist — a fence without puts does no sidecar I/O.
            if self.placement_dirty.swap(false, Ordering::AcqRel) {
                if let Err(e) = self.persist_placement(&dir) {
                    self.placement_dirty.store(true, Ordering::Release);
                    return Err(e).context("persisting placement sidecar");
                }
                self.sidecar_writes.fetch_add(1, Ordering::Relaxed);
            }
        }
        Ok(())
    }

    /// Advance the commit watermark (monotonic).
    pub fn mark_committed_at(&self, iter: usize) {
        let mut committed = self.committed.lock().unwrap();
        *committed = Some(match *committed {
            Some(old) => old.max(iter),
            None => iter,
        });
    }

    pub fn committed(&self) -> Option<usize> {
        *self.committed.lock().unwrap()
    }

    pub fn total_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().bytes_written()).sum()
    }

    pub fn total_records(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().records_written()).sum()
    }

    /// Bytes the shards' on-disk representation currently occupies
    /// (0 for memory shards; shrinks when compaction runs).
    pub fn total_on_disk_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().on_disk_bytes()).sum()
    }

    /// Per-shard garbage ratios (superseded-record fraction a compaction
    /// pass would reclaim; always 0 for memory shards).
    pub fn garbage_ratios(&self) -> Vec<f64> {
        self.shards.iter().map(|s| s.lock().unwrap().garbage_ratio()).collect()
    }

    /// Compact every live shard whose garbage ratio has reached
    /// `threshold` and whose on-disk size is at least `min_bytes`
    /// (`threshold <= 0` compacts any shard with garbage at all). Down
    /// shards are skipped — their log is unreachable until they heal.
    /// `max_pass_bytes > 0` bounds each shard's pass to a generational
    /// fold of at most that many segment bytes (worst-garbage segments
    /// first); `0` keeps the monolithic full-shard pass. Returns
    /// `(shard, stats)` for each pass that ran, and feeds the
    /// `compaction_runs`/`compaction_reclaimed_bytes`/
    /// `segments_compacted`/`compact_pass_bytes` counters.
    pub fn compact_if_needed(
        &self,
        threshold: f64,
        min_bytes: u64,
        max_pass_bytes: u64,
    ) -> Result<Vec<(usize, CompactionStats)>> {
        let mut out = Vec::new();
        for (s, shard) in self.shards.iter().enumerate() {
            let mut guard = shard.lock().unwrap();
            if guard.is_down() || !guard.is_writable() {
                continue;
            }
            let ratio = guard.garbage_ratio();
            if ratio <= 0.0 || ratio < threshold || guard.on_disk_bytes() < min_bytes {
                continue;
            }
            if let Some(stats) = guard
                .compact(max_pass_bytes)
                .with_context(|| format!("compacting shard {s}"))?
            {
                self.note_compaction(&stats);
                out.push((s, stats));
            }
        }
        // Parity backends churn a superseded record per incremental
        // update, so they compact under the same trigger (reported with
        // shard indices past the data shards).
        let n = self.shards.len();
        for (p, shard) in self.parity.iter().enumerate() {
            let mut guard = shard.lock().unwrap();
            let ratio = guard.garbage_ratio();
            if ratio <= 0.0 || ratio < threshold || guard.on_disk_bytes() < min_bytes {
                continue;
            }
            if let Some(stats) = guard
                .compact(max_pass_bytes)
                .with_context(|| format!("compacting parity shard {p}"))?
            {
                self.note_compaction(&stats);
                out.push((n + p, stats));
            }
        }
        Ok(out)
    }

    fn note_compaction(&self, stats: &CompactionStats) {
        self.compaction_runs.fetch_add(1, Ordering::Relaxed);
        self.compaction_reclaimed.fetch_add(stats.reclaimed_bytes, Ordering::Relaxed);
        self.segments_compacted.fetch_add(stats.segments_compacted as u64, Ordering::Relaxed);
        self.compact_pass_bytes.fetch_add(stats.pass_bytes, Ordering::Relaxed);
    }

    /// Compaction passes run through this router so far.
    pub fn compaction_runs(&self) -> u64 {
        self.compaction_runs.load(Ordering::Relaxed)
    }

    /// Segment bytes reclaimed by those passes.
    pub fn compaction_reclaimed_bytes(&self) -> u64 {
        self.compaction_reclaimed.load(Ordering::Relaxed)
    }

    /// Segments folded by compaction passes so far.
    pub fn segments_compacted(&self) -> u64 {
        self.segments_compacted.load(Ordering::Relaxed)
    }

    /// Segment bytes read by compaction passes so far.
    pub fn compact_pass_bytes(&self) -> u64 {
        self.compact_pass_bytes.load(Ordering::Relaxed)
    }

    /// Durability barriers paid across every backend (data + parity):
    /// per-record appends and manifest rewrites on the per-record path,
    /// one per fenced batch under group commit. 0 for memory shards.
    pub fn total_fsyncs(&self) -> u64 {
        self.shards
            .iter()
            .chain(self.parity.iter())
            .map(|s| s.lock().unwrap().fsyncs())
            .sum()
    }
}

impl super::CheckpointStore for ShardedStore {
    fn put_atoms(&mut self, iter: usize, atoms: &[(usize, &[f32])]) -> Result<()> {
        self.put_atoms_at(iter, atoms)
    }

    fn get_atom(&self, atom: usize) -> Result<Option<SavedAtom>> {
        self.get_atom_any(atom)
    }

    fn read_atom_into(&self, atom: usize, out: &mut Vec<f32>) -> Result<Option<usize>> {
        self.get_atom_any_ref(atom, out)
    }

    fn bytes_written(&self) -> u64 {
        self.total_bytes()
    }

    fn records_written(&self) -> u64 {
        self.total_records()
    }

    fn committed_iter(&self) -> Option<usize> {
        self.committed()
    }

    fn mark_committed(&mut self, iter: usize) {
        self.mark_committed_at(iter);
    }

    fn sync(&mut self) -> Result<()> {
        self.sync_all()
    }
}

#[cfg(test)]
mod tests {
    use super::ShardedStore;
    use crate::partition::Partition;
    use crate::util::rng::Rng;

    #[test]
    fn routes_by_modulo_and_reads_back() {
        let s = ShardedStore::new_mem(3);
        s.put_atoms_at(2, &[(0, &[1.0][..]), (1, &[2.0][..]), (5, &[3.0][..])]).unwrap();
        assert_eq!(s.shard_of(5), 2);
        assert_eq!(s.get_atom_any(5).unwrap().unwrap().values, vec![3.0]);
        assert!(s.get_atom_any(7).unwrap().is_none());
        assert_eq!(s.total_records(), 3);
        assert_eq!(s.total_bytes(), 12);
        // Exactly one shard holds each atom.
        let io = s.per_shard_io();
        assert_eq!(io.len(), 3);
        assert_eq!(io.iter().map(|&(_, r)| r).sum::<u64>(), 3);
    }

    #[test]
    fn partition_routing_follows_owners() {
        let mut rng = Rng::new(9);
        let partition = Partition::random(12, 4, &mut rng);
        let s = ShardedStore::new_mem(4);
        s.set_route_partition(&partition);
        for atom in 0..12 {
            assert_eq!(s.shard_of(atom), partition.owner[atom] % 4);
        }
    }

    #[test]
    fn reads_survive_rerouting() {
        // Write under one routing, re-route, write a newer record, and
        // confirm the freshest record wins regardless of which shard
        // holds it — including after routing an atom *back* to a shard
        // that still holds one of its stale records.
        let mut rng = Rng::new(10);
        let mut partition = Partition::random(8, 4, &mut rng);
        let s = ShardedStore::new_mem(2);
        s.set_route_partition(&partition);
        let atoms: Vec<(usize, &[f32])> = (0..8).map(|a| (a, &[1.0f32][..])).collect();
        s.put_atoms_at(1, &atoms).unwrap();

        partition.repartition(&[0, 1]);
        s.set_route_partition(&partition);
        let newer: Vec<(usize, &[f32])> = (0..8).map(|a| (a, &[2.0f32][..])).collect();
        s.put_atoms_at(5, &newer).unwrap();

        for a in 0..8 {
            let got = s.get_atom_any(a).unwrap().unwrap();
            assert_eq!(got.iter, 5, "atom {a}");
            assert_eq!(got.values, vec![2.0]);
        }
    }

    #[test]
    fn placement_tracks_freshest_routed_record() {
        let s = ShardedStore::new_mem(2);
        assert_eq!(s.placement_of(0), None, "nothing written yet");
        s.put_atoms_at(1, &[(0, &[1.0][..]), (1, &[1.0][..]), (2, &[1.0][..])]).unwrap();
        assert_eq!(s.placement_of(0), Some(0));
        assert_eq!(s.placement_of(1), Some(1));
        assert_eq!(s.placement_of(2), Some(0));
        // A newer record re-routed elsewhere moves placement; an *older*
        // record does not (the freshest copy still governs).
        let mut route = Partition::random(3, 1, &mut Rng::new(1));
        route.owner = vec![1, 1, 1];
        route.atoms_of = vec![vec![], vec![0, 1, 2]];
        s.set_route_partition(&route);
        s.put_atoms_at(5, &[(0, &[5.0][..])]).unwrap();
        assert_eq!(s.placement_of(0), Some(1));
        s.clear_route();
        s.put_atoms_at(3, &[(0, &[3.0][..])]).unwrap();
        assert_eq!(s.placement_of(0), Some(1), "older record must not move placement");
        // Same-iteration rewrite (a rebuild/re-adoption copy) does move
        // placement to where the latest copy landed.
        s.put_atoms_at(5, &[(0, &[5.0][..])]).unwrap();
        assert_eq!(s.placement_of(0), Some(0));
        let snapshot = s.placement_shards();
        assert_eq!(snapshot[0], Some(0));
        assert_eq!(snapshot[1], Some(1));
    }

    #[test]
    fn get_atom_any_ref_matches_owned_scan() {
        let s = ShardedStore::new_mem(3);
        s.put_atoms_at(1, &[(0, &[1.0, 2.0][..]), (1, &[3.0][..])]).unwrap();
        s.put_atoms_at(4, &[(1, &[4.0][..])]).unwrap();
        let mut buf = Vec::new();
        for atom in 0..2 {
            let owned = s.get_atom_any(atom).unwrap().unwrap();
            let it = s.get_atom_any_ref(atom, &mut buf).unwrap().unwrap();
            assert_eq!((it, buf.clone()), (owned.iter, owned.values.clone()), "atom {atom}");
        }
        assert_eq!(s.get_atom_any_ref(9, &mut buf).unwrap(), None);
    }

    #[test]
    fn watermark_is_monotonic() {
        let s = ShardedStore::new_mem(1);
        assert_eq!(s.committed(), None);
        s.mark_committed_at(4);
        s.mark_committed_at(2);
        assert_eq!(s.committed(), Some(4));
        s.mark_committed_at(9);
        assert_eq!(s.committed(), Some(9));
    }

    #[test]
    fn disk_shards_roundtrip() {
        let dir = std::env::temp_dir()
            .join(format!("scar-sharded-disk-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let s = ShardedStore::open_disk(&dir, 2).unwrap();
            s.put_atoms_at(3, &[(0, &[1.0][..]), (1, &[2.0, 3.0][..])]).unwrap();
            s.sync_all().unwrap();
        }
        let s = ShardedStore::open_disk(&dir, 2).unwrap();
        assert_eq!(s.get_atom_any(1).unwrap().unwrap().values, vec![2.0, 3.0]);
        assert_eq!(s.total_bytes(), 12);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn compact_if_needed_respects_threshold_and_counts() {
        let dir = std::env::temp_dir()
            .join(format!("scar-sharded-compact-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let s = ShardedStore::open_disk(&dir, 2).unwrap();
        for iter in 1..=6usize {
            s.put_atoms_at(iter, &[(0, &[iter as f32][..]), (1, &[iter as f32 * 2.0][..])])
                .unwrap();
        }
        s.sync_all().unwrap();
        let before = s.total_on_disk_bytes();
        assert!(s.garbage_ratios().iter().all(|&r| r > 0.5), "{:?}", s.garbage_ratios());
        // A threshold above the actual ratios runs nothing.
        assert!(s.compact_if_needed(0.99, 0, 0).unwrap().is_empty());
        assert_eq!(s.compaction_runs(), 0);
        // A min_bytes floor above the shard sizes also runs nothing.
        assert!(s.compact_if_needed(0.5, before * 4, 0).unwrap().is_empty());
        let runs = s.compact_if_needed(0.5, 0, 0).unwrap();
        assert_eq!(runs.len(), 2, "both shards were above the threshold");
        assert!(s.total_on_disk_bytes() < before);
        assert_eq!(s.compaction_runs(), 2);
        assert!(s.compaction_reclaimed_bytes() > 0);
        assert_eq!(s.get_atom_any(0).unwrap().unwrap().values, vec![6.0]);
        assert_eq!(s.get_atom_any(1).unwrap().unwrap().values, vec![12.0]);
        // Memory shards never report garbage, so the trigger is inert.
        let mem = ShardedStore::new_mem(2);
        mem.put_atoms_at(1, &[(0, &[1.0][..])]).unwrap();
        mem.put_atoms_at(2, &[(0, &[2.0][..])]).unwrap();
        assert!(mem.compact_if_needed(0.0, 0, 0).unwrap().is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn parity_reconstructs_without_reading_the_atom() {
        use crate::storage::ShardBackend;
        let s = ShardedStore::new_mem(3).with_mem_parity(1);
        let atoms: Vec<(usize, Vec<f32>)> =
            (0..9).map(|a| (a, vec![a as f32 + 0.5, -(a as f32)])).collect();
        let refs: Vec<(usize, &[f32])> = atoms.iter().map(|(a, v)| (*a, &v[..])).collect();
        s.put_atoms_at(2, &refs).unwrap();
        s.parity_fence().unwrap();
        // reconstruct_atom never reads the atom's own record, so equality
        // with the direct read proves survivor-only recovery per atom.
        for a in 0..9 {
            let direct = s.get_atom_any(a).unwrap().unwrap();
            let rebuilt = s.reconstruct_atom(a).unwrap().unwrap();
            assert_eq!(rebuilt, direct, "atom {a}");
        }
        // Losing the record outright changes nothing for reconstruction.
        assert!(s.shards[1].lock().unwrap().corrupt_record(4).unwrap());
        let rebuilt = s.reconstruct_atom(4).unwrap().unwrap();
        assert_eq!((rebuilt.iter, rebuilt.values), (2, vec![4.5, -4.0]));
    }

    #[test]
    fn scrub_repairs_a_corrupt_member_in_place() {
        use crate::storage::ShardBackend;
        let s = ShardedStore::new_mem(2).with_mem_parity(1);
        let atoms: Vec<(usize, Vec<f32>)> = (0..6).map(|a| (a, vec![a as f32; 3])).collect();
        let refs: Vec<(usize, &[f32])> = atoms.iter().map(|(a, v)| (*a, &v[..])).collect();
        s.put_atoms_at(1, &refs).unwrap();
        // A later overwrite, so the repaired record must come back at the
        // *overwritten* iteration, not the stripe's original one.
        s.put_atoms_at(4, &[(3, &[9.0, 9.0, 9.0][..])]).unwrap();
        assert!(s.shards[1].lock().unwrap().corrupt_record(3).unwrap());
        assert_eq!(s.repaired_records(), 0);
        let repaired = s.parity_fence().unwrap();
        assert_eq!(repaired, 1);
        assert_eq!((s.repaired_records(), s.repaired_bytes()), (1, 12));
        let got = s.get_atom_any(3).unwrap().unwrap();
        assert_eq!((got.iter, got.values), (4, vec![9.0, 9.0, 9.0]));
        // A clean follow-up fence repairs nothing further.
        assert_eq!(s.parity_fence().unwrap(), 0);
    }

    #[test]
    fn unrepairable_stripe_is_a_clean_error() {
        use crate::storage::ShardBackend;
        let s = ShardedStore::new_mem(2).with_mem_parity(1);
        let atoms: Vec<(usize, Vec<f32>)> = (0..4).map(|a| (a, vec![a as f32])).collect();
        let refs: Vec<(usize, &[f32])> = atoms.iter().map(|(a, v)| (*a, &v[..])).collect();
        s.put_atoms_at(1, &refs).unwrap();
        // Two corruptions in one stripe exceed what single parity absorbs.
        assert!(s.shards[0].lock().unwrap().corrupt_record(0).unwrap());
        assert!(s.shards[1].lock().unwrap().corrupt_record(1).unwrap());
        let err = s.scrub_parity().unwrap_err();
        assert!(
            format!("{err:#}").contains("parity shard can absorb"),
            "unexpected error: {err:#}"
        );
    }

    #[test]
    fn placement_sidecar_survives_reopen_and_validates() {
        let dir = std::env::temp_dir()
            .join(format!("scar-sharded-placement-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let s = ShardedStore::open_disk(&dir, 2).unwrap();
            s.put_atoms_at(3, &[(0, &[1.0][..]), (1, &[2.0][..]), (2, &[4.0][..])]).unwrap();
            s.sync_all().unwrap();
        }
        let s = ShardedStore::open_disk(&dir, 2).unwrap();
        assert_eq!(s.placement_of(0), Some(0), "sidecar reloaded on open");
        assert_eq!(s.placement_of(1), Some(1));
        assert_eq!(s.placement_of(2), Some(0));
        drop(s);
        // An entry the named shard cannot honour (no record at least that
        // fresh) is dropped — stale sidecars stay conservative, not wrong.
        let sidecar = dir.join("placement.json");
        std::fs::write(&sidecar, r#"{"placement": [[0, 0, 3], [5, 1, 9]]}"#).unwrap();
        let s = ShardedStore::open_disk(&dir, 2).unwrap();
        assert_eq!(s.placement_of(0), Some(0));
        assert_eq!(s.placement_of(5), None, "unhonoured sidecar entry must read as unknown");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn disk_parity_reopens_and_recovers_a_wiped_shard() {
        let dir = std::env::temp_dir()
            .join(format!("scar-sharded-parity-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let s = ShardedStore::open_disk(&dir, 2)
                .unwrap()
                .with_disk_parity(&dir, 1)
                .unwrap();
            s.put_atoms_at(
                2,
                &[
                    (0, &[1.0, 2.0][..]),
                    (1, &[3.0][..]),
                    (2, &[5.0][..]),
                    (3, &[7.0, 8.0][..]),
                ],
            )
            .unwrap();
            s.parity_fence().unwrap();
            s.sync_all().unwrap();
        }
        // Cold restart with shard 0's directory destroyed outright.
        std::fs::remove_dir_all(dir.join("shard-000")).unwrap();
        let s = ShardedStore::open_disk(&dir, 2).unwrap();
        assert_eq!(s.n_parity(), 1, "parity dir auto-detected on reopen");
        assert!(s.get_atom_any(0).unwrap().is_none(), "shard 0's records are gone");
        let rebuilt = s.reconstruct_atom(0).unwrap().unwrap();
        assert_eq!((rebuilt.iter, rebuilt.values), (2, vec![1.0, 2.0]));
        let rebuilt = s.reconstruct_atom(2).unwrap().unwrap();
        assert_eq!((rebuilt.iter, rebuilt.values), (2, vec![5.0]));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fence_without_puts_skips_the_placement_sidecar() {
        let dir = std::env::temp_dir()
            .join(format!("scar-sharded-sidecar-skip-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let s = ShardedStore::open_disk(&dir, 2).unwrap();
        s.put_atoms_at(1, &[(0, &[1.0][..]), (1, &[2.0][..])]).unwrap();
        s.sync_all().unwrap();
        assert_eq!(s.sidecar_writes(), 1);
        // Deleting the sidecar and fencing again proves the skip: a
        // clean placement map does no sidecar I/O at all, so the file
        // is not recreated.
        std::fs::remove_file(dir.join("placement.json")).unwrap();
        s.sync_all().unwrap();
        assert_eq!(s.sidecar_writes(), 1, "clean fence must not rewrite the sidecar");
        assert!(!dir.join("placement.json").exists());
        // A put re-dirties the map; the next fence persists it again.
        s.put_atoms_at(2, &[(0, &[3.0][..])]).unwrap();
        s.sync_all().unwrap();
        assert_eq!(s.sidecar_writes(), 2);
        assert!(dir.join("placement.json").exists());
        // A same-value rewrite (placement entry unchanged) stays clean.
        s.put_atoms_at(2, &[(0, &[3.0][..])]).unwrap();
        s.sync_all().unwrap();
        assert_eq!(s.sidecar_writes(), 2, "unchanged placement entry must not dirty the map");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn no_parity_fence_is_zero_cost() {
        let s = ShardedStore::new_mem(2);
        s.put_atoms_at(1, &[(0, &[1.0][..]), (1, &[2.0][..]), (2, &[3.0][..])]).unwrap();
        assert_eq!(s.parity_fence().unwrap(), 0);
        // The early return fires before any stripe iteration or fence
        // accounting — provably zero work, not merely zero repairs.
        assert_eq!(s.parity_fences(), 0);
        assert_eq!(s.stripes_scrubbed(), 0);
        assert_eq!(s.stripes_reencoded(), 0);
    }

    #[test]
    fn dirty_only_fence_reencodes_only_touched_stripes() {
        // 8 atoms over 2 shards = 4 stripes. The first fence settles
        // everything written so far; after a single-atom update the next
        // fence must visit exactly that atom's stripe.
        let s = ShardedStore::new_mem(2).with_mem_parity(1);
        let atoms: Vec<(usize, Vec<f32>)> = (0..8).map(|a| (a, vec![a as f32; 2])).collect();
        let refs: Vec<(usize, &[f32])> = atoms.iter().map(|(a, v)| (*a, &v[..])).collect();
        s.put_atoms_at(1, &refs).unwrap();
        s.parity_fence().unwrap();
        assert_eq!((s.stripes_scrubbed(), s.stripes_reencoded()), (4, 4));
        s.put_atoms_at(2, &[(0, &[9.0, 9.0][..])]).unwrap();
        s.parity_fence().unwrap();
        assert_eq!((s.stripes_scrubbed(), s.stripes_reencoded()), (5, 5));
        // A fence with nothing touched does no stripe work at all.
        s.parity_fence().unwrap();
        assert_eq!((s.stripes_scrubbed(), s.stripes_reencoded()), (5, 5));
        // Parity stays fully usable: every atom reconstructs to the
        // freshest readable record, including the updated one.
        for a in 0..8 {
            let direct = s.get_atom_any(a).unwrap().unwrap();
            let rebuilt = s.reconstruct_atom(a).unwrap().unwrap();
            assert_eq!(rebuilt, direct, "atom {a}");
        }
    }

    #[test]
    fn deep_scrub_interval_widens_the_fence() {
        let s = ShardedStore::new_mem(2).with_mem_parity(1).with_scrub_interval(2);
        let atoms: Vec<(usize, Vec<f32>)> = (0..8).map(|a| (a, vec![a as f32])).collect();
        let refs: Vec<(usize, &[f32])> = atoms.iter().map(|(a, v)| (*a, &v[..])).collect();
        s.put_atoms_at(1, &refs).unwrap();
        s.parity_fence().unwrap(); // fence 1: dirty-only (4 touched stripes)
        assert_eq!((s.stripes_scrubbed(), s.stripes_reencoded()), (4, 4));
        s.put_atoms_at(2, &[(0, &[9.0][..])]).unwrap();
        s.parity_fence().unwrap(); // fence 2: deep — full-state scan
        assert_eq!((s.stripes_scrubbed(), s.stripes_reencoded()), (8, 8));
        s.parity_fence().unwrap(); // fence 3: dirty-only again, nothing touched
        assert_eq!((s.stripes_scrubbed(), s.stripes_reencoded()), (8, 8));
        assert_eq!(s.parity_fences(), 3);
    }

    #[test]
    fn parallel_fence_matches_serial() {
        use crate::storage::ShardBackend;
        // Same writes and the same corruption through a serial fence and
        // a fanned-out one: repairs, work counters, and every record
        // (data and reconstruction) must be byte-identical.
        let build = || {
            let s = ShardedStore::new_mem(4).with_mem_parity(1);
            let atoms: Vec<(usize, Vec<f32>)> =
                (0..32).map(|a| (a, vec![a as f32 * 0.5, -(a as f32)])).collect();
            let refs: Vec<(usize, &[f32])> = atoms.iter().map(|(a, v)| (*a, &v[..])).collect();
            s.put_atoms_at(1, &refs).unwrap();
            s.put_atoms_at(3, &[(5, &[7.0, 7.0][..]), (17, &[8.0, 8.0][..])]).unwrap();
            assert!(s.shards[1].lock().unwrap().corrupt_record(5).unwrap());
            s
        };
        let serial = build();
        let parallel = build();
        parallel.set_fence_workers(4);
        assert_eq!(serial.parity_fence().unwrap(), parallel.parity_fence().unwrap());
        assert_eq!(serial.repaired_records(), parallel.repaired_records());
        assert_eq!(serial.stripes_scrubbed(), parallel.stripes_scrubbed());
        assert_eq!(serial.stripes_reencoded(), parallel.stripes_reencoded());
        for a in 0..32 {
            assert_eq!(
                serial.get_atom_any(a).unwrap(),
                parallel.get_atom_any(a).unwrap(),
                "atom {a}"
            );
            assert_eq!(
                serial.reconstruct_atom(a).unwrap(),
                parallel.reconstruct_atom(a).unwrap(),
                "reconstruct atom {a}"
            );
        }
    }
}
