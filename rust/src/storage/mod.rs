//! Shared persistent storage for checkpoints (paper §4.3).
//!
//! The paper writes checkpoints to NFS/CephFS/Cassandra; here the same
//! role is played by a two-level trait split:
//!
//! * [`ShardBackend`] — the primitive write/read surface one storage
//!   shard must implement. Two backends:
//!   - [`MemStore`] — in-memory map; used by the experiment harness where
//!     thousands of simulated failures make disk I/O pointless.
//!   - [`DiskStore`] — an append-only segment log + JSON manifest on a
//!     local directory standing in for the shared filesystem. Atom
//!     records are CRC-checked; the manifest maps each atom to its latest
//!     record (and the one before it, for crash fallback), which
//!     implements the paper's *running checkpoint* (a mix of atoms saved
//!     at different iterations, §4.2).
//! * [`CheckpointStore`] — what the checkpoint coordinator, recovery
//!   coordinator, and cluster consume: the backend surface plus the
//!   *commit watermark* bookkeeping that the async write pipeline needs
//!   (see [`shard::ShardedStore`] and
//!   [`crate::checkpoint::AsyncCheckpointer`]). Both backends also
//!   implement `CheckpointStore` directly (delegation macro below), so a
//!   one-shard store is the degenerate router.
//!
//! All backends account bytes written so the harness can verify the
//! §4.2 data-volume parity claim (fraction r every rC iterations == full
//! every C), and expose a latency model for the Fig 9 wall-clock
//! simulation without actually sleeping.

pub mod shard;

use std::collections::HashMap;
use std::fs;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

pub use shard::ShardedStore;

/// A saved atom: which iteration it was captured at, and its values.
#[derive(Debug, Clone, PartialEq)]
pub struct SavedAtom {
    pub iter: usize,
    pub values: Vec<f32>,
}

/// The primitive write/read surface of one storage shard.
pub trait ShardBackend: Send {
    /// Persist atom values captured at iteration `iter`. Overwrites any
    /// previous record for the same atoms (running-checkpoint semantics).
    fn put_atoms(&mut self, iter: usize, atoms: &[(usize, &[f32])]) -> Result<()>;

    /// Latest saved record for an atom, if any.
    fn get_atom(&self, atom: usize) -> Result<Option<SavedAtom>>;

    /// Total payload bytes written so far (for §4.2/§5.5 accounting).
    fn bytes_written(&self) -> u64;

    /// Number of put operations (individual atom records).
    fn records_written(&self) -> u64;

    /// Durability fence: flush any buffered metadata (e.g. the disk
    /// manifest). No-op for backends whose puts are immediately durable.
    fn sync(&mut self) -> Result<()> {
        Ok(())
    }

    /// Advance the injected-fault epoch clock to training iteration
    /// `iter`. Real backends have no fault schedule, so this is a no-op;
    /// [`ChaosBackend`](crate::chaos::ChaosBackend) uses it to trigger
    /// kill/slow/torn-write windows at deterministic iterations.
    fn advance_epoch(&mut self, _iter: usize) {}

    /// Whether the shard is currently refusing service (an injected
    /// fault). Healthy backends always serve; the router uses this to
    /// re-route writes and skip reads in degraded mode.
    fn is_down(&self) -> bool {
        false
    }
}

/// Write/read interface to the shared persistent checkpoint storage, as
/// consumed by the checkpoint/recovery coordinators: the shard surface
/// plus commit-watermark bookkeeping.
///
/// The watermark answers "which barriers are fully durable?". A plain
/// backend is synchronous — every put is durable on return — so its
/// watermark is `None` ("not tracked; everything committed"). The
/// sharded/pipelined [`ShardedStore`] tracks a real watermark that the
/// async writer pool advances at each flush fence; recovery refuses to
/// read records beyond it (see [`crate::recovery::recover`]).
pub trait CheckpointStore: Send {
    fn put_atoms(&mut self, iter: usize, atoms: &[(usize, &[f32])]) -> Result<()>;

    fn get_atom(&self, atom: usize) -> Result<Option<SavedAtom>>;

    fn bytes_written(&self) -> u64;

    fn records_written(&self) -> u64;

    /// Highest iteration whose checkpoint barrier is fully committed, or
    /// `None` when the store is synchronous (no watermark tracked).
    fn committed_iter(&self) -> Option<usize> {
        None
    }

    /// Advance the commit watermark (monotonic; no-op on synchronous
    /// backends).
    fn mark_committed(&mut self, _iter: usize) {}

    /// Durability fence (manifest writes etc.).
    fn sync(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Implement [`CheckpointStore`] for a backend type by delegating to its
/// [`ShardBackend`] impl: a plain backend is a synchronous store (puts
/// durable on return, no watermark tracked). A macro rather than a
/// blanket impl so [`shard::ShardedStore`] can implement
/// `CheckpointStore` directly with a real watermark (a blanket
/// `impl<T: ShardBackend> CheckpointStore for T` would conflict with it
/// under coherence).
macro_rules! checkpoint_store_via_backend {
    ($ty:ty) => {
        impl CheckpointStore for $ty {
            fn put_atoms(&mut self, iter: usize, atoms: &[(usize, &[f32])]) -> Result<()> {
                ShardBackend::put_atoms(self, iter, atoms)
            }

            fn get_atom(&self, atom: usize) -> Result<Option<SavedAtom>> {
                ShardBackend::get_atom(self, atom)
            }

            fn bytes_written(&self) -> u64 {
                ShardBackend::bytes_written(self)
            }

            fn records_written(&self) -> u64 {
                ShardBackend::records_written(self)
            }

            fn sync(&mut self) -> Result<()> {
                ShardBackend::sync(self)
            }
        }
    };
}

checkpoint_store_via_backend!(MemStore);
checkpoint_store_via_backend!(DiskStore);

// ---------------------------------------------------------------------------
// In-memory store
// ---------------------------------------------------------------------------

#[derive(Debug, Default)]
pub struct MemStore {
    map: HashMap<usize, SavedAtom>,
    bytes: u64,
    records: u64,
}

impl MemStore {
    pub fn new() -> Self {
        Self::default()
    }
}

impl ShardBackend for MemStore {
    fn put_atoms(&mut self, iter: usize, atoms: &[(usize, &[f32])]) -> Result<()> {
        for (id, vals) in atoms {
            self.map.insert(*id, SavedAtom { iter, values: vals.to_vec() });
            self.bytes += (vals.len() * 4) as u64;
            self.records += 1;
        }
        Ok(())
    }

    fn get_atom(&self, atom: usize) -> Result<Option<SavedAtom>> {
        Ok(self.map.get(&atom).cloned())
    }

    fn bytes_written(&self) -> u64 {
        self.bytes
    }

    fn records_written(&self) -> u64 {
        self.records
    }
}

// ---------------------------------------------------------------------------
// Disk store: append-only segment log + manifest
// ---------------------------------------------------------------------------

/// Record layout (little endian):
///   magic  u32 = 0x5343_4152 ("SCAR")
///   atom   u64
///   iter   u64
///   len    u64                  (f32 count)
///   data   len * f32
///   crc32  u32                  (over atom..data bytes)
const RECORD_MAGIC: u32 = 0x5343_4152;

#[derive(Debug, Clone, Copy)]
struct RecordLoc {
    segment: u64,
    offset: u64,
    iter: usize,
}

/// Per-atom index entry: the latest record plus the one before it. The
/// previous record is the crash-recovery fallback — if the latest record
/// is truncated (crash mid-append) or fails its CRC, reads transparently
/// fall back instead of poisoning the whole store.
#[derive(Debug, Clone, Copy)]
struct AtomIndex {
    latest: RecordLoc,
    prev: Option<RecordLoc>,
}

pub struct DiskStore {
    dir: PathBuf,
    index: HashMap<usize, AtomIndex>,
    current_segment: u64,
    current_file: Option<fs::File>,
    current_len: u64,
    segment_limit: u64,
    bytes: u64,
    records: u64,
}

impl DiskStore {
    /// Open (or create) a store rooted at `dir`. Replays the manifest if
    /// one exists, so a coordinator restart sees the running checkpoint.
    pub fn open(dir: &Path) -> Result<DiskStore> {
        fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        let mut store = DiskStore {
            dir: dir.to_path_buf(),
            index: HashMap::new(),
            current_segment: 0,
            current_file: None,
            current_len: 0,
            segment_limit: 64 << 20, // 64 MiB segments
            bytes: 0,
            records: 0,
        };
        let manifest = dir.join("manifest.json");
        if manifest.exists() {
            store.load_manifest(&manifest)?;
        }
        Ok(store)
    }

    fn segment_path(&self, seg: u64) -> PathBuf {
        self.dir.join(format!("seg-{seg:06}.bin"))
    }

    fn load_manifest(&mut self, path: &Path) -> Result<()> {
        let text = fs::read_to_string(path)?;
        let v = Json::parse(&text).context("parsing checkpoint manifest")?;
        self.current_segment = v.get("next_segment").as_usize().unwrap_or(0) as u64;
        self.bytes = v.get("bytes").as_usize().unwrap_or(0) as u64;
        self.records = v.get("records").as_usize().unwrap_or(0) as u64;
        if let Some(entries) = v.get("atoms").as_arr() {
            for e in entries {
                let atom = e.get("atom").as_usize().context("manifest atom id")?;
                let latest = RecordLoc {
                    segment: e.get("seg").as_usize().unwrap_or(0) as u64,
                    offset: e.get("off").as_usize().unwrap_or(0) as u64,
                    iter: e.get("iter").as_usize().unwrap_or(0),
                };
                let prev = match e.get("pseg").as_usize() {
                    Some(pseg) => Some(RecordLoc {
                        segment: pseg as u64,
                        offset: e.get("poff").as_usize().unwrap_or(0) as u64,
                        iter: e.get("piter").as_usize().unwrap_or(0),
                    }),
                    None => None,
                };
                self.index.insert(atom, AtomIndex { latest, prev });
            }
        }
        Ok(())
    }

    /// Persist the manifest; called by the coordinator after each
    /// checkpoint barrier (cheap: proportional to atom count).
    pub fn write_manifest(&self) -> Result<()> {
        let mut atoms = Vec::with_capacity(self.index.len());
        for (atom, idx) in &self.index {
            let loc = &idx.latest;
            let mut fields = vec![
                ("atom", Json::from(*atom)),
                ("seg", Json::from(loc.segment as usize)),
                ("off", Json::from(loc.offset as usize)),
                ("iter", Json::from(loc.iter)),
            ];
            if let Some(p) = &idx.prev {
                fields.push(("pseg", Json::from(p.segment as usize)));
                fields.push(("poff", Json::from(p.offset as usize)));
                fields.push(("piter", Json::from(p.iter)));
            }
            atoms.push(crate::util::json::obj(fields));
        }
        let v = crate::util::json::obj([
            ("next_segment", Json::from(self.current_segment as usize)),
            ("bytes", Json::from(self.bytes as usize)),
            ("records", Json::from(self.records as usize)),
            ("atoms", Json::Arr(atoms)),
        ]);
        let tmp = self.dir.join("manifest.json.tmp");
        fs::write(&tmp, v.to_string())?;
        fs::rename(&tmp, self.dir.join("manifest.json"))?;
        Ok(())
    }

    fn ensure_segment(&mut self) -> Result<()> {
        if self.current_file.is_some() && self.current_len < self.segment_limit {
            return Ok(());
        }
        if self.current_file.is_some() {
            self.current_segment += 1;
        }
        let path = self.segment_path(self.current_segment);
        let file = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening segment {}", path.display()))?;
        self.current_len = file.metadata()?.len();
        self.current_file = Some(file);
        Ok(())
    }

    /// Read and validate one record. Any structural failure — short read
    /// (truncated final record after a crash), bad magic, atom mismatch,
    /// implausible length, CRC mismatch — is an error the caller may fall
    /// back from.
    fn read_record(&self, atom: usize, loc: &RecordLoc) -> Result<SavedAtom> {
        let mut file = fs::File::open(self.segment_path(loc.segment))?;
        let file_len = file.metadata()?.len();
        use std::io::Seek;
        file.seek(std::io::SeekFrom::Start(loc.offset))?;
        let mut head = [0u8; 28];
        file.read_exact(&mut head)
            .with_context(|| format!("record for atom {atom} truncated (header)"))?;
        let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
        if magic != RECORD_MAGIC {
            bail!("corrupt record for atom {atom}: bad magic");
        }
        let rec_atom = u64::from_le_bytes(head[4..12].try_into().unwrap()) as usize;
        let rec_iter = u64::from_le_bytes(head[12..20].try_into().unwrap()) as usize;
        let len = u64::from_le_bytes(head[20..28].try_into().unwrap()) as usize;
        if rec_atom != atom {
            bail!("corrupt index: record holds atom {rec_atom}, wanted {atom}");
        }
        // Validate the length against the segment before allocating: a
        // corrupted len field must stay a recoverable record error (the
        // prev-record fallback), never a multi-GiB allocation.
        let payload = (len as u64)
            .checked_mul(4)
            .and_then(|v| v.checked_add(4))
            .filter(|&v| {
                loc.offset
                    .checked_add(28)
                    .and_then(|o| o.checked_add(v))
                    .map(|end| end <= file_len)
                    .unwrap_or(false)
            })
            .with_context(|| {
                format!("corrupt record for atom {atom}: implausible length {len}")
            })?;
        let mut data = vec![0u8; payload as usize];
        file.read_exact(&mut data)
            .with_context(|| format!("record for atom {atom} truncated (payload)"))?;
        let crc_stored = u32::from_le_bytes(data[len * 4..].try_into().unwrap());
        let mut crc_input = Vec::with_capacity(24 + len * 4);
        crc_input.extend_from_slice(&head[4..]);
        crc_input.extend_from_slice(&data[..len * 4]);
        let crc = crc32fast::hash(&crc_input);
        if crc != crc_stored {
            bail!("corrupt record for atom {atom}: crc mismatch");
        }
        let values = data[..len * 4]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(SavedAtom { iter: rec_iter, values })
    }
}

impl ShardBackend for DiskStore {
    fn put_atoms(&mut self, iter: usize, atoms: &[(usize, &[f32])]) -> Result<()> {
        for (id, vals) in atoms {
            self.ensure_segment()?;
            let mut buf = Vec::with_capacity(28 + vals.len() * 4);
            buf.extend_from_slice(&RECORD_MAGIC.to_le_bytes());
            buf.extend_from_slice(&(*id as u64).to_le_bytes());
            buf.extend_from_slice(&(iter as u64).to_le_bytes());
            buf.extend_from_slice(&(vals.len() as u64).to_le_bytes());
            for v in *vals {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            let crc = crc32fast::hash(&buf[4..]);
            buf.extend_from_slice(&crc.to_le_bytes());

            let offset = self.current_len;
            let file = self.current_file.as_mut().unwrap();
            file.write_all(&buf)?;
            self.current_len += buf.len() as u64;
            let loc = RecordLoc { segment: self.current_segment, offset, iter };
            let prev = self.index.get(id).map(|e| e.latest);
            self.index.insert(*id, AtomIndex { latest: loc, prev });
            self.bytes += (vals.len() * 4) as u64;
            self.records += 1;
        }
        Ok(())
    }

    fn get_atom(&self, atom: usize) -> Result<Option<SavedAtom>> {
        let Some(entry) = self.index.get(&atom) else {
            return Ok(None);
        };
        match self.read_record(atom, &entry.latest) {
            Ok(saved) => Ok(Some(saved)),
            Err(latest_err) => match &entry.prev {
                // Crash fallback: a torn/corrupt latest record falls back
                // to the previous good record for the atom instead of
                // poisoning the whole store.
                Some(prev) => {
                    let saved = self.read_record(atom, prev).with_context(|| {
                        format!(
                            "atom {atom}: latest record unreadable ({latest_err:#}) \
                             and fallback record also unreadable"
                        )
                    })?;
                    Ok(Some(saved))
                }
                None => Err(latest_err),
            },
        }
    }

    fn bytes_written(&self) -> u64 {
        self.bytes
    }

    fn records_written(&self) -> u64 {
        self.records
    }

    fn sync(&mut self) -> Result<()> {
        self.write_manifest()
    }
}

/// Simple shared-storage latency model for simulated wall-clock reporting
/// (Fig 9): seconds = per_op + bytes * per_byte. Defaults approximate a
/// CephFS-class networked filesystem (1 GB/s streaming, 0.5 ms per op).
#[derive(Debug, Clone, Copy)]
pub struct LatencyModel {
    pub per_op_s: f64,
    pub per_byte_s: f64,
}

impl Default for LatencyModel {
    fn default() -> Self {
        LatencyModel { per_op_s: 0.5e-3, per_byte_s: 1.0 / 1.0e9 }
    }
}

impl LatencyModel {
    pub fn dump_seconds(&self, bytes: u64, ops: u64) -> f64 {
        self.per_op_s * ops as f64 + self.per_byte_s * bytes as f64
    }

    /// Wall-clock for a barrier striped across shards that commit in
    /// parallel (each `(bytes, ops)` entry is one shard's share): the
    /// slowest shard gates the barrier. With one shard this degenerates
    /// to [`dump_seconds`](LatencyModel::dump_seconds).
    pub fn sharded_dump_seconds(&self, per_shard: &[(u64, u64)]) -> f64 {
        per_shard
            .iter()
            .map(|&(bytes, ops)| self.dump_seconds(bytes, ops))
            .fold(0.0, f64::max)
    }

    /// In-loop stall a training iteration pays for one checkpoint barrier
    /// under this model: synchronous mode pays the full (sharded) dump on
    /// the training path; async mode pays nothing here — the dump runs on
    /// the writer pool and only shows up if it outlasts the checkpoint
    /// interval (back-pressure, which the caller prices separately).
    pub fn barrier_stall_seconds(&self, per_shard: &[(u64, u64)], async_mode: bool) -> f64 {
        if async_mode {
            0.0
        } else {
            self.sharded_dump_seconds(per_shard)
        }
    }

    /// In-loop stall of async back-pressure under a bounded writer queue
    /// (`storage.max_pending`): each stalled barrier waits for roughly
    /// one queued barrier's dump to drain, gated by the slowest shard.
    /// `per_barrier` is one barrier's `(bytes, ops)` share per shard.
    pub fn backpressure_stall_seconds(
        &self,
        per_barrier: &[(u64, u64)],
        stalled_barriers: u64,
    ) -> f64 {
        self.sharded_dump_seconds(per_barrier) * stalled_barriers as f64
    }
}

#[cfg(test)]
mod tests {
    // Import ShardBackend (not CheckpointStore) so concrete-type method
    // calls resolve unambiguously.
    use super::{fs, DiskStore, LatencyModel, MemStore, PathBuf, ShardBackend};

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("scar-store-test-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn memstore_roundtrip_and_accounting() {
        let mut s = MemStore::new();
        s.put_atoms(3, &[(0, &[1.0, 2.0][..]), (5, &[3.0][..])]).unwrap();
        assert_eq!(s.get_atom(0).unwrap().unwrap().values, vec![1.0, 2.0]);
        assert_eq!(s.get_atom(5).unwrap().unwrap().iter, 3);
        assert!(s.get_atom(9).unwrap().is_none());
        assert_eq!(s.bytes_written(), 12);
        assert_eq!(s.records_written(), 2);
    }

    #[test]
    fn diskstore_roundtrip() {
        let dir = tmpdir("roundtrip");
        let mut s = DiskStore::open(&dir).unwrap();
        s.put_atoms(1, &[(7, &[1.5, -2.5, 3.5][..])]).unwrap();
        s.put_atoms(4, &[(7, &[9.0, 9.0, 9.0][..])]).unwrap(); // overwrite
        let got = s.get_atom(7).unwrap().unwrap();
        assert_eq!(got.iter, 4);
        assert_eq!(got.values, vec![9.0, 9.0, 9.0]);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn diskstore_persists_via_manifest() {
        let dir = tmpdir("manifest");
        {
            let mut s = DiskStore::open(&dir).unwrap();
            s.put_atoms(2, &[(0, &[4.0][..]), (1, &[5.0, 6.0][..])]).unwrap();
            s.write_manifest().unwrap();
        }
        let s = DiskStore::open(&dir).unwrap();
        assert_eq!(s.get_atom(1).unwrap().unwrap().values, vec![5.0, 6.0]);
        assert_eq!(s.bytes_written(), 12);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn diskstore_detects_corruption() {
        let dir = tmpdir("corrupt");
        let mut s = DiskStore::open(&dir).unwrap();
        s.put_atoms(1, &[(0, &[1.0, 2.0][..])]).unwrap();
        // Flip a payload byte on disk; the only record has no fallback.
        let seg = dir.join("seg-000000.bin");
        let mut bytes = fs::read(&seg).unwrap();
        bytes[30] ^= 0xFF;
        fs::write(&seg, &bytes).unwrap();
        assert!(s.get_atom(0).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn diskstore_crc_mismatch_falls_back_to_previous_record() {
        let dir = tmpdir("crc-fallback");
        let mut s = DiskStore::open(&dir).unwrap();
        s.put_atoms(1, &[(0, &[1.0, 2.0][..])]).unwrap();
        s.put_atoms(5, &[(0, &[8.0, 9.0][..])]).unwrap();
        // Corrupt a payload byte of the *second* record. Record size is
        // 28 (header) + 8 (payload) + 4 (crc) = 40 bytes.
        let seg = dir.join("seg-000000.bin");
        let mut bytes = fs::read(&seg).unwrap();
        bytes[40 + 30] ^= 0xFF;
        fs::write(&seg, &bytes).unwrap();
        let got = s.get_atom(0).unwrap().unwrap();
        assert_eq!(got.iter, 1, "must fall back to the first record");
        assert_eq!(got.values, vec![1.0, 2.0]);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn diskstore_corrupt_length_field_falls_back_without_allocating() {
        let dir = tmpdir("len-fallback");
        let mut s = DiskStore::open(&dir).unwrap();
        s.put_atoms(1, &[(0, &[1.0, 2.0][..])]).unwrap();
        s.put_atoms(5, &[(0, &[8.0, 9.0][..])]).unwrap();
        // Blow up the second record's len field (record bytes 20..28).
        let seg = dir.join("seg-000000.bin");
        let mut bytes = fs::read(&seg).unwrap();
        bytes[40 + 20..40 + 28].copy_from_slice(&u64::MAX.to_le_bytes());
        fs::write(&seg, &bytes).unwrap();
        let got = s.get_atom(0).unwrap().unwrap();
        assert_eq!(got.iter, 1, "must fall back, not attempt a huge allocation");
        assert_eq!(got.values, vec![1.0, 2.0]);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn diskstore_truncated_final_record_falls_back_after_reopen() {
        let dir = tmpdir("truncate-fallback");
        {
            let mut s = DiskStore::open(&dir).unwrap();
            s.put_atoms(1, &[(0, &[1.0, 2.0][..])]).unwrap();
            s.put_atoms(6, &[(0, &[7.0, 7.5][..])]).unwrap();
            s.write_manifest().unwrap();
        }
        // Simulate a crash mid-append: cut the final record short.
        let seg = dir.join("seg-000000.bin");
        let bytes = fs::read(&seg).unwrap();
        assert_eq!(bytes.len(), 80);
        fs::write(&seg, &bytes[..52]).unwrap(); // second record torn
        let s = DiskStore::open(&dir).unwrap();
        let got = s.get_atom(0).unwrap().unwrap();
        assert_eq!(got.iter, 1, "manifest must fall back to the previous record");
        assert_eq!(got.values, vec![1.0, 2.0]);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn diskstore_corruption_with_no_fallback_still_fails_loudly() {
        let dir = tmpdir("no-fallback");
        {
            let mut s = DiskStore::open(&dir).unwrap();
            s.put_atoms(1, &[(0, &[1.0][..])]).unwrap();
            s.write_manifest().unwrap();
        }
        let seg = dir.join("seg-000000.bin");
        let bytes = fs::read(&seg).unwrap();
        fs::write(&seg, &bytes[..10]).unwrap();
        let s = DiskStore::open(&dir).unwrap();
        assert!(s.get_atom(0).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn latency_model() {
        let m = LatencyModel::default();
        let t = m.dump_seconds(1_000_000_000, 2);
        assert!((t - 1.001).abs() < 1e-9);
        // Sharded: the slowest shard gates the barrier.
        let sharded = m.sharded_dump_seconds(&[(1_000_000_000, 2), (500, 1)]);
        assert!((sharded - t).abs() < 1e-12);
        assert_eq!(m.barrier_stall_seconds(&[(1000, 1)], true), 0.0);
        assert!(m.barrier_stall_seconds(&[(1000, 1)], false) > 0.0);
        // Back-pressure: stalled barriers pay one queued dump each.
        let one = m.sharded_dump_seconds(&[(1000, 1)]);
        assert_eq!(m.backpressure_stall_seconds(&[(1000, 1)], 0), 0.0);
        assert!((m.backpressure_stall_seconds(&[(1000, 1)], 3) - 3.0 * one).abs() < 1e-12);
    }
}
