//! Shared persistent storage for checkpoints (paper §4.3).
//!
//! The paper writes checkpoints to NFS/CephFS/Cassandra; here the same
//! role is played by a two-level trait split:
//!
//! * [`ShardBackend`] — the primitive write/read surface one storage
//!   shard must implement. Two backends:
//!   - [`MemStore`] — in-memory map; used by the experiment harness where
//!     thousands of simulated failures make disk I/O pointless.
//!   - [`DiskStore`] — an append-only segment log + JSON manifest on a
//!     local directory standing in for the shared filesystem. Atom
//!     records are CRC-checked; the manifest maps each atom to its latest
//!     record (and the one before it, for crash fallback), which
//!     implements the paper's *running checkpoint* (a mix of atoms saved
//!     at different iterations, §4.2). Sealed segments are mmap'd once
//!     and served zero-copy (the `mmap` module, feature-gated with a
//!     pread fallback): [`DiskStore::get_atom_ref`] hands back a borrowed
//!     [`AtomRef`] view of the validated payload, so the caller's decode
//!     is the only copy; superseded records are reclaimed by
//!     [`DiskStore::compact`] (fresh segments + atomic manifest swap).
//! * [`CheckpointStore`] — what the checkpoint coordinator, recovery
//!   coordinator, and cluster consume: the backend surface plus the
//!   *commit watermark* bookkeeping that the async write pipeline needs
//!   (see [`shard::ShardedStore`] and
//!   [`crate::checkpoint::AsyncCheckpointer`]). Both backends also
//!   implement `CheckpointStore` directly (delegation macro below), so a
//!   one-shard store is the degenerate router.
//!
//! All backends account bytes written so the harness can verify the
//! §4.2 data-volume parity claim (fraction r every rC iterations == full
//! every C), and expose a latency model for the Fig 9 wall-clock
//! simulation without actually sleeping.

mod mmap;
pub mod parity;
pub mod shard;

use std::cell::{Cell, Ref, RefCell};
use std::collections::{HashMap, HashSet};
use std::fs;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use self::mmap::SegmentMap;
use crate::util::json::Json;

pub use shard::{EpochReport, ShardedStore};

/// A saved atom: which iteration it was captured at, and its values.
#[derive(Debug, Clone, PartialEq)]
pub struct SavedAtom {
    pub iter: usize,
    pub values: Vec<f32>,
}

/// A borrowed view of one validated record's payload inside a mapped
/// segment — the zero-copy read surface of [`DiskStore`]. Holding an
/// `AtomRef` keeps a read borrow on the store's segment-map cache, so
/// decode it (via [`copy_into`](AtomRef::copy_into) or
/// [`to_saved`](AtomRef::to_saved)) and drop it before writing.
pub struct AtomRef<'a> {
    iter: usize,
    /// Little-endian f32 payload bytes, CRC-validated before this view
    /// was handed out.
    payload: Ref<'a, [u8]>,
}

impl AtomRef<'_> {
    /// Iteration the record was captured at.
    pub fn iter(&self) -> usize {
        self.iter
    }

    /// f32 element count of the payload.
    pub fn len(&self) -> usize {
        self.payload.len() / 4
    }

    pub fn is_empty(&self) -> bool {
        self.payload.is_empty()
    }

    /// Decode the payload into `out` (cleared first) — the single copy of
    /// the zero-copy path.
    pub fn copy_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.len());
        out.extend(
            self.payload
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap())),
        );
    }

    /// Owned form, byte-equal to the pread path's [`SavedAtom`].
    pub fn to_saved(&self) -> SavedAtom {
        let mut values = Vec::new();
        self.copy_into(&mut values);
        SavedAtom { iter: self.iter, values }
    }
}

/// Outcome of a [`DiskStore::get_atom_ref`] read: a borrowed view when
/// the record sits in a mapped sealed segment, the owned fallback
/// otherwise (active segment, or a platform/build without mmap). The two
/// forms are byte-equal for the same record.
pub enum AtomRead<'a> {
    Mapped(AtomRef<'a>),
    Owned(SavedAtom),
}

impl AtomRead<'_> {
    pub fn iter(&self) -> usize {
        match self {
            AtomRead::Mapped(r) => r.iter(),
            AtomRead::Owned(s) => s.iter,
        }
    }

    /// Decode into `out` (cleared first); one copy either way.
    pub fn copy_into(&self, out: &mut Vec<f32>) {
        match self {
            AtomRead::Mapped(r) => r.copy_into(out),
            AtomRead::Owned(s) => {
                out.clear();
                out.extend_from_slice(&s.values);
            }
        }
    }

    pub fn to_saved(self) -> SavedAtom {
        match self {
            AtomRead::Mapped(r) => r.to_saved(),
            AtomRead::Owned(s) => s,
        }
    }
}

/// The primitive write/read surface of one storage shard.
pub trait ShardBackend: Send {
    /// Persist atom values captured at iteration `iter`. Overwrites any
    /// previous record for the same atoms (running-checkpoint semantics).
    fn put_atoms(&mut self, iter: usize, atoms: &[(usize, &[f32])]) -> Result<()>;

    /// Latest saved record for an atom, if any.
    fn get_atom(&self, atom: usize) -> Result<Option<SavedAtom>>;

    /// Latest record decoded straight into `out` (cleared first),
    /// returning the record's iteration. The default buys nothing over
    /// [`get_atom`](ShardBackend::get_atom); backends with a borrowed
    /// read path ([`DiskStore`]'s mmap'd segments) override it so the
    /// decode into `out` is the only copy.
    fn read_atom_into(&self, atom: usize, out: &mut Vec<f32>) -> Result<Option<usize>> {
        Ok(self.get_atom(atom)?.map(|s| {
            out.clear();
            out.extend_from_slice(&s.values);
            s.iter
        }))
    }

    /// Cheap peek at the latest *readable* record's iteration, without
    /// decoding its payload. May over-report when an index entry points
    /// at a physically corrupt record the full read would fall back
    /// from — callers that care must verify against the actual read
    /// (see [`ShardedStore::get_atom_any_ref`](shard::ShardedStore::get_atom_any_ref)).
    fn atom_iter(&self, atom: usize) -> Result<Option<usize>> {
        Ok(self.get_atom(atom)?.map(|s| s.iter))
    }

    /// Total payload bytes written so far (for §4.2/§5.5 accounting).
    fn bytes_written(&self) -> u64;

    /// Number of put operations (individual atom records).
    fn records_written(&self) -> u64;

    /// Durability fence: flush any buffered metadata (e.g. the disk
    /// manifest). No-op for backends whose puts are immediately durable.
    fn sync(&mut self) -> Result<()> {
        Ok(())
    }

    /// Durability barriers the backend's write protocol has required so
    /// far (modeled fsyncs — see [`DiskStore`]: one per acknowledged
    /// record append plus one per manifest rewrite on the per-record
    /// path, one per non-empty fence under group commit). Backends with
    /// no durability protocol report 0.
    fn fsyncs(&self) -> u64 {
        0
    }

    /// Switch the backend between per-record appends (every put durable
    /// on return) and group-commit batching (a fence's appends coalesce
    /// into one segment write + one manifest delta + one barrier at
    /// `sync`). No-op for backends with no write buffering to speak of.
    fn set_group_commit(&mut self, _on: bool) {}

    /// Advance the injected-fault epoch clock to training iteration
    /// `iter`. Real backends have no fault schedule, so this is a no-op;
    /// [`ChaosBackend`](crate::chaos::ChaosBackend) uses it to trigger
    /// kill/slow/torn-write windows at deterministic iterations.
    fn advance_epoch(&mut self, _iter: usize) {}

    /// Whether the shard is currently refusing service (an injected
    /// fault). Healthy backends always serve; the router uses this to
    /// re-route writes and skip reads in degraded mode.
    fn is_down(&self) -> bool {
        false
    }

    /// Whether the shard currently accepts writes. A *partitioned* shard
    /// (injected network fault — reachable but unwritable) reports
    /// `false` here while still serving reads; the router re-routes its
    /// writes without touching the read path. Healthy backends are
    /// always writable.
    fn is_writable(&self) -> bool {
        true
    }

    /// Tear a put mid-batch (the chaos torn-write injection): records
    /// `atoms[..keep]` land whole, the first tail record is the
    /// in-flight record a crash cut short. The default — memory
    /// semantics — simply never writes the tail; [`DiskStore`] overrides
    /// it to append a *physically truncated* record, so reads exercise
    /// the real truncation/CRC fallback end to end.
    fn put_torn(&mut self, iter: usize, atoms: &[(usize, &[f32])], keep: usize) -> Result<()> {
        self.put_atoms(iter, &atoms[..keep])
    }

    /// Fraction of the backend's on-disk bytes a compaction pass would
    /// reclaim (superseded records, fallback redundancy, torn garbage).
    /// Backends with no log to compact report 0.
    fn garbage_ratio(&self) -> f64 {
        0.0
    }

    /// Bytes the backend currently occupies on disk. Unlike the
    /// cumulative `bytes_written` accounting, compaction shrinks this.
    fn on_disk_bytes(&self) -> u64 {
        0
    }

    /// Fold superseded records into fresh segments, if the backend has a
    /// segment log to compact; `None` when there is nothing to do.
    /// `max_pass_bytes` bounds one pass: `0` folds the whole log (the
    /// monolithic full pass); a nonzero budget runs a *generational*
    /// pass over only the worst-garbage-ratio sealed segments whose
    /// combined size fits the budget.
    fn compact(&mut self, _max_pass_bytes: u64) -> Result<Option<CompactionStats>> {
        Ok(None)
    }

    /// Run a compaction pass that crashes *inside the manifest rename
    /// window*: phase one (fresh segments hit the disk) completes, the
    /// commit never lands. Used by the chaos fsync-fault injection; the
    /// default — backends with no manifest to lose — does nothing.
    /// `max_pass_bytes` selects the same segments the real pass would.
    fn compact_abandoned(&mut self, _max_pass_bytes: u64) -> Result<()> {
        Ok(())
    }

    /// Corrupt the latest record for `atom` in place (the chaos bitflip
    /// injection): after this, reading the atom must behave exactly as a
    /// soft error would make it — a CRC mismatch on disk, a missing
    /// record in memory. Returns whether a record existed to corrupt.
    /// The default — backends with no record to damage — does nothing.
    fn corrupt_record(&mut self, _atom: usize) -> Result<bool> {
        Ok(false)
    }

    /// Drain the backend's media-error notifications: atoms whose records
    /// it detected (or injected) physical damage on since the last call.
    /// The sharded router polls this at every epoch advance and marks the
    /// affected stripes dirty, so a dirty-only parity fence still scrubs
    /// and repairs them even when no write touched their stripe. Healthy
    /// backends never report anything.
    fn take_corruptions(&mut self) -> Vec<usize> {
        Vec::new()
    }

    /// Attach a flight-recorder handle. Real backends have nothing to
    /// narrate, so the default drops it;
    /// [`ChaosBackend`](crate::chaos::ChaosBackend) keeps it and records
    /// fault injections, heals, and replays as iteration-clocked events.
    fn set_recorder(&mut self, _rec: crate::obs::Recorder) {}
}

/// Write/read interface to the shared persistent checkpoint storage, as
/// consumed by the checkpoint/recovery coordinators: the shard surface
/// plus commit-watermark bookkeeping.
///
/// The watermark answers "which barriers are fully durable?". A plain
/// backend is synchronous — every put is durable on return — so its
/// watermark is `None` ("not tracked; everything committed"). The
/// sharded/pipelined [`ShardedStore`] tracks a real watermark that the
/// async writer pool advances at each flush fence; recovery refuses to
/// read records beyond it (see [`crate::recovery::recover`]).
pub trait CheckpointStore: Send {
    fn put_atoms(&mut self, iter: usize, atoms: &[(usize, &[f32])]) -> Result<()>;

    fn get_atom(&self, atom: usize) -> Result<Option<SavedAtom>>;

    /// Freshest record decoded straight into `out` (cleared first),
    /// returning its iteration — the single-copy restore path recovery
    /// uses. Backends with a borrowed read surface override it.
    fn read_atom_into(&self, atom: usize, out: &mut Vec<f32>) -> Result<Option<usize>> {
        Ok(self.get_atom(atom)?.map(|s| {
            out.clear();
            out.extend_from_slice(&s.values);
            s.iter
        }))
    }

    fn bytes_written(&self) -> u64;

    fn records_written(&self) -> u64;

    /// Highest iteration whose checkpoint barrier is fully committed, or
    /// `None` when the store is synchronous (no watermark tracked).
    fn committed_iter(&self) -> Option<usize> {
        None
    }

    /// Advance the commit watermark (monotonic; no-op on synchronous
    /// backends).
    fn mark_committed(&mut self, _iter: usize) {}

    /// Durability fence (manifest writes etc.).
    fn sync(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Implement [`CheckpointStore`] for a backend type by delegating to its
/// [`ShardBackend`] impl: a plain backend is a synchronous store (puts
/// durable on return, no watermark tracked). A macro rather than a
/// blanket impl so [`shard::ShardedStore`] can implement
/// `CheckpointStore` directly with a real watermark (a blanket
/// `impl<T: ShardBackend> CheckpointStore for T` would conflict with it
/// under coherence).
macro_rules! checkpoint_store_via_backend {
    ($ty:ty) => {
        impl CheckpointStore for $ty {
            fn put_atoms(&mut self, iter: usize, atoms: &[(usize, &[f32])]) -> Result<()> {
                ShardBackend::put_atoms(self, iter, atoms)
            }

            fn get_atom(&self, atom: usize) -> Result<Option<SavedAtom>> {
                ShardBackend::get_atom(self, atom)
            }

            fn read_atom_into(&self, atom: usize, out: &mut Vec<f32>) -> Result<Option<usize>> {
                ShardBackend::read_atom_into(self, atom, out)
            }

            fn bytes_written(&self) -> u64 {
                ShardBackend::bytes_written(self)
            }

            fn records_written(&self) -> u64 {
                ShardBackend::records_written(self)
            }

            fn sync(&mut self) -> Result<()> {
                ShardBackend::sync(self)
            }
        }
    };
}

checkpoint_store_via_backend!(MemStore);
checkpoint_store_via_backend!(DiskStore);

// ---------------------------------------------------------------------------
// In-memory store
// ---------------------------------------------------------------------------

#[derive(Debug, Default)]
pub struct MemStore {
    map: HashMap<usize, SavedAtom>,
    bytes: u64,
    records: u64,
}

impl MemStore {
    pub fn new() -> Self {
        Self::default()
    }
}

impl ShardBackend for MemStore {
    fn put_atoms(&mut self, iter: usize, atoms: &[(usize, &[f32])]) -> Result<()> {
        for (id, vals) in atoms {
            self.map.insert(*id, SavedAtom { iter, values: vals.to_vec() });
            self.bytes += (vals.len() * 4) as u64;
            self.records += 1;
        }
        Ok(())
    }

    fn get_atom(&self, atom: usize) -> Result<Option<SavedAtom>> {
        Ok(self.map.get(&atom).cloned())
    }

    fn read_atom_into(&self, atom: usize, out: &mut Vec<f32>) -> Result<Option<usize>> {
        Ok(self.map.get(&atom).map(|s| {
            out.clear();
            out.extend_from_slice(&s.values);
            s.iter
        }))
    }

    fn atom_iter(&self, atom: usize) -> Result<Option<usize>> {
        Ok(self.map.get(&atom).map(|s| s.iter))
    }

    fn bytes_written(&self) -> u64 {
        self.bytes
    }

    fn records_written(&self) -> u64 {
        self.records
    }

    /// Memory model of a bitflipped record: there is no CRC to fail, so
    /// the post-detection state — "this record is unreadable" — is
    /// modelled directly by dropping it. Cumulative byte/record counters
    /// are untouched, matching the disk backend (where the damaged bytes
    /// stay in the log).
    fn corrupt_record(&mut self, atom: usize) -> Result<bool> {
        Ok(self.map.remove(&atom).is_some())
    }
}

// ---------------------------------------------------------------------------
// Disk store: append-only segment log + manifest
// ---------------------------------------------------------------------------

/// Record layout (little endian):
///   magic  u32 = 0x5343_4152 ("SCAR")
///   atom   u64
///   iter   u64
///   len    u64                  (f32 count)
///   data   len * f32
///   crc32  u32                  (over atom..data bytes)
const RECORD_MAGIC: u32 = 0x5343_4152;

/// Fixed record header size (magic + atom + iter + len).
const RECORD_HEADER: usize = 28;

#[derive(Debug, Clone, Copy)]
struct RecordLoc {
    segment: u64,
    offset: u64,
    iter: usize,
    /// Total on-disk record bytes (header + payload + CRC) — the unit of
    /// the live/garbage accounting that drives compaction.
    len: u64,
    /// Known-unreadable record (a chaos torn write left it physically
    /// truncated). A torn record may sit in `latest` — reads fall back
    /// from it — but must never be carried into a `prev` slot: the
    /// fallback chain only ever holds readable records.
    torn: bool,
}

/// Outcome of one segment-log compaction pass.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CompactionStats {
    /// Live records carried into the fresh segments.
    pub live_records: u64,
    /// Superseded (tombstoned) records dropped since the last pass.
    pub dead_records: u64,
    /// Segment-file bytes reclaimed by the pass.
    pub reclaimed_bytes: u64,
    /// Old segment files deleted.
    pub segments_removed: usize,
    /// Input segments the pass folded (every sealed segment for a full
    /// pass, the worst-garbage subset for a generational one).
    pub segments_compacted: usize,
    /// Input segment bytes the pass processed — bounded by
    /// `storage.compact_max_bytes_per_pass` for generational passes.
    pub pass_bytes: u64,
    /// Generation tag stamped on the pass's output segments (0 for a
    /// full pass, which resets the generation clock).
    pub generation: u64,
}

/// Everything phase one of a compaction produced, before the manifest
/// swap makes it visible. Dropping a plan without committing it models a
/// mid-compaction crash: the old manifest still governs every read, and
/// the orphaned fresh segments are removed on the next
/// [`DiskStore::open`] (`rust/tests/proptests.rs` pins that recovery
/// after such a crash returns the pre-compaction parameters).
pub struct CompactionPlan {
    /// Atoms rewritten into output segments: their new `latest` record
    /// (the `prev` fallback is dropped — it was redundancy).
    entries: Vec<(usize, RecordLoc)>,
    /// Atoms whose `prev` slot pointed into a folded segment while the
    /// latest record is readable elsewhere: drop the fallback, no rewrite.
    drop_prev: Vec<usize>,
    /// Input segments the pass folds (deleted at commit).
    selected: Vec<u64>,
    new_segments: Vec<u64>,
    new_bytes: u64,
    /// Combined on-disk size of the selected segments.
    pass_bytes: u64,
    /// Generation tag for the output segments.
    generation: u64,
    /// Full pass (rebuild the whole log) vs a budgeted generational one.
    full: bool,
}

/// Per-segment accounting that drives generational compaction: which
/// pass produced the segment and how much of it is still live.
#[derive(Debug, Clone, Copy, Default)]
struct SegMeta {
    /// Budgeted pass that wrote this segment (0 = plain append segment
    /// or full-pass output).
    generation: u64,
    /// Bytes referenced as some atom's latest record.
    live: u64,
    /// Total segment-file bytes.
    total: u64,
}

/// Per-atom index entry: the latest record plus the one before it. The
/// previous record is the crash-recovery fallback — if the latest record
/// is truncated (crash mid-append) or fails its CRC, reads transparently
/// fall back instead of poisoning the whole store.
#[derive(Debug, Clone, Copy)]
struct AtomIndex {
    latest: RecordLoc,
    prev: Option<RecordLoc>,
}

pub struct DiskStore {
    dir: PathBuf,
    index: HashMap<usize, AtomIndex>,
    current_segment: u64,
    current_file: Option<fs::File>,
    current_len: u64,
    segment_limit: u64,
    bytes: u64,
    records: u64,
    /// Lazily-built read-only maps of sealed segments (the `mmap` read
    /// path). Interior mutability because reads take `&self`; the store
    /// is only ever used behind a shard lock.
    maps: RefCell<HashMap<u64, SegmentMap>>,
    /// Reads served from a mapped segment (observability/tests).
    mapped_reads: Cell<u64>,
    /// Total record bytes appended to segment files, including
    /// superseded records and torn garbage — the garbage-ratio
    /// denominator. Compaction resets it to the live size.
    disk_bytes: u64,
    /// On-disk bytes of each atom's latest record — the live numerator.
    live_bytes: u64,
    /// Records tombstoned (superseded) since open or last compaction.
    dead_records: u64,
    /// Compaction passes run by this handle.
    compactions: u64,
    /// Cumulative bytes reclaimed by this handle's compactions.
    reclaimed_bytes: u64,
    /// Group-commit mode: appends coalesce into `wbuf` and hit the file
    /// as one write (plus one manifest delta line) per `sync` fence.
    group_commit: bool,
    /// Pending coalesced record bytes for the active segment.
    wbuf: Vec<u8>,
    /// File offset at which `wbuf` begins (the active segment's flushed
    /// length). Buffered records live at offsets `>= wbuf_base`.
    wbuf_base: u64,
    /// Atoms whose index entry changed since the last manifest write —
    /// the working set one manifest delta line covers.
    dirty_atoms: HashSet<usize>,
    /// Durability barriers issued so far (modeled fsyncs): one per
    /// acknowledged record append + one per manifest rewrite on the
    /// per-record path; one per non-empty fence under group commit.
    fsyncs: u64,
    /// Manifest epoch: bumped by every full rewrite. Delta lines carry
    /// the epoch they extend, so a crash between a full rewrite and the
    /// delta-file truncation can never replay stale deltas.
    manifest_epoch: u64,
    /// Delta lines appended since the last full rewrite (growth bound).
    delta_lines: u64,
    /// Per-segment generation/live/total accounting.
    seg_meta: HashMap<u64, SegMeta>,
    /// Highest segment number ever allocated. Generational passes write
    /// output segments numbered past the active one, so the append
    /// roll-over allocates from here, never from `current_segment + 1`.
    high_segment: u64,
    /// Generation tag the next budgeted pass will stamp on its outputs
    /// (persisted; a full pass resets it to 1).
    next_generation: u64,
}

impl DiskStore {
    /// Open (or create) a store rooted at `dir`. Replays the manifest if
    /// one exists, so a coordinator restart sees the running checkpoint.
    /// Segment files the manifest does not know about (a crash after a
    /// segment roll-over, or mid-compaction before the manifest swap —
    /// including the orphaned outputs of a partial generational pass)
    /// are removed: their records were never durable by the manifest's
    /// account, and leaving them would collide with future appends.
    pub fn open(dir: &Path) -> Result<DiskStore> {
        fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        let mut store = DiskStore {
            dir: dir.to_path_buf(),
            index: HashMap::new(),
            current_segment: 0,
            current_file: None,
            current_len: 0,
            segment_limit: 64 << 20, // 64 MiB segments
            bytes: 0,
            records: 0,
            maps: RefCell::new(HashMap::new()),
            mapped_reads: Cell::new(0),
            disk_bytes: 0,
            live_bytes: 0,
            dead_records: 0,
            compactions: 0,
            reclaimed_bytes: 0,
            group_commit: false,
            wbuf: Vec::new(),
            wbuf_base: 0,
            dirty_atoms: HashSet::new(),
            fsyncs: 0,
            manifest_epoch: 0,
            delta_lines: 0,
            seg_meta: HashMap::new(),
            high_segment: 0,
            next_generation: 1,
        };
        let manifest = dir.join("manifest.json");
        if manifest.exists() {
            store.load_manifest(&manifest)?;
        }
        for seg in store.segment_numbers()? {
            // A segment is live if the manifest's segment table knows it
            // (generational outputs may be numbered past the active
            // segment) or it predates the active one (legacy manifests
            // carry no table). Everything else is a crash orphan.
            let known = seg <= store.current_segment || store.seg_meta.contains_key(&seg);
            if !known {
                let _ = fs::remove_file(store.segment_path(seg));
            } else if let Ok(meta) = fs::metadata(store.segment_path(seg)) {
                store.disk_bytes += meta.len();
                store.seg_meta.entry(seg).or_default().total = meta.len();
            }
        }
        // Per-segment live bytes are rebuilt from the index, not trusted
        // from the manifest: the segment files are the ground truth for
        // totals, the index for liveness.
        for e in store.index.values() {
            store.seg_meta.entry(e.latest.segment).or_default().live += e.latest.len;
        }
        store.high_segment =
            store.seg_meta.keys().copied().max().unwrap_or(0).max(store.current_segment);
        // Manifests written before record sizes were tracked load every
        // entry with rlen = 0 (a real record is never smaller than its
        // header). Unknown live size must read as "fully live", not
        // "fully garbage" — otherwise the first flush fence would rewrite
        // a legacy store's entire log for nothing. The first genuine
        // compaction rebuilds exact accounting.
        if store.index.values().any(|e| e.latest.len == 0) {
            store.live_bytes = store.disk_bytes;
            for m in store.seg_meta.values_mut() {
                m.live = m.total;
            }
        }
        Ok(store)
    }

    fn segment_path(&self, seg: u64) -> PathBuf {
        self.dir.join(format!("seg-{seg:06}.bin"))
    }

    /// Existing segment numbers under the store directory, ascending.
    fn segment_numbers(&self) -> Result<Vec<u64>> {
        let mut segs = Vec::new();
        for entry in fs::read_dir(&self.dir)
            .with_context(|| format!("listing checkpoint dir {}", self.dir.display()))?
        {
            let name = entry?.file_name();
            let name = name.to_string_lossy();
            if let Some(num) = name.strip_prefix("seg-").and_then(|s| s.strip_suffix(".bin")) {
                if let Ok(n) = num.parse::<u64>() {
                    segs.push(n);
                }
            }
        }
        segs.sort_unstable();
        Ok(segs)
    }

    /// Cap segment files at `bytes` before rolling to a fresh one
    /// (default 64 MiB). Small limits let tests exercise sealed-segment
    /// (mmap) reads and multi-segment compaction with tiny data.
    pub fn set_segment_limit(&mut self, bytes: u64) {
        self.segment_limit = bytes.max(1);
    }

    /// Reads served from an mmap'd sealed segment so far (0 when the
    /// `mmap` feature is off or the platform has no mmap).
    pub fn mapped_reads(&self) -> u64 {
        self.mapped_reads.get()
    }

    fn load_manifest(&mut self, path: &Path) -> Result<()> {
        let text = fs::read_to_string(path)?;
        let v = Json::parse(&text).context("parsing checkpoint manifest")?;
        self.current_segment = v.get("next_segment").as_usize().unwrap_or(0) as u64;
        self.bytes = v.get("bytes").as_usize().unwrap_or(0) as u64;
        self.records = v.get("records").as_usize().unwrap_or(0) as u64;
        self.manifest_epoch = v.get("epoch").as_usize().unwrap_or(0) as u64;
        self.next_generation = v.get("next_generation").as_usize().unwrap_or(1).max(1) as u64;
        if let Some(segs) = v.get("segments").as_arr() {
            for e in segs {
                let Some(seg) = e.get("seg").as_usize() else { continue };
                let generation = e.get("gen").as_usize().unwrap_or(0) as u64;
                self.seg_meta
                    .insert(seg as u64, SegMeta { generation, live: 0, total: 0 });
            }
        }
        if let Some(entries) = v.get("atoms").as_arr() {
            for e in entries {
                let (atom, entry) = parse_index_entry(e)?;
                self.live_bytes += entry.latest.len;
                self.index.insert(atom, entry);
            }
        }
        // Replay the group-commit manifest deltas on top: each line is
        // one fence's changed atoms. Lines from a stale epoch (a crash
        // landed between a full rewrite and the delta truncation) are
        // skipped; an unparseable tail (torn delta append) ends the
        // replay — everything after it was never acknowledged.
        let delta = self.dir.join("manifest.delta.jsonl");
        if let Ok(text) = fs::read_to_string(&delta) {
            for line in text.lines() {
                let Ok(d) = Json::parse(line) else { break };
                if d.get("base").as_usize().unwrap_or(usize::MAX) as u64 != self.manifest_epoch {
                    continue;
                }
                self.current_segment =
                    d.get("next_segment").as_usize().unwrap_or(self.current_segment as usize)
                        as u64;
                self.bytes = d.get("bytes").as_usize().unwrap_or(self.bytes as usize) as u64;
                self.records =
                    d.get("records").as_usize().unwrap_or(self.records as usize) as u64;
                if let Some(entries) = d.get("atoms").as_arr() {
                    for e in entries {
                        let (atom, entry) = parse_index_entry(e)?;
                        if let Some(old) = self.index.insert(atom, entry) {
                            self.live_bytes = self.live_bytes.saturating_sub(old.latest.len);
                        }
                        self.live_bytes += entry.latest.len;
                        self.delta_lines += 1;
                    }
                }
            }
        }
        Ok(())
    }

    /// Persist the full manifest (atomic tmp + rename — the commit point
    /// for compaction) and truncate the group-commit delta file the new
    /// epoch supersedes. Cost is proportional to atom count; the
    /// group-commit fence path instead appends one delta line per fence
    /// and only falls back here when the delta file has grown enough to
    /// be worth folding.
    pub fn write_manifest(&mut self) -> Result<()> {
        // Buffered appends must be on disk before a manifest (full or
        // delta) is allowed to reference their offsets.
        self.flush_wbuf()?;
        self.manifest_epoch += 1;
        let mut atoms = Vec::with_capacity(self.index.len());
        let mut ids: Vec<usize> = self.index.keys().copied().collect();
        ids.sort_unstable();
        for atom in ids {
            atoms.push(manifest_atom_entry(atom, &self.index[&atom]));
        }
        let mut segs: Vec<u64> = self.seg_meta.keys().copied().collect();
        segs.sort_unstable();
        let segments = segs
            .into_iter()
            .map(|seg| {
                let m = &self.seg_meta[&seg];
                crate::util::json::obj([
                    ("seg", Json::from(seg as usize)),
                    ("gen", Json::from(m.generation as usize)),
                    ("live", Json::from(m.live as usize)),
                    ("total", Json::from(m.total as usize)),
                ])
            })
            .collect();
        let v = crate::util::json::obj([
            ("next_segment", Json::from(self.current_segment as usize)),
            ("bytes", Json::from(self.bytes as usize)),
            ("records", Json::from(self.records as usize)),
            ("epoch", Json::from(self.manifest_epoch as usize)),
            ("next_generation", Json::from(self.next_generation as usize)),
            ("segments", Json::Arr(segments)),
            ("atoms", Json::Arr(atoms)),
        ]);
        let tmp = self.dir.join("manifest.json.tmp");
        fs::write(&tmp, v.to_string())?;
        fs::rename(&tmp, self.dir.join("manifest.json"))?;
        // Stale delta lines carry the previous epoch, so even if this
        // removal is lost to a crash they can never replay.
        let _ = fs::remove_file(self.dir.join("manifest.delta.jsonl"));
        self.delta_lines = 0;
        self.dirty_atoms.clear();
        self.fsyncs += 1;
        Ok(())
    }

    /// One group-commit durability fence: flush the coalesced append
    /// buffer as a single segment write, then cover the fence's changed
    /// atoms with one manifest delta line — one barrier per shard per
    /// fence instead of one per record plus a full manifest rewrite. A
    /// clean fence (nothing buffered, nothing dirty) pays nothing.
    fn group_commit_fence(&mut self) -> Result<()> {
        if self.wbuf.is_empty() && self.dirty_atoms.is_empty() {
            return Ok(());
        }
        // Bound delta growth: fold into a full rewrite once the delta
        // file carries more entries than the index itself is worth.
        if self.delta_lines >= (self.index.len() as u64 * 4).max(64) {
            return self.write_manifest();
        }
        self.flush_wbuf()?;
        let mut ids: Vec<usize> = self.dirty_atoms.iter().copied().collect();
        ids.sort_unstable();
        let atoms = ids
            .into_iter()
            .filter_map(|a| self.index.get(&a).map(|idx| manifest_atom_entry(a, idx)))
            .collect::<Vec<_>>();
        let n = atoms.len() as u64;
        let line = crate::util::json::obj([
            ("base", Json::from(self.manifest_epoch as usize)),
            ("next_segment", Json::from(self.current_segment as usize)),
            ("bytes", Json::from(self.bytes as usize)),
            ("records", Json::from(self.records as usize)),
            ("atoms", Json::Arr(atoms)),
        ]);
        let mut f = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(self.dir.join("manifest.delta.jsonl"))?;
        f.write_all(line.to_string().as_bytes())?;
        f.write_all(b"\n")?;
        self.dirty_atoms.clear();
        self.delta_lines += n;
        self.fsyncs += 1;
        Ok(())
    }

    /// Write the pending group-commit buffer to the active segment as
    /// one coalesced append. No-op when nothing is buffered.
    fn flush_wbuf(&mut self) -> Result<()> {
        if self.wbuf.is_empty() {
            return Ok(());
        }
        let file = self
            .current_file
            .as_mut()
            .expect("buffered record bytes require an open segment");
        file.write_all(&self.wbuf)?;
        self.wbuf.clear();
        self.wbuf_base = self.current_len;
        Ok(())
    }

    fn ensure_segment(&mut self) -> Result<()> {
        if self.current_file.is_some() && self.current_len < self.segment_limit {
            return Ok(());
        }
        if self.current_file.is_some() {
            // Seal the old segment with its buffered tail before rolling.
            self.flush_wbuf()?;
            // Generational passes allocate output segments numbered past
            // the active one; continue after ALL known segments so a
            // fresh append segment never collides with a live generation.
            self.current_segment = self.high_segment + 1;
        }
        self.high_segment = self.high_segment.max(self.current_segment);
        let path = self.segment_path(self.current_segment);
        let file = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening segment {}", path.display()))?;
        self.current_len = file.metadata()?.len();
        self.wbuf_base = self.current_len;
        self.current_file = Some(file);
        Ok(())
    }

    /// Latest readable record as a borrowed-or-owned [`AtomRead`]: the
    /// torn/corrupt fallback chain applies exactly as on
    /// [`get_atom`](ShardBackend::get_atom), but records in sealed mmap'd
    /// segments come back as [`AtomRef`] views into the mapping — the
    /// caller's decode (e.g. [`AtomRef::copy_into`]) is the only copy.
    /// Byte-equality between the two forms is pinned in the module tests.
    pub fn get_atom_ref(&self, atom: usize) -> Result<Option<AtomRead<'_>>> {
        let Some(entry) = self.index.get(&atom).copied() else {
            return Ok(None);
        };
        match self.read_any(atom, &entry.latest) {
            Ok(read) => Ok(Some(read)),
            Err(latest_err) => match &entry.prev {
                // Crash fallback: a torn/corrupt latest record falls back
                // to the previous good record for the atom instead of
                // poisoning the whole store.
                Some(prev) => {
                    let read = self.read_any(atom, prev).with_context(|| {
                        format!(
                            "atom {atom}: latest record unreadable ({latest_err:#}) \
                             and fallback record also unreadable"
                        )
                    })?;
                    Ok(Some(read))
                }
                None => Err(latest_err),
            },
        }
    }

    /// Read and validate one record. Any structural failure — short read
    /// (truncated final record after a crash), bad magic, atom mismatch,
    /// implausible length, CRC mismatch — is an error the caller may fall
    /// back from. Records in sealed segments (everything before the
    /// active one) are served borrowed from an mmap when available; the
    /// active segment, and platforms without mmap, use pread-style file
    /// reads into an owned record.
    fn read_any(&self, atom: usize, loc: &RecordLoc) -> Result<AtomRead<'_>> {
        // A group-commit record still sitting in the append buffer is
        // served straight from it (torn buffered records fail validation
        // exactly like their on-disk form, so the fallback chain holds).
        if !self.wbuf.is_empty()
            && loc.segment == self.current_segment
            && loc.offset >= self.wbuf_base
        {
            let off = (loc.offset - self.wbuf_base) as usize;
            return Ok(AtomRead::Owned(decode_record(atom, &self.wbuf, off)?));
        }
        // Sealed segments — everything but the active one, including
        // generational outputs numbered past it — may be mmap'd.
        if loc.segment != self.current_segment {
            if let Some(atom_ref) = self.mapped_ref(atom, loc)? {
                return Ok(AtomRead::Mapped(atom_ref));
            }
        }
        Ok(AtomRead::Owned(self.read_record_file(atom, loc)?))
    }

    /// Zero-copy read path: validate the record in place and hand back a
    /// borrowed view of its payload inside the sealed segment's mapping.
    /// `Ok(None)` means "no mapping available, use the file path"; `Err`
    /// is a structural record failure (fallback to the previous record
    /// applies exactly as on the file path).
    fn mapped_ref(&self, atom: usize, loc: &RecordLoc) -> Result<Option<AtomRef<'_>>> {
        // Build the mapping lazily under a short write borrow, so the
        // read borrow below can escape in the returned `AtomRef`. The
        // already-mapped fast path takes no write borrow at all, so
        // reads of mapped segments stay legal while an `AtomRef` into
        // another record is still alive.
        if !self.maps.borrow().contains_key(&loc.segment) {
            let Ok(file) = fs::File::open(self.segment_path(loc.segment)) else {
                return Ok(None);
            };
            let Some(map) = SegmentMap::map(&file) else {
                return Ok(None);
            };
            self.maps.borrow_mut().insert(loc.segment, map);
        }
        let maps = self.maps.borrow();
        let (iter, payload) =
            validate_record(atom, maps[&loc.segment].bytes(), loc.offset as usize)?;
        self.mapped_reads.set(self.mapped_reads.get() + 1);
        let seg = loc.segment;
        let (lo, hi) = (payload.start, payload.end);
        Ok(Some(AtomRef {
            iter,
            payload: Ref::map(maps, move |m| &m[&seg].bytes()[lo..hi]),
        }))
    }

    /// Plain file read path (the active segment, and the feature-gated
    /// fallback when mmap is unavailable).
    fn read_record_file(&self, atom: usize, loc: &RecordLoc) -> Result<SavedAtom> {
        let mut file = fs::File::open(self.segment_path(loc.segment))?;
        let file_len = file.metadata()?.len();
        use std::io::Seek;
        file.seek(std::io::SeekFrom::Start(loc.offset))?;
        let mut head = [0u8; RECORD_HEADER];
        file.read_exact(&mut head)
            .with_context(|| format!("record for atom {atom} truncated (header)"))?;
        let len = u64::from_le_bytes(head[20..28].try_into().unwrap());
        // Validate the length against the segment before allocating: a
        // corrupted len field must stay a recoverable record error (the
        // prev-record fallback), never a multi-GiB allocation.
        let tail = len
            .checked_mul(4)
            .and_then(|v| v.checked_add(4))
            .filter(|&v| {
                loc.offset
                    .checked_add(RECORD_HEADER as u64)
                    .and_then(|o| o.checked_add(v))
                    .map(|end| end <= file_len)
                    .unwrap_or(false)
            })
            .with_context(|| {
                format!("corrupt record for atom {atom}: implausible length {len}")
            })?;
        let mut rec = head.to_vec();
        rec.resize(RECORD_HEADER + tail as usize, 0);
        file.read_exact(&mut rec[RECORD_HEADER..])
            .with_context(|| format!("record for atom {atom} truncated (payload)"))?;
        decode_record(atom, &rec, 0)
    }
}

/// Serialize one record in the on-disk layout (header + payload + CRC) —
/// shared by the append path and the compactor.
fn encode_record(atom: usize, iter: usize, vals: &[f32]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(RECORD_HEADER + vals.len() * 4 + 4);
    buf.extend_from_slice(&RECORD_MAGIC.to_le_bytes());
    buf.extend_from_slice(&(atom as u64).to_le_bytes());
    buf.extend_from_slice(&(iter as u64).to_le_bytes());
    buf.extend_from_slice(&(vals.len() as u64).to_le_bytes());
    for v in vals {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    let crc = crc32fast::hash(&buf[4..]);
    buf.extend_from_slice(&crc.to_le_bytes());
    buf
}

/// One atom's manifest/delta JSON entry — shared by the full manifest
/// writer and the group-commit delta appender.
fn manifest_atom_entry(atom: usize, idx: &AtomIndex) -> Json {
    let loc = &idx.latest;
    let mut fields = vec![
        ("atom", Json::from(atom)),
        ("seg", Json::from(loc.segment as usize)),
        ("off", Json::from(loc.offset as usize)),
        ("iter", Json::from(loc.iter)),
        ("rlen", Json::from(loc.len as usize)),
    ];
    if loc.torn {
        fields.push(("torn", Json::from(1usize)));
    }
    if let Some(p) = &idx.prev {
        fields.push(("pseg", Json::from(p.segment as usize)));
        fields.push(("poff", Json::from(p.offset as usize)));
        fields.push(("piter", Json::from(p.iter)));
        fields.push(("prlen", Json::from(p.len as usize)));
    }
    crate::util::json::obj(fields)
}

/// Inverse of [`manifest_atom_entry`] — shared by the manifest loader
/// and the delta replayer.
fn parse_index_entry(e: &Json) -> Result<(usize, AtomIndex)> {
    let atom = e.get("atom").as_usize().context("manifest atom id")?;
    let latest = RecordLoc {
        segment: e.get("seg").as_usize().unwrap_or(0) as u64,
        offset: e.get("off").as_usize().unwrap_or(0) as u64,
        iter: e.get("iter").as_usize().unwrap_or(0),
        len: e.get("rlen").as_usize().unwrap_or(0) as u64,
        torn: e.get("torn").as_usize().unwrap_or(0) != 0,
    };
    let prev = e.get("pseg").as_usize().map(|pseg| RecordLoc {
        segment: pseg as u64,
        offset: e.get("poff").as_usize().unwrap_or(0) as u64,
        iter: e.get("piter").as_usize().unwrap_or(0),
        len: e.get("prlen").as_usize().unwrap_or(0) as u64,
        torn: false, // prev slots only ever hold readable records
    });
    Ok((atom, AtomIndex { latest, prev }))
}

/// Validate the record at `offset` within `seg` (a whole mapped segment,
/// or a single record read from the file) without decoding its payload:
/// returns the record's iteration and the payload byte range — what the
/// borrowed [`AtomRef`] read path serves in place. Every structural
/// failure — truncation, bad magic, atom mismatch, implausible length,
/// CRC mismatch — is an error the caller may fall back from.
fn validate_record(
    atom: usize,
    seg: &[u8],
    offset: usize,
) -> Result<(usize, std::ops::Range<usize>)> {
    let head_end = offset
        .checked_add(RECORD_HEADER)
        .filter(|&e| e <= seg.len())
        .with_context(|| format!("record for atom {atom} truncated (header)"))?;
    let head = &seg[offset..head_end];
    let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
    if magic != RECORD_MAGIC {
        bail!("corrupt record for atom {atom}: bad magic");
    }
    let rec_atom = u64::from_le_bytes(head[4..12].try_into().unwrap()) as usize;
    let rec_iter = u64::from_le_bytes(head[12..20].try_into().unwrap()) as usize;
    let len = u64::from_le_bytes(head[20..28].try_into().unwrap()) as usize;
    if rec_atom != atom {
        bail!("corrupt index: record holds atom {rec_atom}, wanted {atom}");
    }
    // Bound the claimed length against the available bytes before
    // touching the payload (a corrupted len field must stay a recoverable
    // record error, never an out-of-bounds access or huge allocation).
    let payload_end = len
        .checked_mul(4)
        .and_then(|p| head_end.checked_add(p))
        .filter(|&e| e.checked_add(4).map(|e4| e4 <= seg.len()).unwrap_or(false))
        .with_context(|| format!("corrupt record for atom {atom}: implausible length {len}"))?;
    let payload = &seg[head_end..payload_end];
    let crc_stored = u32::from_le_bytes(seg[payload_end..payload_end + 4].try_into().unwrap());
    let mut hasher = crc32fast::Hasher::new();
    hasher.update(&head[4..]);
    hasher.update(payload);
    if hasher.finalize() != crc_stored {
        bail!("corrupt record for atom {atom}: crc mismatch");
    }
    Ok((rec_iter, head_end..payload_end))
}

/// Decode and validate the record at `offset` within `seg` into an owned
/// [`SavedAtom`] (the pread-path form of [`validate_record`]).
fn decode_record(atom: usize, seg: &[u8], offset: usize) -> Result<SavedAtom> {
    let (iter, payload) = validate_record(atom, seg, offset)?;
    let values = seg[payload]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(SavedAtom { iter, values })
}

impl ShardBackend for DiskStore {
    fn put_atoms(&mut self, iter: usize, atoms: &[(usize, &[f32])]) -> Result<()> {
        for (id, vals) in atoms {
            self.ensure_segment()?;
            let buf = encode_record(*id, iter, vals);
            let offset = self.current_len;
            if self.group_commit {
                // Coalesce: the bytes land at exactly this offset when
                // the fence flushes the buffer in one write.
                self.wbuf.extend_from_slice(&buf);
            } else {
                let file = self.current_file.as_mut().unwrap();
                file.write_all(&buf)?;
                // Per-record durability: every acknowledged append is
                // its own barrier.
                self.fsyncs += 1;
            }
            self.current_len += buf.len() as u64;
            let rec_len = buf.len() as u64;
            let loc = RecordLoc {
                segment: self.current_segment,
                offset,
                iter,
                len: rec_len,
                torn: false,
            };
            // The fallback slot must stay readable: superseding a torn
            // latest carries the previous *good* record forward instead
            // of the known-unreadable torn bytes.
            let prev = self.index.get(id).and_then(|e| {
                if e.latest.torn {
                    e.prev
                } else {
                    Some(e.latest)
                }
            });
            if let Some(old) = self.index.get(id) {
                // The superseded record is a tombstone from here on.
                self.live_bytes = self.live_bytes.saturating_sub(old.latest.len);
                self.dead_records += 1;
                if let Some(m) = self.seg_meta.get_mut(&old.latest.segment) {
                    m.live = m.live.saturating_sub(old.latest.len);
                }
            }
            self.index.insert(*id, AtomIndex { latest: loc, prev });
            self.dirty_atoms.insert(*id);
            let m = self.seg_meta.entry(self.current_segment).or_default();
            m.total += rec_len;
            m.live += rec_len;
            self.disk_bytes += rec_len;
            self.live_bytes += rec_len;
            self.bytes += (vals.len() * 4) as u64;
            self.records += 1;
        }
        Ok(())
    }

    /// Disk torn write: the kept prefix lands whole, then the first tail
    /// record is appended *physically truncated* (header + half the
    /// payload, no CRC) — exactly the bytes a crash mid-append leaves.
    /// The index keeps the previous good record as the fallback, so the
    /// next read of the torn atom drives the real truncation/CRC fallback
    /// (and the manifest-tracked fallback after a reopen).
    fn put_torn(&mut self, iter: usize, atoms: &[(usize, &[f32])], keep: usize) -> Result<()> {
        ShardBackend::put_atoms(self, iter, &atoms[..keep])?;
        let Some(&(atom, vals)) = atoms.get(keep) else {
            return Ok(());
        };
        let buf = encode_record(atom, iter, vals);
        let torn_len = RECORD_HEADER + (vals.len() * 4) / 2;
        self.ensure_segment()?;
        let offset = self.current_len;
        if self.group_commit {
            // The crash cut the coalesced fence write short: the torn
            // prefix is what the next flush puts on disk. No barrier is
            // counted — a torn write is by definition unacknowledged.
            self.wbuf.extend_from_slice(&buf[..torn_len]);
        } else {
            let file = self.current_file.as_mut().unwrap();
            file.write_all(&buf[..torn_len])?;
        }
        self.current_len += torn_len as u64;
        self.disk_bytes += torn_len as u64;
        self.seg_meta.entry(self.current_segment).or_default().total += torn_len as u64;
        // Only an atom with a durable prior record gets its index entry
        // retargeted at the torn bytes (prev = that record): the crash
        // analogue of an acknowledged-then-torn append. An atom with no
        // prior record keeps "no record" semantics, like the memory
        // backend's dropped tail.
        if let Some(entry) = self.index.get(&atom).copied() {
            let loc = RecordLoc {
                segment: self.current_segment,
                offset,
                iter,
                len: torn_len as u64,
                torn: true,
            };
            self.live_bytes =
                self.live_bytes.saturating_sub(entry.latest.len) + torn_len as u64;
            self.dead_records += 1;
            if let Some(m) = self.seg_meta.get_mut(&entry.latest.segment) {
                m.live = m.live.saturating_sub(entry.latest.len);
            }
            self.seg_meta.entry(self.current_segment).or_default().live += torn_len as u64;
            // Back-to-back tears: the fallback stays the last *readable*
            // record, never an earlier torn one.
            let prev = if entry.latest.torn { entry.prev } else { Some(entry.latest) };
            self.index.insert(atom, AtomIndex { latest: loc, prev });
            self.dirty_atoms.insert(atom);
        }
        Ok(())
    }

    fn get_atom(&self, atom: usize) -> Result<Option<SavedAtom>> {
        Ok(self.get_atom_ref(atom)?.map(AtomRead::to_saved))
    }

    fn read_atom_into(&self, atom: usize, out: &mut Vec<f32>) -> Result<Option<usize>> {
        match self.get_atom_ref(atom)? {
            None => Ok(None),
            Some(read) => {
                read.copy_into(out);
                Ok(Some(read.iter()))
            }
        }
    }

    fn atom_iter(&self, atom: usize) -> Result<Option<usize>> {
        // Index peek: a torn latest record is known-unreadable, so its
        // fallback's iteration is the honest answer. (Physical corruption
        // the index doesn't know about can still over-report — callers
        // verify against the actual read.)
        Ok(self.index.get(&atom).and_then(|e| {
            if e.latest.torn {
                e.prev.map(|p| p.iter)
            } else {
                Some(e.latest.iter)
            }
        }))
    }

    fn bytes_written(&self) -> u64 {
        self.bytes
    }

    fn records_written(&self) -> u64 {
        self.records
    }

    fn sync(&mut self) -> Result<()> {
        if self.group_commit {
            self.group_commit_fence()
        } else {
            self.write_manifest()
        }
    }

    fn fsyncs(&self) -> u64 {
        self.fsyncs
    }

    fn set_group_commit(&mut self, on: bool) {
        if !on {
            // Leaving group-commit mode must not strand buffered bytes.
            let _ = self.flush_wbuf();
        }
        self.group_commit = on;
    }

    fn garbage_ratio(&self) -> f64 {
        DiskStore::garbage_ratio(self)
    }

    fn on_disk_bytes(&self) -> u64 {
        self.disk_bytes
    }

    fn compact(&mut self, max_pass_bytes: u64) -> Result<Option<CompactionStats>> {
        let plan = self.prepare_compaction(max_pass_bytes)?;
        if !plan.full && plan.selected.is_empty() {
            // Budgeted pass found no sealed garbage worth folding (all
            // the garbage may still sit in the active segment).
            return Ok(None);
        }
        Ok(Some(self.commit_compaction(plan)?))
    }

    fn compact_abandoned(&mut self, max_pass_bytes: u64) -> Result<()> {
        // Phase one only: fresh segments land on disk, the manifest swap
        // (the commit point) never happens — exactly a crash inside the
        // rename window. Dropping the plan loses nothing: the in-memory
        // index still governs every read, and the next `open` removes the
        // orphaned fresh segments (generational or full-pass alike).
        let _abandoned = DiskStore::prepare_compaction(self, max_pass_bytes)?;
        Ok(())
    }

    /// Disk bitflip: physically flip one payload bit of the atom's
    /// latest record inside its segment file, exactly the soft error a
    /// cosmic ray or firmware bug leaves. The next read fails the CRC
    /// and drives the real corrupt-record fallback/repair machinery. A
    /// latest record that is already torn is already unreadable —
    /// nothing left to corrupt.
    fn corrupt_record(&mut self, atom: usize) -> Result<bool> {
        let Some(entry) = self.index.get(&atom).copied() else {
            return Ok(false);
        };
        let loc = entry.latest;
        if loc.torn {
            return Ok(false);
        }
        // A group-commit record may still be buffered; materialize it so
        // the flip below damages the real on-disk bytes.
        self.flush_wbuf()?;
        let path = self.segment_path(loc.segment);
        let mut file = fs::OpenOptions::new()
            .read(true)
            .write(true)
            .open(&path)
            .with_context(|| format!("opening segment {} to corrupt", path.display()))?;
        use std::io::Seek;
        // First payload byte (the CRC for a zero-length payload — a CRC
        // flip is detected the same way).
        let pos = loc.offset + RECORD_HEADER as u64;
        file.seek(std::io::SeekFrom::Start(pos))?;
        let mut b = [0u8; 1];
        file.read_exact(&mut b)?;
        b[0] ^= 0x01;
        file.seek(std::io::SeekFrom::Start(pos))?;
        file.write_all(&b)?;
        // A sealed segment may already be mmap'd; drop the mapping so
        // the next read sees the damaged bytes.
        self.maps.borrow_mut().remove(&loc.segment);
        Ok(true)
    }
}

// ---------------------------------------------------------------------------
// Segment-log compaction
// ---------------------------------------------------------------------------

impl DiskStore {
    /// Fraction of on-disk segment bytes not referenced as any atom's
    /// latest record: superseded records, prev-fallback redundancy, and
    /// torn garbage. This is what a compaction pass reclaims, and what
    /// the `storage.compact_threshold` trigger compares against.
    pub fn garbage_ratio(&self) -> f64 {
        if self.disk_bytes == 0 {
            return 0.0;
        }
        1.0 - (self.live_bytes.min(self.disk_bytes) as f64 / self.disk_bytes as f64)
    }

    /// Bytes the segment files currently occupy (shrinks on compaction,
    /// unlike the cumulative `bytes_written` accounting).
    pub fn on_disk_bytes(&self) -> u64 {
        self.disk_bytes
    }

    /// `(compaction passes, bytes reclaimed)` by this handle so far.
    pub fn compaction_counters(&self) -> (u64, u64) {
        (self.compactions, self.reclaimed_bytes)
    }

    /// Highest generation tag currently present among the store's
    /// segments (0 = no budgeted pass has left outputs).
    pub fn max_generation(&self) -> u64 {
        self.seg_meta.values().map(|m| m.generation).max().unwrap_or(0)
    }

    /// Pick the input segments for a budgeted generational pass: sealed
    /// segments only (the active one keeps absorbing appends), worst
    /// garbage ratio first, greedily while the combined size fits
    /// `max_pass_bytes`. When nothing fits, the single worst segment is
    /// taken alone so a bounded pass always makes progress — the one
    /// case a pass may exceed its budget.
    fn select_segments(&self, max_pass_bytes: u64) -> (Vec<u64>, u64) {
        let mut candidates: Vec<(u64, u64, u64)> = self
            .seg_meta
            .iter()
            .filter(|(seg, m)| {
                **seg != self.current_segment && m.total > 0 && m.total > m.live
            })
            .map(|(seg, m)| (*seg, m.total.saturating_sub(m.live), m.total))
            .collect();
        // Worst garbage ratio first; segment number breaks ties so the
        // pass layout is deterministic.
        candidates.sort_by(|a, b| {
            let ra = a.1 as f64 / a.2 as f64;
            let rb = b.1 as f64 / b.2 as f64;
            rb.partial_cmp(&ra).unwrap().then(a.0.cmp(&b.0))
        });
        let mut selected = Vec::new();
        let mut pass_bytes = 0u64;
        for (seg, _garbage, total) in &candidates {
            if pass_bytes + total <= max_pass_bytes {
                selected.push(*seg);
                pass_bytes += total;
            }
        }
        if selected.is_empty() {
            if let Some((seg, _g, total)) = candidates.first() {
                selected.push(*seg);
                pass_bytes = *total;
            }
        }
        selected.sort_unstable();
        (selected, pass_bytes)
    }

    /// Phase one of a compaction: fold live records into fresh output
    /// segments, numbered after every known segment. `max_pass_bytes = 0`
    /// is the monolithic full pass (every atom rewritten); a nonzero
    /// budget folds only the worst-garbage sealed segments whose
    /// combined size fits it, stamping the outputs with the next
    /// generation tag. Nothing becomes visible — the index, the
    /// manifest, and the old segments are untouched, so dropping the
    /// plan instead of committing it is exactly a mid-compaction crash
    /// (and loses nothing: the next [`DiskStore::open`] removes the
    /// orphaned fresh segments).
    pub fn prepare_compaction(&mut self, max_pass_bytes: u64) -> Result<CompactionPlan> {
        // The active segment's buffered tail must be on disk: a pass
        // reads records through the normal fallback chain, and the
        // output it writes must survive the buffer being dropped.
        self.flush_wbuf()?;
        let full = max_pass_bytes == 0;
        let (selected, pass_bytes, generation) = if full {
            let segs: Vec<u64> = {
                let mut s: Vec<u64> = self.seg_meta.keys().copied().collect();
                if !s.contains(&self.current_segment) {
                    s.push(self.current_segment);
                }
                s.sort_unstable();
                s
            };
            (segs, self.disk_bytes, 0)
        } else {
            let (sel, bytes) = self.select_segments(max_pass_bytes);
            (sel, bytes, self.next_generation)
        };
        let in_pass: HashSet<u64> = selected.iter().copied().collect();
        let mut atoms: Vec<usize> = if full {
            self.index.keys().copied().collect()
        } else {
            self.index
                .iter()
                .filter(|(_, e)| {
                    in_pass.contains(&e.latest.segment)
                        || e.prev.map(|p| in_pass.contains(&p.segment)).unwrap_or(false)
                })
                .map(|(a, _)| *a)
                .collect()
        };
        atoms.sort_unstable(); // deterministic segment layout
        let mut seg = self.high_segment + 1;
        let mut entries = Vec::with_capacity(atoms.len());
        let mut drop_prev = Vec::new();
        let mut new_segments: Vec<u64> = Vec::new();
        let mut file: Option<fs::File> = None;
        let mut offset = 0u64;
        let mut new_bytes = 0u64;
        for atom in atoms {
            if !full {
                let entry = self.index[&atom];
                if !in_pass.contains(&entry.latest.segment) {
                    // Only the prev fallback sits in a folded segment. If
                    // the latest record is readable where it is, the
                    // fallback is pure redundancy — drop it, no rewrite.
                    // An unreadable latest means prev holds the readable
                    // copy: fall through and rewrite it as the new latest.
                    if self.read_any(atom, &entry.latest).is_ok() {
                        drop_prev.push(atom);
                        continue;
                    }
                }
            }
            // get_atom applies the torn/corrupt fallback, so compaction
            // always carries the *readable* copy forward.
            let saved = ShardBackend::get_atom(self, atom)?
                .with_context(|| format!("compacting atom {atom}"))?;
            let buf = encode_record(atom, saved.iter, &saved.values);
            if file.is_some() && offset >= self.segment_limit {
                seg += 1;
                file = None;
            }
            if file.is_none() {
                let path = self.segment_path(seg);
                // Truncate: a leftover orphan from an earlier crashed
                // compaction must not leak stale bytes into this one.
                let f = fs::OpenOptions::new()
                    .create(true)
                    .write(true)
                    .truncate(true)
                    .open(&path)
                    .with_context(|| {
                        format!("creating compaction segment {}", path.display())
                    })?;
                new_segments.push(seg);
                offset = 0;
                file = Some(f);
            }
            file.as_mut().unwrap().write_all(&buf)?;
            let rec_len = buf.len() as u64;
            let loc =
                RecordLoc { segment: seg, offset, iter: saved.iter, len: rec_len, torn: false };
            entries.push((atom, loc));
            offset += rec_len;
            new_bytes += rec_len;
        }
        Ok(CompactionPlan {
            entries,
            drop_prev,
            selected,
            new_segments,
            new_bytes,
            pass_bytes,
            generation,
            full,
        })
    }

    /// Phase two: atomically swap the manifest onto the fresh segments,
    /// retarget the in-memory index, and delete every folded segment
    /// file. The manifest rename is the commit point — a crash before it
    /// recovers the pre-compaction store, a crash after it the compacted
    /// one; no interleaving reads half of each. Generational commits
    /// touch only the folded segments' index entries; the active segment
    /// (and its group-commit buffer) keeps absorbing appends.
    pub fn commit_compaction(&mut self, plan: CompactionPlan) -> Result<CompactionStats> {
        let old_bytes = self.disk_bytes;
        let old_segments = self.segment_numbers()?;
        let dead = self.dead_records;
        let live_records = plan.entries.len() as u64;
        if plan.full {
            self.index.clear();
            for (atom, loc) in &plan.entries {
                // Latest-only: after a rewrite of every live record the
                // prev fallback is redundancy the pass exists to reclaim.
                self.index.insert(*atom, AtomIndex { latest: *loc, prev: None });
            }
            // Appends continue at the end of the last fresh segment (or a
            // brand-new one when the store was empty).
            self.current_segment =
                plan.new_segments.last().copied().unwrap_or(self.high_segment + 1);
            self.current_file = None;
            self.current_len = 0;
            self.wbuf.clear();
            self.wbuf_base = 0;
            self.seg_meta.clear();
            self.disk_bytes = plan.new_bytes;
            self.live_bytes = plan.new_bytes;
            self.dead_records = 0;
            // A full pass resets the generation clock.
            self.next_generation = 1;
        } else {
            for (atom, loc) in &plan.entries {
                let old = self
                    .index
                    .insert(*atom, AtomIndex { latest: *loc, prev: None })
                    .expect("compaction plan rewrote an atom the index no longer holds");
                self.live_bytes = self.live_bytes.saturating_sub(old.latest.len) + loc.len;
                if let Some(m) = self.seg_meta.get_mut(&old.latest.segment) {
                    m.live = m.live.saturating_sub(old.latest.len);
                }
            }
            for atom in &plan.drop_prev {
                if let Some(e) = self.index.get_mut(atom) {
                    e.prev = None;
                }
            }
            for seg in &plan.selected {
                self.seg_meta.remove(seg);
            }
            self.disk_bytes =
                self.disk_bytes.saturating_sub(plan.pass_bytes) + plan.new_bytes;
            self.next_generation = plan.generation + 1;
        }
        for (_, loc) in &plan.entries {
            let m = self
                .seg_meta
                .entry(loc.segment)
                .or_insert(SegMeta { generation: plan.generation, live: 0, total: 0 });
            m.total += loc.len;
            m.live += loc.len;
        }
        if plan.full {
            self.seg_meta.entry(self.current_segment).or_default();
        }
        self.high_segment = self
            .high_segment
            .max(self.current_segment)
            .max(plan.new_segments.last().copied().unwrap_or(0));
        self.write_manifest()?; // the commit point
        if plan.full {
            self.maps.borrow_mut().clear();
        } else {
            let mut maps = self.maps.borrow_mut();
            for seg in &plan.selected {
                maps.remove(seg);
            }
        }
        let mut removed = 0usize;
        if plan.full {
            for segnum in old_segments {
                if !plan.new_segments.contains(&segnum)
                    && fs::remove_file(self.segment_path(segnum)).is_ok()
                {
                    removed += 1;
                }
            }
        } else {
            for seg in &plan.selected {
                if fs::remove_file(self.segment_path(*seg)).is_ok() {
                    removed += 1;
                }
            }
        }
        self.compactions += 1;
        let reclaimed = old_bytes.saturating_sub(self.disk_bytes);
        self.reclaimed_bytes += reclaimed;
        Ok(CompactionStats {
            live_records,
            dead_records: if plan.full { dead } else { 0 },
            reclaimed_bytes: reclaimed,
            segments_removed: removed,
            segments_compacted: plan.selected.len(),
            pass_bytes: plan.pass_bytes,
            generation: plan.generation,
        })
    }

    /// Fold superseded records into fresh segments (prepare + commit).
    /// Reads before and after return identical values; only the on-disk
    /// footprint shrinks. `max_pass_bytes = 0` folds the whole log;
    /// nonzero runs one budgeted generational pass.
    pub fn compact(&mut self, max_pass_bytes: u64) -> Result<CompactionStats> {
        let plan = self.prepare_compaction(max_pass_bytes)?;
        self.commit_compaction(plan)
    }
}

/// Simple shared-storage latency model for simulated wall-clock reporting
/// (Fig 9): seconds = per_op + bytes * per_byte. Defaults approximate a
/// CephFS-class networked filesystem (1 GB/s streaming, 0.5 ms per op).
#[derive(Debug, Clone, Copy)]
pub struct LatencyModel {
    pub per_op_s: f64,
    pub per_byte_s: f64,
}

impl Default for LatencyModel {
    fn default() -> Self {
        LatencyModel { per_op_s: 0.5e-3, per_byte_s: 1.0 / 1.0e9 }
    }
}

impl LatencyModel {
    pub fn dump_seconds(&self, bytes: u64, ops: u64) -> f64 {
        self.per_op_s * ops as f64 + self.per_byte_s * bytes as f64
    }

    /// Wall-clock for a barrier striped across shards that commit in
    /// parallel (each `(bytes, ops)` entry is one shard's share): the
    /// slowest shard gates the barrier. With one shard this degenerates
    /// to [`dump_seconds`](LatencyModel::dump_seconds).
    pub fn sharded_dump_seconds(&self, per_shard: &[(u64, u64)]) -> f64 {
        per_shard
            .iter()
            .map(|&(bytes, ops)| self.dump_seconds(bytes, ops))
            .fold(0.0, f64::max)
    }

    /// In-loop stall a training iteration pays for one checkpoint barrier
    /// under this model: synchronous mode pays the full (sharded) dump on
    /// the training path; async mode pays nothing here — the dump runs on
    /// the writer pool and only shows up if it outlasts the checkpoint
    /// interval (back-pressure, which the caller prices separately).
    pub fn barrier_stall_seconds(&self, per_shard: &[(u64, u64)], async_mode: bool) -> f64 {
        if async_mode {
            0.0
        } else {
            self.sharded_dump_seconds(per_shard)
        }
    }

    /// In-loop stall of async back-pressure under a bounded writer queue
    /// (`storage.max_pending`): each stalled barrier waits for roughly
    /// one queued barrier's dump to drain, gated by the slowest shard.
    /// `per_barrier` is one barrier's `(bytes, ops)` share per shard.
    pub fn backpressure_stall_seconds(
        &self,
        per_barrier: &[(u64, u64)],
        stalled_barriers: u64,
    ) -> f64 {
        self.sharded_dump_seconds(per_barrier) * stalled_barriers as f64
    }
}

#[cfg(test)]
mod tests {
    // Import ShardBackend (not CheckpointStore) so concrete-type method
    // calls resolve unambiguously.
    use super::{fs, DiskStore, LatencyModel, MemStore, PathBuf, ShardBackend};

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("scar-store-test-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn memstore_roundtrip_and_accounting() {
        let mut s = MemStore::new();
        s.put_atoms(3, &[(0, &[1.0, 2.0][..]), (5, &[3.0][..])]).unwrap();
        assert_eq!(s.get_atom(0).unwrap().unwrap().values, vec![1.0, 2.0]);
        assert_eq!(s.get_atom(5).unwrap().unwrap().iter, 3);
        assert!(s.get_atom(9).unwrap().is_none());
        assert_eq!(s.bytes_written(), 12);
        assert_eq!(s.records_written(), 2);
    }

    #[test]
    fn diskstore_roundtrip() {
        let dir = tmpdir("roundtrip");
        let mut s = DiskStore::open(&dir).unwrap();
        s.put_atoms(1, &[(7, &[1.5, -2.5, 3.5][..])]).unwrap();
        s.put_atoms(4, &[(7, &[9.0, 9.0, 9.0][..])]).unwrap(); // overwrite
        let got = s.get_atom(7).unwrap().unwrap();
        assert_eq!(got.iter, 4);
        assert_eq!(got.values, vec![9.0, 9.0, 9.0]);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn diskstore_persists_via_manifest() {
        let dir = tmpdir("manifest");
        {
            let mut s = DiskStore::open(&dir).unwrap();
            s.put_atoms(2, &[(0, &[4.0][..]), (1, &[5.0, 6.0][..])]).unwrap();
            s.write_manifest().unwrap();
        }
        let s = DiskStore::open(&dir).unwrap();
        assert_eq!(s.get_atom(1).unwrap().unwrap().values, vec![5.0, 6.0]);
        assert_eq!(s.bytes_written(), 12);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn diskstore_detects_corruption() {
        let dir = tmpdir("corrupt");
        let mut s = DiskStore::open(&dir).unwrap();
        s.put_atoms(1, &[(0, &[1.0, 2.0][..])]).unwrap();
        // Flip a payload byte on disk; the only record has no fallback.
        let seg = dir.join("seg-000000.bin");
        let mut bytes = fs::read(&seg).unwrap();
        bytes[30] ^= 0xFF;
        fs::write(&seg, &bytes).unwrap();
        assert!(s.get_atom(0).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn diskstore_crc_mismatch_falls_back_to_previous_record() {
        let dir = tmpdir("crc-fallback");
        let mut s = DiskStore::open(&dir).unwrap();
        s.put_atoms(1, &[(0, &[1.0, 2.0][..])]).unwrap();
        s.put_atoms(5, &[(0, &[8.0, 9.0][..])]).unwrap();
        // Corrupt a payload byte of the *second* record. Record size is
        // 28 (header) + 8 (payload) + 4 (crc) = 40 bytes.
        let seg = dir.join("seg-000000.bin");
        let mut bytes = fs::read(&seg).unwrap();
        bytes[40 + 30] ^= 0xFF;
        fs::write(&seg, &bytes).unwrap();
        let got = s.get_atom(0).unwrap().unwrap();
        assert_eq!(got.iter, 1, "must fall back to the first record");
        assert_eq!(got.values, vec![1.0, 2.0]);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn diskstore_corrupt_length_field_falls_back_without_allocating() {
        let dir = tmpdir("len-fallback");
        let mut s = DiskStore::open(&dir).unwrap();
        s.put_atoms(1, &[(0, &[1.0, 2.0][..])]).unwrap();
        s.put_atoms(5, &[(0, &[8.0, 9.0][..])]).unwrap();
        // Blow up the second record's len field (record bytes 20..28).
        let seg = dir.join("seg-000000.bin");
        let mut bytes = fs::read(&seg).unwrap();
        bytes[40 + 20..40 + 28].copy_from_slice(&u64::MAX.to_le_bytes());
        fs::write(&seg, &bytes).unwrap();
        let got = s.get_atom(0).unwrap().unwrap();
        assert_eq!(got.iter, 1, "must fall back, not attempt a huge allocation");
        assert_eq!(got.values, vec![1.0, 2.0]);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn diskstore_truncated_final_record_falls_back_after_reopen() {
        let dir = tmpdir("truncate-fallback");
        {
            let mut s = DiskStore::open(&dir).unwrap();
            s.put_atoms(1, &[(0, &[1.0, 2.0][..])]).unwrap();
            s.put_atoms(6, &[(0, &[7.0, 7.5][..])]).unwrap();
            s.write_manifest().unwrap();
        }
        // Simulate a crash mid-append: cut the final record short.
        let seg = dir.join("seg-000000.bin");
        let bytes = fs::read(&seg).unwrap();
        assert_eq!(bytes.len(), 80);
        fs::write(&seg, &bytes[..52]).unwrap(); // second record torn
        let s = DiskStore::open(&dir).unwrap();
        let got = s.get_atom(0).unwrap().unwrap();
        assert_eq!(got.iter, 1, "manifest must fall back to the previous record");
        assert_eq!(got.values, vec![1.0, 2.0]);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn diskstore_corruption_with_no_fallback_still_fails_loudly() {
        let dir = tmpdir("no-fallback");
        {
            let mut s = DiskStore::open(&dir).unwrap();
            s.put_atoms(1, &[(0, &[1.0][..])]).unwrap();
            s.write_manifest().unwrap();
        }
        let seg = dir.join("seg-000000.bin");
        let bytes = fs::read(&seg).unwrap();
        fs::write(&seg, &bytes[..10]).unwrap();
        let s = DiskStore::open(&dir).unwrap();
        assert!(s.get_atom(0).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn borrowed_reads_are_byte_equal_to_owned_reads() {
        use super::AtomRead;
        let dir = tmpdir("atomref");
        let mut s = DiskStore::open(&dir).unwrap();
        s.set_segment_limit(1); // every put rolls to a fresh (sealed) segment
        for iter in 1..=3usize {
            s.put_atoms(iter, &[(0, &[iter as f32, -(iter as f32)][..])]).unwrap();
        }
        s.put_atoms(4, &[(1, &[9.0][..])]).unwrap(); // active segment
        for atom in [0usize, 1] {
            let owned = ShardBackend::get_atom(&s, atom).unwrap().unwrap();
            {
                let via_ref = s.get_atom_ref(atom).unwrap().unwrap();
                if atom == 0 && cfg!(all(unix, target_pointer_width = "64", feature = "mmap")) {
                    assert!(
                        matches!(via_ref, AtomRead::Mapped(_)),
                        "sealed record must be served borrowed"
                    );
                }
                let mut buf = Vec::new();
                via_ref.copy_into(&mut buf);
                assert_eq!(buf, owned.values, "atom {atom}: borrowed decode diverged");
                assert_eq!(via_ref.iter(), owned.iter);
                assert_eq!(via_ref.to_saved(), owned, "owned conversion diverged");
            }
            // And the into-buffer read matches too.
            let mut buf2 = vec![99.0f32]; // must be cleared by the read
            let it = ShardBackend::read_atom_into(&s, atom, &mut buf2).unwrap().unwrap();
            assert_eq!((it, buf2), (owned.iter, owned.values.clone()));
        }
        // A torn latest record serves the fallback identically both ways.
        s.put_torn(6, &[(0, &[5.0, 5.0][..])], 0).unwrap();
        let owned = ShardBackend::get_atom(&s, 0).unwrap().unwrap();
        assert_eq!(owned.iter, 3, "torn latest must fall back");
        let mut buf = Vec::new();
        let it = ShardBackend::read_atom_into(&s, 0, &mut buf).unwrap().unwrap();
        assert_eq!((it, buf), (owned.iter, owned.values.clone()));
        assert_eq!(ShardBackend::atom_iter(&s, 0).unwrap(), Some(3), "peek is torn-aware");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sealed_segments_are_served_zero_copy() {
        let dir = tmpdir("mmap-sealed");
        let mut s = DiskStore::open(&dir).unwrap();
        s.set_segment_limit(1); // every put rolls to a fresh segment
        for iter in 1..=3usize {
            s.put_atoms(iter, &[(0, &[iter as f32][..])]).unwrap();
        }
        s.put_atoms(4, &[(1, &[9.0][..])]).unwrap();
        // Atom 0's latest record now sits in a sealed segment; atom 1's
        // is in the active one.
        assert!(s.current_segment >= 3);
        assert_eq!(s.get_atom(0).unwrap().unwrap().values, vec![3.0]);
        assert_eq!(s.get_atom(1).unwrap().unwrap().values, vec![9.0]);
        if cfg!(all(unix, target_pointer_width = "64", feature = "mmap")) {
            assert!(s.mapped_reads() > 0, "sealed reads must go through the mmap path");
        }
        // A reopen serves the same bytes (maps rebuilt lazily).
        s.write_manifest().unwrap();
        drop(s);
        let s = DiskStore::open(&dir).unwrap();
        assert_eq!(s.get_atom(0).unwrap().unwrap().values, vec![3.0]);
        assert_eq!(s.get_atom(1).unwrap().unwrap().values, vec![9.0]);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn compaction_reclaims_superseded_records_and_preserves_reads() {
        let dir = tmpdir("compact");
        let mut s = DiskStore::open(&dir).unwrap();
        for iter in 1..=8usize {
            s.put_atoms(iter, &[(0, &[iter as f32, 0.5][..]), (1, &[-(iter as f32)][..])])
                .unwrap();
        }
        s.write_manifest().unwrap();
        let before_disk = s.on_disk_bytes();
        assert!(DiskStore::garbage_ratio(&s) > 0.5, "7/8 of each atom's records are garbage");
        let a0 = s.get_atom(0).unwrap().unwrap();
        let a1 = s.get_atom(1).unwrap().unwrap();
        let stats = DiskStore::compact(&mut s, 0).unwrap();
        assert_eq!(stats.live_records, 2);
        assert!(stats.reclaimed_bytes > 0);
        assert!(stats.segments_removed >= 1);
        assert!(stats.segments_compacted >= 1);
        assert_eq!(stats.pass_bytes, before_disk, "a full pass processes the whole log");
        assert_eq!(stats.generation, 0, "full-pass outputs reset the generation clock");
        assert!(s.on_disk_bytes() < before_disk, "compaction must shrink the on-disk bytes");
        assert_eq!(DiskStore::garbage_ratio(&s), 0.0);
        assert_eq!(s.get_atom(0).unwrap().unwrap(), a0);
        assert_eq!(s.get_atom(1).unwrap().unwrap(), a1);
        // Cumulative write accounting is untouched by compaction.
        assert_eq!(s.records_written(), 16);
        // The swapped manifest governs a reopen, and appends continue.
        drop(s);
        let mut s = DiskStore::open(&dir).unwrap();
        assert_eq!(s.get_atom(0).unwrap().unwrap(), a0);
        assert_eq!(s.get_atom(1).unwrap().unwrap(), a1);
        s.put_atoms(9, &[(0, &[99.0, 99.0][..])]).unwrap();
        assert_eq!(s.get_atom(0).unwrap().unwrap().values, vec![99.0, 99.0]);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_put_leaves_truncated_record_and_falls_back() {
        let dir = tmpdir("torn-put");
        let mut s = DiskStore::open(&dir).unwrap();
        s.put_atoms(1, &[(0, &[1.0, 2.0][..]), (1, &[5.0][..])]).unwrap();
        // Tear a 2-record batch after the first record: atom 1's new
        // record lands physically truncated.
        s.put_torn(4, &[(0, &[9.0, 9.0][..]), (1, &[7.0][..])], 1).unwrap();
        assert_eq!(s.get_atom(0).unwrap().unwrap().values, vec![9.0, 9.0]);
        let got = s.get_atom(1).unwrap().unwrap();
        assert_eq!(got.iter, 1, "torn record must fall back to the previous one");
        assert_eq!(got.values, vec![5.0]);
        // Same story through the manifest after a reopen.
        s.write_manifest().unwrap();
        drop(s);
        let mut s = DiskStore::open(&dir).unwrap();
        let got = s.get_atom(1).unwrap().unwrap();
        assert_eq!((got.iter, got.values.clone()), (1, vec![5.0]));
        // Overwriting the torn atom must carry the last *readable* record
        // into the fallback slot — never the torn bytes. Corrupt the
        // fresh record (a later crash mid-append) and the read still
        // lands on the good iter-1 record.
        s.put_atoms(6, &[(1, &[8.0][..])]).unwrap();
        assert_eq!(s.get_atom(1).unwrap().unwrap().values, vec![8.0]);
        s.write_manifest().unwrap();
        drop(s);
        let seg = dir.join("seg-000000.bin");
        let bytes = fs::read(&seg).unwrap();
        fs::write(&seg, &bytes[..bytes.len() - 10]).unwrap();
        let s = DiskStore::open(&dir).unwrap();
        let got = s.get_atom(1).unwrap().unwrap();
        assert_eq!(
            (got.iter, got.values.clone()),
            (1, vec![5.0]),
            "fallback chain must skip the torn record"
        );
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn mid_compaction_crash_leaves_pre_compaction_state() {
        let dir = tmpdir("compact-crash");
        let mut s = DiskStore::open(&dir).unwrap();
        for iter in 1..=5usize {
            s.put_atoms(iter, &[(0, &[iter as f32][..]), (1, &[10.0 + iter as f32][..])])
                .unwrap();
        }
        s.write_manifest().unwrap();
        let a0 = s.get_atom(0).unwrap().unwrap();
        let a1 = s.get_atom(1).unwrap().unwrap();
        // Phase one only — the manifest swap (the commit point) never
        // happens, exactly a crash mid-compaction.
        let _plan = s.prepare_compaction(0).unwrap();
        assert!(dir.join("seg-000001.bin").exists(), "fresh segment written by phase one");
        drop(s);
        let s = DiskStore::open(&dir).unwrap();
        assert_eq!(s.get_atom(0).unwrap().unwrap(), a0);
        assert_eq!(s.get_atom(1).unwrap().unwrap(), a1);
        assert!(
            !s.segment_path(1).exists(),
            "orphaned compaction segment must be removed on reopen"
        );
        fs::remove_dir_all(&dir).unwrap();
    }

    /// The group-commit path must be byte-identical to the per-record
    /// path: same segment files, same reads, while paying one barrier
    /// per fence instead of one per record plus a manifest rewrite.
    #[test]
    fn group_commit_is_byte_identical_and_batches_barriers() {
        let dir_a = tmpdir("gc-per-record");
        let dir_b = tmpdir("gc-group");
        let mut a = DiskStore::open(&dir_a).unwrap();
        let mut b = DiskStore::open(&dir_b).unwrap();
        b.set_group_commit(true);
        for fence in 0..4usize {
            for s in [&mut a, &mut b] {
                s.put_atoms(
                    fence + 1,
                    &[
                        (0, &[fence as f32, 1.0][..]),
                        (1, &[-(fence as f32)][..]),
                        (2, &[0.5, 0.5, 0.5][..]),
                    ],
                )
                .unwrap();
            }
            // Buffered reads are served before the fence lands.
            assert_eq!(
                b.get_atom(2).unwrap().unwrap().values,
                vec![0.5, 0.5, 0.5],
                "buffered record must be readable pre-fence"
            );
            ShardBackend::sync(&mut a).unwrap();
            ShardBackend::sync(&mut b).unwrap();
        }
        let seg_a = fs::read(dir_a.join("seg-000000.bin")).unwrap();
        let seg_b = fs::read(dir_b.join("seg-000000.bin")).unwrap();
        assert_eq!(seg_a, seg_b, "coalesced writes must produce identical segment bytes");
        for atom in 0..3usize {
            assert_eq!(a.get_atom(atom).unwrap(), b.get_atom(atom).unwrap());
        }
        // Per-record: 3 record barriers + 1 manifest rewrite per fence.
        // Group commit: exactly one barrier per (non-empty) fence.
        assert_eq!(ShardBackend::fsyncs(&a), 4 * (3 + 1));
        assert_eq!(ShardBackend::fsyncs(&b), 4);
        // A clean fence pays nothing.
        ShardBackend::sync(&mut b).unwrap();
        assert_eq!(ShardBackend::fsyncs(&b), 4);
        // The delta manifest governs a reopen identically to the full one.
        drop(b);
        let b = DiskStore::open(&dir_b).unwrap();
        for atom in 0..3usize {
            assert_eq!(a.get_atom(atom).unwrap(), b.get_atom(atom).unwrap());
        }
        fs::remove_dir_all(&dir_a).unwrap();
        fs::remove_dir_all(&dir_b).unwrap();
    }

    /// A crash before the group-commit fence (buffer dropped, delta line
    /// never appended) must land the reopen on the last fenced state —
    /// the same fallback the per-record path gets from its manifest.
    #[test]
    fn group_commit_dropped_fence_recovers_last_fenced_state() {
        let dir = tmpdir("gc-crash");
        {
            let mut s = DiskStore::open(&dir).unwrap();
            s.set_group_commit(true);
            s.put_atoms(1, &[(0, &[1.0][..]), (1, &[2.0][..])]).unwrap();
            ShardBackend::sync(&mut s).unwrap();
            // Unfenced overwrite: buffered, then the handle is dropped.
            s.put_atoms(2, &[(0, &[9.0][..])]).unwrap();
        }
        let s = DiskStore::open(&dir).unwrap();
        let got = s.get_atom(0).unwrap().unwrap();
        assert_eq!((got.iter, got.values.clone()), (1, vec![1.0]));
        assert_eq!(s.get_atom(1).unwrap().unwrap().values, vec![2.0]);
        fs::remove_dir_all(&dir).unwrap();
    }

    /// A budgeted generational pass folds only the worst-garbage sealed
    /// segments within the byte budget, stamps its outputs with a fresh
    /// generation, preserves every read, and survives a reopen (the
    /// manifest segment table keeps outputs numbered past the active
    /// segment from being swept as orphans).
    #[test]
    fn generational_pass_respects_budget_and_preserves_reads() {
        let dir = tmpdir("generational");
        let mut s = DiskStore::open(&dir).unwrap();
        s.set_segment_limit(128); // small segments => many sealed ones
        for round in 1..=8usize {
            for atom in 0..4usize {
                s.put_atoms(round, &[(atom, &[round as f32, atom as f32][..])]).unwrap();
            }
        }
        ShardBackend::sync(&mut s).unwrap();
        let before: Vec<_> = (0..4).map(|a| s.get_atom(a).unwrap().unwrap()).collect();
        let before_disk = s.on_disk_bytes();
        let budget = 300u64;
        let stats = DiskStore::compact(&mut s, budget).unwrap();
        assert!(stats.segments_compacted >= 1);
        assert!(
            stats.pass_bytes <= budget,
            "pass bytes {} exceeded budget {budget}",
            stats.pass_bytes
        );
        assert_eq!(stats.generation, 1, "first budgeted pass stamps generation 1");
        assert!(s.on_disk_bytes() < before_disk);
        for (atom, want) in before.iter().enumerate() {
            assert_eq!(&s.get_atom(atom).unwrap().unwrap(), want);
        }
        // Passes chain: the next one stamps the next generation.
        for atom in 0..4usize {
            s.put_atoms(9, &[(atom, &[9.0, atom as f32][..])]).unwrap();
        }
        ShardBackend::sync(&mut s).unwrap();
        let stats2 = DiskStore::compact(&mut s, budget).unwrap();
        assert_eq!(stats2.generation, 2);
        assert!(s.max_generation() >= 1);
        // Reopen: generational outputs survive, reads identical, and
        // appends keep working.
        drop(s);
        let mut s = DiskStore::open(&dir).unwrap();
        for atom in 0..4usize {
            assert_eq!(s.get_atom(atom).unwrap().unwrap().values, vec![9.0, atom as f32]);
        }
        s.put_atoms(10, &[(0, &[10.0, 0.0][..])]).unwrap();
        assert_eq!(s.get_atom(0).unwrap().unwrap().values, vec![10.0, 0.0]);
        fs::remove_dir_all(&dir).unwrap();
    }

    /// An abandoned generational pass (crash before the manifest swap)
    /// leaves orphan generation segments; the reopen removes them and
    /// recovers the pre-pass state.
    #[test]
    fn abandoned_generational_pass_is_cleaned_up_on_reopen() {
        let dir = tmpdir("generational-crash");
        let mut s = DiskStore::open(&dir).unwrap();
        s.set_segment_limit(128);
        for round in 1..=6usize {
            for atom in 0..3usize {
                s.put_atoms(round, &[(atom, &[round as f32][..])]).unwrap();
            }
        }
        ShardBackend::sync(&mut s).unwrap();
        let before: Vec<_> = (0..3).map(|a| s.get_atom(a).unwrap().unwrap()).collect();
        let segs_before = s.segment_numbers().unwrap();
        ShardBackend::compact_abandoned(&mut s, 300).unwrap();
        assert!(
            s.segment_numbers().unwrap().len() > segs_before.len(),
            "phase one must have written orphan generation segments"
        );
        drop(s);
        let s = DiskStore::open(&dir).unwrap();
        assert_eq!(s.segment_numbers().unwrap(), segs_before, "orphans must be swept");
        for (atom, want) in before.iter().enumerate() {
            assert_eq!(&s.get_atom(atom).unwrap().unwrap(), want);
        }
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn latency_model() {
        let m = LatencyModel::default();
        let t = m.dump_seconds(1_000_000_000, 2);
        assert!((t - 1.001).abs() < 1e-9);
        // Sharded: the slowest shard gates the barrier.
        let sharded = m.sharded_dump_seconds(&[(1_000_000_000, 2), (500, 1)]);
        assert!((sharded - t).abs() < 1e-12);
        assert_eq!(m.barrier_stall_seconds(&[(1000, 1)], true), 0.0);
        assert!(m.barrier_stall_seconds(&[(1000, 1)], false) > 0.0);
        // Back-pressure: stalled barriers pay one queued dump each.
        let one = m.sharded_dump_seconds(&[(1000, 1)]);
        assert_eq!(m.backpressure_stall_seconds(&[(1000, 1)], 0), 0.0);
        assert!((m.backpressure_stall_seconds(&[(1000, 1)], 3) - 3.0 * one).abs() < 1e-12);
    }
}
