//! Read-only segment maps: the zero-copy read path for sealed
//! [`DiskStore`](super::DiskStore) segments.
//!
//! A sealed segment (one the store no longer appends to) is mapped once
//! and every record read is served straight out of the mapping — no
//! `seek`/`read` syscalls, no intermediate record buffer. Two read forms
//! sit on top of a mapping:
//!
//! * owned — `DiskStore::get_atom` decodes the payload into a fresh
//!   `SavedAtom` (one copy: the little-endian `f32` decode);
//! * borrowed — `DiskStore::get_atom_ref` hands back an
//!   [`AtomRef`](super::AtomRef) view of the CRC-validated payload bytes
//!   *inside* the mapping, so the caller's decode (straight into its own
//!   buffer, e.g. the recovery planner's slice copy) is the only copy.
//!   The view holds a read borrow on the store's segment-map cache:
//!   decode and drop it before the next write or compaction.
//!
//! The mapping uses raw `mmap`/`munmap` declarations: on unix targets std
//! already links the platform C library, so no external crate is needed
//! and the vendored build stays offline. The `mmap` cargo feature
//! (default-on) gates the whole path; with the feature off — or on a
//! non-unix or 32-bit target (where the declared `off_t` width would not
//! match the C ABI) — [`SegmentMap::map`] returns `None` and `DiskStore`
//! falls back to its plain pread-style file reads, byte-for-byte
//! equivalent, just slower.

// 64-bit unix only: the raw declaration below types `offset` as i64,
// which matches off_t on LP64 targets; 32-bit targets (off_t = 32-bit
// long without large-file support) would have a mismatched ABI, so they
// take the pread fallback instead.
#[cfg(all(unix, target_pointer_width = "64", feature = "mmap"))]
mod imp {
    use std::fs::File;
    use std::os::unix::io::AsRawFd;

    // Shared across Linux and the BSD family (incl. macOS).
    const PROT_READ: i32 = 0x1;
    const MAP_SHARED: i32 = 0x1;

    extern "C" {
        fn mmap(
            addr: *mut u8,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut u8;
        fn munmap(addr: *mut u8, len: usize) -> i32;
    }

    /// One read-only mapping of a whole segment file.
    pub struct SegmentMap {
        ptr: *mut u8,
        len: usize,
    }

    // The mapping is plain read-only memory owned by this struct; moving
    // it between threads is safe (DiskStore itself is only `Send`, and
    // every access goes through `&self` under the shard lock).
    unsafe impl Send for SegmentMap {}

    impl SegmentMap {
        /// Map `file` read-only at its current length. Returns `None`
        /// when mapping is impossible (empty file, exotic filesystem) so
        /// the caller can fall back to file reads.
        pub fn map(file: &File) -> Option<SegmentMap> {
            let len = file.metadata().ok()?.len() as usize;
            if len == 0 {
                return None;
            }
            let ptr = unsafe {
                mmap(std::ptr::null_mut(), len, PROT_READ, MAP_SHARED, file.as_raw_fd(), 0)
            };
            if ptr.is_null() || ptr as isize == -1 {
                return None;
            }
            Some(SegmentMap { ptr, len })
        }

        pub fn bytes(&self) -> &[u8] {
            unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
        }
    }

    impl Drop for SegmentMap {
        fn drop(&mut self) {
            unsafe {
                munmap(self.ptr, self.len);
            }
        }
    }
}

#[cfg(not(all(unix, target_pointer_width = "64", feature = "mmap")))]
mod imp {
    /// Fallback stub: no mapping is ever produced, so `DiskStore` serves
    /// every read through the pread-style file path.
    pub struct SegmentMap(());

    #[allow(dead_code)]
    impl SegmentMap {
        pub fn map(_file: &std::fs::File) -> Option<SegmentMap> {
            None
        }

        pub fn bytes(&self) -> &[u8] {
            &[]
        }
    }
}

pub(crate) use imp::SegmentMap;

#[cfg(test)]
mod tests {
    use super::SegmentMap;
    use std::io::Write;

    #[test]
    fn maps_reflect_file_contents() {
        let dir = std::env::temp_dir().join(format!("scar-mmap-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("seg.bin");
        {
            let mut f = std::fs::File::create(&path).unwrap();
            f.write_all(b"hello segment").unwrap();
        }
        let f = std::fs::File::open(&path).unwrap();
        match SegmentMap::map(&f) {
            Some(m) => assert_eq!(m.bytes(), b"hello segment"),
            // Non-unix, 32-bit, or feature-off builds return None.
            None => {
                assert!(cfg!(not(all(unix, target_pointer_width = "64", feature = "mmap"))))
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_file_is_not_mapped() {
        let dir = std::env::temp_dir().join(format!("scar-mmap-empty-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("seg.bin");
        std::fs::File::create(&path).unwrap();
        let f = std::fs::File::open(&path).unwrap();
        assert!(SegmentMap::map(&f).is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
