//! Failure injection and detection (paper §4.3, §5.3).
//!
//! * [`FailureInjector`] draws the experiment-side failure schedule: the
//!   failure iteration is geometric ("we sample the failure iteration
//!   from a geometric distribution", §5.3) and the lost set is either a
//!   uniformly-random fraction of atoms (Fig 6/7/8 semantics) or the atom
//!   set owned by a random subset of PS nodes (cluster semantics).
//! * [`FailurePlan`] is the declarative layer above the injector: a named
//!   failure *model* (single loss, correlated multi-node loss, cascading
//!   losses, a flaky node) that expands into the per-trial
//!   [`FailureEvent`] sequence consumed by
//!   [`crate::harness::run_plan_trial`] and the scenario engine. The
//!   correlated and flaky models follow the failure regimes studied in
//!   related work on unreliable networks (Yu et al. 2019) rather than the
//!   paper's single-kill experiments.
//! * [`HeartbeatDetector`] is the in-process stand-in for the paper's
//!   ZooKeeper-style failure detector used by the threaded cluster
//!   runtime: nodes post heartbeats; a node silent for longer than the
//!   timeout is declared failed.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::partition::Partition;
use crate::util::rng::Rng;

/// What fails and when, for one simulated trial.
#[derive(Debug, Clone, PartialEq)]
pub struct FailureEvent {
    /// Training iteration during which the failure strikes.
    pub iter: usize,
    /// Atom ids whose values are lost.
    pub lost_atoms: Vec<usize>,
    /// PS nodes that died (empty when injecting at atom granularity).
    pub failed_nodes: Vec<usize>,
}

#[derive(Debug, Clone, Copy)]
pub struct FailureInjector {
    /// Geometric parameter for the failure iteration: P(fail at k) =
    /// p(1-p)^{k-1}. Mean 1/p.
    pub geom_p: f64,
    /// Cap so failures land inside the unperturbed trajectory (failures
    /// sampled past the cap are wrapped back in, preserving shape).
    pub max_iter: usize,
}

impl FailureInjector {
    pub fn new(geom_p: f64, max_iter: usize) -> Self {
        assert!(geom_p > 0.0 && geom_p <= 1.0);
        assert!(max_iter >= 1);
        FailureInjector { geom_p, max_iter }
    }

    pub fn sample_iter(&self, rng: &mut Rng) -> usize {
        let k = rng.geometric(self.geom_p);
        ((k - 1) % self.max_iter) + 1
    }

    /// Lose a uniformly-random `fraction` of atoms (Fig 7 semantics).
    pub fn sample_atom_failure(
        &self,
        n_atoms: usize,
        fraction: f64,
        rng: &mut Rng,
    ) -> FailureEvent {
        let k = ((n_atoms as f64 * fraction).round() as usize).clamp(1, n_atoms);
        let mut lost = rng.sample_indices(n_atoms, k);
        lost.sort_unstable();
        FailureEvent { iter: self.sample_iter(rng), lost_atoms: lost, failed_nodes: vec![] }
    }

    /// Kill `n_failed` random PS nodes; lost atoms follow the partition
    /// (cluster semantics, §4.3).
    pub fn sample_node_failure(
        &self,
        partition: &Partition,
        n_failed: usize,
        rng: &mut Rng,
    ) -> FailureEvent {
        let n_nodes = partition.n_nodes();
        let n_failed = n_failed.min(n_nodes.saturating_sub(1)); // keep one survivor
        let mut nodes = rng.sample_indices(n_nodes, n_failed);
        nodes.sort_unstable();
        FailureEvent {
            iter: self.sample_iter(rng),
            lost_atoms: partition.lost_atoms(&nodes),
            failed_nodes: nodes,
        }
    }
}

// ---------------------------------------------------------------------------
// Failure plans
// ---------------------------------------------------------------------------

/// A declarative failure model: what kind of loss a trial suffers and how
/// often. A plan is sampled per trial into a sorted [`FailureEvent`]
/// sequence (one event for the classic single-failure experiments, many
/// for cascades and flaky nodes).
#[derive(Debug, Clone, PartialEq)]
pub enum FailurePlan {
    /// One uniformly-random loss of `fraction` of all atoms at a
    /// geometric iteration (Fig 7/8 semantics).
    Single { fraction: f64 },
    /// `nodes` of `of_nodes` PS nodes die *together* at one geometric
    /// iteration; the lost set is the union of their partitions
    /// (correlated failures: a rack/switch taking out several nodes).
    Correlated { nodes: usize, of_nodes: usize },
    /// An initial loss of `fraction` atoms followed by `extra` further
    /// independent losses of the same size, `gap` iterations apart
    /// (cascading failures: recovery load or a spreading fault knocking
    /// out more capacity).
    Cascade { fraction: f64, extra: usize, gap: usize },
    /// A flaky node owning a fixed random `fraction` of atoms loses them
    /// at its first (geometric) failure and then again with probability
    /// `prob` every `period` iterations, for at most `max_events`
    /// occasions (intermittent hardware: same data lost repeatedly).
    Flaky { fraction: f64, period: usize, prob: f64, max_events: usize },
}

impl FailurePlan {
    /// Short kind tag (matches the scenario-file `fail = "..."` values).
    pub fn kind(&self) -> &'static str {
        match self {
            FailurePlan::Single { .. } => "single",
            FailurePlan::Correlated { .. } => "correlated",
            FailurePlan::Cascade { .. } => "cascade",
            FailurePlan::Flaky { .. } => "flaky",
        }
    }

    /// Validate parameter ranges, with scenario-file-quality messages.
    pub fn validate(&self) -> Result<(), String> {
        let frac_ok = |f: f64| f > 0.0 && f <= 1.0;
        match self {
            FailurePlan::Single { fraction } => {
                if !frac_ok(*fraction) {
                    return Err(format!("single: fraction must be in (0, 1], got {fraction}"));
                }
            }
            FailurePlan::Correlated { nodes, of_nodes } => {
                if *of_nodes < 2 {
                    return Err(format!("correlated: of_nodes must be >= 2, got {of_nodes}"));
                }
                if *nodes == 0 || nodes >= of_nodes {
                    return Err(format!(
                        "correlated: nodes must be in [1, of_nodes-1={}], got {nodes}",
                        of_nodes - 1
                    ));
                }
            }
            FailurePlan::Cascade { fraction, gap, .. } => {
                if !frac_ok(*fraction) {
                    return Err(format!("cascade: fraction must be in (0, 1], got {fraction}"));
                }
                if *gap == 0 {
                    return Err("cascade: gap must be >= 1".to_string());
                }
            }
            FailurePlan::Flaky { fraction, period, prob, max_events } => {
                if !frac_ok(*fraction) {
                    return Err(format!("flaky: fraction must be in (0, 1], got {fraction}"));
                }
                if *period == 0 {
                    return Err("flaky: period must be >= 1".to_string());
                }
                if !(0.0..=1.0).contains(prob) {
                    return Err(format!("flaky: prob must be in [0, 1], got {prob}"));
                }
                if *max_events == 0 {
                    return Err("flaky: max_events must be >= 1".to_string());
                }
            }
        }
        Ok(())
    }

    /// Draw one trial's failure events, sorted by iteration. The first
    /// event's iteration is geometric via `inj`; follow-up events (cascade
    /// steps, flaky repeats) are offset from it and may land past
    /// `inj.max_iter` — the trial runner applies them to the live
    /// post-recovery run, which extends beyond the unperturbed horizon.
    pub fn sample_events(
        &self,
        inj: &FailureInjector,
        n_atoms: usize,
        rng: &mut Rng,
    ) -> Vec<FailureEvent> {
        let mut events = match self {
            FailurePlan::Single { fraction } => {
                vec![inj.sample_atom_failure(n_atoms, *fraction, rng)]
            }
            FailurePlan::Correlated { nodes, of_nodes } => {
                let partition = Partition::random(n_atoms, *of_nodes, rng);
                vec![inj.sample_node_failure(&partition, *nodes, rng)]
            }
            FailurePlan::Cascade { fraction, extra, gap } => {
                let first = inj.sample_atom_failure(n_atoms, *fraction, rng);
                let base_iter = first.iter;
                let mut evs = vec![first];
                for i in 1..=*extra {
                    let mut ev = inj.sample_atom_failure(n_atoms, *fraction, rng);
                    ev.iter = base_iter + i * gap;
                    evs.push(ev);
                }
                evs
            }
            FailurePlan::Flaky { fraction, period, prob, max_events } => {
                let first = inj.sample_iter(rng);
                let k = ((n_atoms as f64 * fraction).round() as usize).clamp(1, n_atoms);
                let mut lost = rng.sample_indices(n_atoms, k);
                lost.sort_unstable();
                let mut evs = Vec::new();
                for i in 0..*max_events {
                    // The first occasion always fires; later ones flake
                    // with probability `prob`. The bernoulli draw happens
                    // for every occasion so the rng stream length is
                    // independent of the outcomes (determinism across
                    // refactors).
                    let fires = rng.bernoulli(*prob);
                    if i == 0 || fires {
                        evs.push(FailureEvent {
                            iter: first + i * period,
                            lost_atoms: lost.clone(),
                            failed_nodes: vec![],
                        });
                    }
                }
                evs
            }
        };
        events.sort_by_key(|e| e.iter);
        events
    }
}

// ---------------------------------------------------------------------------
// Heartbeat detector
// ---------------------------------------------------------------------------

/// Liveness state of one monitored node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Liveness {
    Alive,
    Suspected,
    Dead,
}

/// In-process heartbeat failure detector. PS node threads call
/// [`HeartbeatDetector::beat`]; the controller polls [`check`]. A node is
/// `Suspected` after `timeout` without a beat and `Dead` after
/// `2*timeout` (two-level so transient scheduling hiccups don't trigger
/// recovery — mirrors ZooKeeper session vs connection timeouts).
#[derive(Debug)]
pub struct HeartbeatDetector {
    timeout: Duration,
    last: HashMap<usize, Instant>,
    declared_dead: HashMap<usize, bool>,
}

impl HeartbeatDetector {
    pub fn new(timeout: Duration) -> Self {
        HeartbeatDetector { timeout, last: HashMap::new(), declared_dead: HashMap::new() }
    }

    pub fn register(&mut self, node: usize) {
        self.last.insert(node, Instant::now());
        self.declared_dead.insert(node, false);
    }

    pub fn beat(&mut self, node: usize) {
        self.beat_at(node, Instant::now());
    }

    /// Record a beat with its *send* timestamp. Controllers that drain
    /// beat channels lazily must use this — processing-time stamps would
    /// make stale buffered beats look fresh and mask real failures.
    pub fn beat_at(&mut self, node: usize, at: Instant) {
        // Beats from deregistered/dead nodes are ignored (a node must be
        // re-registered by the controller after replacement).
        if self.declared_dead.get(&node) == Some(&false) {
            let entry = self.last.entry(node).or_insert(at);
            if at > *entry {
                *entry = at;
            }
        }
    }

    pub fn deregister(&mut self, node: usize) {
        self.last.remove(&node);
        self.declared_dead.remove(&node);
    }

    /// Controller-side declaration: mark `node` dead *now*, without
    /// waiting for heartbeat silence. Used by deterministic failure
    /// detection (scenario cluster sweeps), where a scheduled kill is
    /// declared at its kill iteration instead of after 2× the timeout.
    /// Returns false if the node was unknown or already declared.
    pub fn declare_dead(&mut self, node: usize) -> bool {
        if self.declared_dead.get(&node) == Some(&false) {
            self.declared_dead.insert(node, true);
            true
        } else {
            false
        }
    }

    pub fn liveness(&self, node: usize) -> Liveness {
        if self.declared_dead.get(&node) == Some(&true) {
            return Liveness::Dead;
        }
        match self.last.get(&node) {
            None => Liveness::Dead,
            Some(t) => {
                let dt = t.elapsed();
                if dt > 2 * self.timeout {
                    Liveness::Dead
                } else if dt > self.timeout {
                    Liveness::Suspected
                } else {
                    Liveness::Alive
                }
            }
        }
    }

    /// Poll: returns nodes newly declared dead (each reported once).
    pub fn check(&mut self) -> Vec<usize> {
        let mut newly_dead = Vec::new();
        let nodes: Vec<usize> = self.last.keys().copied().collect();
        for node in nodes {
            if self.declared_dead.get(&node) == Some(&true) {
                continue;
            }
            if self.last[&node].elapsed() > 2 * self.timeout {
                self.declared_dead.insert(node, true);
                newly_dead.push(node);
            }
        }
        newly_dead.sort_unstable();
        newly_dead
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failure_iter_within_cap() {
        let inj = FailureInjector::new(0.05, 30);
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let it = inj.sample_iter(&mut rng);
            assert!((1..=30).contains(&it));
        }
    }

    #[test]
    fn atom_failure_fraction() {
        let inj = FailureInjector::new(0.1, 50);
        let mut rng = Rng::new(2);
        let ev = inj.sample_atom_failure(100, 0.25, &mut rng);
        assert_eq!(ev.lost_atoms.len(), 25);
        let mut sorted = ev.lost_atoms.clone();
        sorted.dedup();
        assert_eq!(sorted.len(), 25);
    }

    #[test]
    fn node_failure_respects_partition() {
        let inj = FailureInjector::new(0.1, 50);
        let mut rng = Rng::new(3);
        let partition = Partition::random(40, 4, &mut rng);
        let ev = inj.sample_node_failure(&partition, 2, &mut rng);
        assert_eq!(ev.failed_nodes.len(), 2);
        for &a in &ev.lost_atoms {
            assert!(ev.failed_nodes.contains(&partition.owner[a]));
        }
    }

    #[test]
    fn node_failure_keeps_a_survivor() {
        let inj = FailureInjector::new(0.1, 50);
        let mut rng = Rng::new(4);
        let partition = Partition::random(10, 3, &mut rng);
        let ev = inj.sample_node_failure(&partition, 99, &mut rng);
        assert_eq!(ev.failed_nodes.len(), 2);
    }

    #[test]
    fn heartbeat_lifecycle() {
        // Generous margins: the suspected window is [T, 2T]; sleeps sit
        // mid-window so scheduler jitter on a loaded box cannot flip the
        // expected state.
        let mut det = HeartbeatDetector::new(Duration::from_millis(150));
        det.register(0);
        det.register(1);
        assert_eq!(det.liveness(0), Liveness::Alive);
        std::thread::sleep(Duration::from_millis(200));
        det.beat(1);
        assert_eq!(det.liveness(0), Liveness::Suspected);
        assert_eq!(det.liveness(1), Liveness::Alive);
        std::thread::sleep(Duration::from_millis(200));
        let dead = det.check();
        assert_eq!(dead, vec![0]);
        // Reported once only.
        assert!(det.check().is_empty());
        assert_eq!(det.liveness(0), Liveness::Dead);
        // Beats after death are ignored.
        det.beat(0);
        assert_eq!(det.liveness(0), Liveness::Dead);
    }

    #[test]
    fn declare_dead_is_immediate_and_idempotent() {
        let mut det = HeartbeatDetector::new(Duration::from_secs(3600));
        det.register(0);
        det.register(1);
        assert!(det.declare_dead(0));
        assert!(!det.declare_dead(0), "second declaration is a no-op");
        assert!(!det.declare_dead(9), "unknown node");
        assert_eq!(det.liveness(0), Liveness::Dead);
        assert_eq!(det.liveness(1), Liveness::Alive);
        // check() does not re-report a declared node.
        assert!(det.check().is_empty());
    }

    #[test]
    fn unknown_node_is_dead() {
        let det = HeartbeatDetector::new(Duration::from_millis(10));
        assert_eq!(det.liveness(99), Liveness::Dead);
    }

    #[test]
    fn plan_single_matches_injector_semantics() {
        let inj = FailureInjector::new(0.1, 40);
        let mut rng = Rng::new(5);
        let evs = FailurePlan::Single { fraction: 0.25 }.sample_events(&inj, 80, &mut rng);
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].lost_atoms.len(), 20);
        assert!((1..=40).contains(&evs[0].iter));
    }

    #[test]
    fn plan_correlated_loses_node_partitions() {
        let inj = FailureInjector::new(0.1, 40);
        let mut rng = Rng::new(6);
        let plan = FailurePlan::Correlated { nodes: 2, of_nodes: 4 };
        let evs = plan.sample_events(&inj, 100, &mut rng);
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].failed_nodes.len(), 2);
        // Random balanced partition: 2 of 4 nodes own half the atoms.
        assert_eq!(evs[0].lost_atoms.len(), 50);
    }

    #[test]
    fn plan_cascade_spaces_events() {
        let inj = FailureInjector::new(0.1, 40);
        let mut rng = Rng::new(7);
        let plan = FailurePlan::Cascade { fraction: 0.1, extra: 3, gap: 5 };
        let evs = plan.sample_events(&inj, 50, &mut rng);
        assert_eq!(evs.len(), 4);
        for (i, ev) in evs.iter().enumerate() {
            assert_eq!(ev.iter, evs[0].iter + i * 5);
            assert_eq!(ev.lost_atoms.len(), 5);
        }
        // Cascade steps draw independent subsets.
        assert_ne!(evs[0].lost_atoms, evs[1].lost_atoms);
    }

    #[test]
    fn plan_flaky_repeats_same_atoms() {
        let inj = FailureInjector::new(0.1, 40);
        let mut rng = Rng::new(8);
        let plan =
            FailurePlan::Flaky { fraction: 0.2, period: 4, prob: 1.0, max_events: 3 };
        let evs = plan.sample_events(&inj, 60, &mut rng);
        assert_eq!(evs.len(), 3);
        for ev in &evs {
            assert_eq!(ev.lost_atoms, evs[0].lost_atoms);
        }
        assert_eq!(evs[1].iter, evs[0].iter + 4);
        assert_eq!(evs[2].iter, evs[0].iter + 8);
        // prob = 0 still fires the first occasion only.
        let plan0 =
            FailurePlan::Flaky { fraction: 0.2, period: 4, prob: 0.0, max_events: 5 };
        assert_eq!(plan0.sample_events(&inj, 60, &mut rng).len(), 1);
    }

    #[test]
    fn plan_validation_messages() {
        assert!(FailurePlan::Single { fraction: 0.5 }.validate().is_ok());
        assert!(FailurePlan::Single { fraction: 0.0 }.validate().is_err());
        assert!(FailurePlan::Correlated { nodes: 4, of_nodes: 4 }.validate().is_err());
        assert!(FailurePlan::Cascade { fraction: 0.5, extra: 2, gap: 0 }.validate().is_err());
        let e = FailurePlan::Flaky { fraction: 0.5, period: 0, prob: 0.5, max_events: 2 }
            .validate()
            .unwrap_err();
        assert!(e.contains("period"), "{e}");
    }
}
