//! Failure injection and detection (paper §4.3, §5.3).
//!
//! * [`FailureInjector`] draws the experiment-side failure schedule: the
//!   failure iteration is geometric ("we sample the failure iteration
//!   from a geometric distribution", §5.3) and the lost set is either a
//!   uniformly-random fraction of atoms (Fig 6/7/8 semantics) or the atom
//!   set owned by a random subset of PS nodes (cluster semantics).
//! * [`HeartbeatDetector`] is the in-process stand-in for the paper's
//!   ZooKeeper-style failure detector used by the threaded cluster
//!   runtime: nodes post heartbeats; a node silent for longer than the
//!   timeout is declared failed.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::partition::Partition;
use crate::util::rng::Rng;

/// What fails and when, for one simulated trial.
#[derive(Debug, Clone, PartialEq)]
pub struct FailureEvent {
    /// Training iteration during which the failure strikes.
    pub iter: usize,
    /// Atom ids whose values are lost.
    pub lost_atoms: Vec<usize>,
    /// PS nodes that died (empty when injecting at atom granularity).
    pub failed_nodes: Vec<usize>,
}

#[derive(Debug, Clone, Copy)]
pub struct FailureInjector {
    /// Geometric parameter for the failure iteration: P(fail at k) =
    /// p(1-p)^{k-1}. Mean 1/p.
    pub geom_p: f64,
    /// Cap so failures land inside the unperturbed trajectory (failures
    /// sampled past the cap are wrapped back in, preserving shape).
    pub max_iter: usize,
}

impl FailureInjector {
    pub fn new(geom_p: f64, max_iter: usize) -> Self {
        assert!(geom_p > 0.0 && geom_p <= 1.0);
        assert!(max_iter >= 1);
        FailureInjector { geom_p, max_iter }
    }

    pub fn sample_iter(&self, rng: &mut Rng) -> usize {
        let k = rng.geometric(self.geom_p);
        ((k - 1) % self.max_iter) + 1
    }

    /// Lose a uniformly-random `fraction` of atoms (Fig 7 semantics).
    pub fn sample_atom_failure(
        &self,
        n_atoms: usize,
        fraction: f64,
        rng: &mut Rng,
    ) -> FailureEvent {
        let k = ((n_atoms as f64 * fraction).round() as usize).clamp(1, n_atoms);
        let mut lost = rng.sample_indices(n_atoms, k);
        lost.sort_unstable();
        FailureEvent { iter: self.sample_iter(rng), lost_atoms: lost, failed_nodes: vec![] }
    }

    /// Kill `n_failed` random PS nodes; lost atoms follow the partition
    /// (cluster semantics, §4.3).
    pub fn sample_node_failure(
        &self,
        partition: &Partition,
        n_failed: usize,
        rng: &mut Rng,
    ) -> FailureEvent {
        let n_nodes = partition.n_nodes();
        let n_failed = n_failed.min(n_nodes.saturating_sub(1)); // keep one survivor
        let mut nodes = rng.sample_indices(n_nodes, n_failed);
        nodes.sort_unstable();
        FailureEvent {
            iter: self.sample_iter(rng),
            lost_atoms: partition.lost_atoms(&nodes),
            failed_nodes: nodes,
        }
    }
}

// ---------------------------------------------------------------------------
// Heartbeat detector
// ---------------------------------------------------------------------------

/// Liveness state of one monitored node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Liveness {
    Alive,
    Suspected,
    Dead,
}

/// In-process heartbeat failure detector. PS node threads call
/// [`HeartbeatDetector::beat`]; the controller polls [`check`]. A node is
/// `Suspected` after `timeout` without a beat and `Dead` after
/// `2*timeout` (two-level so transient scheduling hiccups don't trigger
/// recovery — mirrors ZooKeeper session vs connection timeouts).
#[derive(Debug)]
pub struct HeartbeatDetector {
    timeout: Duration,
    last: HashMap<usize, Instant>,
    declared_dead: HashMap<usize, bool>,
}

impl HeartbeatDetector {
    pub fn new(timeout: Duration) -> Self {
        HeartbeatDetector { timeout, last: HashMap::new(), declared_dead: HashMap::new() }
    }

    pub fn register(&mut self, node: usize) {
        self.last.insert(node, Instant::now());
        self.declared_dead.insert(node, false);
    }

    pub fn beat(&mut self, node: usize) {
        self.beat_at(node, Instant::now());
    }

    /// Record a beat with its *send* timestamp. Controllers that drain
    /// beat channels lazily must use this — processing-time stamps would
    /// make stale buffered beats look fresh and mask real failures.
    pub fn beat_at(&mut self, node: usize, at: Instant) {
        // Beats from deregistered/dead nodes are ignored (a node must be
        // re-registered by the controller after replacement).
        if self.declared_dead.get(&node) == Some(&false) {
            let entry = self.last.entry(node).or_insert(at);
            if at > *entry {
                *entry = at;
            }
        }
    }

    pub fn deregister(&mut self, node: usize) {
        self.last.remove(&node);
        self.declared_dead.remove(&node);
    }

    pub fn liveness(&self, node: usize) -> Liveness {
        if self.declared_dead.get(&node) == Some(&true) {
            return Liveness::Dead;
        }
        match self.last.get(&node) {
            None => Liveness::Dead,
            Some(t) => {
                let dt = t.elapsed();
                if dt > 2 * self.timeout {
                    Liveness::Dead
                } else if dt > self.timeout {
                    Liveness::Suspected
                } else {
                    Liveness::Alive
                }
            }
        }
    }

    /// Poll: returns nodes newly declared dead (each reported once).
    pub fn check(&mut self) -> Vec<usize> {
        let mut newly_dead = Vec::new();
        let nodes: Vec<usize> = self.last.keys().copied().collect();
        for node in nodes {
            if self.declared_dead.get(&node) == Some(&true) {
                continue;
            }
            if self.last[&node].elapsed() > 2 * self.timeout {
                self.declared_dead.insert(node, true);
                newly_dead.push(node);
            }
        }
        newly_dead.sort_unstable();
        newly_dead
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failure_iter_within_cap() {
        let inj = FailureInjector::new(0.05, 30);
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let it = inj.sample_iter(&mut rng);
            assert!((1..=30).contains(&it));
        }
    }

    #[test]
    fn atom_failure_fraction() {
        let inj = FailureInjector::new(0.1, 50);
        let mut rng = Rng::new(2);
        let ev = inj.sample_atom_failure(100, 0.25, &mut rng);
        assert_eq!(ev.lost_atoms.len(), 25);
        let mut sorted = ev.lost_atoms.clone();
        sorted.dedup();
        assert_eq!(sorted.len(), 25);
    }

    #[test]
    fn node_failure_respects_partition() {
        let inj = FailureInjector::new(0.1, 50);
        let mut rng = Rng::new(3);
        let partition = Partition::random(40, 4, &mut rng);
        let ev = inj.sample_node_failure(&partition, 2, &mut rng);
        assert_eq!(ev.failed_nodes.len(), 2);
        for &a in &ev.lost_atoms {
            assert!(ev.failed_nodes.contains(&partition.owner[a]));
        }
    }

    #[test]
    fn node_failure_keeps_a_survivor() {
        let inj = FailureInjector::new(0.1, 50);
        let mut rng = Rng::new(4);
        let partition = Partition::random(10, 3, &mut rng);
        let ev = inj.sample_node_failure(&partition, 99, &mut rng);
        assert_eq!(ev.failed_nodes.len(), 2);
    }

    #[test]
    fn heartbeat_lifecycle() {
        // Generous margins: the suspected window is [T, 2T]; sleeps sit
        // mid-window so scheduler jitter on a loaded box cannot flip the
        // expected state.
        let mut det = HeartbeatDetector::new(Duration::from_millis(150));
        det.register(0);
        det.register(1);
        assert_eq!(det.liveness(0), Liveness::Alive);
        std::thread::sleep(Duration::from_millis(200));
        det.beat(1);
        assert_eq!(det.liveness(0), Liveness::Suspected);
        assert_eq!(det.liveness(1), Liveness::Alive);
        std::thread::sleep(Duration::from_millis(200));
        let dead = det.check();
        assert_eq!(dead, vec![0]);
        // Reported once only.
        assert!(det.check().is_empty());
        assert_eq!(det.liveness(0), Liveness::Dead);
        // Beats after death are ignored.
        det.beat(0);
        assert_eq!(det.liveness(0), Liveness::Dead);
    }

    #[test]
    fn unknown_node_is_dead() {
        let det = HeartbeatDetector::new(Duration::from_millis(10));
        assert_eq!(det.liveness(99), Liveness::Dead);
    }
}
