//! Per-shard rebuild planner: turn a set of dead storage shards into the
//! *minimal* set of atom slices that must be re-persisted.
//!
//! SCAR's core claim is that recovery cost is governed by the
//! perturbation you re-introduce, so the right system rebuilds only the
//! lost slice of state instead of blasting the full checkpoint back out.
//! Before this planner the checkpoint front-end re-persisted the
//! **entire** running checkpoint from its in-memory cache whenever any
//! shard died — write amplification proportional to the full model, for a
//! fault that only ever takes out `1/n_shards` of the records.
//!
//! The planner consumes the [`ShardedStore`] **placement map** (per atom:
//! which shard holds its freshest routed record) and the coordinator's
//! per-atom saved iterations, and produces a [`RebuildPlan`]: exactly the
//! atoms whose freshest committed record lived on a dead shard, grouped
//! by the iteration their replacement records must keep (records keep
//! their original saved iterations, so the commit-watermark recovery rule
//! is unchanged). Executing the plan writes those slices from the
//! coordinator's in-memory running-checkpoint cache (§4.3 keeps that
//! cache precisely so the persistent copy is re-derivable) through the
//! store's degraded router, which re-homes them onto survivors.
//!
//! The same plan shape also describes the *heal* direction: a flaky shard
//! that comes back re-adopts its slice (the atoms routed to it) via
//! [`RebuildPlan::for_atoms`], so its records are fresh again and a later
//! death of a survivor does not have to rebuild them.
//!
//! A plan can execute from two [`RebuildSource`]s: the coordinator's warm
//! in-memory cache (in-process recovery, the fast path), or — when the
//! cache died with the process — the store's **parity shards**
//! ([`RebuildPlan::execute_from_parity`]): each lost atom is
//! reconstructed from its stripe's surviving members plus the XOR parity
//! record alone (see [`crate::storage::parity`]), so a cold restart plus
//! a dead shard is still a bounded selective rebuild instead of data
//! loss.
//!
//! Byte-identity contract: every record the plan writes carries `(saved
//! iteration, cache value)` — exactly the payload the freshest committed
//! record for that atom already holds — so recovered parameters after a
//! selective rebuild are byte-identical to the old full re-persist
//! (pinned in `rust/tests/chaos.rs`), while `rebuilt_bytes` drops from
//! the full checkpoint size to roughly `1/n_shards` of it.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::params::{AtomLayout, ParamStore};
use crate::storage::ShardedStore;

/// Where a [`RebuildPlan`] sources its replacement payloads.
pub enum RebuildSource<'a> {
    /// The checkpoint coordinator's warm in-memory running-checkpoint
    /// cache — the in-process fast path.
    Cache(&'a ParamStore, &'a AtomLayout),
    /// The store's parity shards — the cold-restart path, when no cache
    /// survived the process.
    Parity,
}

/// A minimal rebuild: the atom slices whose freshest committed records
/// were lost (or must be re-adopted), each pinned to the iteration its
/// replacement record keeps.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RebuildPlan {
    /// Shards whose loss this plan repairs (empty for heal/re-adoption
    /// plans built from an explicit atom set).
    pub dead_shards: Vec<usize>,
    /// `(atom, record iteration)` pairs to rebuild, ascending by atom.
    pub atoms: Vec<(usize, usize)>,
}

impl RebuildPlan {
    /// Plan the rebuild for `dead` shards: an atom needs rebuilding iff
    /// its freshest routed record is placed on a dead shard. Unknown
    /// placement (a store reopened from disk, whose placement map only
    /// reflects writes through this handle) is treated as possibly-dead —
    /// conservative, never lossy.
    pub fn for_dead_shards(
        dead: &[usize],
        placement: &[Option<usize>],
        saved_iter: impl Fn(usize) -> usize,
        n_atoms: usize,
    ) -> RebuildPlan {
        let mut atoms = Vec::new();
        for atom in 0..n_atoms {
            let lost = match placement.get(atom).copied().flatten() {
                Some(shard) => dead.contains(&shard),
                None => true,
            };
            if lost {
                atoms.push((atom, saved_iter(atom)));
            }
        }
        RebuildPlan { dead_shards: dead.to_vec(), atoms }
    }

    /// Plan for an explicit atom set (heal re-adoption, and the cluster's
    /// dead-node slices).
    pub fn for_atoms(atoms: &[usize], saved_iter: impl Fn(usize) -> usize) -> RebuildPlan {
        let mut atoms: Vec<(usize, usize)> = atoms.iter().map(|&a| (a, saved_iter(a))).collect();
        atoms.sort_unstable();
        RebuildPlan { dead_shards: Vec::new(), atoms }
    }

    pub fn is_empty(&self) -> bool {
        self.atoms.is_empty()
    }

    /// Atoms this plan rebuilds.
    pub fn rebuilt_atoms(&self) -> usize {
        self.atoms.len()
    }

    /// The plan's slices grouped by the iteration their records keep —
    /// one store write per group, deterministic order (BTreeMap).
    pub fn by_iter(&self) -> BTreeMap<usize, Vec<usize>> {
        let mut slices: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for &(atom, iter) in &self.atoms {
            slices.entry(iter).or_default().push(atom);
        }
        slices
    }

    /// Execute against the coordinator's in-memory running-checkpoint
    /// cache: write each slice at its saved iteration through the store's
    /// (degraded) router, so replacement records land on live shards.
    /// Returns the payload bytes written — the `rebuilt_bytes` the
    /// reports carry.
    pub fn execute_from_cache(
        &self,
        cache: &ParamStore,
        layout: &AtomLayout,
        store: &ShardedStore,
    ) -> Result<u64> {
        self.execute_from_cache_with(cache, layout, store, 1)
    }

    /// [`execute_from_cache`](RebuildPlan::execute_from_cache) fanned out
    /// over up to `workers` threads, one slice group per home shard —
    /// the writer pool's rule, so each shard is written from exactly one
    /// thread and the result is byte-identical to the serial pass
    /// (records carry the same `(iteration, payload)` either way, and
    /// parity's XOR read-modify-write commutes across stripe members,
    /// exactly as it does under the async writer pool). Payloads are
    /// staged in one flat arena per group instead of an owned buffer per
    /// record.
    pub fn execute_from_cache_with(
        &self,
        cache: &ParamStore,
        layout: &AtomLayout,
        store: &ShardedStore,
        workers: usize,
    ) -> Result<u64> {
        let mut bytes = 0u64;
        for (iter, atoms) in self.by_iter() {
            let homes = store.shard_map(&atoms);
            let mut groups: Vec<Vec<usize>> = vec![Vec::new(); store.n_shards()];
            for (&a, home) in atoms.iter().zip(homes) {
                groups[home].push(a);
            }
            groups.retain(|g| !g.is_empty());
            let write_group = |group: &[usize]| -> Result<u64> {
                let mut buf = Vec::new();
                let mut arena: Vec<f32> = Vec::new();
                let mut spans: Vec<(usize, usize, usize)> = Vec::with_capacity(group.len());
                for &a in group {
                    cache.read_atom(layout, a, &mut buf);
                    let start = arena.len();
                    arena.extend_from_slice(&buf);
                    spans.push((a, start, arena.len()));
                }
                let refs: Vec<(usize, &[f32])> =
                    spans.iter().map(|&(a, s, e)| (a, &arena[s..e])).collect();
                store.put_atoms_at(iter, &refs)?;
                Ok((arena.len() * 4) as u64)
            };
            let n_workers = workers.max(1).min(groups.len().max(1));
            if n_workers <= 1 {
                for g in &groups {
                    bytes += write_group(g)?;
                }
                continue;
            }
            let chunk = (groups.len() + n_workers - 1) / n_workers;
            let write_group = &write_group;
            let results: Vec<Result<u64>> = std::thread::scope(|scope| {
                let handles: Vec<_> = groups
                    .chunks(chunk)
                    .map(|part| {
                        scope.spawn(move || -> Result<u64> {
                            let mut total = 0u64;
                            for g in part {
                                total += write_group(g)?;
                            }
                            Ok(total)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("rebuild worker panicked"))
                    .collect()
            });
            for r in results {
                bytes += r?;
            }
        }
        Ok(bytes)
    }

    /// Execute against the store's parity shards: each planned atom is
    /// reconstructed from its stripe's surviving members plus the parity
    /// record — the atom's own (lost) records are never read — and
    /// re-persisted at the iteration the parity metadata carries (the
    /// plan's own iterations may be a conservative `0` when the caller
    /// has no coordinator state, as after a cold restart). Atoms with no
    /// parity coverage (never written) are skipped; a stripe with more
    /// damage than parity absorbs is a hard error. Returns the payload
    /// bytes written, like
    /// [`execute_from_cache`](RebuildPlan::execute_from_cache).
    pub fn execute_from_parity(&self, store: &ShardedStore) -> Result<u64> {
        self.execute_from_parity_with(store, 1)
    }

    /// [`execute_from_parity`](RebuildPlan::execute_from_parity) fanned
    /// out over up to `workers` threads. Each worker owns a contiguous
    /// chunk of the (sorted) plan and one reusable reconstruction buffer
    /// — no per-atom allocation. Safe to run concurrently: every
    /// construction path hands the plan atoms whose reconstructions are
    /// independent (atoms sharing a home shard occupy distinct stripes
    /// under `slot = atom % n_shards` routing), and repairs write exactly
    /// the bytes parity already encodes, so worker interleaving cannot
    /// change any record.
    pub fn execute_from_parity_with(&self, store: &ShardedStore, workers: usize) -> Result<u64> {
        let rebuild = |atoms: &[(usize, usize)]| -> Result<u64> {
            let mut bytes = 0u64;
            let mut buf: Vec<f32> = Vec::new();
            for &(atom, _) in atoms {
                let Some(iter) = store.reconstruct_atom_into(atom, &mut buf)? else {
                    continue;
                };
                bytes += (buf.len() * 4) as u64;
                store.put_atoms_repair(iter, &[(atom, &buf[..])])?;
            }
            Ok(bytes)
        };
        let n_workers = workers.max(1).min(self.atoms.len().max(1));
        if n_workers <= 1 {
            return rebuild(&self.atoms);
        }
        let chunk = (self.atoms.len() + n_workers - 1) / n_workers;
        let rebuild = &rebuild;
        let results: Vec<Result<u64>> = std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .atoms
                .chunks(chunk)
                .map(|part| scope.spawn(move || rebuild(part)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("rebuild worker panicked"))
                .collect()
        });
        let mut bytes = 0u64;
        for r in results {
            bytes += r?;
        }
        Ok(bytes)
    }

    /// Dispatch on the payload source (see [`RebuildSource`]).
    pub fn execute(&self, source: RebuildSource<'_>, store: &ShardedStore) -> Result<u64> {
        match source {
            RebuildSource::Cache(cache, layout) => {
                self.execute_from_cache(cache, layout, store)
            }
            RebuildSource::Parity => self.execute_from_parity(store),
        }
    }

    /// Narrate an execution of this plan into a flight recorder: one
    /// `Rebuild` event at `iter` carrying the payload source tag
    /// (`"cache"` / `"parity"`), the plan's atom count, the bytes the
    /// execute call reported, and the worker fan-out it ran with.
    pub fn record_into(
        &self,
        rec: &crate::obs::Recorder,
        iter: usize,
        source: &str,
        bytes: u64,
        workers: usize,
    ) {
        if !rec.is_enabled() {
            return;
        }
        rec.record(
            iter,
            crate::obs::EventKind::Rebuild {
                source: source.to_string(),
                atoms: self.rebuilt_atoms(),
                bytes,
                workers,
            },
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::{AtomLayout, ParamStore, Tensor};

    #[test]
    fn plans_only_dead_placed_atoms() {
        // Atoms 0..6; placement: even atoms on shard 0, odd on shard 1,
        // atom 5 unknown (conservatively rebuilt).
        let placement = vec![Some(0), Some(1), Some(0), Some(1), Some(0), None];
        let plan = RebuildPlan::for_dead_shards(&[1], &placement, |a| 10 + a, 6);
        assert_eq!(plan.dead_shards, vec![1]);
        assert_eq!(plan.atoms, vec![(1, 11), (3, 13), (5, 15)]);
        assert_eq!(plan.rebuilt_atoms(), 3);
        let by = plan.by_iter();
        assert_eq!(by.len(), 3);
        assert_eq!(by[&11], vec![1]);

        // Nothing placed on the dead shard: the plan is empty — the old
        // behavior re-persisted the whole checkpoint here.
        let all_safe = vec![Some(0); 6];
        assert!(RebuildPlan::for_dead_shards(&[1], &all_safe, |_| 0, 6).is_empty());
    }

    #[test]
    fn executes_slices_from_the_cache_and_counts_bytes() {
        let mut cache = ParamStore::new(vec![Tensor::zeros("w", &[4, 2])]);
        let layout = AtomLayout::new(AtomLayout::rows_of(&cache, "w"));
        for (i, v) in cache.get_mut("w").data.iter_mut().enumerate() {
            *v = i as f32;
        }
        let store = ShardedStore::new_mem(2);
        // Saved iters: atom 1 at 4, atom 3 at 4, grouped into one write.
        let plan = RebuildPlan::for_atoms(&[1, 3], |_| 4);
        let bytes = plan.execute_from_cache(&cache, &layout, &store).unwrap();
        assert_eq!(bytes, 16, "2 atoms x 2 f32s x 4 bytes");
        let got = store.get_atom_any(3).unwrap().unwrap();
        assert_eq!(got.iter, 4);
        assert_eq!(got.values, vec![6.0, 7.0]);
        assert!(store.get_atom_any(0).unwrap().is_none(), "unplanned atom untouched");
    }

    #[test]
    fn executes_from_parity_without_the_cache() {
        let store = ShardedStore::new_mem(2).with_mem_parity(1);
        let payloads: Vec<(usize, Vec<f32>)> =
            (0..4).map(|a| (a, vec![a as f32 + 0.25, -(a as f32)])).collect();
        let refs: Vec<(usize, &[f32])> =
            payloads.iter().map(|(a, v)| (*a, v.as_slice())).collect();
        store.put_atoms_at(5, &refs).unwrap();
        store.parity_fence().unwrap();
        // Lose shard 0's records outright (the cache is gone with the
        // process — the plan's iterations are the conservative 0).
        for atom in [0usize, 2] {
            assert!(store.corrupt_record_on(0, atom).unwrap());
        }
        let plan = RebuildPlan::for_atoms(&[0, 2], |_| 0);
        let bytes = plan
            .execute(RebuildSource::Parity, &store)
            .expect("parity rebuild");
        assert_eq!(bytes, 16, "2 atoms x 2 f32s x 4 bytes");
        for atom in [0usize, 2] {
            let got = store.get_atom_any(atom).unwrap().unwrap();
            assert_eq!(got.iter, 5, "record iteration restored from parity metadata");
            assert_eq!(got.values, vec![atom as f32 + 0.25, -(atom as f32)]);
        }
    }

    #[test]
    fn parallel_execute_matches_serial() {
        // Cache path: the same plan through 1 worker and 4 workers must
        // land byte-identical records and report the same byte count.
        let mut cache = ParamStore::new(vec![Tensor::zeros("w", &[16, 2])]);
        let layout = AtomLayout::new(AtomLayout::rows_of(&cache, "w"));
        for (i, v) in cache.get_mut("w").data.iter_mut().enumerate() {
            *v = i as f32 * 0.5;
        }
        let atoms: Vec<usize> = (0..16).collect();
        let plan = RebuildPlan::for_atoms(&atoms, |a| 3 + (a % 2));
        let serial = ShardedStore::new_mem(4);
        let fanned = ShardedStore::new_mem(4);
        let b1 = plan.execute_from_cache(&cache, &layout, &serial).unwrap();
        let b2 = plan.execute_from_cache_with(&cache, &layout, &fanned, 4).unwrap();
        assert_eq!(b1, b2, "cache-path bytes");
        for a in 0..16 {
            let lhs = serial.get_atom_any(a).unwrap().unwrap();
            let rhs = fanned.get_atom_any(a).unwrap().unwrap();
            assert_eq!((lhs.iter, lhs.values), (rhs.iter, rhs.values), "atom {a}");
        }

        // Parity path: reconstruct shard 2's wiped slice serially and
        // with 4 workers from identically-prepared stores.
        let build = || {
            let store = ShardedStore::new_mem(4).with_mem_parity(1);
            let payloads: Vec<(usize, Vec<f32>)> =
                (0..16).map(|a| (a, vec![a as f32, -(a as f32)])).collect();
            let refs: Vec<(usize, &[f32])> =
                payloads.iter().map(|(a, v)| (*a, v.as_slice())).collect();
            store.put_atoms_at(7, &refs).unwrap();
            store.parity_fence().unwrap();
            for atom in (2..16).step_by(4) {
                assert!(store.corrupt_record_on(2, atom).unwrap());
            }
            store
        };
        let victims: Vec<usize> = (2..16).step_by(4).collect();
        let plan = RebuildPlan::for_atoms(&victims, |_| 0);
        let (s1, s2) = (build(), build());
        let b1 = plan.execute_from_parity(&s1).unwrap();
        let b2 = plan.execute_from_parity_with(&s2, 4).unwrap();
        assert_eq!(b1, b2, "parity-path bytes");
        assert_eq!(b1, 32, "4 atoms x 2 f32s x 4 bytes");
        for a in 0..16 {
            let lhs = s1.get_atom_any(a).unwrap().unwrap();
            let rhs = s2.get_atom_any(a).unwrap().unwrap();
            assert_eq!((lhs.iter, lhs.values), (rhs.iter, rhs.values), "atom {a}");
        }
    }
}
