//! Recovery coordinator (paper §4.1, §4.3).
//!
//! On failure of a subset of PS nodes, the coordinator either
//!
//! * **fully** restores *all* atoms from the running checkpoint (the
//!   traditional baseline — the whole job state rolls back), or
//! * **partially** restores only the atoms owned by the failed nodes,
//!   leaving surviving atoms at their current (more converged) values.
//!
//! Theorem 4.1: the partial perturbation is never larger; Theorem 4.2:
//! with uniformly-random loss of fraction p, E‖δ'‖² = p‖δ‖². Both are
//! checked as properties in `rust/tests/proptests.rs`, and the returned
//! [`RecoveryReport`] carries the measured ‖δ‖ so experiments can feed the
//! Theorem 3.2 bound.

pub mod planner;

use anyhow::{Context, Result};

use crate::params::{AtomLayout, ParamStore};
use crate::storage::CheckpointStore;

pub use planner::{RebuildPlan, RebuildSource};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryMode {
    Full,
    Partial,
}

/// The commit-watermark read rule, shared by every recovery path
/// (coordinator [`recover`] and the cluster's `recover_nodes`): a record
/// newer than the store's watermark belongs to an in-flight async barrier
/// and must not be read — the caller forgot the `flush` epoch fence.
pub(crate) fn check_watermark(
    atom: usize,
    saved_iter: usize,
    watermark: Option<usize>,
) -> Result<()> {
    if let Some(w) = watermark {
        if saved_iter > w {
            anyhow::bail!(
                "atom {atom} record from iteration {saved_iter} is beyond the commit \
                 watermark {w}; flush the checkpoint pipeline before recovery"
            );
        }
    }
    Ok(())
}

impl std::str::FromStr for RecoveryMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "full" => Ok(RecoveryMode::Full),
            "partial" => Ok(RecoveryMode::Partial),
            other => Err(format!("unknown recovery mode '{other}' (full|partial)")),
        }
    }
}

/// What recovery did, including the perturbation size ‖δ‖ it injected
/// (distance between the pre-failure state and the post-recovery state).
#[derive(Debug, Clone)]
pub struct RecoveryReport {
    pub mode: RecoveryMode,
    pub atoms_restored: usize,
    pub elems_restored: usize,
    /// ‖δ‖: L2 distance between pre-failure and post-recovery full state.
    pub delta_norm: f64,
    pub secs: f64,
}

/// Restore `state` after losing `lost_atoms`, reading the running
/// checkpoint through `store`.
///
/// * `Partial`: only `lost_atoms` are overwritten.
/// * `Full`: every atom is overwritten (traditional checkpoint-restart).
///
/// Atoms never checkpointed fall back to their value in the coordinator's
/// initial snapshot — impossible here because the coordinator persists
/// x⁽⁰⁾ at startup, so a missing record is an error.
///
/// **Commit-watermark rule:** when the store tracks a watermark (the
/// sharded/pipelined store does), recovery only ever reads
/// fully-committed running-checkpoint state — a record newer than the
/// watermark means an async barrier is still in flight and the caller
/// forgot the `flush` epoch fence
/// ([`AsyncCheckpointer::flush`](crate::checkpoint::AsyncCheckpointer::flush)).
/// That is a hard error: recovering from a half-committed barrier would
/// make async and sync runs diverge silently.
///
/// **Degraded mode:** when a storage shard is down (an injected fault
/// from [`crate::chaos`], or any backend reporting
/// [`is_down`](crate::storage::ShardBackend::is_down)), the sharded
/// store's read scan skips it and recovery proceeds through the
/// *surviving* shards' records, still under the watermark. The checkpoint
/// front-end re-persists the dead shard's slice from its in-memory cache
/// the moment the shard dies, so every atom keeps a readable record and a
/// shard loss degrades placement, never recoverability
/// (`rust/tests/chaos.rs` pins recovered bytes across shard kills).
pub fn recover(
    mode: RecoveryMode,
    state: &mut ParamStore,
    layout: &AtomLayout,
    lost_atoms: &[usize],
    store: &dyn CheckpointStore,
) -> Result<RecoveryReport> {
    let t0 = std::time::Instant::now();
    let pre = state.clone();
    let all_atoms: Vec<usize>;
    let atoms: &[usize] = match mode {
        RecoveryMode::Partial => lost_atoms,
        RecoveryMode::Full => {
            all_atoms = (0..layout.n_atoms()).collect();
            &all_atoms
        }
    };
    let watermark = store.committed_iter();
    let mut elems = 0usize;
    // Single-copy restore path: records decode straight into `buf` (on
    // mmap-backed disk shards, directly out of the mapped segment) and
    // from there into the live state — no intermediate `SavedAtom`.
    let mut buf = Vec::new();
    for &a in atoms {
        let saved_iter = store
            .read_atom_into(a, &mut buf)
            .with_context(|| format!("reading atom {a} from checkpoint store"))?
            .with_context(|| format!("atom {a} missing from running checkpoint"))?;
        check_watermark(a, saved_iter, watermark)?;
        elems += buf.len();
        state.write_atom(layout, a, &buf);
    }
    Ok(RecoveryReport {
        mode,
        atoms_restored: atoms.len(),
        elems_restored: elems,
        delta_norm: state.l2_distance(&pre),
        secs: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::{CheckpointCoordinator, CheckpointPolicy};
    use crate::params::{AtomLayout, ParamStore, Tensor};
    use crate::storage::MemStore;
    use crate::util::rng::Rng;

    /// Build: x(0)=0, checkpoint at x(C)=1, current x(T)=2 per element.
    fn scenario(n: usize) -> (ParamStore, AtomLayout, MemStore) {
        let ps0 = ParamStore::new(vec![Tensor::zeros("w", &[n, 2])]);
        let layout = AtomLayout::new(AtomLayout::rows_of(&ps0, "w"));
        let mut store = MemStore::new();
        let mut coord =
            CheckpointCoordinator::new(CheckpointPolicy::full(1), &ps0, &layout, &mut store)
                .unwrap();
        let mut rng = Rng::new(0);
        let mut ps_c = ps0.clone();
        ps_c.get_mut("w").data.iter_mut().for_each(|v| *v = 1.0);
        coord.checkpoint_now(5, &ps_c, &layout, &mut store, &mut rng).unwrap();
        let mut ps_t = ps0;
        ps_t.get_mut("w").data.iter_mut().for_each(|v| *v = 2.0);
        (ps_t, layout, store)
    }

    #[test]
    fn partial_restores_only_lost() {
        let (mut state, layout, store) = scenario(4);
        let rep = recover(RecoveryMode::Partial, &mut state, &layout, &[1, 3], &store).unwrap();
        assert_eq!(rep.atoms_restored, 2);
        let w = &state.get("w").data;
        assert_eq!(&w[..], &[2., 2., 1., 1., 2., 2., 1., 1.]);
        // ‖δ'‖ = sqrt(4 elements × 1²) = 2
        assert!((rep.delta_norm - 2.0).abs() < 1e-9);
    }

    #[test]
    fn full_restores_everything() {
        let (mut state, layout, store) = scenario(4);
        let rep = recover(RecoveryMode::Full, &mut state, &layout, &[1], &store).unwrap();
        assert_eq!(rep.atoms_restored, 4);
        assert!(state.get("w").data.iter().all(|&v| v == 1.0));
        // ‖δ‖ = sqrt(8 × 1²)
        assert!((rep.delta_norm - 8f64.sqrt()).abs() < 1e-9);
    }

    #[test]
    fn thm_4_1_partial_delta_never_larger() {
        let (state, layout, store) = scenario(6);
        let full = recover(
            RecoveryMode::Full,
            &mut state.clone(),
            &layout,
            &[0, 2, 4],
            &store,
        )
        .unwrap();
        let part = recover(
            RecoveryMode::Partial,
            &mut state.clone(),
            &layout,
            &[0, 2, 4],
            &store,
        )
        .unwrap();
        assert!(part.delta_norm <= full.delta_norm + 1e-12);
    }

    #[test]
    fn recovery_refuses_records_beyond_watermark() {
        use crate::storage::ShardedStore;
        let ps0 = ParamStore::new(vec![Tensor::zeros("w", &[3, 2])]);
        let layout = AtomLayout::new(AtomLayout::rows_of(&ps0, "w"));
        let store = ShardedStore::new_mem(2);
        store
            .put_atoms_at(
                0,
                &[(0, &[0.0, 0.0][..]), (1, &[0.0, 0.0][..]), (2, &[0.0, 0.0][..])],
            )
            .unwrap();
        store.mark_committed_at(4);
        // An in-flight async barrier's record lands beyond the watermark.
        store.put_atoms_at(8, &[(1, &[9.0, 9.0][..])]).unwrap();
        let mut state = ps0.clone();
        let err =
            recover(RecoveryMode::Partial, &mut state, &layout, &[1], &store).unwrap_err();
        assert!(format!("{err:?}").contains("watermark"), "{err:?}");
        // Once the barrier commits (the flush fence), the read succeeds.
        store.mark_committed_at(8);
        recover(RecoveryMode::Partial, &mut state, &layout, &[1], &store).unwrap();
        assert_eq!(&state.get("w").data[2..4], &[9.0, 9.0][..]);
    }

    #[test]
    fn no_loss_partial_is_identity() {
        let (mut state, layout, store) = scenario(3);
        let before = state.clone();
        let rep = recover(RecoveryMode::Partial, &mut state, &layout, &[], &store).unwrap();
        assert_eq!(rep.delta_norm, 0.0);
        assert_eq!(state.get("w").data, before.get("w").data);
    }
}
