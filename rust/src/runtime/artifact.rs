//! Artifact metadata: the contract between the L2 compile path and the
//! L3 coordinator.
//!
//! `python/compile/aot.py` writes, per model variant, an HLO-text file and
//! a `<name>.meta.json` describing the step function's flat signature:
//! inputs/outputs with a *kind* each —
//!
//! * `param`  — model parameters (atomized, checkpointed, recoverable)
//! * `opt`    — optimizer state co-located with params (checkpointed)
//! * `data`   — per-iteration inputs the coordinator feeds (batches,
//!              step counters, problem constants)
//! * `metric` — outputs only: the loss scalar
//!
//! Output convention: updated `param`/`opt` tensors in input order, then
//! the `(1,)` loss.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoKind {
    Param,
    Opt,
    Data,
    Metric,
}

impl IoKind {
    fn parse(s: &str) -> Result<IoKind> {
        Ok(match s {
            "param" => IoKind::Param,
            "opt" => IoKind::Opt,
            "data" => IoKind::Data,
            "metric" => IoKind::Metric,
            other => bail!("unknown io kind '{other}'"),
        })
    }

    /// Is this tensor part of the checkpointed job state?
    pub fn is_state(self) -> bool {
        matches!(self, IoKind::Param | IoKind::Opt)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub kind: IoKind,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl IoSpec {
    pub fn elem_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn parse(v: &Json) -> Result<IoSpec> {
        let name = v.get("name").as_str().context("io entry missing name")?.to_string();
        let kind = IoKind::parse(v.get("kind").as_str().context("io entry missing kind")?)?;
        let shape = v
            .get("shape")
            .as_arr()
            .context("io entry missing shape")?
            .iter()
            .map(|s| s.as_usize().context("bad shape entry"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = match v.get("dtype").as_str().unwrap_or("f32") {
            "f32" => DType::F32,
            "i32" => DType::I32,
            other => bail!("unsupported dtype '{other}'"),
        };
        Ok(IoSpec { name, kind, shape, dtype })
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub model: String,
    pub hlo_path: PathBuf,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub hyper: Json,
    pub atoms_hint: Json,
}

impl ArtifactMeta {
    pub fn load(meta_path: &Path) -> Result<ArtifactMeta> {
        let text = std::fs::read_to_string(meta_path)
            .with_context(|| format!("reading {}", meta_path.display()))?;
        let v = Json::parse(&text)
            .with_context(|| format!("parsing {}", meta_path.display()))?;
        Self::from_json(&v, meta_path.parent().unwrap_or(Path::new(".")))
    }

    pub fn from_json(v: &Json, dir: &Path) -> Result<ArtifactMeta> {
        let name = v.get("name").as_str().context("meta missing name")?.to_string();
        let model = v.get("model").as_str().unwrap_or("").to_string();
        let hlo = v.get("hlo").as_str().context("meta missing hlo")?;
        let inputs = v
            .get("inputs")
            .as_arr()
            .context("meta missing inputs")?
            .iter()
            .map(IoSpec::parse)
            .collect::<Result<Vec<_>>>()?;
        let outputs = v
            .get("outputs")
            .as_arr()
            .context("meta missing outputs")?
            .iter()
            .map(IoSpec::parse)
            .collect::<Result<Vec<_>>>()?;
        let meta = ArtifactMeta {
            name,
            model,
            hlo_path: dir.join(hlo),
            inputs,
            outputs,
            hyper: v.get("hyper").clone(),
            atoms_hint: v.get("atoms").clone(),
        };
        meta.validate()?;
        Ok(meta)
    }

    /// Interface sanity: outputs must be the state tensors (in input
    /// order) followed by exactly one metric.
    pub fn validate(&self) -> Result<()> {
        let state_in: Vec<&IoSpec> =
            self.inputs.iter().filter(|s| s.kind.is_state()).collect();
        let state_out: Vec<&IoSpec> =
            self.outputs.iter().filter(|s| s.kind.is_state()).collect();
        if state_in.len() != state_out.len() {
            bail!(
                "artifact {}: {} state inputs but {} state outputs",
                self.name,
                state_in.len(),
                state_out.len()
            );
        }
        for (i, o) in state_in.iter().zip(&state_out) {
            if i.name != o.name || i.shape != o.shape {
                bail!(
                    "artifact {}: state io mismatch {} {:?} vs {} {:?}",
                    self.name,
                    i.name,
                    i.shape,
                    o.name,
                    o.shape
                );
            }
        }
        let metrics: Vec<&IoSpec> = self
            .outputs
            .iter()
            .filter(|s| s.kind == IoKind::Metric)
            .collect();
        if metrics.len() != 1 {
            bail!("artifact {}: expected exactly 1 metric output", self.name);
        }
        if self.outputs.last().map(|s| s.kind) != Some(IoKind::Metric) {
            bail!("artifact {}: metric must be the last output", self.name);
        }
        Ok(())
    }

    pub fn state_specs(&self) -> Vec<&IoSpec> {
        self.inputs.iter().filter(|s| s.kind.is_state()).collect()
    }

    pub fn data_specs(&self) -> Vec<&IoSpec> {
        self.inputs.iter().filter(|s| s.kind == IoKind::Data).collect()
    }

    pub fn hyper_f64(&self, key: &str) -> Option<f64> {
        self.hyper.get(key).as_f64()
    }
}

/// Discover every artifact in a directory (via `*.meta.json`).
pub fn discover(dir: &Path) -> Result<Vec<ArtifactMeta>> {
    let mut metas = Vec::new();
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("listing artifact dir {}", dir.display()))?;
    for entry in entries {
        let path = entry?.path();
        if path.file_name().and_then(|n| n.to_str()).map_or(false, |n| n.ends_with(".meta.json"))
        {
            metas.push(ArtifactMeta::load(&path)?);
        }
    }
    metas.sort_by(|a, b| a.name.cmp(&b.name));
    Ok(metas)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta_json(extra_out: &str) -> String {
        format!(
            r#"{{
              "name": "toy", "model": "qp", "hlo": "toy.hlo.txt",
              "inputs": [
                {{"name":"x","kind":"param","shape":[4],"dtype":"f32"}},
                {{"name":"a","kind":"data","shape":[4,4],"dtype":"f32"}}
              ],
              "outputs": [
                {{"name":"x","kind":"param","shape":[4],"dtype":"f32"}}{extra_out}
              ],
              "hyper": {{"lr": 0.05}}
            }}"#
        )
    }

    #[test]
    fn parses_valid_meta() {
        let j = Json::parse(&meta_json(
            r#", {"name":"loss","kind":"metric","shape":[1],"dtype":"f32"}"#,
        ))
        .unwrap();
        let m = ArtifactMeta::from_json(&j, Path::new("/tmp")).unwrap();
        assert_eq!(m.name, "toy");
        assert_eq!(m.inputs.len(), 2);
        assert_eq!(m.state_specs().len(), 1);
        assert_eq!(m.data_specs().len(), 1);
        assert_eq!(m.hyper_f64("lr"), Some(0.05));
        assert_eq!(m.hlo_path, Path::new("/tmp/toy.hlo.txt"));
    }

    #[test]
    fn rejects_missing_metric() {
        let j = Json::parse(&meta_json("")).unwrap();
        assert!(ArtifactMeta::from_json(&j, Path::new("/tmp")).is_err());
    }

    #[test]
    fn rejects_state_mismatch() {
        let src = r#"{
          "name":"bad","model":"m","hlo":"h",
          "inputs":[{"name":"x","kind":"param","shape":[4]}],
          "outputs":[{"name":"y","kind":"param","shape":[4]},
                     {"name":"loss","kind":"metric","shape":[1]}]
        }"#;
        let j = Json::parse(src).unwrap();
        assert!(ArtifactMeta::from_json(&j, Path::new("/tmp")).is_err());
    }

    #[test]
    fn elem_count() {
        let spec = IoSpec { name: "w".into(), kind: IoKind::Param, shape: vec![3, 4], dtype: DType::F32 };
        assert_eq!(spec.elem_count(), 12);
    }
}
