//! PJRT runtime: load AOT artifacts and execute them from the hot path.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! One compiled executable per model variant, cached for the process
//! lifetime. Python never runs here — artifacts are produced once by
//! `make artifacts`.

pub mod artifact;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

pub use artifact::{ArtifactMeta, DType, IoKind, IoSpec};

/// A compiled model variant ready to execute.
pub struct LoadedArtifact {
    pub meta: ArtifactMeta,
    exe: PjRtLoadedExecutable,
}

/// The process-wide PJRT engine: client + executable cache.
pub struct Engine {
    client: PjRtClient,
    artifact_dir: PathBuf,
    cache: HashMap<String, LoadedArtifact>,
}

impl Engine {
    /// CPU PJRT client rooted at an artifact directory.
    pub fn cpu(artifact_dir: &Path) -> Result<Engine> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, artifact_dir: artifact_dir.to_path_buf(), cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.artifact_dir
    }

    /// Load + compile (or fetch from cache) a variant by name.
    pub fn load(&mut self, name: &str) -> Result<&LoadedArtifact> {
        if !self.cache.contains_key(name) {
            let meta_path = self.artifact_dir.join(format!("{name}.meta.json"));
            let meta = ArtifactMeta::load(&meta_path)?;
            let proto = HloModuleProto::from_text_file(
                meta.hlo_path
                    .to_str()
                    .context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text for {name}"))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?;
            self.cache.insert(name.to_string(), LoadedArtifact { meta, exe });
        }
        Ok(&self.cache[name])
    }

    /// Upload an f32 host slice straight to a device buffer (one copy —
    /// the L3 upload hot path; see EXPERIMENTS.md §Perf).
    pub fn buffer_f32(&self, shape: &[usize], data: &[f32]) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, shape, None)?)
    }

    /// Upload an i32 host slice straight to a device buffer.
    pub fn buffer_i32(&self, shape: &[usize], data: &[i32]) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, shape, None)?)
    }

    /// Upload a [`HostTensor`].
    pub fn to_buffer(&self, t: &HostTensor) -> Result<PjRtBuffer> {
        match t {
            HostTensor::F32 { shape, data } => self.buffer_f32(shape, data),
            HostTensor::I32 { shape, data } => self.buffer_i32(shape, data),
        }
    }

    /// Execute a loaded artifact on device buffers. The artifact was
    /// lowered with `return_tuple=True`, so the single device output is a
    /// tuple literal that we decompose into the flat output list.
    ///
    /// NOTE: this deliberately routes through `execute_b` (caller-owned
    /// input buffers): the xla crate's literal-based `execute` leaks every
    /// input device buffer per call (`buffer.release()` without a
    /// matching free in xla_rs.cc) — ~MBs/step on our workloads.
    pub fn execute_buffers<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        name: &str,
        inputs: &[B],
    ) -> Result<Vec<Literal>> {
        let art = self
            .cache
            .get(name)
            .with_context(|| format!("artifact {name} not loaded"))?;
        if inputs.len() != art.meta.inputs.len() {
            bail!(
                "artifact {name}: got {} inputs, expected {}",
                inputs.len(),
                art.meta.inputs.len()
            );
        }
        let result = art.exe.execute_b::<B>(inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }

    /// Execute with host literals (buffers created and freed internally).
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        name: &str,
        inputs: &[L],
    ) -> Result<Vec<Literal>> {
        let buffers: Vec<PjRtBuffer> = inputs
            .iter()
            .map(|l| Ok(self.client.buffer_from_host_literal(None, l.borrow())?))
            .collect::<Result<_>>()?;
        self.execute_buffers(name, &buffers)
    }

    /// Convenience: load-if-needed then execute.
    pub fn run<L: std::borrow::Borrow<Literal>>(
        &mut self,
        name: &str,
        inputs: &[L],
    ) -> Result<Vec<Literal>> {
        self.load(name)?;
        self.execute(name, inputs)
    }
}

/// A host-side tensor ready for device upload — what model data streams
/// produce (avoids building an intermediate `Literal`, which would cost a
/// second copy on the upload path).
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> HostTensor {
        debug_assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        HostTensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> HostTensor {
        debug_assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        HostTensor::I32 { shape: shape.to_vec(), data }
    }
}

// ---------------------------------------------------------------------------
// Literal <-> host helpers
// ---------------------------------------------------------------------------

/// Build an f32 literal of the given shape from host data. Single memcpy
/// (`vec1` + `reshape` would copy twice — this is the L3 upload hot path,
/// see EXPERIMENTS.md §Perf).
pub fn literal_f32(shape: &[usize], data: &[f32]) -> Result<Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    if n != data.len() {
        bail!("literal_f32: shape {:?} wants {} elems, got {}", shape, n, data.len());
    }
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    Ok(Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        shape,
        bytes,
    )?)
}

/// Build an i32 literal of the given shape from host data (single memcpy).
pub fn literal_i32(shape: &[usize], data: &[i32]) -> Result<Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    if n != data.len() {
        bail!("literal_i32: shape {:?} wants {} elems, got {}", shape, n, data.len());
    }
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    Ok(Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        shape,
        bytes,
    )?)
}

/// Read an f32 literal back to host.
pub fn literal_to_f32(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Copy an f32 literal into an existing host buffer (no allocation —
/// the L3 download hot path).
pub fn literal_into_f32(lit: &Literal, dst: &mut [f32]) -> Result<()> {
    if lit.element_count() != dst.len() {
        bail!(
            "literal_into_f32: literal has {} elems, dst has {}",
            lit.element_count(),
            dst.len()
        );
    }
    lit.copy_raw_to(dst)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let lit = literal_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(literal_to_f32(&lit).unwrap(), vec![1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[2, 2], &[1.0]).is_err());
        assert!(literal_i32(&[3], &[1, 2]).is_err());
    }
}
