//! Synthetic dataset generators (DESIGN.md §3 substitutions).
//!
//! The paper's datasets (MNIST, CoverType, MovieLens, Jester, 20News,
//! Reuters, ClueWeb12) are not available offline; each generator below
//! produces a synthetic workload matched on the statistics that govern
//! the training dynamics the paper measures — dimensionality, class
//! structure, rank/sparsity, topic structure — so iteration-cost
//! behaviour is preserved even though absolute losses differ.

use crate::util::rng::Rng;

/// Dense classification dataset: Gaussian mixture with one component per
/// class (stand-in for MNIST / CoverType in MLR and CNN experiments).
#[derive(Debug, Clone)]
pub struct Classification {
    pub dim: usize,
    pub classes: usize,
    /// xs is row-major (n, dim)
    pub xs: Vec<f32>,
    pub labels: Vec<usize>,
}

impl Classification {
    pub fn gaussian_mixture(
        dim: usize,
        classes: usize,
        n: usize,
        sep: f64,
        seed: u64,
    ) -> Classification {
        let mut rng = Rng::new(seed);
        // Random unit mean per class, scaled by `sep`.
        let mut means = vec![0f32; classes * dim];
        for c in 0..classes {
            let mut norm = 0.0f64;
            for d in 0..dim {
                let v = rng.normal();
                means[c * dim + d] = v as f32;
                norm += v * v;
            }
            let norm = norm.sqrt().max(1e-9);
            for d in 0..dim {
                means[c * dim + d] = (means[c * dim + d] as f64 / norm * sep) as f32;
            }
        }
        let mut xs = vec![0f32; n * dim];
        let mut labels = vec![0usize; n];
        for i in 0..n {
            let c = rng.below(classes);
            labels[i] = c;
            for d in 0..dim {
                xs[i * dim + d] = means[c * dim + d] + rng.normal() as f32;
            }
        }
        Classification { dim, classes, xs, labels }
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Sample a batch: (x row-major (b, dim), one-hot y (b, classes)).
    pub fn batch(&self, b: usize, rng: &mut Rng) -> (Vec<f32>, Vec<f32>) {
        let mut x = vec![0f32; b * self.dim];
        let mut y = vec![0f32; b * self.classes];
        for i in 0..b {
            let j = rng.below(self.len());
            x[i * self.dim..(i + 1) * self.dim]
                .copy_from_slice(&self.xs[j * self.dim..(j + 1) * self.dim]);
            y[i * self.classes + self.labels[j]] = 1.0;
        }
        (x, y)
    }
}

/// Low-rank + noise ratings matrix with a Bernoulli observation mask
/// (stand-in for MovieLens / Jester in the MF-ALS experiments).
#[derive(Debug, Clone)]
pub struct Ratings {
    pub m: usize,
    pub n: usize,
    /// row-major (m, n); zero where unobserved
    pub values: Vec<f32>,
    /// row-major (m, n) in {0.0, 1.0}
    pub mask: Vec<f32>,
}

impl Ratings {
    pub fn lowrank(m: usize, n: usize, rank: usize, density: f64, noise: f64, seed: u64) -> Ratings {
        let mut rng = Rng::new(seed);
        let mut u = vec![0f32; m * rank];
        let mut v = vec![0f32; rank * n];
        for x in u.iter_mut() {
            *x = rng.normal() as f32 / (rank as f32).sqrt();
        }
        for x in v.iter_mut() {
            *x = rng.normal() as f32 / (rank as f32).sqrt();
        }
        let mut values = vec![0f32; m * n];
        let mut mask = vec![0f32; m * n];
        let mut observed = 0usize;
        for i in 0..m {
            for j in 0..n {
                if rng.bernoulli(density) {
                    let mut dot = 0f32;
                    for k in 0..rank {
                        dot += u[i * rank + k] * v[k * n + j];
                    }
                    values[i * n + j] = dot + (noise * rng.normal()) as f32;
                    mask[i * n + j] = 1.0;
                    observed += 1;
                }
            }
        }
        // Guarantee every row/col has at least one observation so the ALS
        // normal equations stay well posed.
        if observed == 0 {
            mask[0] = 1.0;
        }
        for i in 0..m {
            if mask[i * n..(i + 1) * n].iter().all(|&x| x == 0.0) {
                let j = rng.below(n);
                mask[i * n + j] = 1.0;
            }
        }
        for j in 0..n {
            if (0..m).all(|i| mask[i * n + j] == 0.0) {
                let i = rng.below(m);
                mask[i * n + j] = 1.0;
            }
        }
        Ratings { m, n, values, mask }
    }

    pub fn nnz(&self) -> usize {
        self.mask.iter().filter(|&&x| x > 0.0).count()
    }
}

/// Corpus drawn from the LDA generative model (stand-in for 20News /
/// Reuters / ClueWeb12). Ground-truth topics are Dirichlet(beta) over the
/// vocabulary; each document mixes topics via Dirichlet(alpha).
#[derive(Debug, Clone)]
pub struct Corpus {
    pub vocab: usize,
    pub docs: Vec<Vec<u32>>,
}

impl Corpus {
    pub fn lda_generative(
        n_docs: usize,
        vocab: usize,
        topics: usize,
        mean_len: usize,
        alpha: f64,
        beta: f64,
        seed: u64,
    ) -> Corpus {
        let mut rng = Rng::new(seed);
        let phi: Vec<Vec<f64>> = (0..topics).map(|_| rng.dirichlet(beta, vocab)).collect();
        let mut docs = Vec::with_capacity(n_docs);
        for _ in 0..n_docs {
            let theta = rng.dirichlet(alpha, topics);
            // Document lengths: uniform in [mean/2, 3*mean/2).
            let len = (mean_len / 2 + rng.below(mean_len)).max(4);
            let mut doc = Vec::with_capacity(len);
            for _ in 0..len {
                let z = rng.categorical(&theta);
                let w = rng.categorical(&phi[z]);
                doc.push(w as u32);
            }
            docs.push(doc);
        }
        Corpus { vocab, docs }
    }

    pub fn n_tokens(&self) -> usize {
        self.docs.iter().map(|d| d.len()).sum()
    }
}

/// Markov-chain token stream for the transformer LM: structured enough
/// that the loss curve has headroom to drop, reproducible per (seed).
#[derive(Debug, Clone)]
pub struct TokenStream {
    pub vocab: usize,
    /// Sparse per-state transition tables: each state has `branch`
    /// successors with geometric-ish weights.
    succ: Vec<Vec<u32>>,
}

impl TokenStream {
    pub fn markov(vocab: usize, branch: usize, seed: u64) -> TokenStream {
        let mut rng = Rng::new(seed);
        let succ = (0..vocab)
            .map(|_| (0..branch).map(|_| rng.below(vocab) as u32).collect())
            .collect();
        TokenStream { vocab, succ }
    }

    /// Sample a (tokens, targets) batch of shape (b, s): targets are the
    /// next-token shift of tokens.
    pub fn batch(&self, b: usize, s: usize, rng: &mut Rng) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = vec![0i32; b * s];
        let mut targets = vec![0i32; b * s];
        for row in 0..b {
            let mut cur = rng.below(self.vocab) as u32;
            for col in 0..s {
                tokens[row * s + col] = cur as i32;
                // Prefer early successors (geometric-ish): index j w.p. ~ 2^-j.
                let succ = &self.succ[cur as usize];
                let mut j = 0;
                while j + 1 < succ.len() && rng.bernoulli(0.5) {
                    j += 1;
                }
                cur = succ[j];
                targets[row * s + col] = cur as i32;
            }
        }
        (tokens, targets)
    }
}

/// SPD matrix with prescribed condition number for the QP experiments:
/// A = Q diag(λ) Qᵀ with λ log-spaced in [1/cond, 1], Q a random rotation.
pub fn spd_matrix(dim: usize, cond: f64, rng: &mut Rng) -> Vec<f32> {
    // Random orthogonal Q via Gram-Schmidt on a Gaussian matrix.
    let mut q = vec![0f64; dim * dim];
    for v in q.iter_mut() {
        *v = rng.normal();
    }
    for i in 0..dim {
        for j in 0..i {
            let dot: f64 = (0..dim).map(|k| q[i * dim + k] * q[j * dim + k]).sum();
            for k in 0..dim {
                q[i * dim + k] -= dot * q[j * dim + k];
            }
        }
        let norm: f64 = (0..dim).map(|k| q[i * dim + k] * q[i * dim + k]).sum::<f64>().sqrt();
        for k in 0..dim {
            q[i * dim + k] /= norm.max(1e-12);
        }
    }
    // Eigenvalues log-spaced.
    let lambdas: Vec<f64> = (0..dim)
        .map(|i| {
            let t = if dim == 1 { 0.0 } else { i as f64 / (dim - 1) as f64 };
            (1.0 / cond).powf(1.0 - t)
        })
        .collect();
    let mut a = vec![0f32; dim * dim];
    for r in 0..dim {
        for c in 0..dim {
            let mut acc = 0f64;
            for k in 0..dim {
                acc += q[k * dim + r] * lambdas[k] * q[k * dim + c];
            }
            a[r * dim + c] = acc as f32;
        }
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixture_is_classifiable() {
        let d = Classification::gaussian_mixture(8, 3, 500, 4.0, 1);
        assert_eq!(d.len(), 500);
        // Nearest-class-mean error should beat chance easily at sep=4.
        // (cheap proxy: points closer to own-class sample than random one)
        let mut rng = Rng::new(2);
        let (x, y) = d.batch(64, &mut rng);
        assert_eq!(x.len(), 64 * 8);
        assert_eq!(y.len(), 64 * 3);
        for row in y.chunks(3) {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn ratings_density_and_coverage() {
        let r = Ratings::lowrank(50, 40, 5, 0.2, 0.05, 3);
        let frac = r.nnz() as f64 / (50.0 * 40.0);
        assert!((frac - 0.2).abs() < 0.08, "density={frac}");
        for i in 0..50 {
            assert!(r.mask[i * 40..(i + 1) * 40].iter().any(|&m| m > 0.0));
        }
    }

    #[test]
    fn corpus_tokens_in_vocab() {
        let c = Corpus::lda_generative(20, 100, 5, 30, 0.5, 0.1, 4);
        assert_eq!(c.docs.len(), 20);
        for doc in &c.docs {
            assert!(doc.len() >= 4);
            assert!(doc.iter().all(|&w| (w as usize) < 100));
        }
    }

    #[test]
    fn token_stream_shapes() {
        let ts = TokenStream::markov(64, 3, 5);
        let mut rng = Rng::new(6);
        let (t, y) = ts.batch(4, 16, &mut rng);
        assert_eq!(t.len(), 64);
        assert_eq!(y.len(), 64);
        assert!(t.iter().all(|&v| (0..64).contains(&v)));
    }

    #[test]
    fn spd_matrix_is_symmetric_positive() {
        let mut rng = Rng::new(7);
        let dim = 6;
        let a = spd_matrix(dim, 50.0, &mut rng);
        for i in 0..dim {
            for j in 0..dim {
                assert!((a[i * dim + j] - a[j * dim + i]).abs() < 1e-4);
            }
        }
        // x^T A x > 0 for a few random x.
        for _ in 0..5 {
            let x: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
            let mut quad = 0f64;
            for i in 0..dim {
                for j in 0..dim {
                    quad += x[i] * a[i * dim + j] as f64 * x[j];
                }
            }
            assert!(quad > 0.0);
        }
    }
}
