//! Uniform-random atom → PS-node partitioning (paper §4).
//!
//! "We will assume that parameters are partitioned uniformly at random
//! across the PS nodes ... the partitioning scheme is typically within
//! the control of the PS system, which can choose a random partitioning."
//!
//! The partition also drives failure semantics: when a PS node dies, the
//! atoms it owns are the lost parameters (Thm 4.2's random subset), and
//! recovery re-partitions them onto the survivors (§4.3 step 2).

use crate::util::rng::Rng;

/// Assignment of atoms to parameter-server nodes.
#[derive(Debug, Clone)]
pub struct Partition {
    /// owner[atom] = ps node id
    pub owner: Vec<usize>,
    /// atoms_of[node] = atom ids owned by that node
    pub atoms_of: Vec<Vec<usize>>,
}

impl Partition {
    /// Shuffle atoms and deal them round-robin so node loads are balanced
    /// to within one atom while the *subset* owned by each node stays
    /// uniformly random.
    pub fn random(n_atoms: usize, n_nodes: usize, rng: &mut Rng) -> Partition {
        assert!(n_nodes > 0, "need at least one PS node");
        let mut order: Vec<usize> = (0..n_atoms).collect();
        rng.shuffle(&mut order);
        let mut owner = vec![0usize; n_atoms];
        let mut atoms_of = vec![Vec::new(); n_nodes];
        for (i, atom) in order.into_iter().enumerate() {
            let node = i % n_nodes;
            owner[atom] = node;
            atoms_of[node].push(atom);
        }
        Partition { owner, atoms_of }
    }

    pub fn n_nodes(&self) -> usize {
        self.atoms_of.len()
    }

    pub fn n_atoms(&self) -> usize {
        self.owner.len()
    }

    /// Atoms lost if `nodes` fail.
    pub fn lost_atoms(&self, nodes: &[usize]) -> Vec<usize> {
        let mut lost: Vec<usize> = nodes
            .iter()
            .flat_map(|&n| self.atoms_of[n].iter().copied())
            .collect();
        lost.sort_unstable();
        lost
    }

    /// Move atoms owned by `failed` nodes onto the surviving nodes
    /// round-robin (recovery coordinator step 1, §4.3). Returns the moved
    /// atom ids. No-op if every node failed (caller restarts the job).
    pub fn repartition(&mut self, failed: &[usize]) -> Vec<usize> {
        let failed_set: Vec<bool> = {
            let mut v = vec![false; self.n_nodes()];
            for &f in failed {
                v[f] = true;
            }
            v
        };
        let survivors: Vec<usize> =
            (0..self.n_nodes()).filter(|&n| !failed_set[n]).collect();
        if survivors.is_empty() {
            return Vec::new();
        }
        let mut moved = Vec::new();
        for &f in failed {
            let atoms = std::mem::take(&mut self.atoms_of[f]);
            for (i, atom) in atoms.into_iter().enumerate() {
                let dst = survivors[i % survivors.len()];
                self.owner[atom] = dst;
                self.atoms_of[dst].push(atom);
                moved.push(atom);
            }
        }
        moved.sort_unstable();
        moved
    }

    /// Internal consistency (proptest target).
    pub fn is_consistent(&self) -> bool {
        let mut seen = vec![false; self.n_atoms()];
        for (node, atoms) in self.atoms_of.iter().enumerate() {
            for &a in atoms {
                if a >= self.n_atoms() || seen[a] || self.owner[a] != node {
                    return false;
                }
                seen[a] = true;
            }
        }
        seen.into_iter().all(|s| s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_partition_is_consistent_and_balanced() {
        let mut rng = Rng::new(1);
        let p = Partition::random(103, 8, &mut rng);
        assert!(p.is_consistent());
        let sizes: Vec<usize> = p.atoms_of.iter().map(|v| v.len()).collect();
        let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(max - min <= 1, "{sizes:?}");
    }

    #[test]
    fn lost_atoms_match_owner() {
        let mut rng = Rng::new(2);
        let p = Partition::random(40, 4, &mut rng);
        let lost = p.lost_atoms(&[1, 3]);
        for &a in &lost {
            assert!(p.owner[a] == 1 || p.owner[a] == 3);
        }
        assert_eq!(lost.len(), p.atoms_of[1].len() + p.atoms_of[3].len());
    }

    #[test]
    fn repartition_moves_everything_to_survivors() {
        let mut rng = Rng::new(3);
        let mut p = Partition::random(50, 5, &mut rng);
        let before = p.lost_atoms(&[0, 2]);
        let moved = p.repartition(&[0, 2]);
        assert_eq!(before, moved);
        assert!(p.is_consistent());
        assert!(p.atoms_of[0].is_empty() && p.atoms_of[2].is_empty());
    }

    #[test]
    fn repartition_all_failed_is_noop() {
        let mut rng = Rng::new(4);
        let mut p = Partition::random(10, 2, &mut rng);
        let moved = p.repartition(&[0, 1]);
        assert!(moved.is_empty());
    }

    #[test]
    fn different_seeds_differ() {
        let p1 = Partition::random(64, 4, &mut Rng::new(10));
        let p2 = Partition::random(64, 4, &mut Rng::new(11));
        assert_ne!(p1.owner, p2.owner);
    }
}
