//! Threaded parameter-server deployment (paper Fig 4 architecture).
//!
//! PS nodes run as OS threads owning their atom partitions and posting
//! heartbeats; the fault-tolerance controller (this module, driven by the
//! training loop) routes gets/puts, detects silent nodes via
//! [`HeartbeatDetector`], and on failure re-partitions lost atoms onto
//! survivors and reloads them from the shared checkpoint store — i.e.
//! partial recovery, end to end, over real message passing.
//!
//! The offline crate set has no tokio; `std::thread` + `mpsc` provide the
//! same coordination semantics (the paper's PS is thread-per-node too).

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::checkpoint::{AsyncCheckpointer, CheckpointMode, CheckpointPolicy};
use crate::failure::{HeartbeatDetector, Liveness};
use crate::obs::{EventKind, Recorder};
use crate::params::{AtomLayout, ParamStore};
use crate::partition::Partition;
use crate::policy::{PolicyConfig, PolicyController};
use crate::storage::{CheckpointStore, ShardedStore};
use crate::trainer::Trainer;
use crate::util::rng::Rng;

/// Messages understood by a PS node thread.
enum PsMsg {
    Get { atoms: Vec<usize>, reply: Sender<Vec<(usize, Vec<f32>)>> },
    Put { values: Vec<(usize, Vec<f32>)> },
    /// Simulated hardware failure: drop all state and exit silently
    /// (no more heartbeats — the detector must notice).
    Kill,
    /// Graceful shutdown at end of job.
    Shutdown,
}

struct NodeHandle {
    tx: Sender<PsMsg>,
    join: Option<JoinHandle<()>>,
    alive: bool,
}

fn spawn_node(id: usize, beat_tx: Sender<(usize, Instant)>) -> NodeHandle {
    let (tx, rx): (Sender<PsMsg>, Receiver<PsMsg>) = channel();
    let join = std::thread::Builder::new()
        .name(format!("ps-node-{id}"))
        .spawn(move || {
            let mut store: HashMap<usize, Vec<f32>> = HashMap::new();
            loop {
                // Heartbeat on every wakeup (including idle timeouts).
                let _ = beat_tx.send((id, Instant::now()));
                match rx.recv_timeout(Duration::from_millis(2)) {
                    Ok(PsMsg::Get { atoms, reply }) => {
                        let vals = atoms
                            .into_iter()
                            .filter_map(|a| store.get(&a).map(|v| (a, v.clone())))
                            .collect();
                        let _ = reply.send(vals);
                    }
                    Ok(PsMsg::Put { values }) => {
                        for (a, v) in values {
                            store.insert(a, v);
                        }
                    }
                    Ok(PsMsg::Kill) => {
                        // Hardware failure: state vanishes, thread dies,
                        // no deregistration — silence is the signal.
                        return;
                    }
                    Ok(PsMsg::Shutdown) => return,
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
                }
            }
        })
        .expect("spawning ps node thread");
    NodeHandle { tx, join: Some(join), alive: true }
}

/// What one [`Cluster::recover_nodes`] call rebuilt: the re-homed atom
/// ids, the reload's size (the dead nodes' slices only — the selective
/// analogue of the storage layer's `rebuilt_bytes`), and the measured
/// recovery perturbation ‖δ‖.
#[derive(Debug, Clone, Default)]
pub struct RecoverOutcome {
    /// Atoms re-homed and reloaded from the running checkpoint.
    pub moved: Vec<usize>,
    /// ‖δ‖ over the moved atoms (reloaded vs the controller's view).
    pub delta_norm: f64,
    /// Atoms the reload plan covered (== `moved.len()`).
    pub rebuilt_atoms: usize,
    /// Payload bytes reloaded from the store.
    pub rebuilt_bytes: u64,
}

/// A notable runtime event, for logs and assertions in tests.
#[derive(Debug, Clone, PartialEq)]
pub enum ClusterEvent {
    NodeKilled { node: usize, iter: usize },
    NodeDeclaredDead { node: usize, iter: usize },
    Recovered { nodes: Vec<usize>, atoms: usize, iter: usize },
    Checkpoint { iter: usize, atoms: usize },
}

/// The live PS deployment: node threads + partition + FT controller.
pub struct Cluster {
    nodes: Vec<NodeHandle>,
    pub partition: Partition,
    detector: HeartbeatDetector,
    beat_rx: Receiver<(usize, Instant)>,
    pub events: Vec<ClusterEvent>,
    scratch: Vec<f32>,
}

impl Cluster {
    /// Spawn `n_nodes` PS threads and randomly partition the layout's
    /// atoms across them, seeding node state from `init`.
    pub fn start(
        n_nodes: usize,
        init: &ParamStore,
        layout: &AtomLayout,
        heartbeat_timeout: Duration,
        rng: &mut Rng,
    ) -> Result<Cluster> {
        let (beat_tx, beat_rx) = channel();
        let mut detector = HeartbeatDetector::new(heartbeat_timeout);
        let nodes: Vec<NodeHandle> = (0..n_nodes)
            .map(|id| {
                detector.register(id);
                spawn_node(id, beat_tx.clone())
            })
            .collect();
        let partition = Partition::random(layout.n_atoms(), n_nodes, rng);
        let mut cluster = Cluster {
            nodes,
            partition,
            detector,
            beat_rx,
            events: Vec::new(),
            scratch: Vec::new(),
        };
        cluster.scatter_all(init, layout)?;
        Ok(cluster)
    }

    fn drain_beats(&mut self) {
        while let Ok((node, at)) = self.beat_rx.try_recv() {
            self.detector.beat_at(node, at);
        }
    }

    /// Push every atom to its owner.
    pub fn scatter_all(&mut self, state: &ParamStore, layout: &AtomLayout) -> Result<()> {
        let atoms: Vec<usize> = (0..layout.n_atoms()).collect();
        self.scatter(state, layout, &atoms)
    }

    /// Push a subset of atoms to their owners.
    pub fn scatter(
        &mut self,
        state: &ParamStore,
        layout: &AtomLayout,
        atoms: &[usize],
    ) -> Result<()> {
        let mut per_node: HashMap<usize, Vec<(usize, Vec<f32>)>> = HashMap::new();
        for &a in atoms {
            state.read_atom(layout, a, &mut self.scratch);
            per_node
                .entry(self.partition.owner[a])
                .or_default()
                .push((a, self.scratch.clone()));
        }
        for (node, values) in per_node {
            if self.nodes[node].alive {
                let _ = self.nodes[node].tx.send(PsMsg::Put { values });
            }
        }
        self.drain_beats();
        Ok(())
    }

    /// Pull every atom from the PS nodes into `state`. Atoms on dead
    /// nodes are left untouched (the caller runs recovery first).
    pub fn gather(&mut self, state: &mut ParamStore, layout: &AtomLayout) -> Result<()> {
        let mut pending = Vec::new();
        for node in 0..self.nodes.len() {
            if !self.nodes[node].alive || self.partition.atoms_of[node].is_empty() {
                continue;
            }
            let (reply_tx, reply_rx) = channel();
            let atoms = self.partition.atoms_of[node].clone();
            if self.nodes[node]
                .tx
                .send(PsMsg::Get { atoms, reply: reply_tx })
                .is_err()
            {
                continue; // node died between liveness check and send
            }
            pending.push((node, reply_rx));
        }
        for (node, rx) in pending {
            match rx.recv_timeout(Duration::from_millis(500)) {
                Ok(values) => {
                    for (a, v) in values {
                        state.write_atom(layout, a, &v);
                    }
                }
                Err(_) => {
                    // Treat as failed; detector will confirm.
                    let _ = node;
                }
            }
        }
        self.drain_beats();
        Ok(())
    }

    /// Simulate a hardware failure of `node` at `iter`.
    pub fn kill_node(&mut self, node: usize, iter: usize) {
        if self.nodes[node].alive {
            let _ = self.nodes[node].tx.send(PsMsg::Kill);
            self.nodes[node].alive = false; // controller-side bookkeeping
            self.events.push(ClusterEvent::NodeKilled { node, iter });
        }
    }

    /// Poll the failure detector; returns nodes newly declared dead.
    pub fn poll_failures(&mut self, iter: usize) -> Vec<usize> {
        self.drain_beats();
        let dead = self.detector.check();
        for &node in &dead {
            self.events.push(ClusterEvent::NodeDeclaredDead { node, iter });
        }
        dead
    }

    /// Deterministic detection: declare a scheduled kill dead at its kill
    /// iteration instead of waiting for heartbeat silence (what scenario
    /// sweeps need for byte-reproducible reports). Returns false if the
    /// node was already declared.
    pub fn declare_failed(&mut self, node: usize, iter: usize) -> bool {
        if !self.detector.declare_dead(node) {
            return false;
        }
        self.events.push(ClusterEvent::NodeDeclaredDead { node, iter });
        true
    }

    /// Recovery coordinator (§4.3): re-partition the dead nodes' atoms
    /// onto survivors and reload their values from the running checkpoint
    /// in shared storage. The reload covers exactly the moved atoms —
    /// never the full state (the node-level analogue of the storage
    /// layer's [`RebuildPlan`](crate::recovery::RebuildPlan) slices) —
    /// read through the store's single-copy path, and its size is
    /// reported as `rebuilt_atoms`/`rebuilt_bytes` alongside the
    /// recovery ‖δ‖.
    /// `reference` is the controller's current view of the full parameter
    /// state (the last scattered values) — the recovery perturbation ‖δ‖
    /// is the L2 distance between it and the reloaded checkpoint values
    /// over the moved atoms, the cluster analogue of the harness's
    /// pre/post-recovery distance (Thm 3.2's δ).
    pub fn recover_nodes(
        &mut self,
        dead: &[usize],
        layout: &AtomLayout,
        store: &dyn CheckpointStore,
        iter: usize,
        reference: &ParamStore,
    ) -> Result<RecoverOutcome> {
        if dead.is_empty() {
            return Ok(RecoverOutcome::default());
        }
        let moved = self.partition.repartition(dead);
        if moved.is_empty() && self.partition.n_atoms() > 0 {
            bail!("all PS nodes failed; cannot recover in place");
        }
        // Reload lost atoms from persistent storage into their new
        // owners — the dead nodes' slices only, single-copy reads.
        let watermark = store.committed_iter();
        let mut per_node: HashMap<usize, Vec<(usize, Vec<f32>)>> = HashMap::new();
        let mut delta_sq = 0.0f64;
        let mut rebuilt_bytes = 0u64;
        let mut buf = Vec::new();
        for &a in &moved {
            let saved_iter = store
                .read_atom_into(a, &mut buf)?
                .with_context(|| format!("atom {a} missing from checkpoint store"))?;
            crate::recovery::check_watermark(a, saved_iter, watermark)?;
            reference.read_atom(layout, a, &mut self.scratch);
            for (new, old) in buf.iter().zip(self.scratch.iter()) {
                let d = (*new - *old) as f64;
                delta_sq += d * d;
            }
            rebuilt_bytes += (buf.len() * 4) as u64;
            per_node
                .entry(self.partition.owner[a])
                .or_default()
                .push((a, buf.clone()));
        }
        for (node, values) in per_node {
            let _ = self.nodes[node].tx.send(PsMsg::Put { values });
        }
        self.events.push(ClusterEvent::Recovered {
            nodes: dead.to_vec(),
            atoms: moved.len(),
            iter,
        });
        Ok(RecoverOutcome {
            rebuilt_atoms: moved.len(),
            rebuilt_bytes,
            moved,
            delta_norm: delta_sq.sqrt(),
        })
    }

    pub fn alive_nodes(&self) -> Vec<usize> {
        (0..self.nodes.len())
            .filter(|&n| self.detector.liveness(n) == Liveness::Alive && self.nodes[n].alive)
            .collect()
    }

    pub fn shutdown(mut self) {
        for node in &self.nodes {
            let _ = node.tx.send(PsMsg::Shutdown);
        }
        for node in self.nodes.iter_mut() {
            if let Some(j) = node.join.take() {
                let _ = j.join();
            }
        }
    }
}

/// Outcome of a full cluster training run.
#[derive(Debug)]
pub struct ClusterRunReport {
    pub losses: Vec<f64>,
    pub events: Vec<ClusterEvent>,
    pub checkpoint_bytes: u64,
    /// Checkpoint records written through degraded routing (a storage
    /// shard was down and its batches re-homed to survivors).
    pub degraded_records: u64,
    /// Aggregate recovery perturbation sqrt(Σ‖δᵢ‖²) over every recovery
    /// event — the same convention as the harness path, so cluster
    /// trials feed the Thm 3.2 bound's ‖δ‖ instead of NaN.
    pub recovery_delta_norm: f64,
    /// Atoms selectively rebuilt/reloaded across all recovery events:
    /// node recoveries reload exactly the dead nodes' slices, and the
    /// checkpointer rebuilds exactly dead storage shards' slices (plus
    /// healed-shard re-adoptions) — never the full checkpoint.
    pub rebuilt_atoms: u64,
    /// Payload bytes those selective rebuilds moved.
    pub rebuilt_bytes: u64,
    /// Segment-compaction passes run on the store during this job.
    pub compaction_runs: u64,
    /// Segment bytes those passes reclaimed.
    pub compaction_reclaimed_bytes: u64,
    /// Live policy/mode switches the adaptive controller applied
    /// (0 without [`ClusterJob::adaptive`]).
    pub policy_switches: u64,
    /// Checkpoint interval held at end of run (the adaptive controller
    /// may have retuned it away from the configured policy's).
    pub final_interval: usize,
}

/// How scheduled node kills are *detected*.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Detect {
    /// Realistic mode: a node is dead after 2× this heartbeat timeout of
    /// silence. Wall-clock — the declaration iteration varies run to run.
    Heartbeat(Duration),
    /// Deterministic mode: a scheduled kill is declared dead at its kill
    /// iteration (what scenario sweeps need for byte-identical reports).
    Immediate,
}

/// Full configuration of one threaded-PS training job (the declarative
/// form `run_cluster_training` consumes; scenario cluster sweeps build
/// one per trial).
#[derive(Debug, Clone)]
pub struct ClusterJob {
    pub n_nodes: usize,
    pub iters: usize,
    pub policy: CheckpointPolicy,
    pub ckpt_mode: CheckpointMode,
    pub ckpt_writers: usize,
    /// Async back-pressure bound (0 = unbounded queue).
    pub max_pending: usize,
    /// Garbage-ratio threshold for segment compaction at flush fences
    /// (0 = never compact; only disk shards accumulate garbage).
    pub compact_threshold: f64,
    /// Minimum on-disk shard size before compaction runs.
    pub compact_min_bytes: u64,
    /// Per-pass segment-byte budget for generational compaction
    /// (0 = monolithic full-shard passes).
    pub compact_max_pass_bytes: u64,
    /// `(iteration, node)` kill schedule: same-iteration entries model a
    /// correlated rack loss, increasing iterations a cascade. Nodes are
    /// not revived.
    pub kills: Vec<(usize, usize)>,
    pub seed: u64,
    pub detect: Detect,
    /// Stop as soon as the loss reaches this threshold (scenario
    /// iteration-cost measurement); `None` runs all `iters`.
    pub stop_at_loss: Option<f64>,
    /// Flight recorder narrating the run: node kills/recoveries here,
    /// plus everything the checkpointer and chaos layer record. The
    /// default disabled recorder is a zero-cost no-op.
    pub recorder: Recorder,
    /// Adaptive-policy controller config: when set, the training loop
    /// feeds a [`PolicyController`] the live loss and node-failure
    /// arrivals and applies its switches at iteration boundaries.
    /// `None` = static policy (the default).
    pub adaptive: Option<PolicyConfig>,
}

impl ClusterJob {
    /// A plain job: heartbeat detection, unbounded queue, full run.
    pub fn new(n_nodes: usize, iters: usize, policy: CheckpointPolicy, seed: u64) -> ClusterJob {
        ClusterJob {
            n_nodes,
            iters,
            policy,
            ckpt_mode: CheckpointMode::Sync,
            ckpt_writers: 1,
            max_pending: 0,
            compact_threshold: 0.0,
            compact_min_bytes: 0,
            compact_max_pass_bytes: 0,
            kills: Vec::new(),
            seed,
            detect: Detect::Heartbeat(Duration::from_millis(20)),
            stop_at_loss: None,
            recorder: Recorder::disabled(),
            adaptive: None,
        }
    }
}

/// Drive a full training job on a threaded cluster: gather → step →
/// scatter, with checkpointing, a schedule of node kills, and
/// detector-triggered partial recovery.
///
/// Checkpoint records are routed to the *owner node's shard* of the
/// sharded store (and re-routed after every re-partition), so each PS
/// node streams its slice of the running checkpoint to its own backend —
/// the Fig 4 layout. In [`CheckpointMode::Async`] the barriers hand
/// snapshots to the writer pool and training proceeds; every recovery is
/// preceded by a `flush` epoch fence so it only reads fully-committed
/// state.
///
/// The store may be chaos-wrapped ([`crate::chaos`]): shard kills, slow
/// windows, and torn writes fire at deterministic iterations via the
/// fault clock the checkpoint front-end advances every iteration, with
/// degraded routing and cache rebuild keeping recovery able to read every
/// atom through the survivors.
pub fn run_cluster_training(
    trainer: &mut dyn Trainer,
    store: Arc<ShardedStore>,
    job: &ClusterJob,
) -> Result<ClusterRunReport> {
    // Reject unusable schedules up front — a silently-dropped kill would
    // report a failure-free run as a successful recovery experiment.
    for &(kill_iter, node) in &job.kills {
        if node >= job.n_nodes {
            bail!(
                "kill schedule targets node {node}, but the cluster has {} nodes",
                job.n_nodes
            );
        }
        if kill_iter >= job.iters {
            bail!(
                "kill schedule entry at iter {kill_iter} is past the run length {}",
                job.iters
            );
        }
    }
    let heartbeat_timeout = match job.detect {
        Detect::Heartbeat(t) => t,
        // Immediate mode keeps the detector around but effectively muted:
        // scheduled kills are declared by the controller, not by silence.
        Detect::Immediate => Duration::from_secs(3600),
    };
    trainer.init(job.seed)?;
    let layout = trainer.layout().clone();
    let mut rng = Rng::new(job.seed ^ 0xC1A5);
    let mut cluster = Cluster::start(
        job.n_nodes,
        trainer.state(),
        &layout,
        heartbeat_timeout,
        &mut rng,
    )?;
    // Each PS node writes to its own shard (node id mod shard count).
    store.set_route_partition(&cluster.partition);
    let mut ck = AsyncCheckpointer::new(
        job.policy,
        trainer.state(),
        &layout,
        store.clone(),
        job.ckpt_mode,
        job.ckpt_writers,
    )?
    .with_max_pending(job.max_pending)
    .with_compaction(job.compact_threshold, job.compact_min_bytes)
    .with_compaction_budget(job.compact_max_pass_bytes)
    .with_recorder(job.recorder.clone());
    if job.adaptive.is_some() {
        // The controller may flip sync → async mid-run; make sure the
        // writer pool exists even when the job starts sync.
        ck = ck.with_writer_pool(job.ckpt_writers.max(1));
    }
    let mut ctl = job.adaptive.map(|cfg| {
        let base = cfg.base_interval.max(1) as f64;
        let initial_k = (base / job.policy.interval.max(1) as f64).round().max(1.0) as usize;
        PolicyController::new(cfg, initial_k, job.ckpt_mode)
    });

    let mut losses = Vec::with_capacity(job.iters);
    let mut recovery_delta_sq = 0.0f64;
    let mut rebuilt_atoms = 0u64;
    let mut rebuilt_bytes = 0u64;
    for iter in 0..job.iters {
        let mut killed_now = Vec::new();
        for &(kill_iter, node) in &job.kills {
            if iter == kill_iter {
                cluster.kill_node(node, iter);
                if job.recorder.is_enabled() {
                    job.recorder.record(iter, EventKind::NodeKill { node });
                }
                killed_now.push(node);
            }
        }
        // Give the detector a chance to notice silence before the gather.
        let mut dead = cluster.poll_failures(iter);
        if job.detect == Detect::Immediate {
            for node in killed_now {
                if cluster.declare_failed(node, iter) {
                    dead.push(node);
                }
            }
            dead.sort_unstable();
            dead.dedup();
        }
        if !dead.is_empty() {
            // Epoch fence: recovery only reads fully-committed state.
            ck.flush()?;
            // ‖δ‖ is measured against the controller's current full view
            // (the last scattered state still holds the dead nodes' lost
            // values), so cluster cells report a real perturbation size.
            let outcome =
                cluster.recover_nodes(&dead, &layout, store.as_ref(), iter, trainer.state())?;
            recovery_delta_sq += outcome.delta_norm * outcome.delta_norm;
            rebuilt_atoms += outcome.rebuilt_atoms as u64;
            rebuilt_bytes += outcome.rebuilt_bytes;
            if job.recorder.is_enabled() {
                job.recorder.record(
                    iter,
                    EventKind::NodeRecover {
                        nodes: dead.len(),
                        atoms: outcome.rebuilt_atoms,
                        delta_norm: outcome.delta_norm,
                    },
                );
            }
            if let Some(ctl) = ctl.as_mut() {
                let frac = outcome.rebuilt_atoms as f64 / layout.n_atoms().max(1) as f64;
                ctl.observe_failure(iter, frac);
            }
            // New records follow the atoms' new owners.
            store.set_route_partition(&cluster.partition);
        }

        // Worker: pull params, compute the step via the AOT artifact,
        // push updates back.
        let mut state = trainer.state().clone();
        cluster.gather(&mut state, &layout)?;
        trainer.set_state(state);
        let loss = trainer.step(iter)?;
        losses.push(loss);
        let atoms: Vec<usize> = (0..layout.n_atoms()).collect();
        cluster.scatter(trainer.state(), &layout, &atoms)?;

        if let Some(ctl) = ctl.as_mut() {
            ctl.observe_loss(loss);
            if let Some(sw) = ctl.decide(iter + 1) {
                ck.set_policy(sw.policy);
                ck.set_mode(sw.mode)?;
                if job.recorder.is_enabled() {
                    job.recorder.record(
                        iter + 1,
                        EventKind::PolicySwitch {
                            k: sw.k,
                            interval: sw.policy.interval,
                            mode: sw.mode.to_string(),
                        },
                    );
                }
            }
        }
        if let Some(stats) = ck.maybe_checkpoint(iter + 1, trainer.state(), &layout, &mut rng)? {
            cluster
                .events
                .push(ClusterEvent::Checkpoint { iter: iter + 1, atoms: stats.atoms_saved });
        }
        if matches!(job.stop_at_loss, Some(t) if loss <= t) {
            break;
        }
    }
    // Storage-shard deaths rebuilt selectively by the checkpointer count
    // toward the same totals as node-slice reloads.
    rebuilt_atoms += ck.rebuilt_atoms() + ck.readopted_atoms();
    rebuilt_bytes += ck.rebuilt_bytes() + ck.readopted_bytes();
    if let Some(ctl) = ctl.as_mut() {
        // Reporting only — stall counts never feed decisions.
        ctl.note_stalls(ck.backpressure_stalls());
    }
    let policy_switches = ctl.as_ref().map(|c| c.switches()).unwrap_or(0);
    let final_interval = ck.policy().interval;
    ck.finish()?;
    let events = cluster.events.clone();
    let bytes = store.total_bytes();
    let degraded = store.degraded_records();
    let compaction_runs = store.compaction_runs();
    let compaction_reclaimed_bytes = store.compaction_reclaimed_bytes();
    cluster.shutdown();
    Ok(ClusterRunReport {
        losses,
        events,
        checkpoint_bytes: bytes,
        degraded_records: degraded,
        recovery_delta_norm: recovery_delta_sq.sqrt(),
        rebuilt_atoms,
        rebuilt_bytes,
        compaction_runs,
        compaction_reclaimed_bytes,
        policy_switches,
        final_interval,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::{Tensor};

    fn setup(n_atoms: usize) -> (ParamStore, AtomLayout) {
        let store = ParamStore::new(vec![Tensor::zeros("w", &[n_atoms, 3])]);
        let layout = AtomLayout::new(AtomLayout::rows_of(&store, "w"));
        (store, layout)
    }

    #[test]
    fn scatter_gather_roundtrip() {
        let (mut state, layout) = setup(12);
        for (i, v) in state.get_mut("w").data.iter_mut().enumerate() {
            *v = i as f32;
        }
        let mut rng = Rng::new(1);
        let mut cluster =
            Cluster::start(3, &state, &layout, Duration::from_millis(50), &mut rng).unwrap();
        let mut out = ParamStore::new(vec![Tensor::zeros("w", &[12, 3])]);
        cluster.gather(&mut out, &layout).unwrap();
        assert_eq!(out.get("w").data, state.get("w").data);
        cluster.shutdown();
    }

    #[test]
    fn killed_node_is_detected_and_recovered() {
        let (state, layout) = setup(10);
        let mut rng = Rng::new(2);
        let mut cluster =
            Cluster::start(3, &state, &layout, Duration::from_millis(10), &mut rng).unwrap();
        // Checkpoint store holding x(0) for every atom.
        let mut store = crate::storage::MemStore::new();
        {
            let mut buf = Vec::new();
            let mut payload = Vec::new();
            for a in 0..layout.n_atoms() {
                state.read_atom(&layout, a, &mut buf);
                payload.push((a, buf.clone()));
            }
            let refs: Vec<(usize, &[f32])> =
                payload.iter().map(|(a, v)| (*a, v.as_slice())).collect();
            store.put_atoms(0, &refs).unwrap();
        }
        cluster.kill_node(1, 0);
        // Wait for silence to exceed 2x timeout.
        std::thread::sleep(Duration::from_millis(40));
        let dead = cluster.poll_failures(1);
        assert_eq!(dead, vec![1]);
        let outcome = cluster.recover_nodes(&dead, &layout, &store, 1, &state).unwrap();
        assert!(!outcome.moved.is_empty());
        // Recovery reloads exactly the values the reference holds
        // (x(0) everywhere), so the measured perturbation is zero.
        assert_eq!(outcome.delta_norm, 0.0);
        // The reload covers exactly the dead node's slice — never the
        // full state — and its size is reported.
        assert_eq!(outcome.rebuilt_atoms, outcome.moved.len());
        assert_eq!(outcome.rebuilt_bytes, (outcome.moved.len() * 3 * 4) as u64);
        assert!(cluster.partition.atoms_of[1].is_empty());
        assert!(cluster.partition.is_consistent());
        // All atoms still gatherable.
        let mut out = ParamStore::new(vec![Tensor::zeros("w", &[10, 3])]);
        cluster.gather(&mut out, &layout).unwrap();
        cluster.shutdown();
    }

    #[test]
    fn correlated_kill_schedule_recovers_both_nodes() {
        // Two nodes die at the same iteration (rack failure); the
        // schedule-driven training loop must detect and recover both.
        use crate::models::synthetic::SyntheticTrainer;
        let mut trainer = SyntheticTrainer::new(24, 0.8, 5);
        let store = Arc::new(ShardedStore::new_mem(4));
        // Plenty of post-kill iterations: synthetic steps are ~µs, and the
        // detector needs 2× the heartbeat timeout of wall-clock silence.
        let job = ClusterJob {
            kills: vec![(6, 1), (6, 2)],
            detect: Detect::Heartbeat(Duration::from_millis(2)),
            ..ClusterJob::new(4, 400, CheckpointPolicy::full(4), 9)
        };
        let report = run_cluster_training(&mut trainer, store, &job).unwrap();
        let killed: Vec<usize> = report
            .events
            .iter()
            .filter_map(|e| match e {
                ClusterEvent::NodeKilled { node, .. } => Some(*node),
                _ => None,
            })
            .collect();
        assert_eq!(killed, vec![1, 2]);
        let recovered: usize = report
            .events
            .iter()
            .map(|e| match e {
                ClusterEvent::Recovered { nodes, .. } => nodes.len(),
                _ => 0,
            })
            .sum();
        assert_eq!(recovered, 2, "events: {:?}", report.events);
        assert!(report.losses.last().unwrap() < &report.losses[0]);
    }

    #[test]
    fn async_checkpointing_survives_node_failure() {
        // Pipelined barriers + a kill: the pre-recovery flush fence must
        // leave the store fully committed so partial recovery works.
        use crate::models::synthetic::SyntheticTrainer;
        let mut trainer = SyntheticTrainer::new(16, 0.8, 7);
        let store = Arc::new(ShardedStore::new_mem(3));
        let policy = CheckpointPolicy::partial(4, 2, crate::checkpoint::Selector::Priority);
        let job = ClusterJob {
            ckpt_mode: CheckpointMode::Async,
            ckpt_writers: 2,
            kills: vec![(5, 0)],
            detect: Detect::Heartbeat(Duration::from_millis(2)),
            ..ClusterJob::new(3, 300, policy, 13)
        };
        let report = run_cluster_training(&mut trainer, store.clone(), &job).unwrap();
        assert!(
            report.events.iter().any(|e| matches!(e, ClusterEvent::Recovered { .. })),
            "events: {:?}",
            report.events
        );
        assert!(report.losses.last().unwrap() < &report.losses[0]);
        // The final fence committed everything the pool wrote.
        assert!(store.committed().is_some());
        assert_eq!(report.checkpoint_bytes, store.total_bytes());
    }

    #[test]
    fn recorder_narrates_node_kills_and_recoveries() {
        use crate::models::synthetic::SyntheticTrainer;
        let mut trainer = SyntheticTrainer::new(16, 0.8, 3);
        let store = Arc::new(ShardedStore::new_mem(2));
        let rec = Recorder::enabled();
        let job = ClusterJob {
            kills: vec![(5, 1)],
            detect: Detect::Immediate,
            recorder: rec.clone(),
            ..ClusterJob::new(3, 40, CheckpointPolicy::full(4), 11)
        };
        run_cluster_training(&mut trainer, store, &job).unwrap();
        let events = rec.drain();
        assert!(
            events
                .iter()
                .any(|e| e.iter == 5 && matches!(e.kind, EventKind::NodeKill { node: 1 })),
            "missing NodeKill: {events:?}"
        );
        assert!(
            events.iter().any(|e| e.iter == 5
                && matches!(e.kind, EventKind::NodeRecover { nodes: 1, .. })),
            "missing NodeRecover: {events:?}"
        );
    }

    #[test]
    fn adaptive_cluster_job_is_deterministic() {
        // The controller's decisions are iteration-clocked, so two
        // adaptive runs on the same seed must agree on losses, events,
        // and the switch schedule — even with async writers in play.
        use crate::models::synthetic::SyntheticTrainer;
        let run = || {
            let mut trainer = SyntheticTrainer::new(24, 0.85, 6);
            let store = Arc::new(ShardedStore::new_mem(3));
            let job = ClusterJob {
                ckpt_mode: CheckpointMode::Async,
                ckpt_writers: 2,
                kills: vec![(10, 1), (14, 2)],
                detect: Detect::Immediate,
                adaptive: Some(PolicyConfig {
                    window: 8,
                    dump_cost_iters: 2.0,
                    ..PolicyConfig::default()
                }),
                ..ClusterJob::new(4, 80, CheckpointPolicy::full(8), 17)
            };
            let report = run_cluster_training(&mut trainer, store, &job).unwrap();
            (report.losses, report.events, report.policy_switches, report.final_interval)
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "adaptive cluster runs must be byte-identical on one seed");
        assert!(a.0.last().unwrap() < &a.0[0]);
    }

    #[test]
    fn immediate_detection_with_chaos_shard_kill_is_deterministic() {
        // Deterministic detection + an injected storage-shard kill: the
        // node kill is declared at its schedule iteration (no wall-clock
        // heartbeats) and recovery reads through the surviving shards, so
        // two runs on the same seed produce identical losses and events.
        use crate::chaos::{FaultKind, FaultPlan, ShardFault};
        use crate::models::synthetic::SyntheticTrainer;

        let run = || {
            let mut trainer = SyntheticTrainer::new(18, 0.8, 4);
            let plan = FaultPlan {
                faults: vec![ShardFault {
                    shard: 1,
                    at: 4,
                    kind: FaultKind::Kill { heal_at: None },
                }],
            };
            let store = Arc::new(plan.mem_store(3));
            let job = ClusterJob {
                ckpt_mode: CheckpointMode::Async,
                ckpt_writers: 2,
                kills: vec![(7, 2)],
                detect: Detect::Immediate,
                ..ClusterJob::new(3, 60, CheckpointPolicy::full(4), 21)
            };
            let report = run_cluster_training(&mut trainer, store.clone(), &job).unwrap();
            assert_eq!(store.down_shards(), vec![1]);
            assert!(store.degraded_records() > 0, "writes re-homed off the dead shard");
            (report.losses, report.events)
        };
        let (losses_a, events_a) = run();
        let (losses_b, events_b) = run();
        assert_eq!(losses_a, losses_b, "losses must be byte-identical");
        assert_eq!(events_a, events_b, "events must be identical");
        // The scheduled node kill was declared at its kill iteration and
        // recovered in the same loop pass.
        assert!(events_a
            .iter()
            .any(|e| matches!(e, ClusterEvent::NodeDeclaredDead { node: 2, iter: 7 })));
        assert!(events_a
            .iter()
            .any(|e| matches!(e, ClusterEvent::Recovered { iter: 7, .. })));
    }
}
