//! Chaos: deterministic storage-fault injection for the sharded
//! checkpoint store.
//!
//! The scenario engine can kill PS *nodes*, but until this subsystem the
//! storage layer itself was never the failure domain — every shard of the
//! running checkpoint was assumed perfectly available and perfectly
//! durable. Storage faults behave qualitatively differently from clean
//! worker kills (a dead shard takes *history* with it, a slow shard
//! back-pressures the write pipeline, a torn record silently loses the
//! freshest save), so they get a first-class, reproducible model here:
//!
//! * [`FaultPlan`] — a declarative, epoch-keyed schedule of per-shard
//!   faults. No wall-clock anywhere: every fault is keyed to a training
//!   iteration, so the same plan on the same seed produces byte-identical
//!   runs whatever the thread scheduling.
//! * [`ChaosBackend`] — wraps any [`ShardBackend`] and applies the plan:
//!   - **kill** — the shard refuses reads and writes from epoch `at`
//!     until it heals (never, by default). Routing reacts in
//!     [`ShardedStore`](crate::storage::ShardedStore): writes re-route to
//!     the first surviving shard, reads skip the dead shard, and the
//!     checkpoint coordinator re-persists the running checkpoint from its
//!     in-memory cache (§4.3 keeps one precisely so the persistent copy
//!     is re-derivable) — see
//!     [`AsyncCheckpointer`](crate::checkpoint::AsyncCheckpointer).
//!   - **slow** — puts inside the window sleep `delay_us` wall-clock
//!     microseconds, so an async writer pool genuinely falls behind and
//!     the bounded queue (`storage.max_pending`) exerts back-pressure.
//!     Results stay byte-identical; only wall-clock changes.
//!   - **torn write** — the first put at/after epoch `at` is torn
//!     mid-batch: the leading half of its records land, the tail is
//!     discarded (a one-record batch loses its record), exactly what
//!     `DiskStore`'s CRC check does to a record cut short by a crash.
//!     Readers transparently see the previous record for the torn atoms.
//!   - **partition** — the shard is reachable but unwritable inside
//!     `[at, until)`: reads serve throughout, writes re-route at the
//!     router (counted as degraded). No record is ever lost in-process
//!     or after the heal, so the recovery planner has nothing to
//!     rebuild — the fault family that distinguishes *unreachability*
//!     from *data loss*. (Crash durability is the one carve-out: a
//!     partitioned shard's manifest cannot sync until it heals, so a
//!     crash *inside* the window rolls its unsynced tail back — exactly
//!     the fsync family's territory; see `ShardedStore::sync_all`.)
//!   - **flaky** — deterministic kill+heal cycles (`period`, `down_for`,
//!     `cycles`). Each down phase triggers a selective rebuild of the
//!     shard's slice onto survivors; each heal has the shard re-adopt
//!     its slice via the planner so its records are fresh again.
//!   - **fsync** — one-shot metadata-journal loss: the next manifest
//!     sync at/after `at` silently does not persist, or a compaction
//!     pass due first crashes inside the manifest rename window. A
//!     reopen recovers the last manifest that genuinely hit the disk.
//!   - **bitflip** — one-shot soft error: at the first epoch tick
//!     at/after `at`, one payload bit of the target atom's latest record
//!     flips in place (on disk: physically, in the segment file; in
//!     memory: the record becomes unreadable, the post-CRC-detection
//!     state). With erasure coding enabled the next parity fence
//!     detects the CRC mismatch and *repairs the record from parity*;
//!     without it, reads fall back to the previous good record.
//!   - **replay** — one-shot at-least-once delivery: the freshest put
//!     batch delivered *before* epoch `at` is captured, and re-delivered
//!     at the first durability fence at/after `at` — a network retry
//!     arriving long after the original send. Re-delivery goes through
//!     the iteration-supersede rule: any record whose atom has since
//!     been overwritten at a newer iteration is dropped (counted as
//!     superseded), the rest land carrying their *original* iteration,
//!     so the store's freshest-record-by-iteration read scan is
//!     unaffected. A correct store makes replay a state no-op —
//!     byte-identical to the fault-free run — which is exactly what the
//!     family pins.
//!
//! When a [`Recorder`](crate::obs::Recorder) is attached
//! (`ShardBackend::set_recorder`), every injection and heal is recorded
//! as an iteration-clocked event: window families (kill/flaky/partition/
//! slow) emit a `Fault` on entry and a `Heal` on exit, one-shots
//! (torn/fsync/bitflip) emit a `Fault` when they fire, and replays emit
//! a `Replay` event carrying the re-delivered/superseded record counts.
//!
//! The epoch clock is advanced by the checkpoint front-end once per
//! training iteration (`ShardedStore::advance_epoch`), so faults take
//! effect at deterministic points of the run. Writes carry their barrier
//! iteration and are judged by it — an in-flight async write enqueued
//! before a kill still lands (it was in flight before the crash), which
//! keeps async and sync runs equivalent.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::obs::{EventKind, Recorder};
use crate::storage::{CompactionStats, MemStore, SavedAtom, ShardBackend, ShardedStore};

/// What goes wrong with one shard (see the module docs for semantics).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Shard unavailable from `at` until `heal_at` (`None` = forever).
    Kill { heal_at: Option<usize> },
    /// Puts inside `[at, until)` sleep `delay_us` microseconds each
    /// (`until = None` = for the rest of the run).
    Slow { until: Option<usize>, delay_us: u64 },
    /// The first put at/after `at` is torn mid-batch (fires once).
    TornWrite,
    /// Network partition in `[at, until)`: the shard is reachable but
    /// unwritable — reads are served throughout, writes re-route at the
    /// router (`until = None` = for the rest of the run). No data is
    /// lost, so the recovery planner has nothing to rebuild.
    Partition { until: Option<usize> },
    /// Deterministic kill+heal cycles: down in
    /// `[at + c·period, at + c·period + down_for)` for `c in 0..cycles`.
    /// Each heal has the shard re-adopt its slice via the rebuild
    /// planner, so its records are fresh again before the next cycle.
    Flaky { period: usize, down_for: usize, cycles: usize },
    /// One-shot fsync failure at/after `at`: the next durability fence
    /// (manifest sync) silently does not persist, or — if a compaction
    /// pass comes first — the pass crashes inside the manifest rename
    /// window (fresh segments land, the commit never does). Models
    /// metadata-journal loss; recovery after a reopen lands on the last
    /// manifest that genuinely reached the disk.
    FsyncFail,
    /// One-shot soft error at the first epoch tick at/after `at`: one
    /// payload bit of `atom`'s latest record on this shard flips in
    /// place (see [`ShardBackend::corrupt_record`]). The record stays
    /// where it is — the damage is only *observable* through a CRC
    /// mismatch on read, and only *repairable* from parity.
    Bitflip { atom: usize },
    /// One-shot at-least-once delivery: the freshest put batch delivered
    /// before `at` is re-delivered at the first durability fence
    /// at/after `at`, filtered through the iteration-supersede rule (a
    /// record overwritten at a newer iteration is dropped; survivors
    /// land at their original iteration). Stresses the
    /// freshest-record-by-iteration read scan directly: a correct store
    /// makes the replay a state no-op.
    Replay,
}

/// One scheduled fault: which shard, from which epoch, what kind.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShardFault {
    pub shard: usize,
    /// Training iteration the fault takes effect at (>= 1; epoch 0 is the
    /// x⁽⁰⁾ startup dump, which is assumed healthy).
    pub at: usize,
    pub kind: FaultKind,
}

/// A deterministic storage-fault schedule. Empty by default (no chaos).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub faults: Vec<ShardFault>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Validate against a shard count: every fault must target an
    /// existing shard at epoch >= 1; no epoch may leave every shard down
    /// at once (degraded reads need a survivor — kill and flaky windows
    /// are checked, with overlapping heal windows, not just
    /// forever-kills); and no epoch may leave every shard *unwritable*
    /// (down or partitioned — degraded writes need a writable target).
    pub fn validate(&self, n_shards: usize) -> Result<()> {
        for f in &self.faults {
            if f.shard >= n_shards {
                bail!(
                    "chaos fault targets shard {}, but the store has {n_shards} shard(s)",
                    f.shard
                );
            }
            if f.at == 0 {
                bail!("chaos fault on shard {} has at = 0; epochs start at 1", f.shard);
            }
            match f.kind {
                FaultKind::Kill { heal_at: Some(h) } => {
                    if h <= f.at {
                        bail!(
                            "chaos kill on shard {}: heal_at {h} must be > at {}",
                            f.shard,
                            f.at
                        );
                    }
                }
                FaultKind::Partition { until: Some(u) } => {
                    if u <= f.at {
                        bail!(
                            "chaos partition on shard {}: until {u} must be > at {}",
                            f.shard,
                            f.at
                        );
                    }
                }
                FaultKind::Flaky { period, down_for, cycles } => {
                    if cycles == 0 {
                        bail!("chaos flaky on shard {}: cycles must be >= 1", f.shard);
                    }
                    if down_for == 0 {
                        bail!("chaos flaky on shard {}: down_for must be >= 1", f.shard);
                    }
                    if period <= down_for {
                        bail!(
                            "chaos flaky on shard {}: period {period} must be > down_for \
                             {down_for} (each cycle needs an up phase to heal into)",
                            f.shard
                        );
                    }
                }
                _ => {}
            }
        }
        // Down windows: kills plus every flaky cycle, as (shard, start,
        // end) intervals. An "all shards down" (or unwritable) interval
        // can only begin at some window's start epoch, so checking each
        // start is exhaustive.
        let mut down_windows: Vec<(usize, usize, Option<usize>)> = Vec::new();
        let mut unwritable_windows: Vec<(usize, usize, Option<usize>)> = Vec::new();
        for f in &self.faults {
            match f.kind {
                FaultKind::Kill { heal_at } => down_windows.push((f.shard, f.at, heal_at)),
                FaultKind::Flaky { period, down_for, cycles } => {
                    for c in 0..cycles {
                        let start = f.at + c * period;
                        down_windows.push((f.shard, start, Some(start + down_for)));
                    }
                }
                FaultKind::Partition { until } => {
                    unwritable_windows.push((f.shard, f.at, until));
                }
                _ => {}
            }
        }
        // A down shard is also unwritable.
        unwritable_windows.extend(down_windows.iter().copied());
        let covers = |(_, at, end): &(usize, usize, Option<usize>), e: usize| {
            *at <= e && end.map(|u| e < u).unwrap_or(true)
        };
        for &(_, e, _) in &down_windows {
            let mut down = vec![false; n_shards];
            for w in &down_windows {
                if covers(w, e) {
                    down[w.0] = true;
                }
            }
            if down.iter().all(|&d| d) {
                bail!(
                    "chaos plan takes every shard down at iteration {e}; at least one \
                     shard must be serving"
                );
            }
        }
        for &(_, e, _) in &unwritable_windows {
            let mut unwritable = vec![false; n_shards];
            for w in &unwritable_windows {
                if covers(w, e) {
                    unwritable[w.0] = true;
                }
            }
            if unwritable.iter().all(|&d| d) {
                bail!(
                    "chaos plan leaves no writable shard at iteration {e} (kills + \
                     partitions cover the whole store); at least one shard must accept \
                     writes"
                );
            }
        }
        Ok(())
    }

    /// Faults scheduled for one shard.
    fn for_shard(&self, shard: usize) -> Vec<ShardFault> {
        self.faults.iter().copied().filter(|f| f.shard == shard).collect()
    }

    /// Wrap each backend in a [`ChaosBackend`] applying this plan.
    pub fn wrap(&self, backends: Vec<Box<dyn ShardBackend>>) -> Vec<Box<dyn ShardBackend>> {
        backends
            .into_iter()
            .enumerate()
            .map(|(s, inner)| {
                Box::new(ChaosBackend::new(inner, s, self.for_shard(s))) as Box<dyn ShardBackend>
            })
            .collect()
    }

    /// `n_shards` in-memory shards behind this plan — the store every
    /// harness-backed chaos trial uses.
    pub fn mem_store(&self, n_shards: usize) -> ShardedStore {
        let backends = (0..n_shards)
            .map(|_| Box::new(MemStore::new()) as Box<dyn ShardBackend>)
            .collect();
        ShardedStore::from_backends(self.wrap(backends))
    }

    /// `n_shards` on-disk shards under `dir/shard-NNN/` behind this plan
    /// — chaos over the durable tier. Kill/slow windows behave exactly as
    /// on memory shards; torn writes leave a *physically truncated*
    /// record in the segment log, so reads drive `DiskStore`'s real
    /// CRC/manifest fallback end to end (`rust/tests/chaos.rs` pins that
    /// results stay byte-identical to the same plan on memory shards).
    pub fn disk_store(&self, dir: &Path, n_shards: usize) -> Result<ShardedStore> {
        let backends = ShardedStore::disk_backends(dir, n_shards)?;
        Ok(ShardedStore::from_backends(self.wrap(backends)).with_placement_dir(dir))
    }

    /// Serialize to the scenario value model (`{kill: [...], slow: [...],
    /// torn: [...], partition: [...], flaky: [...], fsync: [...]}`), the
    /// inverse of the scenario `[chaos]` parser.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut kills = Vec::new();
        let mut slows = Vec::new();
        let mut torns = Vec::new();
        let mut partitions = Vec::new();
        let mut flakies = Vec::new();
        let mut fsyncs = Vec::new();
        let mut bitflips = Vec::new();
        let mut replays = Vec::new();
        for f in &self.faults {
            let mut m = BTreeMap::new();
            m.insert("shard".to_string(), Json::from(f.shard));
            m.insert("at".to_string(), Json::from(f.at));
            match f.kind {
                FaultKind::Kill { heal_at } => {
                    if let Some(h) = heal_at {
                        m.insert("heal_at".to_string(), Json::from(h));
                    }
                    kills.push(Json::Obj(m));
                }
                FaultKind::Slow { until, delay_us } => {
                    if let Some(u) = until {
                        m.insert("until".to_string(), Json::from(u));
                    }
                    m.insert("delay_us".to_string(), Json::from(delay_us as usize));
                    slows.push(Json::Obj(m));
                }
                FaultKind::TornWrite => torns.push(Json::Obj(m)),
                FaultKind::Partition { until } => {
                    if let Some(u) = until {
                        m.insert("until".to_string(), Json::from(u));
                    }
                    partitions.push(Json::Obj(m));
                }
                FaultKind::Flaky { period, down_for, cycles } => {
                    m.insert("period".to_string(), Json::from(period));
                    m.insert("down_for".to_string(), Json::from(down_for));
                    m.insert("cycles".to_string(), Json::from(cycles));
                    flakies.push(Json::Obj(m));
                }
                FaultKind::FsyncFail => fsyncs.push(Json::Obj(m)),
                FaultKind::Bitflip { atom } => {
                    m.insert("atom".to_string(), Json::from(atom));
                    bitflips.push(Json::Obj(m));
                }
                FaultKind::Replay => replays.push(Json::Obj(m)),
            }
        }
        let mut obj = BTreeMap::new();
        for (key, arr) in [
            ("kill", kills),
            ("slow", slows),
            ("torn", torns),
            ("partition", partitions),
            ("flaky", flakies),
            ("fsync", fsyncs),
            ("bitflip", bitflips),
            ("replay", replays),
        ] {
            if !arr.is_empty() {
                obj.insert(key.to_string(), Json::Arr(arr));
            }
        }
        crate::util::json::Json::Obj(obj)
    }

    /// Parse the compact CLI chaos grammar (`scar train/cluster --chaos`,
    /// RunConfig key `chaos`): comma-separated entries, each
    /// `kind:shard@at` plus a kind-specific suffix —
    ///
    /// * `kill:1@6` / `kill:1@6..9` (heal at 9)
    /// * `slow:0@4..9x50` (50 µs per put; `..9` optional)
    /// * `torn:2@8`
    /// * `part:0@4..12` (partition; `..12` optional)
    /// * `flaky:2@5p8d3c2` (period 8, down 3, 2 cycles)
    /// * `fsync:0@7`
    /// * `bitflip:1@6` / `bitflip:1@6a9` (flip a bit of atom 9's record;
    ///   the atom defaults to the shard index when the `aATOM` suffix is
    ///   omitted)
    /// * `replay:1@7` (re-deliver shard 1's freshest pre-7 put batch at
    ///   the first fence at/after epoch 7)
    ///
    /// The empty string parses to the empty (no-chaos) plan.
    pub fn parse_spec(spec: &str) -> Result<FaultPlan> {
        fn num(s: &str, what: &str, entry: &str) -> Result<usize> {
            s.parse::<usize>()
                .map_err(|_| anyhow::anyhow!("chaos spec '{entry}': bad {what} '{s}'"))
        }
        /// Split `"4..9"`-style windows; the `..end` part is optional.
        fn window(s: &str, entry: &str) -> Result<(usize, Option<usize>)> {
            match s.split_once("..") {
                None => Ok((num(s, "epoch", entry)?, None)),
                Some((a, b)) => Ok((num(a, "epoch", entry)?, Some(num(b, "epoch", entry)?))),
            }
        }
        let mut faults = Vec::new();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let (kind_tag, rest) = entry
                .split_once(':')
                .with_context(|| format!("chaos spec '{entry}': expected kind:shard@at..."))?;
            let (shard, tail) = rest
                .split_once('@')
                .with_context(|| format!("chaos spec '{entry}': expected shard@at after ':'"))?;
            let shard = num(shard, "shard", entry)?;
            let fault = match kind_tag {
                "kill" => {
                    let (at, heal_at) = window(tail, entry)?;
                    ShardFault { shard, at, kind: FaultKind::Kill { heal_at } }
                }
                "slow" => {
                    let (win, delay) = tail.split_once('x').with_context(|| {
                        format!("chaos spec '{entry}': slow needs xDELAY_US suffix")
                    })?;
                    let (at, until) = window(win, entry)?;
                    let delay_us = num(delay, "delay_us", entry)? as u64;
                    ShardFault { shard, at, kind: FaultKind::Slow { until, delay_us } }
                }
                "torn" => ShardFault {
                    shard,
                    at: num(tail, "epoch", entry)?,
                    kind: FaultKind::TornWrite,
                },
                "part" | "partition" => {
                    let (at, until) = window(tail, entry)?;
                    ShardFault { shard, at, kind: FaultKind::Partition { until } }
                }
                "flaky" => {
                    // at 'p' period 'd' down_for 'c' cycles, all required.
                    let (at, rest) = tail.split_once('p').with_context(|| {
                        format!("chaos spec '{entry}': flaky needs pPERIOD")
                    })?;
                    let (period, rest) = rest.split_once('d').with_context(|| {
                        format!("chaos spec '{entry}': flaky needs dDOWN_FOR")
                    })?;
                    let (down_for, cycles) = rest.split_once('c').with_context(|| {
                        format!("chaos spec '{entry}': flaky needs cCYCLES")
                    })?;
                    ShardFault {
                        shard,
                        at: num(at, "epoch", entry)?,
                        kind: FaultKind::Flaky {
                            period: num(period, "period", entry)?,
                            down_for: num(down_for, "down_for", entry)?,
                            cycles: num(cycles, "cycles", entry)?,
                        },
                    }
                }
                "fsync" => ShardFault {
                    shard,
                    at: num(tail, "epoch", entry)?,
                    kind: FaultKind::FsyncFail,
                },
                "bitflip" => {
                    // `AT` or `ATaATOM`; the atom defaults to the shard
                    // index (every shard owns its own atom id under
                    // modulo routing, so the default always has a record
                    // to hit).
                    let (at, atom) = match tail.split_once('a') {
                        None => (num(tail, "epoch", entry)?, shard),
                        Some((at, atom)) => {
                            (num(at, "epoch", entry)?, num(atom, "atom", entry)?)
                        }
                    };
                    ShardFault { shard, at, kind: FaultKind::Bitflip { atom } }
                }
                "replay" => ShardFault {
                    shard,
                    at: num(tail, "epoch", entry)?,
                    kind: FaultKind::Replay,
                },
                other => bail!(
                    "chaos spec '{entry}': unknown fault kind '{other}' \
                     (kill|slow|torn|part|flaky|fsync|bitflip|replay)"
                ),
            };
            faults.push(fault);
        }
        Ok(FaultPlan { faults })
    }
}

/// A captured put batch awaiting replay: `(barrier iter, owned records)`.
type ReplayBatch = (usize, Vec<(usize, Vec<f32>)>);

/// Fault-injecting wrapper around one storage shard.
pub struct ChaosBackend {
    inner: Box<dyn ShardBackend>,
    shard: usize,
    faults: Vec<ShardFault>,
    /// Fired flags for one-shot faults (parallel to `faults`).
    fired: Vec<bool>,
    /// Current epoch (highest iteration seen by the clock or a put).
    epoch: usize,
    /// Records dropped by torn writes (accounting/debugging).
    torn_records: u64,
    /// Durability fences silently dropped by fsync faults.
    fsync_failures: u64,
    /// Records corrupted by bitflip faults.
    bitflips: u64,
    /// Atoms corrupted since the last `take_corruptions` drain, so the
    /// router can mark their stripes dirty for the next parity fence.
    corrupted: Vec<usize>,
    /// Captured batches for replay faults (parallel to `faults`; the
    /// freshest fully-delivered pre-`at` batch wins).
    replay_buf: Vec<Option<ReplayBatch>>,
    /// Records re-delivered by replay faults.
    replayed_records: u64,
    /// Re-delivered records dropped by the iteration-supersede rule.
    superseded_records: u64,
    /// Flight recorder (disabled unless attached via `set_recorder`).
    rec: Recorder,
}

impl ChaosBackend {
    pub fn new(inner: Box<dyn ShardBackend>, shard: usize, faults: Vec<ShardFault>) -> Self {
        let fired = vec![false; faults.len()];
        let replay_buf = (0..faults.len()).map(|_| None).collect();
        ChaosBackend {
            inner,
            shard,
            faults,
            fired,
            epoch: 0,
            torn_records: 0,
            fsync_failures: 0,
            bitflips: 0,
            corrupted: Vec::new(),
            replay_buf,
            replayed_records: 0,
            superseded_records: 0,
            rec: Recorder::disabled(),
        }
    }

    pub fn torn_records(&self) -> u64 {
        self.torn_records
    }

    pub fn fsync_failures(&self) -> u64 {
        self.fsync_failures
    }

    pub fn bitflips(&self) -> u64 {
        self.bitflips
    }

    pub fn replayed_records(&self) -> u64 {
        self.replayed_records
    }

    pub fn superseded_records(&self) -> u64 {
        self.superseded_records
    }

    /// Is the shard inside a kill window (or a flaky down phase) at
    /// `epoch`?
    fn down_at(&self, epoch: usize) -> bool {
        self.faults.iter().any(|f| match f.kind {
            FaultKind::Kill { heal_at } => {
                f.at <= epoch
                    && match heal_at {
                        Some(h) => epoch < h,
                        None => true,
                    }
            }
            FaultKind::Flaky { period, down_for, cycles } => {
                if epoch < f.at {
                    return false;
                }
                let rel = epoch - f.at;
                rel / period < cycles && rel % period < down_for
            }
            _ => false,
        })
    }

    /// Is the shard inside a partition (unwritable) window at `epoch`?
    fn partitioned_at(&self, epoch: usize) -> bool {
        self.faults.iter().any(|f| match f.kind {
            FaultKind::Partition { until } => {
                f.at <= epoch
                    && match until {
                        Some(u) => epoch < u,
                        None => true,
                    }
            }
            _ => false,
        })
    }

    /// Consume a pending one-shot fsync fault, if one is due at the
    /// current epoch.
    fn take_fsync_fault(&mut self) -> bool {
        for i in 0..self.faults.len() {
            if !self.fired[i]
                && matches!(self.faults[i].kind, FaultKind::FsyncFail)
                && self.epoch >= self.faults[i].at
            {
                self.fired[i] = true;
                self.fsync_failures += 1;
                self.rec.record(
                    self.epoch,
                    EventKind::Fault { fault: "fsync".to_string(), shard: self.shard },
                );
                return true;
            }
        }
        false
    }

    /// Which window family has the shard down at `epoch` (for the
    /// recorder's fault tag; kill wins when windows overlap).
    fn down_kind_at(&self, epoch: usize) -> &'static str {
        let mut kind = "kill";
        for f in &self.faults {
            match f.kind {
                FaultKind::Kill { heal_at } => {
                    if f.at <= epoch && heal_at.map(|h| epoch < h).unwrap_or(true) {
                        return "kill";
                    }
                }
                FaultKind::Flaky { period, down_for, cycles } => {
                    if epoch >= f.at {
                        let rel = epoch - f.at;
                        if rel / period < cycles && rel % period < down_for {
                            kind = "flaky";
                        }
                    }
                }
                _ => {}
            }
        }
        kind
    }

    /// Remember the freshest fully-delivered pre-`at` batch for every
    /// pending replay fault (called after a successful whole put).
    fn capture_replay(&mut self, iter: usize, atoms: &[(usize, &[f32])]) {
        for i in 0..self.faults.len() {
            if self.fired[i]
                || !matches!(self.faults[i].kind, FaultKind::Replay)
                || iter >= self.faults[i].at
            {
                continue;
            }
            let fresher = match &self.replay_buf[i] {
                Some((stored, _)) => iter >= *stored,
                None => true,
            };
            if fresher {
                self.replay_buf[i] =
                    Some((iter, atoms.iter().map(|(a, v)| (*a, v.to_vec())).collect()));
            }
        }
    }

    /// Fire any replay fault due at the current epoch. Runs at the
    /// durability fence (`sync`), after the writer pool has drained —
    /// the one point where "the freshest batch delivered before `at`"
    /// is the same set in sync and async mode, so the re-delivery (and
    /// its trace event) is deterministic across modes.
    fn fire_replays(&mut self) {
        for i in 0..self.faults.len() {
            if self.fired[i]
                || !matches!(self.faults[i].kind, FaultKind::Replay)
                || self.epoch < self.faults[i].at
            {
                continue;
            }
            self.fired[i] = true;
            let Some((orig_iter, batch)) = self.replay_buf[i].take() else {
                // Nothing was ever delivered before `at` — the retry had
                // nothing to carry.
                self.rec.record(
                    self.epoch,
                    EventKind::Replay { shard: self.shard, records: 0, superseded: 0 },
                );
                continue;
            };
            // The iteration-supersede rule, applied at the delivery
            // boundary: a record whose atom has since been overwritten
            // at a newer iteration is dropped; the rest re-land at their
            // *original* iteration, so a re-delivered record is
            // byte-identical to the one already present and the
            // freshest-record read scan is unaffected either way.
            let mut superseded = 0u64;
            let mut deliver: Vec<(usize, &[f32])> = Vec::new();
            for (atom, values) in &batch {
                match self.inner.atom_iter(*atom) {
                    Ok(Some(cur)) if cur > orig_iter => superseded += 1,
                    _ => deliver.push((*atom, values.as_slice())),
                }
            }
            let replayed = deliver.len() as u64;
            if !deliver.is_empty() {
                // Injection must never fail the training loop; a refused
                // re-delivery (e.g. the shard died meanwhile) is simply a
                // retry that never arrived.
                let _ = self.inner.put_atoms(orig_iter, &deliver);
            }
            self.replayed_records += replayed;
            self.superseded_records += superseded;
            self.rec.record(
                self.epoch,
                EventKind::Replay { shard: self.shard, records: replayed, superseded },
            );
        }
    }

    /// Injected write delay at `epoch`, if inside a slow window.
    fn slow_at(&self, epoch: usize) -> Option<u64> {
        self.faults.iter().find_map(|f| match f.kind {
            FaultKind::Slow { until, delay_us } => {
                let inside = f.at <= epoch
                    && match until {
                        Some(u) => epoch < u,
                        None => true,
                    };
                if inside {
                    Some(delay_us)
                } else {
                    None
                }
            }
            _ => None,
        })
    }
}

impl ShardBackend for ChaosBackend {
    fn put_atoms(&mut self, iter: usize, atoms: &[(usize, &[f32])]) -> Result<()> {
        // A write is refused only when the shard is down *now* (the
        // clock) for a put issued at/after the kill (its barrier iter).
        // Two deliberate acceptances keep async and sync runs equivalent:
        // a put with a pre-kill iter lands while the shard is down (it
        // was in flight before the crash), and a put whose iter falls
        // inside a kill window the shard has since healed from lands too
        // (the write was merely delayed past the outage).
        if iter > self.epoch {
            self.epoch = iter;
        }
        if self.down_at(self.epoch) && self.down_at(iter) {
            bail!("shard {} is down (injected kill)", self.shard);
        }
        // Same in-flight acceptance rule as kills: a put issued before
        // the partition began still lands (it was on the wire).
        if self.partitioned_at(self.epoch) && self.partitioned_at(iter) {
            bail!("shard {} is partitioned (injected fault): reachable but unwritable", self.shard);
        }
        if let Some(delay_us) = self.slow_at(iter) {
            if delay_us > 0 {
                std::thread::sleep(std::time::Duration::from_micros(delay_us));
            }
        }
        for i in 0..self.faults.len() {
            if self.fired[i] || !matches!(self.faults[i].kind, FaultKind::TornWrite) {
                continue;
            }
            if iter >= self.faults[i].at {
                self.fired[i] = true;
                // Tear mid-batch: the leading half lands, the tail is the
                // in-flight record a crash cut short. Floor division so a
                // one-record batch loses its record — a torn write always
                // tears *something*. The backend decides what a tear
                // physically is: memory backends drop the tail outright,
                // DiskStore appends a truncated record so reads exercise
                // its real CRC/manifest fallback.
                let keep = atoms.len() / 2;
                self.torn_records += (atoms.len() - keep) as u64;
                self.rec.record(
                    iter,
                    EventKind::Fault { fault: "torn".to_string(), shard: self.shard },
                );
                return self.inner.put_torn(iter, atoms, keep);
            }
        }
        self.inner.put_atoms(iter, atoms)?;
        // Only a *whole* delivery is a replayable batch (a torn one never
        // fully existed on the wire to retry).
        self.capture_replay(iter, atoms);
        Ok(())
    }

    fn get_atom(&self, atom: usize) -> Result<Option<SavedAtom>> {
        if self.down_at(self.epoch) {
            bail!("shard {} is down (injected kill)", self.shard);
        }
        // Partitioned shards still serve reads — that is the point.
        self.inner.get_atom(atom)
    }

    fn read_atom_into(&self, atom: usize, out: &mut Vec<f32>) -> Result<Option<usize>> {
        if self.down_at(self.epoch) {
            bail!("shard {} is down (injected kill)", self.shard);
        }
        self.inner.read_atom_into(atom, out)
    }

    fn atom_iter(&self, atom: usize) -> Result<Option<usize>> {
        if self.down_at(self.epoch) {
            bail!("shard {} is down (injected kill)", self.shard);
        }
        self.inner.atom_iter(atom)
    }

    fn bytes_written(&self) -> u64 {
        self.inner.bytes_written()
    }

    fn records_written(&self) -> u64 {
        self.inner.records_written()
    }

    fn sync(&mut self) -> Result<()> {
        if self.down_at(self.epoch) {
            bail!("shard {} is down (injected kill)", self.shard);
        }
        // Replays fire at the fence: the pool has drained, so the
        // captured batch is mode-independent (see `fire_replays`).
        self.fire_replays();
        if self.take_fsync_fault() {
            // The fence is acknowledged but never reaches the disk: the
            // manifest on disk stays whatever the previous sync wrote —
            // only a reopen (a crash) observes the loss.
            return Ok(());
        }
        self.inner.sync()
    }

    fn advance_epoch(&mut self, iter: usize) {
        let was_down = self.down_at(self.epoch);
        let was_partitioned = self.partitioned_at(self.epoch);
        let was_slow = self.slow_at(self.epoch).is_some();
        if iter > self.epoch {
            self.epoch = iter;
        }
        self.inner.advance_epoch(iter);
        // Narrate window transitions (entry = Fault, exit = Heal). The
        // guard keeps the disabled-recorder path down to one branch.
        if self.rec.is_enabled() {
            let down = self.down_at(self.epoch);
            let partitioned = self.partitioned_at(self.epoch);
            let slow = self.slow_at(self.epoch).is_some();
            if !was_down && down {
                let fault = self.down_kind_at(self.epoch).to_string();
                self.rec.record(iter, EventKind::Fault { fault, shard: self.shard });
            }
            if was_down && !down {
                self.rec.record(iter, EventKind::Heal { shard: self.shard });
            }
            if !was_partitioned && partitioned {
                self.rec.record(
                    iter,
                    EventKind::Fault { fault: "partition".to_string(), shard: self.shard },
                );
            }
            if was_partitioned && !partitioned {
                self.rec.record(iter, EventKind::Heal { shard: self.shard });
            }
            if !was_slow && slow {
                self.rec.record(
                    iter,
                    EventKind::Fault { fault: "slow".to_string(), shard: self.shard },
                );
            }
            if was_slow && !slow {
                self.rec.record(iter, EventKind::Heal { shard: self.shard });
            }
        }
        // Bitflips fire one-shot off the fault clock, so the corruption
        // lands at a deterministic epoch in every mode. A fault whose
        // atom has no record yet simply misses (no bit to flip); IO
        // errors while flipping are ignored — injection must never fail
        // the training loop, and the suite asserts on repairs, not
        // flips.
        for i in 0..self.faults.len() {
            if self.fired[i] {
                continue;
            }
            let FaultKind::Bitflip { atom } = self.faults[i].kind else {
                continue;
            };
            if self.epoch >= self.faults[i].at {
                self.fired[i] = true;
                if let Ok(true) = self.inner.corrupt_record(atom) {
                    self.bitflips += 1;
                    self.corrupted.push(atom);
                    self.rec.record(
                        iter,
                        EventKind::Fault { fault: "bitflip".to_string(), shard: self.shard },
                    );
                }
            }
        }
    }

    fn is_down(&self) -> bool {
        self.down_at(self.epoch)
    }

    fn is_writable(&self) -> bool {
        !self.partitioned_at(self.epoch)
    }

    fn put_torn(&mut self, iter: usize, atoms: &[(usize, &[f32])], keep: usize) -> Result<()> {
        self.inner.put_torn(iter, atoms, keep)
    }

    fn garbage_ratio(&self) -> f64 {
        self.inner.garbage_ratio()
    }

    fn on_disk_bytes(&self) -> u64 {
        self.inner.on_disk_bytes()
    }

    fn compact(&mut self, max_pass_bytes: u64) -> Result<Option<CompactionStats>> {
        if self.down_at(self.epoch) {
            bail!("shard {} is down (injected kill)", self.shard);
        }
        if self.take_fsync_fault() {
            // The pass crashes inside the manifest rename window: phase
            // one's fresh segments land on disk, the commit (manifest
            // swap) never happens. In-process reads are unaffected; a
            // reopen recovers the last manifest that reached the disk
            // and removes the orphaned fresh segments.
            self.inner.compact_abandoned(max_pass_bytes)?;
            return Ok(None);
        }
        self.inner.compact(max_pass_bytes)
    }

    fn compact_abandoned(&mut self, max_pass_bytes: u64) -> Result<()> {
        self.inner.compact_abandoned(max_pass_bytes)
    }

    fn fsyncs(&self) -> u64 {
        self.inner.fsyncs()
    }

    fn set_group_commit(&mut self, on: bool) {
        self.inner.set_group_commit(on);
    }

    fn corrupt_record(&mut self, atom: usize) -> Result<bool> {
        self.inner.corrupt_record(atom)
    }

    fn take_corruptions(&mut self) -> Vec<usize> {
        let mut atoms = self.inner.take_corruptions();
        atoms.append(&mut self.corrupted);
        atoms
    }

    fn set_recorder(&mut self, rec: Recorder) {
        self.inner.set_recorder(rec.clone());
        self.rec = rec;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn put1(store: &mut dyn ShardBackend, iter: usize, atom: usize, val: f32) {
        store.put_atoms(iter, &[(atom, &[val][..])]).unwrap();
    }

    #[test]
    fn kill_window_blocks_and_heals() {
        let plan = FaultPlan {
            faults: vec![ShardFault {
                shard: 0,
                at: 5,
                kind: FaultKind::Kill { heal_at: Some(9) },
            }],
        };
        let mut b = ChaosBackend::new(Box::new(MemStore::new()), 0, plan.for_shard(0));
        put1(&mut b, 2, 0, 1.0);
        assert!(!b.is_down());
        b.advance_epoch(5);
        assert!(b.is_down());
        assert!(b.get_atom(0).is_err());
        assert!(b.put_atoms(6, &[(0, &[2.0][..])]).is_err());
        // In-flight write from before the kill still lands.
        put1(&mut b, 4, 1, 3.0);
        b.advance_epoch(9);
        assert!(!b.is_down());
        assert_eq!(b.get_atom(0).unwrap().unwrap().values, vec![1.0]);
        assert_eq!(b.get_atom(1).unwrap().unwrap().values, vec![3.0]);
    }

    #[test]
    fn torn_write_drops_the_tail_once() {
        let plan = FaultPlan {
            faults: vec![ShardFault { shard: 0, at: 3, kind: FaultKind::TornWrite }],
        };
        let mut b = ChaosBackend::new(Box::new(MemStore::new()), 0, plan.for_shard(0));
        b.put_atoms(1, &[(0, &[1.0][..]), (1, &[1.0][..])]).unwrap();
        // Torn put: atom 0 lands (prefix), atom 1's record is lost.
        b.put_atoms(4, &[(0, &[9.0][..]), (1, &[9.0][..])]).unwrap();
        assert_eq!(b.torn_records(), 1);
        assert_eq!(b.get_atom(0).unwrap().unwrap().iter, 4);
        assert_eq!(b.get_atom(1).unwrap().unwrap().iter, 1, "tail keeps the old record");
        // Fires once; the next put is whole.
        b.put_atoms(6, &[(0, &[5.0][..]), (1, &[5.0][..])]).unwrap();
        assert_eq!(b.get_atom(1).unwrap().unwrap().iter, 6);
    }

    #[test]
    fn torn_write_tears_a_single_record_batch_entirely() {
        let plan = FaultPlan {
            faults: vec![ShardFault { shard: 0, at: 2, kind: FaultKind::TornWrite }],
        };
        let mut b = ChaosBackend::new(Box::new(MemStore::new()), 0, plan.for_shard(0));
        put1(&mut b, 1, 0, 1.0);
        // A one-record put still tears: the record is lost, not kept.
        put1(&mut b, 3, 0, 9.0);
        assert_eq!(b.torn_records(), 1);
        assert_eq!(b.get_atom(0).unwrap().unwrap().iter, 1);
    }

    #[test]
    fn slow_window_only_delays() {
        let plan = FaultPlan {
            faults: vec![ShardFault {
                shard: 0,
                at: 1,
                kind: FaultKind::Slow { until: Some(3), delay_us: 1 },
            }],
        };
        let mut b = ChaosBackend::new(Box::new(MemStore::new()), 0, plan.for_shard(0));
        put1(&mut b, 1, 0, 1.0);
        put1(&mut b, 5, 0, 2.0);
        assert_eq!(b.get_atom(0).unwrap().unwrap().values, vec![2.0]);
        assert!(!b.is_down());
    }

    #[test]
    fn plan_validation() {
        let ok = FaultPlan {
            faults: vec![ShardFault { shard: 1, at: 4, kind: FaultKind::Kill { heal_at: None } }],
        };
        ok.validate(2).unwrap();
        assert!(ok.validate(1).is_err(), "shard out of range");
        let zero = FaultPlan {
            faults: vec![ShardFault { shard: 0, at: 0, kind: FaultKind::TornWrite }],
        };
        assert!(zero.validate(1).is_err(), "epoch 0 rejected");
        let all_dead = FaultPlan {
            faults: vec![
                ShardFault { shard: 0, at: 2, kind: FaultKind::Kill { heal_at: None } },
                ShardFault { shard: 1, at: 3, kind: FaultKind::Kill { heal_at: None } },
            ],
        };
        assert!(all_dead.validate(2).is_err(), "needs a survivor");
        let bad_heal = FaultPlan {
            faults: vec![ShardFault {
                shard: 0,
                at: 5,
                kind: FaultKind::Kill { heal_at: Some(5) },
            }],
        };
        assert!(bad_heal.validate(2).is_err(), "heal_at must be after at");
        // Overlapping *temporary* kill windows that leave no survivor are
        // rejected too, not just forever-kills.
        let overlap = FaultPlan {
            faults: vec![
                ShardFault { shard: 0, at: 2, kind: FaultKind::Kill { heal_at: Some(20) } },
                ShardFault { shard: 1, at: 3, kind: FaultKind::Kill { heal_at: Some(10) } },
            ],
        };
        assert!(overlap.validate(2).is_err(), "iterations 3..10 have no serving shard");
        // Disjoint windows are fine: some shard serves at every epoch.
        let disjoint = FaultPlan {
            faults: vec![
                ShardFault { shard: 0, at: 2, kind: FaultKind::Kill { heal_at: Some(5) } },
                ShardFault { shard: 1, at: 6, kind: FaultKind::Kill { heal_at: Some(9) } },
            ],
        };
        disjoint.validate(2).unwrap();
    }

    #[test]
    fn partition_window_blocks_writes_but_serves_reads() {
        let plan = FaultPlan {
            faults: vec![ShardFault {
                shard: 0,
                at: 3,
                kind: FaultKind::Partition { until: Some(7) },
            }],
        };
        let mut b = ChaosBackend::new(Box::new(MemStore::new()), 0, plan.for_shard(0));
        put1(&mut b, 1, 0, 1.0);
        b.advance_epoch(4);
        assert!(!b.is_down(), "a partitioned shard is not down");
        assert!(!b.is_writable(), "but it refuses writes");
        assert!(b.put_atoms(5, &[(0, &[5.0][..])]).is_err());
        // In-flight write from before the partition still lands.
        put1(&mut b, 2, 1, 2.0);
        // Reads are served throughout the window.
        assert_eq!(b.get_atom(0).unwrap().unwrap().values, vec![1.0]);
        assert_eq!(b.get_atom(1).unwrap().unwrap().values, vec![2.0]);
        b.advance_epoch(7);
        assert!(b.is_writable(), "the partition lifts at `until`");
        put1(&mut b, 8, 0, 8.0);
        assert_eq!(b.get_atom(0).unwrap().unwrap().values, vec![8.0]);
    }

    #[test]
    fn partitioned_shard_reroutes_writes_and_keeps_serving_reads() {
        let plan = FaultPlan {
            faults: vec![ShardFault {
                shard: 1,
                at: 3,
                kind: FaultKind::Partition { until: Some(8) },
            }],
        };
        let store = plan.mem_store(2);
        store.put_atoms_at(1, &[(0, &[1.0][..]), (1, &[1.0][..])]).unwrap();
        let report = store.advance_epoch(4);
        assert!(report.newly_down.is_empty(), "a partition is not a death");
        assert_eq!(store.down_shards(), Vec::<usize>::new());
        assert_eq!(store.unwritable_shards(), vec![1]);
        // Writes for atom 1 re-route to shard 0 (degraded), reads still
        // find both the old record on the partitioned shard and the new
        // one on the survivor.
        store.put_atoms_at(5, &[(1, &[5.0][..])]).unwrap();
        assert_eq!(store.degraded_records(), 1);
        assert_eq!(store.placement_of(1), Some(0));
        assert_eq!(store.get_atom_any(1).unwrap().unwrap().values, vec![5.0]);
        assert_eq!(store.get_atom_any(0).unwrap().unwrap().values, vec![1.0]);
        // After the window, writes land home again.
        store.advance_epoch(8);
        assert_eq!(store.unwritable_shards(), Vec::<usize>::new());
        store.put_atoms_at(9, &[(1, &[9.0][..])]).unwrap();
        assert_eq!(store.placement_of(1), Some(1));
    }

    #[test]
    fn flaky_shard_cycles_down_and_heals() {
        // period 4, down 2, 2 cycles from epoch 3: down at [3,5) and
        // [7,9), up everywhere else and after the cycles end.
        let plan = FaultPlan {
            faults: vec![ShardFault {
                shard: 0,
                at: 3,
                kind: FaultKind::Flaky { period: 4, down_for: 2, cycles: 2 },
            }],
        };
        let mut b = ChaosBackend::new(Box::new(MemStore::new()), 0, plan.for_shard(0));
        let down_epochs: Vec<usize> = (0..12)
            .filter(|&e| {
                b.advance_epoch(e);
                b.is_down()
            })
            .collect();
        assert_eq!(down_epochs, vec![3, 4, 7, 8]);
        // The store-level clock reports each transition exactly once.
        let store = plan.mem_store(2);
        let mut transitions = Vec::new();
        for e in 1..12 {
            let r = store.advance_epoch(e);
            for s in r.newly_down {
                transitions.push((e, "down", s));
            }
            for s in r.newly_healed {
                transitions.push((e, "heal", s));
            }
        }
        assert_eq!(
            transitions,
            vec![(3, "down", 0), (5, "heal", 0), (7, "down", 0), (9, "heal", 0)]
        );
    }

    #[test]
    fn fsync_fault_drops_one_fence_then_recovers() {
        let dir = std::env::temp_dir().join(format!("scar-chaos-fsync-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let plan = FaultPlan {
            faults: vec![ShardFault { shard: 0, at: 2, kind: FaultKind::FsyncFail }],
        };
        let store = plan.disk_store(&dir, 1).unwrap();
        store.put_atoms_at(1, &[(0, &[1.0][..])]).unwrap();
        store.sync_all().unwrap(); // epoch 1: before the fault, durable
        store.advance_epoch(2);
        store.put_atoms_at(2, &[(0, &[2.0][..])]).unwrap();
        store.sync_all().unwrap(); // silently dropped by the fault
        store.put_atoms_at(3, &[(0, &[3.0][..])]).unwrap();
        // In-process reads are unaffected — only a crash observes it.
        assert_eq!(store.get_atom_any(0).unwrap().unwrap().values, vec![3.0]);
        drop(store);
        let reopened = ShardedStore::open_disk(&dir, 1).unwrap();
        let got = reopened.get_atom_any(0).unwrap().unwrap();
        assert_eq!(
            (got.iter, got.values),
            (1, vec![1.0]),
            "a crash must land on the last manifest that reached the disk"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn parse_spec_grammar_round_trips() {
        let plan = FaultPlan::parse_spec(
            "kill:1@6..9, slow:0@4..9x50, torn:2@8, part:0@4..12, flaky:2@5p8d3c2, fsync:0@7, \
             bitflip:1@6, bitflip:0@3a7",
        )
        .unwrap();
        assert_eq!(
            plan.faults,
            vec![
                ShardFault { shard: 1, at: 6, kind: FaultKind::Kill { heal_at: Some(9) } },
                ShardFault {
                    shard: 0,
                    at: 4,
                    kind: FaultKind::Slow { until: Some(9), delay_us: 50 },
                },
                ShardFault { shard: 2, at: 8, kind: FaultKind::TornWrite },
                ShardFault { shard: 0, at: 4, kind: FaultKind::Partition { until: Some(12) } },
                ShardFault {
                    shard: 2,
                    at: 5,
                    kind: FaultKind::Flaky { period: 8, down_for: 3, cycles: 2 },
                },
                ShardFault { shard: 0, at: 7, kind: FaultKind::FsyncFail },
                ShardFault { shard: 1, at: 6, kind: FaultKind::Bitflip { atom: 1 } },
                ShardFault { shard: 0, at: 3, kind: FaultKind::Bitflip { atom: 7 } },
            ]
        );
        assert!(FaultPlan::parse_spec("").unwrap().is_empty());
        assert!(FaultPlan::parse_spec("bitflip:0@3afoo").is_err());
        assert!(FaultPlan::parse_spec("kill:1@forever").is_err());
        assert!(FaultPlan::parse_spec("meteor:0@3").is_err());
        assert!(FaultPlan::parse_spec("flaky:0@3").is_err(), "flaky needs p/d/c");
    }

    #[test]
    fn validation_covers_new_families() {
        // Flaky windows participate in the no-survivor check: shard 0
        // killed forever, shard 1 flaky-down overlapping → rejected.
        let no_reader = FaultPlan {
            faults: vec![
                ShardFault { shard: 0, at: 2, kind: FaultKind::Kill { heal_at: None } },
                ShardFault {
                    shard: 1,
                    at: 4,
                    kind: FaultKind::Flaky { period: 5, down_for: 2, cycles: 1 },
                },
            ],
        };
        assert!(no_reader.validate(2).is_err(), "flaky down phase leaves no reader");
        // A kill plus a partition covering the other shard leaves no
        // writable target → rejected, even though reads still work.
        let no_writer = FaultPlan {
            faults: vec![
                ShardFault { shard: 0, at: 2, kind: FaultKind::Kill { heal_at: None } },
                ShardFault { shard: 1, at: 3, kind: FaultKind::Partition { until: Some(9) } },
            ],
        };
        assert!(no_writer.validate(2).is_err(), "no writable shard at 3..9");
        // Partitions alone never violate the read-survivor rule.
        let both_partitioned = FaultPlan {
            faults: vec![
                ShardFault { shard: 0, at: 2, kind: FaultKind::Partition { until: Some(5) } },
                ShardFault { shard: 1, at: 6, kind: FaultKind::Partition { until: Some(9) } },
            ],
        };
        both_partitioned.validate(2).unwrap();
        // Degenerate flaky parameters are named errors.
        let bad_flaky = |period, down_for, cycles| FaultPlan {
            faults: vec![ShardFault {
                shard: 0,
                at: 2,
                kind: FaultKind::Flaky { period, down_for, cycles },
            }],
        };
        assert!(bad_flaky(4, 4, 1).validate(2).is_err(), "down_for must be < period");
        assert!(bad_flaky(4, 0, 1).validate(2).is_err(), "down_for must be >= 1");
        assert!(bad_flaky(4, 2, 0).validate(2).is_err(), "cycles must be >= 1");
        let bad_partition = FaultPlan {
            faults: vec![ShardFault {
                shard: 0,
                at: 5,
                kind: FaultKind::Partition { until: Some(5) },
            }],
        };
        assert!(bad_partition.validate(2).is_err(), "until must be > at");
    }

    #[test]
    fn bitflip_fires_once_at_its_epoch() {
        let faults = vec![ShardFault { shard: 0, at: 3, kind: FaultKind::Bitflip { atom: 0 } }];
        let mut b = ChaosBackend::new(Box::new(MemStore::new()), 0, faults);
        put1(&mut b, 1, 0, 1.5);
        b.advance_epoch(2);
        assert_eq!(b.bitflips(), 0, "not due yet");
        assert!(b.get_atom(0).unwrap().is_some());
        b.advance_epoch(3);
        assert_eq!(b.bitflips(), 1, "fired at its epoch");
        assert!(
            b.get_atom(0).unwrap().is_none(),
            "memory model: the corrupted record is unreadable"
        );
        // One-shot: a rewritten record is not re-corrupted.
        put1(&mut b, 4, 0, 2.5);
        b.advance_epoch(5);
        assert_eq!(b.bitflips(), 1);
        assert_eq!(b.get_atom(0).unwrap().unwrap().values, vec![2.5]);
    }

    #[test]
    fn disk_store_torn_write_drives_the_real_crc_fallback() {
        let dir = std::env::temp_dir().join(format!("scar-chaos-disk-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let plan = FaultPlan {
            faults: vec![ShardFault { shard: 0, at: 2, kind: FaultKind::TornWrite }],
        };
        let store = plan.disk_store(&dir, 1).unwrap();
        store.put_atoms_at(1, &[(0, &[1.0, 2.0][..])]).unwrap();
        // Torn: the record lands physically truncated in the segment log.
        store.put_atoms_at(3, &[(0, &[9.0, 9.0][..])]).unwrap();
        let got = store.get_atom_any(0).unwrap().unwrap();
        assert_eq!((got.iter, got.values), (1, vec![1.0, 2.0]));
        store.sync_all().unwrap();
        drop(store);
        // The manifest-tracked fallback survives a reopen of the raw
        // (unwrapped) disk shards.
        let store = ShardedStore::open_disk(&dir, 1).unwrap();
        let got = store.get_atom_any(0).unwrap().unwrap();
        assert_eq!((got.iter, got.values), (1, vec![1.0, 2.0]));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn mem_store_routes_around_a_dead_shard() {
        let plan = FaultPlan {
            faults: vec![ShardFault { shard: 1, at: 3, kind: FaultKind::Kill { heal_at: None } }],
        };
        let store = plan.mem_store(2);
        // Atom 1 homes on shard 1; before the kill it lands there.
        store.put_atoms_at(1, &[(0, &[1.0][..]), (1, &[1.0][..])]).unwrap();
        let report = store.advance_epoch(3);
        assert_eq!(report.newly_down, vec![1]);
        assert!(report.newly_healed.is_empty());
        assert_eq!(store.down_shards(), vec![1]);
        // Degraded write: atom 1 re-routes to the survivor.
        store.put_atoms_at(4, &[(1, &[4.0][..])]).unwrap();
        assert_eq!(store.degraded_records(), 1);
        // Degraded read: the dead shard is skipped, the survivor's record
        // is found.
        assert_eq!(store.get_atom_any(1).unwrap().unwrap().values, vec![4.0]);
        // Atom 0 never depended on shard 1.
        assert_eq!(store.get_atom_any(0).unwrap().unwrap().values, vec![1.0]);
    }

    #[test]
    fn parse_spec_accepts_replay() {
        let plan = FaultPlan::parse_spec("replay:1@7").unwrap();
        assert_eq!(
            plan.faults,
            vec![ShardFault { shard: 1, at: 7, kind: FaultKind::Replay }]
        );
        // Round-trips through the scenario value model.
        let json = plan.to_json();
        assert_eq!(json.get("replay").idx(0).get("shard").as_usize(), Some(1));
        assert_eq!(json.get("replay").idx(0).get("at").as_usize(), Some(7));
    }

    #[test]
    fn replay_redelivery_is_idempotent() {
        let faults = vec![ShardFault { shard: 0, at: 3, kind: FaultKind::Replay }];
        let mut b = ChaosBackend::new(Box::new(MemStore::new()), 0, faults);
        b.put_atoms(2, &[(0, &[2.0][..]), (1, &[7.0][..])]).unwrap();
        b.advance_epoch(3);
        b.sync().unwrap(); // fires: both records re-land at iter 2
        assert_eq!(b.replayed_records(), 2);
        assert_eq!(b.superseded_records(), 0);
        let got = b.get_atom(0).unwrap().unwrap();
        assert_eq!((got.iter, got.values), (2, vec![2.0]), "state is a no-op");
        // One-shot: a later fence does not re-fire.
        b.sync().unwrap();
        assert_eq!(b.replayed_records(), 2);
    }

    #[test]
    fn replay_respects_the_supersede_rule() {
        let faults = vec![ShardFault { shard: 0, at: 4, kind: FaultKind::Replay }];
        let mut b = ChaosBackend::new(Box::new(MemStore::new()), 0, faults);
        b.put_atoms(2, &[(0, &[2.0][..]), (1, &[2.0][..])]).unwrap();
        b.put_atoms(3, &[(0, &[3.0][..])]).unwrap(); // freshest pre-`at` batch wins
        b.advance_epoch(4);
        b.put_atoms(4, &[(0, &[4.0][..])]).unwrap(); // supersedes the captured record
        b.sync().unwrap();
        assert_eq!(b.superseded_records(), 1, "newer record blocks the re-delivery");
        assert_eq!(b.replayed_records(), 0);
        let got = b.get_atom(0).unwrap().unwrap();
        assert_eq!((got.iter, got.values), (4, vec![4.0]), "stale replay never regresses state");
    }

    #[test]
    fn replay_with_nothing_captured_fires_empty() {
        let faults = vec![ShardFault { shard: 0, at: 2, kind: FaultKind::Replay }];
        let mut b = ChaosBackend::new(Box::new(MemStore::new()), 0, faults);
        let rec = Recorder::enabled();
        b.set_recorder(rec.clone());
        b.advance_epoch(2);
        b.sync().unwrap();
        assert_eq!(b.replayed_records(), 0);
        // The (empty) firing is still narrated.
        let events = rec.drain();
        assert_eq!(events.len(), 1);
        assert!(matches!(
            events[0].kind,
            EventKind::Replay { shard: 0, records: 0, superseded: 0 }
        ));
    }

    #[test]
    fn recorder_narrates_faults_and_heals() {
        let faults = vec![
            ShardFault { shard: 0, at: 3, kind: FaultKind::Kill { heal_at: Some(5) } },
            ShardFault { shard: 0, at: 7, kind: FaultKind::TornWrite },
        ];
        let mut b = ChaosBackend::new(Box::new(MemStore::new()), 0, faults);
        let rec = Recorder::enabled();
        b.set_recorder(rec.clone());
        put1(&mut b, 1, 0, 1.0);
        for e in 2..7 {
            b.advance_epoch(e);
        }
        put1(&mut b, 7, 0, 7.0); // torn
        let events = rec.drain();
        let tags: Vec<(usize, &str)> = events.iter().map(|e| (e.iter, e.kind.tag())).collect();
        assert_eq!(tags, vec![(3, "fault"), (5, "heal"), (7, "fault")]);
        assert!(matches!(
            &events[0].kind,
            EventKind::Fault { fault, shard: 0 } if fault == "kill"
        ));
        assert!(matches!(
            &events[2].kind,
            EventKind::Fault { fault, shard: 0 } if fault == "torn"
        ));
    }
}
