//! Chaos: deterministic storage-fault injection for the sharded
//! checkpoint store.
//!
//! The scenario engine can kill PS *nodes*, but until this subsystem the
//! storage layer itself was never the failure domain — every shard of the
//! running checkpoint was assumed perfectly available and perfectly
//! durable. Storage faults behave qualitatively differently from clean
//! worker kills (a dead shard takes *history* with it, a slow shard
//! back-pressures the write pipeline, a torn record silently loses the
//! freshest save), so they get a first-class, reproducible model here:
//!
//! * [`FaultPlan`] — a declarative, epoch-keyed schedule of per-shard
//!   faults. No wall-clock anywhere: every fault is keyed to a training
//!   iteration, so the same plan on the same seed produces byte-identical
//!   runs whatever the thread scheduling.
//! * [`ChaosBackend`] — wraps any [`ShardBackend`] and applies the plan:
//!   - **kill** — the shard refuses reads and writes from epoch `at`
//!     until it heals (never, by default). Routing reacts in
//!     [`ShardedStore`](crate::storage::ShardedStore): writes re-route to
//!     the first surviving shard, reads skip the dead shard, and the
//!     checkpoint coordinator re-persists the running checkpoint from its
//!     in-memory cache (§4.3 keeps one precisely so the persistent copy
//!     is re-derivable) — see
//!     [`AsyncCheckpointer`](crate::checkpoint::AsyncCheckpointer).
//!   - **slow** — puts inside the window sleep `delay_us` wall-clock
//!     microseconds, so an async writer pool genuinely falls behind and
//!     the bounded queue (`storage.max_pending`) exerts back-pressure.
//!     Results stay byte-identical; only wall-clock changes.
//!   - **torn write** — the first put at/after epoch `at` is torn
//!     mid-batch: the leading half of its records land, the tail is
//!     discarded (a one-record batch loses its record), exactly what
//!     `DiskStore`'s CRC check does to a record cut short by a crash.
//!     Readers transparently see the previous record for the torn atoms.
//!
//! The epoch clock is advanced by the checkpoint front-end once per
//! training iteration (`ShardedStore::advance_epoch`), so faults take
//! effect at deterministic points of the run. Writes carry their barrier
//! iteration and are judged by it — an in-flight async write enqueued
//! before a kill still lands (it was in flight before the crash), which
//! keeps async and sync runs equivalent.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Result};

use crate::storage::{CompactionStats, MemStore, SavedAtom, ShardBackend, ShardedStore};

/// What goes wrong with one shard (see the module docs for semantics).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Shard unavailable from `at` until `heal_at` (`None` = forever).
    Kill { heal_at: Option<usize> },
    /// Puts inside `[at, until)` sleep `delay_us` microseconds each
    /// (`until = None` = for the rest of the run).
    Slow { until: Option<usize>, delay_us: u64 },
    /// The first put at/after `at` is torn mid-batch (fires once).
    TornWrite,
}

/// One scheduled fault: which shard, from which epoch, what kind.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShardFault {
    pub shard: usize,
    /// Training iteration the fault takes effect at (>= 1; epoch 0 is the
    /// x⁽⁰⁾ startup dump, which is assumed healthy).
    pub at: usize,
    pub kind: FaultKind,
}

/// A deterministic storage-fault schedule. Empty by default (no chaos).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub faults: Vec<ShardFault>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Validate against a shard count: every fault must target an
    /// existing shard at epoch >= 1, and no epoch may leave every shard
    /// down at once (degraded routing needs a survivor at all times —
    /// overlapping heal windows are checked, not just forever-kills).
    pub fn validate(&self, n_shards: usize) -> Result<()> {
        for f in &self.faults {
            if f.shard >= n_shards {
                bail!(
                    "chaos fault targets shard {}, but the store has {n_shards} shard(s)",
                    f.shard
                );
            }
            if f.at == 0 {
                bail!("chaos fault on shard {} has at = 0; epochs start at 1", f.shard);
            }
            if let FaultKind::Kill { heal_at: Some(h) } = f.kind {
                if h <= f.at {
                    bail!(
                        "chaos kill on shard {}: heal_at {h} must be > at {}",
                        f.shard,
                        f.at
                    );
                }
            }
        }
        // An "all shards down" interval can only begin at some kill's
        // `at` epoch, so checking each of those epochs is exhaustive.
        let kills: Vec<(usize, usize, Option<usize>)> = self
            .faults
            .iter()
            .filter_map(|f| match f.kind {
                FaultKind::Kill { heal_at } => Some((f.shard, f.at, heal_at)),
                _ => None,
            })
            .collect();
        for &(_, e, _) in &kills {
            let mut down = vec![false; n_shards];
            for &(s, at, heal) in &kills {
                let covers = at <= e
                    && match heal {
                        Some(h) => e < h,
                        None => true,
                    };
                if covers {
                    down[s] = true;
                }
            }
            if down.iter().all(|&d| d) {
                bail!(
                    "chaos plan takes every shard down at iteration {e}; at least one \
                     shard must be serving"
                );
            }
        }
        Ok(())
    }

    /// Faults scheduled for one shard.
    fn for_shard(&self, shard: usize) -> Vec<ShardFault> {
        self.faults.iter().copied().filter(|f| f.shard == shard).collect()
    }

    /// Wrap each backend in a [`ChaosBackend`] applying this plan.
    pub fn wrap(&self, backends: Vec<Box<dyn ShardBackend>>) -> Vec<Box<dyn ShardBackend>> {
        backends
            .into_iter()
            .enumerate()
            .map(|(s, inner)| {
                Box::new(ChaosBackend::new(inner, s, self.for_shard(s))) as Box<dyn ShardBackend>
            })
            .collect()
    }

    /// `n_shards` in-memory shards behind this plan — the store every
    /// harness-backed chaos trial uses.
    pub fn mem_store(&self, n_shards: usize) -> ShardedStore {
        let backends = (0..n_shards)
            .map(|_| Box::new(MemStore::new()) as Box<dyn ShardBackend>)
            .collect();
        ShardedStore::from_backends(self.wrap(backends))
    }

    /// `n_shards` on-disk shards under `dir/shard-NNN/` behind this plan
    /// — chaos over the durable tier. Kill/slow windows behave exactly as
    /// on memory shards; torn writes leave a *physically truncated*
    /// record in the segment log, so reads drive `DiskStore`'s real
    /// CRC/manifest fallback end to end (`rust/tests/chaos.rs` pins that
    /// results stay byte-identical to the same plan on memory shards).
    pub fn disk_store(&self, dir: &Path, n_shards: usize) -> Result<ShardedStore> {
        let backends = ShardedStore::disk_backends(dir, n_shards)?;
        Ok(ShardedStore::from_backends(self.wrap(backends)))
    }

    /// Serialize to the scenario value model (`{kill: [...], slow: [...],
    /// torn: [...]}`), the inverse of the scenario `[chaos]` parser.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut kills = Vec::new();
        let mut slows = Vec::new();
        let mut torns = Vec::new();
        for f in &self.faults {
            let mut m = BTreeMap::new();
            m.insert("shard".to_string(), Json::from(f.shard));
            m.insert("at".to_string(), Json::from(f.at));
            match f.kind {
                FaultKind::Kill { heal_at } => {
                    if let Some(h) = heal_at {
                        m.insert("heal_at".to_string(), Json::from(h));
                    }
                    kills.push(Json::Obj(m));
                }
                FaultKind::Slow { until, delay_us } => {
                    if let Some(u) = until {
                        m.insert("until".to_string(), Json::from(u));
                    }
                    m.insert("delay_us".to_string(), Json::from(delay_us as usize));
                    slows.push(Json::Obj(m));
                }
                FaultKind::TornWrite => torns.push(Json::Obj(m)),
            }
        }
        let mut obj = BTreeMap::new();
        if !kills.is_empty() {
            obj.insert("kill".to_string(), Json::Arr(kills));
        }
        if !slows.is_empty() {
            obj.insert("slow".to_string(), Json::Arr(slows));
        }
        if !torns.is_empty() {
            obj.insert("torn".to_string(), Json::Arr(torns));
        }
        crate::util::json::Json::Obj(obj)
    }
}

/// Fault-injecting wrapper around one storage shard.
pub struct ChaosBackend {
    inner: Box<dyn ShardBackend>,
    shard: usize,
    faults: Vec<ShardFault>,
    /// Fired flags for one-shot faults (parallel to `faults`).
    fired: Vec<bool>,
    /// Current epoch (highest iteration seen by the clock or a put).
    epoch: usize,
    /// Records dropped by torn writes (accounting/debugging).
    torn_records: u64,
}

impl ChaosBackend {
    pub fn new(inner: Box<dyn ShardBackend>, shard: usize, faults: Vec<ShardFault>) -> Self {
        let fired = vec![false; faults.len()];
        ChaosBackend { inner, shard, faults, fired, epoch: 0, torn_records: 0 }
    }

    pub fn torn_records(&self) -> u64 {
        self.torn_records
    }

    /// Is the shard inside a kill window at `epoch`?
    fn down_at(&self, epoch: usize) -> bool {
        self.faults.iter().any(|f| match f.kind {
            FaultKind::Kill { heal_at } => {
                f.at <= epoch
                    && match heal_at {
                        Some(h) => epoch < h,
                        None => true,
                    }
            }
            _ => false,
        })
    }

    /// Injected write delay at `epoch`, if inside a slow window.
    fn slow_at(&self, epoch: usize) -> Option<u64> {
        self.faults.iter().find_map(|f| match f.kind {
            FaultKind::Slow { until, delay_us } => {
                let inside = f.at <= epoch
                    && match until {
                        Some(u) => epoch < u,
                        None => true,
                    };
                if inside {
                    Some(delay_us)
                } else {
                    None
                }
            }
            _ => None,
        })
    }
}

impl ShardBackend for ChaosBackend {
    fn put_atoms(&mut self, iter: usize, atoms: &[(usize, &[f32])]) -> Result<()> {
        // A write is refused only when the shard is down *now* (the
        // clock) for a put issued at/after the kill (its barrier iter).
        // Two deliberate acceptances keep async and sync runs equivalent:
        // a put with a pre-kill iter lands while the shard is down (it
        // was in flight before the crash), and a put whose iter falls
        // inside a kill window the shard has since healed from lands too
        // (the write was merely delayed past the outage).
        if iter > self.epoch {
            self.epoch = iter;
        }
        if self.down_at(self.epoch) && self.down_at(iter) {
            bail!("shard {} is down (injected kill)", self.shard);
        }
        if let Some(delay_us) = self.slow_at(iter) {
            if delay_us > 0 {
                std::thread::sleep(std::time::Duration::from_micros(delay_us));
            }
        }
        for i in 0..self.faults.len() {
            if self.fired[i] || !matches!(self.faults[i].kind, FaultKind::TornWrite) {
                continue;
            }
            if iter >= self.faults[i].at {
                self.fired[i] = true;
                // Tear mid-batch: the leading half lands, the tail is the
                // in-flight record a crash cut short. Floor division so a
                // one-record batch loses its record — a torn write always
                // tears *something*. The backend decides what a tear
                // physically is: memory backends drop the tail outright,
                // DiskStore appends a truncated record so reads exercise
                // its real CRC/manifest fallback.
                let keep = atoms.len() / 2;
                self.torn_records += (atoms.len() - keep) as u64;
                return self.inner.put_torn(iter, atoms, keep);
            }
        }
        self.inner.put_atoms(iter, atoms)
    }

    fn get_atom(&self, atom: usize) -> Result<Option<SavedAtom>> {
        if self.down_at(self.epoch) {
            bail!("shard {} is down (injected kill)", self.shard);
        }
        self.inner.get_atom(atom)
    }

    fn bytes_written(&self) -> u64 {
        self.inner.bytes_written()
    }

    fn records_written(&self) -> u64 {
        self.inner.records_written()
    }

    fn sync(&mut self) -> Result<()> {
        if self.down_at(self.epoch) {
            bail!("shard {} is down (injected kill)", self.shard);
        }
        self.inner.sync()
    }

    fn advance_epoch(&mut self, iter: usize) {
        if iter > self.epoch {
            self.epoch = iter;
        }
        self.inner.advance_epoch(iter);
    }

    fn is_down(&self) -> bool {
        self.down_at(self.epoch)
    }

    fn put_torn(&mut self, iter: usize, atoms: &[(usize, &[f32])], keep: usize) -> Result<()> {
        self.inner.put_torn(iter, atoms, keep)
    }

    fn garbage_ratio(&self) -> f64 {
        self.inner.garbage_ratio()
    }

    fn on_disk_bytes(&self) -> u64 {
        self.inner.on_disk_bytes()
    }

    fn compact(&mut self) -> Result<Option<CompactionStats>> {
        if self.down_at(self.epoch) {
            bail!("shard {} is down (injected kill)", self.shard);
        }
        self.inner.compact()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn put1(store: &mut dyn ShardBackend, iter: usize, atom: usize, val: f32) {
        store.put_atoms(iter, &[(atom, &[val][..])]).unwrap();
    }

    #[test]
    fn kill_window_blocks_and_heals() {
        let plan = FaultPlan {
            faults: vec![ShardFault {
                shard: 0,
                at: 5,
                kind: FaultKind::Kill { heal_at: Some(9) },
            }],
        };
        let mut b = ChaosBackend::new(Box::new(MemStore::new()), 0, plan.for_shard(0));
        put1(&mut b, 2, 0, 1.0);
        assert!(!b.is_down());
        b.advance_epoch(5);
        assert!(b.is_down());
        assert!(b.get_atom(0).is_err());
        assert!(b.put_atoms(6, &[(0, &[2.0][..])]).is_err());
        // In-flight write from before the kill still lands.
        put1(&mut b, 4, 1, 3.0);
        b.advance_epoch(9);
        assert!(!b.is_down());
        assert_eq!(b.get_atom(0).unwrap().unwrap().values, vec![1.0]);
        assert_eq!(b.get_atom(1).unwrap().unwrap().values, vec![3.0]);
    }

    #[test]
    fn torn_write_drops_the_tail_once() {
        let plan = FaultPlan {
            faults: vec![ShardFault { shard: 0, at: 3, kind: FaultKind::TornWrite }],
        };
        let mut b = ChaosBackend::new(Box::new(MemStore::new()), 0, plan.for_shard(0));
        b.put_atoms(1, &[(0, &[1.0][..]), (1, &[1.0][..])]).unwrap();
        // Torn put: atom 0 lands (prefix), atom 1's record is lost.
        b.put_atoms(4, &[(0, &[9.0][..]), (1, &[9.0][..])]).unwrap();
        assert_eq!(b.torn_records(), 1);
        assert_eq!(b.get_atom(0).unwrap().unwrap().iter, 4);
        assert_eq!(b.get_atom(1).unwrap().unwrap().iter, 1, "tail keeps the old record");
        // Fires once; the next put is whole.
        b.put_atoms(6, &[(0, &[5.0][..]), (1, &[5.0][..])]).unwrap();
        assert_eq!(b.get_atom(1).unwrap().unwrap().iter, 6);
    }

    #[test]
    fn torn_write_tears_a_single_record_batch_entirely() {
        let plan = FaultPlan {
            faults: vec![ShardFault { shard: 0, at: 2, kind: FaultKind::TornWrite }],
        };
        let mut b = ChaosBackend::new(Box::new(MemStore::new()), 0, plan.for_shard(0));
        put1(&mut b, 1, 0, 1.0);
        // A one-record put still tears: the record is lost, not kept.
        put1(&mut b, 3, 0, 9.0);
        assert_eq!(b.torn_records(), 1);
        assert_eq!(b.get_atom(0).unwrap().unwrap().iter, 1);
    }

    #[test]
    fn slow_window_only_delays() {
        let plan = FaultPlan {
            faults: vec![ShardFault {
                shard: 0,
                at: 1,
                kind: FaultKind::Slow { until: Some(3), delay_us: 1 },
            }],
        };
        let mut b = ChaosBackend::new(Box::new(MemStore::new()), 0, plan.for_shard(0));
        put1(&mut b, 1, 0, 1.0);
        put1(&mut b, 5, 0, 2.0);
        assert_eq!(b.get_atom(0).unwrap().unwrap().values, vec![2.0]);
        assert!(!b.is_down());
    }

    #[test]
    fn plan_validation() {
        let ok = FaultPlan {
            faults: vec![ShardFault { shard: 1, at: 4, kind: FaultKind::Kill { heal_at: None } }],
        };
        ok.validate(2).unwrap();
        assert!(ok.validate(1).is_err(), "shard out of range");
        let zero = FaultPlan {
            faults: vec![ShardFault { shard: 0, at: 0, kind: FaultKind::TornWrite }],
        };
        assert!(zero.validate(1).is_err(), "epoch 0 rejected");
        let all_dead = FaultPlan {
            faults: vec![
                ShardFault { shard: 0, at: 2, kind: FaultKind::Kill { heal_at: None } },
                ShardFault { shard: 1, at: 3, kind: FaultKind::Kill { heal_at: None } },
            ],
        };
        assert!(all_dead.validate(2).is_err(), "needs a survivor");
        let bad_heal = FaultPlan {
            faults: vec![ShardFault {
                shard: 0,
                at: 5,
                kind: FaultKind::Kill { heal_at: Some(5) },
            }],
        };
        assert!(bad_heal.validate(2).is_err(), "heal_at must be after at");
        // Overlapping *temporary* kill windows that leave no survivor are
        // rejected too, not just forever-kills.
        let overlap = FaultPlan {
            faults: vec![
                ShardFault { shard: 0, at: 2, kind: FaultKind::Kill { heal_at: Some(20) } },
                ShardFault { shard: 1, at: 3, kind: FaultKind::Kill { heal_at: Some(10) } },
            ],
        };
        assert!(overlap.validate(2).is_err(), "iterations 3..10 have no serving shard");
        // Disjoint windows are fine: some shard serves at every epoch.
        let disjoint = FaultPlan {
            faults: vec![
                ShardFault { shard: 0, at: 2, kind: FaultKind::Kill { heal_at: Some(5) } },
                ShardFault { shard: 1, at: 6, kind: FaultKind::Kill { heal_at: Some(9) } },
            ],
        };
        disjoint.validate(2).unwrap();
    }

    #[test]
    fn disk_store_torn_write_drives_the_real_crc_fallback() {
        let dir = std::env::temp_dir().join(format!("scar-chaos-disk-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let plan = FaultPlan {
            faults: vec![ShardFault { shard: 0, at: 2, kind: FaultKind::TornWrite }],
        };
        let store = plan.disk_store(&dir, 1).unwrap();
        store.put_atoms_at(1, &[(0, &[1.0, 2.0][..])]).unwrap();
        // Torn: the record lands physically truncated in the segment log.
        store.put_atoms_at(3, &[(0, &[9.0, 9.0][..])]).unwrap();
        let got = store.get_atom_any(0).unwrap().unwrap();
        assert_eq!((got.iter, got.values), (1, vec![1.0, 2.0]));
        store.sync_all().unwrap();
        drop(store);
        // The manifest-tracked fallback survives a reopen of the raw
        // (unwrapped) disk shards.
        let store = ShardedStore::open_disk(&dir, 1).unwrap();
        let got = store.get_atom_any(0).unwrap().unwrap();
        assert_eq!((got.iter, got.values), (1, vec![1.0, 2.0]));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn mem_store_routes_around_a_dead_shard() {
        let plan = FaultPlan {
            faults: vec![ShardFault { shard: 1, at: 3, kind: FaultKind::Kill { heal_at: None } }],
        };
        let store = plan.mem_store(2);
        // Atom 1 homes on shard 1; before the kill it lands there.
        store.put_atoms_at(1, &[(0, &[1.0][..]), (1, &[1.0][..])]).unwrap();
        let newly = store.advance_epoch(3);
        assert_eq!(newly, vec![1]);
        assert_eq!(store.down_shards(), vec![1]);
        // Degraded write: atom 1 re-routes to the survivor.
        store.put_atoms_at(4, &[(1, &[4.0][..])]).unwrap();
        assert_eq!(store.degraded_records(), 1);
        // Degraded read: the dead shard is skipped, the survivor's record
        // is found.
        assert_eq!(store.get_atom_any(1).unwrap().unwrap().values, vec![4.0]);
        // Atom 0 never depended on shard 1.
        assert_eq!(store.get_atom_any(0).unwrap().unwrap().values, vec![1.0]);
    }
}
