//! Observability: a deterministic flight recorder and a metrics registry.
//!
//! The recorder is iteration-clocked, never wall-clocked: every event
//! carries the training iteration it belongs to, and `drain()` merges
//! whatever the producing threads pushed into one canonical order — sort
//! by `(iter, serialized form)` — so two runs of the same seed produce
//! byte-identical traces regardless of thread scheduling. A disabled
//! recorder is a no-op handle (one `Option` check per call, no
//! allocation, no lock), which is what keeps the byte-identity and bench
//! contracts intact when tracing is off.
//!
//! The registry replaces hand-threaded counter plumbing: subsystems
//! register `Counter`/`Gauge` handles by name and a `snapshot()` at trial
//! end produces the `name -> value` map that `TrialResult`, cell sums,
//! and `--json` output derive from.
//!
//! Traces export as JSONL (one event object per line, sorted keys) and as
//! Chrome `trace_event` JSON (`chrome://tracing` / Perfetto); `scar trace`
//! loads the JSONL form and renders a per-shard timeline ([`timeline`]).

use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};
use crate::util::json::Json;

pub mod timeline;

// ---------------------------------------------------------------------------
// Events
// ---------------------------------------------------------------------------

/// One recorded event, keyed by the training iteration it happened at.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub iter: usize,
    pub kind: EventKind,
}

/// The event taxonomy. Mirrors the fault taxonomy plus the checkpoint,
/// recovery, and training signals the cost model prices.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// A checkpoint barrier: atoms/bytes that hit the store after the
    /// delta-skip filter, and what the filter dropped.
    Barrier { atoms: usize, bytes: u64, skipped_atoms: u64, skipped_bytes: u64 },
    /// A flush fence committed the watermark.
    Flush { watermark: usize },
    /// Parity-fence scrub phase: stripes examined, records repaired.
    Scrub { stripes: u64, repaired: u64 },
    /// Parity-fence re-encode phase.
    Reencode { stripes: u64 },
    /// A chaos fault fired (one-shots) or its window opened (kill/slow/
    /// partition/flaky phases).
    Fault { fault: String, shard: usize },
    /// A windowed chaos fault's window closed: the shard is back.
    Heal { shard: usize },
    /// A replay fault re-delivered a captured put batch; `superseded`
    /// records were dropped by the iteration-supersede rule.
    Replay { shard: usize, records: u64, superseded: u64 },
    /// A rebuild plan executed (cache re-persist, heal re-adoption,
    /// parity reconstruction).
    Rebuild { source: String, atoms: usize, bytes: u64, workers: usize },
    /// An async barrier blocked on `max_pending` back-pressure.
    Stall { pending: usize },
    /// Cluster: a PS node was killed.
    NodeKill { node: usize },
    /// Cluster: dead nodes recovered from shared storage, re-introducing
    /// a perturbation of norm `delta_norm` (the Thm 3.2 input).
    NodeRecover { nodes: usize, atoms: usize, delta_norm: f64 },
    /// Per-iteration training progress: loss and ‖xₜ − xₜ₋₁‖ (the update
    /// norm bounding the slow-mode amplitude in the Thm 3.2 terms).
    Progress { loss: f64, update_norm: f64 },
    /// The adaptive policy controller applied a new checkpoint policy at
    /// a fence point: grid index k (fraction 1/k), the new interval, and
    /// the new sync/async mode.
    PolicySwitch { k: usize, interval: usize, mode: String },
    /// A segment-compaction pass ran on a shard: the generation its
    /// outputs were stamped with (0 = monolithic full pass), segments
    /// folded, and segment bytes reclaimed.
    Compaction { shard: usize, generation: u64, segments: u64, reclaimed: u64 },
}

impl EventKind {
    /// Stable tag used in JSONL, Chrome trace names, and tables.
    pub fn tag(&self) -> &'static str {
        match self {
            EventKind::Barrier { .. } => "barrier",
            EventKind::Flush { .. } => "flush",
            EventKind::Scrub { .. } => "scrub",
            EventKind::Reencode { .. } => "reencode",
            EventKind::Fault { .. } => "fault",
            EventKind::Heal { .. } => "heal",
            EventKind::Replay { .. } => "replay",
            EventKind::Rebuild { .. } => "rebuild",
            EventKind::Stall { .. } => "stall",
            EventKind::NodeKill { .. } => "node_kill",
            EventKind::NodeRecover { .. } => "node_recover",
            EventKind::Progress { .. } => "progress",
            EventKind::PolicySwitch { .. } => "policy_switch",
            EventKind::Compaction { .. } => "compaction",
        }
    }

    /// The shard this event is about, if it is shard-scoped.
    pub fn shard(&self) -> Option<usize> {
        match self {
            EventKind::Fault { shard, .. }
            | EventKind::Heal { shard }
            | EventKind::Replay { shard, .. }
            | EventKind::Compaction { shard, .. } => Some(*shard),
            _ => None,
        }
    }

    /// Payload fields (everything but `iter` and the tag).
    fn args(&self) -> BTreeMap<String, Json> {
        fn num(m: &mut BTreeMap<String, Json>, k: &str, v: f64) {
            m.insert(k.to_string(), Json::Num(v));
        }
        let mut m = BTreeMap::new();
        match self {
            EventKind::Barrier { atoms, bytes, skipped_atoms, skipped_bytes } => {
                num(&mut m, "atoms", *atoms as f64);
                num(&mut m, "bytes", *bytes as f64);
                num(&mut m, "skipped_atoms", *skipped_atoms as f64);
                num(&mut m, "skipped_bytes", *skipped_bytes as f64);
            }
            EventKind::Flush { watermark } => num(&mut m, "watermark", *watermark as f64),
            EventKind::Scrub { stripes, repaired } => {
                num(&mut m, "stripes", *stripes as f64);
                num(&mut m, "repaired", *repaired as f64);
            }
            EventKind::Reencode { stripes } => num(&mut m, "stripes", *stripes as f64),
            EventKind::Fault { fault, shard } => {
                m.insert("fault".to_string(), Json::from(fault.as_str()));
                num(&mut m, "shard", *shard as f64);
            }
            EventKind::Heal { shard } => num(&mut m, "shard", *shard as f64),
            EventKind::Replay { shard, records, superseded } => {
                num(&mut m, "shard", *shard as f64);
                num(&mut m, "records", *records as f64);
                num(&mut m, "superseded", *superseded as f64);
            }
            EventKind::Rebuild { source, atoms, bytes, workers } => {
                m.insert("source".to_string(), Json::from(source.as_str()));
                num(&mut m, "atoms", *atoms as f64);
                num(&mut m, "bytes", *bytes as f64);
                num(&mut m, "workers", *workers as f64);
            }
            EventKind::Stall { pending } => num(&mut m, "pending", *pending as f64),
            EventKind::NodeKill { node } => num(&mut m, "node", *node as f64),
            EventKind::NodeRecover { nodes, atoms, delta_norm } => {
                num(&mut m, "nodes", *nodes as f64);
                num(&mut m, "atoms", *atoms as f64);
                num(&mut m, "delta_norm", *delta_norm);
            }
            EventKind::Progress { loss, update_norm } => {
                num(&mut m, "loss", *loss);
                num(&mut m, "update_norm", *update_norm);
            }
            EventKind::PolicySwitch { k, interval, mode } => {
                num(&mut m, "k", *k as f64);
                num(&mut m, "interval", *interval as f64);
                m.insert("mode".to_string(), Json::from(mode.as_str()));
            }
            EventKind::Compaction { shard, generation, segments, reclaimed } => {
                num(&mut m, "shard", *shard as f64);
                num(&mut m, "generation", *generation as f64);
                num(&mut m, "segments", *segments as f64);
                num(&mut m, "reclaimed", *reclaimed as f64);
            }
        }
        m
    }
}

impl Event {
    pub fn to_json(&self) -> Json {
        let mut m = self.kind.args();
        m.insert("iter".to_string(), Json::from(self.iter));
        m.insert("event".to_string(), Json::from(self.kind.tag()));
        Json::Obj(m)
    }

    pub fn from_json(v: &Json) -> Result<Event> {
        fn us(v: &Json, key: &str) -> Result<usize> {
            v.get(key)
                .as_usize()
                .ok_or_else(|| anyhow!("trace event missing numeric field '{key}'"))
        }
        fn u(v: &Json, key: &str) -> Result<u64> {
            Ok(us(v, key)? as u64)
        }
        fn f(v: &Json, key: &str) -> Result<f64> {
            v.get(key)
                .as_f64()
                .ok_or_else(|| anyhow!("trace event missing numeric field '{key}'"))
        }
        fn s(v: &Json, key: &str) -> Result<String> {
            Ok(v.get(key)
                .as_str()
                .ok_or_else(|| anyhow!("trace event missing string field '{key}'"))?
                .to_string())
        }
        let iter = us(v, "iter")?;
        let tag = s(v, "event")?;
        let kind = match tag.as_str() {
            "barrier" => EventKind::Barrier {
                atoms: us(v, "atoms")?,
                bytes: u(v, "bytes")?,
                skipped_atoms: u(v, "skipped_atoms")?,
                skipped_bytes: u(v, "skipped_bytes")?,
            },
            "flush" => EventKind::Flush { watermark: us(v, "watermark")? },
            "scrub" => EventKind::Scrub { stripes: u(v, "stripes")?, repaired: u(v, "repaired")? },
            "reencode" => EventKind::Reencode { stripes: u(v, "stripes")? },
            "fault" => EventKind::Fault { fault: s(v, "fault")?, shard: us(v, "shard")? },
            "heal" => EventKind::Heal { shard: us(v, "shard")? },
            "replay" => EventKind::Replay {
                shard: us(v, "shard")?,
                records: u(v, "records")?,
                superseded: u(v, "superseded")?,
            },
            "rebuild" => EventKind::Rebuild {
                source: s(v, "source")?,
                atoms: us(v, "atoms")?,
                bytes: u(v, "bytes")?,
                workers: us(v, "workers")?,
            },
            "stall" => EventKind::Stall { pending: us(v, "pending")? },
            "node_kill" => EventKind::NodeKill { node: us(v, "node")? },
            "node_recover" => EventKind::NodeRecover {
                nodes: us(v, "nodes")?,
                atoms: us(v, "atoms")?,
                delta_norm: f(v, "delta_norm")?,
            },
            "progress" => {
                EventKind::Progress { loss: f(v, "loss")?, update_norm: f(v, "update_norm")? }
            }
            "policy_switch" => EventKind::PolicySwitch {
                k: us(v, "k")?,
                interval: us(v, "interval")?,
                mode: s(v, "mode")?,
            },
            "compaction" => EventKind::Compaction {
                shard: us(v, "shard")?,
                generation: u(v, "generation")?,
                segments: u(v, "segments")?,
                reclaimed: u(v, "reclaimed")?,
            },
            other => bail!("unknown trace event kind '{other}'"),
        };
        Ok(Event { iter, kind })
    }
}

// ---------------------------------------------------------------------------
// Recorder
// ---------------------------------------------------------------------------

/// A cheap, cloneable handle to a trial's event sink.
///
/// `Recorder::disabled()` is the default everywhere: `record()` on it is
/// one `Option` check — no lock, no allocation — so tracing-off runs pay
/// nothing (pinned by `rust/tests/obs.rs` byte-identity and the bench
/// counters). An enabled recorder shares one `Mutex<Vec<Event>>` across
/// all clones; writer-pool threads may push concurrently because
/// `drain()` re-sorts into a canonical order anyway.
#[derive(Clone, Default)]
pub struct Recorder {
    core: Option<Arc<Mutex<Vec<Event>>>>,
}

impl fmt::Debug for Recorder {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Recorder {{ enabled: {} }}", self.is_enabled())
    }
}

impl Recorder {
    /// The no-op sink: records nothing, costs one branch per call.
    pub fn disabled() -> Recorder {
        Recorder { core: None }
    }

    pub fn enabled() -> Recorder {
        Recorder { core: Some(Arc::new(Mutex::new(Vec::new()))) }
    }

    pub fn is_enabled(&self) -> bool {
        self.core.is_some()
    }

    pub fn record(&self, iter: usize, kind: EventKind) {
        if let Some(core) = &self.core {
            core.lock().unwrap().push(Event { iter, kind });
        }
    }

    /// Take all recorded events in canonical order: sorted by
    /// `(iter, serialized event)`. The serialized tiebreak makes the
    /// merge independent of which thread pushed first, so same-seed
    /// traces are byte-identical.
    pub fn drain(&self) -> Vec<Event> {
        let Some(core) = &self.core else {
            return Vec::new();
        };
        let mut events = std::mem::take(&mut *core.lock().unwrap());
        events.sort_by_cached_key(|e| (e.iter, e.to_json().to_string()));
        events
    }
}

// ---------------------------------------------------------------------------
// Trace serialization
// ---------------------------------------------------------------------------

/// One event object per line, keys sorted — the `scar trace` input format.
pub fn to_jsonl(events: &[Event]) -> String {
    let mut out = String::new();
    for e in events {
        out.push_str(&e.to_json().to_string());
        out.push('\n');
    }
    out
}

pub fn parse_jsonl(s: &str) -> Result<Vec<Event>> {
    let mut events = Vec::new();
    for (lineno, line) in s.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let v = Json::parse(line)
            .map_err(|e| anyhow!("trace line {}: {}", lineno + 1, e))?;
        events.push(Event::from_json(&v).map_err(|e| anyhow!("trace line {}: {}", lineno + 1, e))?);
    }
    Ok(events)
}

/// Chrome `trace_event` JSON (open in `chrome://tracing` or Perfetto).
/// Iterations map to microsecond timestamps; shard-scoped events get one
/// `tid` lane per shard, global lanes hold training (0), checkpoint (1),
/// and cluster (2) events.
pub fn to_chrome_trace(events: &[Event]) -> String {
    let mut arr = Vec::with_capacity(events.len());
    for e in events {
        let tid = match &e.kind {
            EventKind::Progress { .. } => 0,
            EventKind::NodeKill { .. } | EventKind::NodeRecover { .. } => 2,
            k => match k.shard() {
                Some(s) => 3 + s,
                None => 1,
            },
        };
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::from(e.kind.tag()));
        m.insert("ph".to_string(), Json::from("i"));
        m.insert("s".to_string(), Json::from("t"));
        m.insert("ts".to_string(), Json::from(e.iter));
        m.insert("pid".to_string(), Json::from(0usize));
        m.insert("tid".to_string(), Json::from(tid));
        m.insert("args".to_string(), Json::Obj(e.kind.args()));
        arr.push(Json::Obj(m));
    }
    let mut top = BTreeMap::new();
    top.insert("traceEvents".to_string(), Json::Arr(arr));
    top.insert("displayTimeUnit".to_string(), Json::from("ms"));
    Json::Obj(top).to_string()
}

// ---------------------------------------------------------------------------
// Metrics registry
// ---------------------------------------------------------------------------

/// A named-metric registry: `Counter`/`Gauge` handles are registered (or
/// re-fetched) by name, and `snapshot()` yields the `name -> value` map
/// that reports and `--json` output derive from. Cloning shares the
/// underlying metrics.
#[derive(Clone, Default)]
pub struct Registry {
    counters: Arc<Mutex<BTreeMap<String, Arc<AtomicU64>>>>,
    gauges: Arc<Mutex<BTreeMap<String, Arc<Mutex<f64>>>>>,
}

impl fmt::Debug for Registry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Registry {{ metrics: {} }}", self.snapshot().len())
    }
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get-or-create the counter `name`; all handles for one name share
    /// the same underlying value.
    pub fn counter(&self, name: &str) -> Counter {
        let mut m = self.counters.lock().unwrap();
        Counter(m.entry(name.to_string()).or_default().clone())
    }

    /// Get-or-create the gauge `name`.
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut m = self.gauges.lock().unwrap();
        Gauge(m.entry(name.to_string()).or_default().clone())
    }

    /// All metrics by name. Counters and gauges share one namespace in
    /// the snapshot; a gauge wins on a (never intended) name collision.
    pub fn snapshot(&self) -> BTreeMap<String, f64> {
        let mut out = BTreeMap::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.insert(k.clone(), v.load(Ordering::Relaxed) as f64);
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            out.insert(k.clone(), *v.lock().unwrap());
        }
        out
    }
}

/// A monotonically increasing u64 metric.
#[derive(Clone, Debug)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    /// Overwrite — for deriving a registry entry from an existing
    /// subsystem counter at snapshot time.
    pub fn set(&self, n: u64) {
        self.0.store(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-value-wins f64 metric.
#[derive(Clone, Debug, Default)]
pub struct Gauge(Arc<Mutex<f64>>);

impl Gauge {
    pub fn set(&self, v: f64) {
        *self.0.lock().unwrap() = v;
    }
    pub fn get(&self) -> f64 {
        *self.0.lock().unwrap()
    }
}

/// The canonical per-trial counters every report carries (zero-valued
/// when a path never ran, so metric maps always share one key set and
/// the nightly trend CSV keeps a stable column list).
pub const STANDARD_COUNTERS: &[&str] = &[
    "rebuilt_atoms",
    "rebuilt_bytes",
    "compaction_runs",
    "compaction_reclaimed_bytes",
    "repaired_records",
    "repaired_bytes",
    "skipped_atoms",
    "skipped_bytes",
    "backpressure_stalls",
    "degraded_records",
    "policy_switches",
    "interval_chosen",
    "fence_fsyncs",
    "segments_compacted",
    "compact_pass_bytes",
];

/// Standard gauges that join the counters in every snapshot (same
/// stable-column rationale; gauges because they carry fractional,
/// last-value-wins quantities).
pub const STANDARD_GAUGES: &[&str] = &["policy_regret", "fsyncs_per_fence", "fence_wall_ms"];

/// A registry with every standard counter and gauge pre-registered at
/// zero.
pub fn standard_registry() -> Registry {
    let r = Registry::new();
    for name in STANDARD_COUNTERS {
        let _ = r.counter(name);
    }
    for name in STANDARD_GAUGES {
        let _ = r.gauge(name);
    }
    r
}

/// Sum `src` into `acc` key-wise (cell and scenario aggregation).
pub fn merge_metrics(acc: &mut BTreeMap<String, f64>, src: &BTreeMap<String, f64>) {
    for (k, v) in src {
        *acc.entry(k.clone()).or_insert(0.0) += v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_records_nothing() {
        let rec = Recorder::disabled();
        assert!(!rec.is_enabled());
        rec.record(3, EventKind::Heal { shard: 1 });
        assert!(rec.drain().is_empty());
    }

    #[test]
    fn drain_order_is_canonical() {
        // Push the same events in two different orders; drains must match.
        let a = Recorder::enabled();
        a.record(5, EventKind::Heal { shard: 0 });
        a.record(5, EventKind::Fault { fault: "kill".into(), shard: 2 });
        a.record(2, EventKind::Stall { pending: 4 });

        let b = Recorder::enabled();
        b.record(2, EventKind::Stall { pending: 4 });
        b.record(5, EventKind::Fault { fault: "kill".into(), shard: 2 });
        b.record(5, EventKind::Heal { shard: 0 });

        let ea = a.drain();
        assert_eq!(ea, b.drain());
        assert_eq!(ea[0].iter, 2);
        assert!(a.drain().is_empty(), "drain consumes");
    }

    #[test]
    fn clones_share_the_sink() {
        let rec = Recorder::enabled();
        let clone = rec.clone();
        clone.record(1, EventKind::Flush { watermark: 1 });
        assert_eq!(rec.drain().len(), 1);
    }

    #[test]
    fn jsonl_roundtrip() {
        let events = vec![
            Event {
                iter: 4,
                kind: EventKind::Barrier { atoms: 3, bytes: 96, skipped_atoms: 1, skipped_bytes: 32 },
            },
            Event { iter: 6, kind: EventKind::Fault { fault: "torn".into(), shard: 2 } },
            Event { iter: 7, kind: EventKind::Replay { shard: 1, records: 5, superseded: 3 } },
            Event {
                iter: 8,
                kind: EventKind::Rebuild { source: "cache".into(), atoms: 12, bytes: 384, workers: 2 },
            },
            Event { iter: 9, kind: EventKind::NodeRecover { nodes: 1, atoms: 10, delta_norm: 0.25 } },
            Event { iter: 9, kind: EventKind::Progress { loss: 0.5, update_norm: 0.01 } },
            Event {
                iter: 16,
                kind: EventKind::PolicySwitch { k: 4, interval: 2, mode: "sync".into() },
            },
            Event {
                iter: 20,
                kind: EventKind::Compaction { shard: 2, generation: 3, segments: 4, reclaimed: 512 },
            },
        ];
        let text = to_jsonl(&events);
        assert_eq!(parse_jsonl(&text).unwrap(), events);
    }

    #[test]
    fn parse_jsonl_rejects_garbage() {
        assert!(parse_jsonl("{\"event\":\"nope\",\"iter\":1}").is_err());
        assert!(parse_jsonl("not json").is_err());
    }

    #[test]
    fn chrome_trace_is_valid_json() {
        let events =
            vec![Event { iter: 3, kind: EventKind::Fault { fault: "kill".into(), shard: 1 } }];
        let parsed = Json::parse(&to_chrome_trace(&events)).unwrap();
        let arr = parsed.get("traceEvents").as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("name").as_str(), Some("fault"));
        assert_eq!(arr[0].get("tid").as_usize(), Some(4)); // shard 1 lane
    }

    #[test]
    fn registry_counters_and_gauges() {
        let reg = Registry::new();
        let c = reg.counter("rebuilt_bytes");
        c.add(10);
        reg.counter("rebuilt_bytes").add(5); // same underlying counter
        reg.gauge("delta_norm").set(1.5);
        let snap = reg.snapshot();
        assert_eq!(snap["rebuilt_bytes"], 15.0);
        assert_eq!(snap["delta_norm"], 1.5);
    }

    #[test]
    fn standard_registry_has_all_keys_at_zero() {
        let snap = standard_registry().snapshot();
        assert_eq!(snap.len(), STANDARD_COUNTERS.len() + STANDARD_GAUGES.len());
        assert!(snap.values().all(|v| *v == 0.0));
        assert!(snap.contains_key("policy_switches"));
        assert!(snap.contains_key("policy_regret"));
        assert!(snap.contains_key("fence_fsyncs"));
        assert!(snap.contains_key("fsyncs_per_fence"));
        assert!(snap.contains_key("fence_wall_ms"));
    }

    #[test]
    fn merge_metrics_sums_keywise() {
        let mut acc = BTreeMap::new();
        let mut src = BTreeMap::new();
        src.insert("a".to_string(), 2.0);
        merge_metrics(&mut acc, &src);
        merge_metrics(&mut acc, &src);
        assert_eq!(acc["a"], 4.0);
    }
}
