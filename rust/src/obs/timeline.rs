//! `scar trace` rendering: a per-shard SVG timeline plus a fault →
//! recovery-latency summary table.
//!
//! The timeline is the per-event view of what `BENCH_7.json` and the
//! scenario metrics only show in aggregate: one horizontal lane per
//! shard (plus `train`, `checkpoint`, and — when present — `cluster`
//! lanes), iterations running left to right, every recorded event drawn
//! as a colored marker at the iteration it fired. Windowed faults
//! (kill/flaky/partition) are drawn as translucent spans from the fault
//! to its heal; the training lane carries the loss curve when the trace
//! holds `progress` events.

use std::collections::{BTreeMap, BTreeSet};

use crate::util::trend::{xml_escape, PALETTE};

use super::{Event, EventKind};

const WIDTH: f64 = 960.0;
const LEFT: f64 = 110.0;
const RIGHT_PAD: f64 = 170.0;
const TOP: f64 = 34.0;
const LANE_H: f64 = 34.0;
const BOTTOM: f64 = 46.0;

/// A lane on the timeline, in display order.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
enum Lane {
    Train,
    Checkpoint,
    Cluster,
    Shard(usize),
}

impl Lane {
    fn label(&self) -> String {
        match self {
            Lane::Train => "train".to_string(),
            Lane::Checkpoint => "checkpoint".to_string(),
            Lane::Cluster => "cluster".to_string(),
            Lane::Shard(s) => format!("shard {s}"),
        }
    }
}

fn lane_of(kind: &EventKind) -> Lane {
    match kind {
        EventKind::Progress { .. } => Lane::Train,
        EventKind::NodeKill { .. } | EventKind::NodeRecover { .. } => Lane::Cluster,
        k => match k.shard() {
            Some(s) => Lane::Shard(s),
            None => Lane::Checkpoint,
        },
    }
}

/// Tags in first-appearance order with a palette color each (legend order
/// is deterministic because the input events are in canonical order).
fn tag_colors(events: &[Event]) -> Vec<(&'static str, &'static str)> {
    let mut tags: Vec<&'static str> = Vec::new();
    for e in events {
        let t = e.kind.tag();
        if !tags.contains(&t) {
            tags.push(t);
        }
    }
    tags.into_iter().enumerate().map(|(i, t)| (t, PALETTE[i % PALETTE.len()])).collect()
}

/// Render the trace as a self-contained SVG timeline. Always succeeds;
/// an empty trace renders an empty (but valid) canvas.
pub fn render_timeline(events: &[Event]) -> String {
    let max_iter = events.iter().map(|e| e.iter).max().unwrap_or(0).max(1);

    // Lane set: train and checkpoint always exist; cluster and shard
    // lanes only when the trace mentions them.
    let mut lanes: BTreeSet<Lane> = BTreeSet::new();
    lanes.insert(Lane::Train);
    lanes.insert(Lane::Checkpoint);
    for e in events {
        lanes.insert(lane_of(&e.kind));
    }
    let lanes: Vec<Lane> = lanes.into_iter().collect();
    let lane_index: BTreeMap<Lane, usize> =
        lanes.iter().cloned().enumerate().map(|(i, l)| (l, i)).collect();

    let plot_w = WIDTH - LEFT - RIGHT_PAD;
    let height = TOP + lanes.len() as f64 * LANE_H + BOTTOM;
    let x = |iter: usize| LEFT + iter as f64 / max_iter as f64 * plot_w;
    let lane_top = |i: usize| TOP + i as f64 * LANE_H;

    let colors = tag_colors(events);
    let color = |tag: &str| {
        colors.iter().find(|(t, _)| *t == tag).map(|(_, c)| *c).unwrap_or("#000000")
    };

    let mut svg = String::new();
    svg.push_str(&format!(
        "<svg xmlns=\"http://www.w3.org/2000/svg\" width=\"{WIDTH}\" height=\"{height}\" \
         viewBox=\"0 0 {WIDTH} {height}\" font-family=\"monospace\" font-size=\"11\">\n"
    ));
    svg.push_str("<rect width=\"100%\" height=\"100%\" fill=\"white\"/>\n");
    svg.push_str(&format!(
        "<text x=\"{LEFT}\" y=\"18\" font-size=\"13\">flight-recorder timeline \
         ({} events, {} iters)</text>\n",
        events.len(),
        max_iter
    ));

    // Lane stripes + labels.
    for (i, lane) in lanes.iter().enumerate() {
        let y = lane_top(i);
        if i % 2 == 0 {
            svg.push_str(&format!(
                "<rect x=\"{LEFT}\" y=\"{y}\" width=\"{plot_w:.1}\" height=\"{LANE_H}\" \
                 fill=\"#f5f5f5\"/>\n"
            ));
        }
        svg.push_str(&format!(
            "<text x=\"{:.1}\" y=\"{:.1}\" text-anchor=\"end\">{}</text>\n",
            LEFT - 8.0,
            y + LANE_H / 2.0 + 4.0,
            xml_escape(&lane.label())
        ));
    }

    // Windowed fault spans: each Fault pairs with the first later
    // un-consumed Heal on its shard; unhealed faults span to the end.
    for (fault_iter, shard, heal_iter) in pair_faults(events) {
        let Some(&li) = lane_index.get(&Lane::Shard(shard)) else { continue };
        let x0 = x(fault_iter);
        let x1 = x(heal_iter.unwrap_or(max_iter));
        svg.push_str(&format!(
            "<rect x=\"{x0:.1}\" y=\"{:.1}\" width=\"{:.1}\" height=\"{:.1}\" \
             fill=\"#d62728\" fill-opacity=\"0.14\"/>\n",
            lane_top(li) + 3.0,
            (x1 - x0).max(2.0),
            LANE_H - 6.0
        ));
    }

    // Loss curve on the train lane.
    let losses: Vec<(usize, f64)> = events
        .iter()
        .filter_map(|e| match &e.kind {
            EventKind::Progress { loss, .. } => Some((e.iter, *loss)),
            _ => None,
        })
        .collect();
    if losses.len() >= 2 {
        let li = lane_index[&Lane::Train];
        let (lo, hi) = losses
            .iter()
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), (_, l)| (lo.min(*l), hi.max(*l)));
        let span = (hi - lo).max(1e-12);
        let pts: Vec<String> = losses
            .iter()
            .map(|(it, l)| {
                let ly = lane_top(li) + LANE_H - 5.0 - (l - lo) / span * (LANE_H - 10.0);
                format!("{:.1},{:.1}", x(*it), ly)
            })
            .collect();
        svg.push_str(&format!(
            "<polyline points=\"{}\" fill=\"none\" stroke=\"{}\" stroke-width=\"1.2\"/>\n",
            pts.join(" "),
            color("progress")
        ));
    }

    // Event markers (progress is the polyline above, skip its dots).
    for e in events {
        if matches!(e.kind, EventKind::Progress { .. }) {
            continue;
        }
        let li = lane_index[&lane_of(&e.kind)];
        let cy = lane_top(li) + LANE_H / 2.0;
        svg.push_str(&format!(
            "<circle cx=\"{:.1}\" cy=\"{cy:.1}\" r=\"3.5\" fill=\"{}\"><title>{}</title></circle>\n",
            x(e.iter),
            color(e.kind.tag()),
            xml_escape(&format!("iter {}: {}", e.iter, e.to_json().to_string()))
        ));
    }

    // X axis.
    let axis_y = TOP + lanes.len() as f64 * LANE_H;
    svg.push_str(&format!(
        "<line x1=\"{LEFT}\" y1=\"{axis_y:.1}\" x2=\"{:.1}\" y2=\"{axis_y:.1}\" \
         stroke=\"#333\"/>\n",
        LEFT + plot_w
    ));
    let ticks = 6usize;
    for t in 0..=ticks {
        let iter = max_iter * t / ticks;
        svg.push_str(&format!(
            "<text x=\"{:.1}\" y=\"{:.1}\" text-anchor=\"middle\" fill=\"#333\">{}</text>\n",
            x(iter),
            axis_y + 16.0,
            iter
        ));
    }
    svg.push_str(&format!(
        "<text x=\"{:.1}\" y=\"{:.1}\" text-anchor=\"middle\">iteration</text>\n",
        LEFT + plot_w / 2.0,
        axis_y + 34.0
    ));

    // Legend.
    let lx = WIDTH - RIGHT_PAD + 16.0;
    for (i, (tag, c)) in colors.iter().enumerate() {
        let ly = TOP + i as f64 * 16.0;
        svg.push_str(&format!(
            "<rect x=\"{lx:.1}\" y=\"{:.1}\" width=\"10\" height=\"10\" fill=\"{c}\"/>\n",
            ly - 9.0
        ));
        svg.push_str(&format!("<text x=\"{:.1}\" y=\"{ly:.1}\">{tag}</text>\n", lx + 16.0));
    }

    svg.push_str("</svg>\n");
    svg
}

/// Pair every `Fault` with the first later un-consumed `Heal` on its
/// shard. Returns `(fault_iter, shard, heal_iter)` in trace order;
/// `heal_iter` is `None` for one-shot faults (torn/fsync/bitflip) and
/// faults that never healed.
fn pair_faults(events: &[Event]) -> Vec<(usize, usize, Option<usize>)> {
    let mut heals: Vec<(usize, usize, bool)> = events
        .iter()
        .filter_map(|e| match e.kind {
            EventKind::Heal { shard } => Some((e.iter, shard, false)),
            _ => None,
        })
        .collect();
    let mut out = Vec::new();
    for e in events {
        if let EventKind::Fault { shard, .. } = e.kind {
            let heal = heals
                .iter_mut()
                .find(|(hi, hs, used)| !*used && *hs == shard && *hi >= e.iter)
                .map(|h| {
                    h.2 = true;
                    h.0
                });
            out.push((e.iter, shard, heal));
        }
    }
    out
}

/// The fault → recovery-latency table: for each injected fault, when it
/// healed, how many iterations that took, and how many bytes of rebuild
/// work landed inside its window (faults with overlapping windows on
/// different shards attribute shared rebuilds to each — the rebuild
/// events themselves are not shard-scoped).
pub fn fault_latency_table(events: &[Event]) -> String {
    let pairs = pair_faults(events);
    let max_iter = events.iter().map(|e| e.iter).max().unwrap_or(0);
    let mut out = String::new();
    out.push_str("fault      shard      at  healed   iters  rebuilt_bytes\n");
    for (fault_iter, shard, heal) in &pairs {
        let kind = events
            .iter()
            .find_map(|e| match &e.kind {
                EventKind::Fault { fault, shard: s } if *s == *shard && e.iter == *fault_iter => {
                    Some(fault.clone())
                }
                _ => None,
            })
            .unwrap_or_else(|| "?".to_string());
        let window_end = heal.unwrap_or(max_iter);
        let rebuilt: u64 = events
            .iter()
            .filter(|e| e.iter >= *fault_iter && e.iter <= window_end)
            .map(|e| match &e.kind {
                EventKind::Rebuild { bytes, .. } => *bytes,
                _ => 0,
            })
            .sum();
        let (healed, iters) = match heal {
            Some(h) => (h.to_string(), (h - fault_iter).to_string()),
            None => ("-".to_string(), "-".to_string()),
        };
        out.push_str(&format!(
            "{kind:<10} {shard:>5} {fault_iter:>7} {healed:>7} {iters:>7} {rebuilt:>14}\n"
        ));
    }
    if pairs.is_empty() {
        out.push_str("(no fault events in trace)\n");
    }
    out
}

/// Per-tag event counts, for the `scar trace` text summary.
pub fn summary_counts(events: &[Event]) -> BTreeMap<&'static str, usize> {
    let mut out = BTreeMap::new();
    for e in events {
        *out.entry(e.kind.tag()).or_insert(0) += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Event> {
        vec![
            Event { iter: 1, kind: EventKind::Progress { loss: 1.0, update_norm: 0.2 } },
            Event {
                iter: 2,
                kind: EventKind::Barrier { atoms: 4, bytes: 128, skipped_atoms: 0, skipped_bytes: 0 },
            },
            Event { iter: 3, kind: EventKind::Fault { fault: "flaky".into(), shard: 1 } },
            Event {
                iter: 4,
                kind: EventKind::Rebuild { source: "cache".into(), atoms: 2, bytes: 64, workers: 1 },
            },
            Event { iter: 5, kind: EventKind::Progress { loss: 0.5, update_norm: 0.1 } },
            Event { iter: 6, kind: EventKind::Heal { shard: 1 } },
            Event { iter: 7, kind: EventKind::Fault { fault: "torn".into(), shard: 2 } },
        ]
    }

    #[test]
    fn timeline_has_lanes_markers_and_legend() {
        let svg = render_timeline(&sample());
        assert!(svg.starts_with("<svg"));
        assert!(svg.contains("shard 1"));
        assert!(svg.contains("shard 2"));
        assert!(svg.contains("checkpoint"));
        assert!(svg.contains(">fault</text>"));
        assert!(svg.contains("polyline"), "loss curve rendered");
        assert!(svg.ends_with("</svg>\n"));
    }

    #[test]
    fn empty_trace_renders() {
        let svg = render_timeline(&[]);
        assert!(svg.starts_with("<svg") && svg.ends_with("</svg>\n"));
    }

    #[test]
    fn latency_pairs_fault_with_heal() {
        let table = fault_latency_table(&sample());
        let flaky_row = table.lines().find(|l| l.starts_with("flaky")).unwrap();
        assert!(flaky_row.contains(" 6 "), "healed at 6: {flaky_row}");
        assert!(flaky_row.ends_with("64"), "rebuild bytes inside window: {flaky_row}");
        let torn_row = table.lines().find(|l| l.starts_with("torn")).unwrap();
        assert!(torn_row.contains('-'), "one-shot fault has no heal: {torn_row}");
    }

    #[test]
    fn counts_by_tag() {
        let counts = summary_counts(&sample());
        assert_eq!(counts["progress"], 2);
        assert_eq!(counts["fault"], 2);
    }
}
