//! # SCAR — Self-Correcting Algorithm Recovery
//!
//! A from-scratch reproduction of *Fault Tolerance in Iterative-Convergent
//! Machine Learning* (Qiao, Aragam, Zhang, Xing; ICML 2019) as a
//! three-layer Rust + JAX + Pallas training framework:
//!
//! * **L3 (this crate)** — the parameter-server coordinator: random atom
//!   partitioning, the fault-tolerance controller (checkpoint coordinator
//!   with priority/round/random partial checkpoints, recovery coordinator
//!   with partial/full recovery), failure injection/detection, sharded
//!   persistent storage with a pipelined writer pool and commit-watermark
//!   recovery ([`storage::ShardedStore`] +
//!   [`checkpoint::AsyncCheckpointer`]), deterministic storage-fault
//!   injection with degraded-mode routing and recovery ([`chaos`]), the
//!   Theorem 3.2 iteration-cost bound, and the experiment harness that
//!   regenerates every figure in the paper.
//! * **L2** — JAX step functions (QP, MLR, MF-ALS, CNN, Transformer)
//!   AOT-lowered once to HLO text (`python/compile/`).
//! * **L1** — Pallas kernels for the dense hot-spots (fused MLR gradient,
//!   blocked matmul), verified against pure-jnp oracles.
//!
//! The Rust binary is self-contained after `make artifacts`; Python never
//! runs on the training path.
//!
//! Quick tour: [`models::build_trainer`] binds an artifact to a
//! [`params::ParamStore`] + [`params::AtomLayout`]; a
//! [`checkpoint::CheckpointCoordinator`] and [`recovery::recover`]
//! implement the paper's strategies; [`harness`] measures iteration
//! costs; [`cluster`] runs the threaded PS deployment; [`scenario`] turns
//! whole experiments into data files (`scenarios/*.toml`) executed as
//! parallel trial sweeps via `scar run-scenario`; [`obs`] is the
//! deterministic flight recorder + metrics registry behind `--trace`,
//! `--json`, and `scar trace`; [`policy`] closes the advisor loop with a
//! runtime controller that retunes checkpointing mid-run
//! (`policy = "adaptive"` scenario cells).

pub mod advisor;
pub mod chaos;
pub mod checkpoint;
pub mod cluster;
pub mod config;
pub mod data;
pub mod failure;
pub mod harness;
pub mod models;
pub mod obs;
pub mod params;
pub mod partition;
pub mod policy;
pub mod recovery;
pub mod runtime;
pub mod scenario;
pub mod storage;
pub mod theory;
pub mod trainer;
pub mod util;

/// Default artifact directory relative to the repo root; overridable with
/// `SCAR_ARTIFACTS` (used by every example and bench).
pub fn artifact_dir() -> std::path::PathBuf {
    std::env::var("SCAR_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
