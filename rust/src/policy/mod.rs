//! Runtime policy controller — the advisor loop, closed (paper §7;
//! ROADMAP "adaptive fault-tolerance policy engine").
//!
//! [`crate::advisor`] estimates the contraction rate `c` from the live
//! loss curve and scores candidate checkpoint policies under the
//! Thm 3.2 / Daly-style overhead trade, but nothing ever *acted* on the
//! scores: every knob was frozen per trial. The [`PolicyController`]
//! turns the estimate into live reconfiguration:
//!
//! 1. **Observe.** Every iteration the training loop feeds it the loss
//!    ([`observe_loss`](PolicyController::observe_loss)) and any failure
//!    arrivals with their lost-parameter fraction
//!    ([`observe_failure`](PolicyController::observe_failure)). Both are
//!    iteration-clocked and deterministic for a fixed seed.
//! 2. **Decide.** At each observation-window boundary
//!    ([`decide`](PolicyController::decide)) it re-evaluates the
//!    candidate grid of [`recommend_policy`] under the current rate
//!    estimate and the *windowed* failure arrival rate, and proposes a
//!    switch when a candidate beats the held policy's predicted overhead
//!    by more than the hysteresis margin. It also proposes the
//!    checkpoint mode: sync while failures are arriving (fences are
//!    taken constantly anyway, so the pipeline buys nothing), async in
//!    quiet regimes (overlap the dump with training).
//! 3. **Apply.** The caller applies the decision at the next safe fence
//!    point only — `AsyncCheckpointer::set_policy` /
//!    `AsyncCheckpointer::set_mode` at an iteration boundary — and
//!    narrates it as a `policy_switch` flight-recorder event.
//!
//! **Determinism contract.** Decisions are a pure function of
//! iteration-clocked observations (losses, failure iterations, lost
//! fractions). Wall-clock observables — back-pressure stall counts in
//! particular, which the docs on
//! [`wait_for_queue_room`](crate::checkpoint::AsyncCheckpointer) place
//! explicitly outside the determinism surface — are *recorded* via
//! [`note_stalls`](PolicyController::note_stalls) for reporting but are
//! never an input to `decide`. Same seed ⇒ same switch schedule ⇒
//! byte-identical runs (`rust/tests/policy.rs` pins this across
//! {mem, disk} × {sync, async}).
//!
//! **Regret.** At end of run,
//! [`regret_per_iter`](PolicyController::regret_per_iter) scores the
//! *held* policy schedule against the best fixed policy in hindsight
//! (the oracle), both priced by the same cost model under the final
//! rate estimate and the whole-run failure rate — a model-based
//! regret-vs-oracle number that needs no extra runs and stays
//! deterministic.

use crate::advisor::{expected_rework_iters, recommend_policy, AdvisorInputs};
use crate::checkpoint::{CheckpointMode, CheckpointPolicy, Selector};

pub use crate::advisor::OnlineRateEstimator;

/// Tuning knobs of the controller (scenario `[advisor]` table).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PolicyConfig {
    /// Iterations between decision points — the observation window.
    /// `0` disables the controller entirely.
    pub window: usize,
    /// Blocking cost of one *full-size* checkpoint dump in iteration
    /// units (the advisor's `t_dump_full / t_iter` ratio). This both
    /// drives the overhead trade and is priced into every trial's
    /// iteration cost (static cells too), so adaptive-vs-static
    /// comparisons charge for checkpoint bandwidth. `0` (the default)
    /// keeps all existing reports byte-identical.
    pub dump_cost_iters: f64,
    /// Relative predicted-overhead improvement a candidate must show
    /// over the held policy before the controller switches.
    pub hysteresis: f64,
    /// Base full-checkpoint interval C the candidate grid derives from.
    pub base_interval: usize,
    /// Prior for the fraction of parameters lost per failure, used until
    /// the first observed failure reports its real fraction.
    pub lost_fraction: f64,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        PolicyConfig {
            window: 16,
            dump_cost_iters: 0.0,
            hysteresis: 0.1,
            base_interval: 8,
            lost_fraction: 0.25,
        }
    }
}

/// One applied (or proposed) switch: the new policy, its grid index k,
/// the new mode, and the predicted overhead that justified it.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicySwitch {
    pub iter: usize,
    pub policy: CheckpointPolicy,
    pub k: usize,
    pub mode: CheckpointMode,
    /// Predicted overhead per iteration of the switched-to policy under
    /// the inputs that drove the decision.
    pub predicted_overhead: f64,
}

/// Predicted overhead per iteration of candidate `k` under `inputs`
/// (the advisor's scoring formula, callable for any k — including a
/// held k that is not on the power-of-two grid).
fn overhead_of(inputs: &AdvisorInputs, k: usize) -> f64 {
    let policy = CheckpointPolicy::partial(inputs.base_interval, k, Selector::Priority);
    let mean_lag = (inputs.base_interval as f64) / 2.0 + (policy.interval as f64) / 2.0;
    let rework = expected_rework_iters(inputs.c, mean_lag, inputs.lost_fraction);
    inputs.t_dump_full * policy.fraction / policy.interval as f64
        + inputs.failure_rate * rework * inputs.t_iter
}

/// The runtime policy controller. See the module docs for the protocol.
#[derive(Debug, Clone)]
pub struct PolicyController {
    cfg: PolicyConfig,
    est: OnlineRateEstimator,
    /// Iteration-keyed failure arrivals: (iteration, lost fraction).
    failures: Vec<(usize, f64)>,
    /// Wall-clock stall observations — reporting only, never a decision
    /// input (they are outside the determinism surface).
    stalls_seen: u64,
    /// Measured per-fence wall-clock (last value + EWMA, milliseconds).
    /// Reporting only for now, same determinism rule as stalls: this is
    /// the seed for a learned dump-cost model (ROADMAP), but `decide`
    /// MUST NOT read it until that model replays deterministically.
    last_fence_wall_ms: f64,
    ewma_fence_wall_ms: f64,
    held_k: usize,
    held_mode: CheckpointMode,
    /// (adoption iteration, k) — the held-policy schedule, seeded with
    /// the initial policy at iteration 0. Feeds regret accounting.
    history: Vec<(usize, usize)>,
    switches: u64,
}

impl PolicyController {
    pub fn new(cfg: PolicyConfig, initial_k: usize, initial_mode: CheckpointMode) -> Self {
        PolicyController {
            cfg,
            est: OnlineRateEstimator::default(),
            failures: Vec::new(),
            stalls_seen: 0,
            last_fence_wall_ms: 0.0,
            ewma_fence_wall_ms: 0.0,
            held_k: initial_k.max(1),
            held_mode: initial_mode,
            history: vec![(0, initial_k.max(1))],
            switches: 0,
        }
    }

    /// Feed the loss after one training iteration.
    pub fn observe_loss(&mut self, loss: f64) {
        self.est.observe(loss);
    }

    /// Record a failure arrival at `iter` that lost `lost_fraction` of
    /// the parameters (e.g. `lost_atoms / n_atoms`).
    pub fn observe_failure(&mut self, iter: usize, lost_fraction: f64) {
        self.failures.push((iter, lost_fraction.clamp(0.0, 1.0)));
    }

    /// Record back-pressure stalls. Reporting only: stall counts are
    /// wall-clock nondeterministic, so they MUST NOT feed `decide` —
    /// `stalls_never_affect_decisions` pins this.
    pub fn note_stalls(&mut self, n: u64) {
        self.stalls_seen += n;
    }

    pub fn stalls_seen(&self) -> u64 {
        self.stalls_seen
    }

    /// Record a measured flush-fence wall-clock, in milliseconds.
    /// Reporting only (see the field docs): the EWMA is the input a
    /// future learned dump-cost model would consume in place of the
    /// configured `dump_cost_iters`; nothing reads it in `decide` today.
    pub fn observe_fence_wall_ms(&mut self, ms: f64) {
        if !ms.is_finite() || ms < 0.0 {
            return;
        }
        self.last_fence_wall_ms = ms;
        self.ewma_fence_wall_ms = if self.ewma_fence_wall_ms == 0.0 {
            ms
        } else {
            0.2 * ms + 0.8 * self.ewma_fence_wall_ms
        };
    }

    /// The most recently observed fence wall-clock (ms).
    pub fn last_fence_wall_ms(&self) -> f64 {
        self.last_fence_wall_ms
    }

    /// Smoothed fence wall-clock (ms; EWMA with alpha 0.2).
    pub fn ewma_fence_wall_ms(&self) -> f64 {
        self.ewma_fence_wall_ms
    }

    pub fn switches(&self) -> u64 {
        self.switches
    }

    /// The currently held grid index k (fraction 1/k every C/k iters).
    pub fn held_k(&self) -> usize {
        self.held_k
    }

    pub fn held_mode(&self) -> CheckpointMode {
        self.held_mode
    }

    /// Windowed failure arrival rate: failures per iteration over the
    /// trailing `4 * window` iterations — recent enough to track regime
    /// shifts, wide enough not to flap on a single arrival.
    fn windowed_failure_rate(&self, iter: usize) -> f64 {
        let span = (4 * self.cfg.window).min(iter).max(1);
        let from = iter - span;
        let recent = self.failures.iter().filter(|(fi, _)| *fi > from && *fi <= iter).count();
        recent as f64 / span as f64
    }

    /// Failures inside the trailing `2 * window` iterations (the mode
    /// rule's activity test).
    fn recent_failures(&self, iter: usize) -> usize {
        let from = iter.saturating_sub(2 * self.cfg.window);
        self.failures.iter().filter(|(fi, _)| *fi > from && *fi <= iter).count()
    }

    /// Mean observed lost fraction, or the configured prior before any
    /// failure has been seen.
    fn lost_fraction(&self) -> f64 {
        if self.failures.is_empty() {
            return self.cfg.lost_fraction;
        }
        self.failures.iter().map(|(_, p)| p).sum::<f64>() / self.failures.len() as f64
    }

    /// Cost-model inputs at `iter` under the current estimates.
    fn inputs_at(&self, c: f64, failure_rate: f64) -> AdvisorInputs {
        AdvisorInputs {
            c,
            lost_fraction: self.lost_fraction(),
            failure_rate,
            t_iter: 1.0,
            t_dump_full: self.cfg.dump_cost_iters,
            base_interval: self.cfg.base_interval.max(1),
        }
    }

    /// Re-evaluate at an observation-window boundary. Returns the switch
    /// to apply at this iteration's fence point, or `None` when `iter`
    /// is not a boundary, the rate estimate is not yet trustworthy, or
    /// the held policy is still (near-)best.
    pub fn decide(&mut self, iter: usize) -> Option<PolicySwitch> {
        if self.cfg.window == 0 || iter == 0 || iter % self.cfg.window != 0 {
            return None;
        }
        let c = self.est.rate()?;
        let failure_rate = self.windowed_failure_rate(iter);
        let inputs = self.inputs_at(c, failure_rate);
        let scores = recommend_policy(&inputs);
        let best = scores.first()?;
        let held_overhead = overhead_of(&inputs, self.held_k);

        // k rule: switch only past the hysteresis margin, so ties and
        // noise-level differences never flap the interval.
        let k_changed = best.k != self.held_k
            && best.overhead_per_iter < held_overhead * (1.0 - self.cfg.hysteresis);
        // Mode rule: failures arriving ⇒ sync (every failure forces a
        // drain fence anyway, and recovery reads want a settled store);
        // quiet ⇒ async (overlap dumps with training). Iteration-keyed
        // arrivals only — deterministic by construction.
        let want_mode = if self.recent_failures(iter) >= 2 {
            CheckpointMode::Sync
        } else {
            CheckpointMode::Async
        };
        let mode_changed = want_mode != self.held_mode;
        if !k_changed && !mode_changed {
            return None;
        }
        let (new_k, predicted) = if k_changed {
            (best.k, best.overhead_per_iter)
        } else {
            (self.held_k, held_overhead)
        };
        self.held_k = new_k;
        self.held_mode = want_mode;
        self.history.push((iter, new_k));
        self.switches += 1;
        Some(PolicySwitch {
            iter,
            policy: CheckpointPolicy::partial(
                self.cfg.base_interval.max(1),
                new_k,
                Selector::Priority,
            ),
            k: new_k,
            mode: want_mode,
            predicted_overhead: predicted,
        })
    }

    /// Model-based regret vs the fixed-policy oracle, in overhead units
    /// per iteration: the time-weighted predicted overhead of the held
    /// schedule minus the best single policy's, both under the final
    /// rate estimate and the whole-run failure rate. `0.0` when no rate
    /// was ever estimable (nothing to regret against).
    pub fn regret_per_iter(&self, total_iters: usize) -> f64 {
        if total_iters == 0 {
            return 0.0;
        }
        let Some(c) = self.est.rate() else {
            return 0.0;
        };
        let failure_rate = self.failures.len() as f64 / total_iters as f64;
        let inputs = self.inputs_at(c, failure_rate);
        // Held schedule: each span priced at its k.
        let mut held = 0.0;
        for (i, &(start, k)) in self.history.iter().enumerate() {
            let end = self.history.get(i + 1).map(|&(s, _)| s).unwrap_or(total_iters);
            let span = end.saturating_sub(start).min(total_iters - start.min(total_iters));
            held += span as f64 * overhead_of(&inputs, k);
        }
        held /= total_iters as f64;
        // Oracle: best fixed k on the candidate grid, in hindsight.
        let oracle = recommend_policy(&inputs)
            .first()
            .map(|s| s.overhead_per_iter)
            .unwrap_or(held);
        (held - oracle).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Converging loss curve at rate c, long enough to warm the
    /// estimator.
    fn feed_losses(ctl: &mut PolicyController, n: usize, c: f64) {
        for k in 0..n {
            ctl.observe_loss(1.0 + 5.0 * c.powi(k as i32));
        }
    }

    fn cfg() -> PolicyConfig {
        PolicyConfig {
            window: 8,
            dump_cost_iters: 2.0,
            hysteresis: 0.1,
            base_interval: 8,
            lost_fraction: 0.25,
        }
    }

    #[test]
    fn no_decision_off_window_boundary() {
        let mut ctl = PolicyController::new(cfg(), 1, CheckpointMode::Sync);
        feed_losses(&mut ctl, 32, 0.9);
        for iter in [1, 3, 7, 9, 15] {
            assert!(ctl.decide(iter).is_none(), "iter {iter} is not a boundary");
        }
    }

    #[test]
    fn no_decision_before_rate_warm() {
        let mut ctl = PolicyController::new(cfg(), 1, CheckpointMode::Sync);
        ctl.observe_loss(1.0);
        ctl.observe_loss(0.9);
        assert!(ctl.decide(8).is_none(), "cold estimator must not switch");
    }

    #[test]
    fn bursty_failures_shorten_the_interval() {
        let mut ctl = PolicyController::new(cfg(), 1, CheckpointMode::Sync);
        feed_losses(&mut ctl, 16, 0.9);
        for iter in 5..=10 {
            ctl.observe_failure(iter, 0.5);
        }
        let sw = ctl.decide(16).expect("frequent failures must trigger a switch");
        assert!(sw.k > 1, "expected a finer-grained policy, got k={}", sw.k);
        assert!(sw.policy.interval < 8);
        assert_eq!(ctl.switches(), 1);
    }

    #[test]
    fn quiet_regime_holds_and_prefers_async() {
        let mut ctl = PolicyController::new(cfg(), 1, CheckpointMode::Async);
        feed_losses(&mut ctl, 32, 0.9);
        // No failures: every k costs the same dump bytes, so the held
        // k=1 stays (hysteresis kills ties) and async stays.
        assert!(ctl.decide(32).is_none());
        assert_eq!(ctl.switches(), 0);
    }

    #[test]
    fn failure_burst_flips_to_sync_then_quiet_flips_back() {
        let mut ctl = PolicyController::new(cfg(), 1, CheckpointMode::Async);
        feed_losses(&mut ctl, 200, 0.9);
        ctl.observe_failure(3, 0.25);
        ctl.observe_failure(6, 0.25);
        let sw = ctl.decide(8).expect("burst inside the window must flip the mode");
        assert_eq!(sw.mode, CheckpointMode::Sync);
        // Far later, the trailing window is quiet again: flip back.
        let back = ctl
            .decide(craft_quiet_boundary())
            .expect("quiet regime must flip back to async");
        assert_eq!(back.mode, CheckpointMode::Async);
    }

    /// A window boundary far past the burst (trailing 2*window quiet).
    fn craft_quiet_boundary() -> usize {
        64
    }

    #[test]
    fn stalls_never_affect_decisions() {
        let drive = |stalls: u64| {
            let mut ctl = PolicyController::new(cfg(), 1, CheckpointMode::Sync);
            feed_losses(&mut ctl, 16, 0.9);
            for iter in 5..=10 {
                ctl.observe_failure(iter, 0.5);
            }
            ctl.note_stalls(stalls);
            let d = ctl.decide(16);
            (d, ctl.held_k(), ctl.held_mode())
        };
        assert_eq!(drive(0), drive(1_000_000), "stall counts must never change a decision");
    }

    #[test]
    fn regret_zero_when_held_matches_oracle() {
        let mut ctl = PolicyController::new(cfg(), 1, CheckpointMode::Sync);
        feed_losses(&mut ctl, 64, 0.9);
        // No failures ⇒ every k has equal predicted overhead ⇒ the held
        // schedule is an oracle.
        assert!(ctl.regret_per_iter(64).abs() < 1e-12);
    }

    #[test]
    fn regret_positive_when_held_policy_was_wrong() {
        // Hold k=1 the whole run while failures were frequent: the
        // oracle (finer k) must be strictly better.
        let mut ctl = PolicyController::new(
            PolicyConfig { window: 0, ..cfg() },
            1,
            CheckpointMode::Sync,
        );
        feed_losses(&mut ctl, 64, 0.9);
        for iter in (4..64).step_by(4) {
            ctl.observe_failure(iter, 0.5);
        }
        assert!(ctl.decide(16).is_none(), "window = 0 disables the controller");
        assert!(ctl.regret_per_iter(64) > 0.0);
    }
}
