//! Theory layer: the iteration-cost bounds of §3, function-by-function
//! against the paper.
//!
//! | paper | here |
//! |---|---|
//! | assumption (3): ‖x⁽ᵏ⁺¹⁾ − x*‖ ≤ c‖x⁽ᵏ⁾ − x*‖ | `c` fit by [`estimate_rate`] / [`estimate_rate_conservative`] |
//! | ι(δ, ε) = κ(y, ε) − κ(x, ε) (Def. 3.1) | measured by [`crate::harness::run_trial`]; bounded here |
//! | Theorem 3.2 / eq. (6) | [`iteration_cost_bound`] |
//! | Δ_T = Σ_{ℓ=0}^{T} c^{−ℓ} E‖δ_ℓ‖ (eq. 6) | [`delta_t`] |
//! | κ(x, ε) for a linear sequence | [`kappa_unperturbed`] |
//! | eq. (14), App. B.1 (per-iteration perturbations) | [`infinite_horizon_bound`] |
//! | Example 3.3's error floor (c/(1−c))Δ | [`irreducible_error`] |
//!
//! The "value of c is determined empirically" (Fig 3/5 captions); the
//! estimators below are the empirical side of that contract.

/// A perturbation event: iteration index ℓ and expected norm E‖δ_ℓ‖.
///
/// The iteration index matters because eq. (6) discounts by c^{−ℓ}:
/// *later* perturbations are discounted **less**, i.e. cost more — a
/// failure just before convergence hurts more than one at the start.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Perturbation {
    pub iter: usize,
    pub norm: f64,
}

/// Fit the contraction rate `c` of assumption (3) by least squares on
/// log(error): log e_k ≈ log e_0 + k log c. Points with error below
/// `floor` are dropped (converged plateau / numerical noise would bias
/// the slope).
///
/// ```
/// use scar::theory::estimate_rate;
/// // An exactly geometric error curve e_k = 10 · 0.93^k recovers c.
/// let errors: Vec<f64> = (0..100).map(|k| 10.0 * 0.93f64.powi(k)).collect();
/// let c = estimate_rate(&errors, 1e-12);
/// assert!((c - 0.93).abs() < 1e-6);
/// ```
pub fn estimate_rate(errors: &[f64], floor: f64) -> f64 {
    let pts: Vec<(f64, f64)> = errors
        .iter()
        .enumerate()
        .filter(|(_, &e)| e > floor && e.is_finite())
        .map(|(k, &e)| (k as f64, e.ln()))
        .collect();
    if pts.len() < 2 {
        return f64::NAN;
    }
    let xs: Vec<f64> = pts.iter().map(|p| p.0).collect();
    let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
    let (_, slope) = crate::util::stats::linfit(&xs, &ys);
    slope.exp().clamp(1e-6, 0.999999)
}

/// Conservative rate estimate: fit on the *tail* half of the qualifying
/// points. Multi-mode systems (e.g. a QP with spread eigenvalues) decay
/// fast early and slow late; assumption (3) requires a `c` valid at every
/// step, so the bound must use the slowest (asymptotic) mode or it stops
/// being an upper bound.
pub fn estimate_rate_tail(errors: &[f64], floor: f64) -> f64 {
    let qualifying: Vec<f64> = errors
        .iter()
        .copied()
        .take_while(|&e| e > floor && e.is_finite())
        .collect();
    if qualifying.len() < 4 {
        return estimate_rate(errors, floor);
    }
    estimate_rate(&qualifying[qualifying.len() / 2..], floor)
}

/// Conservative empirical `c` for use in the *bound*: assumption (3)
/// requires a per-step contraction factor valid at EVERY step, so take
/// the max of the tail regression and a high percentile of observed
/// one-step ratios e_{k+1}/e_k over the tail (robust to a multi-mode
/// spectrum where early fast modes bias regressions optimistic, and to
/// stochastic-trajectory noise).
pub fn estimate_rate_conservative(errors: &[f64], floor: f64) -> f64 {
    let regression = estimate_rate_tail(errors, floor);
    let qualifying: Vec<f64> = errors
        .iter()
        .copied()
        .take_while(|&e| e > floor && e.is_finite())
        .collect();
    if qualifying.len() < 6 {
        return regression;
    }
    let tail = &qualifying[qualifying.len() / 2..];
    let ratios: Vec<f64> = tail
        .windows(2)
        .map(|w| w[1] / w[0])
        .filter(|r| r.is_finite() && *r > 0.0)
        .collect();
    if ratios.is_empty() {
        return regression;
    }
    let p92 = crate::util::stats::percentile(&ratios, 92.0);
    regression.max(p92).clamp(1e-6, 0.99999)
}

/// Fit the asymptotic (slow) decay mode of an error curve: regression on
/// the tail half gives `log e = log A + k log c`; returns (A, c).
///
/// For multi-mode systems A < ||x0 - x*|| (fast modes carry part of the
/// initial error but vanish early). Using A as the eq.-(6) denominator
/// keeps the bound an upper bound: the theorem's kappa(x, eps) assumes
/// the whole distance decays at rate c, which *understates* how quickly
/// the real sequence converges (fast modes help), so pairing the measured
/// baseline iteration count with the full ||x0 - x*|| would produce a
/// bound the slow mode can beat. See EXPERIMENTS.md (Fig 3).
pub fn estimate_slow_mode(errors: &[f64], floor: f64) -> (f64, f64) {
    let qualifying: Vec<(f64, f64)> = errors
        .iter()
        .enumerate()
        .take_while(|(_, &e)| e > floor && e.is_finite())
        .map(|(k, &e)| (k as f64, e.ln()))
        .collect();
    if qualifying.len() < 4 {
        return (errors.first().copied().unwrap_or(f64::NAN), estimate_rate(errors, floor));
    }
    let tail = &qualifying[qualifying.len() / 2..];
    let xs: Vec<f64> = tail.iter().map(|p| p.0).collect();
    let ys: Vec<f64> = tail.iter().map(|p| p.1).collect();
    let (intercept, slope) = crate::util::stats::linfit(&xs, &ys);
    (intercept.exp(), slope.exp().clamp(1e-6, 0.99999))
}

/// Δ_T = Σ c^{−ℓ} E‖δ_ℓ‖ — the time-discounted perturbation aggregate of
/// eq. (6). The c^{−ℓ} factor grows with ℓ: perturbations near
/// convergence dominate the bound.
///
/// ```
/// use scar::theory::{delta_t, Perturbation};
/// // c = 0.5, one unit perturbation at l = 2: Delta_T = 0.5^-2 = 4.
/// let dt = delta_t(0.5, &[Perturbation { iter: 2, norm: 1.0 }]);
/// assert!((dt - 4.0).abs() < 1e-12);
/// // Aggregation is additive across events (linearity of expectation).
/// let two = delta_t(0.5, &[
///     Perturbation { iter: 2, norm: 1.0 },
///     Perturbation { iter: 3, norm: 1.0 },
/// ]);
/// assert!((two - 12.0).abs() < 1e-12);
/// ```
pub fn delta_t(c: f64, perturbations: &[Perturbation]) -> f64 {
    perturbations
        .iter()
        .map(|p| c.powi(-(p.iter as i32)) * p.norm)
        .sum()
}

/// Theorem 3.2, eq. (6): the expected iteration cost of perturbations
/// δ_0..δ_T under assumption (3) is bounded by
///
/// ```text
/// E[ι] ≤ log(1 + Δ_T / ‖x⁽⁰⁾ − x*‖) / log(1/c)
/// ```
///
/// `x0_dist` is ‖x⁽⁰⁾ − x*‖ (or the slow-mode amplitude from
/// [`estimate_slow_mode`] for multi-mode systems — see that function's
/// docs for why). This is the curve every `fig5`/`fig6` sweep compares
/// measured costs against, and what [`crate::advisor`] evaluates over
/// candidate checkpoint policies.
///
/// ```
/// use scar::theory::{iteration_cost_bound, Perturbation};
/// // Hand computation: c = 0.5, ‖x0−x*‖ = 4, one unit delta at l = 2:
/// // Delta_T = 4, bound = log(1 + 4/4) / log 2 = 1 extra iteration.
/// let b = iteration_cost_bound(0.5, 4.0, &[Perturbation { iter: 2, norm: 1.0 }]);
/// assert!((b - 1.0).abs() < 1e-12);
/// // No perturbations, no cost.
/// assert_eq!(iteration_cost_bound(0.9, 10.0, &[]), 0.0);
/// ```
pub fn iteration_cost_bound(c: f64, x0_dist: f64, perturbations: &[Perturbation]) -> f64 {
    assert!(c > 0.0 && c < 1.0, "need 0 < c < 1, got {c}");
    assert!(x0_dist > 0.0);
    let dt = delta_t(c, perturbations);
    (1.0 + dt / x0_dist).ln() / (1.0 / c).ln()
}

/// κ(x, ε) for the unperturbed linear sequence (Def. 3.1's
/// iterations-to-ε-optimality): log(‖x⁽⁰⁾ − x*‖ / ε) / log(1/c).
///
/// ```
/// use scar::theory::kappa_unperturbed;
/// // Halving error each step from 8 to 1 takes 3 iterations.
/// let k = kappa_unperturbed(0.5, 8.0, 1.0);
/// assert!((k - 3.0).abs() < 1e-12);
/// ```
pub fn kappa_unperturbed(c: f64, x0_dist: f64, eps: f64) -> f64 {
    (x0_dist / eps).ln() / (1.0 / c).ln()
}

/// Eq. (14) (App. B.1): iteration-cost bound under perturbations of size
/// ≤ Δ in *every* iteration. Returns `None` when the bound is
/// uninformative, i.e. ε or ‖x⁽⁰⁾ − x*‖ is not above the irreducible
/// error (c/(1−c))Δ of Example 3.3 — the sequence can never converge
/// below that floor.
///
/// ```
/// use scar::theory::infinite_horizon_bound;
/// // Informative region: small per-iteration noise, target above floor.
/// assert!(infinite_horizon_bound(0.9, 10.0, 1.0, 0.01).is_some());
/// // eps below the irreducible error (0.9/0.1 * 0.01 = 0.09): no bound.
/// assert!(infinite_horizon_bound(0.9, 10.0, 0.05, 0.01).is_none());
/// ```
pub fn infinite_horizon_bound(c: f64, x0_dist: f64, eps: f64, delta: f64) -> Option<f64> {
    assert!(c > 0.0 && c < 1.0);
    let irreducible = c / (1.0 - c) * delta;
    if x0_dist <= irreducible || eps <= irreducible {
        return None;
    }
    let num = 1.0 - irreducible / x0_dist;
    let den = 1.0 - irreducible / eps;
    Some((num / den).ln() / (1.0 / c).ln())
}

/// The irreducible error floor (c/(1−c))Δ of Example 3.3: under
/// per-iteration perturbations of size Δ, no amount of training pushes
/// the error below this value.
///
/// ```
/// use scar::theory::irreducible_error;
/// assert!((irreducible_error(0.9, 0.01) - 0.09).abs() < 1e-12);
/// ```
pub fn irreducible_error(c: f64, delta: f64) -> f64 {
    c / (1.0 - c) * delta
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_recovered_from_exact_geometric() {
        let c: f64 = 0.93;
        let errors: Vec<f64> = (0..200).map(|k| 10.0 * c.powi(k)).collect();
        let fit = estimate_rate(&errors, 1e-12);
        assert!((fit - c).abs() < 1e-6, "fit={fit}");
    }

    #[test]
    fn rate_ignores_floor_plateau() {
        let c: f64 = 0.9;
        let mut errors: Vec<f64> = (0..100).map(|k| 5.0 * c.powi(k)).collect();
        errors.extend(std::iter::repeat(1e-9).take(100)); // converged noise
        let fit = estimate_rate(&errors, 1e-6);
        assert!((fit - c).abs() < 1e-4, "fit={fit}");
    }

    #[test]
    fn tail_rate_tracks_slow_mode() {
        // Two-mode decay: fast 0.5^k + slow 0.97^k. The whole-curve fit
        // lands between the modes; the tail fit must find ~0.97.
        let errors: Vec<f64> =
            (0..300).map(|k| 10.0 * 0.5f64.powi(k) + 1.0 * 0.97f64.powi(k)).collect();
        let whole = estimate_rate(&errors, 1e-9);
        let tail = estimate_rate_tail(&errors, 1e-9);
        assert!(tail > whole);
        assert!((tail - 0.97).abs() < 0.005, "tail={tail}");
    }

    #[test]
    fn conservative_rate_at_least_slowest_mode() {
        let errors: Vec<f64> =
            (0..1000).map(|k| 10.0 * 0.6f64.powi(k) + 2.0 * 0.995f64.powi(k)).collect();
        let c = estimate_rate_conservative(&errors, 1e-12);
        assert!(c >= 0.9945, "c={c}");
        assert!(c <= 0.99999);
    }

    #[test]
    fn bound_zero_without_perturbations() {
        let b = iteration_cost_bound(0.9, 10.0, &[]);
        assert!(b.abs() < 1e-12);
    }

    #[test]
    fn bound_monotone_in_norm_and_recency() {
        let small = iteration_cost_bound(0.9, 10.0, &[Perturbation { iter: 5, norm: 1.0 }]);
        let large = iteration_cost_bound(0.9, 10.0, &[Perturbation { iter: 5, norm: 2.0 }]);
        let later = iteration_cost_bound(0.9, 10.0, &[Perturbation { iter: 10, norm: 1.0 }]);
        assert!(large > small);
        // Later perturbations are discounted *less* (c^{-l} grows with l).
        assert!(later > small);
    }

    #[test]
    fn bound_matches_hand_computation() {
        // c=0.5, x0=4, single delta at l=2 of norm 1: Delta_T = 0.5^{-2} = 4.
        // bound = log(1 + 4/4)/log 2 = 1.
        let b = iteration_cost_bound(0.5, 4.0, &[Perturbation { iter: 2, norm: 1.0 }]);
        assert!((b - 1.0).abs() < 1e-12);
    }

    #[test]
    fn exact_tightness_for_adversarial_delta() {
        // With delta chosen along the worst-case direction and an exactly-
        // c-contracting map, the perturbed sequence needs exactly `bound`
        // extra iterations: simulate the scalar system x <- c x.
        let c = 0.8f64;
        let x0 = 8.0f64;
        let eps = 1e-3;
        let t = 7usize;
        let norm = 0.3;
        // Unperturbed iterations to eps:
        let k_unpert = kappa_unperturbed(c, x0, eps).ceil() as usize;
        // Simulate perturbed: error multiplies by c, plus delta at iter t.
        let mut e = x0;
        let mut k = 0usize;
        loop {
            if k == t {
                e += norm; // adversarial: directly away from x*
            }
            e *= c;
            k += 1;
            if e < eps {
                break;
            }
        }
        let bound = iteration_cost_bound(c, x0, &[Perturbation { iter: t, norm }]);
        let cost = k as f64 - k_unpert as f64;
        assert!(cost <= bound.ceil() + 1.0, "cost={cost} bound={bound}");
        assert!(bound < cost + 2.0, "bound should be tight: cost={cost} bound={bound}");
    }

    #[test]
    fn infinite_bound_informative_region() {
        assert!(infinite_horizon_bound(0.9, 10.0, 1.0, 0.01).is_some());
        // irreducible = 9*delta; eps below it → None
        assert!(infinite_horizon_bound(0.9, 10.0, 0.05, 0.01).is_none());
        let irr = irreducible_error(0.9, 0.01);
        assert!((irr - 0.09).abs() < 1e-12);
    }
}
