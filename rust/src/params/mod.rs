//! Model state as named dense tensors decomposed into *atoms*.
//!
//! An **atom** is the paper's unit of parameter partitioning, checkpoint
//! prioritization, and failure: "the rows of the parameter matrix are
//! randomly partitioned" (MLR), "the rows of L and the columns of R" (MF),
//! per-document topic distributions (LDA), and layers or layer-shards
//! (CNN, §5.1). An atom owns one or more *segments* — contiguous f32
//! ranges inside tensors — so that e.g. a CNN layer atom spans its weight
//! and bias tensors plus the co-located Adam moments, and an `R`-column
//! atom spans a strided set of ranges.
//!
//! Everything downstream (partitioner, checkpoint coordinator, recovery,
//! priority distances) operates on atoms, never on raw tensors.

use std::collections::HashMap;

/// A dense f32 tensor with a shape. All model state in the coordinator is
/// f32 — integer artifact inputs (transformer tokens) are data, not params.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(name: &str, shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { name: name.to_string(), shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(name: &str, shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "tensor {name}: shape/data mismatch");
        Tensor { name: name.to_string(), shape: shape.to_vec(), data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Rows for a matrix-shaped tensor (first-dim count otherwise).
    pub fn rows(&self) -> usize {
        self.shape.first().copied().unwrap_or(1)
    }

    /// Elements per first-dim slice.
    pub fn row_len(&self) -> usize {
        if self.shape.is_empty() {
            1
        } else {
            self.shape[1..].iter().product::<usize>().max(1)
        }
    }
}

/// A contiguous range of one tensor's flat data.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Segment {
    pub tensor: usize,
    pub start: usize,
    pub len: usize,
}

/// The atom decomposition of a model's state.
#[derive(Debug, Clone, Default)]
pub struct AtomLayout {
    pub atoms: Vec<Vec<Segment>>,
    /// Per-atom distance weights (all 1.0 unless the model overrides —
    /// LDA scales total-variation distance by document length, App. C).
    pub weights: Vec<f64>,
    /// Distance metric used by the priority selector.
    pub norm: AtomNorm,
}

/// Distance metric between an atom's current value and its checkpointed
/// value. L2 is the default; scaled total variation is the paper's choice
/// for LDA's doc-topic distributions (App. C).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AtomNorm {
    #[default]
    L2,
    /// 0.5 * sum |p_i - q_i| over the atom after normalizing each side to
    /// sum 1 (atoms hold unnormalized topic counts), times the atom weight.
    ScaledTv,
}

impl AtomLayout {
    pub fn n_atoms(&self) -> usize {
        self.atoms.len()
    }

    /// Uniform-weight layout from segments.
    pub fn new(atoms: Vec<Vec<Segment>>) -> AtomLayout {
        let weights = vec![1.0; atoms.len()];
        AtomLayout { atoms, weights, norm: AtomNorm::L2 }
    }

    /// One atom per first-dim row of the given tensor.
    pub fn rows_of(store: &ParamStore, tensor_name: &str) -> Vec<Vec<Segment>> {
        let ti = store.index(tensor_name);
        let t = &store.tensors[ti];
        let rl = t.row_len();
        (0..t.rows())
            .map(|r| vec![Segment { tensor: ti, start: r * rl, len: rl }])
            .collect()
    }

    /// One atom per column of a 2-D tensor (strided: one segment per row).
    pub fn cols_of(store: &ParamStore, tensor_name: &str) -> Vec<Vec<Segment>> {
        let ti = store.index(tensor_name);
        let t = &store.tensors[ti];
        assert_eq!(t.shape.len(), 2, "cols_of needs a matrix");
        let (rows, cols) = (t.shape[0], t.shape[1]);
        (0..cols)
            .map(|c| {
                (0..rows)
                    .map(|r| Segment { tensor: ti, start: r * cols + c, len: 1 })
                    .collect()
            })
            .collect()
    }

    /// Total f32 elements across the atom's segments.
    pub fn atom_len(&self, atom: usize) -> usize {
        self.atoms[atom].iter().map(|s| s.len).sum()
    }

    /// Sum of all atom lengths.
    pub fn total_len(&self) -> usize {
        (0..self.atoms.len()).map(|a| self.atom_len(a)).sum()
    }

    /// Every (tensor, element) covered at most once? (proptest invariant)
    pub fn is_disjoint(&self, store: &ParamStore) -> bool {
        let mut seen: Vec<Vec<bool>> =
            store.tensors.iter().map(|t| vec![false; t.len()]).collect();
        for segs in &self.atoms {
            for s in segs {
                for i in s.start..s.start + s.len {
                    if seen[s.tensor][i] {
                        return false;
                    }
                    seen[s.tensor][i] = true;
                }
            }
        }
        true
    }
}

/// The coordinator-side value store: the job's full parameter state.
#[derive(Debug, Clone, Default)]
pub struct ParamStore {
    pub tensors: Vec<Tensor>,
    index: HashMap<String, usize>,
}

impl ParamStore {
    pub fn new(tensors: Vec<Tensor>) -> ParamStore {
        let index = tensors
            .iter()
            .enumerate()
            .map(|(i, t)| (t.name.clone(), i))
            .collect();
        ParamStore { tensors, index }
    }

    pub fn index(&self, name: &str) -> usize {
        *self
            .index
            .get(name)
            .unwrap_or_else(|| panic!("no tensor named '{name}'"))
    }

    pub fn get(&self, name: &str) -> &Tensor {
        &self.tensors[self.index(name)]
    }

    pub fn get_mut(&mut self, name: &str) -> &mut Tensor {
        let i = self.index(name);
        &mut self.tensors[i]
    }

    pub fn total_elems(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Copy an atom's values out into a flat buffer.
    pub fn read_atom(&self, layout: &AtomLayout, atom: usize, out: &mut Vec<f32>) {
        out.clear();
        for s in &layout.atoms[atom] {
            out.extend_from_slice(&self.tensors[s.tensor].data[s.start..s.start + s.len]);
        }
    }

    /// Overwrite an atom's values from a flat buffer.
    pub fn write_atom(&mut self, layout: &AtomLayout, atom: usize, vals: &[f32]) {
        let mut off = 0;
        for s in &layout.atoms[atom] {
            self.tensors[s.tensor].data[s.start..s.start + s.len]
                .copy_from_slice(&vals[off..off + s.len]);
            off += s.len;
        }
        assert_eq!(off, vals.len(), "atom value length mismatch");
    }

    /// L2 distance between this store and another over one atom, honoring
    /// the layout's norm and weight (used by priority selection and by the
    /// perturbation-size accounting for Theorem 3.2).
    pub fn atom_distance(&self, other: &ParamStore, layout: &AtomLayout, atom: usize) -> f64 {
        let w = layout.weights[atom];
        match layout.norm {
            AtomNorm::L2 => {
                let mut acc = 0.0f64;
                for s in &layout.atoms[atom] {
                    let a = &self.tensors[s.tensor].data[s.start..s.start + s.len];
                    let b = &other.tensors[s.tensor].data[s.start..s.start + s.len];
                    for (x, y) in a.iter().zip(b) {
                        let d = (*x as f64) - (*y as f64);
                        acc += d * d;
                    }
                }
                acc.sqrt() * w
            }
            AtomNorm::ScaledTv => {
                // Normalize both sides over the atom, then 0.5*L1.
                let (mut sa, mut sb) = (0.0f64, 0.0f64);
                for s in &layout.atoms[atom] {
                    sa += self.tensors[s.tensor].data[s.start..s.start + s.len]
                        .iter()
                        .map(|&x| x as f64)
                        .sum::<f64>();
                    sb += other.tensors[s.tensor].data[s.start..s.start + s.len]
                        .iter()
                        .map(|&x| x as f64)
                        .sum::<f64>();
                }
                let (sa, sb) = (sa.max(1e-12), sb.max(1e-12));
                let mut acc = 0.0f64;
                for s in &layout.atoms[atom] {
                    let a = &self.tensors[s.tensor].data[s.start..s.start + s.len];
                    let b = &other.tensors[s.tensor].data[s.start..s.start + s.len];
                    for (x, y) in a.iter().zip(b) {
                        acc += ((*x as f64) / sa - (*y as f64) / sb).abs();
                    }
                }
                0.5 * acc * w
            }
        }
    }

    /// Whole-state L2 distance (the perturbation size ‖δ‖ of §3).
    pub fn l2_distance(&self, other: &ParamStore) -> f64 {
        let mut acc = 0.0f64;
        for (a, b) in self.tensors.iter().zip(&other.tensors) {
            debug_assert_eq!(a.len(), b.len());
            for (x, y) in a.data.iter().zip(&b.data) {
                let d = (*x as f64) - (*y as f64);
                acc += d * d;
            }
        }
        acc.sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> ParamStore {
        ParamStore::new(vec![
            Tensor::from_vec("w", &[3, 2], vec![0., 1., 2., 3., 4., 5.]),
            Tensor::from_vec("b", &[2], vec![10., 20.]),
        ])
    }

    #[test]
    fn row_atoms_cover_tensor() {
        let s = store();
        let atoms = AtomLayout::rows_of(&s, "w");
        assert_eq!(atoms.len(), 3);
        let layout = AtomLayout::new(atoms);
        assert_eq!(layout.total_len(), 6);
        assert!(layout.is_disjoint(&s));
    }

    #[test]
    fn col_atoms_are_strided() {
        let s = store();
        let atoms = AtomLayout::cols_of(&s, "w");
        let layout = AtomLayout::new(atoms);
        assert_eq!(layout.n_atoms(), 2);
        let mut buf = Vec::new();
        s.read_atom(&layout, 1, &mut buf);
        assert_eq!(buf, vec![1., 3., 5.]);
        assert!(layout.is_disjoint(&s));
    }

    #[test]
    fn read_write_roundtrip() {
        let mut s = store();
        let layout = AtomLayout::new(AtomLayout::rows_of(&s, "w"));
        let mut buf = Vec::new();
        s.read_atom(&layout, 2, &mut buf);
        assert_eq!(buf, vec![4., 5.]);
        s.write_atom(&layout, 2, &[9., 9.]);
        assert_eq!(s.get("w").data, vec![0., 1., 2., 3., 9., 9.]);
    }

    #[test]
    fn distances() {
        let a = store();
        let mut b = store();
        b.get_mut("w").data[0] = 3.0; // delta of 3 at one element
        assert!((a.l2_distance(&b) - 3.0).abs() < 1e-9);
        let layout = AtomLayout::new(AtomLayout::rows_of(&a, "w"));
        assert!((a.atom_distance(&b, &layout, 0) - 3.0).abs() < 1e-9);
        assert_eq!(a.atom_distance(&b, &layout, 1), 0.0);
    }

    #[test]
    fn tv_distance_normalizes() {
        let a = ParamStore::new(vec![Tensor::from_vec("t", &[4], vec![1., 1., 1., 1.])]);
        let b = ParamStore::new(vec![Tensor::from_vec("t", &[4], vec![2., 2., 2., 2.])]);
        let mut layout = AtomLayout::new(vec![vec![Segment { tensor: 0, start: 0, len: 4 }]]);
        layout.norm = AtomNorm::ScaledTv;
        // Same distribution after normalization => TV distance 0.
        assert!(a.atom_distance(&b, &layout, 0).abs() < 1e-9);
    }
}
