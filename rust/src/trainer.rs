//! The `Trainer` abstraction: one iterative-convergent training job.
//!
//! A trainer owns the full job state (a [`ParamStore`]) plus its atom
//! decomposition, and advances it one iteration at a time — eq. (1)'s
//! `x(k+1) = f(x(k))`. Implementations:
//!
//! * [`crate::models::HloTrainer`] — artifact-backed (QP, MLR, MF, CNN,
//!   Transformer): the step executes AOT-compiled HLO via PJRT.
//! * [`crate::models::lda::LdaTrainer`] — pure-Rust collapsed Gibbs
//!   sampler (inherently sequential per-token state; see DESIGN.md).
//!
//! Determinism contract: `step(iter)` must depend only on (seed, iter,
//! current state) — the harness replays trajectories from mid-run
//! snapshots and the data stream must reproduce exactly.

use anyhow::Result;

use crate::params::{AtomLayout, ParamStore};

pub trait Trainer {
    fn name(&self) -> &str;

    /// Reset parameters and data stream to the initial state for `seed`.
    fn init(&mut self, seed: u64) -> Result<()>;

    /// Run iteration `iter` (0-based), returning the post-step loss.
    fn step(&mut self, iter: usize) -> Result<f64>;

    fn state(&self) -> &ParamStore;

    fn state_mut(&mut self) -> &mut ParamStore;

    fn layout(&self) -> &AtomLayout;

    /// Replace the full job state (used when resuming from snapshots).
    fn set_state(&mut self, state: ParamStore) {
        *self.state_mut() = state;
    }

    /// Lower is better for every workload in the paper (losses /
    /// negative log-likelihood).
    fn loss_name(&self) -> &str {
        "loss"
    }
}
