//! Run configuration: JSON config files + CLI overrides.
//!
//! A `RunConfig` fully describes one training job under SCAR: the model
//! variant, the PS topology, the checkpoint policy, the recovery mode and
//! the failure-injection schedule. `scar train --config run.json
//! --override key=value ...` is the launcher entry point.

use std::path::Path;
use std::str::FromStr;

use anyhow::{bail, Context, Result};

use crate::checkpoint::{CheckpointMode, CheckpointPolicy, Selector};
use crate::failure::FailurePlan;
use crate::recovery::RecoveryMode;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Artifact variant name (or `lda_<dataset>` for the Rust substrate).
    pub model: String,
    pub seed: u64,
    /// Iterations to run (0 = run to the convergence target).
    pub iters: usize,
    /// Target iterations used to fix the convergence threshold ε.
    pub target_iters: usize,
    pub ps_nodes: usize,
    pub workers: usize,
    /// Base (full-checkpoint) interval C.
    pub checkpoint_interval: usize,
    /// Partial-checkpoint divisor k: fraction 1/k every C/k iterations.
    pub checkpoint_k: usize,
    /// Barrier write mode: `sync` blocks on storage; `async` hands the
    /// barrier snapshot to the background writer pool.
    pub checkpoint_mode: CheckpointMode,
    /// Shards the checkpoint store stripes atoms over.
    pub storage_shards: usize,
    /// Writer threads serving the shards in async mode (0 = one per
    /// shard).
    pub storage_writers: usize,
    /// Async back-pressure bound: a barrier blocks once more than this
    /// many write jobs are pending (0 = unbounded).
    pub storage_max_pending: usize,
    /// Garbage-ratio threshold for disk-shard segment compaction at
    /// flush fences (0 = never compact).
    pub storage_compact_threshold: f64,
    /// Minimum on-disk shard bytes before compaction runs.
    pub storage_compact_min_bytes: usize,
    /// Per-pass segment-byte budget for generational compaction
    /// (0 = monolithic full-shard passes).
    pub storage_compact_max_bytes_per_pass: usize,
    /// Group-commit write batching: one coalesced write + one durability
    /// barrier per shard per fence (byte-identical to per-record writes;
    /// no-op on memory shards).
    pub storage_group_commit: bool,
    /// Erasure-coded parity shards (0 = off, 1 = single-parity XOR
    /// coding): flush fences encode each stripe of atom records into a
    /// parity record, so a dead shard's slice is reconstructable from
    /// survivors alone and CRC-failed records are repaired in place.
    pub storage_parity: usize,
    pub selector: Selector,
    pub recovery: RecoveryMode,
    /// Inject a failure? (fraction of atoms lost; 0 disables)
    pub fail_fraction: f64,
    /// Geometric parameter for the failure iteration.
    pub fail_geom_p: f64,
    /// Failure model: single | correlated | cascade | flaky (see
    /// [`FailurePlan`]). `correlated` kills `fail_nodes` of `ps_nodes`
    /// together; the others lose `fail_fraction` of atoms.
    pub fail_plan: String,
    /// Correlated plan: PS nodes killed together.
    pub fail_nodes: usize,
    /// Cascade plan: follow-up failures after the first.
    pub fail_cascade_extra: usize,
    /// Cascade plan: iterations between failures.
    pub fail_cascade_gap: usize,
    /// Flaky plan: iterations between repeat occasions.
    pub fail_flaky_period: usize,
    /// Flaky plan: probability each later occasion fires.
    pub fail_flaky_prob: f64,
    /// Flaky plan: maximum occasions.
    pub fail_flaky_max: usize,
    /// Where checkpoints go (empty = in-memory store).
    pub checkpoint_dir: String,
    /// Injected storage-fault schedule in the compact CLI grammar
    /// ([`FaultPlan::parse_spec`](crate::chaos::FaultPlan::parse_spec)):
    /// comma-separated `kill:1@6..9`,
    /// `slow:0@4..9x50`, `torn:2@8`, `part:0@4..12`, `flaky:2@5p8d3c2`,
    /// `fsync:0@7`, `bitflip:1@6a9` entries. Empty = no chaos.
    pub chaos: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "mlr_covtype".to_string(),
            seed: 42,
            iters: 100,
            target_iters: 60,
            ps_nodes: 4,
            workers: 1,
            checkpoint_interval: 8,
            checkpoint_k: 1,
            checkpoint_mode: CheckpointMode::Sync,
            storage_shards: 1,
            storage_writers: 0,
            storage_max_pending: 0,
            storage_compact_threshold: 0.0,
            storage_compact_min_bytes: 0,
            storage_compact_max_bytes_per_pass: 0,
            storage_group_commit: false,
            storage_parity: 0,
            selector: Selector::Priority,
            recovery: RecoveryMode::Partial,
            fail_fraction: 0.0,
            fail_geom_p: 0.05,
            fail_plan: "single".to_string(),
            fail_nodes: 1,
            fail_cascade_extra: 1,
            fail_cascade_gap: 5,
            fail_flaky_period: 5,
            fail_flaky_prob: 0.5,
            fail_flaky_max: 5,
            checkpoint_dir: String::new(),
            chaos: String::new(),
        }
    }
}

impl RunConfig {
    pub fn policy(&self) -> CheckpointPolicy {
        CheckpointPolicy::partial(self.checkpoint_interval, self.checkpoint_k, self.selector)
    }

    pub fn from_file(path: &Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let v = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        let mut cfg = RunConfig::default();
        let obj = v.as_obj().context("config must be a JSON object")?;
        // `chaos` validates against `storage_shards`, so apply it after
        // every other key regardless of the file's key order.
        let mut keys: Vec<&String> = obj.keys().collect();
        keys.sort_by_key(|k| *k == "chaos");
        for k in keys {
            cfg.apply(k, &json_to_str(&obj[k]))?;
        }
        Ok(cfg)
    }

    /// Apply one `key=value` override.
    pub fn apply(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "model" => self.model = value.to_string(),
            "seed" => self.seed = value.parse().context("seed")?,
            "iters" => self.iters = value.parse().context("iters")?,
            "target_iters" => self.target_iters = value.parse().context("target_iters")?,
            "ps_nodes" => self.ps_nodes = value.parse().context("ps_nodes")?,
            "workers" => self.workers = value.parse().context("workers")?,
            "checkpoint_interval" => {
                self.checkpoint_interval = value.parse().context("checkpoint_interval")?
            }
            "checkpoint_k" => self.checkpoint_k = value.parse().context("checkpoint_k")?,
            "checkpoint_mode" => {
                self.checkpoint_mode =
                    CheckpointMode::from_str(value).map_err(anyhow::Error::msg)?
            }
            "storage_shards" => {
                self.storage_shards = value.parse().context("storage_shards")?
            }
            "storage_writers" => {
                self.storage_writers = value.parse().context("storage_writers")?
            }
            "storage_max_pending" => {
                self.storage_max_pending = value.parse().context("storage_max_pending")?
            }
            "storage_compact_threshold" => {
                self.storage_compact_threshold =
                    value.parse().context("storage_compact_threshold")?
            }
            "storage_compact_min_bytes" => {
                self.storage_compact_min_bytes =
                    value.parse().context("storage_compact_min_bytes")?
            }
            "storage_compact_max_bytes_per_pass" => {
                self.storage_compact_max_bytes_per_pass =
                    value.parse().context("storage_compact_max_bytes_per_pass")?
            }
            "storage_group_commit" => {
                self.storage_group_commit = value.parse().context("storage_group_commit")?
            }
            "storage_parity" => {
                self.storage_parity = value.parse().context("storage_parity")?
            }
            "selector" => {
                self.selector = Selector::from_str(value).map_err(anyhow::Error::msg)?
            }
            "recovery" => {
                self.recovery = RecoveryMode::from_str(value).map_err(anyhow::Error::msg)?
            }
            "fail_fraction" => self.fail_fraction = value.parse().context("fail_fraction")?,
            "fail_geom_p" => self.fail_geom_p = value.parse().context("fail_geom_p")?,
            "fail_plan" => self.fail_plan = value.to_string(),
            "fail_nodes" => self.fail_nodes = value.parse().context("fail_nodes")?,
            "fail_cascade_extra" => {
                self.fail_cascade_extra = value.parse().context("fail_cascade_extra")?
            }
            "fail_cascade_gap" => {
                self.fail_cascade_gap = value.parse().context("fail_cascade_gap")?
            }
            "fail_flaky_period" => {
                self.fail_flaky_period = value.parse().context("fail_flaky_period")?
            }
            "fail_flaky_prob" => {
                self.fail_flaky_prob = value.parse().context("fail_flaky_prob")?
            }
            "fail_flaky_max" => self.fail_flaky_max = value.parse().context("fail_flaky_max")?,
            "checkpoint_dir" => self.checkpoint_dir = value.to_string(),
            "chaos" => self.chaos = value.to_string(),
            other => bail!("unknown config key '{other}'"),
        }
        self.validate()
    }

    pub fn validate(&self) -> Result<()> {
        if self.ps_nodes == 0 {
            bail!("ps_nodes must be >= 1");
        }
        if self.checkpoint_interval == 0 {
            bail!("checkpoint_interval must be >= 1");
        }
        if self.checkpoint_k == 0 || self.checkpoint_k > self.checkpoint_interval {
            bail!(
                "checkpoint_k must be in [1, checkpoint_interval={}]",
                self.checkpoint_interval
            );
        }
        if self.storage_shards == 0 {
            bail!("storage_shards must be >= 1");
        }
        if !(0.0..1.0).contains(&self.storage_compact_threshold) {
            bail!(
                "storage_compact_threshold must be in [0, 1), got {}",
                self.storage_compact_threshold
            );
        }
        if self.storage_parity > 1 {
            bail!(
                "storage_parity must be 0 or 1 (only single-parity XOR coding is \
                 implemented), got {}",
                self.storage_parity
            );
        }
        if !(0.0..=1.0).contains(&self.fail_fraction) {
            bail!("fail_fraction must be in [0, 1]");
        }
        if !(0.0..1.0).contains(&self.fail_geom_p) && self.fail_geom_p != 1.0 {
            bail!("fail_geom_p must be in (0, 1]");
        }
        if !["single", "correlated", "cascade", "flaky"].contains(&self.fail_plan.as_str()) {
            bail!(
                "fail_plan must be one of single|correlated|cascade|flaky, got '{}'",
                self.fail_plan
            );
        }
        if let Some(plan) = self.failure_plan() {
            plan.validate().map_err(anyhow::Error::msg)?;
        }
        // Chaos spec: both the grammar and the plan's shard/epoch rules
        // must hold against the configured shard count.
        crate::chaos::FaultPlan::parse_spec(&self.chaos)?.validate(self.storage_shards)?;
        Ok(())
    }

    /// The parsed storage-fault schedule (empty plan when no `chaos` key
    /// is set). `validate` has already checked it, so this cannot fail
    /// on a validated config.
    pub fn chaos_plan(&self) -> Result<crate::chaos::FaultPlan> {
        crate::chaos::FaultPlan::parse_spec(&self.chaos)
    }

    /// Writer-pool size after resolving the `0 = one per shard` default.
    pub fn effective_writers(&self) -> usize {
        if self.storage_writers == 0 {
            self.storage_shards
        } else {
            self.storage_writers
        }
    }

    /// The configured failure model, or `None` when failure injection is
    /// disabled (`fail_fraction = 0` for atom-loss plans).
    pub fn failure_plan(&self) -> Option<FailurePlan> {
        match self.fail_plan.as_str() {
            "correlated" => Some(FailurePlan::Correlated {
                nodes: self.fail_nodes,
                of_nodes: self.ps_nodes,
            }),
            _ if self.fail_fraction <= 0.0 => None,
            "single" => Some(FailurePlan::Single { fraction: self.fail_fraction }),
            "cascade" => Some(FailurePlan::Cascade {
                fraction: self.fail_fraction,
                extra: self.fail_cascade_extra,
                gap: self.fail_cascade_gap,
            }),
            "flaky" => Some(FailurePlan::Flaky {
                fraction: self.fail_fraction,
                period: self.fail_flaky_period,
                prob: self.fail_flaky_prob,
                max_events: self.fail_flaky_max,
            }),
            _ => None,
        }
    }
}

fn json_to_str(v: &Json) -> String {
    match v {
        Json::Str(s) => s.clone(),
        other => other.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn overrides_apply() {
        let mut cfg = RunConfig::default();
        cfg.apply("model", "mf_jester").unwrap();
        cfg.apply("checkpoint_k", "4").unwrap();
        cfg.apply("selector", "random").unwrap();
        cfg.apply("recovery", "full").unwrap();
        assert_eq!(cfg.model, "mf_jester");
        assert_eq!(cfg.policy().fraction, 0.25);
        assert_eq!(cfg.selector, Selector::Random);
        assert_eq!(cfg.recovery, RecoveryMode::Full);
    }

    #[test]
    fn rejects_bad_values() {
        let mut cfg = RunConfig::default();
        assert!(cfg.apply("checkpoint_k", "0").is_err());
        assert!(cfg.apply("nonsense", "1").is_err());
        assert!(cfg.apply("fail_fraction", "1.5").is_err());
    }

    #[test]
    fn storage_and_mode_keys_apply() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.checkpoint_mode, CheckpointMode::Sync);
        cfg.apply("checkpoint_mode", "async").unwrap();
        cfg.apply("storage_shards", "4").unwrap();
        assert_eq!(cfg.checkpoint_mode, CheckpointMode::Async);
        assert_eq!(cfg.effective_writers(), 4, "writers default to one per shard");
        cfg.apply("storage_writers", "2").unwrap();
        assert_eq!(cfg.effective_writers(), 2);
        cfg.apply("storage_max_pending", "3").unwrap();
        assert_eq!(cfg.storage_max_pending, 3);
        cfg.apply("storage_compact_threshold", "0.4").unwrap();
        cfg.apply("storage_compact_min_bytes", "1024").unwrap();
        assert!((cfg.storage_compact_threshold - 0.4).abs() < 1e-12);
        assert_eq!(cfg.storage_compact_min_bytes, 1024);
        cfg.apply("storage_parity", "1").unwrap();
        assert_eq!(cfg.storage_parity, 1);
        cfg.apply("storage_compact_max_bytes_per_pass", "65536").unwrap();
        assert_eq!(cfg.storage_compact_max_bytes_per_pass, 65536);
        cfg.apply("storage_group_commit", "true").unwrap();
        assert!(cfg.storage_group_commit);
        assert!(cfg.apply("storage_group_commit", "yes").is_err());
        assert!(cfg.apply("storage_shards", "0").is_err());
        assert!(cfg.apply("checkpoint_mode", "never").is_err());
        assert!(cfg.apply("storage_compact_threshold", "1.5").is_err());
        // Only single-parity coding exists.
        assert!(cfg.apply("storage_parity", "2").is_err());
    }

    #[test]
    fn failure_plan_keys() {
        let mut cfg = RunConfig::default();
        assert!(cfg.failure_plan().is_none(), "disabled by default");
        cfg.apply("fail_fraction", "0.25").unwrap();
        assert_eq!(
            cfg.failure_plan(),
            Some(FailurePlan::Single { fraction: 0.25 })
        );
        cfg.apply("fail_plan", "cascade").unwrap();
        cfg.apply("fail_cascade_extra", "3").unwrap();
        cfg.apply("fail_cascade_gap", "7").unwrap();
        assert_eq!(
            cfg.failure_plan(),
            Some(FailurePlan::Cascade { fraction: 0.25, extra: 3, gap: 7 })
        );
        cfg.apply("fail_plan", "correlated").unwrap();
        cfg.apply("fail_nodes", "2").unwrap();
        assert_eq!(
            cfg.failure_plan(),
            Some(FailurePlan::Correlated { nodes: 2, of_nodes: cfg.ps_nodes })
        );
        assert!(cfg.apply("fail_plan", "meteor").is_err());
        // apply() restores nothing on error, so reset before the flaky case.
        cfg.fail_plan = "flaky".to_string();
        cfg.apply("fail_flaky_prob", "0.9").unwrap();
        assert!(matches!(
            cfg.failure_plan(),
            Some(FailurePlan::Flaky { prob, .. }) if (prob - 0.9).abs() < 1e-12
        ));
    }

    #[test]
    fn chaos_key_parses_and_validates_against_shards() {
        use crate::chaos::FaultKind;
        let mut cfg = RunConfig::default();
        cfg.apply("storage_shards", "3").unwrap();
        cfg.apply("chaos", "kill:1@6..9,part:0@4..12,bitflip:2@5a8").unwrap();
        let plan = cfg.chaos_plan().unwrap();
        assert_eq!(plan.faults.len(), 3);
        assert_eq!(plan.faults[0].kind, FaultKind::Kill { heal_at: Some(9) });
        assert_eq!(plan.faults[2].kind, FaultKind::Bitflip { atom: 8 });
        // Out-of-range shard and grammar errors are rejected.
        assert!(cfg.apply("chaos", "kill:7@6").is_err());
        assert!(cfg.apply("chaos", "meteor:0@6").is_err());
        // A single-shard store cannot lose its only shard.
        let mut one = RunConfig::default();
        assert!(one.apply("chaos", "kill:0@6").is_err());
        // A config *file* may list `chaos` before `storage_shards`
        // (BTreeMap order); from_file must still accept it.
        let dir = std::env::temp_dir().join(format!("scar-cfg-chaos-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("run.json");
        std::fs::write(&p, r#"{"chaos":"kill:1@6","storage_shards":2}"#).unwrap();
        let cfg = RunConfig::from_file(&p).unwrap();
        assert_eq!(cfg.chaos_plan().unwrap().faults.len(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn parses_config_file() {
        let dir = std::env::temp_dir().join(format!("scar-cfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("run.json");
        std::fs::write(&p, r#"{"model":"qp4","iters":200,"selector":"round"}"#).unwrap();
        let cfg = RunConfig::from_file(&p).unwrap();
        assert_eq!(cfg.model, "qp4");
        assert_eq!(cfg.iters, 200);
        assert_eq!(cfg.selector, Selector::RoundRobin);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
