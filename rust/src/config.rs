//! Run configuration: JSON config files + CLI overrides.
//!
//! A `RunConfig` fully describes one training job under SCAR: the model
//! variant, the PS topology, the checkpoint policy, the recovery mode and
//! the failure-injection schedule. `scar train --config run.json
//! --override key=value ...` is the launcher entry point.

use std::path::Path;
use std::str::FromStr;

use anyhow::{bail, Context, Result};

use crate::checkpoint::{CheckpointPolicy, Selector};
use crate::recovery::RecoveryMode;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Artifact variant name (or `lda_<dataset>` for the Rust substrate).
    pub model: String,
    pub seed: u64,
    /// Iterations to run (0 = run to the convergence target).
    pub iters: usize,
    /// Target iterations used to fix the convergence threshold ε.
    pub target_iters: usize,
    pub ps_nodes: usize,
    pub workers: usize,
    /// Base (full-checkpoint) interval C.
    pub checkpoint_interval: usize,
    /// Partial-checkpoint divisor k: fraction 1/k every C/k iterations.
    pub checkpoint_k: usize,
    pub selector: Selector,
    pub recovery: RecoveryMode,
    /// Inject a failure? (fraction of atoms lost; 0 disables)
    pub fail_fraction: f64,
    /// Geometric parameter for the failure iteration.
    pub fail_geom_p: f64,
    /// Where checkpoints go (empty = in-memory store).
    pub checkpoint_dir: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "mlr_covtype".to_string(),
            seed: 42,
            iters: 100,
            target_iters: 60,
            ps_nodes: 4,
            workers: 1,
            checkpoint_interval: 8,
            checkpoint_k: 1,
            selector: Selector::Priority,
            recovery: RecoveryMode::Partial,
            fail_fraction: 0.0,
            fail_geom_p: 0.05,
            checkpoint_dir: String::new(),
        }
    }
}

impl RunConfig {
    pub fn policy(&self) -> CheckpointPolicy {
        CheckpointPolicy::partial(self.checkpoint_interval, self.checkpoint_k, self.selector)
    }

    pub fn from_file(path: &Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let v = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        let mut cfg = RunConfig::default();
        let obj = v.as_obj().context("config must be a JSON object")?;
        for (k, val) in obj {
            cfg.apply(k, &json_to_str(val))?;
        }
        Ok(cfg)
    }

    /// Apply one `key=value` override.
    pub fn apply(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "model" => self.model = value.to_string(),
            "seed" => self.seed = value.parse().context("seed")?,
            "iters" => self.iters = value.parse().context("iters")?,
            "target_iters" => self.target_iters = value.parse().context("target_iters")?,
            "ps_nodes" => self.ps_nodes = value.parse().context("ps_nodes")?,
            "workers" => self.workers = value.parse().context("workers")?,
            "checkpoint_interval" => {
                self.checkpoint_interval = value.parse().context("checkpoint_interval")?
            }
            "checkpoint_k" => self.checkpoint_k = value.parse().context("checkpoint_k")?,
            "selector" => {
                self.selector = Selector::from_str(value).map_err(anyhow::Error::msg)?
            }
            "recovery" => {
                self.recovery = RecoveryMode::from_str(value).map_err(anyhow::Error::msg)?
            }
            "fail_fraction" => self.fail_fraction = value.parse().context("fail_fraction")?,
            "fail_geom_p" => self.fail_geom_p = value.parse().context("fail_geom_p")?,
            "checkpoint_dir" => self.checkpoint_dir = value.to_string(),
            other => bail!("unknown config key '{other}'"),
        }
        self.validate()
    }

    pub fn validate(&self) -> Result<()> {
        if self.ps_nodes == 0 {
            bail!("ps_nodes must be >= 1");
        }
        if self.checkpoint_interval == 0 {
            bail!("checkpoint_interval must be >= 1");
        }
        if self.checkpoint_k == 0 || self.checkpoint_k > self.checkpoint_interval {
            bail!(
                "checkpoint_k must be in [1, checkpoint_interval={}]",
                self.checkpoint_interval
            );
        }
        if !(0.0..=1.0).contains(&self.fail_fraction) {
            bail!("fail_fraction must be in [0, 1]");
        }
        if !(0.0..1.0).contains(&self.fail_geom_p) && self.fail_geom_p != 1.0 {
            bail!("fail_geom_p must be in (0, 1]");
        }
        Ok(())
    }
}

fn json_to_str(v: &Json) -> String {
    match v {
        Json::Str(s) => s.clone(),
        other => other.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn overrides_apply() {
        let mut cfg = RunConfig::default();
        cfg.apply("model", "mf_jester").unwrap();
        cfg.apply("checkpoint_k", "4").unwrap();
        cfg.apply("selector", "random").unwrap();
        cfg.apply("recovery", "full").unwrap();
        assert_eq!(cfg.model, "mf_jester");
        assert_eq!(cfg.policy().fraction, 0.25);
        assert_eq!(cfg.selector, Selector::Random);
        assert_eq!(cfg.recovery, RecoveryMode::Full);
    }

    #[test]
    fn rejects_bad_values() {
        let mut cfg = RunConfig::default();
        assert!(cfg.apply("checkpoint_k", "0").is_err());
        assert!(cfg.apply("nonsense", "1").is_err());
        assert!(cfg.apply("fail_fraction", "1.5").is_err());
    }

    #[test]
    fn parses_config_file() {
        let dir = std::env::temp_dir().join(format!("scar-cfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("run.json");
        std::fs::write(&p, r#"{"model":"qp4","iters":200,"selector":"round"}"#).unwrap();
        let cfg = RunConfig::from_file(&p).unwrap();
        assert_eq!(cfg.model, "qp4");
        assert_eq!(cfg.iters, 200);
        assert_eq!(cfg.selector, Selector::RoundRobin);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
