//! Runtime policy advisor — the paper's §7 future-work item, implemented:
//!
//! > "By approximating c and ‖x⁽⁰⁾ − x*‖, we may obtain a predictive model
//! > which can be evaluated on-the-fly to inform decisions made by a
//! > system during run-time."
//!
//! [`OnlineRateEstimator`] tracks the contraction rate `c` from the live
//! loss curve (no x* needed: for linearly-convergent iterates the excess
//! loss ratio tends to the same c; we use robust one-step ratios of the
//! loss *decrement*, which is ∝ the error for smooth objectives).
//!
//! [`recommend_policy`] evaluates Theorem 3.2 over a candidate policy
//! grid using the closed form that follows from Thm 4.2 + eq. (6):
//! with checkpoint lag L = T − C and lost fraction p, the expected
//! perturbation is E‖δ'‖ ≈ √p · e₀c^T (c^{−L} + 1), so
//! `ι(L, p) ≤ log(1 + √p (c^{−L} + 1)) / log(1/c)` —
//! notably independent of T itself. Expected total overhead per
//! failure window then trades rework iterations against dump cost, the
//! same structure as Daly's optimum-checkpoint-interval analysis but with
//! SCAR's partial-recovery iteration cost in place of full rework.

use crate::checkpoint::{CheckpointPolicy, Selector};

/// Online estimate of the contraction rate from observed losses.
///
/// For a linearly-convergent sequence loss_k = ℓ* + A·c^k, successive
/// *decrements* d_k = loss_{k-1} − loss_k = A c^{k-1}(1−c) also decay at
/// exactly rate c, and unlike excess-over-floor they need no estimate of
/// ℓ*. The estimator keeps a sliding window of losses, EMA-smooths the
/// curve (stochastic trainers produce noisy losses), and fits
/// log(decrement) against iteration by least squares — `exp(slope)` is c.
#[derive(Debug, Clone)]
pub struct OnlineRateEstimator {
    /// smoothing factor for the loss curve
    smooth_alpha: f64,
    /// (iteration index, smoothed loss)
    window: std::collections::VecDeque<(usize, f64)>,
    window_cap: usize,
    smoothed: Option<f64>,
    n: usize,
}

impl Default for OnlineRateEstimator {
    fn default() -> Self {
        Self::new(0.3)
    }
}

impl OnlineRateEstimator {
    pub fn new(smooth_alpha: f64) -> Self {
        OnlineRateEstimator {
            smooth_alpha,
            window: std::collections::VecDeque::new(),
            window_cap: 512,
            smoothed: None,
            n: 0,
        }
    }

    /// Feed the loss after one iteration.
    pub fn observe(&mut self, loss: f64) {
        if !loss.is_finite() {
            return;
        }
        let s = match self.smoothed {
            None => loss,
            Some(prev) => (1.0 - self.smooth_alpha) * prev + self.smooth_alpha * loss,
        };
        self.smoothed = Some(s);
        self.window.push_back((self.n, s));
        if self.window.len() > self.window_cap {
            self.window.pop_front();
        }
        self.n += 1;
    }

    /// Current estimate of c (None until the window holds enough clearly
    /// improving observations for the fit to be trustworthy).
    ///
    /// Degenerate windows return `None` instead of a bogus rate:
    ///
    /// * decrements below `RATE_EPS` of the loss scale are numeric
    ///   jitter, not progress — a flat (or float-jittering) curve never
    ///   reaches the fit;
    /// * a window where improvements fail to outnumber regressions 2:1
    ///   is noise-dominated: the log-decrement fit would chase noise;
    /// * a non-negative fitted slope means the decrements are not
    ///   shrinking (c >= 1) — not a contraction, so there is nothing for
    ///   the Thm 3.2 model to price.
    pub fn rate(&self) -> Option<f64> {
        const RATE_EPS: f64 = 1e-12;
        let mut worsening = 0usize;
        let pts: Vec<(f64, f64)> = self
            .window
            .iter()
            .zip(self.window.iter().skip(1))
            .filter_map(|(&(_, a), &(k, b))| {
                let dec = a - b;
                let eps = RATE_EPS * a.abs().max(1.0);
                if dec < -eps {
                    worsening += 1;
                }
                (dec > eps).then(|| (k as f64, dec.ln()))
            })
            .collect();
        if pts.len() < 8 || pts.len() < 2 * worsening {
            return None;
        }
        let xs: Vec<f64> = pts.iter().map(|p| p.0).collect();
        let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
        let (_, slope) = crate::util::stats::linfit(&xs, &ys);
        if slope >= 0.0 {
            return None;
        }
        Some(slope.exp().clamp(1e-3, 0.99999))
    }

    pub fn observations(&self) -> usize {
        self.n
    }
}

/// Environment + cost model inputs for a recommendation.
#[derive(Debug, Clone)]
pub struct AdvisorInputs {
    /// Estimated contraction rate (from [`OnlineRateEstimator`] or
    /// offline fitting).
    pub c: f64,
    /// Expected fraction of parameters lost per failure (e.g. 1/n_nodes
    /// for single-node failures under random partitioning).
    pub lost_fraction: f64,
    /// Failures per iteration (geometric p of §5.3).
    pub failure_rate: f64,
    /// Seconds per training iteration.
    pub t_iter: f64,
    /// Blocking seconds per *full-size* checkpoint barrier; partial
    /// checkpoints scale this by their fraction (§4.2 parity).
    pub t_dump_full: f64,
    /// Base full-checkpoint interval C under consideration.
    pub base_interval: usize,
}

/// Expected rework iterations after one failure under lag `l` and lost
/// fraction `p` (closed form from Thm 3.2 + Thm 4.2; see module docs).
pub fn expected_rework_iters(c: f64, lag: f64, lost_fraction: f64) -> f64 {
    assert!(c > 0.0 && c < 1.0);
    let p = lost_fraction.clamp(0.0, 1.0);
    if p == 0.0 {
        return 0.0;
    }
    (1.0 + p.sqrt() * (c.powf(-lag) + 1.0)).ln() / (1.0 / c).ln()
}

/// One evaluated candidate.
#[derive(Debug, Clone)]
pub struct PolicyScore {
    pub policy: CheckpointPolicy,
    pub k: usize,
    /// Expected rework iterations per failure.
    pub rework_iters: f64,
    /// Expected overhead seconds per iteration (dump amortized + rework
    /// weighted by failure rate).
    pub overhead_per_iter: f64,
}

/// Evaluate the candidate grid k ∈ {1, 2, 4, 8, ...} (fraction 1/k every
/// C/k iterations; same bytes per C iterations) and return the scores
/// sorted best-first.
pub fn recommend_policy(inputs: &AdvisorInputs) -> Vec<PolicyScore> {
    assert!(inputs.c > 0.0 && inputs.c < 1.0, "advisor needs 0 < c < 1");
    let mut scores = Vec::new();
    let mut k = 1usize;
    while k <= inputs.base_interval {
        let policy = CheckpointPolicy::partial(inputs.base_interval, k, Selector::Priority);
        // Mean staleness of a parameter in the running checkpoint: half
        // the effective refresh period. Priority refreshes the
        // fastest-moving atoms sooner; we use the conservative uniform
        // mean (interval * k / 2 would be the refresh period of the
        // *coldest* atom; the mean atom is refreshed every `interval`
        // barriers when fraction 1/k covers all atoms over k barriers).
        let mean_lag = (inputs.base_interval as f64) / 2.0 + (policy.interval as f64) / 2.0;
        let rework = expected_rework_iters(inputs.c, mean_lag, inputs.lost_fraction);
        let dump_per_iter = inputs.t_dump_full * policy.fraction / policy.interval as f64;
        let overhead = dump_per_iter + inputs.failure_rate * rework * inputs.t_iter;
        scores.push(PolicyScore { policy, k, rework_iters: rework, overhead_per_iter: overhead });
        k *= 2;
    }
    scores.sort_by(|a, b| a.overhead_per_iter.partial_cmp(&b.overhead_per_iter).unwrap());
    scores
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_estimator_recovers_rate() {
        let c: f64 = 0.92;
        let mut est = OnlineRateEstimator::new(0.1);
        // loss = floor + excess with excess decaying at rate c
        for k in 0..200 {
            est.observe(1.0 + 5.0 * c.powi(k));
        }
        let got = est.rate().expect("enough observations");
        assert!((got - c).abs() < 0.03, "got {got}");
    }

    #[test]
    fn online_estimator_robust_to_noise() {
        let c: f64 = 0.9;
        let mut rng = crate::util::rng::Rng::new(5);
        let mut est = OnlineRateEstimator::new(0.05);
        for k in 0..400 {
            let noise = 1.0 + 0.1 * rng.normal();
            est.observe(2.0 + 10.0 * c.powi(k / 2) * noise.abs());
        }
        let got = est.rate().unwrap();
        assert!(got > 0.8 && got < 1.0, "got {got}");
    }

    #[test]
    fn no_rate_until_warm() {
        let mut est = OnlineRateEstimator::default();
        for k in 0..5 {
            est.observe(10.0 - k as f64);
        }
        assert!(est.rate().is_none());
    }

    #[test]
    fn flat_loss_gives_no_rate() {
        let mut est = OnlineRateEstimator::default();
        for _ in 0..100 {
            est.observe(5.0);
        }
        assert!(est.rate().is_none(), "flat loss must not produce a rate");
    }

    #[test]
    fn float_jitter_around_constant_gives_no_rate() {
        // ±1e-13 around 5.0 is numeric noise: every smoothed decrement is
        // far below the relative epsilon, so the fit never sees a point.
        let mut est = OnlineRateEstimator::default();
        for k in 0..200 {
            let jitter = if k % 2 == 0 { 1e-13 } else { -1e-13 };
            est.observe(5.0 + jitter);
        }
        assert!(est.rate().is_none(), "sub-epsilon jitter must not produce a rate");
    }

    #[test]
    fn noise_dominated_window_gives_no_rate() {
        // Pure noise around a constant: regressions are as common as
        // improvements, so the 2:1 majority guard rejects the window.
        let mut rng = crate::util::rng::Rng::new(11);
        let mut est = OnlineRateEstimator::default();
        for _ in 0..400 {
            est.observe(5.0 + 0.5 * rng.normal());
        }
        assert!(est.rate().is_none(), "noise-dominated window must not produce a rate");
    }

    #[test]
    fn increasing_loss_gives_no_rate() {
        let mut est = OnlineRateEstimator::default();
        for k in 0..100 {
            est.observe(1.0 + 0.1 * k as f64);
        }
        assert!(est.rate().is_none(), "diverging loss must not produce a rate");
    }

    #[test]
    fn rework_monotone_in_lag_and_fraction() {
        let base = expected_rework_iters(0.9, 4.0, 0.5);
        assert!(expected_rework_iters(0.9, 8.0, 0.5) > base);
        assert!(expected_rework_iters(0.9, 4.0, 0.75) > base);
        assert_eq!(expected_rework_iters(0.9, 4.0, 0.0), 0.0);
    }

    #[test]
    fn recommendation_prefers_fine_checkpoints_when_failures_frequent() {
        let mk = |failure_rate| AdvisorInputs {
            c: 0.9,
            lost_fraction: 0.5,
            failure_rate,
            t_iter: 1.0,
            t_dump_full: 0.2,
            base_interval: 8,
        };
        // Frequent failures: fine-grained (large k) should win.
        let frequent = recommend_policy(&mk(0.05));
        assert!(frequent[0].k >= 4, "frequent: {:?}", frequent[0]);
        // Failure-free: all candidates cost the same dump bytes; the
        // ordering must then follow dump amortization only and k=1 must
        // not be strictly worse than k=8.
        let rare = recommend_policy(&mk(0.0));
        let k1 = rare.iter().find(|s| s.k == 1).unwrap();
        let k8 = rare.iter().find(|s| s.k == 8).unwrap();
        assert!((k1.overhead_per_iter - k8.overhead_per_iter).abs() < 1e-9);
    }

    #[test]
    fn scores_sorted_best_first() {
        let scores = recommend_policy(&AdvisorInputs {
            c: 0.95,
            lost_fraction: 0.25,
            failure_rate: 0.01,
            t_iter: 2.0,
            t_dump_full: 0.5,
            base_interval: 8,
        });
        for w in scores.windows(2) {
            assert!(w[0].overhead_per_iter <= w[1].overhead_per_iter);
        }
    }
}
