//! Deterministic PRNG (xoshiro256++ seeded via SplitMix64).
//!
//! The `rand` crate is not in the offline set; experiments need seeded,
//! reproducible streams anyway (each trial derives an independent seed),
//! so we carry our own generator plus the handful of distributions the
//! paper's workloads require: uniform, normal (Box–Muller), geometric
//! (failure iteration, §5.3), Dirichlet/categorical (LDA corpus), and
//! subset sampling (random parameter loss).

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal deviate from Box–Muller
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (for per-trial seeding).
    pub fn derive(&self, stream: u64) -> Rng {
        let mut sm = self.s[0] ^ stream.wrapping_mul(0xA24BAED4963EE407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = (s[0].wrapping_add(s[3]))
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u = self.f64();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Geometric: number of trials until first success (support 1, 2, ...).
    /// Used for the failure-iteration distribution (paper §5.3).
    pub fn geometric(&mut self, p: f64) -> usize {
        assert!(p > 0.0 && p <= 1.0);
        let u = self.f64().max(f64::MIN_POSITIVE);
        ((u.ln() / (1.0 - p).ln()).ceil() as usize).max(1)
    }

    /// Marsaglia–Tsang gamma sampler (shape k > 0, scale 1).
    pub fn gamma(&mut self, k: f64) -> f64 {
        if k < 1.0 {
            // Boost via Gamma(k+1) * U^{1/k}.
            let g = self.gamma(k + 1.0);
            let u = self.f64().max(f64::MIN_POSITIVE);
            return g * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64().max(f64::MIN_POSITIVE);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }

    /// Symmetric Dirichlet(alpha) over `dim` categories.
    pub fn dirichlet(&mut self, alpha: f64, dim: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..dim).map(|_| self.gamma(alpha)).collect();
        let sum: f64 = v.iter().sum();
        if sum <= 0.0 {
            // Degenerate draw (all zeros): fall back to uniform.
            return vec![1.0 / dim as f64; dim];
        }
        for x in v.iter_mut() {
            *x /= sum;
        }
        v
    }

    /// Draw an index proportional to (non-negative) weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) uniformly (partial
    /// Fisher–Yates; O(n) memory, O(n) time — fine for our atom counts).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let base = Rng::new(7);
        let mut a = base.derive(1);
        let mut b = base.derive(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(1);
        let n = 20000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn geometric_mean() {
        let mut r = Rng::new(3);
        let p = 0.1;
        let n = 20000;
        let mean: f64 = (0..n).map(|_| r.geometric(p) as f64).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / p).abs() < 0.3, "mean={mean}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(4);
        for &alpha in &[0.1, 1.0, 5.0] {
            let v = r.dirichlet(alpha, 16);
            let s: f64 = v.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(v.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(5);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(6);
        let mut counts = [0usize; 5];
        for _ in 0..50000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(7);
        let w = [1.0, 3.0];
        let n = 40000;
        let ones = (0..n).filter(|_| r.categorical(&w) == 1).count();
        let frac = ones as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.02, "frac={frac}");
    }
}
