//! Small statistics toolkit for the experiment harness.
//!
//! Mean / sample-std / 95% confidence intervals for the figure error bars
//! (paper: "error bars indicate 95% confidence intervals, calculated by
//! repeating each trial 100 times"), plus least-squares line fitting used
//! to estimate the contraction rate `c` of Theorem 3.2 from an observed
//! convergence curve.

/// Summary of a sample: mean, sample std, and a 95% CI half-width.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    /// Half-width of the 95% confidence interval on the mean.
    pub ci95: f64,
}

/// z-quantile for two-sided 95% (normal approximation; trials >= 30 in all
/// sweeps, so the t-correction is below our reporting precision).
const Z95: f64 = 1.959964;

pub fn summarize(xs: &[f64]) -> Summary {
    let n = xs.len();
    if n == 0 {
        return Summary { n: 0, mean: f64::NAN, std: f64::NAN, ci95: f64::NAN };
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    if n == 1 {
        return Summary { n, mean, std: 0.0, ci95: 0.0 };
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64;
    let std = var.sqrt();
    let ci95 = Z95 * std / (n as f64).sqrt();
    Summary { n, mean, std, ci95 }
}

/// Least squares fit y = a + b*x; returns (a, b).
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    assert!(n >= 2.0, "linfit needs >= 2 points");
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
    }
    let b = if sxx > 0.0 { sxy / sxx } else { 0.0 };
    (my - b * mx, b)
}

/// Percentile (linear interpolation) of an unsorted sample, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = pos - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// L2 norm of a slice.
pub fn l2(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// L2 distance between equal-length slices.
pub fn l2_dist(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x as f64) - (y as f64);
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert!(s.ci95 > 0.0);
    }

    #[test]
    fn summary_degenerate() {
        assert!(summarize(&[]).mean.is_nan());
        let s = summarize(&[7.0]);
        assert_eq!(s.mean, 7.0);
        assert_eq!(s.ci95, 0.0);
    }

    #[test]
    fn linfit_exact_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (a, b) = linfit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn norms() {
        assert!((l2(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
        assert!((l2_dist(&[1.0, 1.0], &[4.0, 5.0]) - 5.0).abs() < 1e-9);
    }
}
