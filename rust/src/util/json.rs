//! Minimal JSON parser/serializer.
//!
//! `serde_json` is not in the offline crate set on this image, so the
//! artifact metadata (`artifacts/*.meta.json`) and run configs are parsed
//! with this self-contained implementation. It supports the full JSON
//! grammar minus exotic number forms (numbers are parsed as f64; integers
//! round-trip exactly up to 2^53, far beyond any shape we store).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so serialization
/// is deterministic — handy for golden tests and checkpoint manifests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]`-style access; returns Null for missing keys so lookups
    /// can be chained without unwrapping.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // -- serialization ---------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Convenience builder for objects: `obj([("a", 1.0.into())])`.
pub fn obj<I: IntoIterator<Item = (&'static str, Json)>>(items: I) -> Json {
    Json::Obj(items.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", s)))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are rare in our metadata;
                            // map unpaired surrogates to the replacement
                            // char rather than erroring.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b").as_str(), Some("x\ny"));
        assert_eq!(v.get("c"), &Json::Null);
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"shape":[784,10],"name":"w","kind":"param","f":1.5}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café \t \\""#).unwrap();
        assert_eq!(v.as_str(), Some("café \t \\"));
        let round = Json::parse(&v.to_string()).unwrap();
        assert_eq!(round, v);
    }

    #[test]
    fn usize_accessor() {
        assert_eq!(Json::parse("784").unwrap().as_usize(), Some(784));
        assert_eq!(Json::parse("1.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
    }
}
