//! Tiny command-line argument parser (clap is not in the offline set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and free
//! positional arguments. Typed accessors parse on demand and report the
//! offending flag in the error message.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    /// flags the program has asked about (for unknown-flag detection)
    seen: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    pub fn parse_from<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut args = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.flags.insert(body.to_string(), v);
                } else {
                    args.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse the process args, skipping argv[0].
    pub fn parse() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().insert(key.to_string());
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.mark(key);
        match self.flags.get(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.mark(key);
        match self.flags.get(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.mark(key);
        match self.flags.get(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        self.mark(key);
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }

    /// List of unknown flags (present but never queried). Call at the end
    /// of argument handling to warn about typos.
    pub fn unknown(&self) -> Vec<String> {
        let seen = self.seen.borrow();
        self.flags
            .keys()
            .filter(|k| !seen.contains(*k))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse_from(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_kv_and_flags() {
        // NB: a bare boolean flag followed by a non-flag token would consume
        // it as a value (ambiguity inherent to `--flag value` grammars), so
        // positional args come first or flags use `=`.
        let a = args(&["pos1", "--model", "mlr", "--trials=30", "--verbose"]);
        assert_eq!(a.str_or("model", ""), "mlr");
        assert_eq!(a.usize_or("trials", 0), 30);
        assert!(a.bool("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn defaults() {
        let a = args(&[]);
        assert_eq!(a.usize_or("n", 7), 7);
        assert_eq!(a.f64_or("p", 0.5), 0.5);
        assert!(!a.bool("flag"));
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = args(&["--x", "-3"]);
        assert_eq!(a.f64_or("x", 0.0), -3.0);
    }

    #[test]
    fn unknown_flags_reported() {
        let a = args(&["--typo", "1", "--ok", "2"]);
        let _ = a.usize_or("ok", 0);
        assert_eq!(a.unknown(), vec!["typo".to_string()]);
    }

    #[test]
    #[should_panic(expected = "expects an integer")]
    fn bad_int_panics() {
        let a = args(&["--n", "abc"]);
        let _ = a.usize_or("n", 0);
    }
}
